//! Resource models (§VI-B, Eq. 16–18): DSP packing and BRAM18K mapping.

use super::{ceil_div, TileConfig, Workload};

/// Resource usage of one engine configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Resources {
    pub dsp: usize,
    pub bram18k: usize,
}

impl Resources {
    pub fn add(self, other: Resources) -> Resources {
        Resources { dsp: self.dsp + other.dsp, bram18k: self.bram18k + other.bram18k }
    }

    pub fn fits(&self, dsp_budget: usize, bram_budget: usize) -> bool {
        self.dsp <= dsp_budget && self.bram18k <= bram_budget
    }
}

/// DSP packing factor `f_packing` [2]: a DSP48E2 (27x18 multiplier) packs
/// two sub-4-bit multiplies sharing one operand; 8-bit and above use one
/// DSP per multiply. (The M4BRAM work the paper cites explores deeper
/// packing; two-way INT4 packing is the standard Xilinx technique.)
pub fn f_packing(w_bits: u32) -> usize {
    if w_bits <= 4 {
        2
    } else {
        1
    }
}

/// BRAM18K units for a buffer of `depth` words x `width` bits, using the
/// standard UltraScale aspect-ratio table (512x36 .. 16384x1). Synthesis
/// picks the aspect ratio minimizing unit count; so do we.
pub fn bram18_units(depth: usize, width: u32) -> usize {
    if depth == 0 || width == 0 {
        return 0;
    }
    const CONFIGS: [(usize, u32); 6] =
        [(512, 36), (1024, 18), (2048, 9), (4096, 4), (8192, 2), (16384, 1)];
    CONFIGS
        .iter()
        .map(|&(d, w)| ceil_div(depth, d) * ceil_div(width as usize, w as usize))
        .min()
        .unwrap()
}

/// Eq. 16–18: resources of one `M_t x N_t x K_f` tile on workload `w`.
///
/// Each PE owns `ceil(K_f / f_packing)` DSPs, each DSP fed by its own
/// BRAM18-backed FIFO of depth `ceil(K/K_f)`; LHS buffers replicate per
/// PE-row (`M_t`), RHS per PE-column (`N_t`).
pub fn tile_resources(w: &Workload, t: &TileConfig) -> Resources {
    let fp = f_packing(w.w_bits);
    let dsp_pe = ceil_div(t.kf, fp);
    let dsp = t.mt * t.nt * dsp_pe;

    let buff_depth = ceil_div(w.k, t.kf);
    // LHS FIFOs hold activations, RHS FIFOs hold weights.
    let bram_pe_lhs = dsp_pe * bram18_units(buff_depth, w.a_bits);
    let bram_pe_rhs = dsp_pe * bram18_units(buff_depth, w.w_bits);
    let bram = t.mt * bram_pe_lhs + t.nt * bram_pe_rhs;
    Resources { dsp, bram18k: bram }
}

/// BRAM18K units to hold an `rows x cols` intermediate tile of
/// `bits`-bit words on-chip (the `M_t x R` buffer both SVD engines need).
pub fn intermediate_buffer_bram(rows: usize, cols: usize, bits: u32) -> usize {
    // Banked per row for parallel access by the consuming engine.
    rows * bram18_units(cols, bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packing_rule() {
        assert_eq!(f_packing(4), 2);
        assert_eq!(f_packing(3), 2);
        assert_eq!(f_packing(6), 1);
        assert_eq!(f_packing(8), 1);
    }

    #[test]
    fn bram_table_hand_checks() {
        // 512 x 36 fits exactly one unit.
        assert_eq!(bram18_units(512, 36), 1);
        // 1024 x 18 fits one unit via the 1024x18 aspect.
        assert_eq!(bram18_units(1024, 18), 1);
        // 64 x 8: one unit (well under capacity).
        assert_eq!(bram18_units(64, 8), 1);
        // 2048 x 36: 2048*36 = 72Kb -> 4 units via 2048x9 aspect x4.
        assert_eq!(bram18_units(2048, 36), 4);
        assert_eq!(bram18_units(0, 8), 0);
    }

    #[test]
    fn dsp_scales_with_tile_and_packing() {
        let w4 = Workload::new(512, 512, 512, 4, 8);
        let w8 = Workload::new(512, 512, 512, 8, 8);
        let t = TileConfig::new(8, 8, 8);
        let r4 = tile_resources(&w4, &t);
        let r8 = tile_resources(&w8, &t);
        assert_eq!(r4.dsp, 8 * 8 * 4); // Kf=8 packed 2-way -> 4 DSP/PE
        assert_eq!(r8.dsp, 8 * 8 * 8);
        assert!(r4.dsp < r8.dsp);
    }

    #[test]
    fn bram_scales_with_mt_nt() {
        let w = Workload::new(512, 512, 512, 8, 8);
        let small = tile_resources(&w, &TileConfig::new(4, 4, 8));
        let big = tile_resources(&w, &TileConfig::new(16, 16, 8));
        assert!(big.bram18k > small.bram18k);
    }

    #[test]
    fn fits_budget() {
        let r = Resources { dsp: 100, bram18k: 50 };
        assert!(r.fits(100, 50));
        assert!(!r.fits(99, 50));
        assert!(!r.fits(100, 49));
        let sum = r.add(Resources { dsp: 1, bram18k: 2 });
        assert_eq!(sum, Resources { dsp: 101, bram18k: 52 });
    }
}
