"""Layer-1 Pallas kernels (build-time; lowered into the model HLO)."""

from .quant_matmul import fake_quant, quant_matmul
from .svd_matmul import cascade_matmul

__all__ = ["quant_matmul", "fake_quant", "cascade_matmul"]
