//! Accuracy evaluation service: corpus loading + BLEU scoring.
//!
//! The paper reports BLEU on WMT2019 test sets; we score the synthetic
//! held-out sets written by the Python compile path (DESIGN.md
//! §Substitutions) with a standard corpus-level BLEU-4 (+brevity penalty)
//! implemented in [`bleu`].

pub mod bleu;
mod corpus;
mod evaluator;

pub use bleu::{bleu_score, BleuDetail};
pub use corpus::Corpus;
pub use evaluator::{evaluate_bleu, translate_corpus};

/// Strip BOS/EOS/PAD framing from a token row: keep tokens after the
/// leading BOS up to (excluding) the first EOS/PAD.
pub fn strip_specials(row: &[i32], bos: i32, eos: i32, pad: i32) -> Vec<i32> {
    let start = usize::from(row.first() == Some(&bos));
    let mut out = Vec::new();
    for &t in &row[start..] {
        if t == eos || t == pad {
            break;
        }
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strip_specials_basic() {
        assert_eq!(strip_specials(&[1, 5, 6, 2, 0, 0], 1, 2, 0), vec![5, 6]);
        assert_eq!(strip_specials(&[5, 6, 0], 1, 2, 0), vec![5, 6]);
        assert_eq!(strip_specials(&[1, 2], 1, 2, 0), Vec::<i32>::new());
        assert_eq!(strip_specials(&[], 1, 2, 0), Vec::<i32>::new());
    }
}
