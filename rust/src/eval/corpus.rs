//! `corpus_<pair>.bin` reader — token corpora written by
//! `python/compile/train.py::save_corpus`.
//!
//! Layout: magic `ITCP` | u32 n | u32 seq_len | i32 src[n*s] | i32 tgt[n*s].

use std::path::Path;

use anyhow::{bail, Context, Result};

/// A (source, reference) token corpus with fixed sequence length.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub n: usize,
    pub seq_len: usize,
    /// Row-major `[n x seq_len]`.
    src: Vec<i32>,
    tgt: Vec<i32>,
}

impl Corpus {
    pub fn load(path: impl AsRef<Path>) -> Result<Corpus> {
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("reading corpus {:?}", path.as_ref()))?;
        Self::parse(&bytes)
    }

    pub fn parse(bytes: &[u8]) -> Result<Corpus> {
        if bytes.len() < 12 || &bytes[..4] != b"ITCP" {
            bail!("not an ITCP corpus");
        }
        let n = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        let s = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let want = 12 + 2 * n * s * 4;
        if bytes.len() != want {
            bail!("corpus size mismatch: {} != {want}", bytes.len());
        }
        let read = |off: usize, count: usize| -> Vec<i32> {
            bytes[off..off + count * 4]
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()
        };
        Ok(Corpus { n, seq_len: s, src: read(12, n * s), tgt: read(12 + n * s * 4, n * s) })
    }

    pub fn src_row(&self, i: usize) -> &[i32] {
        &self.src[i * self.seq_len..(i + 1) * self.seq_len]
    }

    pub fn tgt_row(&self, i: usize) -> &[i32] {
        &self.tgt[i * self.seq_len..(i + 1) * self.seq_len]
    }

    /// First `k` rows as a (sub)corpus (cheap calibration subsets).
    pub fn head(&self, k: usize) -> Corpus {
        let k = k.min(self.n);
        Corpus {
            n: k,
            seq_len: self.seq_len,
            src: self.src[..k * self.seq_len].to_vec(),
            tgt: self.tgt[..k * self.seq_len].to_vec(),
        }
    }

    /// Flat source tokens for rows `[start, start+count)`, zero-padded to
    /// `count` rows — literal packing for a fixed-batch artifact.
    pub fn src_batch(&self, start: usize, count: usize, pad_id: i32) -> Vec<i32> {
        let mut out = vec![pad_id; count * self.seq_len];
        let end = (start + count).min(self.n);
        for (bi, i) in (start..end).enumerate() {
            out[bi * self.seq_len..(bi + 1) * self.seq_len]
                .copy_from_slice(self.src_row(i));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth(n: usize, s: usize) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(b"ITCP");
        b.extend_from_slice(&(n as u32).to_le_bytes());
        b.extend_from_slice(&(s as u32).to_le_bytes());
        for k in 0..2 * n * s {
            b.extend_from_slice(&(k as i32).to_le_bytes());
        }
        b
    }

    #[test]
    fn parse_and_index() {
        let c = Corpus::parse(&synth(3, 4)).unwrap();
        assert_eq!((c.n, c.seq_len), (3, 4));
        assert_eq!(c.src_row(1), &[4, 5, 6, 7]);
        assert_eq!(c.tgt_row(0), &[12, 13, 14, 15]);
    }

    #[test]
    fn head_and_batches() {
        let c = Corpus::parse(&synth(5, 3)).unwrap();
        let h = c.head(2);
        assert_eq!(h.n, 2);
        assert_eq!(h.tgt_row(1), &[18, 19, 20]);
        // Batch past the end zero-pads with pad_id.
        let b = c.src_batch(4, 2, -7);
        assert_eq!(&b[..3], c.src_row(4));
        assert_eq!(&b[3..], &[-7, -7, -7]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Corpus::parse(b"ITCPxx").is_err());
        let mut b = synth(2, 2);
        b.pop();
        assert!(Corpus::parse(&b).is_err());
    }

    #[test]
    fn loads_real_corpus() {
        let dir = crate::model::Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = crate::model::Manifest::load(&dir).unwrap();
        for (pair, info) in &m.pairs {
            let c = Corpus::load(&info.corpus).unwrap();
            assert_eq!(c.seq_len, m.model.seq_len, "{pair}");
            assert!(c.n >= 64, "{pair}: test corpus too small");
            // Every row is BOS-framed.
            for i in 0..c.n.min(8) {
                assert_eq!(c.src_row(i)[0], m.model.bos_id);
                assert_eq!(c.tgt_row(i)[0], m.model.bos_id);
            }
        }
    }
}
