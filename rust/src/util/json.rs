//! Minimal JSON parser + writer.
//!
//! The image vendors no `serde`/`serde_json` facade, so the library carries
//! its own small JSON implementation — enough for the artifact manifest,
//! platform/experiment configs, and report emission. Strict on structure,
//! permissive on whitespace; numbers are f64 (the manifest carries nothing
//! that loses precision).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use `BTreeMap` for deterministic iteration order
/// (reports and golden tests depend on stable output).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------- accessors ----------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null on any miss.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(v) => v.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ---------------- builders ----------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // ---------------- parsing ----------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // ---------------- writing ----------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Multi-byte UTF-8: copy the remaining continuation bytes.
                    let len = if c >= 0xf0 {
                        4
                    } else if c >= 0xe0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    self.pos = start + len;
                    s.push_str(
                        std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("bad utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").idx(2).get("b").as_str(), Some("x"));
        assert_eq!(j.get("c"), &Json::Null);
        assert_eq!(j.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"itera","nums":[1,2.5,-3],"ok":true,"sub":{"x":null}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""Aµλ""#).unwrap();
        assert_eq!(j.as_str(), Some("Aµλ"));
        let out = Json::Str("q\"\\\n".into()).to_string();
        assert_eq!(Json::parse(&out).unwrap().as_str(), Some("q\"\\\n"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn large_manifest_like() {
        let mut obj = BTreeMap::new();
        obj.insert(
            "scales".to_string(),
            Json::arr_f64(&(0..100).map(|i| i as f64 * 0.1).collect::<Vec<_>>()),
        );
        let j = Json::Obj(obj);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("scales").as_arr().unwrap().len(), 100);
        assert!((parsed.get("scales").idx(42).as_f64().unwrap() - 4.2).abs() < 1e-12);
    }
}
