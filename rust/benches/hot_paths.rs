//! Hot-path microbenchmarks (custom harness; no criterion in the image).
//!
//! Covers the compute kernels the perf pass optimizes (EXPERIMENTS.md
//! §Perf): Algorithm 1 and its SVD building blocks, quantization, the
//! dense matmul, the dataflow simulator, the DSE sweep, BLEU scoring, and
//! — when artifacts are present — the PJRT translate call that dominates
//! every figure runner.

use itera_llm::benchkit::Bench;
use itera_llm::compress::{itera, quant_only, svd_baseline};
use itera_llm::dse;
use itera_llm::eval::bleu_score;
use itera_llm::hw::{sim, EngineKind, Platform, TileConfig, Workload};
use itera_llm::linalg::{svd, svd_top1};
use itera_llm::quant;
use itera_llm::tensor::Matrix;
use itera_llm::util::rng::Pcg64;

fn main() {
    let mut b = Bench::new();
    let mut rng = Pcg64::new(0xBE7C);

    // ---- linalg -------------------------------------------------------
    let w64 = Matrix::randn(64, 64, &mut rng).scale(0.1);
    let w512 = Matrix::randn(512, 512, &mut rng).scale(0.1);
    b.bench("linalg/svd_jacobi_64x64", || {
        std::hint::black_box(svd(&w64));
    });
    b.bench("linalg/svd_top1_64x64", || {
        std::hint::black_box(svd_top1(&w64, 1));
    });
    b.bench("linalg/svd_top1_512x512", || {
        std::hint::black_box(svd_top1(&w512, 1));
    });

    // ---- tensor -------------------------------------------------------
    let a = Matrix::randn(256, 256, &mut rng);
    let c = Matrix::randn(256, 256, &mut rng);
    b.bench("tensor/matmul_256", || {
        std::hint::black_box(a.matmul(&c));
    });

    // ---- compression --------------------------------------------------
    b.bench("compress/itera_64x64_r32_w4", || {
        std::hint::black_box(itera(&w64, 32, 4));
    });
    b.bench("compress/itera_512x512_r64_w4", || {
        std::hint::black_box(itera(&w512, 64, 4));
    });
    b.bench("compress/svd_baseline_64x64_r32", || {
        std::hint::black_box(svd_baseline(&w64, 32, 4));
    });
    b.bench("compress/quant_only_512x512", || {
        std::hint::black_box(quant_only(&w512, 4));
    });
    b.bench("quant/quantize_cols_512x512", || {
        std::hint::black_box(quant::quantize_cols(&w512, 4));
    });

    // ---- hardware models ----------------------------------------------
    let w = Workload::new(512, 512, 512, 4, 8);
    let platform = Platform::zcu111();
    b.bench("hw/sim_matmul_512_t16", || {
        std::hint::black_box(sim::simulate_matmul(&w, &TileConfig::new(16, 16, 8), 427.0));
    });
    b.bench("dse/sweep_single_svd_512_r128", || {
        std::hint::black_box(dse::sweep_engines(
            &w,
            Some(128),
            &platform,
            &[EngineKind::SingleSvd],
        ));
    });
    b.bench("dse/best_design_all_kinds", || {
        std::hint::black_box(dse::best_design_for_layer(&w, Some(128), &platform));
    });

    // ---- eval -----------------------------------------------------------
    let refs: Vec<Vec<i32>> = (0..96)
        .map(|i| (0..16).map(|j| ((i * 17 + j * 3) % 120 + 3) as i32).collect())
        .collect();
    b.bench("eval/bleu_96x16", || {
        std::hint::black_box(bleu_score(&refs, &refs));
    });

    // ---- PJRT runtime (needs artifacts) ---------------------------------
    if itera_llm::model::Manifest::default_dir().join("manifest.json").exists() {
        use std::collections::BTreeMap;
        let manifest =
            itera_llm::model::Manifest::load(itera_llm::model::Manifest::default_dir()).unwrap();
        let engine = itera_llm::runtime::Engine::cpu().unwrap();
        let model = itera_llm::model::PairModel::load(&manifest, "en-de").unwrap();
        let corpus = itera_llm::eval::Corpus::load(&manifest.pairs["en-de"].corpus).unwrap();
        let session = itera_llm::runtime::TranslateSession::new(
            &engine,
            &manifest,
            itera_llm::runtime::Mode::Dense,
        )
        .unwrap();
        let bank = session.build_bank(&model, &BTreeMap::new(), None).unwrap();
        let src = corpus.src_batch(0, session.batch(), manifest.model.pad_id);
        b.bench("runtime/translate_batch16", || {
            std::hint::black_box(session.translate(&bank, &src).unwrap());
        });
        b.bench("runtime/build_bank_fp32", || {
            std::hint::black_box(session.build_bank(&model, &BTreeMap::new(), None).unwrap());
        });

        // 512^3 kernel artifact (the Fig. 10 workload via Pallas-lowered HLO).
        let exe = engine.load_hlo(&manifest.artifacts.linear512_dense).unwrap();
        let mut r = Pcg64::new(5);
        let x = Matrix::randn(512, 512, &mut r);
        let wm = Matrix::randn(512, 512, &mut r);
        let bx = engine.upload_f32(x.data(), &[512, 512]).unwrap();
        let bw = engine.upload_f32(wm.data(), &[512, 512]).unwrap();
        b.bench("runtime/linear512_dense_kernel", || {
            std::hint::black_box(engine.run_tuple1(&exe, &[&bx, &bw]).unwrap());
        });
    } else {
        eprintln!("(artifacts not built; skipping runtime benches)");
    }

    b.finish();
}
