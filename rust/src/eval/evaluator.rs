//! Batched corpus evaluation through a translate session.

use anyhow::Result;

use crate::model::ModelDims;
use crate::runtime::{ArgBank, TranslateSession};

use super::{bleu_score, strip_specials, BleuDetail, Corpus};

/// Greedy-translate up to `limit` sentences of `corpus` (0 = all) and
/// return the de-framed hypothesis token sequences.
pub fn translate_corpus(
    session: &TranslateSession,
    bank: &ArgBank,
    corpus: &Corpus,
    dims: &ModelDims,
    limit: usize,
) -> Result<Vec<Vec<i32>>> {
    let n = if limit == 0 { corpus.n } else { limit.min(corpus.n) };
    let b = session.batch();
    let s = session.seq_len();
    let mut hyps = Vec::with_capacity(n);
    let mut start = 0;
    while start < n {
        let src = corpus.src_batch(start, b, dims.pad_id);
        let out = session.translate(bank, &src)?;
        let take = (n - start).min(b);
        for r in 0..take {
            hyps.push(strip_specials(
                &out[r * s..(r + 1) * s],
                dims.bos_id,
                dims.eos_id,
                dims.pad_id,
            ));
        }
        start += b;
    }
    Ok(hyps)
}

/// BLEU of a configuration over (a prefix of) a corpus.
pub fn evaluate_bleu(
    session: &TranslateSession,
    bank: &ArgBank,
    corpus: &Corpus,
    dims: &ModelDims,
    limit: usize,
) -> Result<BleuDetail> {
    let hyps = translate_corpus(session, bank, corpus, dims, limit)?;
    let refs: Vec<Vec<i32>> = (0..hyps.len())
        .map(|i| strip_specials(corpus.tgt_row(i), dims.bos_id, dims.eos_id, dims.pad_id))
        .collect();
    Ok(bleu_score(&hyps, &refs))
}
