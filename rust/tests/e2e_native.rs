//! End-to-end integration on the native runtime: testkit tiny model ->
//! greedy translate -> BLEU -> serving loop — in the **default** build.
//!
//! This is the suite the `pjrt`-gated `e2e_runtime.rs` could never be:
//! hermetic (the testkit generator synthesizes the weight store, manifest
//! and corpus — no Python artifacts) and always compiled, so CI exercises
//! true end-to-end execution on every push. The load-bearing assertions:
//!
//! * greedy decode is **bit-deterministic** — across calls, across
//!   separately constructed backends, and across worker counts (the
//!   parallel matmul is bit-identical to serial);
//! * the **factored** (two skinny matmuls, true rank) path matches the
//!   **dense** path executing the reconstructed `w1·w2` weights within
//!   float-association tolerance, with any greedy-token divergence
//!   accounted for by a genuine near-tie in the dense trajectory;
//! * truncated-rank factored execution **costs fewer MACs** than dense —
//!   the paper's FLOP savings realized at runtime, not just on paper;
//! * BLEU evaluation and the request-batching serve loop run end-to-end —
//!   and the continuous (slot-scheduled) serve loop answers every request
//!   with exactly the static batcher's tokens while balancing its
//!   request/response/latency accounting (the soak test);
//! * under a byte-bounded paged KV pool, preemption-by-eviction and
//!   re-prefill keep survivor outputs bit-identical to an unbounded run
//!   and leak zero pages (the memory-pressure soak).

use std::collections::BTreeMap;

use itera_llm::compress::{itera, quant_only, CompressedLinear};
use itera_llm::coordinator::{Batcher, ServeTuning};
use itera_llm::eval::{evaluate_bleu, translate_corpus, Corpus};
use itera_llm::model::{Manifest, PairModel};
use itera_llm::runtime::{DecodePolicy, KernelTier, Mode, NativeBackend, TranslateBackend};
use itera_llm::testkit::tinymodel;

struct Fixture {
    dir: std::path::PathBuf,
    manifest: Manifest,
    model: PairModel,
    corpus: Corpus,
}

impl Drop for Fixture {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.dir).ok();
    }
}

fn fixture(tag: &str) -> Fixture {
    let (dir, manifest) = tinymodel::generate_in_temp(tag, 0x7E57).expect("generate tiny model");
    let model = PairModel::load(&manifest, tinymodel::PAIR).expect("load tiny model");
    let corpus = Corpus::load(&manifest.pairs[tinymodel::PAIR].corpus).expect("load tiny corpus");
    Fixture { dir, manifest, model, corpus }
}

/// Factor every linear through Algorithm 1 at `rank_frac` of r_max, W`wl`.
fn factor_all(f: &Fixture, rank_frac: f64, wl: u32) -> BTreeMap<String, CompressedLinear> {
    let mut layers = BTreeMap::new();
    for l in &f.manifest.linears {
        let r = ((l.r_max as f64 * rank_frac).round() as usize).clamp(1, l.r_max);
        let (c, _) = itera(f.model.linear(&l.name), r, wl);
        layers.insert(l.name.clone(), c);
    }
    layers
}

/// Quantization-only compression of every linear at W`wl`.
fn quant_all(f: &Fixture, wl: u32) -> BTreeMap<String, CompressedLinear> {
    f.manifest
        .linears
        .iter()
        .map(|l| (l.name.clone(), quant_only(f.model.linear(&l.name), wl)))
        .collect()
}

#[test]
fn fp32_pipeline_translates_and_scores() {
    let f = fixture("fp32_pipeline");
    let backend = NativeBackend::fp32(&f.manifest, &f.model, 2).unwrap();
    assert_eq!(backend.kind(), "native");
    let dims = &f.manifest.model;

    let hyps = translate_corpus(&backend, &f.corpus, dims, 0).unwrap();
    assert_eq!(hyps.len(), f.corpus.n, "every corpus row gets a hypothesis");
    for h in &hyps {
        assert!(h.len() < dims.seq_len, "de-framed hypothesis fits the buffer");
        for &t in h {
            assert!(
                t >= 0 && (t as usize) < dims.vocab,
                "emitted token {t} outside the vocabulary"
            );
            assert!(
                t != dims.pad_id && t != dims.eos_id,
                "strip_specials must cut at EOS/PAD, got {t}"
            );
        }
    }
    // BLEU runs end-to-end and lands in range (the random tiny model is
    // not trained, so the score itself is incidental).
    let d = evaluate_bleu(&backend, &f.corpus, dims, 0).unwrap();
    assert!((0.0..=100.0).contains(&d.score), "BLEU {}", d.score);
}

#[test]
fn greedy_decode_is_bit_deterministic() {
    let f = fixture("determinism");
    let dims = &f.manifest.model;
    let src = f.corpus.src_batch(0, dims.eval_batch, dims.pad_id);

    let b1 = NativeBackend::fp32(&f.manifest, &f.model, 1).unwrap();
    let first = b1.translate(&src).unwrap();
    assert_eq!(first, b1.translate(&src).unwrap(), "repeat call must be bit-identical");

    // A separately constructed backend — and one with a different worker
    // count (the pool-parallel matmul is bit-identical to serial) — must
    // reproduce the exact token stream.
    let model2 = PairModel::load(&f.manifest, tinymodel::PAIR).unwrap();
    let b2 = NativeBackend::fp32(&f.manifest, &model2, 3).unwrap();
    assert_eq!(first, b2.translate(&src).unwrap(), "fresh backend, more workers");

    // Output is BOS-framed like the AOT graph's buffer.
    for r in 0..dims.eval_batch {
        assert_eq!(first[r * dims.seq_len], dims.bos_id, "row {r} starts with BOS");
    }
}

/// Top-2 logit margins along an already-decoded trajectory `out`:
/// `margins[r][i]` is the margin of the logits row that chose position
/// `i+1` of batch row `r`. One teacher-forced forward pass suffices —
/// causal masking (masked attention weights underflow to exactly 0 and
/// are skipped) makes position `i`'s logits over the full buffer
/// identical to what the greedy loop saw at step `i`, when positions
/// past `i` were still PAD. Because the margins are measured along
/// `out` itself, they stay valid for judging a divergence *from* `out`
/// even after an earlier near-tie.
fn margins_along(
    backend: &NativeBackend,
    src: &[i32],
    out: &[i32],
    dims: &itera_llm::model::ModelDims,
) -> Vec<Vec<f32>> {
    let s = dims.seq_len;
    let b = src.len() / s;
    let logits = backend.forward_logits(src, out).unwrap();
    let mut margins = vec![vec![f32::INFINITY; s - 1]; b];
    for r in 0..b {
        for i in 0..s - 1 {
            let row = logits.row(r * s + i);
            let mut best = 0usize;
            for (v, &x) in row.iter().enumerate() {
                if x > row[best] {
                    best = v;
                }
            }
            let second = row
                .iter()
                .enumerate()
                .filter(|(v, _)| *v != best)
                .fold(f32::NEG_INFINITY, |m, (_, &x)| m.max(x));
            margins[r][i] = row[best] - second;
        }
    }
    margins
}

/// Assert two decoded buffers agree row by row; a divergence is only
/// tolerated if `margins` (measured along trajectory `a`) show a genuine
/// near-tie at the first differing step of that row.
fn assert_match_or_near_tie(a: &[i32], b: &[i32], margins: &[Vec<f32>], s: usize, what: &str) {
    let rows = a.len() / s;
    for r in 0..rows {
        let (ra, rb) = (&a[r * s..(r + 1) * s], &b[r * s..(r + 1) * s]);
        if ra == rb {
            continue;
        }
        let first = (0..s).find(|&i| ra[i] != rb[i]).unwrap();
        assert!(first > 0, "{what}: BOS slot differs in row {r}");
        let margin = margins[r][first - 1];
        assert!(
            margin < 1e-2,
            "{what}: row {r} diverges at position {first} with a wide top-2 \
             margin {margin} — a real numerical bug, not a near-tie \
             ({ra:?} vs {rb:?})"
        );
    }
}

#[test]
fn factored_path_matches_dense_reconstruction() {
    let f = fixture("parity");
    let dims = &f.manifest.model;
    // Full-rank Algorithm-1 factors, FP32 activations: the dense backend
    // executes the reconstructed product w1·w2, the factored backend the
    // two skinny matmuls — same math, different float association.
    let layers = factor_all(&f, 1.0, 8);
    let dense = NativeBackend::new(&f.manifest, &f.model, &layers, None, Mode::Dense, 2).unwrap();
    let fact = NativeBackend::new(&f.manifest, &f.model, &layers, None, Mode::Svd, 2).unwrap();

    // Teacher-forced logits agree within float-association tolerance.
    let src = f.corpus.src_batch(0, dims.eval_batch, dims.pad_id);
    let tgt = f.corpus.src_batch(0, dims.eval_batch, dims.pad_id); // copy pair
    let ld = dense.forward_logits(&src, &tgt).unwrap();
    let lf = fact.forward_logits(&src, &tgt).unwrap();
    assert_eq!(ld.shape(), lf.shape());
    let mut max_err = 0.0f32;
    for (a, b) in ld.data().iter().zip(lf.data()) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(
        max_err < 1e-3,
        "factored vs dense logits drifted beyond tolerance: max err {max_err}"
    );

    // Greedy outputs agree token-for-token, except where the dense
    // trajectory itself had a near-tie (then either choice is valid).
    // Margins are measured along dense_out's own trajectory, so the
    // judgement stays sound even if dense_out contains a near-tie pick.
    let dense_out = dense.translate(&src).unwrap();
    let fact_out = fact.translate(&src).unwrap();
    let margins = margins_along(&dense, &src, &dense_out, dims);
    assert_match_or_near_tie(&dense_out, &fact_out, &margins, dims.seq_len, "factored vs dense");
}

#[test]
fn truncated_factored_path_saves_macs_and_runs() {
    let f = fixture("flops");
    let dims = &f.manifest.model;
    let layers = factor_all(&f, 0.25, 8); // quarter rank: r=4 on 16x16
    let dense =
        NativeBackend::new(&f.manifest, &f.model, &layers, Some(8), Mode::Dense, 2).unwrap();
    let fact =
        NativeBackend::new(&f.manifest, &f.model, &layers, Some(8), Mode::Svd, 2).unwrap();
    let macs_dense = dense.linear_macs_per_translate(dims.eval_batch);
    let macs_fact = fact.linear_macs_per_translate(dims.eval_batch);
    assert!(
        macs_fact * 2 <= macs_dense,
        "quarter-rank factors must at least halve linear MACs: {macs_fact} vs {macs_dense}"
    );
    // And the cheap path actually executes + scores.
    let d = evaluate_bleu(&fact, &f.corpus, dims, 4).unwrap();
    assert!((0.0..=100.0).contains(&d.score));
}

#[test]
fn svd_mode_rejects_unfactored_layers() {
    let f = fixture("reject");
    let mut layers = BTreeMap::new();
    for l in &f.manifest.linears {
        layers.insert(l.name.clone(), quant_only(f.model.linear(&l.name), 8));
    }
    let err = NativeBackend::new(&f.manifest, &f.model, &layers, Some(8), Mode::Svd, 1);
    assert!(err.is_err(), "Dense layers must be rejected by the factored execution mode");
    // ... and a missing layer is rejected too.
    let err = NativeBackend::new(&f.manifest, &f.model, &BTreeMap::new(), Some(8), Mode::Svd, 1);
    assert!(err.is_err(), "SVD mode requires every linear to be factored");
}

#[test]
fn serve_demo_runs_on_the_native_backend() {
    let f = fixture("serve");
    let stats = itera_llm::coordinator::serve_demo_native(
        &f.manifest,
        tinymodel::PAIR,
        10,
        2,
        Mode::Dense,
        DecodePolicy::Cached,
        Batcher::Static,
        &ServeTuning::default(),
    )
    .unwrap();
    assert_eq!(stats.served, 10, "every request must be answered");
    assert_eq!(stats.received, 10, "requests in == responses out");
    assert!(stats.batches >= 1 && stats.batches <= 10);
    assert!(stats.wall_s > 0.0);
    // Serving throughput is observable: the loop counts generated tokens
    // and per-request latency, not just batch totals.
    assert!(stats.tokens > 0, "echoing real sentences must emit tokens");
    assert!(stats.tokens_per_s() > 0.0);
    assert_eq!(stats.latency.count(), 10, "one latency sample per request");
}

#[test]
fn serve_demo_runs_quantized() {
    // The serving loop end-to-end on the bit-packed W8 bank.
    let f = fixture("serve_q");
    let stats = itera_llm::coordinator::serve_demo_native(
        &f.manifest,
        tinymodel::PAIR,
        6,
        2,
        Mode::Quantized,
        DecodePolicy::Cached,
        Batcher::Static,
        &ServeTuning::default(),
    )
    .unwrap();
    assert_eq!(stats.served, 6, "every request must be answered");
}

#[test]
fn serve_demo_replay_and_cached_translate_identically() {
    // The serving path produces the same translations under either
    // decode policy (closed-loop client, same request stream).
    let f = fixture("serve_decode");
    let cached = itera_llm::coordinator::serve_demo_native(
        &f.manifest,
        tinymodel::PAIR,
        8,
        2,
        Mode::Dense,
        DecodePolicy::Cached,
        Batcher::Static,
        &ServeTuning::default(),
    )
    .unwrap();
    let replay = itera_llm::coordinator::serve_demo_native(
        &f.manifest,
        tinymodel::PAIR,
        8,
        2,
        Mode::Dense,
        DecodePolicy::Replay,
        Batcher::Static,
        &ServeTuning::default(),
    )
    .unwrap();
    assert_eq!(cached.served, replay.served);
    assert_eq!(
        cached.tokens, replay.tokens,
        "same deterministic request stream must emit the same token count"
    );
}

#[test]
fn serve_demo_runs_continuous() {
    // The full demo path (closed-loop client + continuous scheduler) on
    // the bit-packed W8 bank, and the replay guard: continuous requires
    // the cached decode policy.
    let f = fixture("serve_cont");
    let stats = itera_llm::coordinator::serve_demo_native(
        &f.manifest,
        tinymodel::PAIR,
        6,
        2,
        Mode::Quantized,
        DecodePolicy::Cached,
        Batcher::Continuous,
        &ServeTuning::default(),
    )
    .unwrap();
    assert_eq!(stats.served, 6, "every request must be answered");
    assert_eq!(stats.received, 6, "requests in == responses out");
    let err = itera_llm::coordinator::serve_demo_native(
        &f.manifest,
        tinymodel::PAIR,
        2,
        2,
        Mode::Dense,
        DecodePolicy::Replay,
        Batcher::Continuous,
        &ServeTuning::default(),
    );
    assert!(err.is_err(), "continuous batching over replay decode must be rejected");
}

/// THE continuous-batching serving soak bar: the full tinymodel corpus
/// (every row, repeated) through `serve_loop_continuous` at capacity 3
/// must (a) answer every request with **exactly** the tokens the static
/// batcher serves, (b) balance its token accounting (requests in ==
/// responses out, one latency sample each, all finite/non-negative), and
/// (c) keep the slots busy (occupancy) on a backlogged trace.
#[test]
fn serve_continuous_soak_matches_static_batching() {
    use std::sync::mpsc;

    use itera_llm::coordinator::{
        response_channel, serve_loop, serve_loop_continuous, Request, ServeConfig,
    };

    let f = fixture("soak");
    let dims = &f.manifest.model;
    let backend = NativeBackend::fp32(&f.manifest, &f.model, 2).unwrap();

    // The full corpus, twice over — enough lifecycle churn to exercise
    // retire/admit/reuse on every slot.
    let rows: Vec<Vec<i32>> = (0..2 * f.corpus.n)
        .map(|i| f.corpus.src_row(i % f.corpus.n).to_vec())
        .collect();
    let n = rows.len();

    // One pre-queued (open-loop) channel per serving discipline, same
    // request stream.
    let serve = |continuous: bool| {
        let (tx, rx) = mpsc::channel::<Request>();
        let mut receivers = Vec::new();
        for row in &rows {
            let (rtx, rrx) = response_channel();
            tx.send(Request::new(row.clone(), rtx)).unwrap();
            receivers.push(rrx);
        }
        drop(tx);
        let stats = if continuous {
            serve_loop_continuous(&backend, &rx, dims, n, &ServeConfig::new(3)).unwrap()
        } else {
            serve_loop(&backend, &rx, dims, n).unwrap()
        };
        let responses: Vec<(Vec<i32>, f64)> = receivers
            .into_iter()
            .map(|r| {
                let resp = r
                    .recv()
                    .expect("server answers every request")
                    .expect("fault-free soak must succeed");
                (resp.tokens, resp.latency_s)
            })
            .collect();
        (stats, responses)
    };

    let (stat_s, resp_s) = serve(false);
    let (stat_c, resp_c) = serve(true);

    // (a) Bit-identical responses, request by request.
    for (i, ((ts, _), (tc, _))) in resp_s.iter().zip(&resp_c).enumerate() {
        assert_eq!(ts, tc, "request {i}: continuous response diverged from static");
    }

    // (b) Accounting balances on both sides.
    for (tag, stats, resp) in [("static", &stat_s, &resp_s), ("continuous", &stat_c, &resp_c)] {
        assert_eq!(stats.served, n, "{tag}: every request answered");
        assert_eq!(stats.received, n, "{tag}: requests in == responses out");
        assert_eq!(stats.failed(), 0, "{tag}: fault-free soak has no error outcomes");
        assert!(stats.is_balanced(), "{tag}: accounting identity violated: {stats:?}");
        let resp_tokens: usize = resp.iter().map(|(t, _)| t.len()).sum();
        assert_eq!(stats.tokens, resp_tokens, "{tag}: token counts balance");
        assert_eq!(stats.latency.count(), n, "{tag}: one latency sample per request");
        assert!(stats.latency.min() >= 0.0, "{tag}: negative latency");
        assert!(stats.latency.max().is_finite(), "{tag}: non-finite latency");
        for (_, lat) in resp.iter() {
            assert!(*lat >= 0.0 && lat.is_finite(), "{tag}: bad per-response latency");
        }
    }
    assert_eq!(stat_s.tokens, stat_c.tokens, "same stream, same generated tokens");

    // (c) A fully backlogged trace keeps the slots hot. (Conservative
    // floor: the random tiny model's lifecycles vary per row, so the
    // drain tail can cost real occupancy at capacity 3; the scheduler
    // unit tests pin exact occupancy on scripted traces and the longer
    // staggered bench workload sits above 0.9.)
    assert!(
        stat_c.occupancy > 0.5,
        "continuous occupancy {} too low for a backlogged trace",
        stat_c.occupancy
    );
    assert!(stat_c.batches > 0, "continuous loop must report decode steps");
}

/// THE fault-tolerance chaos soak: the native engine wrapped in the
/// deterministic fault-injection harness at capacity 3, with scripted
/// admission faults (`Err` and panic), scripted step faults (`Err` and
/// panic), one stalling slot reclaimed by its deadline, and two clients
/// that disconnect before serving starts — all driven through an
/// open-ended server that only a [`ShutdownSignal`] drain ends. Proves
/// the PR's acceptance bar: every submitted request receives exactly
/// one terminal outcome, non-faulted responses are **bit-identical** to
/// a fault-free run, and the graceful shutdown drains with balanced
/// `received == served + shed + expired + cancelled + faulted`
/// accounting.
#[test]
fn serve_continuous_chaos_soak_is_exactly_once_and_bit_identical() {
    use std::sync::mpsc;

    use itera_llm::coordinator::{
        response_channel, serve_loop_continuous, Request, RequestLimits, ResponseRx, ServeConfig,
        ServeError, ServeResult, ShutdownSignal,
    };
    use itera_llm::testkit::faultkit::{FaultScript, FaultyEngine};

    let f = fixture("chaos");
    let dims = &f.manifest.model;
    let backend = NativeBackend::fp32(&f.manifest, &f.model, 2).unwrap();

    const N: usize = 12;
    const DROPPED: [usize; 2] = [4, 9];
    let rows: Vec<Vec<i32>> =
        (0..N).map(|i| f.corpus.src_row(i % f.corpus.n).to_vec()).collect();

    // Fault-free reference run on the bare engine: the bit-identity bar.
    let reference: Vec<Vec<i32>> = {
        let (tx, rx) = mpsc::channel::<Request>();
        let receivers: Vec<ResponseRx> = rows
            .iter()
            .map(|row| {
                let (rtx, rrx) = response_channel();
                tx.send(Request::new(row.clone(), rtx)).unwrap();
                rrx
            })
            .collect();
        drop(tx);
        let stats =
            serve_loop_continuous(&backend, &rx, dims, N, &ServeConfig::new(3)).unwrap();
        assert_eq!(stats.served, N, "reference run is fault-free");
        receivers
            .iter()
            .map(|r| r.recv().expect("answered").expect("fault-free").tokens)
            .collect()
    };

    // Scripts are indexed by ADMISSION order. Disconnected clients are
    // cancelled out of the queue before the first tick, so the admission
    // order is the submission order with the dropped requests removed.
    let survivors: Vec<usize> = (0..N).filter(|i| !DROPPED.contains(i)).collect();
    let mut scripts = vec![FaultScript::clean(); survivors.len()];
    scripts[1] =
        FaultScript { born_poisoned: true, stalls: false, fault_at_step: None, panics: false };
    scripts[3] =
        FaultScript { born_poisoned: false, stalls: true, fault_at_step: None, panics: false };
    scripts[5] =
        FaultScript { born_poisoned: true, stalls: false, fault_at_step: None, panics: true };
    scripts[7] =
        FaultScript { born_poisoned: false, stalls: false, fault_at_step: Some(0), panics: true };
    scripts[8] =
        FaultScript { born_poisoned: false, stalls: false, fault_at_step: Some(0), panics: false };
    let engine = FaultyEngine::scripted(&backend, scripts.clone());

    let (tx, rx) = mpsc::channel::<Request>();
    let mut receivers: Vec<Option<ResponseRx>> = Vec::new();
    for (i, row) in rows.iter().enumerate() {
        let (rtx, rrx) = response_channel();
        // The stalling admission carries a short per-request deadline
        // (the reclaim path); everyone else decodes to EOS unbounded.
        let req = if survivors.iter().position(|&s| s == i) == Some(3) {
            Request::new(row.clone(), rtx).with_limits(RequestLimits::none().with_deadline(10))
        } else {
            Request::new(row.clone(), rtx)
        };
        tx.send(req).unwrap();
        // Dropping the receiver here IS the client disconnect.
        receivers.push(if DROPPED.contains(&i) { None } else { Some(rrx) });
    }

    let signal = ShutdownSignal::new();
    let cfg = ServeConfig {
        capacity: 3,
        queue_limit: None,
        default_limits: RequestLimits::none(),
        shutdown: Some(signal.clone()),
        ..Default::default()
    };
    // Collector thread: gather every surviving client's terminal
    // outcome, then flip the drain signal; the open-ended server
    // (`n_requests = usize::MAX`) runs on this thread until the drain.
    let drainer = signal.clone();
    let collector = std::thread::spawn(move || {
        let outs: Vec<(usize, ServeResult)> = receivers
            .into_iter()
            .enumerate()
            .filter_map(|(i, r)| r.map(|rrx| (i, rrx.recv().expect("server answers"))))
            .collect();
        drainer.drain();
        outs
    });
    let stats = serve_loop_continuous(&engine, &rx, dims, usize::MAX, &cfg).unwrap();
    let outcomes = collector.join().expect("collector thread");
    drop(tx);

    // Exactly one terminal outcome per surviving client, classified by
    // its script; survivors bit-identical to the fault-free reference.
    assert_eq!(outcomes.len(), N - DROPPED.len());
    for (i, out) in outcomes {
        let adm = survivors.iter().position(|&s| s == i).unwrap();
        let script = scripts[adm];
        if script.survives() {
            let resp = out.unwrap_or_else(|e| panic!("clean request {i} must survive, got {e}"));
            assert_eq!(
                resp.tokens, reference[i],
                "request {i}: survivor must be bit-identical to the fault-free run"
            );
        } else if script.stalls {
            assert!(
                matches!(out, Err(ServeError::DeadlineExceeded)),
                "request {i}: stalled slot must be reclaimed by its deadline, got {out:?}"
            );
        } else {
            assert!(
                matches!(out, Err(ServeError::EngineFault(_))),
                "request {i}: scripted fault must surface as EngineFault, got {out:?}"
            );
        }
    }

    // Graceful shutdown drained with balanced books.
    assert_eq!(stats.received, N);
    assert_eq!(stats.served, 5, "five clean admissions");
    assert_eq!(stats.cancelled, DROPPED.len(), "disconnects cancelled, not decoded");
    assert_eq!(stats.faulted, 4, "two poisoned admissions + two step faults");
    assert_eq!(stats.expired, 1, "the stalled slot expired");
    assert_eq!(stats.shed, 0, "unbounded queue sheds nothing");
    assert!(stats.is_balanced(), "accounting identity violated: {stats:?}");
    assert!(stats.batches > 0);
    assert_eq!(engine.admitted() as usize, survivors.len(), "one admission per surviving request");
}

/// Overload shedding end-to-end: a 12-request burst against capacity 3
/// with a queue bound of 3. The pre-queued burst lands before the first
/// tick, so the queue absorbs 3 requests and the other 9 are answered
/// immediately with a typed `Overloaded` rejection — nobody waits, and
/// the books balance. (The CI overload smoke drives the same path via
/// `itera serve --tinymodel --burst N --queue-limit N`.)
#[test]
fn serve_continuous_overload_sheds_and_balances() {
    use std::sync::mpsc;

    use itera_llm::coordinator::{
        response_channel, serve_loop_continuous, Request, ResponseRx, ServeConfig, ServeError,
    };

    let f = fixture("overload");
    let dims = &f.manifest.model;
    let backend = NativeBackend::fp32(&f.manifest, &f.model, 2).unwrap();

    const N: usize = 12;
    let (tx, rx) = mpsc::channel::<Request>();
    let receivers: Vec<ResponseRx> = (0..N)
        .map(|i| {
            let (rtx, rrx) = response_channel();
            tx.send(Request::new(f.corpus.src_row(i % f.corpus.n).to_vec(), rtx)).unwrap();
            rrx
        })
        .collect();
    drop(tx);

    let mut cfg = ServeConfig::new(3);
    cfg.queue_limit = Some(3);
    let stats = serve_loop_continuous(&backend, &rx, dims, N, &cfg).unwrap();

    assert_eq!(stats.received, N);
    assert_eq!(stats.shed, N - 3, "queue bound 3 absorbs 3 of the burst");
    assert_eq!(stats.served, 3);
    assert!(stats.is_balanced(), "accounting identity violated: {stats:?}");
    let (mut ok, mut over) = (0usize, 0usize);
    for rrx in &receivers {
        match rrx.recv() {
            Some(Ok(_)) => ok += 1,
            Some(Err(ServeError::Overloaded)) => over += 1,
            other => panic!("unexpected outcome {other:?}"),
        }
    }
    assert_eq!((ok, over), (3, N - 3), "every burst request answered exactly once");
}

/// THE memory-pressure chaos soak: the native engine on a page-backed
/// KV pool with a deliberately tight byte budget (one slot's worst case
/// plus four one-token pages), wrapped in the fault-injection harness —
/// one scripted step fault and one poisoned admission ride on top of
/// continuous eviction pressure. The workload is N copies of the
/// longest-decoding corpus row, so two live slots are guaranteed to
/// outgrow the budget mid-decode and the younger one is evicted back to
/// the queue and re-prefilled. The bars: survivors are **bit-identical**
/// to a fault-free run on an unbounded pool (eviction + replay changes
/// nothing), the accounting identity balances with the two scripted
/// faults, and **zero KV pages leak** across every retirement path
/// (retire, fault, evict). This is the e2e the CI memory leg runs.
#[test]
fn serve_continuous_memory_pressure_soak_is_bit_identical_and_leak_free() {
    use std::sync::mpsc;

    use itera_llm::coordinator::{
        response_channel, serve_loop_continuous, Request, ResponseRx, ServeConfig, ServeError,
    };
    use itera_llm::runtime::SlotEngine;
    use itera_llm::testkit::faultkit::{FaultScript, FaultyEngine};

    let f = fixture("mempress");
    let dims = &f.manifest.model;
    let s = dims.seq_len;
    let unbounded = NativeBackend::fp32(&f.manifest, &f.model, 2).unwrap();

    // Probe for the corpus row with the longest greedy decode:
    // long-lived slots are what make two live sequences outgrow a tight
    // budget at the same time.
    let probe: Vec<Vec<i32>> = (0..f.corpus.n).map(|i| f.corpus.src_row(i).to_vec()).collect();
    let outs = unbounded.translate_stream(&probe).unwrap();
    let steps_of = |out: &[i32]| {
        out[1..s].iter().position(|&t| t == dims.eos_id).map(|p| p + 1).unwrap_or(s - 1)
    };
    let longest = (0..probe.len()).max_by_key(|&i| steps_of(&outs[i])).unwrap();
    let long_steps = steps_of(&outs[longest]);

    const N: usize = 10;
    let rows: Vec<Vec<i32>> = (0..N).map(|_| probe[longest].clone()).collect();

    // Fault-free reference on the unbounded pool: the bit-identity bar.
    let reference: Vec<Vec<i32>> = {
        let (tx, rx) = mpsc::channel::<Request>();
        let receivers: Vec<ResponseRx> = rows
            .iter()
            .map(|row| {
                let (rtx, rrx) = response_channel();
                tx.send(Request::new(row.clone(), rtx)).unwrap();
                rrx
            })
            .collect();
        drop(tx);
        let stats =
            serve_loop_continuous(&unbounded, &rx, dims, N, &ServeConfig::new(3)).unwrap();
        assert_eq!(stats.served, N, "reference run is fault-free");
        assert_eq!(stats.preempted, 0, "unbounded pool never preempts");
        receivers
            .iter()
            .map(|r| r.recv().expect("answered").expect("fault-free").tokens)
            .collect()
    };

    // One-token pages, budget = worst case + 4 pages: a second slot is
    // admitted as soon as the gate sees room, but two long decodes can
    // never both reach full length.
    let paged = NativeBackend::fp32(&f.manifest, &f.model, 2).unwrap().with_kv_pool(None, 1);
    let worst = paged.slot_worst_bytes();
    let page = paged.kv_pool().page_bytes();
    let budget = worst + 4 * page;
    let paged = paged.with_kv_pool(Some(budget), 1);

    // Chaos rider: admission #0 (request 0, admitted alone on the first
    // tick) faults at its first decode step; admission #1 (request 1) is
    // born poisoned. Every later admission — including preemption
    // re-admissions — falls past the script list and is clean.
    let scripts = vec![
        FaultScript { fault_at_step: Some(0), ..FaultScript::clean() },
        FaultScript { born_poisoned: true, ..FaultScript::clean() },
    ];
    let engine = FaultyEngine::scripted(&paged, scripts);

    let (tx, rx) = mpsc::channel::<Request>();
    let receivers: Vec<ResponseRx> = rows
        .iter()
        .map(|row| {
            let (rtx, rrx) = response_channel();
            tx.send(Request::new(row.clone(), rtx)).unwrap();
            rrx
        })
        .collect();
    drop(tx);
    let stats = serve_loop_continuous(&engine, &rx, dims, N, &ServeConfig::new(3)).unwrap();

    // The two scripted victims fault; every survivor must be
    // bit-identical to the fault-free unbounded run — eviction plus
    // re-prefill may not change a single token.
    for (i, rrx) in receivers.iter().enumerate() {
        let out = rrx.recv().expect("server answers every request");
        if i < 2 {
            assert!(
                matches!(out, Err(ServeError::EngineFault(_))),
                "request {i}: scripted fault must surface as EngineFault, got {out:?}"
            );
        } else {
            let resp = out.unwrap_or_else(|e| panic!("survivor {i} must be served, got {e}"));
            assert_eq!(
                resp.tokens, reference[i],
                "request {i}: survivor diverged after preemption/re-prefill"
            );
        }
    }

    assert_eq!(stats.received, N);
    assert_eq!(stats.served, N - 2);
    assert_eq!(stats.faulted, 2, "one step fault + one poisoned admission");
    assert_eq!((stats.shed, stats.expired, stats.cancelled), (0, 0, 0), "{stats:?}");
    assert!(stats.is_balanced(), "accounting identity violated: {stats:?}");

    // Guaranteed preemption whenever the longest decode actually runs
    // long (a random tiny model decodes most rows to the buffer end;
    // guarded so the bar never hinges on incidental corpus content).
    if long_steps >= 8 {
        assert!(
            stats.preempted >= 1,
            "two {long_steps}-step decodes under a {}-page budget must collide",
            budget / page
        );
    }

    // Zero page leaks: every retirement path released its slot's pages.
    assert_eq!(paged.kv_pool().outstanding_pages(), 0, "leaked KV pages after drain");
    assert_eq!(paged.kv_pool().resident_bytes(), 0, "resident bytes after drain");
}

/// Backend over `layers` at A8 with the given execution mode.
fn backend(
    f: &Fixture,
    layers: &BTreeMap<String, CompressedLinear>,
    mode: Mode,
    workers: usize,
) -> NativeBackend {
    NativeBackend::new(&f.manifest, &f.model, layers, Some(8), mode, workers).unwrap()
}

/// The quantized vs fake-quant bit-parity check shared by the dense and
/// factored acceptance tests: same greedy tokens, bit-identical
/// teacher-forced logits, across worker counts.
fn assert_quantized_parity(
    f: &Fixture,
    layers: &BTreeMap<String, CompressedLinear>,
    reference_mode: Mode,
    tag: &str,
) {
    let dims = &f.manifest.model;
    let src = f.corpus.src_batch(0, dims.eval_batch, dims.pad_id);
    let fq = backend(f, layers, reference_mode, 2);
    let want_tokens = fq.translate(&src).unwrap();
    let want_logits = fq.forward_logits(&src, &src).unwrap();
    for workers in [1usize, 3] {
        let qb = backend(f, layers, Mode::Quantized, workers);
        assert_eq!(
            want_tokens,
            qb.translate(&src).unwrap(),
            "{tag}, workers={workers}: greedy tokens diverged"
        );
        let got_logits = qb.forward_logits(&src, &src).unwrap();
        assert_eq!(
            want_logits.data(),
            got_logits.data(),
            "{tag}, workers={workers}: teacher-forced logits diverged"
        );
    }
}

/// THE quantized-runtime acceptance bar: greedy decode from bit-packed
/// sub-8-bit storage is **bit-identical** to the fake-quant f32 native
/// path — for every word length in {4, 6, 8}, in dense form, across
/// worker counts. Fake-quant f32 is numerically identical to integer
/// storage + dequantization, so any token (or logit-bit) divergence here
/// is a real packing/kernel bug, not float noise.
#[test]
fn quantized_dense_decode_bit_identical_to_fake_quant() {
    let f = fixture("qdense");
    for wl in [4u32, 6, 8] {
        let layers = quant_all(&f, wl);
        assert_quantized_parity(&f, &layers, Mode::Dense, &format!("W{wl} dense"));
    }
}

/// Same bar for the factored form: Algorithm 1 factor pairs executed as
/// packed cascades (per-rank column scales on W1, per-rank row scales on
/// W2) must reproduce the factored f32 path bit for bit.
#[test]
fn quantized_factored_decode_bit_identical_to_fake_quant() {
    let f = fixture("qfact");
    for wl in [4u32, 6, 8] {
        let layers = factor_all(&f, 0.5, wl);
        assert_quantized_parity(&f, &layers, Mode::Svd, &format!("W{wl} factored"));
    }
}

#[test]
fn quantized_mode_rejects_unpackable_banks() {
    let f = fixture("qreject");
    // A missing layer is rejected.
    let err =
        NativeBackend::new(&f.manifest, &f.model, &BTreeMap::new(), Some(8), Mode::Quantized, 1);
    assert!(err.is_err(), "quantized mode requires every linear to be compressed");
    // FP-identity probe layers (no quant grid, no scales) cannot pack.
    let probes: BTreeMap<String, CompressedLinear> = f
        .manifest
        .linears
        .iter()
        .map(|l| {
            let c = CompressedLinear::Dense {
                w: f.model.linear(&l.name).clone(),
                wl: 16,
                scales: Vec::new(),
            };
            (l.name.clone(), c)
        })
        .collect();
    let err = NativeBackend::new(&f.manifest, &f.model, &probes, Some(8), Mode::Quantized, 1);
    assert!(err.is_err(), "FP-identity probes must be rejected, not mispacked");
}

#[test]
fn quantized_mode_cuts_resident_weight_bytes() {
    let f = fixture("qbytes");
    let layers = quant_all(&f, 4);
    let fq = NativeBackend::new(&f.manifest, &f.model, &layers, Some(8), Mode::Dense, 1).unwrap();
    let qb =
        NativeBackend::new(&f.manifest, &f.model, &layers, Some(8), Mode::Quantized, 1).unwrap();
    // W4 on the tiny 16-wide layers: > 4x fewer bytes even with the
    // per-column scale overhead (the 512-wide bench shapes reach ~7.9x).
    assert!(
        qb.weight_bytes() * 4 <= fq.weight_bytes(),
        "packed bank {} B vs f32 {} B",
        qb.weight_bytes(),
        fq.weight_bytes()
    );
    // And the packed bank's accounting agrees with the backend's.
    use itera_llm::coordinator::{compress_model_from, Method};
    let weights: Vec<&itera_llm::tensor::Matrix> =
        f.manifest.linears.iter().map(|l| f.model.linear(&l.name)).collect();
    let cm =
        compress_model_from(&f.manifest.linears, &weights, &Method::QuantOnly { wl: 4 }, None, 1);
    let bank = cm.packed_bank(&f.manifest).unwrap();
    let bank_bytes: usize = bank.values().map(|p| p.packed_bytes()).sum();
    assert_eq!(bank_bytes, qb.weight_bytes(), "bank vs backend byte accounting");
}

/// THE decode-cache acceptance bar: KV-cached greedy decode
/// ([`DecodePolicy::Cached`], the default) is **bit-identical** to the
/// full-buffer replay reference for all three execution modes — dense
/// fake-quant, factored cascade, bit-packed quantized (both packed
/// shapes) — plus the FP32 reference, across worker counts, on the full
/// hermetic-tiny-model corpus. Any token divergence is a real cache/step
/// bug (argmax over bit-equal logits), not float noise.
#[test]
fn cached_decode_bit_identical_to_replay_all_modes() {
    let f = fixture("decode_cache");
    let dims = &f.manifest.model;
    // Every corpus row at once: content lengths vary per row, so rows
    // reach EOS/PAD at different decode steps and exercise the ragged
    // DecodeState bookkeeping.
    let src = f.corpus.src_batch(0, f.corpus.n, dims.pad_id);
    let banks = [
        ("W6 dense", Mode::Dense, quant_all(&f, 6)),
        ("W8 factored", Mode::Svd, factor_all(&f, 0.5, 8)),
        ("W4 packed dense", Mode::Quantized, quant_all(&f, 4)),
        ("W4 packed cascade", Mode::Quantized, factor_all(&f, 0.5, 4)),
    ];
    for (tag, mode, layers) in &banks {
        let replay = backend(&f, layers, *mode, 2).with_decode(DecodePolicy::Replay);
        assert_eq!(replay.decode_policy(), DecodePolicy::Replay);
        let want = replay.translate(&src).unwrap();
        for workers in [1usize, 3] {
            let cached = backend(&f, layers, *mode, workers);
            assert_eq!(
                cached.decode_policy(),
                DecodePolicy::Cached,
                "cached must be the default policy"
            );
            assert_eq!(
                want,
                cached.translate(&src).unwrap(),
                "{tag}, workers={workers}: cached decode diverged from replay"
            );
        }
    }
    // And the FP32 reference path (no activation quant, original weights).
    let replay = NativeBackend::fp32(&f.manifest, &f.model, 2)
        .unwrap()
        .with_decode(DecodePolicy::Replay);
    let want = replay.translate(&src).unwrap();
    for workers in [1usize, 3] {
        let cached = NativeBackend::fp32(&f.manifest, &f.model, workers).unwrap();
        assert_eq!(want, cached.translate(&src).unwrap(), "fp32, workers={workers}");
    }
}

/// The modeled MAC reduction behind the decode cache: per-translate
/// decoder linears drop from `rows*seq*(seq-1)` activation rows to
/// `rows*(seq-1)` — a factor `seq_len` on the decoder stack, well over
/// the 3x acceptance bar on the whole translate even with the encoder
/// and hoisted cross-K/V included.
#[test]
fn cached_decode_macs_model_drops() {
    let f = fixture("decode_macs");
    let rows = f.manifest.model.eval_batch;
    let fp32_be = NativeBackend::fp32(&f.manifest, &f.model, 1).unwrap();
    let replay = fp32_be.linear_macs_for(rows, DecodePolicy::Replay);
    let cached = fp32_be.linear_macs_for(rows, DecodePolicy::Cached);
    assert!(
        cached * 3 <= replay,
        "cached decode must model >= 3x fewer linear MACs: {cached} vs {replay}"
    );
    // The default policy is cached, and the policy-less accessor follows
    // the backend's own policy.
    assert_eq!(fp32_be.linear_macs_per_translate(rows), cached);
    assert_eq!(
        fp32_be.with_decode(DecodePolicy::Replay).linear_macs_per_translate(rows),
        replay
    );
    // Factored execution keeps the same structural reduction.
    let layers = factor_all(&f, 0.5, 8);
    let fact = backend(&f, &layers, Mode::Svd, 1);
    assert!(
        fact.linear_macs_for(rows, DecodePolicy::Cached) * 3
            <= fact.linear_macs_for(rows, DecodePolicy::Replay)
    );
}

#[test]
fn compressed_model_native_backend_bridge() {
    use itera_llm::coordinator::{compress_model_from, Method};
    let f = fixture("bridge");
    let weights: Vec<&itera_llm::tensor::Matrix> =
        f.manifest.linears.iter().map(|l| f.model.linear(&l.name)).collect();
    // Quant-only -> dense execution.
    let cm = compress_model_from(
        &f.manifest.linears,
        &weights,
        &Method::QuantOnly { wl: 8 },
        None,
        2,
    );
    let backend = cm.native_backend(&f.manifest, &f.model, 2).unwrap();
    let d = evaluate_bleu(&backend, &f.corpus, &f.manifest.model, 4).unwrap();
    assert!((0.0..=100.0).contains(&d.score));
    // Algorithm-1 family -> factored execution (mode follows the method).
    let cm = compress_model_from(
        &f.manifest.linears,
        &weights,
        &Method::SvdIter { wl: 8, rank_frac: 0.5 },
        None,
        2,
    );
    assert_eq!(cm.mode(), Mode::Svd);
    let backend = cm.native_backend(&f.manifest, &f.model, 2).unwrap();
    let d = evaluate_bleu(&backend, &f.corpus, &f.manifest.model, 4).unwrap();
    assert!((0.0..=100.0).contains(&d.score));
    // Explicit-mode bridge: the same compression executes bit-packed and
    // reproduces the factored path's BLEU exactly (same tokens).
    let qbackend = cm.native_backend_mode(&f.manifest, &f.model, Mode::Quantized, 2).unwrap();
    let dq = evaluate_bleu(&qbackend, &f.corpus, &f.manifest.model, 4).unwrap();
    assert_eq!(d.score, dq.score, "quantized bridge must score identically");
    assert!(qbackend.weight_bytes() < backend.weight_bytes());
}

/// Kernel-tier contract end-to-end on both packed shapes: the `Exact`
/// tier is **bit-identical** to the pre-tier default construction
/// (tokens and teacher-forced step logits — the tier is pure dispatch,
/// zero numerics), and the `Fast` tier's step logits stay inside the
/// same scale-aware |Δlogit| bound the `validate --kernel fast` gate
/// enforces — which itself must pass on a hermetic tiny model, both
/// tiers (a breach is a non-zero CLI exit, surfaced here as `Err`).
#[test]
fn kernel_tier_exact_bit_identical_and_fast_within_parity_gate() {
    let f = fixture("ktier");
    let dims = &f.manifest.model;
    let s = dims.seq_len;
    let src = f.corpus.src_batch(0, dims.eval_batch, dims.pad_id);

    for (tag, layers) in [("W4 dense", quant_all(&f, 4)), ("W4 cascade", factor_all(&f, 0.5, 4))] {
        let base = backend(&f, &layers, Mode::Quantized, 2);
        let exact = backend(&f, &layers, Mode::Quantized, 2).with_kernel(KernelTier::Exact);
        let fast = backend(&f, &layers, Mode::Quantized, 2).with_kernel(KernelTier::Fast);
        assert_eq!(
            base.translate(&src).unwrap(),
            exact.translate(&src).unwrap(),
            "{tag}: exact tier must decode today's exact tokens"
        );

        let mut dmax = 0.0f32;
        let mut lmax = 0.0f32;
        for r in 0..dims.eval_batch {
            let row = &src[r * s..(r + 1) * s];
            let tgt = base.translate(row).unwrap();
            let want = base.step_logits(row, &tgt[..s]).unwrap();
            let got = exact.step_logits(row, &tgt[..s]).unwrap();
            assert_eq!(want.data(), got.data(), "{tag}, row {r}: exact tier step logits");
            let tiered = fast.step_logits(row, &tgt[..s]).unwrap();
            // NaN-sticky max: a poisoned logit can never slip under tol.
            for (&x, &y) in want.data().iter().zip(tiered.data()) {
                let d = (x - y).abs();
                if !(d <= dmax) {
                    dmax = d;
                }
                if !(x.abs() <= lmax) {
                    lmax = x.abs();
                }
            }
        }
        let tol = 1.5f32.max(0.05 * lmax);
        assert!(dmax <= tol, "{tag}: fast tier drifted, max |dlogit| {dmax} > {tol}");
    }

    // The CLI parity gate holds on its own hermetic tiny model.
    for tier in ["exact", "fast"] {
        itera_llm::cli::main_with_args(&[
            "validate".into(),
            "--kernel".into(),
            tier.into(),
            "--mode".into(),
            "quantized".into(),
            "--decode".into(),
            "cached".into(),
        ])
        .unwrap_or_else(|e| panic!("validate --kernel {tier} breached its parity gate: {e:#}"));
    }
}

/// THE fast-tier fault-isolation regression (the envelope-bugfix bar):
/// a NaN smuggled into one request's activations — here through a
/// poisoned `src_emb` row only that request references — must fault
/// **exactly that request** with a typed `EngineFault` naming the
/// non-finite lane, while its batchmates decode to completion
/// bit-identical to a sequential run and the serve books balance.
/// Before the typed [`itera_llm::qkernel::QKernelError`] path, the
/// envelope `assert!`s inside `qmatvec_i32` would have panicked the
/// whole batched step instead.
#[test]
fn fast_tier_poisoned_activation_faults_one_request_and_books_balance() {
    use std::collections::BTreeSet;
    use std::sync::mpsc;

    use itera_llm::coordinator::{
        response_channel, serve_loop_continuous, Request, ResponseRx, ServeConfig, ServeError,
    };

    let f = fixture("poison");
    let dims = &f.manifest.model;

    // A vocabulary row no corpus sentence references: poisoning its
    // embedding corrupts exactly the request we hand it to.
    let used: BTreeSet<i32> =
        (0..f.corpus.n).flat_map(|i| f.corpus.src_row(i).iter().copied()).collect();
    let poison_tok = (0..dims.vocab as i32)
        .find(|t| !used.contains(t) && *t != dims.pad_id && *t != dims.bos_id && *t != dims.eos_id)
        .expect("tiny vocab has unused tokens");

    // NaN one lane of that row, the way a corrupted weight shard would.
    // Model-load finiteness checks ran clean at load time; this is the
    // post-load corruption class only the runtime can catch.
    let mut model = PairModel::load(&f.manifest, tinymodel::PAIR).unwrap();
    let mut emb = model.weights.get("src_emb").unwrap().clone();
    emb.row_mut(poison_tok as usize)[0] = f32::NAN;
    model.weights.insert("src_emb", emb);

    let layers = quant_all(&f, 4);
    let engine = NativeBackend::new(&f.manifest, &model, &layers, Some(8), Mode::Quantized, 2)
        .unwrap()
        .with_kernel(KernelTier::Fast);

    const N: usize = 6;
    const VICTIM: usize = 2;
    let mut rows: Vec<Vec<i32>> =
        (0..N).map(|i| f.corpus.src_row(i % f.corpus.n).to_vec()).collect();
    rows[VICTIM][1] = poison_tok; // swapped into a content position

    // Sequential fast-tier decode of the clean rows: the bit-identity
    // bar — the victim must not perturb its batchmates.
    let want: Vec<Vec<i32>> = rows
        .iter()
        .enumerate()
        .filter(|&(i, _)| i != VICTIM)
        .map(|(_, row)| engine.translate(row).unwrap())
        .collect();

    let (tx, rx) = mpsc::channel::<Request>();
    let receivers: Vec<ResponseRx> = rows
        .iter()
        .map(|row| {
            let (rtx, rrx) = response_channel();
            tx.send(Request::new(row.clone(), rtx)).unwrap();
            rrx
        })
        .collect();
    drop(tx);
    let stats = serve_loop_continuous(&engine, &rx, dims, N, &ServeConfig::new(3)).unwrap();

    let mut clean = want.iter();
    for (i, rrx) in receivers.iter().enumerate() {
        let out = rrx.recv().expect("every request gets exactly one terminal outcome");
        match out {
            Err(ServeError::EngineFault(msg)) => {
                assert_eq!(i, VICTIM, "clean request {i} faulted: {msg}");
                assert!(
                    msg.contains("non-finite"),
                    "fault must name the poisoned activation, got: {msg}"
                );
            }
            Err(other) => panic!("request {i}: unexpected terminal outcome {other:?}"),
            Ok(resp) => {
                assert_ne!(i, VICTIM, "the poisoned request must fault, not decode");
                assert_eq!(
                    resp.tokens,
                    *clean.next().unwrap(),
                    "request {i}: survivor diverged from the sequential run"
                );
            }
        }
    }

    assert_eq!(stats.received, N);
    assert_eq!(stats.served, N - 1, "everyone but the victim answered");
    assert_eq!(stats.faulted, 1, "exactly the poisoned request faults");
    assert_eq!((stats.shed, stats.expired, stats.cancelled), (0, 0, 0), "{stats:?}");
    assert!(stats.is_balanced(), "accounting identity violated: {stats:?}");
}
