//! # ITERA-LLM
//!
//! Reproduction of *ITERA-LLM: Boosting Sub-8-Bit Large Language Model
//! Inference via Iterative Tensor Decomposition* (CS.AR 2025) as a
//! four-layer Rust + JAX + Pallas system:
//!
//! * **Layer 4 ([`runtime`])** — model execution. Two interchangeable
//!   backends behind [`runtime::TranslateBackend`]: the always-built
//!   pure-Rust native engine ([`runtime::native`], dense and factored
//!   low-rank execution on [`tensor::Matrix`]) and the optional PJRT
//!   session (`pjrt` feature) that executes the AOT-compiled artifacts.
//! * **Layer 3 (the rest of this crate)** — the software/hardware
//!   co-design framework: compression engine ([`compress`], Algorithm 1),
//!   sensitivity-based rank allocation ([`sra`]), FPGA analytical models
//!   and dataflow simulator ([`hw`]), design-space exploration ([`dse`]),
//!   BLEU evaluation service ([`eval`]) and the serving/experiment
//!   coordinator ([`coordinator`]).
//! * **Layer 2** — JAX transformer (`python/compile/model.py`), lowered
//!   once to HLO text under `make artifacts`.
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`) implementing
//!   the paper's MatMul engines; lowered into the same HLO.
//!
//! Python never runs at inference time: the default build executes models
//! natively from the weight store, and a `pjrt` build can additionally
//! load `artifacts/*.hlo.txt` through the PJRT C API.

pub mod cli;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod dse;
pub mod eval;
pub mod hw;
pub mod model;
pub mod runtime;
pub mod sra;
pub mod linalg;
pub mod quant;
pub mod tensor;
pub mod testkit;
pub mod benchkit;
pub mod util;
