//! `itera` — CLI entry point for the ITERA-LLM co-design framework.
//!
//! Every build ships the full native-runtime CLI (`info`, `eval`,
//! `serve`, `validate`); the PJRT-artifact commands (`fig`, `compress`,
//! `sra`, `serve --backend pjrt`) additionally need `--features pjrt`
//! with the `xla` crate vendored, and explain as much when invoked
//! without it.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = itera_llm::cli::main_with_args(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
