//! Fault-handling vocabulary for the serving stack: the typed error
//! taxonomy, per-request limits, the one-shot response channel with
//! disconnect detection, and the graceful-shutdown signal.
//!
//! Serving failures are **data, not panics**: every request submitted to
//! a serve loop receives exactly one terminal outcome — a [`Response`]
//! or a [`ServeError`] — through its [`ResponseRx`]. The scheduler
//! ([`super::scheduler::ContinuousBatcher`]) and the serve loops
//! ([`super::serve`]) never abort the whole process for a single bad
//! request; they retire the offender with a typed error and keep every
//! other slot stepping bit-identically (slot independence is the
//! [`crate::runtime::SlotEngine`] contract).
//!
//! `std::sync::mpsc` has no way to ask a `Sender` whether its `Receiver`
//! is still alive without sending, so the response channel here is a
//! small hand-rolled one-shot (`Mutex` + `Condvar` + liveness flags):
//! dropping the [`ResponseRx`] is visible to the server through
//! [`ResponseTx::is_disconnected`], which is what lets the serve loop
//! cancel orphaned slots instead of decoding to EOS for nobody.

use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Why a request did not produce a translation. Every variant is a
/// per-request outcome: the server stays up and other requests are
/// unaffected.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Rejected at admission: the bounded queue was full or the server
    /// was draining. Clients may retry (ideally with backoff).
    Overloaded,
    /// The per-request deadline (measured in decode steps since
    /// submission, queue wait included) elapsed before completion.
    DeadlineExceeded,
    /// The client disappeared (response receiver dropped) and the
    /// request was retired without decoding further.
    Cancelled,
    /// The engine failed or panicked while admitting or stepping this
    /// request; the message carries the underlying fault.
    EngineFault(String),
}

impl ServeError {
    /// Stable short tag for stats tables and logs.
    pub fn key(&self) -> &'static str {
        match self {
            ServeError::Overloaded => "overloaded",
            ServeError::DeadlineExceeded => "deadline_exceeded",
            ServeError::Cancelled => "cancelled",
            ServeError::EngineFault(_) => "engine_fault",
        }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Overloaded => write!(f, "overloaded: admission queue full or draining"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded before completion"),
            ServeError::Cancelled => write!(f, "cancelled: client disconnected"),
            ServeError::EngineFault(msg) => write!(f, "engine fault: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// A [`ServeError`] pinned to the request it failed — the attribution
/// unit the HTTP layer logs and serializes. The taxonomy itself stays
/// id-free (errors are compared structurally all over the test suite);
/// threading the request id happens at the reporting boundary via
/// [`ServeError::attributed`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AttributedError {
    /// Server-assigned per-request id (unique for the server's lifetime).
    pub id: u64,
    pub err: ServeError,
}

impl fmt::Display for AttributedError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "request {}: {}", self.id, self.err)
    }
}

impl std::error::Error for AttributedError {}

impl ServeError {
    /// Attach the failing request's id for logs and error bodies.
    pub fn attributed(self, id: u64) -> AttributedError {
        AttributedError { id, err: self }
    }
}

/// Per-request latency/length budget. Unset fields are unlimited (or
/// fall back to the server's defaults via [`RequestLimits::or`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RequestLimits {
    /// Retire with [`ServeError::DeadlineExceeded`] once this many
    /// decode steps have elapsed since submission. The clock is the
    /// batcher's deterministic step counter — queue wait counts, wall
    /// time never does, so expiry is reproducible.
    pub deadline_steps: Option<usize>,
    /// Retire **successfully** (truncation, not an error) after this
    /// many generated tokens, bounding the decode cost any single
    /// request can consume.
    pub max_new_tokens: Option<usize>,
}

impl RequestLimits {
    pub fn none() -> RequestLimits {
        RequestLimits::default()
    }

    pub fn with_deadline(mut self, steps: usize) -> RequestLimits {
        self.deadline_steps = Some(steps);
        self
    }

    pub fn with_max_new_tokens(mut self, tokens: usize) -> RequestLimits {
        self.max_new_tokens = Some(tokens);
        self
    }

    /// Fill unset fields from server-side defaults.
    pub fn or(self, defaults: RequestLimits) -> RequestLimits {
        RequestLimits {
            deadline_steps: self.deadline_steps.or(defaults.deadline_steps),
            max_new_tokens: self.max_new_tokens.or(defaults.max_new_tokens),
        }
    }
}

/// A served translation: de-framed tokens + server-observed latency.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub tokens: Vec<i32>,
    pub latency_s: f64,
}

/// The terminal outcome every submitted request receives exactly once.
pub type ServeResult = Result<Response, ServeError>;

/// Cooperative drain signal: flip it and the serve loop stops admitting,
/// finishes what is queued and live, and exits with balanced accounting.
/// Clone freely — all clones observe the same flag.
#[derive(Clone, Default)]
pub struct ShutdownSignal(Arc<AtomicBool>);

impl ShutdownSignal {
    pub fn new() -> ShutdownSignal {
        ShutdownSignal::default()
    }

    /// Request a graceful drain (idempotent).
    pub fn drain(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_draining(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// Outcome of a bounded wait on [`ResponseRx::recv_timeout`].
#[derive(Debug, PartialEq)]
pub enum TimedRecv {
    /// The terminal outcome arrived within the timeout.
    Ready(ServeResult),
    /// The server dropped its half without ever responding (the
    /// `recv() == None` case): nothing will ever arrive.
    SenderGone,
    /// Nothing arrived within the timeout; the request may still be in
    /// flight — retry, or drop the receiver to cancel it.
    TimedOut,
}

/// One event on a streaming receive ([`ResponseRx::recv_progress`]).
#[derive(Debug, PartialEq)]
pub enum StreamEvent {
    /// Newly generated tokens since the previous progress read (the
    /// incremental side-channel the chunked HTTP responses are wired to).
    Tokens(Vec<i32>),
    /// The terminal outcome: no further events follow.
    Done(ServeResult),
    /// Sender dropped without a terminal outcome (server bug/shutdown).
    SenderGone,
    /// No progress within the timeout.
    TimedOut,
}

struct ChannelState {
    value: Option<ServeResult>,
    /// Incremental token progress pushed by the server before the
    /// terminal outcome ([`ResponseTx::push_tokens`]); `taken` marks how
    /// much of it the receiver has already consumed.
    progress: Vec<i32>,
    taken: usize,
    tx_gone: bool,
    rx_gone: bool,
}

struct ChannelInner {
    state: Mutex<ChannelState>,
    cv: Condvar,
}

/// A poisoned mutex only means the *other* side panicked mid-access;
/// the state itself is a few flags and an `Option`, always coherent.
fn lock(inner: &ChannelInner) -> MutexGuard<'_, ChannelState> {
    inner.state.lock().unwrap_or_else(|e| e.into_inner())
}

/// One-shot response channel: the server holds the [`ResponseTx`], the
/// client blocks on [`ResponseRx::recv`]. Either side dropping is
/// observable by the other — the disconnect detection the serve loop's
/// orphaned-slot cancellation is built on.
pub fn response_channel() -> (ResponseTx, ResponseRx) {
    let inner = Arc::new(ChannelInner {
        state: Mutex::new(ChannelState {
            value: None,
            progress: Vec::new(),
            taken: 0,
            tx_gone: false,
            rx_gone: false,
        }),
        cv: Condvar::new(),
    });
    (ResponseTx(inner.clone()), ResponseRx(inner))
}

/// Server half of [`response_channel`].
pub struct ResponseTx(Arc<ChannelInner>);

impl ResponseTx {
    /// Deliver the terminal outcome. Returns `false` when the receiver
    /// is gone (client disconnected) or an outcome was already sent —
    /// a request can never be answered twice.
    pub fn send(&self, result: ServeResult) -> bool {
        let mut st = lock(&self.0);
        if st.rx_gone || st.value.is_some() {
            return false;
        }
        st.value = Some(result);
        self.0.cv.notify_all();
        true
    }

    /// Append incremental token progress ahead of the terminal outcome
    /// (the streaming side-channel). Returns `false` once the receiver is
    /// gone or the terminal outcome was already delivered.
    pub fn push_tokens(&self, tokens: &[i32]) -> bool {
        if tokens.is_empty() {
            return true;
        }
        let mut st = lock(&self.0);
        if st.rx_gone || st.value.is_some() {
            return false;
        }
        st.progress.extend_from_slice(tokens);
        self.0.cv.notify_all();
        true
    }

    /// The receiving side dropped: nobody will read a response, so the
    /// request's slot should be cancelled instead of decoded to EOS.
    pub fn is_disconnected(&self) -> bool {
        lock(&self.0).rx_gone
    }
}

impl Drop for ResponseTx {
    fn drop(&mut self) {
        let mut st = lock(&self.0);
        st.tx_gone = true;
        self.0.cv.notify_all();
    }
}

/// Client half of [`response_channel`].
pub struct ResponseRx(Arc<ChannelInner>);

impl ResponseRx {
    /// Block for the terminal outcome. `None` only when the server
    /// dropped its half without ever responding (a server bug — the
    /// serve loops answer every request they take).
    pub fn recv(&self) -> Option<ServeResult> {
        let mut st = lock(&self.0);
        loop {
            if let Some(v) = st.value.take() {
                return Some(v);
            }
            if st.tx_gone {
                return None;
            }
            st = self.0.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Non-blocking probe: the outcome if it has already arrived.
    pub fn try_recv(&self) -> Option<ServeResult> {
        lock(&self.0).value.take()
    }

    /// [`recv`](Self::recv) with an upper bound: connection handlers must
    /// never hang forever on a response that was lost to a server bug —
    /// they time out, answer the client with a typed error, and drop the
    /// receiver (which cancels the server-side slot).
    pub fn recv_timeout(&self, timeout: Duration) -> TimedRecv {
        let deadline = Instant::now() + timeout;
        let mut st = lock(&self.0);
        loop {
            if let Some(v) = st.value.take() {
                return TimedRecv::Ready(v);
            }
            if st.tx_gone {
                return TimedRecv::SenderGone;
            }
            let now = Instant::now();
            if now >= deadline {
                return TimedRecv::TimedOut;
            }
            let (g, _) = self
                .0
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            st = g;
        }
    }

    /// Streaming receive: wait up to `timeout` for the next event —
    /// incremental tokens pushed via [`ResponseTx::push_tokens`] drain
    /// first (exactly once, in order), then the terminal outcome. Chunked
    /// HTTP responses are one `recv_progress` loop.
    pub fn recv_progress(&self, timeout: Duration) -> StreamEvent {
        let deadline = Instant::now() + timeout;
        let mut st = lock(&self.0);
        loop {
            if st.taken < st.progress.len() {
                let fresh = st.progress[st.taken..].to_vec();
                st.taken = st.progress.len();
                return StreamEvent::Tokens(fresh);
            }
            if let Some(v) = st.value.take() {
                return StreamEvent::Done(v);
            }
            if st.tx_gone {
                return StreamEvent::SenderGone;
            }
            let now = Instant::now();
            if now >= deadline {
                return StreamEvent::TimedOut;
            }
            let (g, _) = self
                .0
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            st = g;
        }
    }
}

/// Non-blocking sweep over a set of pending receivers: split out every
/// outcome that has already arrived (`Some`) or whose sender vanished
/// without answering (`None`), returning the rest still pending. Lets
/// collectors and shutdown paths harvest finished work without ever
/// blocking on a straggler.
pub fn drain_ready(pending: Vec<ResponseRx>) -> (Vec<Option<ServeResult>>, Vec<ResponseRx>) {
    let mut resolved = Vec::new();
    let mut still = Vec::new();
    for rx in pending {
        let (value, tx_gone) = {
            let mut st = lock(&rx.0);
            (st.value.take(), st.tx_gone)
        };
        match value {
            Some(v) => resolved.push(Some(v)),
            None if tx_gone => resolved.push(None),
            None => still.push(rx),
        }
    }
    (resolved, still)
}

impl Drop for ResponseRx {
    fn drop(&mut self) {
        lock(&self.0).rx_gone = true;
    }
}

/// Render a `catch_unwind` payload for an [`ServeError::EngineFault`].
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_keys_and_display() {
        let e = ServeError::EngineFault("kv cache torn".into());
        assert_eq!(e.key(), "engine_fault");
        assert!(e.to_string().contains("kv cache torn"));
        assert_eq!(ServeError::Overloaded.key(), "overloaded");
        assert_eq!(ServeError::DeadlineExceeded.key(), "deadline_exceeded");
        assert_eq!(ServeError::Cancelled.key(), "cancelled");
        // The taxonomy is part of the wire contract: Display must be
        // stable enough to grep in logs.
        assert!(ServeError::Overloaded.to_string().contains("overloaded"));
    }

    #[test]
    fn limits_merge_with_defaults() {
        let server = RequestLimits::none().with_deadline(100).with_max_new_tokens(32);
        let per_request = RequestLimits::none().with_deadline(10);
        let eff = per_request.or(server);
        assert_eq!(eff.deadline_steps, Some(10), "per-request deadline wins");
        assert_eq!(eff.max_new_tokens, Some(32), "unset field falls back to server default");
        assert_eq!(RequestLimits::none().or(server), server);
    }

    #[test]
    fn oneshot_delivers_exactly_once() {
        let (tx, rx) = response_channel();
        assert!(tx.send(Ok(Response { tokens: vec![7], latency_s: 0.5 })));
        assert!(!tx.send(Err(ServeError::Overloaded)), "second send must be refused");
        match rx.recv() {
            Some(Ok(r)) => assert_eq!(r.tokens, vec![7]),
            other => panic!("expected the first outcome, got {other:?}"),
        }
        assert!(rx.try_recv().is_none(), "outcome is consumed exactly once");
    }

    #[test]
    fn dropped_receiver_is_visible_to_sender() {
        let (tx, rx) = response_channel();
        assert!(!tx.is_disconnected());
        drop(rx);
        assert!(tx.is_disconnected(), "disconnect must be observable without sending");
        assert!(!tx.send(Err(ServeError::Cancelled)), "send into a dropped receiver fails");
    }

    #[test]
    fn dropped_sender_unblocks_receiver() {
        let (tx, rx) = response_channel();
        let waiter = std::thread::spawn(move || rx.recv());
        drop(tx);
        assert!(waiter.join().unwrap().is_none(), "recv returns None, never hangs");
    }

    /// Satellite regression: the timeout path must return `TimedOut`
    /// without consuming anything, and a later send still delivers.
    #[test]
    fn recv_timeout_expires_then_delivers() {
        let (tx, rx) = response_channel();
        let t0 = Instant::now();
        assert_eq!(rx.recv_timeout(Duration::from_millis(20)), TimedRecv::TimedOut);
        assert!(t0.elapsed() >= Duration::from_millis(20), "must actually wait");
        assert!(tx.send(Ok(Response { tokens: vec![3], latency_s: 0.1 })));
        match rx.recv_timeout(Duration::from_secs(5)) {
            TimedRecv::Ready(Ok(r)) => assert_eq!(r.tokens, vec![3]),
            other => panic!("expected the outcome after timeout retry, got {other:?}"),
        }
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(1)),
            TimedRecv::TimedOut,
            "outcome is consumed exactly once even on the timed path"
        );
    }

    #[test]
    fn recv_timeout_sees_dropped_sender() {
        let (tx, rx) = response_channel();
        drop(tx);
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)), TimedRecv::SenderGone);
    }

    #[test]
    fn progress_streams_in_order_then_terminates() {
        let (tx, rx) = response_channel();
        assert!(tx.push_tokens(&[1, 2]));
        assert!(tx.push_tokens(&[3]));
        assert_eq!(
            rx.recv_progress(Duration::from_secs(1)),
            StreamEvent::Tokens(vec![1, 2, 3]),
            "progress drains coalesced, in push order"
        );
        assert_eq!(rx.recv_progress(Duration::from_millis(5)), StreamEvent::TimedOut);
        assert!(tx.send(Ok(Response { tokens: vec![1, 2, 3, 4], latency_s: 0.2 })));
        assert!(!tx.push_tokens(&[9]), "no progress after the terminal outcome");
        match rx.recv_progress(Duration::from_secs(1)) {
            StreamEvent::Done(Ok(r)) => assert_eq!(r.tokens, vec![1, 2, 3, 4]),
            other => panic!("expected terminal outcome, got {other:?}"),
        }
    }

    #[test]
    fn drain_ready_partitions_without_blocking() {
        let (tx_a, rx_a) = response_channel();
        let (tx_b, rx_b) = response_channel();
        let (tx_c, rx_c) = response_channel();
        tx_a.send(Ok(Response { tokens: vec![1], latency_s: 0.0 }));
        drop(tx_c); // lost without answering
        let (resolved, still) = drain_ready(vec![rx_a, rx_b, rx_c]);
        assert_eq!(resolved.len(), 2, "answered + lost resolve, pending stays");
        assert_eq!(still.len(), 1);
        assert!(matches!(&resolved[0], Some(Ok(r)) if r.tokens == vec![1]));
        assert!(resolved[1].is_none(), "dropped sender surfaces as None");
        drop(tx_b);
        let (resolved, still) = drain_ready(still);
        assert_eq!((resolved.len(), still.len()), (1, 0));
    }

    #[test]
    fn attributed_error_carries_request_id() {
        let e = ServeError::Overloaded.attributed(42);
        assert_eq!(e.id, 42);
        assert_eq!(e.err, ServeError::Overloaded);
        assert!(e.to_string().contains("request 42"), "{e}");
    }

    #[test]
    fn shutdown_signal_is_shared_across_clones() {
        let s = ShutdownSignal::new();
        let c = s.clone();
        assert!(!c.is_draining());
        s.drain();
        assert!(c.is_draining(), "clones observe the same flag");
        s.drain(); // idempotent
        assert!(s.is_draining());
    }
}
