//! Fault-handling vocabulary for the serving stack: the typed error
//! taxonomy, per-request limits, the one-shot response channel with
//! disconnect detection, and the graceful-shutdown signal.
//!
//! Serving failures are **data, not panics**: every request submitted to
//! a serve loop receives exactly one terminal outcome — a [`Response`]
//! or a [`ServeError`] — through its [`ResponseRx`]. The scheduler
//! ([`super::scheduler::ContinuousBatcher`]) and the serve loops
//! ([`super::serve`]) never abort the whole process for a single bad
//! request; they retire the offender with a typed error and keep every
//! other slot stepping bit-identically (slot independence is the
//! [`crate::runtime::SlotEngine`] contract).
//!
//! `std::sync::mpsc` has no way to ask a `Sender` whether its `Receiver`
//! is still alive without sending, so the response channel here is a
//! small hand-rolled one-shot (`Mutex` + `Condvar` + liveness flags):
//! dropping the [`ResponseRx`] is visible to the server through
//! [`ResponseTx::is_disconnected`], which is what lets the serve loop
//! cancel orphaned slots instead of decoding to EOS for nobody.

use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Why a request did not produce a translation. Every variant is a
/// per-request outcome: the server stays up and other requests are
/// unaffected.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Rejected at admission: the bounded queue was full or the server
    /// was draining. Clients may retry (ideally with backoff).
    Overloaded,
    /// The per-request deadline (measured in decode steps since
    /// submission, queue wait included) elapsed before completion.
    DeadlineExceeded,
    /// The client disappeared (response receiver dropped) and the
    /// request was retired without decoding further.
    Cancelled,
    /// The engine failed or panicked while admitting or stepping this
    /// request; the message carries the underlying fault.
    EngineFault(String),
}

impl ServeError {
    /// Stable short tag for stats tables and logs.
    pub fn key(&self) -> &'static str {
        match self {
            ServeError::Overloaded => "overloaded",
            ServeError::DeadlineExceeded => "deadline_exceeded",
            ServeError::Cancelled => "cancelled",
            ServeError::EngineFault(_) => "engine_fault",
        }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Overloaded => write!(f, "overloaded: admission queue full or draining"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded before completion"),
            ServeError::Cancelled => write!(f, "cancelled: client disconnected"),
            ServeError::EngineFault(msg) => write!(f, "engine fault: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Per-request latency/length budget. Unset fields are unlimited (or
/// fall back to the server's defaults via [`RequestLimits::or`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RequestLimits {
    /// Retire with [`ServeError::DeadlineExceeded`] once this many
    /// decode steps have elapsed since submission. The clock is the
    /// batcher's deterministic step counter — queue wait counts, wall
    /// time never does, so expiry is reproducible.
    pub deadline_steps: Option<usize>,
    /// Retire **successfully** (truncation, not an error) after this
    /// many generated tokens, bounding the decode cost any single
    /// request can consume.
    pub max_new_tokens: Option<usize>,
}

impl RequestLimits {
    pub fn none() -> RequestLimits {
        RequestLimits::default()
    }

    pub fn with_deadline(mut self, steps: usize) -> RequestLimits {
        self.deadline_steps = Some(steps);
        self
    }

    pub fn with_max_new_tokens(mut self, tokens: usize) -> RequestLimits {
        self.max_new_tokens = Some(tokens);
        self
    }

    /// Fill unset fields from server-side defaults.
    pub fn or(self, defaults: RequestLimits) -> RequestLimits {
        RequestLimits {
            deadline_steps: self.deadline_steps.or(defaults.deadline_steps),
            max_new_tokens: self.max_new_tokens.or(defaults.max_new_tokens),
        }
    }
}

/// A served translation: de-framed tokens + server-observed latency.
#[derive(Debug, Clone)]
pub struct Response {
    pub tokens: Vec<i32>,
    pub latency_s: f64,
}

/// The terminal outcome every submitted request receives exactly once.
pub type ServeResult = Result<Response, ServeError>;

/// Cooperative drain signal: flip it and the serve loop stops admitting,
/// finishes what is queued and live, and exits with balanced accounting.
/// Clone freely — all clones observe the same flag.
#[derive(Clone, Default)]
pub struct ShutdownSignal(Arc<AtomicBool>);

impl ShutdownSignal {
    pub fn new() -> ShutdownSignal {
        ShutdownSignal::default()
    }

    /// Request a graceful drain (idempotent).
    pub fn drain(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_draining(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

struct ChannelState {
    value: Option<ServeResult>,
    tx_gone: bool,
    rx_gone: bool,
}

struct ChannelInner {
    state: Mutex<ChannelState>,
    cv: Condvar,
}

/// A poisoned mutex only means the *other* side panicked mid-access;
/// the state itself is a few flags and an `Option`, always coherent.
fn lock(inner: &ChannelInner) -> MutexGuard<'_, ChannelState> {
    inner.state.lock().unwrap_or_else(|e| e.into_inner())
}

/// One-shot response channel: the server holds the [`ResponseTx`], the
/// client blocks on [`ResponseRx::recv`]. Either side dropping is
/// observable by the other — the disconnect detection the serve loop's
/// orphaned-slot cancellation is built on.
pub fn response_channel() -> (ResponseTx, ResponseRx) {
    let inner = Arc::new(ChannelInner {
        state: Mutex::new(ChannelState { value: None, tx_gone: false, rx_gone: false }),
        cv: Condvar::new(),
    });
    (ResponseTx(inner.clone()), ResponseRx(inner))
}

/// Server half of [`response_channel`].
pub struct ResponseTx(Arc<ChannelInner>);

impl ResponseTx {
    /// Deliver the terminal outcome. Returns `false` when the receiver
    /// is gone (client disconnected) or an outcome was already sent —
    /// a request can never be answered twice.
    pub fn send(&self, result: ServeResult) -> bool {
        let mut st = lock(&self.0);
        if st.rx_gone || st.value.is_some() {
            return false;
        }
        st.value = Some(result);
        self.0.cv.notify_all();
        true
    }

    /// The receiving side dropped: nobody will read a response, so the
    /// request's slot should be cancelled instead of decoded to EOS.
    pub fn is_disconnected(&self) -> bool {
        lock(&self.0).rx_gone
    }
}

impl Drop for ResponseTx {
    fn drop(&mut self) {
        let mut st = lock(&self.0);
        st.tx_gone = true;
        self.0.cv.notify_all();
    }
}

/// Client half of [`response_channel`].
pub struct ResponseRx(Arc<ChannelInner>);

impl ResponseRx {
    /// Block for the terminal outcome. `None` only when the server
    /// dropped its half without ever responding (a server bug — the
    /// serve loops answer every request they take).
    pub fn recv(&self) -> Option<ServeResult> {
        let mut st = lock(&self.0);
        loop {
            if let Some(v) = st.value.take() {
                return Some(v);
            }
            if st.tx_gone {
                return None;
            }
            st = self.0.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Non-blocking probe: the outcome if it has already arrived.
    pub fn try_recv(&self) -> Option<ServeResult> {
        lock(&self.0).value.take()
    }
}

impl Drop for ResponseRx {
    fn drop(&mut self) {
        lock(&self.0).rx_gone = true;
    }
}

/// Render a `catch_unwind` payload for an [`ServeError::EngineFault`].
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_keys_and_display() {
        let e = ServeError::EngineFault("kv cache torn".into());
        assert_eq!(e.key(), "engine_fault");
        assert!(e.to_string().contains("kv cache torn"));
        assert_eq!(ServeError::Overloaded.key(), "overloaded");
        assert_eq!(ServeError::DeadlineExceeded.key(), "deadline_exceeded");
        assert_eq!(ServeError::Cancelled.key(), "cancelled");
        // The taxonomy is part of the wire contract: Display must be
        // stable enough to grep in logs.
        assert!(ServeError::Overloaded.to_string().contains("overloaded"));
    }

    #[test]
    fn limits_merge_with_defaults() {
        let server = RequestLimits::none().with_deadline(100).with_max_new_tokens(32);
        let per_request = RequestLimits::none().with_deadline(10);
        let eff = per_request.or(server);
        assert_eq!(eff.deadline_steps, Some(10), "per-request deadline wins");
        assert_eq!(eff.max_new_tokens, Some(32), "unset field falls back to server default");
        assert_eq!(RequestLimits::none().or(server), server);
    }

    #[test]
    fn oneshot_delivers_exactly_once() {
        let (tx, rx) = response_channel();
        assert!(tx.send(Ok(Response { tokens: vec![7], latency_s: 0.5 })));
        assert!(!tx.send(Err(ServeError::Overloaded)), "second send must be refused");
        match rx.recv() {
            Some(Ok(r)) => assert_eq!(r.tokens, vec![7]),
            other => panic!("expected the first outcome, got {other:?}"),
        }
        assert!(rx.try_recv().is_none(), "outcome is consumed exactly once");
    }

    #[test]
    fn dropped_receiver_is_visible_to_sender() {
        let (tx, rx) = response_channel();
        assert!(!tx.is_disconnected());
        drop(rx);
        assert!(tx.is_disconnected(), "disconnect must be observable without sending");
        assert!(!tx.send(Err(ServeError::Cancelled)), "send into a dropped receiver fails");
    }

    #[test]
    fn dropped_sender_unblocks_receiver() {
        let (tx, rx) = response_channel();
        let waiter = std::thread::spawn(move || rx.recv());
        drop(tx);
        assert!(waiter.join().unwrap().is_none(), "recv returns None, never hangs");
    }

    #[test]
    fn shutdown_signal_is_shared_across_clones() {
        let s = ShutdownSignal::new();
        let c = s.clone();
        assert!(!c.is_draining());
        s.drain();
        assert!(c.is_draining(), "clones observe the same flag");
        s.drain(); // idempotent
        assert!(s.is_draining());
    }
}
