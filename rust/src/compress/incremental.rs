//! Incremental compression: run Algorithm 1 **once** per `(layer, wl)`
//! at the maximum rank, answer every lower-rank query by truncation.
//!
//! Algorithm 1 is greedy: step `k` depends only on the residual left by
//! steps `0..k`, never on the target rank, so the rank-`r` factors of a
//! run are *exactly* the first `r` columns of `W'1` / rows of `W'2` of a
//! rank-`r_max` run (and the recorded residual-norm trace gives the
//! approximation error at every intermediate rank for free). The SRA
//! search and the DSE sweep probe many ranks of the same layer — two
//! oracle calls per probed layer per iteration — which previously meant
//! recompressing from scratch each time. With [`IncrementalItera`] the
//! whole search costs one full-rank decomposition per layer, and every
//! probe is an O(K*r + r*N) copy.
//!
//! `prop_truncation_invariant` in `tests/proptests.rs` pins the
//! truncation property bit-exactly against a fresh `itera` run.

use std::collections::HashMap;

use crate::quant::WordLen;
use crate::tensor::Matrix;
use crate::util::pool::par_map;

use super::itera::{itera_opts, IteraOpts, IteraTrace};
use super::CompressedLinear;

/// One layer's full-rank Algorithm 1 run, queryable at any rank.
#[derive(Debug, Clone)]
pub struct IncrementalItera {
    /// `W'1 [K x r_max]` — quantized left factors, rank-major columns.
    w1: Matrix,
    /// `W'2 [r_max x N]` — quantized right factors, rank-major rows.
    w2: Matrix,
    /// Per-rank dequant scales of the factor columns/rows (truncate with
    /// the factors — scales are per rank, so a rank prefix keeps exactly
    /// its own prefix of scales).
    s1: Vec<f32>,
    s2: Vec<f32>,
    wl: WordLen,
    trace: IteraTrace,
}

impl IncrementalItera {
    /// Run Algorithm 1 to the layer's maximum rank (`min(K, N)`) with the
    /// default options and record the full factor sequence.
    pub fn compress(w: &Matrix, wl: WordLen) -> IncrementalItera {
        Self::compress_opts(w, wl, &IteraOpts::default())
    }

    /// As [`Self::compress`] with explicit Algorithm 1 ablation switches.
    pub fn compress_opts(w: &Matrix, wl: WordLen, opts: &IteraOpts) -> IncrementalItera {
        let r_max = w.rows().min(w.cols()).max(1);
        let (c, trace) = itera_opts(w, r_max, wl, opts);
        let CompressedLinear::LowRank { w1, w2, s1, s2, .. } = c else {
            unreachable!("itera always returns LowRank");
        };
        IncrementalItera { w1, w2, s1, s2, wl, trace }
    }

    /// Maximum (recorded) rank.
    pub fn r_max(&self) -> usize {
        self.w1.cols()
    }

    pub fn word_len(&self) -> WordLen {
        self.wl
    }

    /// The full-rank run's trace (residual norms index 0..=r_max).
    pub fn trace(&self) -> &IteraTrace {
        &self.trace
    }

    /// Matvec-equivalent cost of the one-time fill.
    pub fn fill_cost(&self) -> u64 {
        self.trace.matvec_equivalents
    }

    /// Rank-`r` factors, bit-identical to `itera(w, r, wl)` (clamped to
    /// `1..=r_max`). Costs one `K*r + r*N` copy — no recompression.
    pub fn query(&self, r: usize) -> CompressedLinear {
        let r = r.clamp(1, self.r_max());
        CompressedLinear::LowRank {
            w1: self.w1.take_cols(r),
            w2: self.w2.take_rows(r),
            wl: self.wl,
            s1: self.s1[..r].to_vec(),
            s2: self.s2[..r].to_vec(),
        }
    }

    /// `||W - W'1[:, :r] W'2[:r, :]||_F` at any rank, straight from the
    /// recorded residual trace (what a fresh rank-`r` run would report).
    pub fn error_at(&self, r: usize) -> f32 {
        let r = r.clamp(1, self.r_max());
        self.trace.residual_norms[r.min(self.trace.residual_norms.len() - 1)]
    }
}

/// Cache of [`IncrementalItera`] runs keyed by `(layer index, wl)`.
///
/// The index space is the caller's layer inventory (manifest order for the
/// coordinator, vector order for synthetic models). `fills` counts actual
/// decompositions, which the "each (layer, wl) compressed at most once"
/// regression test asserts on.
#[derive(Debug, Default)]
pub struct CompressionCache {
    entries: HashMap<(usize, WordLen), IncrementalItera>,
    fills: u64,
}

impl CompressionCache {
    pub fn new() -> CompressionCache {
        CompressionCache::default()
    }

    /// Number of full-rank decompositions performed so far.
    pub fn fills(&self) -> u64 {
        self.fills
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total matvec-equivalent cost of every fill so far.
    pub fn fill_cost(&self) -> u64 {
        self.entries.values().map(|e| e.fill_cost()).sum()
    }

    pub fn get(&self, layer: usize, wl: WordLen) -> Option<&IncrementalItera> {
        self.entries.get(&(layer, wl))
    }

    /// Fill (if missing) and return the entry for `(layer, wl)`.
    pub fn get_or_fill(&mut self, layer: usize, wl: WordLen, w: &Matrix) -> &IncrementalItera {
        if !self.entries.contains_key(&(layer, wl)) {
            self.entries.insert((layer, wl), IncrementalItera::compress(w, wl));
            self.fills += 1;
        }
        &self.entries[&(layer, wl)]
    }

    /// Fill every missing `(i, wl)` entry for `weights[i]`, fanning the
    /// full-rank decompositions out on the shared thread pool.
    pub fn fill_all(&mut self, weights: &[&Matrix], wl: WordLen, workers: usize) {
        let missing: Vec<usize> = (0..weights.len())
            .filter(|&i| !self.entries.contains_key(&(i, wl)))
            .collect();
        if missing.is_empty() {
            return;
        }
        let filled = par_map(missing.len(), workers, |j| {
            IncrementalItera::compress(weights[missing[j]], wl)
        });
        for (j, entry) in filled.into_iter().enumerate() {
            self.entries.insert((missing[j], wl), entry);
            self.fills += 1;
        }
    }

    /// Rank-`r` factors for layer `i` (must be filled).
    pub fn query(&self, layer: usize, wl: WordLen, r: usize) -> Option<CompressedLinear> {
        self.entries.get(&(layer, wl)).map(|e| e.query(r))
    }

    /// Approximation error of layer `i` truncated to rank `r`.
    pub fn error_at(&self, layer: usize, wl: WordLen, r: usize) -> Option<f32> {
        self.entries.get(&(layer, wl)).map(|e| e.error_at(r))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::itera;
    use crate::util::rng::Pcg64;

    fn weights(seed: u64, k: usize, n: usize) -> Matrix {
        let mut rng = Pcg64::new(seed);
        Matrix::randn(k, n, &mut rng).scale(0.1)
    }

    #[test]
    fn query_matches_fresh_run_bitwise() {
        let w = weights(100, 18, 14);
        let inc = IncrementalItera::compress(&w, 4);
        assert_eq!(inc.r_max(), 14);
        for r in [1usize, 3, 7, 14] {
            let cached = inc.query(r);
            let (fresh, _) = itera(&w, r, 4);
            let (CompressedLinear::LowRank { w1: cw1, w2: cw2, .. },
                 CompressedLinear::LowRank { w1: fw1, w2: fw2, .. }) = (&cached, &fresh)
            else {
                panic!("both must be LowRank");
            };
            assert_eq!(cw1.data(), fw1.data(), "w1 at r={r}");
            assert_eq!(cw2.data(), fw2.data(), "w2 at r={r}");
        }
    }

    #[test]
    fn error_at_matches_fresh_trace() {
        let w = weights(101, 16, 16);
        let inc = IncrementalItera::compress(&w, 6);
        for r in [2usize, 5, 9, 16] {
            let (_, trace) = itera(&w, r, 6);
            let fresh = *trace.residual_norms.last().unwrap();
            assert_eq!(inc.error_at(r), fresh, "r={r}");
        }
    }

    #[test]
    fn query_clamps_rank() {
        let w = weights(102, 8, 10);
        let inc = IncrementalItera::compress(&w, 4);
        assert_eq!(inc.query(0).rank(), 1);
        assert_eq!(inc.query(999).rank(), 8);
    }

    #[test]
    fn cache_fills_each_layer_once() {
        let ws: Vec<Matrix> = (0..4).map(|i| weights(110 + i, 12, 12)).collect();
        let refs: Vec<&Matrix> = ws.iter().collect();
        let mut cache = CompressionCache::new();
        cache.fill_all(&refs, 4, 2);
        assert_eq!(cache.fills(), 4);
        assert_eq!(cache.len(), 4);
        // Re-filling and point lookups must not recompress.
        cache.fill_all(&refs, 4, 2);
        for i in 0..4 {
            let _ = cache.get_or_fill(i, 4, &ws[i]);
            assert!(cache.query(i, 4, 5).is_some());
        }
        assert_eq!(cache.fills(), 4);
        // A different word length is a distinct compression.
        let _ = cache.get_or_fill(0, 6, &ws[0]);
        assert_eq!(cache.fills(), 5);
        assert!(cache.fill_cost() > 0);
    }
}
