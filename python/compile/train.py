"""Build-time training of the tiny OPUS-MT-style NMT model.

The paper compresses *pretrained* OPUS-MT checkpoints; those are not
available offline, so this script produces the converged FP32 model that the
post-training compression pipeline (all of it in Rust) starts from. Runs
exactly once, under ``make artifacts``.

Outputs (under ``artifacts/``):
  * ``weights.bin``       — flat binary weight store (see ``save_weights``)
  * ``corpus_<pair>.bin`` — held-out test sentences + calibration subset
  * calibration activation max-abs per compressed linear (into the manifest
    assembled by ``aot.py``)

Adam is implemented inline (no optax in the image); the training path uses
the pure-jnp oracles (``use_kernels=False``) — the Pallas kernels are tied
to that path by the pytest suite and used in the lowered artifacts.
"""

from __future__ import annotations

import struct

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod

TRAIN_SENTENCES = 4096
TEST_SENTENCES = 256
CALIB_SENTENCES = 64
BATCH = 32
STEPS = 700
LR = 2e-3
SEED = 0


def save_weights(path: str, params: dict[str, np.ndarray]) -> None:
    """Flat binary weight store read by ``rust/src/model/weights.rs``.

    Layout: magic ``ITWB`` | u32 n_entries | entries. Entry: u32 name_len |
    name bytes | u32 ndim | u32 dims[ndim] | f32 data (LE, row-major).
    """
    with open(path, "wb") as f:
        f.write(b"ITWB")
        f.write(struct.pack("<I", len(params)))
        for name in sorted(params):
            arr = np.ascontiguousarray(params[name], dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def save_corpus(path: str, src: np.ndarray, tgt: np.ndarray) -> None:
    """Token corpus store read by ``rust/src/eval/corpus.rs``.

    Layout: magic ``ITCP`` | u32 n | u32 seq_len | i32 src[n*s] | i32 tgt[n*s].
    """
    n, s = src.shape
    with open(path, "wb") as f:
        f.write(b"ITCP")
        f.write(struct.pack("<II", n, s))
        f.write(np.ascontiguousarray(src, dtype=np.int32).tobytes())
        f.write(np.ascontiguousarray(tgt, dtype=np.int32).tobytes())


def _loss_fn(params, src, tgt, scales, cfg):
    """Teacher-forced cross-entropy (FP32 path: levels=0)."""
    tgt_in = tgt  # buffer already starts with BOS; predict positions 1..
    logits = model_mod.forward_logits(
        params, src, tgt_in, scales, 0.0, mode="dense", cfg=cfg,
        use_kernels=False,
    )
    # Predict token at position i+1 from logits at position i.
    labels = tgt[:, 1:]
    lg = logits[:, :-1]
    mask = (labels != data_mod.PAD_ID).astype(jnp.float32)
    logp = jax.nn.log_softmax(lg, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def train(pair: str = "en-de", steps: int = STEPS, seed: int = SEED,
          cfg: model_mod.ModelConfig = model_mod.CFG, log=print):
    """Train and return (params, test_corpus, calib_corpus, act_maxabs)."""
    corpus = data_mod.make_corpus(pair, TRAIN_SENTENCES + TEST_SENTENCES, seed + 7)
    train_c = data_mod.Corpus(pair, corpus.src[:TRAIN_SENTENCES],
                              corpus.tgt[:TRAIN_SENTENCES])
    test_c = data_mod.Corpus(pair, corpus.src[TRAIN_SENTENCES:],
                             corpus.tgt[TRAIN_SENTENCES:])

    params = model_mod.init_params(cfg, seed)
    names = list(params)
    scales = np.ones(len(model_mod.compressed_linear_names(cfg)), np.float32)

    loss_grad = jax.jit(
        jax.value_and_grad(lambda p, s, t: _loss_fn(p, s, t, scales, cfg))
    )

    # Inline Adam.
    m = {k: np.zeros_like(v) for k, v in params.items()}
    v = {k: np.zeros_like(p) for k, p in params.items()}
    b1, b2, eps = 0.9, 0.999, 1e-8

    it = data_mod.batches(train_c, BATCH, seed + 13)
    for step in range(1, steps + 1):
        src, tgt = next(it)
        loss, grads = loss_grad(params, src, tgt)
        lr_t = LR * min(1.0, step / 50) * (1.0 - 0.5 * step / steps)
        for k in names:
            g = np.asarray(grads[k])
            m[k] = b1 * m[k] + (1 - b1) * g
            v[k] = b2 * v[k] + (1 - b2) * g * g
            mh = m[k] / (1 - b1**step)
            vh = v[k] / (1 - b2**step)
            params[k] = params[k] - lr_t * mh / (np.sqrt(vh) + eps)
        if step % 100 == 0 or step == 1:
            log(f"[train {pair}] step {step:4d} loss {float(loss):.4f}")

    calib_c = data_mod.Corpus(pair, test_c.src[:CALIB_SENTENCES],
                              test_c.tgt[:CALIB_SENTENCES])

    # Calibration: FP32 forward over the calibration set, collect the
    # max-abs input of every compressed linear (static PTQ ranges).
    stats_fn = jax.jit(
        lambda p, s, t: model_mod.forward_logits(
            p, s, t, scales, 0.0, mode="dense", cfg=cfg,
            collect_stats=True, use_kernels=False)[1]
    )
    maxabs = np.zeros(len(scales), np.float32)
    for i in range(0, CALIB_SENTENCES, BATCH):
        st = stats_fn(params, calib_c.src[i : i + BATCH],
                      calib_c.tgt[i : i + BATCH])
        maxabs = np.maximum(maxabs, np.asarray(st))

    return params, test_c, calib_c, maxabs
