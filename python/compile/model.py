"""Layer-2 JAX model: OPUS-MT-style transformer encoder–decoder.

The paper evaluates OPUS-MT [4] (Marian architecture). We implement the
same architecture at reduced scale (see DESIGN.md §Substitutions) with every
attention / FFN linear routed through the Layer-1 Pallas kernels, because
those are exactly the MatMul workloads the paper's hardware accelerates.

Two compiled variants share one code path:

* ``mode="dense"``  — each compressed linear is ``quant_matmul(aq(x), W)``
  with ``W`` in its original ``[K, N]`` shape. The Rust coordinator feeds
  fake-quantized weights for the quantization-only baseline (or raw FP32
  weights for the reference).
* ``mode="svd"``    — each compressed linear is ``cascade_matmul(aq(x),
  W1, W2)`` with ``W1: [K, r_max]``, ``W2: [r_max, N]``. The coordinator
  zero-pads rank-``r`` factors to ``r_max``, so one artifact evaluates every
  rank allocation the SRA search visits.

Weights are runtime *arguments*, never baked constants: the whole point of
the framework is that the Rust side re-compresses weights thousands of times
(Algorithm 1 sweeps, SRA iterations) against a single compiled graph.

Activation quantization (the "A" in WxAy) happens in-graph via the
``fake_quant`` kernel, parameterized by per-linear scales and a shared
``levels`` scalar — ``levels == 0`` disables it (FP32 activations).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .kernels import cascade_matmul, fake_quant, quant_matmul


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = data_mod.VOCAB_SIZE
    d_model: int = 128
    n_heads: int = 8
    d_ff: int = 256
    n_enc: int = 2
    n_dec: int = 2
    seq_len: int = data_mod.SEQ_LEN

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


CFG = ModelConfig()


# --------------------------------------------------------------------------
# Parameter inventory
# --------------------------------------------------------------------------

def compressed_linear_names(cfg: ModelConfig = CFG) -> list[str]:
    """Ordered names of every linear the framework compresses.

    This ordering is the layer index space used everywhere: SRA rank
    vectors, activation-scale vectors, sensitivity plots (Fig. 4), and the
    per-layer occupancy breakdown (Fig. 12) all index into this list.
    """
    names = []
    for i in range(cfg.n_enc):
        for w in ("self_q", "self_k", "self_v", "self_o", "ff1", "ff2"):
            names.append(f"enc{i}.{w}")
    for i in range(cfg.n_dec):
        for w in (
            "self_q", "self_k", "self_v", "self_o",
            "cross_q", "cross_k", "cross_v", "cross_o",
            "ff1", "ff2",
        ):
            names.append(f"dec{i}.{w}")
    return names


def linear_shape(name: str, cfg: ModelConfig = CFG) -> tuple[int, int]:
    """(K, N) shape of a compressed linear, by name."""
    kind = name.split(".")[1]
    if kind == "ff1":
        return (cfg.d_model, cfg.d_ff)
    if kind == "ff2":
        return (cfg.d_ff, cfg.d_model)
    return (cfg.d_model, cfg.d_model)


def r_max(name: str, cfg: ModelConfig = CFG) -> int:
    k, n = linear_shape(name, cfg)
    return min(k, n)


def other_param_specs(cfg: ModelConfig = CFG) -> list[tuple[str, tuple[int, ...]]]:
    """Uncompressed parameters (embeddings, layer norms) in fixed order."""
    d = cfg.d_model
    specs: list[tuple[str, tuple[int, ...]]] = [
        ("src_emb", (cfg.vocab, d)),
        ("tgt_emb", (cfg.vocab, d)),
        ("pos_emb", (cfg.seq_len, d)),
    ]
    for i in range(cfg.n_enc):
        specs += [
            (f"enc{i}.ln1_g", (d,)), (f"enc{i}.ln1_b", (d,)),
            (f"enc{i}.ln2_g", (d,)), (f"enc{i}.ln2_b", (d,)),
        ]
    specs += [("enc_ln_g", (d,)), ("enc_ln_b", (d,))]
    for i in range(cfg.n_dec):
        specs += [
            (f"dec{i}.ln1_g", (d,)), (f"dec{i}.ln1_b", (d,)),
            (f"dec{i}.ln2_g", (d,)), (f"dec{i}.ln2_b", (d,)),
            (f"dec{i}.ln3_g", (d,)), (f"dec{i}.ln3_b", (d,)),
        ]
    specs += [("dec_ln_g", (d,)), ("dec_ln_b", (d,))]
    return specs


def param_specs(mode: str, cfg: ModelConfig = CFG) -> list[tuple[str, tuple[int, ...]]]:
    """Full ordered argument inventory for a compiled variant.

    The exact order here is recorded in ``artifacts/manifest.json`` and
    replayed by the Rust runtime when packing PJRT literals.
    """
    specs = other_param_specs(cfg)
    for name in compressed_linear_names(cfg):
        k, n = linear_shape(name, cfg)
        if mode == "dense":
            specs.append((name, (k, n)))
        elif mode == "svd":
            r = r_max(name, cfg)
            specs.append((name + ".w1", (k, r)))
            specs.append((name + ".w2", (r, n)))
        else:
            raise ValueError(mode)
    return specs


def init_params(cfg: ModelConfig = CFG, seed: int = 0) -> dict[str, np.ndarray]:
    """Dense FP32 parameter init (training starts here)."""
    rng = np.random.default_rng(seed)
    params: dict[str, np.ndarray] = {}
    for name, shape in other_param_specs(cfg):
        if name.endswith("_g"):
            params[name] = np.ones(shape, dtype=np.float32)
        elif name.endswith("_b"):
            params[name] = np.zeros(shape, dtype=np.float32)
        else:
            params[name] = (rng.standard_normal(shape) * 0.02).astype(np.float32)
    for name in compressed_linear_names(cfg):
        k, n = linear_shape(name, cfg)
        params[name] = (rng.standard_normal((k, n)) * (1.0 / np.sqrt(k))).astype(
            np.float32
        )
    return params


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------

class _Ctx:
    """Carries the weight dict + quantization args through the forward pass
    and records per-linear activation max-abs for calibration."""

    def __init__(self, params, mode, act_scales, act_levels, cfg,
                 use_kernels=True):
        # jnp-ify so numpy params can be indexed by traced token arrays.
        self.params = {k: jnp.asarray(v) for k, v in params.items()}
        self.mode = mode
        self.act_scales = act_scales
        self.act_levels = act_levels
        self.cfg = cfg
        self.use_kernels = use_kernels
        self.names = compressed_linear_names(cfg)
        self.index = {n: i for i, n in enumerate(self.names)}
        self.maxabs = {}

    def linear(self, name: str, x: jnp.ndarray) -> jnp.ndarray:
        """Compressed linear: activation fake-quant + kernel matmul.

        ``x`` arrives as [..., K]; flattened to 2-D for the tiled kernels
        (the hardware sees exactly this [M, K] x [K, N] workload).
        """
        i = self.index[name]
        lead = x.shape[:-1]
        k = x.shape[-1]
        x2 = x.reshape((-1, k))
        self.maxabs[name] = jnp.max(jnp.abs(x2))
        if self.use_kernels:
            xq = fake_quant(x2, self.act_scales[i], self.act_levels)
            if self.mode == "dense":
                y = quant_matmul(xq, self.params[name])
            else:
                y = cascade_matmul(
                    xq, self.params[name + ".w1"], self.params[name + ".w2"]
                )
        else:
            # Pure-jnp path (training / fast calibration): identical math
            # via the reference oracles, differentiable and fast under jit.
            from .kernels import ref as _ref

            xq = _ref.fake_quant_ref(x2, self.act_scales[i], self.act_levels)
            if self.mode == "dense":
                y = _ref.matmul_ref(xq, self.params[name])
            else:
                y = _ref.cascade_ref(
                    xq, self.params[name + ".w1"], self.params[name + ".w2"]
                )
        return y.reshape(lead + (y.shape[-1],))


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention(ctx: _Ctx, prefix: str, q_in, kv_in, mask):
    """Multi-head attention with all four projections through ctx.linear.

    mask: [B, 1, Tq, Tk] additive (-inf where disallowed).
    """
    cfg = ctx.cfg
    b, tq, d = q_in.shape
    tk = kv_in.shape[1]
    h, hd = cfg.n_heads, cfg.head_dim
    q = ctx.linear(f"{prefix}_q", q_in).reshape(b, tq, h, hd).transpose(0, 2, 1, 3)
    k = ctx.linear(f"{prefix}_k", kv_in).reshape(b, tk, h, hd).transpose(0, 2, 1, 3)
    v = ctx.linear(f"{prefix}_v", kv_in).reshape(b, tk, h, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(hd).astype(np.float32)
    scores = scores + mask
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, tq, d)
    return ctx.linear(f"{prefix}_o", out)


def _ffn(ctx: _Ctx, prefix: str, x):
    return ctx.linear(f"{prefix}.ff2", jax.nn.relu(ctx.linear(f"{prefix}.ff1", x)))


_NEG = -1e9


def _encode(ctx: _Ctx, src_tokens):
    """Encoder stack; returns (memory [B,S,D], src_pad_mask [B,1,1,S])."""
    p = ctx.params
    cfg = ctx.cfg
    x = p["src_emb"][src_tokens] + p["pos_emb"][None, : src_tokens.shape[1]]
    pad = (src_tokens == data_mod.PAD_ID)
    mask = jnp.where(pad[:, None, None, :], _NEG, 0.0).astype(jnp.float32)
    for i in range(cfg.n_enc):
        pre = f"enc{i}"
        h = _layer_norm(x, p[f"{pre}.ln1_g"], p[f"{pre}.ln1_b"])
        x = x + _attention(ctx, f"{pre}.self", h, h, mask)
        h = _layer_norm(x, p[f"{pre}.ln2_g"], p[f"{pre}.ln2_b"])
        x = x + _ffn(ctx, pre, h)
    x = _layer_norm(x, p["enc_ln_g"], p["enc_ln_b"])
    return x, mask


def _decode(ctx: _Ctx, tgt_tokens, memory, src_mask):
    """Decoder stack over a full (causally masked) target buffer.

    Returns logits [B, T, V]. The greedy loop recomputes this each step —
    with d=64, T=20 the cost is negligible and it keeps the lowered HLO
    free of KV-cache plumbing.
    """
    p = ctx.params
    cfg = ctx.cfg
    b, t = tgt_tokens.shape
    x = p["tgt_emb"][tgt_tokens] + p["pos_emb"][None, :t]
    causal = jnp.triu(jnp.full((t, t), _NEG, dtype=jnp.float32), k=1)
    tpad = (tgt_tokens == data_mod.PAD_ID)
    self_mask = causal[None, None] + jnp.where(tpad[:, None, None, :], _NEG, 0.0)
    for i in range(cfg.n_dec):
        pre = f"dec{i}"
        h = _layer_norm(x, p[f"{pre}.ln1_g"], p[f"{pre}.ln1_b"])
        x = x + _attention(ctx, f"{pre}.self", h, h, self_mask)
        h = _layer_norm(x, p[f"{pre}.ln2_g"], p[f"{pre}.ln2_b"])
        x = x + _attention(ctx, f"{pre}.cross", h, memory, src_mask)
        h = _layer_norm(x, p[f"{pre}.ln3_g"], p[f"{pre}.ln3_b"])
        x = x + _ffn(ctx, pre, h)
    x = _layer_norm(x, p["dec_ln_g"], p["dec_ln_b"])
    # Tied output head (Marian ties target embedding and lm head).
    return jnp.einsum("btd,vd->btv", x, p["tgt_emb"])


def forward_logits(params, src_tokens, tgt_in, act_scales, act_levels,
                   mode="dense", cfg=CFG, collect_stats=False,
                   use_kernels=True):
    """Teacher-forced logits; optionally also per-linear activation max-abs.

    Used for training (FP32: levels=0) and for calibration (stats=True).
    """
    ctx = _Ctx(params, mode, act_scales, act_levels, cfg, use_kernels)
    memory, src_mask = _encode(ctx, src_tokens)
    logits = _decode(ctx, tgt_in, memory, src_mask)
    if collect_stats:
        stats = jnp.stack([ctx.maxabs[n] for n in ctx.names])
        return logits, stats
    return logits


def translate(params, src_tokens, act_scales, act_levels, mode="dense", cfg=CFG,
              use_kernels=True):
    """Greedy decode: src tokens [B, S] -> tgt tokens [B, T].

    This is THE artifact the Rust coordinator executes for every BLEU
    evaluation. Encoder runs once; the decode loop re-runs the causally
    masked decoder over the growing buffer and argmaxes position ``i``.
    """
    ctx = _Ctx(params, mode, act_scales, act_levels, cfg, use_kernels)
    memory, src_mask = _encode(ctx, src_tokens)
    b = src_tokens.shape[0]
    t = cfg.seq_len
    init = jnp.full((b, t), data_mod.PAD_ID, dtype=jnp.int32)
    init = init.at[:, 0].set(data_mod.BOS_ID)

    def step(i, buf):
        logits = _decode(ctx, buf, memory, src_mask)
        nxt = jnp.argmax(logits[:, i], axis=-1).astype(jnp.int32)
        # Once EOS has been produced, keep emitting PAD.
        done = jnp.any(buf == data_mod.EOS_ID, axis=1)
        nxt = jnp.where(done, data_mod.PAD_ID, nxt)
        return buf.at[:, i + 1].set(nxt)

    buf = jax.lax.fori_loop(0, t - 1, step, init)
    return buf


# --------------------------------------------------------------------------
# Flat-argument wrappers for AOT lowering
# --------------------------------------------------------------------------

def make_flat_translate(mode: str, cfg: ModelConfig = CFG):
    """Return (fn, arg_names) where fn takes flat positional arrays.

    Argument order: src_tokens, act_scales, act_levels, then params in
    ``param_specs(mode)`` order — recorded in the manifest for Rust.
    """
    specs = param_specs(mode, cfg)
    names = [n for n, _ in specs]

    def fn(src_tokens, act_scales, act_levels, *flat):
        params = dict(zip(names, flat))
        return (translate(params, src_tokens, act_scales, act_levels, mode, cfg),)

    return fn, ["src_tokens", "act_scales", "act_levels"] + names


def make_flat_logits(mode: str, cfg: ModelConfig = CFG):
    """Flat-argument teacher-forced logits fn (for perplexity-style eval)."""
    specs = param_specs(mode, cfg)
    names = [n for n, _ in specs]

    def fn(src_tokens, tgt_in, act_scales, act_levels, *flat):
        params = dict(zip(names, flat))
        return (
            forward_logits(params, src_tokens, tgt_in, act_scales, act_levels,
                           mode, cfg),
        )

    return fn, ["src_tokens", "tgt_in", "act_scales", "act_levels"] + names
