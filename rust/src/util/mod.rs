//! Shared utilities: PRNG, JSON, statistics, thread pool, timing.

pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;

use std::time::Instant;

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}
