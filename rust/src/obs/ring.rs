//! Bounded ring-buffer event log for request postmortems.
//!
//! Terminal outcomes that did not produce a normal response (shed,
//! expired, cancelled, faulted) each push one [`Event`]; the newest
//! `capacity` events survive and are exported on `/v1/stats` so an
//! operator can see *which* requests died, at what stage, and why —
//! without any log files or external dependencies.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

/// One logged terminal event.
#[derive(Clone, Debug)]
pub struct Event {
    /// Monotone sequence number (never resets, survives eviction).
    pub seq: u64,
    /// Seconds since the ring was created.
    pub at_s: f64,
    /// Request id the event belongs to.
    pub id: u64,
    /// Terminal outcome key (`shed`, `expired`, `cancelled`, `faulted`).
    pub outcome: &'static str,
    /// Stage the request died in (`submit`, `queue`, `admit`, `decode`).
    pub stage: &'static str,
    /// Free-form detail (typically the `ServeError` display).
    pub detail: String,
}

struct Inner {
    seq: u64,
    buf: VecDeque<Event>,
}

/// Fixed-capacity, mutex-guarded event ring. Pushes happen at terminal
/// outcome frequency (not per decode step), so a mutex is fine.
pub struct Ring {
    capacity: usize,
    t0: Instant,
    inner: Mutex<Inner>,
}

impl Ring {
    pub fn new(capacity: usize) -> Ring {
        Ring {
            capacity: capacity.max(1),
            t0: Instant::now(),
            inner: Mutex::new(Inner { seq: 0, buf: VecDeque::new() }),
        }
    }

    pub fn push(&self, id: u64, outcome: &'static str, stage: &'static str, detail: String) {
        if !super::is_enabled() {
            return;
        }
        let at_s = self.t0.elapsed().as_secs_f64();
        let mut inner = self.inner.lock().unwrap();
        let seq = inner.seq;
        inner.seq += 1;
        if inner.buf.len() == self.capacity {
            inner.buf.pop_front();
        }
        inner.buf.push_back(Event { seq, at_s, id, outcome, stage, detail });
    }

    /// Newest `n` events, oldest first.
    pub fn tail(&self, n: usize) -> Vec<Event> {
        let inner = self.inner.lock().unwrap();
        inner.buf.iter().skip(inner.buf.len().saturating_sub(n)).cloned().collect()
    }

    /// Events currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total events ever pushed (including evicted ones).
    pub fn total(&self) -> u64 {
        self.inner.lock().unwrap().seq
    }

    /// JSON rendering of the newest `n` events for `/v1/stats`.
    pub fn to_json(&self, n: usize) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::Arr(
            self.tail(n)
                .into_iter()
                .map(|e| {
                    Json::obj(vec![
                        ("seq", Json::Num(e.seq as f64)),
                        ("at_s", Json::Num(e.at_s)),
                        ("id", Json::Num(e.id as f64)),
                        ("outcome", Json::Str(e.outcome.to_string())),
                        ("stage", Json::Str(e.stage.to_string())),
                        ("detail", Json::Str(e.detail)),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_keeps_newest_events_and_counts_all() {
        let _gate = crate::obs::test_gate().read().unwrap_or_else(|e| e.into_inner());
        let r = Ring::new(3);
        for i in 0..5u64 {
            r.push(i, "shed", "submit", format!("event {i}"));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.total(), 5);
        let tail = r.tail(10);
        let seqs: Vec<u64> = tail.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![2, 3, 4], "oldest two evicted, order preserved");
        assert_eq!(r.tail(1)[0].seq, 4);
    }
}
