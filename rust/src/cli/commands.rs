//! CLI command implementations.
//!
//! Always-built commands (`info`, `eval`, `serve`, `validate`) run on the
//! native runtime and the analytical hardware models; the figure runners
//! and search commands measure through the PJRT artifacts and need the
//! `pjrt` feature.

use anyhow::{bail, Result};

use crate::coordinator::{compress_model_from, serve_demo_native, Batcher, Method};
use crate::eval::{evaluate_bleu, Corpus};
#[cfg(feature = "pjrt")]
use crate::hw::Platform;
use crate::hw::{sim, TileConfig, Workload};
use crate::model::{Manifest, PairModel};
use crate::qkernel;
use crate::runtime::{DecodePolicy, KernelTier, Mode, NativeBackend};
use crate::tensor::Matrix;
use crate::util::pool::default_workers;
use crate::util::timed;

#[cfg(feature = "pjrt")]
use crate::config::ExpConfig;
#[cfg(feature = "pjrt")]
use crate::coordinator::figures::{self, CodesignPoint, MeasuredPoint};
#[cfg(feature = "pjrt")]
use crate::coordinator::Coordinator;

use super::Args;

#[cfg(feature = "pjrt")]
fn coordinator(args: &Args) -> Result<Coordinator> {
    let mut cfg = match args.flag("config") {
        Some(path) => ExpConfig::load(path)?,
        None => ExpConfig::default(),
    };
    if args.has("fast") {
        cfg = ExpConfig::fast();
    }
    Coordinator::new(cfg)
}

/// Parse the `--decode` flag (greedy-decode policy; cached by default).
fn decode_flag(args: &Args) -> Result<DecodePolicy> {
    match args.flag("decode") {
        None => Ok(DecodePolicy::default()),
        Some(d) => DecodePolicy::parse(d)
            .ok_or_else(|| anyhow::anyhow!("--decode expects replay|cached, got {d}")),
    }
}

/// Parse the `--kernel` flag (decode kernel tier; exact by default).
fn kernel_flag(args: &Args) -> Result<KernelTier> {
    match args.flag("kernel") {
        None => Ok(KernelTier::default()),
        Some(k) => KernelTier::parse(k)
            .ok_or_else(|| anyhow::anyhow!("--kernel expects exact|fast, got {k}")),
    }
}

/// Parse the `--batcher` flag (serving discipline; static by default).
fn batcher_flag(args: &Args) -> Result<Batcher> {
    match args.flag("batcher") {
        None => Ok(Batcher::default()),
        Some(b) => Batcher::parse(b)
            .ok_or_else(|| anyhow::anyhow!("--batcher expects static|continuous, got {b}")),
    }
}

/// Parse an optional usize flag (absent stays `None`, present must parse).
fn opt_usize(args: &Args, name: &str) -> Result<Option<usize>> {
    args.flag(name)
        .map(|s| s.parse::<usize>().map_err(|_| anyhow::anyhow!("--{name} expects a count, got {s}")))
        .transpose()
}

/// First registered language pair (the default for `--pair`).
fn default_pair(manifest: &Manifest) -> Result<String> {
    manifest
        .pairs
        .keys()
        .next()
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("manifest registers no language pairs"))
}

pub fn cmd_info(args: &Args) -> Result<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    println!("itera-llm: ITERA-LLM co-design framework");
    println!("runtime       : native (always built)");
    #[cfg(feature = "pjrt")]
    {
        let engine = crate::runtime::Engine::cpu()?;
        println!("PJRT platform : {}", engine.platform());
    }
    #[cfg(not(feature = "pjrt"))]
    println!("PJRT platform : (not compiled; build with --features pjrt)");
    println!(
        "model         : {} enc + {} dec layers, d={}, vocab={}, seq={}",
        manifest.model.n_enc,
        manifest.model.n_dec,
        manifest.model.d_model,
        manifest.model.vocab,
        manifest.model.seq_len
    );
    println!("compressed linears: {}", manifest.linears.len());
    println!("pairs         : {:?}", manifest.pairs.keys().collect::<Vec<_>>());
    println!("artifacts dir : {:?}", manifest.dir);

    // Memory accounting: dense f32 vs the W<wl> bit-packed layout the
    // quantized execution mode would keep resident for each linear.
    // Analytic projection from manifest shapes (`packed_bytes_for` is
    // exact for the dense layout: packed words + one f32 scale per
    // column); the actual bank of a factored compression is reported by
    // `eval --mode quantized`.
    let wl = args.flag_usize("wl", 4)? as u32;
    if !(2..=8).contains(&wl) {
        bail!("--wl {wl} out of range (packable word lengths are 2..=8)");
    }
    println!("\nper-layer weight bytes, dense layout (f32 vs W{wl} bit-packed):");
    let mut tot_f32 = 0usize;
    let mut tot_packed = 0usize;
    for l in &manifest.linears {
        let f32b = qkernel::fp32_bytes(l.k, l.n);
        let packed = qkernel::packed_bytes_for(l.k, l.n, wl);
        tot_f32 += f32b;
        tot_packed += packed;
        println!(
            "  {:<16} {:>4}x{:<4} {:>12} B {:>12} B  {:>6.2}x",
            l.name,
            l.k,
            l.n,
            f32b,
            packed,
            f32b as f64 / packed as f64
        );
    }
    println!(
        "  {:<16} {:>9} {:>12} B {:>12} B  {:>6.2}x  (dense-packing projection)",
        "total",
        "",
        tot_f32,
        tot_packed,
        tot_f32 as f64 / tot_packed.max(1) as f64
    );
    println!(
        "  (analytic, from manifest shapes; factored layers pack their factor \
         pair instead — `itera eval --mode quantized` reports the actual \
         resident bank)"
    );
    Ok(())
}

/// BLEU evaluation on the native runtime (works in every build): compress
/// with the requested method, execute greedily, score against the
/// references.
pub fn cmd_eval(args: &Args) -> Result<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    let pair = match args.flag("pair") {
        Some(p) => p.to_string(),
        None => default_pair(&manifest)?,
    };
    let model = PairModel::load(&manifest, &pair)?;
    let info = manifest
        .pairs
        .get(&pair)
        .ok_or_else(|| anyhow::anyhow!("unknown language pair {pair}"))?;
    let corpus = Corpus::load(&info.corpus)?;
    let limit = args.flag_usize("limit", 32)?;
    let workers = default_workers(8);

    let method_name = args.flag_or("method", "fp32");
    let (backend, label) = if method_name == "fp32" {
        if let Some(m) = args.flag("mode") {
            if m != "dense" {
                bail!("--mode {m} needs a quantized method; the FP32 reference runs dense");
            }
        }
        (NativeBackend::fp32(&manifest, &model, workers)?, "FP32 reference".to_string())
    } else {
        let wl = args.flag_usize("wl", 8)? as u32;
        if !(2..=8).contains(&wl) {
            bail!("--wl {wl} out of range (weight word length must be 2..=8)");
        }
        let frac = args.flag_f64("rank-frac", 0.5)?;
        let method = match method_name.as_str() {
            "quant" => Method::QuantOnly { wl },
            "svd" => Method::SvdBaseline { wl, rank_frac: frac },
            "itera" => Method::SvdIter { wl, rank_frac: frac },
            other => bail!("unknown method {other} (expected fp32|quant|svd|itera)"),
        };
        let weights: Vec<&Matrix> =
            manifest.linears.iter().map(|l| model.linear(&l.name)).collect();
        let (cm, dt) =
            timed(|| compress_model_from(&manifest.linears, &weights, &method, None, workers));
        println!("compressed {} linears in {dt:.1}s", manifest.linears.len());
        // --mode quantized executes the same compression bit-packed
        // (token-for-token identical to its fake-quant default mode);
        // without the flag the method's own mode runs.
        let mode = match args.flag("mode") {
            None => cm.mode(),
            Some(m) => Mode::parse(m)
                .ok_or_else(|| anyhow::anyhow!("--mode expects dense|svd|quantized"))?,
        };
        let backend = cm.native_backend_mode(&manifest, &model, mode, workers)?;
        (backend, format!("{} [{} exec]", method.label(), mode.key()))
    };

    let backend = backend.with_decode(decode_flag(args)?).with_kernel(kernel_flag(args)?);
    let (d, dt) = timed(|| evaluate_bleu(&backend, &corpus, &manifest.model, limit));
    let d = d?;
    println!("method      : {label}");
    println!("pair        : {pair}");
    println!("backend     : native");
    println!("decode      : {}", backend.decode_policy().key());
    println!("kernel      : {}", backend.kernel_tier().key());
    println!("resident    : {} weight bytes", backend.weight_bytes());
    println!("sentences   : {}", if limit == 0 { corpus.n } else { limit.min(corpus.n) });
    println!("BLEU        : {:.2}", d.score);
    println!("wall time   : {dt:.1}s");
    Ok(())
}

/// Run figure(s). Heavy figures share one compression sweep.
#[cfg(feature = "pjrt")]
pub fn cmd_fig(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all")
        .to_string();
    let pair = args.flag_or("pair", "en-de");
    run_figures(&which, &pair, args)
}

#[cfg(not(feature = "pjrt"))]
pub fn cmd_fig(_args: &Args) -> Result<()> {
    bail!("`itera fig` measures through the PJRT artifacts; build with --features pjrt")
}

#[cfg(feature = "pjrt")]
pub fn run_figures(which: &str, pair: &str, args: &Args) -> Result<()> {
    let needs_coordinator = which != "10";
    let c = if needs_coordinator { Some(coordinator(args)?) } else { None };
    let with_sra = !args.has("no-sra");
    let results = args.flag_or("results", "results");

    let mut sweep_cache: Option<Vec<MeasuredPoint>> = None;
    let mut sweep = |c: &Coordinator| -> Result<Vec<MeasuredPoint>> {
        if let Some(s) = &sweep_cache {
            return Ok(s.clone());
        }
        eprintln!("[fig] running compression sweep (pair {pair}, sra={with_sra}) ...");
        let pts = figures::compression_sweep(c, pair, with_sra)?;
        sweep_cache = Some(pts.clone());
        Ok(pts)
    };

    let run_one = |tag: &str, t: crate::coordinator::report::Table| -> Result<()> {
        print!("{}", t.render());
        t.write_csv(&results, tag)?;
        println!("[saved {results}/{tag}.csv]\n");
        Ok(())
    };

    let all = which == "all";
    if all || which == "1" {
        run_one("fig1", figures::fig1(c.as_ref().unwrap(), pair)?)?;
    }
    if all || which == "4" {
        let layers =
            ["enc0.self_q", "enc1.ff1", "dec0.self_v", "dec0.cross_q", "dec1.ff2", "dec1.cross_o"];
        run_one("fig4", figures::fig4(c.as_ref().unwrap(), pair, &layers)?)?;
    }
    if all || which == "7" {
        let pts = sweep(c.as_ref().unwrap())?;
        run_one("fig7", figures::fig7(c.as_ref().unwrap(), pair, &pts))?;
    }
    if all || which == "8" {
        let pts = sweep(c.as_ref().unwrap())?;
        run_one("fig8", figures::fig8(c.as_ref().unwrap(), pair, &pts))?;
    }
    if all || which == "9" {
        run_one("fig9", figures::fig9(c.as_ref().unwrap())?)?;
    }
    if all || which == "10" {
        run_one("fig10", figures::fig10(&Platform::zcu111()))?;
    }
    if all || which == "11" || which == "12" {
        let c = c.as_ref().unwrap();
        let pts = sweep(c)?;
        let full = Platform::zcu111();
        let quarter = Platform::zcu111_quarter_bw();
        let (t_full, cds_full) = figures::fig11(c, &pts, &full);
        let (t_quarter, cds_quarter) = figures::fig11(c, &pts, &quarter);
        if all || which == "11" {
            run_one("fig11_full_bw", t_full)?;
            run_one("fig11_quarter_bw", t_quarter)?;
            report_headline(&pts, &cds_full, &cds_quarter);
        }
        if all || which == "12" {
            let sel_full = select_fig12(&pts, &cds_full);
            let sel_quarter = select_fig12(&pts, &cds_quarter);
            let named_full: Vec<(&str, &CodesignPoint)> =
                sel_full.iter().map(|(s, p)| (s.as_str(), *p)).collect();
            let named_quarter: Vec<(&str, &CodesignPoint)> =
                sel_quarter.iter().map(|(s, p)| (s.as_str(), *p)).collect();
            run_one("fig12_full_bw", figures::fig12(c, &named_full, &full))?;
            run_one("fig12_quarter_bw", figures::fig12(c, &named_quarter, &quarter))?;
        }
    }
    Ok(())
}

/// Pick the paper's Fig. 12 designs: best quant point and best SVD-SRA
/// point (by BLEU-latency trade-off) in each bandwidth scenario.
#[cfg(feature = "pjrt")]
fn select_fig12<'a>(
    pts: &[MeasuredPoint],
    cds: &'a [CodesignPoint],
) -> Vec<(String, &'a CodesignPoint)> {
    let mut out = Vec::new();
    let quant_best = pts
        .iter()
        .zip(cds)
        .filter(|(p, _)| matches!(p.method, Method::QuantOnly { .. }))
        .max_by(|a, b| a.1.bleu.partial_cmp(&b.1.bleu).unwrap());
    if let Some((_, cd)) = quant_best {
        out.push((format!("quant[{}]", cd.label), cd));
    }
    let sra_best = pts
        .iter()
        .zip(cds)
        .filter(|(p, _)| matches!(p.method, Method::SvdIterRanks { .. } | Method::SvdIter { .. }))
        .min_by(|a, b| a.1.total_latency_cycles.partial_cmp(&b.1.total_latency_cycles).unwrap());
    if let Some((_, cd)) = sra_best {
        out.push((format!("svd[{}]", cd.label), cd));
    }
    out
}

/// The paper's headline: latency reduction of the best SVD point vs the
/// quant baseline at comparable BLEU (within 1 BLEU).
#[cfg(feature = "pjrt")]
fn report_headline(pts: &[MeasuredPoint], full: &[CodesignPoint], quarter: &[CodesignPoint]) {
    for (tag, cds) in [("full-bw", full), ("quarter-bw", quarter)] {
        let mut best: Option<(f64, String, String)> = None;
        for (pi, p) in pts.iter().enumerate() {
            if !matches!(p.method, Method::QuantOnly { .. }) {
                continue;
            }
            for (qi, q) in pts.iter().enumerate() {
                if matches!(q.method, Method::QuantOnly { .. }) {
                    continue;
                }
                if q.bleu + 1.0 < p.bleu {
                    continue; // not comparable accuracy
                }
                let red = figures::headline_latency_reduction(&cds[pi], &cds[qi]);
                if best.as_ref().map(|b| red > b.0).unwrap_or(true) {
                    best = Some((red, cds[pi].label.clone(), cds[qi].label.clone()));
                }
            }
        }
        if let Some((red, ql, sl)) = best {
            println!(
                "[headline {tag}] '{sl}' vs '{ql}': linear-layer latency reduction {:.1}%",
                red * 100.0
            );
        }
    }
}

#[cfg(feature = "pjrt")]
pub fn cmd_compress(args: &Args) -> Result<()> {
    let c = coordinator(args)?;
    let pair = args.flag_or("pair", "en-de");
    let wl = args.flag_usize("wl", 4)? as u32;
    let frac = args.flag_f64("rank-frac", 0.5)?;
    let method = match args.flag_or("method", "itera").as_str() {
        "quant" => Method::QuantOnly { wl },
        "svd" => Method::SvdBaseline { wl, rank_frac: frac },
        "itera" => Method::SvdIter { wl, rank_frac: frac },
        other => bail!("unknown method {other}"),
    };
    let (p, dt) = timed(|| c.measure(&pair, &method));
    let p = p?;
    println!("method      : {}", p.label);
    println!("pair        : {pair}");
    println!("BLEU        : {:.2}", p.bleu);
    println!("compression : {:.2}x vs FP32", p.ratio);
    println!("linear MACs : {:.2} G (batch {})", p.nops as f64 / 1e9, c.cfg.nops_batch);
    println!("wall time   : {dt:.1}s");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
pub fn cmd_compress(_args: &Args) -> Result<()> {
    bail!(
        "`itera compress` measures through the PJRT artifacts; build with \
         --features pjrt (or use `itera eval` for the native runtime)"
    )
}

#[cfg(feature = "pjrt")]
pub fn cmd_sra(args: &Args) -> Result<()> {
    let c = coordinator(args)?;
    let pair = args.flag_or("pair", "en-de");
    let wl = args.flag_usize("wl", 4)? as u32;
    let frac = args.flag_f64("budget-frac", 0.5)?;
    let caps = c.manifest.rank_caps();
    let total: usize = caps.iter().sum();
    let budget = ((total as f64 * frac) as usize).max(caps.len());
    println!("[sra] pair {pair}, W{wl}A8, rank budget {budget} (of {total})");
    let ((ranks, calib_bleu), dt) = timed(|| c.sra_search(&pair, wl, budget));
    println!("[sra] calib BLEU {:.2} after search ({dt:.0}s)", calib_bleu);
    let p = c.measure(&pair, &Method::SvdIterRanks { wl, ranks: ranks.clone() })?;
    let uniform = c.measure(
        &pair,
        &Method::SvdIter { wl, rank_frac: frac },
    )?;
    println!("[sra] test BLEU {:.2} (uniform-rank baseline {:.2})", p.bleu, uniform.bleu);
    println!("[sra] per-layer ranks:");
    for (l, r) in c.manifest.linears.iter().zip(&ranks) {
        println!("    {:<14} {r}", l.name);
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
pub fn cmd_sra(_args: &Args) -> Result<()> {
    bail!("`itera sra` needs the coordinator's PJRT oracle; build with --features pjrt")
}

/// Analytical model vs cycle-level simulator cross-validation table —
/// or, with `--mode quantized`, the packed-kernel cross-validation:
/// pack/unpack exactness, GEMM bit-parity vs the fake-quant f32 kernel,
/// and the byte accounting per word length. With `--decode cached`, the
/// KV-cached decode is cross-validated against the full-buffer replay
/// reference instead (optionally restricted to one `--mode`). With
/// `--batcher continuous`, the slot-scheduled continuous decode is
/// cross-validated against per-request sequential decode (again
/// optionally restricted to one `--mode`). With `--kernel fast`, the
/// non-bit-exact integer decode tier is gated against the exact step
/// reference under a parity-tolerance table (`--kernel exact` asserts
/// bit-identity instead).
pub fn cmd_validate(args: &Args) -> Result<()> {
    use crate::coordinator::report::Table;
    if args.has("kernel") {
        return validate_kernel_tier(args);
    }
    if args.has("batcher") {
        return validate_continuous(args);
    }
    if args.has("decode") {
        return validate_decode(args);
    }
    if args.flag("mode") == Some("quantized") {
        return validate_quantized();
    }
    let mut t = Table::new(
        "Analytical model vs dataflow simulator (512^3 W4A8)",
        &["tile", "analytical_cycles", "simulated_cycles", "ratio", "sim_occupancy"],
    );
    let w = Workload::new(512, 512, 512, 4, 8);
    for (mt, nt, kf) in [(8, 8, 8), (16, 16, 8), (16, 16, 16), (32, 16, 16), (32, 32, 8)] {
        let tile = TileConfig::new(mt, nt, kf);
        let ana = crate::hw::tile_latency_cycles(&w, &tile);
        let s = sim::simulate_matmul(&w, &tile, 1e12);
        t.row(vec![
            format!("{mt}x{nt}x{kf}"),
            format!("{:.0}", ana.latency_cycles),
            format!("{:.0}", s.cycles),
            format!("{:.3}", s.cycles / ana.latency_cycles),
            format!("{:.1}%", s.occupancy * 100.0),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

/// `validate --mode quantized`: cross-validate the qkernel packed storage
/// and integer GEMM against the fake-quant f32 reference on random
/// weights, per word length. "exact" columns must all read `yes` — the
/// same bit-parity contract `tests/e2e_native.rs` pins end-to-end.
fn validate_quantized() -> Result<()> {
    use crate::coordinator::report::Table;
    use crate::qkernel::{packed_bytes_for, QMatrix, ScaleAxis};
    use crate::util::rng::Pcg64;

    let mut t = Table::new(
        "qkernel cross-validation (96x80 weights, 24-row activations)",
        &["wl", "unpack_exact", "gemm_bit_exact", "packed_B", "fp32_B", "ratio"],
    );
    let (k, n) = (96usize, 80usize);
    let mut rng = Pcg64::new(0x9C0DE);
    let w = Matrix::randn(k, n, &mut rng).scale(0.2);
    let x = Matrix::randn(24, k, &mut rng);
    let yes_no = |ok: bool| if ok { "yes".to_string() } else { "NO".to_string() };
    let mut all_ok = true;
    for wl in 2..=8u32 {
        let (q, scales) = crate::quant::quantize_cols(&w, wl);
        let qm = QMatrix::from_fake_quant(&q, &scales, wl, ScaleAxis::Col)?;
        let unpack_ok = qm.to_matrix().data() == q.data();
        let gemm_ok = qm.qmatmul(&x).data() == x.matmul(&q).data();
        let packed = qm.packed_bytes();
        let bytes_ok = packed == packed_bytes_for(k, n, wl);
        all_ok &= unpack_ok && gemm_ok && bytes_ok;
        let f32b = qm.fp32_bytes();
        t.row(vec![
            format!("W{wl}"),
            yes_no(unpack_ok),
            yes_no(gemm_ok),
            format!("{packed}{}", if bytes_ok { "" } else { " (MISMATCH)" }),
            format!("{f32b}"),
            format!("{:.2}x", f32b as f64 / packed as f64),
        ]);
    }
    print!("{}", t.render());
    // Fail the command (non-zero exit) on any parity/accounting break, so
    // scripts and CI can gate on it.
    if !all_ok {
        bail!("qkernel cross-validation FAILED — see table above");
    }
    Ok(())
}

/// Parse the optional `--mode` filter of a validation sub-command.
fn only_mode_flag(args: &Args) -> Result<Option<Mode>> {
    match args.flag("mode") {
        None => Ok(None),
        Some(m) => Mode::parse(m)
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("--mode expects dense|svd|quantized")),
    }
}

/// The cross-validation banks shared by the decode- and batcher-parity
/// tables: one compression per execution mode/structure — dense
/// fake-quant, true-rank factors, and both packed forms (the cascade
/// covers both qkernel scale axes). Kept in one place so the two parity
/// sub-commands can never drift apart in what they test.
fn validation_cases(
    manifest: &Manifest,
    model: &PairModel,
) -> Vec<(
    &'static str,
    Mode,
    std::collections::BTreeMap<String, crate::compress::CompressedLinear>,
)> {
    use std::collections::BTreeMap;

    use crate::compress::{itera, quant_only, CompressedLinear};

    let factor_bank = |wl: u32| -> BTreeMap<String, CompressedLinear> {
        manifest
            .linears
            .iter()
            .map(|l| {
                let r = (l.r_max / 2).max(1);
                (l.name.clone(), itera(model.linear(&l.name), r, wl).0)
            })
            .collect()
    };
    let quant_bank = |wl: u32| -> BTreeMap<String, CompressedLinear> {
        manifest
            .linears
            .iter()
            .map(|l| (l.name.clone(), quant_only(model.linear(&l.name), wl)))
            .collect()
    };
    vec![
        ("quant W8", Mode::Dense, quant_bank(8)),
        ("itera W8 r/2", Mode::Svd, factor_bank(8)),
        ("quant W6 packed", Mode::Quantized, quant_bank(6)),
        ("itera W4 packed cascade", Mode::Quantized, factor_bank(4)),
    ]
}

/// `validate --decode cached [--mode <m>]`: cross-validate the KV-cached
/// incremental decode against the full-buffer replay reference on the
/// hermetic tiny model — greedy tokens must match **bit for bit** per
/// execution mode — and report the modeled linear-MAC reduction. Fails
/// (non-zero exit) on any divergence, so CI can gate on it.
fn validate_decode(args: &Args) -> Result<()> {
    use crate::coordinator::report::Table;
    use crate::runtime::TranslateBackend;
    use crate::testkit::tinymodel;

    if decode_flag(args)? != DecodePolicy::Cached {
        bail!("--decode replay IS the reference; pass --decode cached to cross-validate");
    }
    let only_mode = only_mode_flag(args)?;

    let (dir, manifest) = tinymodel::generate_in_temp("validate_decode", 0xD0C5)?;
    let model = PairModel::load(&manifest, tinymodel::PAIR)?;
    let corpus = Corpus::load(&manifest.pairs[tinymodel::PAIR].corpus)?;
    let rows = corpus.n;
    let src = corpus.src_batch(0, rows, manifest.model.pad_id);
    let cases = validation_cases(&manifest, &model);

    let mut t = Table::new(
        "KV-cached decode vs full-buffer replay (hermetic tiny model)",
        &["mode", "bank", "tokens_exact", "replay_MACs", "cached_MACs", "reduction"],
    );
    let mut all_ok = true;
    let mut ran = 0usize;
    for (bank, mode, layers) in &cases {
        if let Some(m) = only_mode {
            if m != *mode {
                continue;
            }
        }
        ran += 1;
        let replay = NativeBackend::new(&manifest, &model, layers, Some(8), *mode, 2)?
            .with_decode(DecodePolicy::Replay);
        let cached = NativeBackend::new(&manifest, &model, layers, Some(8), *mode, 2)?;
        let ok = replay.translate(&src)? == cached.translate(&src)?;
        all_ok &= ok;
        let rm = cached.linear_macs_for(rows, DecodePolicy::Replay);
        let cm = cached.linear_macs_for(rows, DecodePolicy::Cached);
        t.row(vec![
            mode.key().to_string(),
            bank.to_string(),
            if ok { "yes" } else { "NO" }.to_string(),
            format!("{rm}"),
            format!("{cm}"),
            format!("{:.2}x", rm as f64 / cm.max(1) as f64),
        ]);
    }
    print!("{}", t.render());
    std::fs::remove_dir_all(&dir).ok();
    if ran == 0 {
        bail!("no decode-parity case matches --mode {:?}", args.flag("mode"));
    }
    if !all_ok {
        bail!("cached decode DIVERGED from the replay reference — see table above");
    }
    Ok(())
}

/// `validate --batcher continuous [--mode <m>] [--decode cached]`:
/// cross-validate the slot-scheduled continuous decode against
/// per-request sequential cached decode on the hermetic tiny model. The
/// full corpus is fed through a `ContinuousBatcher` on a staggered
/// arrival trace (a backlog plus one new request per tick, so admissions
/// splice into a live mixed-age batch); every completed buffer must
/// match `translate` of that request alone **bit for bit** — per
/// execution mode (the packed cascade covers both qkernel scale axes).
/// Fails (non-zero exit) on any divergence, so CI can gate on it.
///
/// With `--kv-budget BYTES` the run additionally swaps in a byte-bounded
/// paged KV pool, so the same parity contract is checked under
/// memory-bounded admission and preemption-by-eviction (evicted slots
/// re-prefill and must still match the sequential reference bit for
/// bit). `--kv-budget 0` auto-picks a deliberately tight budget (1.5x
/// one slot's worst-case page demand) so CI needs no model-dependent
/// byte math; `--page-tokens N` sets the page grain (default 2 rows).
fn validate_continuous(args: &Args) -> Result<()> {
    use crate::coordinator::report::Table;
    use crate::coordinator::ContinuousBatcher;
    use crate::runtime::{SlotEngine, TranslateBackend};
    use crate::testkit::tinymodel;

    if batcher_flag(args)? != Batcher::Continuous {
        bail!("--batcher static IS the reference; pass --batcher continuous to cross-validate");
    }
    if decode_flag(args)? != DecodePolicy::Cached {
        bail!("the continuous batcher schedules KV slots; only --decode cached applies");
    }
    let only_mode = only_mode_flag(args)?;

    let (dir, manifest) = tinymodel::generate_in_temp("validate_batcher", 0xBA7C)?;
    let model = PairModel::load(&manifest, tinymodel::PAIR)?;
    let corpus = Corpus::load(&manifest.pairs[tinymodel::PAIR].corpus)?;
    let s = manifest.model.seq_len;
    let capacity = 3usize;
    let kv_budget = opt_usize(args, "kv-budget")?;
    let page_tokens = opt_usize(args, "page-tokens")?;
    let cases = validation_cases(&manifest, &model);

    let kv_note = if kv_budget.is_some() { ", byte-bounded KV pool" } else { "" };
    let mut t = Table::new(
        &format!(
            "Continuous batcher vs sequential cached decode (hermetic tiny model, \
             capacity {capacity}, staggered arrivals{kv_note})"
        ),
        &["mode", "bank", "requests", "tokens_exact", "decode_steps", "preempted", "occupancy"],
    );
    let mut all_ok = true;
    let mut ran = 0usize;
    for (bank, mode, layers) in &cases {
        if let Some(m) = only_mode {
            if m != *mode {
                continue;
            }
        }
        ran += 1;
        let backend = NativeBackend::new(&manifest, &model, layers, Some(8), *mode, 2)?;
        let backend = match (kv_budget, page_tokens) {
            (None, None) => backend,
            (budget, pt) => {
                let pt = pt.unwrap_or(2).clamp(1, s.max(1));
                let backend = backend.with_kv_pool(None, pt);
                match budget {
                    None => backend,
                    Some(b) => {
                        let worst = backend.slot_worst_bytes();
                        let b = if b == 0 { worst + worst / 2 } else { b };
                        if b < worst {
                            bail!(
                                "--kv-budget {b} is below one slot's worst-case page \
                                 demand ({worst} B); nothing would ever be admitted"
                            );
                        }
                        backend.with_kv_pool(Some(b), pt)
                    }
                }
            }
        };

        // Sequential reference: each corpus row decoded alone through the
        // existing cached path.
        let rows: Vec<Vec<i32>> = (0..corpus.n).map(|i| corpus.src_row(i).to_vec()).collect();
        let want = backend.translate_stream(&rows)?;

        // Continuous run on a staggered trace: 2 requests up front, one
        // more per tick — later admissions join a batch of older slots.
        let mut batcher = ContinuousBatcher::new(&backend, capacity);
        let mut submitted = 0usize;
        let mut got: Vec<Option<Vec<i32>>> = vec![None; rows.len()];
        while submitted < rows.len().min(2) {
            batcher
                .submit(rows[submitted].clone())
                .map_err(|e| anyhow::anyhow!("unbounded queue refused a request: {e}"))?;
            submitted += 1;
        }
        while !(submitted == rows.len() && batcher.idle()) {
            if submitted < rows.len() {
                batcher
                    .submit(rows[submitted].clone())
                    .map_err(|e| anyhow::anyhow!("unbounded queue refused a request: {e}"))?;
                submitted += 1;
            }
            for c in batcher.tick() {
                let toks = c
                    .result
                    .map_err(|e| anyhow::anyhow!("request {} faulted during parity run: {e}", c.id))?;
                got[c.id as usize] = Some(toks);
            }
        }

        let ok = got
            .iter()
            .zip(&want)
            .all(|(g, w)| g.as_ref().map(|g| g.as_slice()) == Some(&w[..s]));
        all_ok &= ok;
        t.row(vec![
            mode.key().to_string(),
            bank.to_string(),
            format!("{}", rows.len()),
            if ok { "yes" } else { "NO" }.to_string(),
            format!("{}", batcher.stats().steps),
            format!("{}", batcher.stats().preempted),
            format!("{:.2}", batcher.occupancy()),
        ]);
    }
    print!("{}", t.render());
    std::fs::remove_dir_all(&dir).ok();
    if ran == 0 {
        bail!("no continuous-parity case matches --mode {:?}", args.flag("mode"));
    }
    if !all_ok {
        bail!("continuous-batched decode DIVERGED from sequential decode — see table above");
    }
    Ok(())
}

/// `validate --kernel <tier> [--mode quantized] [--decode cached]`: the
/// kernel-tier parity gate on the hermetic tiny model. The packed
/// validation banks (dense packed + low-rank cascade, covering both
/// qkernel scale axes) decode under the requested tier and are compared
/// against the exact step reference on three surfaces: teacher-forced
/// step logits (max |Δlogit| over every step of every corpus row),
/// greedy decode tokens, and corpus BLEU.
///
/// `--kernel exact` must be **bit-identical** on all three (the tier
/// threaded through is the same fake-quant step path that has always
/// run — this leg pins that the tier plumbing itself changes nothing).
/// `--kernel fast` is non-bit-exact by contract: it passes while
/// max |Δlogit| stays inside a scale-aware tolerance and the BLEU delta
/// stays inside `MAX_BLEU_DELTA`. Any breach fails the command
/// (non-zero exit), so CI gates merging on fast-tier parity.
fn validate_kernel_tier(args: &Args) -> Result<()> {
    use crate::coordinator::report::Table;
    use crate::runtime::TranslateBackend;
    use crate::testkit::tinymodel;

    /// Fast-tier floor for the |Δlogit| tolerance: runtime A8 activation
    /// quantization perturbs each packed linear by ~0.4% relative, so
    /// tiny-model logits land well inside this; a broken kernel (wrong
    /// scale axis, wrapped accumulator, dropped rescale) lands orders of
    /// magnitude outside it.
    const MIN_DLOGIT_TOL: f32 = 1.5;
    /// Fast-tier |Δlogit| tolerance as a fraction of the largest exact
    /// logit magnitude (keeps the gate meaningful if the tiny model's
    /// logit scale drifts).
    const REL_DLOGIT_TOL: f32 = 0.05;
    /// Fast-tier BLEU-delta ceiling (points): near-tie argmax flips may
    /// move a few sentences, a garbage decode collapses BLEU entirely.
    const MAX_BLEU_DELTA: f64 = 15.0;

    let tier = kernel_flag(args)?;
    if decode_flag(args)? != DecodePolicy::Cached {
        bail!("kernel tiers dispatch inside the KV-cached step path; pass --decode cached");
    }
    if let Some(m) = only_mode_flag(args)? {
        if m != Mode::Quantized {
            bail!("kernel tiers dispatch inside packed linears; pass --mode quantized");
        }
    }

    let (dir, manifest) = tinymodel::generate_in_temp("validate_kernel", 0xFA57)?;
    let model = PairModel::load(&manifest, tinymodel::PAIR)?;
    let corpus = Corpus::load(&manifest.pairs[tinymodel::PAIR].corpus)?;
    let s = manifest.model.seq_len;
    let cases = validation_cases(&manifest, &model);

    let mut t = Table::new(
        &format!(
            "{} kernel tier vs exact step reference (hermetic tiny model, {} rows)",
            tier.key(),
            corpus.n
        ),
        &["bank", "max_dlogit", "dlogit_tol", "tokens_equal", "bleu_exact", "bleu_tier", "pass"],
    );
    let mut all_ok = true;
    for (bank, mode, layers) in &cases {
        // Only the packed banks dispatch through the tiered kernels.
        if *mode != Mode::Quantized {
            continue;
        }
        let exact = NativeBackend::new(&manifest, &model, layers, Some(8), *mode, 2)?;
        let tiered =
            NativeBackend::new(&manifest, &model, layers, Some(8), *mode, 2)?.with_kernel(tier);

        let rows: Vec<Vec<i32>> = (0..corpus.n).map(|i| corpus.src_row(i).to_vec()).collect();
        let want = exact.translate_stream(&rows)?;
        let got = tiered.translate_stream(&rows)?;
        let tokens_equal = want == got;

        // Teacher-force the exact tier's own decodes through both tiers'
        // step kernels; every logit of every step is compared, so the
        // bound covers positions greedy decode never argmaxes.
        let mut dmax = 0.0f32;
        let mut lmax = 0.0f32;
        for (src, tgt) in rows.iter().zip(&want) {
            let a = exact.step_logits(src, &tgt[..s])?;
            let b = tiered.step_logits(src, &tgt[..s])?;
            for (&x, &y) in a.data().iter().zip(b.data()) {
                let d = (x - y).abs();
                // `!(<=)` keeps NaN sticky: a poisoned logit can never
                // slip under the tolerance.
                if !(d <= dmax) {
                    dmax = d;
                }
                if !(x.abs() <= lmax) {
                    lmax = x.abs();
                }
            }
        }

        let bleu_exact = evaluate_bleu(&exact, &corpus, &manifest.model, 0)?.score;
        let bleu_tier = evaluate_bleu(&tiered, &corpus, &manifest.model, 0)?.score;
        let bleu_delta = (bleu_exact - bleu_tier).abs();

        let tol = match tier {
            KernelTier::Exact => 0.0,
            KernelTier::Fast => MIN_DLOGIT_TOL.max(REL_DLOGIT_TOL * lmax),
        };
        let ok = match tier {
            KernelTier::Exact => dmax == 0.0 && tokens_equal && bleu_delta == 0.0,
            KernelTier::Fast => dmax <= tol && bleu_delta <= MAX_BLEU_DELTA,
        };
        all_ok &= ok;
        t.row(vec![
            bank.to_string(),
            format!("{dmax:.6}"),
            format!("{tol:.3}"),
            if tokens_equal { "yes" } else { "no" }.to_string(),
            format!("{bleu_exact:.2}"),
            format!("{bleu_tier:.2}"),
            if ok { "yes" } else { "NO" }.to_string(),
        ]);
    }
    print!("{}", t.render());
    std::fs::remove_dir_all(&dir).ok();
    if !all_ok {
        bail!(
            "{} kernel tier BREACHED its parity tolerance — see table above",
            tier.key()
        );
    }
    Ok(())
}

/// Batched serving demo: random test sentences through a compressed
/// model, reporting latency/throughput percentiles. Native by default;
/// `--backend pjrt` uses the AOT artifacts (pjrt builds only). For the
/// native backend, `--mode quantized` serves the bit-packed weight bank.
///
/// Robustness knobs (continuous batcher only): `--queue-limit` bounds
/// admission (overflow sheds with a typed `Overloaded` error),
/// `--deadline` / `--max-new-tokens` set server-side default limits in
/// decode steps / generated tokens, and `--burst` drives the demo client
/// with that many requests in flight (overload needs `burst` past
/// capacity + queue limit). `--kv-budget BYTES` caps the paged KV pool
/// (admission becomes memory-bounded; under pressure the youngest slot
/// is evicted and replayed) and `--page-tokens N` sets the page grain.
/// `--tinymodel` serves the hermetic synthetic model instead of trained
/// artifacts — the CI overload smoke runs without any Python-built
/// files.
pub fn cmd_serve(args: &Args) -> Result<()> {
    use crate::coordinator::{RequestLimits, ServeTuning};

    let requests = args.flag_usize("requests", 64)?;
    let mut limits = RequestLimits::none();
    if let Some(d) = opt_usize(args, "deadline")? {
        limits = limits.with_deadline(d);
    }
    if let Some(m) = opt_usize(args, "max-new-tokens")? {
        limits = limits.with_max_new_tokens(m);
    }
    let tuning = ServeTuning {
        queue_limit: opt_usize(args, "queue-limit")?,
        limits,
        burst: args.flag_usize("burst", 1)?,
        kv_budget: opt_usize(args, "kv-budget")?,
        page_tokens: opt_usize(args, "page-tokens")?,
        kernel: kernel_flag(args)?,
    };
    if let Some(listen) = args.flag("listen") {
        return cmd_serve_http(args, listen, &tuning);
    }
    match args.flag_or("backend", "native").as_str() {
        "native" => {
            let (tmp_dir, manifest) = if args.has("tinymodel") {
                let (dir, manifest) =
                    crate::testkit::tinymodel::generate_in_temp("serve_cli", 0x5E4E)?;
                (Some(dir), manifest)
            } else {
                (None, Manifest::load(Manifest::default_dir())?)
            };
            let pair = match args.flag("pair") {
                Some(p) => p.to_string(),
                None => default_pair(&manifest)?,
            };
            // The serving demo compresses quant-only (Dense layers), so
            // the factored `svd` execution form has nothing to run on.
            let mode = match args.flag("mode") {
                None | Some("dense") => Mode::Dense,
                Some("quantized") => Mode::Quantized,
                Some(m) => bail!("serve --mode expects dense|quantized, got {m}"),
            };
            let decode = decode_flag(args)?;
            let batcher = batcher_flag(args)?;
            let out = serve_demo_native(
                &manifest,
                &pair,
                requests,
                default_workers(8),
                mode,
                decode,
                batcher,
                &tuning,
            );
            if let Some(dir) = tmp_dir {
                std::fs::remove_dir_all(&dir).ok();
            }
            out?;
            Ok(())
        }
        #[cfg(feature = "pjrt")]
        "pjrt" => {
            if let Some(m) = args.flag("mode") {
                bail!("--mode {m} applies to the native backend; the PJRT demo runs dense");
            }
            if batcher_flag(args)? != Batcher::Static {
                bail!(
                    "--batcher continuous needs the native slot API; the AOT artifacts \
                     only translate monolithic batches"
                );
            }
            let c = coordinator(args)?;
            let pair = args.flag_or("pair", "en-de");
            crate::coordinator::serve_demo(&c, &pair, requests)?;
            Ok(())
        }
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => bail!("this binary was built without the `pjrt` feature"),
        other => bail!("unknown backend {other} (expected native|pjrt)"),
    }
}

/// `serve --listen ADDR`: expose the continuous serve loop over HTTP/1.1
/// on the native backend. Runs until `POST /v1/shutdown` — or, with
/// `--loadgen N`, self-drives: a seeded open-loop load generator fires
/// `N` requests at `--rate` req/s over `--connections` keep-alive
/// connections against the server's own port, requests a drain when
/// done, and both sides' reports are printed (the CI HTTP smoke). The
/// self-drive also scrapes `/metrics` + `/v1/stats` while the server is
/// live and bails if the exported counters don't balance or disagree
/// with the client's own ledger. `--metrics` prints a one-line
/// telemetry digest every second.
fn cmd_serve_http(
    args: &Args,
    listen: &str,
    tuning: &crate::coordinator::ServeTuning,
) -> Result<()> {
    if args.flag_or("backend", "native") != "native" {
        bail!("--listen serves the native backend only");
    }
    let (tmp_dir, manifest) = if args.has("tinymodel") {
        let (dir, manifest) = crate::testkit::tinymodel::generate_in_temp("serve_http", 0x5E4E)?;
        (Some(dir), manifest)
    } else {
        (None, Manifest::load(Manifest::default_dir())?)
    };
    let out = serve_http_native(args, &manifest, listen, tuning);
    if let Some(dir) = tmp_dir {
        std::fs::remove_dir_all(&dir).ok();
    }
    out
}

fn serve_http_native(
    args: &Args,
    manifest: &Manifest,
    listen: &str,
    tuning: &crate::coordinator::ServeTuning,
) -> Result<()> {
    use crate::coordinator::{ServeConfig, ShutdownSignal};
    use crate::server::loadgen::{run_loadgen, LoadGenConfig};
    use crate::server::{serve_http, HttpConfig};

    let pair = match args.flag("pair") {
        Some(p) => p.to_string(),
        None => default_pair(manifest)?,
    };
    let mode = match args.flag("mode") {
        None | Some("dense") => Mode::Dense,
        Some("quantized") => Mode::Quantized,
        Some(m) => bail!("serve --mode expects dense|quantized, got {m}"),
    };
    let workers = default_workers(8);
    let model = PairModel::load(manifest, &pair)?;
    let weights: Vec<&Matrix> = manifest.linears.iter().map(|l| model.linear(&l.name)).collect();
    let cm = compress_model_from(
        &manifest.linears,
        &weights,
        &Method::QuantOnly { wl: 8 },
        None,
        workers,
    );
    let backend = cm
        .native_backend_mode(manifest, &model, mode, workers)?
        .with_decode(DecodePolicy::Cached)
        .with_kernel(tuning.kernel);
    // `--kv-budget` / `--page-tokens`: swap the unbounded compatibility
    // pool for a byte-bounded paged one before any slot exists.
    let backend = if tuning.kv_budget.is_some() || tuning.page_tokens.is_some() {
        let pt = tuning.page_tokens.unwrap_or(manifest.model.seq_len);
        backend.with_kv_pool(tuning.kv_budget, pt)
    } else {
        backend
    };
    // The native backend's slot capacity is the model's eval batch.
    let mut serve_cfg = ServeConfig::new(manifest.model.eval_batch);
    serve_cfg.queue_limit = tuning.queue_limit;
    serve_cfg.default_limits = tuning.limits;
    let shutdown = ShutdownSignal::new();
    serve_cfg.shutdown = Some(shutdown.clone());

    let listener = std::net::TcpListener::bind(listen)?;
    let addr = listener.local_addr()?;
    println!(
        "itera http server on {addr} (pair {pair}, W8A8, {} exec, {} kernel)",
        mode.key(),
        tuning.kernel.key()
    );

    let load_cfg = match opt_usize(args, "loadgen")? {
        None => None,
        Some(n) => Some(LoadGenConfig {
            connections: args.flag_usize("connections", 8)?,
            requests: n,
            rate: args.flag_f64("rate", 0.0)?,
            len_range: (2, manifest.model.seq_len.saturating_sub(2).max(2)),
            vocab: manifest.model.vocab as i32,
            deadline_steps: tuning.limits.deadline_steps,
            retry_503: args.flag_usize("retry-503", 0)?,
            ..LoadGenConfig::default()
        }),
    };
    let client = load_cfg.map(|cfg| {
        let shutdown = shutdown.clone();
        std::thread::spawn(move || {
            let report = run_loadgen(addr, &cfg);
            // Scrape telemetry before requesting the drain, so the
            // check exercises the endpoints on a live server.
            let scrape = scrape_telemetry(addr);
            shutdown.drain();
            (report, scrape)
        })
    });
    let digest = args.has("metrics").then(|| {
        use std::sync::atomic::{AtomicBool, Ordering};
        let obs = serve_cfg.obs.clone();
        let stop = std::sync::Arc::new(AtomicBool::new(false));
        let flag = std::sync::Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            let mut ticks = 0u32;
            while !flag.load(Ordering::Relaxed) {
                std::thread::sleep(std::time::Duration::from_millis(100));
                ticks += 1;
                if ticks % 10 == 0 {
                    println!("{}", metrics_digest_line(&obs));
                }
            }
            println!("{}", metrics_digest_line(&obs));
        });
        (stop, handle)
    });

    let mut http_cfg = HttpConfig::new(serve_cfg);
    http_cfg.max_connections = args.flag_usize("max-connections", 256)?;
    let stats = serve_http(&backend, listener, &manifest.model, http_cfg)?;
    if let Some((stop, handle)) = digest {
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        handle.join().ok();
    }

    println!("== server stats ==");
    println!(
        "served {} / received {} (shed {} expired {} cancelled {} faulted {})",
        stats.served, stats.received, stats.shed, stats.expired, stats.cancelled, stats.faulted
    );
    println!(
        "decode steps {} occupancy {:.2} tokens/s {:.1}",
        stats.batches,
        stats.occupancy,
        stats.tokens_per_s()
    );
    println!(
        "latency p50 {:.4}s p95 {:.4}s (queue-wait p95 {:.4}s execution p95 {:.4}s)",
        stats.latency.quantile(0.5),
        stats.latency.quantile(0.95),
        stats.queue_wait.quantile(0.95),
        stats.execution.quantile(0.95)
    );
    if !stats.is_balanced() {
        bail!("serve stats do not balance: {stats:?}");
    }
    if let Some(c) = client {
        let (report, scrape) = c.join().map_err(|_| anyhow::anyhow!("loadgen panicked"))?;
        let report = report?;
        report.print("self-drive");
        if report.ok == 0 {
            bail!("loadgen saw no successful responses");
        }
        verify_scrape(&scrape?, &report)?;
    }
    Ok(())
}

/// Pull `/metrics` (Prometheus text) and `/v1/stats` (JSON) from a
/// live server in one pass.
fn scrape_telemetry(addr: std::net::SocketAddr) -> Result<(String, crate::util::json::Json)> {
    use crate::server::loadgen::http_get;
    let metrics = http_get(addr, "/metrics")?;
    if metrics.status != 200 {
        bail!("GET /metrics returned {}", metrics.status);
    }
    let text =
        String::from_utf8(metrics.body).map_err(|_| anyhow::anyhow!("/metrics is not utf-8"))?;
    let stats = http_get(addr, "/v1/stats")?;
    if stats.status != 200 {
        bail!("GET /v1/stats returned {}", stats.status);
    }
    let json = stats.json().map_err(|e| anyhow::anyhow!("/v1/stats json: {e}"))?;
    Ok((text, json))
}

/// Cross-check a live telemetry scrape against the loadgen ledger: the
/// serve accounting identity must hold inside the scrape, every success
/// the client saw must be in the server's counters, and `/v1/stats`
/// must agree with `/metrics` (both render the same registry).
fn verify_scrape(
    (text, stats_json): &(String, crate::util::json::Json),
    report: &crate::server::loadgen::LoadReport,
) -> Result<()> {
    use crate::obs::{key, parse_text};
    let m = parse_text(text);
    let counter = |name: &str| m.get(name).copied().unwrap_or(0.0);
    let outcome = |o: &str| counter(&key("serve_requests_total", &[("outcome", o)]));
    let received = counter("serve_received_total");
    let outcomes: f64 =
        ["served", "shed", "expired", "cancelled", "faulted"].iter().map(|o| outcome(o)).sum();
    if received != outcomes {
        bail!("/metrics does not balance: received {received} vs outcomes {outcomes}");
    }
    if (outcome("served") as usize) < report.ok {
        bail!("/metrics served {} < loadgen ok {}", outcome("served"), report.ok);
    }
    if (counter("serve_tokens_total") as usize) < report.tokens {
        bail!(
            "/metrics tokens {} < loadgen tokens {}",
            counter("serve_tokens_total"),
            report.tokens
        );
    }
    let json_received =
        stats_json.get("metrics").get("counters").get("serve_received_total").as_f64();
    if json_received != Some(received) {
        bail!("/v1/stats disagrees with /metrics: {json_received:?} vs {received}");
    }
    println!(
        "telemetry scrape: balanced ({} received, {} served, {} tokens)",
        received as u64,
        outcome("served") as u64,
        counter("serve_tokens_total") as u64
    );
    Ok(())
}

/// One-line periodic digest printed by `serve --listen --metrics`.
fn metrics_digest_line(obs: &crate::obs::Obs) -> String {
    use crate::obs::key;
    let snap = obs.registry().snapshot();
    let outcome = |o: &str| snap.counter(&key("serve_requests_total", &[("outcome", o)]));
    format!(
        "[metrics] received {} served {} shed {} expired {} cancelled {} faulted {} \
         queue {} live {} occ {:.2}",
        snap.counter("serve_received_total"),
        outcome("served"),
        outcome("shed"),
        outcome("expired"),
        outcome("cancelled"),
        outcome("faulted"),
        snap.gauge("batcher_queue_depth") as u64,
        snap.gauge("batcher_live_slots") as u64,
        snap.gauge("batcher_occupancy"),
    )
}
