//! Experiment configuration: evaluation budgets, SRA hyper-parameters,
//! worker counts. Defaults are sized for the 1-core CI image; a JSON file
//! (`--config path`) can override any field for larger machines.

use std::path::Path;

use anyhow::Result;

use crate::sra::SraConfig;
use crate::util::json::Json;

/// Tunables for the experiment runners.
#[derive(Debug, Clone)]
pub struct ExpConfig {
    /// Sentences per BLEU evaluation inside search loops (SRA oracle).
    pub calib_sentences: usize,
    /// Sentences per reported BLEU figure (final measurements).
    pub eval_sentences: usize,
    /// Worker threads for per-layer compression jobs.
    pub workers: usize,
    /// SRA hyper-parameters.
    pub sra: SraConfig,
    /// Batch size (M dim) used for NOps accounting, matching the paper's
    /// Fig. 11 evaluation at batch 512.
    pub nops_batch: usize,
    /// Output directory for CSV series.
    pub results_dir: String,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            calib_sentences: 32,
            eval_sentences: 96,
            workers: crate::util::pool::default_workers(8),
            sra: SraConfig {
                delta0: 8,
                alpha: 0.5,
                max_iters: 6,
                patience: 3,
                probe_layers: 4,
                seed: 7,
            },
            nops_batch: 512,
            results_dir: "results".to_string(),
        }
    }
}

impl ExpConfig {
    /// Fast profile for smoke tests and the quickstart example.
    pub fn fast() -> Self {
        ExpConfig {
            calib_sentences: 16,
            eval_sentences: 32,
            sra: SraConfig {
                delta0: 8,
                alpha: 0.5,
                max_iters: 3,
                patience: 2,
                probe_layers: 2,
                seed: 7,
            },
            ..Default::default()
        }
    }

    /// Load overrides from a JSON file; missing keys keep defaults.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut c = ExpConfig::default();
        if let Some(v) = j.get("calib_sentences").as_usize() {
            c.calib_sentences = v;
        }
        if let Some(v) = j.get("eval_sentences").as_usize() {
            c.eval_sentences = v;
        }
        if let Some(v) = j.get("workers").as_usize() {
            c.workers = v;
        }
        if let Some(v) = j.get("nops_batch").as_usize() {
            c.nops_batch = v;
        }
        if let Some(v) = j.get("results_dir").as_str() {
            c.results_dir = v.to_string();
        }
        let s = j.get("sra");
        if let Some(v) = s.get("delta0").as_usize() {
            c.sra.delta0 = v;
        }
        if let Some(v) = s.get("alpha").as_f64() {
            c.sra.alpha = v;
        }
        if let Some(v) = s.get("max_iters").as_usize() {
            c.sra.max_iters = v;
        }
        if let Some(v) = s.get("patience").as_usize() {
            c.sra.patience = v;
        }
        if let Some(v) = s.get("probe_layers").as_usize() {
            c.sra.probe_layers = v;
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = ExpConfig::default();
        assert!(c.calib_sentences <= c.eval_sentences);
        assert!(c.workers >= 1);
        assert_eq!(c.nops_batch, 512);
    }

    #[test]
    fn json_overrides() {
        let dir = std::env::temp_dir().join("itera_cfg_test.json");
        std::fs::write(
            &dir,
            r#"{"calib_sentences": 8, "sra": {"max_iters": 2, "alpha": 0.9}}"#,
        )
        .unwrap();
        let c = ExpConfig::load(&dir).unwrap();
        assert_eq!(c.calib_sentences, 8);
        assert_eq!(c.sra.max_iters, 2);
        assert!((c.sra.alpha - 0.9).abs() < 1e-12);
        // untouched field keeps default
        assert_eq!(c.nops_batch, 512);
        std::fs::remove_file(&dir).ok();
    }
}
