//! Per-request tracing: one [`Trace`] rides along with each request
//! from submit to its terminal outcome and attributes that outcome to
//! a serving stage with per-stage durations.
//!
//! Stage model (see EXPERIMENTS.md §Observability for the diagram):
//!
//! ```text
//! submit ──► queue ──► admit ──► decode (N steps) ──► respond
//! ```
//!
//! Every terminal outcome maps to exactly one stage:
//! - `retired`   → `respond` (a response was produced)
//! - `shed`      → `submit`  (rejected before entering the queue)
//! - `expired`   → `queue` if never admitted, else `decode`
//! - `cancelled` → `queue` if never admitted, else `decode`
//! - `faulted`   → `admit` if it never reached a slot, else `decode`

use std::time::Instant;

/// Terminal outcome of a request, mirroring the PR 6 accounting
/// identity `submitted == retired + shed + expired + cancelled + faulted`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    Retired,
    Shed,
    Expired,
    Cancelled,
    Faulted,
}

impl Outcome {
    pub fn key(self) -> &'static str {
        match self {
            Outcome::Retired => "retired",
            Outcome::Shed => "shed",
            Outcome::Expired => "expired",
            Outcome::Cancelled => "cancelled",
            Outcome::Faulted => "faulted",
        }
    }
}

/// Serving stage a request can terminate in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    Submit,
    Queue,
    Admit,
    Decode,
    Respond,
}

impl Stage {
    pub fn key(self) -> &'static str {
        match self {
            Stage::Submit => "submit",
            Stage::Queue => "queue",
            Stage::Admit => "admit",
            Stage::Decode => "decode",
            Stage::Respond => "respond",
        }
    }
}

/// Live trace for one in-flight request.
#[derive(Clone, Debug)]
pub struct Trace {
    pub id: u64,
    t_submit: Instant,
    t_admit: Option<Instant>,
    steps: usize,
}

impl Trace {
    /// Start a trace at the request's arrival instant.
    pub fn begin(id: u64, t_submit: Instant) -> Trace {
        Trace { id, t_submit, t_admit: None, steps: 0 }
    }

    /// Mark slot admission (idempotent; first call wins).
    pub fn admitted(&mut self, at: Instant) {
        if self.t_admit.is_none() {
            self.t_admit = Some(at);
        }
    }

    pub fn is_admitted(&self) -> bool {
        self.t_admit.is_some()
    }

    /// Count one decode step taken while live in a slot.
    pub fn step(&mut self) {
        self.steps += 1;
    }

    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Queue wait so far (or final, once admitted).
    pub fn queue_wait(&self, now: Instant) -> f64 {
        (self.t_admit.unwrap_or(now) - self.t_submit).as_secs_f64()
    }

    /// Close the trace with a terminal outcome. `reached_slot` is the
    /// scheduler's word on whether the request ever held a slot
    /// (`Completion::slot.is_some()`); it distinguishes admission-time
    /// faults and queued expiries from in-decode ones.
    pub fn finish(&self, outcome: Outcome, reached_slot: bool, now: Instant) -> TraceReport {
        let admitted = self.t_admit.is_some() || reached_slot;
        let stage = match outcome {
            Outcome::Retired => Stage::Respond,
            Outcome::Shed => Stage::Submit,
            Outcome::Expired | Outcome::Cancelled => {
                if admitted {
                    Stage::Decode
                } else {
                    Stage::Queue
                }
            }
            Outcome::Faulted => {
                if admitted {
                    Stage::Decode
                } else {
                    Stage::Admit
                }
            }
        };
        let queue_s = self.queue_wait(now);
        let decode_s = self.t_admit.map(|t| (now - t).as_secs_f64()).unwrap_or(0.0);
        TraceReport {
            id: self.id,
            outcome,
            stage,
            queue_s,
            decode_s,
            total_s: (now - self.t_submit).as_secs_f64(),
            steps: self.steps,
        }
    }
}

/// Closed trace: outcome, attributed stage, and per-stage durations.
#[derive(Clone, Debug)]
pub struct TraceReport {
    pub id: u64,
    pub outcome: Outcome,
    pub stage: Stage,
    pub queue_s: f64,
    pub decode_s: f64,
    pub total_s: f64,
    pub steps: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn every_outcome_maps_to_exactly_one_stage() {
        let t0 = Instant::now();
        let now = t0 + Duration::from_millis(10);
        let queued = Trace::begin(1, t0);
        let mut live = Trace::begin(2, t0);
        live.admitted(t0 + Duration::from_millis(2));
        live.step();
        live.step();

        assert_eq!(live.finish(Outcome::Retired, true, now).stage, Stage::Respond);
        assert_eq!(queued.finish(Outcome::Shed, false, now).stage, Stage::Submit);
        assert_eq!(queued.finish(Outcome::Expired, false, now).stage, Stage::Queue);
        assert_eq!(live.finish(Outcome::Expired, true, now).stage, Stage::Decode);
        assert_eq!(queued.finish(Outcome::Cancelled, false, now).stage, Stage::Queue);
        assert_eq!(live.finish(Outcome::Cancelled, true, now).stage, Stage::Decode);
        assert_eq!(queued.finish(Outcome::Faulted, false, now).stage, Stage::Admit);
        assert_eq!(live.finish(Outcome::Faulted, true, now).stage, Stage::Decode);
    }

    #[test]
    fn durations_split_between_queue_and_decode() {
        let t0 = Instant::now();
        let mut tr = Trace::begin(7, t0);
        tr.admitted(t0 + Duration::from_millis(4));
        let r = tr.finish(Outcome::Retired, true, t0 + Duration::from_millis(10));
        assert!((r.queue_s - 0.004).abs() < 1e-6);
        assert!((r.decode_s - 0.006).abs() < 1e-6);
        assert!((r.total_s - 0.010).abs() < 1e-6);
        // A never-admitted request accrues only queue time.
        let r = Trace::begin(8, t0).finish(Outcome::Expired, false, t0 + Duration::from_millis(10));
        assert!((r.queue_s - 0.010).abs() < 1e-6);
        assert_eq!(r.decode_s, 0.0);
    }
}
