//! Size and operation-count accounting for the Pareto analyses.
//!
//! Compression ratio is normalized to the FP32 model size (§VIII-C: ratio 4
//! == 8-bit quantization; the paper's region of interest is ratio > 4).
//! NOps counts multiply-accumulates of the linear layers at batch size `M`
//! (Fig. 8 reports total fixed-point operations).

use crate::quant::WordLen;

use super::CompressedLinear;

/// Cost summary of a compressed linear layer at batch size `m`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerCost {
    /// Stored weight bits.
    pub bits: u64,
    /// Multiply-accumulate count for one forward pass of batch `m`.
    pub macs: u64,
    /// FP32 bits of the original layer.
    pub fp32_bits: u64,
    /// MACs of the original dense layer at the same batch.
    pub dense_macs: u64,
}

impl LayerCost {
    pub fn ratio(&self) -> f64 {
        self.fp32_bits as f64 / self.bits.max(1) as f64
    }
}

/// Stored bits of a compressed layer. Vector-wise scales are charged to the
/// layer as one FP32 word per quantized vector (the hardware stores them in
/// the per-rank dequant tables).
pub fn param_bits(k: usize, n: usize, rank: Option<usize>, wl: WordLen) -> u64 {
    match rank {
        None => (k * n) as u64 * wl as u64 + 32 * n as u64, // per-column scales
        Some(r) => {
            let w1 = (k * r) as u64 * wl as u64;
            let w2 = (r * n) as u64 * wl as u64;
            w1 + w2 + 32 * (2 * r) as u64 // one scale per rank per side
        }
    }
}

/// Dense MatMul MAC count: `M x K x N`.
pub fn nops_dense(m: usize, k: usize, n: usize) -> u64 {
    (m as u64) * (k as u64) * (n as u64)
}

/// SVD cascade MAC count (Eq. 3): `M x K x r + M x r x N`.
pub fn nops_svd(m: usize, k: usize, n: usize, r: usize) -> u64 {
    (m as u64) * (r as u64) * (k as u64 + n as u64)
}

/// Full cost of a [`CompressedLinear`] at batch `m`, given the original
/// `[K x N]` shape.
pub fn layer_cost(c: &CompressedLinear, m: usize, k: usize, n: usize) -> LayerCost {
    let fp32_bits = (k * n) as u64 * 32;
    let dense_macs = nops_dense(m, k, n);
    match c {
        CompressedLinear::Dense { wl, .. } => LayerCost {
            bits: param_bits(k, n, None, *wl),
            macs: dense_macs,
            fp32_bits,
            dense_macs,
        },
        CompressedLinear::LowRank { w1, wl, .. } => {
            let r = w1.cols();
            LayerCost {
                bits: param_bits(k, n, Some(r), *wl),
                macs: nops_svd(m, k, n, r),
                fp32_bits,
                dense_macs,
            }
        }
    }
}

/// Model-level compression ratio from per-layer costs.
pub fn compression_ratio(costs: &[LayerCost]) -> f64 {
    let fp32: u64 = costs.iter().map(|c| c.fp32_bits).sum();
    let bits: u64 = costs.iter().map(|c| c.bits).sum();
    fp32 as f64 / bits.max(1) as f64
}

/// Rank at which the SVD cascade has the same MACs as the dense layer:
/// `r* = K*N / (K+N)`. Below this the decomposition *reduces* operations.
pub fn breakeven_rank(k: usize, n: usize) -> usize {
    (k * n) / (k + n)
}

/// Rank giving a target weight-bits compression `ratio` (vs FP32) at word
/// length `wl`: solves `32*K*N / (wl * r * (K+N)) = ratio` for r.
pub fn rank_for_ratio(k: usize, n: usize, wl: WordLen, ratio: f64) -> usize {
    let r = (32.0 * (k * n) as f64) / (wl as f64 * ratio * (k + n) as f64);
    (r.floor() as usize).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{quant_only, svd_baseline};
    use crate::tensor::Matrix;
    use crate::util::rng::Pcg64;

    #[test]
    fn quant8_is_ratio_near_4() {
        // §VIII-C: "a compression ratio of 4 corresponds to 8-bit".
        let bits = param_bits(512, 512, None, 8);
        let ratio = (512u64 * 512 * 32) as f64 / bits as f64;
        assert!((ratio - 4.0).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn nops_breakeven() {
        let k = 512;
        let n = 512;
        let r = breakeven_rank(k, n);
        assert_eq!(r, 256);
        assert!(nops_svd(1, k, n, r) <= nops_dense(1, k, n));
        assert!(nops_svd(1, k, n, r + 1) > nops_dense(1, k, n));
    }

    #[test]
    fn rank_for_ratio_roundtrip() {
        for &(k, n) in &[(512usize, 512usize), (64, 128)] {
            for wl in [4u32, 6, 8] {
                for ratio in [4.0, 6.0, 8.0, 12.0] {
                    let r = rank_for_ratio(k, n, wl, ratio);
                    let bits = param_bits(k, n, Some(r), wl);
                    let actual = (k * n * 32) as f64 / bits as f64;
                    // Achieved ratio is >= requested (floor) within scale overhead.
                    assert!(actual > ratio * 0.8, "k={k} wl={wl} ratio={ratio} got {actual}");
                }
            }
        }
    }

    #[test]
    fn layer_cost_consistency() {
        let mut rng = Pcg64::new(80);
        let w = Matrix::randn(64, 128, &mut rng);
        let q = quant_only(&w, 6);
        let c = layer_cost(&q, 16, 64, 128);
        assert_eq!(c.macs, c.dense_macs);
        assert_eq!(c.bits, param_bits(64, 128, None, 6));

        let s = svd_baseline(&w, 20, 6);
        let c2 = layer_cost(&s, 16, 64, 128);
        assert_eq!(c2.macs, nops_svd(16, 64, 128, 20));
        assert!(c2.ratio() > c.ratio());
    }

    #[test]
    fn model_ratio_aggregates() {
        let costs = vec![
            LayerCost { bits: 100, macs: 0, fp32_bits: 800, dense_macs: 0 },
            LayerCost { bits: 300, macs: 0, fp32_bits: 800, dense_macs: 0 },
        ];
        assert!((compression_ratio(&costs) - 4.0).abs() < 1e-12);
    }
}
