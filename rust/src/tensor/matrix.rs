//! Row-major dense f32 matrix.

use crate::util::rng::Pcg64;

/// Row-major dense matrix of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Gaussian random matrix (testing / synthetic workloads).
    pub fn randn(rows: usize, cols: usize, rng: &mut Pcg64) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal()).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Matrix product `self (m x k) * other (k x n)`.
    ///
    /// i-k-j loop order: the inner loop walks both `other.row(k)` and the
    /// output row contiguously, which is the main reason Algorithm 1's
    /// residual updates run at memory speed (see EXPERIMENTS.md §Perf).
    /// Shapes whose B panel outgrows the cache take a blocked path with
    /// identical (bit-exact) accumulation order.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        if m * k * n >= MM_BLOCK_MIN_MACS && k > MM_BK && n > MM_BJ {
            matmul_rows_blocked(self, other, 0, m, &mut out.data);
        } else {
            matmul_rows_simple(self, other, 0, m, &mut out.data);
        }
        out
    }

    /// Row-parallel matrix product on the shared thread pool.
    ///
    /// Splits the output rows into one contiguous chunk per worker and runs
    /// the cache-blocked kernel per chunk. Falls back to [`Self::matmul`]
    /// when a single worker (or a small shape) would not amortize the
    /// thread handoff. Bit-identical to the serial product: each output
    /// element's accumulation order is unchanged.
    pub fn matmul_par(&self, other: &Matrix, workers: usize) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let workers = workers.min(m).max(1);
        if workers == 1 || m * k * n < MM_PAR_MIN_MACS {
            return self.matmul(other);
        }
        let mut out = Matrix::zeros(m, n);
        // Each worker owns a disjoint row range of the single output
        // buffer — no per-chunk buffers, every element written once.
        super::par_row_chunks(&mut out.data, m, n, workers, |i0, i1, out_rows| {
            if k > MM_BK && n > MM_BJ {
                matmul_rows_blocked(self, other, i0, i1, out_rows);
            } else {
                matmul_rows_simple(self, other, i0, i1, out_rows);
            }
        });
        out
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// In-place `self -= other` (residual updates without reallocation).
    pub fn sub_assign(&mut self, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
    }

    /// In-place rank-1 downdate `self -= a * b^T` — the Algorithm 1 residual
    /// step fused to avoid materializing the outer product.
    pub fn sub_outer(&mut self, a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), self.rows);
        assert_eq!(b.len(), self.cols);
        for (i, &ai) in a.iter().enumerate() {
            if ai == 0.0 {
                continue;
            }
            let row = self.row_mut(i);
            for (r, &bj) in row.iter_mut().zip(b) {
                *r -= ai * bj;
            }
        }
    }

    pub fn scale(&self, s: f32) -> Matrix {
        let data = self.data.iter().map(|x| x * s).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Matrix-vector product `self (m x n) * v (n)`.
    pub fn matvec(&self, v: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.matvec_into(v, &mut out);
        out
    }

    /// `out = self * v` without allocating once `out` has capacity — the
    /// power-iteration hot loop reuses one buffer across all sweeps.
    pub fn matvec_into(&self, v: &[f32], out: &mut Vec<f32>) {
        assert_eq!(v.len(), self.cols);
        out.clear();
        out.extend((0..self.rows).map(|i| super::dot(self.row(i), v)));
    }

    /// Row-vector product `x (k) · self (k x n) -> (n)` — the
    /// single-token decode-step kernel. Every output element accumulates
    /// in ascending-`k` order with the zero-activation skip, i.e. exactly
    /// the per-element order of [`Self::matmul`]'s row loop (simple *and*
    /// blocked variants visit `k` ascending), so the result is
    /// **bit-identical** to `Matrix::from_vec(1, k, x.to_vec()).matmul(self)`
    /// — which is what lets the KV-cached decoder run one activation row
    /// at a time and still reproduce the full-buffer replay bit for bit.
    ///
    /// [`Self::tr_matvec`] computes the same product in the same order;
    /// this delegates to it (one implementation to keep bit-synchronized)
    /// and exists to state the matmul-row contract the decode path pins.
    pub fn vecmat(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.rows, "vecmat shape mismatch");
        self.tr_matvec(x)
    }

    /// Column-parallel [`Self::vecmat`] on the shared thread pool: each
    /// worker owns a disjoint contiguous output range and accumulates it
    /// in the same ascending-`k` order, so the result is bit-identical to
    /// the serial kernel for every worker count. Falls back to the serial
    /// kernel when a single worker (or a small shape) would not amortize
    /// the thread handoff — a matvec is bandwidth-bound, so the threshold
    /// sits below the matmul one.
    pub fn vecmat_par(&self, x: &[f32], workers: usize) -> Vec<f32> {
        assert_eq!(x.len(), self.rows, "vecmat shape mismatch");
        let (k, n) = (self.rows, self.cols);
        let workers = workers.min(n).max(1);
        if workers == 1 || k * n < VM_PAR_MIN_MACS {
            return self.vecmat(x);
        }
        let mut out = vec![0.0f32; n];
        // Column chunks are disjoint ranges of the single output vector
        // (an [n x 1] view for the row-chunk scaffolding).
        super::par_row_chunks(&mut out, n, 1, workers, |j0, j1, cols| {
            vecmat_cols(self, x, j0, j1, cols)
        });
        out
    }

    /// `self^T * v` without materializing the transpose.
    pub fn tr_matvec(&self, v: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.tr_matvec_into(v, &mut out);
        out
    }

    /// `out = self^T * v`, allocation-free on reuse (see [`Self::matvec_into`]).
    pub fn tr_matvec_into(&self, v: &[f32], out: &mut Vec<f32>) {
        assert_eq!(v.len(), self.rows);
        out.clear();
        out.resize(self.cols, 0.0);
        for (i, &vi) in v.iter().enumerate() {
            if vi == 0.0 {
                continue;
            }
            super::axpy(vi, self.row(i), out);
        }
    }

    /// Bilinear form `u^T * self * v` in a single pass over the matrix —
    /// the fused version of the `matvec` + `dot` pair in Algorithm 1's
    /// alpha-rescale step: no m-length temporary, and the matrix is read
    /// exactly once. Zero entries of `u` skip whole rows, mirroring
    /// [`Self::sub_outer`]'s sparsity shortcut on quantized factors.
    ///
    /// Note: the outer reduction uses a 4-lane accumulator, which
    /// reassociates the f32 sum relative to the two-pass form — results
    /// agree to rounding (last-ulp) but are not bit-identical to it. The
    /// function itself is deterministic, which is what the compression
    /// reproducibility and truncation-invariant tests rely on.
    pub fn bilinear(&self, u: &[f32], v: &[f32]) -> f32 {
        assert_eq!(u.len(), self.rows);
        assert_eq!(v.len(), self.cols);
        let mut acc = [0.0f32; 4];
        for (i, &ui) in u.iter().enumerate() {
            if ui == 0.0 {
                continue;
            }
            acc[i & 3] += ui * super::dot(self.row(i), v);
        }
        (acc[0] + acc[1]) + (acc[2] + acc[3])
    }

    /// Horizontal concatenation (Algorithm 1's `hstack`).
    pub fn hstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows);
        let mut out = Matrix::zeros(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        out
    }

    /// Vertical concatenation (Algorithm 1's `vstack`).
    pub fn vstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix { rows: self.rows + other.rows, cols: self.cols, data }
    }

    /// Zero-pad to `(rows, cols)` (rank-padding for the SVD artifact).
    pub fn pad_to(&self, rows: usize, cols: usize) -> Matrix {
        assert!(rows >= self.rows && cols >= self.cols);
        let mut out = Matrix::zeros(rows, cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
        }
        out
    }

    /// Take the leading `cols` columns (per-row memcpy — this sits on the
    /// incremental-cache query path).
    pub fn take_cols(&self, cols: usize) -> Matrix {
        assert!(cols <= self.cols);
        let mut out = Matrix::zeros(self.rows, cols);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[..cols]);
        }
        out
    }

    /// Take the leading `rows` rows.
    pub fn take_rows(&self, rows: usize) -> Matrix {
        assert!(rows <= self.rows);
        Matrix::from_vec(rows, self.cols, self.data[..rows * self.cols].to_vec())
    }
}

/// Cache-block edges for the large-shape matmul path: one `MM_BK x MM_BJ`
/// panel of B (32 KiB of f32) stays cache-resident while every A row of
/// the row range streams over it.
const MM_BK: usize = 64;
const MM_BJ: usize = 128;
/// Below this many MACs the plain i-k-j loop wins: B still fits in L2
/// (256x256 f32 = 256 KiB) and blocking is pure bookkeeping. 512^3 and up
/// (B >= 1 MiB) take the blocked path.
const MM_BLOCK_MIN_MACS: usize = 1 << 25;
/// Threads pay off earlier than blocking does: per-row work is O(k*n) and
/// the scoped-pool handoff is microseconds.
const MM_PAR_MIN_MACS: usize = 1 << 22;
/// A single-row matvec streams the whole weight matrix once (bandwidth-
/// bound, no panel reuse), so threads start paying off at smaller shapes
/// than the matmul threshold.
const VM_PAR_MIN_MACS: usize = 1 << 20;

/// i-k-j product of rows `i0..i1` of `a` with `b`, written to `out`
/// (`(i1-i0) x n`, row-major). Zero A entries skip whole B rows — the
/// zero-padded SVD factors rely on this.
fn matmul_rows_simple(a: &Matrix, b: &Matrix, i0: usize, i1: usize, out: &mut [f32]) {
    let n = b.cols;
    for i in i0..i1 {
        let a_row = a.row(i);
        let o_row = &mut out[(i - i0) * n..(i - i0 + 1) * n];
        for (kk, &av) in a_row.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let b_row = &b.data[kk * n..(kk + 1) * n];
            for (o, &bv) in o_row.iter_mut().zip(b_row) {
                *o += av * bv;
            }
        }
    }
}

/// Output columns `j0..j1` of the row-vector product `x · w`, written to
/// `out` (`j1 - j0` elements): ascending-`k` accumulation with the
/// zero-activation skip — per output element, exactly
/// [`matmul_rows_simple`]'s order restricted to one activation row, so
/// `vecmat` results are bit-equal to the corresponding matmul row.
fn vecmat_cols(w: &Matrix, x: &[f32], j0: usize, j1: usize, out: &mut [f32]) {
    let n = w.cols;
    for (kk, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let w_row = &w.data[kk * n + j0..kk * n + j1];
        for (o, &wv) in out.iter_mut().zip(w_row) {
            *o += xv * wv;
        }
    }
}

/// Cache-blocked variant of [`matmul_rows_simple`]: j and k are tiled so
/// the touched B panel fits in cache across the whole row range. The k
/// blocks are visited in ascending order, so every output element
/// accumulates in exactly the same order as the simple loop (bit-equal
/// results).
fn matmul_rows_blocked(a: &Matrix, b: &Matrix, i0: usize, i1: usize, out: &mut [f32]) {
    let (k, n) = (a.cols, b.cols);
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + MM_BJ).min(n);
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + MM_BK).min(k);
            for i in i0..i1 {
                let a_row = a.row(i);
                let o_row = &mut out[(i - i0) * n + j0..(i - i0) * n + j1];
                for kk in k0..k1 {
                    let av = a_row[kk];
                    if av == 0.0 {
                        continue;
                    }
                    let b_row = &b.data[kk * n + j0..kk * n + j1];
                    for (o, &bv) in o_row.iter_mut().zip(b_row) {
                        *o += av * bv;
                    }
                }
            }
            k0 = k1;
        }
        j0 = j1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, v: &[f32]) -> Matrix {
        Matrix::from_vec(rows, cols, v.to_vec())
    }

    #[test]
    fn matmul_hand() {
        let a = mat(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = mat(3, 2, &[7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::new(1);
        let a = Matrix::randn(5, 5, &mut rng);
        let i = Matrix::eye(5);
        let prod = a.matmul(&i);
        for (x, y) in prod.data().iter().zip(a.data()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::new(2);
        let a = Matrix::randn(4, 7, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn sub_outer_matches_explicit() {
        let mut rng = Pcg64::new(3);
        let mut a = Matrix::randn(6, 5, &mut rng);
        let b = a.clone();
        let u: Vec<f32> = (0..6).map(|i| i as f32 * 0.3).collect();
        let v: Vec<f32> = (0..5).map(|i| 1.0 - i as f32 * 0.1).collect();
        a.sub_outer(&u, &v);
        let explicit = b.sub(&crate::tensor::outer(&u, &v));
        for (x, y) in a.data().iter().zip(explicit.data()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn frob_norm_hand() {
        let a = mat(2, 2, &[3., 0., 0., 4.]);
        assert!((a.frob_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn stack_and_pad() {
        let a = mat(2, 2, &[1., 2., 3., 4.]);
        let b = mat(2, 1, &[9., 9.]);
        let h = a.hstack(&b);
        assert_eq!(h.shape(), (2, 3));
        assert_eq!(h.row(0), &[1., 2., 9.]);
        let c = mat(1, 2, &[7., 8.]);
        let v = a.vstack(&c);
        assert_eq!(v.shape(), (3, 2));
        assert_eq!(v.row(2), &[7., 8.]);
        let p = a.pad_to(3, 4);
        assert_eq!(p.shape(), (3, 4));
        assert_eq!(p.get(0, 1), 2.0);
        assert_eq!(p.get(2, 3), 0.0);
        assert_eq!(p.take_cols(2).take_rows(2), a);
    }

    #[test]
    fn matvec_and_transpose_matvec() {
        let a = mat(2, 3, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.matvec(&[1., 0., 1.]), vec![4., 10.]);
        assert_eq!(a.tr_matvec(&[1., 1.]), vec![5., 7., 9.]);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }

    #[test]
    fn blocked_matmul_matches_simple_bitwise() {
        // Shapes straddling the block edges, including non-multiples.
        let mut rng = Pcg64::new(21);
        for &(m, k, n) in &[(3usize, 200usize, 150usize), (17, 130, 257), (40, 64, 129)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let mut simple = vec![0.0f32; m * n];
            matmul_rows_simple(&a, &b, 0, m, &mut simple);
            let mut blocked = vec![0.0f32; m * n];
            matmul_rows_blocked(&a, &b, 0, m, &mut blocked);
            assert_eq!(simple, blocked, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_par_matches_serial() {
        let mut rng = Pcg64::new(22);
        let a = Matrix::randn(170, 180, &mut rng);
        let b = Matrix::randn(180, 190, &mut rng);
        let serial = a.matmul(&b);
        for workers in [1usize, 2, 3, 7] {
            let par = a.matmul_par(&b, workers);
            assert_eq!(serial.data(), par.data(), "workers={workers}");
        }
    }

    #[test]
    fn vecmat_bit_equal_to_one_row_matmul() {
        // Shapes straddling the blocked-path edges, plus a zero activation
        // to exercise the skip predicate the bit-parity contract includes.
        let mut rng = Pcg64::new(24);
        for &(k, n) in &[(3usize, 5usize), (64, 129), (200, 150), (130, 257)] {
            let w = Matrix::randn(k, n, &mut rng);
            let mut x: Vec<f32> = (0..k).map(|i| ((i * 7) as f32 * 0.13).sin()).collect();
            x[k / 2] = 0.0;
            let want = Matrix::from_vec(1, k, x.clone()).matmul(&w);
            let got = w.vecmat(&x);
            assert_eq!(got.len(), n);
            for (a, b) in got.iter().zip(want.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{k}x{n}");
            }
            // tr_matvec computes the same product; same accumulation order.
            assert_eq!(got, w.tr_matvec(&x), "{k}x{n} vs tr_matvec");
        }
    }

    #[test]
    fn vecmat_par_matches_serial_bitwise() {
        // 1100x1100 crosses VM_PAR_MIN_MACS, so workers > 1 take the
        // column-chunked path; smaller shapes exercise the fallback.
        let mut rng = Pcg64::new(25);
        for &(k, n) in &[(1100usize, 1100usize), (40, 30)] {
            let w = Matrix::randn(k, n, &mut rng);
            let x: Vec<f32> = (0..k).map(|i| ((i * 11) as f32 * 0.07).cos()).collect();
            let serial = w.vecmat(&x);
            for workers in [1usize, 2, 3, 7] {
                assert_eq!(serial, w.vecmat_par(&x, workers), "{k}x{n} workers={workers}");
            }
        }
    }

    #[test]
    fn matvec_into_reuses_buffer() {
        let a = mat(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let mut buf = Vec::new();
        a.matvec_into(&[1., 0., 1.], &mut buf);
        assert_eq!(buf, vec![4., 10.]);
        a.tr_matvec_into(&[1., 1.], &mut buf);
        assert_eq!(buf, vec![5., 7., 9.]);
    }

    #[test]
    fn bilinear_matches_matvec_dot() {
        let mut rng = Pcg64::new(23);
        let a = Matrix::randn(9, 7, &mut rng);
        let mut u: Vec<f32> = (0..9).map(|i| (i as f32 * 0.37).sin()).collect();
        u[4] = 0.0; // exercise the zero-row skip
        let v: Vec<f32> = (0..7).map(|i| (i as f32 * 0.11).cos()).collect();
        let via_matvec = crate::tensor::dot(&u, &a.matvec(&v));
        let fused = a.bilinear(&u, &v);
        assert!((via_matvec - fused).abs() < 1e-4, "{via_matvec} vs {fused}");
    }
}
