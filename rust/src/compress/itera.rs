//! Algorithm 1 — SVD-based Iterative Tensor Decomposition.
//!
//! The refinement loop of Fig. 3: at step `k` take the rank-1 SVD of the
//! current residual, quantize the two singular vectors (each with its own
//! scale — the paper's vector-wise scheme), subtract the *quantized* rank-1
//! product from the residual, and append the factors. Because the residual
//! carries the quantization error of every previous step forward, later
//! iterations compensate it; outliers dominate the residual norm and get
//! approximated first.

use crate::linalg::{svd_top1_ws, PowerWorkspace};
use crate::quant::{self, WordLen};
use crate::tensor::Matrix;

use super::CompressedLinear;

/// Per-iteration trace of Algorithm 1 (residual norms for EXPERIMENTS.md
/// and the convergence property tests).
#[derive(Debug, Clone, Default)]
pub struct IteraTrace {
    /// `||R_k||_F` after each iteration, starting with `||W||_F` at k=0.
    pub residual_norms: Vec<f32>,
    /// Matvec-equivalent work this run performed: one unit per O(K*N)
    /// pass over the residual (power sweeps, the fused alpha bilinear,
    /// the rank-1 downdate). The incremental-compression cache and the
    /// SRA cost regression tests use this as a deterministic, wall-clock
    /// independent cost metric.
    pub matvec_equivalents: u64,
}

/// Run Algorithm 1 on `w` with target rank `r` and weight word length `wl`.
///
/// Returns the quantized factor pair `W'1 [K x r]`, `W'2 [r x N]` plus the
/// residual trace. The factors absorb `sigma` as `sqrt(sigma)` on each side
/// (Eq. 2) before quantization, so both live on comparable scales.
pub fn itera(w: &Matrix, r: usize, wl: WordLen) -> (CompressedLinear, IteraTrace) {
    itera_opts(w, r, wl, &IteraOpts::default())
}

/// Ablation switches for Algorithm 1 (`itera` uses the defaults; the
/// `ablation_itera` bench and DESIGN.md §Perf study the others).
#[derive(Debug, Clone, Copy)]
pub struct IteraOpts {
    /// Rescale each quantized rank-1 step by its least-squares alpha
    /// (our refinement on top of the paper's greedy step).
    pub alpha_rescale: bool,
    /// Subtract the *quantized* rank-1 product from the residual (the
    /// paper's error-compensation mechanism). With `false` the residual
    /// uses the unquantized product — degenerating to SVD-then-quantize
    /// computed incrementally, which isolates how much of the win comes
    /// from quantization-in-the-loop.
    pub quant_in_loop: bool,
}

impl Default for IteraOpts {
    fn default() -> Self {
        IteraOpts { alpha_rescale: true, quant_in_loop: true }
    }
}

/// Algorithm 1 with explicit ablation switches.
pub fn itera_opts(
    w: &Matrix,
    r: usize,
    wl: WordLen,
    opts: &IteraOpts,
) -> (CompressedLinear, IteraTrace) {
    let (k_dim, n_dim) = w.shape();
    let r = r.clamp(1, k_dim.min(n_dim));
    let mut residual = w.clone();
    let mut trace = IteraTrace {
        residual_norms: vec![residual.frob_norm()],
        ..Default::default()
    };

    let mut w1 = Matrix::zeros(k_dim, r);
    let mut w2 = Matrix::zeros(r, n_dim);
    // Per-rank dequant scales (0.0 for exhausted-residual ranks, whose
    // factor vectors stay zero).
    let mut s1 = vec![0.0f32; r];
    let mut s2 = vec![0.0f32; r];
    // One workspace for all r truncated SVDs: the power sweeps — the
    // dominant cost of the whole engine — run allocation-free.
    let mut ws = PowerWorkspace::new();

    for k in 0..r {
        let top = svd_top1_ws(&residual, k as u64, &mut ws);
        if top.sigma <= 0.0 {
            // Residual exhausted (exactly representable) — remaining ranks
            // stay zero, which the zero-padded runtime path treats as free.
            trace.residual_norms.push(0.0);
            continue;
        }
        let s_sqrt = top.sigma.sqrt();
        // Eq. 2 split: u * sqrt(sigma) and sqrt(sigma) * v^T ...
        let u_col: Vec<f32> = top.u.iter().map(|x| x * s_sqrt).collect();
        let v_row: Vec<f32> = top.v.iter().map(|x| x * s_sqrt).collect();
        // ... then Quant(): each singular vector quantized with its own
        // scale (vector-wise), exactly the granularity the hardware stores.
        // Grid points and scale are kept apart so every emitted factor
        // value is exactly `grid_int * scale` — the invariant qkernel's
        // packed integer storage re-grids without losing a bit.
        let (qu_int, su) = quant::quantize_vec_parts(&u_col, wl);
        let qu: Vec<f32> = qu_int.iter().map(|&q| quant::dequantize_val(q, su)).collect();
        let (qv_int, sv0) = quant::quantize_vec_parts(&v_row, wl);
        let mut sv = sv0;

        // Optimal step size: rescale the quantized rank-1 direction by the
        // least-squares alpha = <R, qu qv^T> / |qu qv^T|_F^2. The per-rank
        // dequant scale absorbs alpha (`sv = sv0 * alpha`), so qv stays
        // exactly representable on its wl-bit grid — free accuracy the
        // greedy step would leave on the table once quantization bends the
        // direction.
        if opts.alpha_rescale {
            let qv0: Vec<f32> = qv_int.iter().map(|&q| quant::dequantize_val(q, sv0)).collect();
            let nu = crate::tensor::dot(&qu, &qu) as f64;
            let nv = crate::tensor::dot(&qv0, &qv0) as f64;
            let denom = nu * nv;
            if denom > 0.0 {
                // num = qu^T R qv, fused into one pass over the residual
                // (no K-length temporary, R read once instead of twice).
                let num = residual.bilinear(&qu, &qv0) as f64;
                trace.matvec_equivalents += 1;
                let alpha = (num / denom) as f32;
                if alpha.is_finite() && alpha > 0.0 {
                    sv = sv0 * alpha;
                }
            }
        }
        let qv: Vec<f32> = qv_int.iter().map(|&q| quant::dequantize_val(q, sv)).collect();

        // Residual update with the *quantized* rank-1 product, so the next
        // iteration sees (and can compensate) this step's quant error.
        if opts.quant_in_loop {
            residual.sub_outer(&qu, &qv);
        } else {
            // Ablation: subtract the exact rank-1 step instead; the stored
            // factors stay quantized but their error is never compensated.
            residual.sub_outer(&u_col, &v_row);
        }
        trace.matvec_equivalents += 1;
        trace.residual_norms.push(residual.frob_norm());

        for i in 0..k_dim {
            w1.set(i, k, qu[i]);
        }
        w2.row_mut(k).copy_from_slice(&qv);
        s1[k] = su;
        s2[k] = sv;
    }
    trace.matvec_equivalents += ws.matvecs;

    (CompressedLinear::LowRank { w1, w2, wl, s1, s2 }, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::svd_baseline;
    use crate::util::rng::Pcg64;

    fn weights(seed: u64, k: usize, n: usize) -> Matrix {
        let mut rng = Pcg64::new(seed);
        Matrix::randn(k, n, &mut rng).scale(0.1)
    }

    #[test]
    fn residual_norm_monotone_nonincreasing() {
        let w = weights(50, 20, 24);
        let (_, trace) = itera(&w, 12, 4);
        for pair in trace.residual_norms.windows(2) {
            assert!(
                pair[1] <= pair[0] + 1e-4,
                "residual must not grow: {:?}",
                trace.residual_norms
            );
        }
    }

    #[test]
    fn final_residual_matches_reported_error() {
        let w = weights(51, 16, 16);
        let (c, trace) = itera(&w, 8, 6);
        let err = c.error(&w);
        let last = *trace.residual_norms.last().unwrap();
        assert!((err - last).abs() < 1e-3 * err.max(1.0), "{err} vs {last}");
    }

    #[test]
    fn beats_svd_baseline_at_low_bits() {
        // The paper's core claim (Fig. 7): with quantization in the loop,
        // iterative decomposition compensates quant error that the plain
        // SVD-then-quantize baseline cannot.
        let mut wins = 0;
        for seed in 0..6 {
            let w = weights(60 + seed, 32, 32);
            let r = 16;
            let e_iter = itera(&w, r, 4).0.error(&w);
            let e_base = svd_baseline(&w, r, 4).error(&w);
            if e_iter < e_base {
                wins += 1;
            }
        }
        assert!(wins >= 5, "iterative should win at W4 nearly always: {wins}/6");
    }

    #[test]
    fn outlier_column_absorbed_early() {
        // Outliers dominate the residual; the first iterations must chase
        // them (the mechanism the paper credits for the accuracy gain).
        let mut w = weights(70, 16, 16);
        for i in 0..16 {
            w.set(i, 3, w.get(i, 3) * 50.0);
        }
        let (_, trace) = itera(&w, 4, 8);
        // After one iteration the residual should have dropped by far more
        // than a generic rank-1 step on the non-outlier matrix would give.
        let drop = trace.residual_norms[0] / trace.residual_norms[1].max(1e-6);
        assert!(drop > 5.0, "outlier should dominate step 1: drop {drop}");
    }

    #[test]
    fn rank_grows_error_shrinks() {
        let w = weights(52, 24, 24);
        let mut prev = f32::INFINITY;
        for r in [2, 6, 12, 24] {
            let e = itera(&w, r, 6).0.error(&w);
            assert!(e <= prev + 1e-5, "rank {r}: {e} > {prev}");
            prev = e;
        }
    }

    #[test]
    fn factors_are_on_quant_grid() {
        // Every column of W1 / row of W2 must be exactly representable on
        // its own wl-bit grid (idempotent re-quantization).
        let w = weights(53, 12, 10);
        let (c, _) = itera(&w, 5, 4);
        if let CompressedLinear::LowRank { w1, w2, .. } = &c {
            for j in 0..w1.cols() {
                let col = w1.col(j);
                let (qcol, _) = quant::quantize_vec(&col, 4);
                for (a, b) in col.iter().zip(&qcol) {
                    assert!((a - b).abs() < 1e-5);
                }
            }
            for i in 0..w2.rows() {
                let row = w2.row(i).to_vec();
                let (qrow, _) = quant::quantize_vec(&row, 4);
                for (a, b) in row.iter().zip(&qrow) {
                    assert!((a - b).abs() < 1e-5);
                }
            }
        } else {
            panic!("itera must return LowRank");
        }
    }

    #[test]
    fn quant_in_loop_ablation_hurts_at_low_bits() {
        // Removing error compensation must cost accuracy at W3 — the
        // paper's core mechanism, isolated.
        let mut worse = 0;
        for seed in 0..5 {
            let w = weights(90 + seed, 24, 24);
            let on = itera(&w, 12, 3).0.error(&w);
            let off = itera_opts(
                &w,
                12,
                3,
                &IteraOpts { quant_in_loop: false, ..Default::default() },
            )
            .0
            .error(&w);
            if on < off {
                worse += 1;
            }
        }
        assert!(worse >= 4, "compensation should win at W3: {worse}/5");
    }

    #[test]
    fn alpha_rescale_helps_on_average() {
        // Per-step optimal scaling is greedy, so an individual case may
        // tie or lose a hair — but across cases it must win on average
        // and never lose more than 2%.
        let mut sum_on = 0.0f64;
        let mut sum_off = 0.0f64;
        for seed in 0..8 {
            let w = weights(95 + seed, 20, 20);
            let on = itera(&w, 10, 4).0.error(&w) as f64;
            let off = itera_opts(
                &w,
                10,
                4,
                &IteraOpts { alpha_rescale: false, ..Default::default() },
            )
            .0
            .error(&w) as f64;
            assert!(on <= off * 1.02, "alpha {on} vs plain {off}");
            sum_on += on;
            sum_off += off;
        }
        assert!(sum_on < sum_off, "alpha must win on average: {sum_on} vs {sum_off}");
    }

    #[test]
    fn deterministic() {
        let w = weights(54, 14, 14);
        let (a, _) = itera(&w, 7, 5);
        let (b, _) = itera(&w, 7, 5);
        assert_eq!(a.effective().data(), b.effective().data());
    }

    #[test]
    fn trace_counts_matvec_work() {
        let w = weights(55, 16, 16);
        let (_, t4) = itera(&w, 4, 4);
        let (_, t8) = itera(&w, 8, 4);
        assert!(t4.matvec_equivalents > 0, "work must be tallied");
        assert!(
            t8.matvec_equivalents > t4.matvec_equivalents,
            "more ranks, more work: {} vs {}",
            t8.matvec_equivalents,
            t4.matvec_equivalents
        );
    }
}
