//! Fixed-point post-training quantization (the "WxAy" schemes of §VIII-B).
//!
//! Symmetric uniform quantization onto a `2^(wl-1)-1`-level grid. Two
//! granularities:
//!
//! * **per-tensor** — one scale for the whole matrix (used for activations,
//!   whose scale is calibrated offline and applied in-graph by the L1
//!   `fake_quant` kernel);
//! * **per-vector** — one scale per row/column (the paper applies
//!   quantization *vector-wise in the produced matrix* so each quantized
//!   rank-1 singular vector carries its own scale; §VIII-B).
//!
//! All quantization here is *fake-quant*: values are snapped onto the fixed
//! point grid but kept in f32, which is numerically identical to integer
//! storage + dequantization and is what both the PJRT eval path and the
//! compression-error analysis consume. Storage accounting (bits) is handled
//! by `compress::ratio`.

use crate::tensor::Matrix;

/// A word length (the `X`/`Y` in `WXAY`).
///
/// Contract: the fake-quant engine accepts `2..=16` bits — the paper's
/// weight/activation schemes use `2..=8`, and the extra headroom up to 16
/// exists only for FP-identity diagnostics (the Fig. 4 probes quantize at
/// W16 to isolate decomposition error from quantization error). The
/// bit-packed [`crate::qkernel`] storage is restricted to the `2..=8`
/// range the paper (and the hardware) actually uses; feeding it a wider
/// grid is a construction error there, not here.
pub type WordLen = u32;

/// Number of positive levels for a symmetric `wl`-bit grid: `2^(wl-1) - 1`.
///
/// Accepts the full `2..=16` [`WordLen`] contract (see its docs); panics
/// outside it — `levels_boundary_contract` pins both edges.
pub fn levels(wl: WordLen) -> f32 {
    assert!((2..=16).contains(&wl), "word length out of range: {wl}");
    ((1u32 << (wl - 1)) - 1) as f32
}

/// Quantize a scalar onto the grid with scale `s`.
#[inline]
pub fn quantize_val(x: f32, s: f32, lv: f32) -> f32 {
    if s <= 0.0 {
        return 0.0;
    }
    dequantize_val(quantize_int(x, s, lv), s)
}

/// Integer grid point of `x` on the `lv`-level grid with scale `s`:
/// `clamp(round(x/s), -lv, lv)` (0 when `s <= 0`, so a 0-scale vector
/// quantizes to all zeros). [`quantize_val`] is exactly
/// `dequantize_val(quantize_int(..), s)` — grid points are integers
/// `|q| <= 32767`, exactly representable in f32, so the int round-trip
/// loses nothing.
#[inline]
pub fn quantize_int(x: f32, s: f32, lv: f32) -> i32 {
    if s <= 0.0 {
        return 0;
    }
    // A NaN input would otherwise ride `round`/`clamp` through to the
    // saturating `as i32` cast (-> 0); make the fallback explicit.
    if x.is_nan() {
        debug_assert!(false, "NaN fed to quantize_int");
        return 0;
    }
    (x / s).round().clamp(-lv, lv) as i32
}

/// Dequantize grid point `q` at scale `s` — bit-identical to the
/// fake-quant f32 value [`quantize_val`] produced for any `x` rounding to
/// `q`. This equivalence is the contract [`crate::qkernel`]'s packed
/// integer storage rests on.
#[inline]
pub fn dequantize_val(q: i32, s: f32) -> f32 {
    q as f32 * s
}

/// Symmetric scale covering `max_abs` with `lv` levels.
///
/// Hardened against non-finite inputs: a NaN/inf `max_abs` (upstream
/// weight corruption) yields scale 0 — quantizing everything to zero
/// instead of silently poisoning every value in the vector — and trips a
/// `debug_assert` so debug builds surface the corruption at its source.
#[inline]
pub fn scale_for(max_abs: f32, lv: f32) -> f32 {
    if !max_abs.is_finite() {
        debug_assert!(false, "non-finite max_abs {max_abs} fed to scale_for");
        return 0.0;
    }
    if max_abs <= 0.0 {
        0.0
    } else {
        max_abs / lv
    }
}

/// NaN-sticky max-abs accumulator for the per-vector scale folds.
///
/// `f32::max(m, NaN)` returns `m`, so the naive fold silently drops a
/// NaN lane and produces a clean-looking scale for a poisoned vector —
/// the NaN then quantizes to 0 (release builds) among otherwise-sane
/// values. This fold propagates the NaN into the accumulated max so the
/// scale goes through [`scale_for`]'s explicit non-finite hardening
/// (debug assert in debug builds, zero scale in release) instead.
#[inline]
fn max_abs_fold(m: f32, x: f32) -> f32 {
    let a = x.abs();
    if a > m || a.is_nan() {
        a
    } else {
        m
    }
}

/// A non-finite lane caught during vector quantization: the offending
/// index and value, for error messages that point at the poisoned
/// activation instead of a generic "bad scale".
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NonFiniteError {
    /// Index of the first non-finite lane.
    pub index: usize,
    /// The offending value (NaN or ±inf).
    pub value: f32,
}

impl std::fmt::Display for NonFiniteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "non-finite activation {} at lane {}", self.value, self.index)
    }
}

impl std::error::Error for NonFiniteError {}

/// Per-tensor fake-quant; returns the quantized matrix and the scale used.
pub fn quantize_tensor(a: &Matrix, wl: WordLen) -> (Matrix, f32) {
    let lv = levels(wl);
    let s = scale_for(a.max_abs(), lv);
    let q = Matrix::from_vec(
        a.rows(),
        a.cols(),
        a.data().iter().map(|&x| quantize_val(x, s, lv)).collect(),
    );
    (q, s)
}

/// Per-row fake-quant (each row gets its own scale). For `W2 = [r x N]`
/// factors this quantizes each rank's right singular vector independently.
pub fn quantize_rows(a: &Matrix, wl: WordLen) -> (Matrix, Vec<f32>) {
    let lv = levels(wl);
    let mut out = Matrix::zeros(a.rows(), a.cols());
    let mut scales = Vec::with_capacity(a.rows());
    for i in 0..a.rows() {
        let row = a.row(i);
        let s = scale_for(row.iter().fold(0.0f32, |m, &x| max_abs_fold(m, x)), lv);
        scales.push(s);
        let orow = out.row_mut(i);
        for (o, &x) in orow.iter_mut().zip(row) {
            *o = quantize_val(x, s, lv);
        }
    }
    (out, scales)
}

/// Per-column fake-quant (each column gets its own scale). For
/// `W1 = [K x r]` factors this quantizes each rank's left singular vector
/// independently — together with `quantize_rows` this is the paper's
/// "vector-wise" scheme.
pub fn quantize_cols(a: &Matrix, wl: WordLen) -> (Matrix, Vec<f32>) {
    let lv = levels(wl);
    let mut out = Matrix::zeros(a.rows(), a.cols());
    // Per-column max-abs in ONE row-major pass: the matrix is stored
    // row-major, so scanning it column-by-column strides by `cols` floats
    // per access and misses cache on every load for wide matrices.
    // Accumulating all column maxes while streaming rows touches each
    // cache line exactly once (max is order-independent, so the scales
    // are bit-identical to the column-order scan).
    let mut scales = vec![0.0f32; a.cols()];
    for i in 0..a.rows() {
        for (mx, &x) in scales.iter_mut().zip(a.row(i)) {
            *mx = max_abs_fold(*mx, x);
        }
    }
    for s in scales.iter_mut() {
        *s = scale_for(*s, lv);
    }
    for i in 0..a.rows() {
        let row = out.row_mut(i);
        for ((o, &x), &s) in row.iter_mut().zip(a.row(i)).zip(&scales) {
            *o = quantize_val(x, s, lv);
        }
    }
    (out, scales)
}

/// Quantize a vector with its own scale (rank-1 factor path of Algorithm 1).
pub fn quantize_vec(v: &[f32], wl: WordLen) -> (Vec<f32>, f32) {
    let (q, s) = quantize_vec_parts(v, wl);
    (q.iter().map(|&qi| dequantize_val(qi, s)).collect(), s)
}

/// Integer-grid quantization of a vector with its own scale: the grid
/// points plus the scale that dequantizes them. [`quantize_vec`] is the
/// `dequantize_val` image of this — callers that need the integers
/// themselves (packed storage, integer kernels, the scale-absorbing
/// alpha-rescale in Algorithm 1) use this form.
pub fn quantize_vec_parts(v: &[f32], wl: WordLen) -> (Vec<i32>, f32) {
    let lv = levels(wl);
    let s = scale_for(v.iter().fold(0.0f32, |m, &x| max_abs_fold(m, x)), lv);
    (v.iter().map(|&x| quantize_int(x, s, lv)).collect(), s)
}

/// Fallible [`quantize_vec_parts`] for *runtime* activations: scans for
/// non-finite lanes first and reports the offender as a typed error
/// instead of riding the max-abs fold into a zero scale (release) or a
/// `debug_assert` (debug). The fast integer decode tier quantizes every
/// step activation through this, so one poisoned lane becomes a loud,
/// attributable error on exactly that request's step — never a silent
/// all-zeros row.
pub fn try_quantize_vec_parts(
    v: &[f32],
    wl: WordLen,
) -> Result<(Vec<i32>, f32), NonFiniteError> {
    if let Some((index, &value)) = v.iter().enumerate().find(|(_, x)| !x.is_finite()) {
        return Err(NonFiniteError { index, value });
    }
    Ok(quantize_vec_parts(v, wl))
}

/// Mean-squared quantization error.
pub fn mse(a: &Matrix, q: &Matrix) -> f64 {
    assert_eq!(a.shape(), q.shape());
    let n = a.data().len().max(1);
    a.data()
        .iter()
        .zip(q.data())
        .map(|(x, y)| ((x - y) as f64) * ((x - y) as f64))
        .sum::<f64>()
        / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn levels_table() {
        assert_eq!(levels(8), 127.0);
        assert_eq!(levels(4), 7.0);
        assert_eq!(levels(2), 1.0);
    }

    #[test]
    fn grid_snapping_is_idempotent() {
        let mut rng = Pcg64::new(40);
        let a = Matrix::randn(6, 6, &mut rng);
        let (q, _) = quantize_tensor(&a, 5);
        let (q2, _) = quantize_tensor(&q, 5);
        for (x, y) in q.data().iter().zip(q2.data()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn error_bounded_by_half_step() {
        let mut rng = Pcg64::new(41);
        let a = Matrix::randn(10, 10, &mut rng);
        for wl in [4u32, 6, 8] {
            let (q, s) = quantize_tensor(&a, wl);
            for (x, y) in a.data().iter().zip(q.data()) {
                assert!(
                    (x - y).abs() <= 0.5 * s + 1e-6,
                    "wl={wl}: |{x}-{y}| > s/2={s}"
                );
            }
        }
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Pcg64::new(42);
        let a = Matrix::randn(16, 16, &mut rng);
        let errs: Vec<f64> = [3u32, 4, 6, 8]
            .iter()
            .map(|&wl| mse(&a, &quantize_tensor(&a, wl).0))
            .collect();
        for w in errs.windows(2) {
            assert!(w[1] < w[0], "mse should shrink with bits: {errs:?}");
        }
    }

    #[test]
    fn per_vector_beats_per_tensor_with_outliers() {
        // A single giant outlier entry wrecks the per-tensor scale for the
        // whole matrix; vector-wise scales contain the damage to one column
        // — the effect the paper leans on.
        let mut rng = Pcg64::new(43);
        let mut a = Matrix::randn(12, 12, &mut rng);
        a.set(0, 0, a.get(0, 0).abs().max(1.0) * 100.0);
        let (qt, _) = quantize_tensor(&a, 4);
        let (qc, _) = quantize_cols(&a, 4);
        assert!(mse(&a, &qc) < mse(&a, &qt) * 0.2, "{} vs {}", mse(&a, &qc), mse(&a, &qt));
    }

    #[test]
    fn per_row_and_col_transpose_duality() {
        let mut rng = Pcg64::new(44);
        let a = Matrix::randn(5, 9, &mut rng);
        let (qr, sr) = quantize_rows(&a, 6);
        let (qc, sc) = quantize_cols(&a.transpose(), 6);
        assert_eq!(sr.len(), 5);
        assert_eq!(sc.len(), 5);
        for (x, y) in sr.iter().zip(&sc) {
            assert!((x - y).abs() < 1e-7);
        }
        let qct = qc.transpose();
        for (x, y) in qr.data().iter().zip(qct.data()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn quantize_vec_matches_row_quant() {
        let v = vec![0.1f32, -0.9, 0.4, 0.05];
        let (qv, s) = quantize_vec(&v, 4);
        let m = Matrix::from_vec(1, 4, v.clone());
        let (qm, sm) = quantize_rows(&m, 4);
        assert!((s - sm[0]).abs() < 1e-7);
        for (x, y) in qv.iter().zip(qm.row(0)) {
            assert!((x - y).abs() < 1e-7);
        }
    }

    #[test]
    fn zero_matrix_quantizes_to_zero() {
        let a = Matrix::zeros(3, 3);
        let (q, s) = quantize_tensor(&a, 8);
        assert_eq!(s, 0.0);
        assert!(q.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn levels_boundary_contract() {
        // The documented WordLen contract: 2..=16 accepted, edges exact.
        assert_eq!(levels(2), 1.0);
        assert_eq!(levels(8), 127.0);
        assert_eq!(levels(16), 32767.0);
    }

    #[test]
    #[should_panic(expected = "word length out of range")]
    fn levels_rejects_below_contract() {
        levels(1);
    }

    #[test]
    #[should_panic(expected = "word length out of range")]
    fn levels_rejects_above_contract() {
        levels(17);
    }

    #[test]
    fn non_finite_max_abs_yields_zero_scale() {
        // Hardened contract: NaN/inf calibration never poisons a scale —
        // debug builds trip the assert, release builds fall back to 0.
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let r = std::panic::catch_unwind(|| scale_for(bad, 127.0));
            if cfg!(debug_assertions) {
                assert!(r.is_err(), "debug build must flag max_abs {bad}");
            } else {
                assert_eq!(r.unwrap(), 0.0, "release build must 0-scale {bad}");
            }
        }
        // Finite inputs are untouched by the hardening.
        assert!((scale_for(12.7, 127.0) - 0.1).abs() < 1e-6);
        assert_eq!(scale_for(0.0, 127.0), 0.0);
        assert_eq!(scale_for(-3.0, 127.0), 0.0);
    }

    #[test]
    fn int_grid_matches_fake_quant_bitwise() {
        // quantize_val == dequantize_val(quantize_int) — the exactness
        // contract qkernel's packed storage is built on.
        let mut rng = Pcg64::new(45);
        for wl in [2u32, 3, 5, 8] {
            let lv = levels(wl);
            let bound = lv as i32;
            for _ in 0..200 {
                let x = rng.normal() * 3.0;
                let s = scale_for(2.5, lv);
                let q = quantize_int(x, s, lv);
                assert!((-bound..=bound).contains(&q), "wl={wl} q={q}");
                let fq = quantize_val(x, s, lv);
                assert_eq!(dequantize_val(q, s).to_bits(), fq.to_bits(), "wl={wl} x={x}");
            }
        }
        // 0-scale convention.
        assert_eq!(quantize_int(5.0, 0.0, 127.0), 0);
        assert_eq!(quantize_val(5.0, 0.0, 127.0), 0.0);
    }

    #[test]
    fn nan_lane_no_longer_silently_zero_quantizes() {
        // The bugfix: `f32::max` drops NaN, so the old fold produced a
        // clean scale for a poisoned vector and the NaN lane quantized
        // to 0 among otherwise-valid values. The NaN-sticky fold routes
        // it through scale_for's hardening instead: debug builds trip
        // the assert, release builds 0-scale the whole vector.
        let v = vec![0.5f32, f32::NAN, -0.25];
        let r = std::panic::catch_unwind(|| quantize_vec_parts(&v, 8));
        if cfg!(debug_assertions) {
            assert!(r.is_err(), "debug build must flag the NaN lane");
        } else {
            let (q, s) = r.unwrap();
            assert_eq!(s, 0.0, "release build must 0-scale the poisoned vector");
            assert!(q.iter().all(|&qi| qi == 0));
        }
    }

    #[test]
    fn nan_sticky_fold_covers_row_and_col_quant() {
        // quantize_rows / quantize_cols share the hardened fold; only
        // the poisoned vector loses its scale, neighbours keep theirs.
        let a = Matrix::from_vec(2, 2, vec![1.0, f32::NAN, 0.5, -0.5]);
        let rows = std::panic::catch_unwind(|| quantize_rows(&a, 8));
        let cols = std::panic::catch_unwind(|| quantize_cols(&a, 8));
        if cfg!(debug_assertions) {
            assert!(rows.is_err() && cols.is_err(), "debug builds must flag the NaN");
        } else {
            let (q, s) = rows.unwrap();
            assert_eq!(s[0], 0.0, "poisoned row 0-scales");
            assert!(s[1] > 0.0, "clean row keeps its scale");
            assert!(q.row(0).iter().all(|&x| x == 0.0));
            let (qc, sc) = cols.unwrap();
            assert!(sc[0] > 0.0, "clean column keeps its scale");
            assert_eq!(sc[1], 0.0, "poisoned column 0-scales");
            assert_eq!(qc.get(1, 1), 0.0);
        }
    }

    #[test]
    fn try_quantize_vec_parts_reports_the_offending_lane() {
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let v = vec![0.5f32, -0.1, bad, 0.9];
            let e = try_quantize_vec_parts(&v, 8).unwrap_err();
            assert_eq!(e.index, 2);
            assert_eq!(e.value.to_bits(), bad.to_bits());
            assert!(e.to_string().contains("lane 2"), "{e}");
        }
        // Finite vectors take the exact same integer path as the
        // infallible form.
        let v = vec![0.31f32, -0.9, 0.44, 0.0];
        assert_eq!(try_quantize_vec_parts(&v, 8).unwrap(), quantize_vec_parts(&v, 8));
    }

    #[test]
    fn quantize_vec_parts_matches_quantize_vec() {
        let v = vec![0.31f32, -0.9, 0.44, 0.05, -0.002];
        for wl in [2u32, 4, 8] {
            let (qf, sf) = quantize_vec(&v, wl);
            let (qi, si) = quantize_vec_parts(&v, wl);
            assert_eq!(sf.to_bits(), si.to_bits());
            for (f, &i) in qf.iter().zip(&qi) {
                assert_eq!(f.to_bits(), dequantize_val(i, si).to_bits());
            }
        }
    }
}
