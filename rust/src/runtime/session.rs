//! Translate sessions: argument packing + execution for the model
//! artifacts, replaying the manifest's positional argument order.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::compress::CompressedLinear;
use crate::model::{Manifest, PairModel};
use crate::quant;

use super::{Engine, Mode, TranslateBackend};

/// A compiled translate executable plus the manifest metadata needed to
/// pack its arguments.
pub struct TranslateSession<'e> {
    engine: &'e Engine,
    exe: Arc<xla::PjRtLoadedExecutable>,
    manifest: Manifest,
    mode: Mode,
}

/// Device-resident argument buffers for one compression configuration —
/// everything except the source tokens, which vary per batch.
pub struct ArgBank {
    buffers: Vec<xla::PjRtBuffer>,
}

impl<'e> TranslateSession<'e> {
    pub fn new(engine: &'e Engine, manifest: &Manifest, mode: Mode) -> Result<Self> {
        let path = match mode {
            Mode::Dense => &manifest.artifacts.translate_dense,
            Mode::Svd => &manifest.artifacts.translate_svd,
            Mode::Quantized => bail!(
                "no AOT artifact exists for quantized (bit-packed) execution; \
                 use the native backend"
            ),
        };
        let exe = engine.load_hlo(path)?;
        Ok(TranslateSession { engine, exe, manifest: manifest.clone(), mode })
    }

    pub fn mode(&self) -> Mode {
        self.mode
    }

    pub fn batch(&self) -> usize {
        self.manifest.model.eval_batch
    }

    pub fn seq_len(&self) -> usize {
        self.manifest.model.seq_len
    }

    /// Upload every weight argument for one compression configuration.
    ///
    /// * `compressed` maps linear name -> compressed layer; linears absent
    ///   from the map run with their original FP32 weights (Dense mode
    ///   only — the SVD artifact needs a factor pair for every linear).
    /// * `act_wl` is the activation word length (`A` of WxAy); `None`
    ///   disables activation quantization (FP32 activations).
    pub fn build_bank(
        &self,
        model: &PairModel,
        compressed: &BTreeMap<String, CompressedLinear>,
        act_wl: Option<u32>,
    ) -> Result<ArgBank> {
        let order = self
            .manifest
            .arg_order
            .get(self.mode.key())
            .context("manifest missing arg order")?;
        let lv = act_wl.map(quant::levels).unwrap_or(0.0);
        let mut buffers = Vec::with_capacity(order.len() - 1);

        for name in order.iter().skip(1) {
            // skip src_tokens (slot 0)
            let buf = match name.as_str() {
                "act_scales" => {
                    let scales: Vec<f32> = self
                        .manifest
                        .linears
                        .iter()
                        .enumerate()
                        .map(|(i, _)| {
                            if lv > 0.0 {
                                quant::scale_for(model.act_maxabs[i], lv)
                            } else {
                                1.0
                            }
                        })
                        .collect();
                    self.engine.upload_f32(&scales, &[scales.len()])?
                }
                "act_levels" => self.engine.upload_f32(&[lv], &[])?,
                _ => self.upload_param(model, compressed, name)?,
            };
            buffers.push(buf);
        }
        Ok(ArgBank { buffers })
    }

    fn upload_param(
        &self,
        model: &PairModel,
        compressed: &BTreeMap<String, CompressedLinear>,
        name: &str,
    ) -> Result<xla::PjRtBuffer> {
        // SVD factor slots: "<linear>.w1" / "<linear>.w2".
        if let Some(base) = name.strip_suffix(".w1") {
            let info = self
                .manifest
                .linears
                .iter()
                .find(|l| l.name == base)
                .with_context(|| format!("unknown linear {base}"))?;
            let c = compressed
                .get(base)
                .with_context(|| format!("SVD artifact needs a factored layer for {base}"))?;
            let CompressedLinear::LowRank { w1, .. } = c else {
                bail!("layer {base} is not factored; SVD mode needs LowRank");
            };
            let padded = w1.pad_to(info.k, info.r_max);
            return self.engine.upload_f32(padded.data(), &[info.k, info.r_max]);
        }
        if let Some(base) = name.strip_suffix(".w2") {
            let info = self
                .manifest
                .linears
                .iter()
                .find(|l| l.name == base)
                .with_context(|| format!("unknown linear {base}"))?;
            let c = compressed.get(base).context("missing factored layer")?;
            let CompressedLinear::LowRank { w2, .. } = c else {
                bail!("layer {base} is not factored; SVD mode needs LowRank");
            };
            let padded = w2.pad_to(info.r_max, info.n);
            return self.engine.upload_f32(padded.data(), &[info.r_max, info.n]);
        }
        // Dense linear slot (compressed linears appear under their bare
        // name in dense mode).
        if self.manifest.linear_index(name).is_some() {
            let w = match compressed.get(name) {
                Some(c) => c.effective(),
                None => model.linear(name).clone(),
            };
            return self.engine.upload_f32(w.data(), &[w.rows(), w.cols()]);
        }
        // Uncompressed parameter straight from the weight store.
        let m = model
            .weights
            .get(name)
            .with_context(|| format!("weight {name} missing from store"))?;
        let dims = model.weights.dims(name).unwrap();
        self.engine.upload_f32(m.data(), &dims)
    }

    /// Greedy-translate one batch. `src_tokens` is `[batch * seq_len]`
    /// (pad short batches with PAD); returns `[batch * seq_len]` output
    /// tokens (BOS-framed, EOS/PAD-terminated).
    pub fn translate(&self, bank: &ArgBank, src_tokens: &[i32]) -> Result<Vec<i32>> {
        let b = self.batch();
        let s = self.seq_len();
        if src_tokens.len() != b * s {
            bail!("src_tokens len {} != batch {b} x seq {s}", src_tokens.len());
        }
        let src = self.engine.upload_i32(src_tokens, &[b, s])?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + bank.buffers.len());
        args.push(&src);
        args.extend(bank.buffers.iter());
        let out = self.engine.run_tuple1(&self.exe, &args)?;
        out.to_vec::<i32>().context("reading translate output")
    }
}

/// A [`TranslateSession`] bundled with its device-resident [`ArgBank`] —
/// the PJRT implementation of the backend trait the evaluator, serving
/// loop and CLI are written against.
pub struct PjrtBackend<'e> {
    session: TranslateSession<'e>,
    bank: ArgBank,
}

impl<'e> PjrtBackend<'e> {
    pub fn new(session: TranslateSession<'e>, bank: ArgBank) -> PjrtBackend<'e> {
        PjrtBackend { session, bank }
    }

    pub fn session(&self) -> &TranslateSession<'e> {
        &self.session
    }
}

impl TranslateBackend for PjrtBackend<'_> {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn batch(&self) -> usize {
        self.session.batch()
    }

    fn seq_len(&self) -> usize {
        self.session.seq_len()
    }

    fn translate(&self, src_tokens: &[i32]) -> Result<Vec<i32>> {
        self.session.translate(&self.bank, src_tokens)
    }
}
