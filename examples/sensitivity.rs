//! Per-layer sensitivity analysis + SRA allocation inspection (Fig. 4
//! companion).
//!
//! ```bash
//! cargo run --release --example sensitivity [-- <pair>]
//! ```
//!
//! Probes each layer group's tolerance to rank truncation (one layer at a
//! time, FP32 elsewhere — the paper's Fig. 4 protocol), then runs a short
//! SRA search and shows how the allocator shifts rank toward the layers
//! the probe found sensitive.

use anyhow::Result;
use itera_llm::config::ExpConfig;
use itera_llm::coordinator::figures;
use itera_llm::coordinator::Coordinator;

fn main() -> Result<()> {
    let pair = std::env::args().nth(1).unwrap_or_else(|| "en-de".to_string());
    let c = Coordinator::new(ExpConfig::fast())?;

    // One probe layer per structural group.
    let layers = [
        "enc0.self_q",
        "enc1.ff1",
        "dec0.self_v",
        "dec0.cross_q",
        "dec1.ff2",
        "dec1.cross_o",
    ];
    println!("[1/2] probing per-layer rank sensitivity ({pair}) ...");
    let t = figures::fig4(&c, &pair, &layers)?;
    print!("{}", t.render());

    println!("[2/2] SRA allocation at 40% total rank budget (W4A8) ...");
    let caps = c.manifest.rank_caps();
    let budget = caps.iter().sum::<usize>() * 2 / 5;
    let (ranks, calib_bleu) = c.sra_search(&pair, 4, budget);
    println!("calibration BLEU after search: {calib_bleu:.2}");
    println!("{:<16} {:>5} {:>6}", "layer", "rank", "cap");
    for (l, r) in c.manifest.linears.iter().zip(&ranks) {
        let bar = "#".repeat((r * 24 / l.r_max.max(1)).min(24));
        println!("{:<16} {:>5} {:>6}  {bar}", l.name, r, l.r_max);
    }
    Ok(())
}
