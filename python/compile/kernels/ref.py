"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an exact (up to float associativity)
counterpart here; ``python/tests/test_kernels.py`` sweeps shapes and tile
sizes with hypothesis and asserts allclose between kernel and oracle.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Dense matmul oracle: y = x @ w, f32 accumulate."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32)


def cascade_ref(x: jnp.ndarray, w1: jnp.ndarray, w2: jnp.ndarray) -> jnp.ndarray:
    """SVD cascade oracle: y = (x @ w1) @ w2 without reconstructing W."""
    return matmul_ref(matmul_ref(x, w1), w2)


def fake_quant_ref(x: jnp.ndarray, scale, levels) -> jnp.ndarray:
    """Symmetric fixed-point fake-quantization oracle.

    ``q = clip(round(x / scale), -levels, levels) * scale``; a ``levels``
    of 0 disables quantization (identity), matching the runtime convention
    the Rust coordinator uses to request an FP32 activation path.
    """
    scale = jnp.asarray(scale, dtype=x.dtype)
    levels = jnp.asarray(levels, dtype=x.dtype)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -levels, levels) * safe
    return jnp.where(levels > 0, q, x)
