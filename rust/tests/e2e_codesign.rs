//! End-to-end co-design integration tests: the paper's qualitative claims
//! must hold on the substituted substrate (shape, not absolute numbers).
//!
//! Needs the PJRT runtime (BLEU through the compiled artifacts), so it
//! only builds with the `pjrt` feature.

#![cfg(feature = "pjrt")]

use itera_llm::config::ExpConfig;
use itera_llm::coordinator::{figures, Coordinator, Method};
use itera_llm::hw::Platform;
use itera_llm::model::Manifest;

fn coordinator() -> Option<Coordinator> {
    if !Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Coordinator::new(ExpConfig::fast()).unwrap())
}

#[test]
fn iterative_beats_plain_svd_at_matched_budget() {
    // Fig. 7's central ordering: with quantization in the loop, Algorithm 1
    // dominates SVD-then-quantize at the same (wl, rank) budget.
    let Some(c) = coordinator() else { return };
    let pair = "en-de";
    for (wl, frac) in [(4u32, 0.25), (3, 0.4)] {
        let base = c
            .measure(pair, &Method::SvdBaseline { wl, rank_frac: frac })
            .unwrap();
        let iter = c.measure(pair, &Method::SvdIter { wl, rank_frac: frac }).unwrap();
        assert!(
            iter.bleu >= base.bleu - 0.5,
            "W{wl} frac {frac}: iter {:.2} must not lose to baseline {:.2}",
            iter.bleu,
            base.bleu
        );
        assert!((iter.ratio - base.ratio).abs() < 0.05, "same budget, same ratio");
    }
}

#[test]
fn decomposition_extends_the_pareto_front() {
    // In the ratio region beyond quantization-only's reach (between W3's
    // ~10x and W2's ~16x there is NOTHING dense), Algorithm 1 provides
    // usable design points — the mechanism behind the paper's Fig. 7 wins.
    let Some(c) = coordinator() else { return };
    let pair = "en-de";
    let q2 = c.measure(pair, &Method::QuantOnly { wl: 2 }).unwrap();
    let it = c
        .measure(pair, &Method::SvdIter { wl: 4, rank_frac: 0.25 })
        .unwrap();
    assert!(
        it.ratio > 12.0,
        "decomposed point must sit in the high-ratio region: {:.1}",
        it.ratio
    );
    assert!(
        it.bleu > q2.bleu + 10.0,
        "iterative W3 (ratio {:.1}, BLEU {:.1}) must crush quant W2 (ratio {:.1}, BLEU {:.1})",
        it.ratio,
        it.bleu,
        q2.ratio,
        q2.bleu
    );
}

#[test]
fn codesign_latency_reduction_at_comparable_bleu() {
    // Headline claim (§VIII-E): mapped onto ZCU111, a decomposed config
    // at comparable BLEU cuts linear-layer latency vs the quant baseline.
    let Some(c) = coordinator() else { return };
    let pair = "en-de";
    let quant = c.measure(pair, &Method::QuantOnly { wl: 4 }).unwrap();
    let iter = c.measure(pair, &Method::SvdIter { wl: 4, rank_frac: 0.25 }).unwrap();
    // Comparable accuracy regime on this substrate.
    assert!(
        iter.bleu >= quant.bleu - 2.0,
        "iter {:.2} vs quant {:.2}",
        iter.bleu,
        quant.bleu
    );
    for platform in [Platform::zcu111(), Platform::zcu111_quarter_bw()] {
        let cd_q = figures::codesign(&c, &quant, &platform);
        let cd_i = figures::codesign(&c, &iter, &platform);
        let red = figures::headline_latency_reduction(&cd_q, &cd_i);
        assert!(
            red > 0.10,
            "{}: latency reduction {:.1}% should exceed 10% (paper: 12.1-41.1%)",
            platform.name,
            red * 100.0
        );
    }
}

#[test]
fn sra_allocation_not_worse_than_uniform() {
    // Eq. 5's point: the searched allocation must match or beat the
    // equal-split allocation it starts from, measured on the test set.
    let Some(c) = coordinator() else { return };
    let pair = "en-de";
    let caps = c.manifest.rank_caps();
    let budget = caps.iter().sum::<usize>() * 2 / 5;
    let (ranks, _) = c.sra_search(pair, 4, budget);
    assert_eq!(ranks.iter().sum::<usize>(), {
        let eq = itera_llm::sra::equal_split(budget, &caps);
        eq.iter().sum::<usize>()
    });
    let sra_pt = c.measure(pair, &Method::SvdIterRanks { wl: 4, ranks }).unwrap();
    let frac = budget as f64 / caps.iter().sum::<usize>() as f64;
    let uniform = c.measure(pair, &Method::SvdIter { wl: 4, rank_frac: frac }).unwrap();
    assert!(
        sra_pt.bleu >= uniform.bleu - 1.5,
        "SRA {:.2} should not trail uniform {:.2} meaningfully",
        sra_pt.bleu,
        uniform.bleu
    );
}

#[test]
fn fig10_pareto_shapes() {
    // Bandwidth-limited region: some SVD design needs less bandwidth than
    // every comparable-latency baseline design (Fig. 10's left side);
    // compute-bound region: the best SVD latency beats the best baseline
    // latency (right side).
    use itera_llm::dse::sweep_engines;
    use itera_llm::hw::{EngineKind, Workload};
    let w = Workload::new(512, 512, 512, 4, 8);
    let p = Platform::zcu111();
    let base = sweep_engines(&w, None, &p, &[EngineKind::Baseline]);
    let svd = sweep_engines(&w, Some(128), &p, &[EngineKind::SingleSvd, EngineKind::CascadeSvd]);
    let best_base = base
        .iter()
        .map(|d| d.design.latency_cycles)
        .fold(f64::INFINITY, f64::min);
    let best_svd = svd
        .iter()
        .map(|d| d.design.latency_cycles)
        .fold(f64::INFINITY, f64::min);
    assert!(best_svd < best_base, "compute-bound: svd {best_svd} vs base {best_base}");

    // For a latency budget 2x the best baseline, the cheapest-bandwidth
    // SVD design must undercut the cheapest-bandwidth baseline design.
    let budget = best_base * 2.0;
    let min_bw = |pts: &[itera_llm::dse::DesignPoint]| {
        pts.iter()
            .filter(|d| d.design.latency_cycles <= budget)
            .map(|d| d.design.bandwidth_req)
            .fold(f64::INFINITY, f64::min)
    };
    let bw_base = min_bw(&base);
    let bw_svd = min_bw(&svd);
    assert!(
        bw_svd < bw_base,
        "bandwidth-limited: svd needs {bw_svd:.0} b/c vs base {bw_base:.0} b/c"
    );
}

#[test]
fn cascade_populates_finer_design_space() {
    // §VIII-D: the cascade engine fills points between the single-engine
    // Pareto points thanks to the extra (R_t, N_t) degree of freedom.
    use itera_llm::dse::sweep_engines;
    use itera_llm::hw::{EngineKind, Workload};
    let w = Workload::new(512, 512, 512, 4, 8);
    let p = Platform::zcu111();
    let single = sweep_engines(&w, Some(128), &p, &[EngineKind::SingleSvd]);
    let cascade = sweep_engines(&w, Some(128), &p, &[EngineKind::CascadeSvd]);
    assert!(
        cascade.len() > single.len() * 2,
        "cascade {} vs single {} design points",
        cascade.len(),
        single.len()
    );
}
