//! Summary statistics used by the bench harness and experiment reports.

/// Online summary of a sample set (Welford's algorithm for variance).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: usize,
    mean: f64,
    m2: f64,
    sum: f64,
    min: f64,
    max: f64,
    values: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { min: f64::INFINITY, max: f64::NEG_INFINITY, ..Default::default() }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.values.push(x);
    }

    /// Fold another summary into this one. Mean/variance combine via
    /// the pairwise (Chan et al.) update, so the result matches a
    /// single summary fed both sample sets.
    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let (na, nb) = (self.n as f64, other.n as f64);
        let d = other.mean - self.mean;
        self.mean += d * nb / (na + nb);
        self.m2 += other.m2 + d * d * na * nb / (na + nb);
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.values.extend_from_slice(&other.values);
    }

    pub fn count(&self) -> usize {
        self.n
    }

    /// Exact running total of all observations.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// q in [0,1]; linear interpolation between order statistics.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut v = self.values.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            v[lo]
        } else {
            v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
        }
    }

    pub fn median(&self) -> f64 {
        self.quantile(0.5)
    }
}

/// Geometric mean of strictly positive values (used for BLEU).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() || xs.iter().any(|&x| x <= 0.0) {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.median() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quantiles() {
        let mut s = Summary::new();
        for i in 0..101 {
            s.add(i as f64);
        }
        assert!((s.quantile(0.0) - 0.0).abs() < 1e-12);
        assert!((s.quantile(0.5) - 50.0).abs() < 1e-12);
        assert!((s.quantile(1.0) - 100.0).abs() < 1e-12);
        assert!((s.quantile(0.95) - 95.0).abs() < 1e-9);
    }

    #[test]
    fn merge_matches_a_single_combined_summary() {
        let xs = [0.5, 1.5, 2.25, 8.0, 0.125];
        let ys = [3.0, 4.5, 0.75, 6.0];
        let (mut a, mut b, mut both) = (Summary::new(), Summary::new(), Summary::new());
        for &x in &xs {
            a.add(x);
            both.add(x);
        }
        for &y in &ys {
            b.add(y);
            both.add(y);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert!((a.sum() - both.sum()).abs() < 1e-12);
        assert!((a.mean() - both.mean()).abs() < 1e-12);
        assert!((a.var() - both.var()).abs() < 1e-12);
        assert_eq!(a.min(), both.min());
        assert_eq!(a.max(), both.max());
        assert!((a.quantile(0.5) - both.quantile(0.5)).abs() < 1e-12);
        assert!((a.quantile(0.95) - both.quantile(0.95)).abs() < 1e-12);
    }

    #[test]
    fn merge_handles_empty_sides() {
        let mut a = Summary::new();
        let mut b = Summary::new();
        b.add(2.0);
        a.merge(&b);
        assert_eq!(a.count(), 1);
        assert_eq!(a.sum(), 2.0);
        let empty = Summary::new();
        a.merge(&empty);
        assert_eq!(a.count(), 1);
        assert_eq!(a.min(), 2.0);
        assert_eq!(a.max(), 2.0);
    }

    #[test]
    fn geomean_matches_hand() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[1.0, 0.0]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
    }
}
