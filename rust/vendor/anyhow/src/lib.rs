//! Minimal vendored stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io access, so the repository carries the
//! small subset of `anyhow`'s API that the codebase actually uses:
//!
//! * [`Error`] — a message chain (outermost context first); like the real
//!   `anyhow::Error` it deliberately does **not** implement
//!   `std::error::Error`, which is what allows the blanket
//!   `From<E: std::error::Error>` conversion behind `?`.
//! * [`Result<T>`] — alias with the error type defaulted to [`Error`].
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Display semantics match what the callers rely on: `{e}` prints the
//! outermost message, `{e:#}` prints the whole chain separated by `: `
//! (the format `main.rs` uses for fatal errors).

use std::fmt;

/// Error as a message chain, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error arm of a `Result` or to a `None`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!format!("{e}").is_empty());
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e: Result<()> = Err(anyhow!("root"));
        let e = e.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root");
        assert_eq!(e.root_cause(), "root");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
        assert_eq!(Some(3).context("fine").unwrap(), 3);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative: -1");
        assert_eq!(format!("{}", f(11).unwrap_err()), "too big: 11");
    }
}
