//! Dynamic admission scheduling for slot-addressed decode: the
//! continuous-batching engine.
//!
//! The static batcher ([`super::serve::serve_loop`]) runs one monolithic
//! batch lifecycle: group requests, decode the whole batch to completion
//! (stragglers pin every other row), respond, repeat — so the decode
//! engine idles between waves. [`ContinuousBatcher`] keeps it hot by
//! scheduling per-sequence KV slots ([`crate::runtime::SlotEngine`])
//! instead of batches: **between decode steps** it retires EOS'd slots,
//! admits queued requests into the freed capacity (running their encoder
//! pass and splicing their cross-attention context into the live batch),
//! and steps the resulting mixed-age batch.
//!
//! Scheduling is deterministic and wall-clock-free — the queue is kept
//! in submission-id order so dequeue is longest-waiting-first (plain
//! FIFO, preserved even across preemption), admission fills the lowest
//! free slot index, and an idle tick (no live slots, empty queue) is a
//! no-op. That makes the policy unit-testable with scripted
//! arrival/length traces against a mock engine, with no model anywhere.
//!
//! **Memory-bounded admission and preemption-by-eviction.** When the
//! engine reports KV pool accounting ([`SlotEngine::kv_stats`] — the
//! paged allocator in [`crate::runtime::kvpool`]), admission is bounded
//! by *bytes*, not just slot count: a request is admitted only when its
//! worst-case page demand ([`SlotEngine::slot_worst_bytes`]) fits the
//! pool's free bytes net of what live slots need for their next step
//! and what this tick's earlier admissions may grow into; a request
//! whose worst case exceeds the whole budget is shed `Overloaded` (it
//! can never fit), and otherwise the queue simply waits. Live slots
//! only reserve their *next step's* pages, so concurrency over-commits
//! optimistically — and when the pool then runs dry mid-decode, the
//! **youngest-admitted** live slot is evicted back to its id-ordered
//! queue position (pages freed, [`BatcherStats::preempted`]) and
//! re-prefilled on re-admission: decode replays deterministically from
//! the source row, so the final output is **bit-identical** to an
//! uninterrupted run while deadlines keep counting from the original
//! submission (graceful degradation, not silent retry). The oldest
//! live slot is never evicted, so progress is guaranteed; with no
//! memory pressure (unbounded pool, or an engine with no pool) nothing
//! is ever preempted — a long request keeps its slot until it
//! completes, so nothing starves.
//!
//! **Faults are per-request outcomes, not batcher failures.** Every
//! submission ends in exactly one [`Completion`] whose `result` is
//! either the decoded buffer or a typed [`ServeError`]:
//!
//! * deadlines ([`RequestLimits::deadline_steps`], counted in the
//!   batcher's own decode steps, queue wait included) retire expired
//!   work with `DeadlineExceeded`, freeing capacity deterministically;
//! * `max_new_tokens` truncates long decodes into **successful**
//!   completions;
//! * a bounded queue ([`Self::with_queue_limit`]) sheds excess
//!   submissions with `Overloaded` instead of growing without bound,
//!   and [`Self::begin_drain`] sheds all further submissions while the
//!   backlog finishes;
//! * [`Self::cancel`] drops a queued or live request whose client went
//!   away (the serve loop's disconnect detection calls this);
//! * engine `Err`s **and panics** during admit/step are caught
//!   (`catch_unwind`), attributed to the offending request, and retired
//!   as `EngineFault` — the other slots keep stepping bit-identically
//!   (slot independence plus the engine's re-steppable-on-failure
//!   contract, see [`crate::runtime::SlotEngine::step`]).
//!
//! Outputs are **bit-identical** to decoding each request alone through
//! the cached path: slot independence is the engine's contract
//! ([`crate::runtime::SlotEngine`]), pinned end-to-end by
//! `prop_continuous_decode_bit_identical_to_sequential`, the serving
//! soak tests (including the seeded chaos soak) and
//! `itera validate --batcher continuous`.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Instant;

use crate::obs::{Counter, Gauge, Histogram, Obs};
use crate::runtime::SlotEngine;

use super::fault::{panic_message, RequestLimits, ServeError};

/// Which serving batcher runs the decode loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Batcher {
    /// Monolithic batch lifecycle: fill up to capacity, decode the whole
    /// batch to completion, respond, repeat.
    #[default]
    Static,
    /// Slot-addressed lifecycle: retire/admit between decode steps so
    /// the batch stays full under dynamic load ([`ContinuousBatcher`]).
    Continuous,
}

impl Batcher {
    pub fn key(self) -> &'static str {
        match self {
            Batcher::Static => "static",
            Batcher::Continuous => "continuous",
        }
    }

    /// Parse a CLI `--batcher` value.
    pub fn parse(s: &str) -> Option<Batcher> {
        match s {
            "static" => Some(Batcher::Static),
            "continuous" => Some(Batcher::Continuous),
            _ => None,
        }
    }
}

/// One finished request, reported by [`ContinuousBatcher::tick`] —
/// successfully decoded or retired with a typed error, but always
/// reported exactly once.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Completion {
    /// Submission id (assigned FIFO by [`ContinuousBatcher::submit`]).
    pub id: u64,
    /// Slot index the request decoded in (observable slot reuse), or
    /// `None` when it never reached a slot (expired or faulted while
    /// queued).
    pub slot: Option<usize>,
    /// The decoded `seq_len`-token output buffer, or why there is none.
    pub result: Result<Vec<i32>, ServeError>,
}

impl Completion {
    /// The output buffer of a successful completion.
    pub fn tokens(&self) -> Option<&[i32]> {
        self.result.as_ref().ok().map(|t| t.as_slice())
    }
}

/// Deterministic scheduling counters. On any run,
/// `submitted == retired + shed + expired + cancelled + faulted` once
/// the batcher is idle (every submission gets exactly one outcome).
#[derive(Debug, Clone, Default)]
pub struct BatcherStats {
    /// Decode steps executed (idle ticks are not steps).
    pub steps: usize,
    /// Requests admitted into a slot.
    pub admitted: usize,
    /// Slots retired successfully (EOS, full buffer, or truncated by
    /// `max_new_tokens`).
    pub retired: usize,
    /// Sum over steps of live slots — the occupancy numerator.
    pub occupied_slot_steps: usize,
    /// Submissions rejected with [`ServeError::Overloaded`] (bounded
    /// queue full, or draining).
    pub shed: usize,
    /// Requests retired with [`ServeError::DeadlineExceeded`] (queued or
    /// live).
    pub expired: usize,
    /// Requests dropped via [`ContinuousBatcher::cancel`] (client gone).
    pub cancelled: usize,
    /// Requests retired with [`ServeError::EngineFault`] (admission or
    /// step failure/panic).
    pub faulted: usize,
    /// Subset of `retired` cut short by their `max_new_tokens` budget.
    pub truncated: usize,
    /// Live slots evicted back to the queue under memory pressure
    /// (pages freed, request requeued). **Non-terminal**: a preempted
    /// request is still in flight, so this is not part of the
    /// accounting identity.
    pub preempted: usize,
    /// Previously-preempted requests admitted again (re-prefill).
    /// Non-terminal, like `preempted`; `admitted` counts these too
    /// (every admission runs an encoder pass).
    pub requeued: usize,
}

impl BatcherStats {
    /// Mean fraction of `capacity` occupied per decode step, in `[0, 1]`.
    pub fn occupancy(&self, capacity: usize) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.occupied_slot_steps as f64 / (self.steps * capacity.max(1)) as f64
    }
}

/// Registry handles mirroring [`BatcherStats`] plus live gauges and
/// step/admit timing histograms, attached via
/// [`ContinuousBatcher::with_obs`]. Counter mirrors sit next to every
/// `stats.*` increment so the exported identity
/// `batcher_submitted_total == retired + shed + expired + cancelled +
/// faulted` holds exactly when the batcher's own stats balance.
struct SchedObs {
    submitted: Arc<Counter>,
    retired: Arc<Counter>,
    shed: Arc<Counter>,
    expired: Arc<Counter>,
    cancelled: Arc<Counter>,
    faulted: Arc<Counter>,
    admitted: Arc<Counter>,
    steps: Arc<Counter>,
    occupied: Arc<Counter>,
    preempted: Arc<Counter>,
    requeued: Arc<Counter>,
    queue_depth: Arc<Gauge>,
    live_slots: Arc<Gauge>,
    occupancy: Arc<Gauge>,
    kv_resident_bytes: Arc<Gauge>,
    kv_pages_free: Arc<Gauge>,
    step_seconds: Arc<Histogram>,
    admit_seconds: Arc<Histogram>,
}

impl SchedObs {
    fn new(obs: &Obs, capacity: usize) -> SchedObs {
        let reg = obs.registry();
        let outcome = |key| reg.counter_with("batcher_outcomes_total", &[("outcome", key)]);
        reg.gauge("batcher_capacity").set(capacity as f64);
        SchedObs {
            submitted: reg.counter("batcher_submitted_total"),
            retired: outcome("retired"),
            shed: outcome("shed"),
            expired: outcome("expired"),
            cancelled: outcome("cancelled"),
            faulted: outcome("faulted"),
            admitted: reg.counter("batcher_admitted_total"),
            steps: reg.counter("batcher_decode_steps_total"),
            occupied: reg.counter("batcher_occupied_slot_steps_total"),
            preempted: reg.counter("batcher_preempted_total"),
            requeued: reg.counter("batcher_requeued_total"),
            queue_depth: reg.gauge("batcher_queue_depth"),
            live_slots: reg.gauge("batcher_live_slots"),
            occupancy: reg.gauge("batcher_occupancy"),
            kv_resident_bytes: reg.gauge("kv_resident_bytes"),
            kv_pages_free: reg.gauge("kv_pages_free"),
            step_seconds: reg.histogram("batcher_step_seconds", &STEP_BOUNDS),
            admit_seconds: reg.histogram("batcher_admit_seconds", &STEP_BOUNDS),
        }
    }
}

/// Exponential 10µs..~1.3s bounds for step/admit timing.
const STEP_BOUNDS: [f64; 18] = [
    1e-5, 2e-5, 4e-5, 8e-5, 1.6e-4, 3.2e-4, 6.4e-4, 1.28e-3, 2.56e-3, 5.12e-3, 1.024e-2,
    2.048e-2, 4.096e-2, 8.192e-2, 1.6384e-1, 3.2768e-1, 6.5536e-1, 1.31072,
];

/// A queued submission waiting for a slot.
struct Pending {
    id: u64,
    row: Vec<i32>,
    limits: RequestLimits,
    /// `stats.steps` at submission — the deadline epoch. Preserved
    /// across preemption, so deadlines count total time in the system.
    submit_step: usize,
    /// Back in the queue after an eviction (counted as `requeued` when
    /// admitted again).
    requeued: bool,
}

struct Live<S> {
    id: u64,
    slot: S,
    /// The source row, kept so an evicted request can re-prefill from
    /// scratch (decode is deterministic: the replay is bit-identical).
    row: Vec<i32>,
    limits: RequestLimits,
    submit_step: usize,
    /// Decode steps this slot has survived (the `max_new_tokens` meter).
    /// Resets on re-admission — the replayed decode re-earns its budget
    /// step for step, so the truncation point lands on the same token.
    new_tokens: usize,
    /// Monotone admission ticket: the eviction policy preempts the
    /// *youngest* admission (max `admit_seq`), never the oldest.
    admit_seq: u64,
}

/// Continuous-batching engine over any [`SlotEngine`].
///
/// `capacity` bounds concurrent slots; requests beyond it queue FIFO
/// (bounded by [`Self::with_queue_limit`], unbounded otherwise). Drive
/// it with [`submit`](Self::submit) + [`tick`](Self::tick) (one
/// retire/admit/step round per call) or
/// [`run_until_drained`](Self::run_until_drained).
pub struct ContinuousBatcher<'e, E: SlotEngine> {
    engine: &'e E,
    capacity: usize,
    /// Fixed-capacity slot table; `None` entries are free and reusable.
    slots: Vec<Option<Live<E::Slot>>>,
    /// FIFO admission queue.
    queue: VecDeque<Pending>,
    /// Admission-queue bound; submissions beyond it are shed.
    queue_limit: Option<usize>,
    /// Drain mode: shed all further submissions, finish the backlog.
    draining: bool,
    next_id: u64,
    /// Admission tickets handed out so far (see [`Live::admit_seq`]).
    admit_seq: u64,
    stats: BatcherStats,
    /// Registry mirror of `stats` + tick gauges; see [`Self::with_obs`].
    obs: Option<SchedObs>,
}

impl<'e, E: SlotEngine> ContinuousBatcher<'e, E> {
    pub fn new(engine: &'e E, capacity: usize) -> ContinuousBatcher<'e, E> {
        assert!(capacity >= 1, "continuous batcher needs at least one slot");
        ContinuousBatcher {
            engine,
            capacity,
            slots: (0..capacity).map(|_| None).collect(),
            queue: VecDeque::new(),
            queue_limit: None,
            draining: false,
            next_id: 0,
            admit_seq: 0,
            stats: BatcherStats::default(),
            obs: None,
        }
    }

    /// Bound the admission queue: submissions arriving while `limit`
    /// requests already wait are shed with [`ServeError::Overloaded`].
    pub fn with_queue_limit(mut self, limit: usize) -> ContinuousBatcher<'e, E> {
        self.queue_limit = Some(limit);
        self
    }

    /// Mirror every stats increment into `obs` and keep queue-depth /
    /// live-slot / occupancy gauges plus step/admit timing histograms
    /// current per tick. Without this the batcher records nothing.
    pub fn with_obs(mut self, obs: &Obs) -> ContinuousBatcher<'e, E> {
        self.obs = Some(SchedObs::new(obs, self.capacity));
        self
    }

    /// Enqueue one `seq_len`-framed request with default (unlimited)
    /// limits; returns its id (ids are assigned — and admitted — in
    /// submission order), or [`ServeError::Overloaded`] when shed.
    pub fn submit(&mut self, src_row: Vec<i32>) -> Result<u64, ServeError> {
        self.submit_with(src_row, RequestLimits::none())
    }

    /// [`submit`](Self::submit) with a per-request deadline/length
    /// budget.
    pub fn submit_with(
        &mut self,
        src_row: Vec<i32>,
        limits: RequestLimits,
    ) -> Result<u64, ServeError> {
        if let Some(o) = &self.obs {
            o.submitted.inc();
        }
        if self.draining {
            self.stats.shed += 1;
            if let Some(o) = &self.obs {
                o.shed.inc();
            }
            return Err(ServeError::Overloaded);
        }
        if let Some(limit) = self.queue_limit {
            if self.queue.len() >= limit {
                self.stats.shed += 1;
                if let Some(o) = &self.obs {
                    o.shed.inc();
                }
                return Err(ServeError::Overloaded);
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Pending {
            id,
            row: src_row,
            limits,
            submit_step: self.stats.steps,
            requeued: false,
        });
        Ok(id)
    }

    /// Stop admitting: every further [`submit`](Self::submit) is shed
    /// with [`ServeError::Overloaded`] while queued and live work runs
    /// to completion (tick until [`idle`](Self::idle) to finish the
    /// drain).
    pub fn begin_drain(&mut self) {
        self.draining = true;
    }

    pub fn draining(&self) -> bool {
        self.draining
    }

    /// Drop a queued or live request (client disconnected). Returns
    /// whether the id was found; a cancelled request produces **no**
    /// completion — the caller owns its terminal outcome.
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(pos) = self.queue.iter().position(|p| p.id == id) {
            self.queue.remove(pos);
            self.stats.cancelled += 1;
            if let Some(o) = &self.obs {
                o.cancelled.inc();
            }
            return true;
        }
        let engine = self.engine;
        for entry in self.slots.iter_mut() {
            if entry.as_ref().is_some_and(|l| l.id == id) {
                if let Some(mut l) = entry.take() {
                    engine.release_slot(&mut l.slot);
                }
                self.stats.cancelled += 1;
                if let Some(o) = &self.obs {
                    o.cancelled.inc();
                }
                return true;
            }
        }
        false
    }

    /// Requests waiting for a slot.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Currently occupied slots.
    pub fn live(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Slot capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Nothing live and nothing queued: a [`tick`](Self::tick) would be
    /// a no-op.
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.slots.iter().all(|s| s.is_none())
    }

    pub fn stats(&self) -> &BatcherStats {
        &self.stats
    }

    /// Whether `id` currently occupies a slot (admitted, not yet
    /// retired). The serve loop uses this to timestamp slot entry for
    /// the queue-wait vs execution latency split.
    pub fn is_live(&self, id: u64) -> bool {
        self.slots.iter().flatten().any(|l| l.id == id)
    }

    /// Current output buffer of a **live** request (partial decode so
    /// far), or `None` while it is still queued / already retired. This
    /// is the read the serve loop's incremental streaming pushes are
    /// built on; the buffer is framed like the terminal output, so the
    /// caller de-frames it the same way.
    pub fn peek_output(&self, id: u64) -> Option<Vec<i32>> {
        self.slots
            .iter()
            .flatten()
            .find(|l| l.id == id)
            .map(|l| self.engine.slot_output(&l.slot))
    }

    /// Mean slot occupancy over all decode steps so far.
    pub fn occupancy(&self) -> f64 {
        self.stats.occupancy(self.capacity)
    }

    fn deadline_hit(limits: &RequestLimits, submit_step: usize, now: usize) -> bool {
        limits.deadline_steps.is_some_and(|d| now.saturating_sub(submit_step) >= d)
    }

    /// One scheduling round: expire deadlined work (live slots in
    /// ascending slot order, then the queue FIFO), admit queued requests
    /// into free slots (id order, lowest free index first, gated by the
    /// engine's KV budget when it reports one — each admission runs the
    /// request's encoder pass), retire anything already complete (a
    /// degenerate admission can be born finished — it must never reach
    /// the step kernel), evict the youngest live slots back to the
    /// queue while the pool cannot back the next step, step the
    /// mixed-age batch of live slots once, then retire completed slots
    /// and return every completion. An idle round (nothing live after
    /// admission) executes no decode step. Engine failures and panics
    /// never escape: they become [`ServeError::EngineFault`]
    /// completions for the requests they are attributed to.
    pub fn tick(&mut self) -> Vec<Completion> {
        let mut done = Vec::new();
        let now = self.stats.steps;

        // Expire live slots first: the freed capacity is admittable in
        // this same tick. Ascending slot order keeps traces reproducible.
        for si in 0..self.slots.len() {
            let hit = matches!(
                &self.slots[si],
                Some(l) if Self::deadline_hit(&l.limits, l.submit_step, now)
            );
            if !hit {
                continue;
            }
            if let Some(mut l) = self.slots[si].take() {
                self.engine.release_slot(&mut l.slot);
                self.stats.expired += 1;
                if let Some(o) = &self.obs {
                    o.expired.inc();
                }
                done.push(Completion {
                    id: l.id,
                    slot: Some(si),
                    result: Err(ServeError::DeadlineExceeded),
                });
            }
        }

        // Expire queued requests: they never reach a slot. (Deadlines
        // count queue wait — a request nobody can schedule in time is
        // answered, not leaked.)
        let mut keep = VecDeque::with_capacity(self.queue.len());
        for p in self.queue.drain(..) {
            if Self::deadline_hit(&p.limits, p.submit_step, now) {
                self.stats.expired += 1;
                if let Some(o) = &self.obs {
                    o.expired.inc();
                }
                done.push(Completion {
                    id: p.id,
                    slot: None,
                    result: Err(ServeError::DeadlineExceeded),
                });
            } else {
                keep.push_back(p);
            }
        }
        self.queue = keep;

        // Memory-aware admission. When the engine reports KV pool
        // accounting, a request is admitted only if its worst-case page
        // demand fits the pool's free bytes net of (a) the pages live
        // slots need for their next step and (b) the worst case of
        // admissions already made this tick (`planned` — without it a
        // tick could admit work it would immediately have to evict). A
        // request that cannot fit even an empty pool is shed: waiting
        // can never help it. Engines without a pool (`kv_stats() ==
        // None`) skip the gate — admission is slot-count-bounded only.
        let worst = self.engine.slot_worst_bytes();
        let kv = self.engine.kv_stats();
        if kv.and_then(|s| s.budget_bytes).is_some_and(|total| worst > total) {
            while let Some(p) = self.queue.pop_front() {
                self.stats.shed += 1;
                if let Some(o) = &self.obs {
                    o.shed.inc();
                }
                done.push(Completion {
                    id: p.id,
                    slot: None,
                    result: Err(ServeError::Overloaded),
                });
            }
        }
        let kv_free = kv.and_then(|s| s.free_bytes);
        let need_live: usize = self
            .slots
            .iter()
            .flatten()
            .map(|l| self.engine.slot_next_step_bytes(&l.slot))
            .sum();
        let mut planned = 0usize;

        // Admit: fill every free slot while the queue has work and the
        // memory gate passes. A misframed or faulting admission consumes
        // its request (an `EngineFault` completion), not the slot — keep
        // trying the queue until the slot is filled or the queue is
        // empty.
        'admit: for si in 0..self.slots.len() {
            if self.slots[si].is_some() {
                continue;
            }
            while !self.queue.is_empty() {
                if kv_free.is_some_and(|free| worst + need_live + planned > free) {
                    break 'admit;
                }
                let Some(p) = self.queue.pop_front() else { break };
                if p.row.len() != self.engine.slot_seq_len() {
                    self.stats.faulted += 1;
                    if let Some(o) = &self.obs {
                        o.faulted.inc();
                    }
                    done.push(Completion {
                        id: p.id,
                        slot: None,
                        result: Err(ServeError::EngineFault(format!(
                            "request {}: {} tokens, slots are {}-framed",
                            p.id,
                            p.row.len(),
                            self.engine.slot_seq_len()
                        ))),
                    });
                    continue;
                }
                let engine = self.engine;
                let t_admit = self.obs.is_some().then(Instant::now);
                let admitted = catch_unwind(AssertUnwindSafe(|| engine.admit(&p.row)));
                if let (Some(o), Some(t)) = (&self.obs, t_admit) {
                    o.admit_seconds.observe(t.elapsed().as_secs_f64());
                }
                match admitted {
                    Ok(Ok(slot)) => {
                        let ticket = self.admit_seq;
                        self.admit_seq += 1;
                        let requeued = p.requeued;
                        self.slots[si] = Some(Live {
                            id: p.id,
                            slot,
                            row: p.row,
                            limits: p.limits,
                            submit_step: p.submit_step,
                            new_tokens: 0,
                            admit_seq: ticket,
                        });
                        self.stats.admitted += 1;
                        planned += worst;
                        if let Some(o) = &self.obs {
                            o.admitted.inc();
                        }
                        if requeued {
                            self.stats.requeued += 1;
                            if let Some(o) = &self.obs {
                                o.requeued.inc();
                            }
                        }
                        break;
                    }
                    Ok(Err(e)) => {
                        self.stats.faulted += 1;
                        if let Some(o) = &self.obs {
                            o.faulted.inc();
                        }
                        done.push(Completion {
                            id: p.id,
                            slot: None,
                            result: Err(ServeError::EngineFault(format!(
                                "admission failed: {e:#}"
                            ))),
                        });
                    }
                    Err(payload) => {
                        self.stats.faulted += 1;
                        if let Some(o) = &self.obs {
                            o.faulted.inc();
                        }
                        done.push(Completion {
                            id: p.id,
                            slot: None,
                            result: Err(ServeError::EngineFault(format!(
                                "admission panicked: {}",
                                panic_message(payload.as_ref())
                            ))),
                        });
                    }
                }
            }
        }

        // Pre-step retire: only admissions that are complete on arrival
        // (e.g. a seq_len-1 buffer, or EOS aliased to BOS/PAD) — slots
        // finished by a step were retired at the end of that tick.
        done.extend(self.retire_complete());

        // Preemption-by-eviction: live slots reserve only their next
        // step's pages, so the pool can run dry mid-decode once several
        // slots cross page boundaries together. Recover by evicting the
        // youngest-admitted live slot back to the queue: its pages
        // return to the pool and the request re-prefills on
        // re-admission (deterministic decode makes the replay
        // bit-identical). The oldest slot always keeps its pages — a
        // lone slot's worst case fits the budget (anything bigger was
        // shed above), so the batcher can always make progress.
        while let Some(free) = self.engine.kv_stats().and_then(|s| s.free_bytes) {
            let need: usize = self
                .slots
                .iter()
                .flatten()
                .map(|l| self.engine.slot_next_step_bytes(&l.slot))
                .sum();
            if need <= free || self.slots.iter().flatten().count() <= 1 {
                break;
            }
            let victim = (0..self.slots.len())
                .filter(|&i| self.slots[i].is_some())
                .max_by_key(|&i| self.slots[i].as_ref().map(|l| l.admit_seq));
            let Some(mut l) = victim.and_then(|vi| self.slots[vi].take()) else { break };
            self.engine.release_slot(&mut l.slot);
            self.stats.preempted += 1;
            if let Some(o) = &self.obs {
                o.preempted.inc();
            }
            // Requeue at the id-sorted position: the queue stays in
            // submission order, so the victim re-admits before anything
            // that arrived after it (longest waiting first).
            let pos = self.queue.iter().position(|q| q.id > l.id).unwrap_or(self.queue.len());
            self.queue.insert(
                pos,
                Pending {
                    id: l.id,
                    row: l.row,
                    limits: l.limits,
                    submit_step: l.submit_step,
                    requeued: true,
                },
            );
        }

        // Step whatever is live, in ascending slot order (slot
        // independence makes the order bit-irrelevant; fixing it keeps
        // traces reproducible). The whole batch steps under
        // `catch_unwind`; a failure is attributed below.
        let live_idx: Vec<usize> =
            (0..self.slots.len()).filter(|&i| self.slots[i].is_some()).collect();
        if live_idx.is_empty() {
            self.note_gauges();
            return done;
        }
        let occupied = live_idx.len();
        let t_step = self.obs.is_some().then(Instant::now);
        let batch_result = {
            let engine = self.engine;
            let mut live: Vec<&mut E::Slot> =
                self.slots.iter_mut().filter_map(|e| e.as_mut().map(|l| &mut l.slot)).collect();
            catch_unwind(AssertUnwindSafe(move || engine.step(&mut live)))
        };
        if !matches!(batch_result, Ok(Ok(()))) {
            // Fault attribution: re-step each live slot alone (engines
            // must leave failed slots re-steppable — the SlotEngine
            // contract) and retire the ones that fail with EngineFault.
            // Healthy slots advance exactly one step either way, so
            // their outputs stay bit-identical to a fault-free run.
            for &si in &live_idx {
                let solo = {
                    let engine = self.engine;
                    let Some(l) = self.slots[si].as_mut() else { continue };
                    let slot = &mut l.slot;
                    catch_unwind(AssertUnwindSafe(move || engine.step(&mut [slot])))
                };
                let msg = match solo {
                    Ok(Ok(())) => continue,
                    Ok(Err(e)) => format!("step failed: {e:#}"),
                    Err(payload) => format!("step panicked: {}", panic_message(payload.as_ref())),
                };
                if let Some(mut l) = self.slots[si].take() {
                    self.engine.release_slot(&mut l.slot);
                    self.stats.faulted += 1;
                    if let Some(o) = &self.obs {
                        o.faulted.inc();
                    }
                    done.push(Completion {
                        id: l.id,
                        slot: Some(si),
                        result: Err(ServeError::EngineFault(msg)),
                    });
                }
            }
        }
        self.stats.steps += 1;
        self.stats.occupied_slot_steps += occupied;
        if let (Some(o), Some(t)) = (&self.obs, t_step) {
            o.step_seconds.observe(t.elapsed().as_secs_f64());
            o.steps.inc();
            o.occupied.add(occupied as u64);
        }
        for l in self.slots.iter_mut().flatten() {
            l.new_tokens += 1;
        }

        // Retire: free completed slots for the next tick's admissions.
        done.extend(self.retire_complete());
        self.note_gauges();
        done
    }

    /// Refresh the queue-depth / live-slot / occupancy gauges (called at
    /// every [`tick`](Self::tick) exit).
    fn note_gauges(&self) {
        if let Some(o) = &self.obs {
            o.queue_depth.set(self.queue.len() as f64);
            o.live_slots.set(self.slots.iter().filter(|s| s.is_some()).count() as f64);
            o.occupancy.set(self.stats.occupancy(self.capacity));
            if let Some(kv) = self.engine.kv_stats() {
                o.kv_resident_bytes.set(kv.resident_bytes as f64);
                if let Some(fp) = kv.free_pages {
                    o.kv_pages_free.set(fp as f64);
                }
            }
        }
    }

    /// Take every complete slot out of the table (freeing it for reuse)
    /// and return the completions in ascending slot order. A slot whose
    /// `max_new_tokens` budget is spent retires **successfully** with
    /// whatever it decoded (truncation, not an error).
    fn retire_complete(&mut self) -> Vec<Completion> {
        let mut done = Vec::new();
        for si in 0..self.slots.len() {
            let (complete, truncated) = match &self.slots[si] {
                Some(l) => {
                    let natural = self.engine.slot_complete(&l.slot);
                    let budget_spent =
                        l.limits.max_new_tokens.is_some_and(|m| l.new_tokens >= m);
                    (natural || budget_spent, budget_spent && !natural)
                }
                None => (false, false),
            };
            if !complete {
                continue;
            }
            if let Some(mut l) = self.slots[si].take() {
                self.stats.retired += 1;
                if let Some(o) = &self.obs {
                    o.retired.inc();
                }
                if truncated {
                    self.stats.truncated += 1;
                }
                let out = self.engine.slot_output(&l.slot);
                // Output first, then pages back to the pool (retirement
                // is where the engine's leak check runs).
                self.engine.release_slot(&mut l.slot);
                done.push(Completion { id: l.id, slot: Some(si), result: Ok(out) });
            }
        }
        done
    }

    /// Tick until nothing is live or queued; returns every completion in
    /// retirement order. A slot that never completes (a stalled engine)
    /// spins forever unless it carries a deadline — serve loops set a
    /// default deadline for exactly this reason.
    pub fn run_until_drained(&mut self) -> Vec<Completion> {
        let mut out = Vec::new();
        while !self.idle() {
            out.extend(self.tick());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scripted mock engine: no model, no clock. A request row encodes
    /// its own lifecycle — `row[0]` is the number of decode steps until
    /// EOS, `row[1]` a tag echoed in the output — so arrival/length
    /// traces are fully deterministic.
    struct ScriptEngine {
        seq: usize,
    }

    struct ScriptSlot {
        need: usize,
        len: usize,
        tag: i32,
    }

    impl SlotEngine for ScriptEngine {
        type Slot = ScriptSlot;

        fn slot_seq_len(&self) -> usize {
            self.seq
        }

        fn admit(&self, src_row: &[i32]) -> anyhow::Result<ScriptSlot> {
            anyhow::ensure!(src_row.len() == self.seq, "framing");
            Ok(ScriptSlot { need: src_row[0] as usize, len: 0, tag: src_row[1] })
        }

        fn step(&self, slots: &mut [&mut ScriptSlot]) -> anyhow::Result<()> {
            for s in slots.iter_mut() {
                s.len += 1;
            }
            Ok(())
        }

        fn slot_complete(&self, s: &ScriptSlot) -> bool {
            s.len >= s.need || s.len + 1 >= self.seq
        }

        fn slot_output(&self, s: &ScriptSlot) -> Vec<i32> {
            vec![s.tag, s.len as i32]
        }
    }

    fn req(need: usize, tag: i32, seq: usize) -> Vec<i32> {
        let mut r = vec![0; seq];
        r[0] = need as i32;
        r[1] = tag;
        r
    }

    fn ok_tokens(c: &Completion) -> Vec<i32> {
        c.result.clone().unwrap_or_else(|e| panic!("request {} failed: {e}", c.id))
    }

    #[test]
    fn fifo_admission_and_capacity_never_exceeded() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 2);
        for i in 0..5 {
            b.submit(req(3, i, 16)).unwrap();
        }
        assert_eq!(b.pending(), 5);
        let mut completions = Vec::new();
        for _ in 0..30 {
            assert!(b.live() <= 2, "live slots exceed capacity");
            completions.extend(b.tick());
            assert!(b.live() <= 2, "live slots exceed capacity after tick");
            if b.idle() {
                break;
            }
        }
        assert!(b.idle(), "trace must drain");
        // Equal-length requests: FIFO admission implies FIFO completion.
        let ids: Vec<u64> = completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4], "FIFO admission order");
        assert_eq!(b.stats().admitted, 5);
        assert_eq!(b.stats().retired, 5);
    }

    #[test]
    fn slot_reuse_after_retirement() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 3);
        // Slot 0 retires first (1 step), slots 1/2 run long.
        b.submit(req(1, 10, 16)).unwrap();
        b.submit(req(6, 11, 16)).unwrap();
        b.submit(req(6, 12, 16)).unwrap();
        let first = b.tick();
        assert_eq!(first.len(), 1);
        assert_eq!(first[0].id, 0);
        assert_eq!(first[0].slot, Some(0), "short request lived in slot 0");
        // The next request must land in the freed slot 0, not a new one.
        b.submit(req(1, 13, 16)).unwrap();
        let second = b.tick();
        assert_eq!(second.len(), 1);
        assert_eq!(second[0].id, 3);
        assert_eq!(second[0].slot, Some(0), "retired slot is reused");
        assert_eq!(b.live(), 2, "long requests still hold slots 1 and 2");
    }

    #[test]
    fn long_requests_are_never_starved() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 2);
        let long_id = b.submit(req(6, 99, 16)).unwrap();
        // A stream of short requests arrives every tick; the long request
        // keeps its slot (no preemption) and completes on schedule.
        let mut long_done_at = None;
        for tick in 1..=10 {
            b.submit(req(1, tick, 16)).unwrap();
            for c in b.tick() {
                if c.id == long_id {
                    long_done_at = Some(tick);
                }
            }
        }
        assert_eq!(long_done_at, Some(6), "6-step request completes at tick 6");
    }

    #[test]
    fn empty_queue_idle_tick_is_a_noop() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 4);
        assert!(b.idle());
        assert_eq!(b.tick(), Vec::new());
        assert_eq!(b.stats().steps, 0, "idle tick executes no decode step");
        assert_eq!(b.occupancy(), 0.0);
        // ... and the batcher still works after idling.
        b.submit(req(2, 7, 16)).unwrap();
        assert!(!b.idle());
        let out = b.run_until_drained();
        assert_eq!(out.len(), 1);
        assert_eq!(ok_tokens(&out[0]), vec![7, 2]);
        assert_eq!(b.stats().steps, 2);
    }

    #[test]
    fn backlogged_trace_keeps_slots_occupied() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 3);
        for i in 0..9 {
            b.submit(req(4, i, 16)).unwrap();
        }
        let out = b.run_until_drained();
        assert_eq!(out.len(), 9);
        // Equal 4-step lifecycles in cohorts of 3: every step runs a full
        // batch, so occupancy is exactly 1.
        assert_eq!(b.stats().steps, 12);
        assert!((b.occupancy() - 1.0).abs() < 1e-12, "occupancy {}", b.occupancy());
    }

    #[test]
    fn staggered_arrivals_mix_slot_ages() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 3);
        // Arrivals staggered across ticks; lengths differ, so admissions
        // backfill mid-decode and the batch holds mixed-age slots.
        b.submit(req(2, 0, 16)).unwrap();
        b.submit(req(5, 1, 16)).unwrap();
        let mut completions = Vec::new();
        for t in 0..12 {
            if t == 1 {
                b.submit(req(2, 2, 16)).unwrap();
            }
            if t == 3 {
                b.submit(req(1, 3, 16)).unwrap();
            }
            completions.extend(b.tick());
            if b.idle() {
                break;
            }
        }
        assert_eq!(completions.len(), 4);
        // The long request (id 1) outlives later arrivals: 2 and 3
        // complete before it — continuous batching, not head-of-line.
        let pos = |id: u64| completions.iter().position(|c| c.id == id).unwrap();
        assert!(pos(2) < pos(1) && pos(3) < pos(1), "later short requests finish first");
        assert_eq!(b.stats().admitted, 4);
        assert_eq!(b.stats().retired, 4);
        assert!(b.occupancy() > 0.5, "occupancy {}", b.occupancy());
    }

    #[test]
    fn born_complete_admissions_retire_without_stepping() {
        // A slot that is complete the moment it is admitted (need = 0 —
        // the mock twin of a seq_len-1 buffer or EOS-aliased framing)
        // must be retired before the step batch forms, never stepped.
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 2);
        b.submit(req(0, 41, 16)).unwrap();
        let out = b.tick();
        assert_eq!(out.len(), 1);
        assert_eq!(ok_tokens(&out[0]), vec![41, 0], "retired at age 0: never stepped");
        assert_eq!(b.stats().steps, 0, "no live work, no decode step");
        assert!(b.idle());
        // Mixed with a real request, the degenerate one still skips the
        // step batch while the live one decodes normally.
        b.submit(req(0, 42, 16)).unwrap();
        b.submit(req(2, 43, 16)).unwrap();
        let first = b.tick();
        assert_eq!(first.len(), 1, "only the born-complete request retires this tick");
        assert_eq!(ok_tokens(&first[0]), vec![42, 0]);
        let rest = b.run_until_drained();
        assert_eq!(rest.len(), 1);
        assert_eq!(ok_tokens(&rest[0]), vec![43, 2], "the live request stepped to completion");
    }

    #[test]
    fn rejects_misframed_requests_without_dying() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 1);
        b.submit(vec![1, 2, 3]).unwrap(); // not seq_len-framed
        b.submit(req(1, 50, 16)).unwrap(); // healthy follower
        let out = b.run_until_drained();
        assert_eq!(out.len(), 2);
        assert!(
            matches!(&out[0].result, Err(ServeError::EngineFault(_))),
            "misframed request retires as EngineFault, got {:?}",
            out[0].result
        );
        assert_eq!(out[0].slot, None, "never reached a slot");
        assert_eq!(ok_tokens(&out[1]), vec![50, 1], "the healthy request still serves");
        assert_eq!(b.stats().faulted, 1);
        assert_eq!(b.stats().retired, 1);
    }

    #[test]
    fn shed_on_full_queue() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 1).with_queue_limit(2);
        assert_eq!(b.submit(req(3, 0, 16)), Ok(0));
        assert_eq!(b.submit(req(3, 1, 16)), Ok(1));
        // Queue is at its bound: the third submission sheds, and the id
        // space records the rejection nowhere (no ghost completions).
        assert_eq!(b.submit(req(3, 2, 16)), Err(ServeError::Overloaded));
        assert_eq!(b.stats().shed, 1);
        assert_eq!(b.pending(), 2);
        // Ticking admits one (freeing queue room): submission works again.
        let _ = b.tick();
        assert_eq!(b.submit(req(3, 3, 16)), Ok(2), "queue drained below the bound");
        let out = b.run_until_drained();
        let served: Vec<u64> = out.iter().filter(|c| c.result.is_ok()).map(|c| c.id).collect();
        assert_eq!(served, vec![0, 1, 2], "accepted requests all complete, FIFO");
        assert_eq!(b.stats().shed, 1, "exactly one shed");
    }

    #[test]
    fn deadline_expiry_retires_in_ascending_slot_order() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 3);
        let limits = RequestLimits::none().with_deadline(2);
        // Three long requests that cannot finish within 2 steps, plus a
        // queued fourth that inherits the freed capacity.
        for i in 0..3 {
            b.submit_with(req(10, i, 16), limits).unwrap();
        }
        b.submit(req(1, 3, 16)).unwrap();
        assert!(b.tick().is_empty(), "step 1: nothing expires, nothing completes");
        assert!(b.tick().is_empty(), "step 2: deadline not yet elapsed at tick start");
        // Tick 3 starts at steps == 2: all three live slots are expired,
        // in ascending slot order, and the queued request is admitted
        // into freed capacity in the same tick.
        let out = b.tick();
        let expired: Vec<(u64, Option<usize>)> = out
            .iter()
            .filter(|c| c.result == Err(ServeError::DeadlineExceeded))
            .map(|c| (c.id, c.slot))
            .collect();
        assert_eq!(
            expired,
            vec![(0, Some(0)), (1, Some(1)), (2, Some(2))],
            "expiry retires in ascending slot order"
        );
        let served: Vec<u64> = out.iter().filter(|c| c.result.is_ok()).map(|c| c.id).collect();
        assert_eq!(served, vec![3], "freed capacity admits + completes the 1-step request");
        assert_eq!(b.stats().expired, 3);
        assert_eq!(b.stats().retired, 1);
        assert!(b.idle());
    }

    #[test]
    fn deadline_expires_queued_requests_too() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 1);
        b.submit(req(5, 0, 16)).unwrap(); // occupies the only slot
        b.submit_with(req(1, 1, 16), RequestLimits::none().with_deadline(2)).unwrap();
        let mut outcomes = Vec::new();
        while !b.idle() {
            outcomes.extend(b.tick());
        }
        let queued_victim = outcomes.iter().find(|c| c.id == 1).expect("one outcome per request");
        assert_eq!(queued_victim.result, Err(ServeError::DeadlineExceeded));
        assert_eq!(queued_victim.slot, None, "expired while queued: never held a slot");
        assert!(outcomes.iter().any(|c| c.id == 0 && c.result.is_ok()));
        assert_eq!(b.stats().expired, 1);
    }

    #[test]
    fn max_new_tokens_truncates_successfully() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 1);
        b.submit_with(req(10, 9, 16), RequestLimits::none().with_max_new_tokens(3)).unwrap();
        let out = b.run_until_drained();
        assert_eq!(out.len(), 1);
        assert_eq!(ok_tokens(&out[0]), vec![9, 3], "stopped after 3 generated tokens");
        assert_eq!(b.stats().steps, 3);
        assert_eq!(b.stats().retired, 1);
        assert_eq!(b.stats().truncated, 1, "budget-capped retirement is counted");
        assert_eq!(b.stats().expired, 0, "truncation is success, not expiry");
    }

    #[test]
    fn drain_mode_rejects_admissions_but_finishes_backlog() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 1);
        b.submit(req(2, 0, 16)).unwrap();
        b.submit(req(2, 1, 16)).unwrap();
        b.begin_drain();
        assert!(b.draining());
        assert_eq!(b.submit(req(1, 2, 16)), Err(ServeError::Overloaded), "draining sheds");
        let out = b.run_until_drained();
        let served: Vec<u64> = out.iter().filter(|c| c.result.is_ok()).map(|c| c.id).collect();
        assert_eq!(served, vec![0, 1], "queued and live work still completes");
        assert_eq!(b.stats().shed, 1);
        assert!(b.idle());
        // Accounting identity at drain: every submission has one outcome.
        let s = b.stats();
        assert_eq!(3, s.retired + s.shed + s.expired + s.cancelled + s.faulted);
    }

    #[test]
    fn cancel_retires_live_slot_and_queued_request() {
        // The slot-leak regression: a live request whose client vanished
        // must free its slot instead of stepping to EOS for nobody.
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 1);
        let live_id = b.submit(req(10, 0, 16)).unwrap();
        let queued_id = b.submit(req(1, 1, 16)).unwrap();
        let _ = b.tick(); // admits live_id into slot 0
        assert_eq!(b.live(), 1);
        assert!(b.cancel(live_id), "live slot cancels");
        assert_eq!(b.live(), 0, "slot freed immediately, no step to EOS");
        assert!(!b.cancel(live_id), "cancel is idempotent per id");
        // The freed slot serves the queued request on the next tick.
        let out = b.run_until_drained();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, queued_id);
        assert!(out[0].result.is_ok());
        // Cancelling a queued request removes it before admission.
        let q = b.submit(req(5, 2, 16)).unwrap();
        assert!(b.cancel(q));
        assert!(b.idle(), "cancelled queue entry never admits");
        assert_eq!(b.stats().cancelled, 2);
    }

    /// Engine whose step fails (Err or panic) whenever a slot with a
    /// negative tag is in the batch — the minimal poisoned-request twin
    /// of `testkit::faultkit` for isolation unit tests.
    struct PoisonEngine {
        seq: usize,
        panics: bool,
    }

    impl SlotEngine for PoisonEngine {
        type Slot = ScriptSlot;

        fn slot_seq_len(&self) -> usize {
            self.seq
        }

        fn admit(&self, src_row: &[i32]) -> anyhow::Result<ScriptSlot> {
            Ok(ScriptSlot { need: src_row[0] as usize, len: 0, tag: src_row[1] })
        }

        fn step(&self, slots: &mut [&mut ScriptSlot]) -> anyhow::Result<()> {
            // Fail *before* mutating anything: slots stay re-steppable.
            if slots.iter().any(|s| s.tag < 0) {
                if self.panics {
                    panic!("poisoned tag in batch");
                }
                anyhow::bail!("poisoned tag in batch");
            }
            for s in slots.iter_mut() {
                s.len += 1;
            }
            Ok(())
        }

        fn slot_complete(&self, s: &ScriptSlot) -> bool {
            s.len >= s.need || s.len + 1 >= self.seq
        }

        fn slot_output(&self, s: &ScriptSlot) -> Vec<i32> {
            vec![s.tag, s.len as i32]
        }
    }

    #[test]
    fn step_fault_is_isolated_to_the_poisoned_slot() {
        for panics in [false, true] {
            let e = PoisonEngine { seq: 16, panics };
            let mut b = ContinuousBatcher::new(&e, 3);
            b.submit(req(3, 7, 16)).unwrap(); // healthy
            let mut poison = req(3, 0, 16);
            poison[1] = -1; // poisoned tag
            let bad = b.submit(poison).unwrap();
            b.submit(req(3, 8, 16)).unwrap(); // healthy
            let out = b.run_until_drained();
            assert_eq!(out.len(), 3, "every request gets exactly one outcome");
            let fault = out.iter().find(|c| c.id == bad).unwrap();
            assert!(
                matches!(&fault.result, Err(ServeError::EngineFault(m)) if m.contains("poisoned")),
                "poisoned request retires as EngineFault (panics={panics}): {:?}",
                fault.result
            );
            // The healthy slots finish with exactly the outputs a
            // fault-free run produces: 3 steps, their own tags.
            let mut healthy: Vec<Vec<i32>> =
                out.iter().filter(|c| c.result.is_ok()).map(ok_tokens).collect();
            healthy.sort();
            assert_eq!(healthy, vec![vec![7, 3], vec![8, 3]], "panics={panics}");
            assert_eq!(b.stats().faulted, 1);
            assert_eq!(b.stats().retired, 2);
        }
    }

    #[test]
    fn accounting_identity_over_a_mixed_trace() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 2).with_queue_limit(2);
        let mut submitted = 0usize;
        let mut outcomes = 0usize;
        let mut cancelled_by_us = 0usize;
        for i in 0..10 {
            let limits = if i % 3 == 0 {
                RequestLimits::none().with_deadline(1)
            } else {
                RequestLimits::none()
            };
            match b.submit_with(req(4, i, 16), limits) {
                Ok(id) => {
                    submitted += 1;
                    if i == 4 && b.cancel(id) {
                        cancelled_by_us += 1;
                    }
                }
                Err(ServeError::Overloaded) => {
                    submitted += 1;
                    outcomes += 1; // the shed IS the outcome
                }
                Err(e) => panic!("unexpected submit error {e}"),
            }
            outcomes += b.tick().len();
        }
        outcomes += b.run_until_drained().len();
        outcomes += cancelled_by_us;
        assert_eq!(outcomes, submitted, "every submission gets exactly one terminal outcome");
        let s = b.stats();
        assert_eq!(
            submitted,
            s.retired + s.shed + s.expired + s.cancelled + s.faulted,
            "stats balance: {s:?}"
        );
    }

    #[test]
    fn registry_mirror_matches_batcher_stats() {
        let _gate = crate::obs::test_gate().read().unwrap_or_else(|e| e.into_inner());
        use crate::obs::{key, Obs};
        let obs = Obs::fresh();
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 2).with_queue_limit(2).with_obs(&obs);
        let mut submitted = 0usize;
        for i in 0..8 {
            let limits = if i % 3 == 0 {
                RequestLimits::none().with_deadline(1)
            } else {
                RequestLimits::none()
            };
            match b.submit_with(req(3, i, 16), limits) {
                Ok(id) => {
                    submitted += 1;
                    if i == 4 {
                        b.cancel(id);
                    }
                }
                Err(ServeError::Overloaded) => submitted += 1,
                Err(e) => panic!("unexpected submit error {e}"),
            }
            b.tick();
        }
        b.run_until_drained();
        let s = b.stats().clone();
        let snap = obs.registry().snapshot();
        let out = |o: &str| snap.counter(&key("batcher_outcomes_total", &[("outcome", o)]));
        assert_eq!(snap.counter("batcher_submitted_total"), submitted as u64);
        assert_eq!(out("retired"), s.retired as u64);
        assert_eq!(out("shed"), s.shed as u64);
        assert_eq!(out("expired"), s.expired as u64);
        assert_eq!(out("cancelled"), s.cancelled as u64);
        assert_eq!(out("faulted"), s.faulted as u64);
        assert_eq!(snap.counter("batcher_admitted_total"), s.admitted as u64);
        assert_eq!(snap.counter("batcher_decode_steps_total"), s.steps as u64);
        assert_eq!(
            snap.counter("batcher_occupied_slot_steps_total"),
            s.occupied_slot_steps as u64
        );
        // The exported identity holds exactly.
        assert_eq!(
            snap.counter("batcher_submitted_total"),
            out("retired") + out("shed") + out("expired") + out("cancelled") + out("faulted"),
            "exported accounting identity"
        );
        // Gauges settle at idle: nothing queued, nothing live.
        assert_eq!(snap.gauge("batcher_queue_depth"), 0.0);
        assert_eq!(snap.gauge("batcher_live_slots"), 0.0);
        assert_eq!(snap.gauge("batcher_capacity"), 2.0);
        assert!((snap.gauge("batcher_occupancy") - b.occupancy()).abs() < 1e-12);
        // Step timing recorded once per decode step.
        let steps = snap.histograms.get("batcher_step_seconds").expect("step histogram");
        assert_eq!(steps.count, s.steps as u64);
        let admits = snap.histograms.get("batcher_admit_seconds").expect("admit histogram");
        assert_eq!(admits.count, s.admitted as u64);
    }

    /// Mock engine with a byte-accounted page pool: every live slot
    /// consumes `page` bytes per decode step (allocated inside the step,
    /// like the native backend's lazy page-ensure pre-pass), so memory
    /// pressure builds deterministically with no model anywhere.
    struct MemEngine {
        seq: usize,
        /// Bytes one slot allocates per step.
        page: usize,
        budget: usize,
        /// Reported worst-case demand per slot.
        worst: usize,
        used: std::cell::Cell<usize>,
    }

    struct MemSlot {
        need: usize,
        len: usize,
        tag: i32,
        held: usize,
    }

    impl SlotEngine for MemEngine {
        type Slot = MemSlot;

        fn slot_seq_len(&self) -> usize {
            self.seq
        }

        fn admit(&self, src_row: &[i32]) -> anyhow::Result<MemSlot> {
            anyhow::ensure!(src_row.len() == self.seq, "framing");
            Ok(MemSlot { need: src_row[0] as usize, len: 0, tag: src_row[1], held: 0 })
        }

        fn step(&self, slots: &mut [&mut MemSlot]) -> anyhow::Result<()> {
            // Check the whole batch before mutating anything: a failed
            // batch stays re-steppable (the SlotEngine contract).
            let want = slots.len() * self.page;
            anyhow::ensure!(
                self.used.get() + want <= self.budget,
                "mock pool exhausted: {} used + {want} wanted > {} budget",
                self.used.get(),
                self.budget
            );
            for s in slots.iter_mut() {
                self.used.set(self.used.get() + self.page);
                s.held += self.page;
                s.len += 1;
            }
            Ok(())
        }

        fn slot_complete(&self, s: &MemSlot) -> bool {
            s.len >= s.need || s.len + 1 >= self.seq
        }

        fn slot_output(&self, s: &MemSlot) -> Vec<i32> {
            vec![s.tag, s.len as i32]
        }

        fn kv_stats(&self) -> Option<crate::runtime::KvMemStats> {
            let free = self.budget - self.used.get();
            Some(crate::runtime::KvMemStats {
                budget_bytes: Some(self.budget),
                free_bytes: Some(free),
                free_pages: Some(free / self.page.max(1)),
                resident_bytes: self.used.get(),
            })
        }

        fn slot_worst_bytes(&self) -> usize {
            self.worst
        }

        fn slot_next_step_bytes(&self, s: &MemSlot) -> usize {
            if self.slot_complete(s) {
                0
            } else {
                self.page
            }
        }

        fn release_slot(&self, s: &mut MemSlot) {
            self.used.set(self.used.get() - s.held);
            s.held = 0;
        }
    }

    #[test]
    fn memory_pressure_preempts_youngest_and_replays_bit_identically() {
        // Budget fits ~1.5 worst cases: three 4-step requests cannot all
        // run concurrently, so the batcher must evict under pressure and
        // re-prefill — and every output must still equal the
        // no-pressure run's `[tag, 4]`.
        let e = MemEngine { seq: 16, page: 1, budget: 6, worst: 4, used: std::cell::Cell::new(0) };
        let mut b = ContinuousBatcher::new(&e, 3);
        for i in 0..3 {
            b.submit(req(4, i, 16)).unwrap();
        }
        let out = b.run_until_drained();
        assert_eq!(out.len(), 3, "every request completes exactly once");
        let ids: Vec<u64> = out.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![0, 1, 2], "requeue preserves submission order");
        for (i, c) in out.iter().enumerate() {
            assert_eq!(ok_tokens(c), vec![i as i32, 4], "replayed decode is bit-identical");
        }
        let s = b.stats();
        assert!(s.preempted >= 1, "the tight budget must force eviction: {s:?}");
        assert_eq!(s.requeued, s.preempted, "every victim was re-admitted");
        assert_eq!(s.admitted, 3 + s.requeued, "re-admissions run a fresh encoder pass");
        assert_eq!(s.retired, 3);
        assert_eq!(3, s.retired + s.shed + s.expired + s.cancelled + s.faulted, "identity: {s:?}");
        assert_eq!(e.used.get(), 0, "zero bytes leaked after the trace");
    }

    #[test]
    fn admission_is_bounded_by_bytes_not_slot_count() {
        // Free slots exist, but only two worst cases fit the budget: the
        // third request waits in the queue, unshed.
        let e = MemEngine { seq: 16, page: 1, budget: 8, worst: 4, used: std::cell::Cell::new(0) };
        let mut b = ContinuousBatcher::new(&e, 3);
        for i in 0..3 {
            b.submit(req(2, i, 16)).unwrap();
        }
        b.tick();
        assert_eq!(b.live(), 2, "byte budget admits two despite three free slots");
        assert_eq!(b.pending(), 1, "the third queues instead of shedding");
        assert_eq!(b.stats().shed, 0);
        let out = b.run_until_drained();
        assert_eq!(out.len(), 3, "the queued request is served once pages free up");
        assert!(out.iter().all(|c| c.result.is_ok()));
        assert_eq!(e.used.get(), 0);
    }

    #[test]
    fn oversized_requests_are_shed_not_queued_forever() {
        // worst > budget: no amount of waiting can ever admit these.
        let e = MemEngine { seq: 16, page: 1, budget: 3, worst: 4, used: std::cell::Cell::new(0) };
        let mut b = ContinuousBatcher::new(&e, 2);
        b.submit(req(2, 0, 16)).unwrap();
        b.submit(req(2, 1, 16)).unwrap();
        let out = b.tick();
        assert_eq!(out.len(), 2);
        assert!(
            out.iter().all(|c| c.result == Err(ServeError::Overloaded)),
            "never-fits requests shed Overloaded: {out:?}"
        );
        assert_eq!(b.stats().shed, 2);
        assert_eq!(b.stats().preempted, 0);
        assert!(b.idle());
    }

    #[test]
    fn kv_gauges_and_preemption_counters_export() {
        let _gate = crate::obs::test_gate().read().unwrap_or_else(|e| e.into_inner());
        use crate::obs::{key, Obs};
        let obs = Obs::fresh();
        let e = MemEngine { seq: 16, page: 1, budget: 6, worst: 4, used: std::cell::Cell::new(0) };
        let mut b = ContinuousBatcher::new(&e, 3).with_obs(&obs);
        for i in 0..3 {
            b.submit(req(4, i, 16)).unwrap();
        }
        b.run_until_drained();
        let s = b.stats().clone();
        assert!(s.preempted >= 1, "trace must preempt: {s:?}");
        let snap = obs.registry().snapshot();
        assert_eq!(snap.counter("batcher_preempted_total"), s.preempted as u64);
        assert_eq!(snap.counter("batcher_requeued_total"), s.requeued as u64);
        assert_eq!(snap.gauge("kv_resident_bytes"), 0.0, "idle pool holds nothing");
        assert_eq!(snap.gauge("kv_pages_free"), 6.0, "whole budget free at idle");
        // Preemption is non-terminal: the exported identity still holds.
        let out = |o: &str| snap.counter(&key("batcher_outcomes_total", &[("outcome", o)]));
        assert_eq!(
            snap.counter("batcher_submitted_total"),
            out("retired") + out("shed") + out("expired") + out("cancelled") + out("faulted"),
        );
    }

    #[test]
    fn batcher_keys_parse() {
        for k in [Batcher::Static, Batcher::Continuous] {
            assert_eq!(Batcher::parse(k.key()), Some(k));
        }
        assert_eq!(Batcher::default(), Batcher::Static);
        assert_eq!(Batcher::parse("vllm"), None);
    }
}
