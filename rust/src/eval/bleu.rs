//! Corpus-level BLEU (Papineni et al. 2002), the paper's accuracy metric.
//!
//! Standard BLEU-4: modified n-gram precision with clipping, geometric
//! mean over n = 1..=4, multiplied by the brevity penalty. Scores are on
//! the 0-100 scale the paper plots. Token sequences are integer ids (the
//! synthetic languages have no sub-word segmentation).

use std::collections::HashMap;

/// Per-order statistics plus the final score.
#[derive(Debug, Clone)]
pub struct BleuDetail {
    /// Clipped n-gram matches / candidate n-gram counts, n = 1..=4.
    pub precisions: [f64; 4],
    pub brevity_penalty: f64,
    pub hyp_len: usize,
    pub ref_len: usize,
    /// 0-100.
    pub score: f64,
}

fn ngram_counts(seq: &[i32], n: usize) -> HashMap<&[i32], usize> {
    let mut m: HashMap<&[i32], usize> = HashMap::new();
    if seq.len() >= n {
        for w in seq.windows(n) {
            *m.entry(w).or_insert(0) += 1;
        }
    }
    m
}

/// Corpus-level BLEU-4 of `hyps` against single references `refs`.
///
/// Uses the "add-epsilon-free" corpus formulation: match/total counts are
/// accumulated over the whole corpus before taking precisions, so
/// individual empty sentences do not zero the score.
pub fn bleu_score(hyps: &[Vec<i32>], refs: &[Vec<i32>]) -> BleuDetail {
    assert_eq!(hyps.len(), refs.len(), "hyp/ref count mismatch");
    let mut matches = [0usize; 4];
    let mut totals = [0usize; 4];
    let mut hyp_len = 0usize;
    let mut ref_len = 0usize;

    for (h, r) in hyps.iter().zip(refs) {
        hyp_len += h.len();
        ref_len += r.len();
        for n in 1..=4usize {
            let hc = ngram_counts(h, n);
            let rc = ngram_counts(r, n);
            for (gram, &count) in &hc {
                let clip = rc.get(gram).copied().unwrap_or(0);
                matches[n - 1] += count.min(clip);
            }
            totals[n - 1] += h.len().saturating_sub(n - 1);
        }
    }

    let mut precisions = [0.0f64; 4];
    for n in 0..4 {
        precisions[n] = if totals[n] == 0 { 0.0 } else { matches[n] as f64 / totals[n] as f64 };
    }

    let brevity_penalty = if hyp_len == 0 {
        0.0
    } else if hyp_len >= ref_len {
        1.0
    } else {
        (1.0 - ref_len as f64 / hyp_len as f64).exp()
    };

    let score = if precisions.iter().any(|&p| p == 0.0) {
        0.0
    } else {
        let log_mean = precisions.iter().map(|p| p.ln()).sum::<f64>() / 4.0;
        100.0 * brevity_penalty * log_mean.exp()
    };

    BleuDetail { precisions, brevity_penalty, hyp_len, ref_len, score }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_match_is_100() {
        let refs = vec![vec![1, 2, 3, 4, 5], vec![7, 8, 9, 10]];
        let d = bleu_score(&refs, &refs);
        assert!((d.score - 100.0).abs() < 1e-9, "{d:?}");
        assert_eq!(d.brevity_penalty, 1.0);
    }

    #[test]
    fn disjoint_is_zero() {
        let hyps = vec![vec![1, 2, 3, 4, 5]];
        let refs = vec![vec![6, 7, 8, 9, 10]];
        assert_eq!(bleu_score(&hyps, &refs).score, 0.0);
    }

    #[test]
    fn brevity_penalty_kicks_in() {
        // Hypothesis is a perfect prefix but half the length.
        let hyps = vec![vec![1, 2, 3, 4, 5]];
        let refs = vec![vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10]];
        let d = bleu_score(&hyps, &refs);
        assert!(d.brevity_penalty < 1.0);
        assert!(d.score > 0.0 && d.score < 100.0);
    }

    #[test]
    fn clipping_limits_repeats() {
        // "the the the the" against "the cat": unigram precision clipped
        // to 1/4, not 4/4 (the canonical BLEU clipping example).
        let hyps = vec![vec![7, 7, 7, 7]];
        let refs = vec![vec![7, 9]];
        let d = bleu_score(&hyps, &refs);
        assert!((d.precisions[0] - 0.25).abs() < 1e-12, "{:?}", d.precisions);
    }

    #[test]
    fn single_token_error_degrades_not_destroys() {
        let refs = vec![vec![1, 2, 3, 4, 5, 6, 7, 8], vec![1, 2, 3, 4, 5, 6, 7, 8]];
        let mut hyps = refs.clone();
        hyps[0][3] = 99;
        let d = bleu_score(&hyps, &refs);
        assert!(d.score > 50.0 && d.score < 100.0, "{}", d.score);
    }

    #[test]
    fn corpus_level_tolerates_one_empty_hyp() {
        let refs = vec![vec![1, 2, 3, 4, 5], vec![6, 7, 8, 9, 10]];
        let hyps = vec![vec![], vec![6, 7, 8, 9, 10]];
        let d = bleu_score(&hyps, &refs);
        assert!(d.score > 0.0, "corpus BLEU must survive an empty sentence");
    }

    #[test]
    fn monotone_in_corruption() {
        // Progressively corrupt more tokens; BLEU must not increase.
        let base: Vec<Vec<i32>> =
            (0..8).map(|i| (0..12).map(|j| (i * 12 + j) as i32 % 40 + 3).collect()).collect();
        let mut prev = 100.1;
        for frac in [0usize, 2, 4, 8] {
            let hyps: Vec<Vec<i32>> = base
                .iter()
                .map(|row| {
                    let mut r = row.clone();
                    for k in 0..frac.min(r.len()) {
                        r[k] = 999 + k as i32;
                    }
                    r
                })
                .collect();
            let s = bleu_score(&hyps, &base).score;
            assert!(s <= prev + 1e-9, "corruption {frac}: {s} > {prev}");
            prev = s;
        }
    }
}
