//! Compression method dispatch (the four contenders of §VIII-C).

use std::collections::BTreeMap;
use std::hash::{Hash, Hasher};

use crate::compress::{self, CompressedLinear, IncrementalItera, LayerCost};
use crate::model::{LinearInfo, Manifest, PairModel};
use crate::quant::WordLen;
use crate::runtime::{Mode, NativeBackend};
use crate::tensor::Matrix;
use crate::util::pool::par_map;

#[cfg(feature = "pjrt")]
use super::Coordinator;

/// A compression method applied uniformly (or, for SRA, per-layer) to all
/// compressed linears.
#[derive(Debug, Clone, PartialEq)]
pub enum Method {
    /// WxA8 post-training quantization of the dense weights (baseline).
    QuantOnly { wl: WordLen },
    /// Plain SVD truncation to a uniform rank fraction, then quantization
    /// (§VIII-B SVD baseline). `rank_frac` in (0, 1] of each layer's r_max.
    SvdBaseline { wl: WordLen, rank_frac: f64 },
    /// Algorithm 1 at a uniform rank fraction.
    SvdIter { wl: WordLen, rank_frac: f64 },
    /// Algorithm 1 with an explicit per-layer rank vector (SRA output).
    SvdIterRanks { wl: WordLen, ranks: Vec<usize> },
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::QuantOnly { wl } => format!("Quant W{wl}A8"),
            Method::SvdBaseline { wl, rank_frac } => {
                format!("SVD W{wl}A8 r={rank_frac:.2}")
            }
            Method::SvdIter { wl, rank_frac } => {
                format!("SVD-Iter W{wl}A8 r={rank_frac:.2}")
            }
            Method::SvdIterRanks { wl, .. } => format!("SVD-Iter(SRA) W{wl}A8"),
        }
    }

    pub fn word_len(&self) -> WordLen {
        match self {
            Method::QuantOnly { wl }
            | Method::SvdBaseline { wl, .. }
            | Method::SvdIter { wl, .. }
            | Method::SvdIterRanks { wl, .. } => *wl,
        }
    }

    /// Which artifact variant this method's output runs on.
    pub fn mode(&self) -> Mode {
        match self {
            Method::QuantOnly { .. } => Mode::Dense,
            _ => Mode::Svd,
        }
    }
}

/// A fully compressed model: per-linear compressed layers + the activation
/// word length (A8 throughout the paper's evaluation).
#[derive(Debug, Clone)]
pub struct CompressedModel {
    pub method: Method,
    pub layers: BTreeMap<String, CompressedLinear>,
    /// Activation word length fed to the in-graph fake-quant kernel.
    pub act_wl: Option<WordLen>,
}

impl CompressedModel {
    pub fn mode(&self) -> Mode {
        self.method.mode()
    }

    /// (compression ratio vs FP32, total linear-layer MACs at batch `m`).
    pub fn cost(&self, manifest: &Manifest, m: usize) -> (f64, u64) {
        let costs: Vec<LayerCost> = manifest
            .linears
            .iter()
            .map(|l| compress::layer_cost(&self.layers[&l.name], m, l.k, l.n))
            .collect();
        let ratio = compress::compression_ratio(&costs);
        let nops = costs.iter().map(|c| c.macs).sum();
        (ratio, nops)
    }

    /// Per-layer ranks (full rank reported for dense layers).
    pub fn ranks(&self, manifest: &Manifest) -> Vec<usize> {
        manifest.linears.iter().map(|l| self.layers[&l.name].rank()).collect()
    }

    /// Build the always-available native execution backend for this
    /// compressed model: the dense path for `Mode::Dense` methods, the
    /// two-skinny-matmul factored path for the SVD family — so every
    /// compression configuration can be evaluated end-to-end without
    /// PJRT or compiled artifacts.
    pub fn native_backend(
        &self,
        manifest: &Manifest,
        model: &PairModel,
        workers: usize,
    ) -> anyhow::Result<NativeBackend> {
        self.native_backend_mode(manifest, model, self.mode(), workers)
    }

    /// As [`Self::native_backend`] with an explicit execution mode —
    /// `Mode::Quantized` executes this compression bit-packed (and
    /// bit-identically to the fake-quant mode the method defaults to).
    pub fn native_backend_mode(
        &self,
        manifest: &Manifest,
        model: &PairModel,
        mode: Mode,
        workers: usize,
    ) -> anyhow::Result<NativeBackend> {
        NativeBackend::new(manifest, model, &self.layers, self.act_wl, mode, workers)
    }

    /// Materialize the bit-packed weight bank of this compressed model in
    /// manifest order — the resident form `Mode::Quantized` executes,
    /// exposed directly for byte accounting and packed-artifact tooling.
    pub fn packed_bank(
        &self,
        manifest: &Manifest,
    ) -> anyhow::Result<BTreeMap<String, crate::qkernel::PackedLinear>> {
        let mut bank = BTreeMap::new();
        for l in &manifest.linears {
            let c = self
                .layers
                .get(&l.name)
                .ok_or_else(|| anyhow::anyhow!("no compressed layer for {}", l.name))?;
            let p = crate::qkernel::PackedLinear::from_compressed(c)
                .map_err(|e| anyhow::anyhow!("packing layer {}: {e}", l.name))?;
            bank.insert(l.name.clone(), p);
        }
        Ok(bank)
    }

    /// Cheap structural fingerprint for evaluation memoization.
    pub fn fingerprint(&self, pair: &str) -> u64 {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        pair.hash(&mut h);
        self.act_wl.hash(&mut h);
        match &self.method {
            Method::QuantOnly { wl } => (0u8, *wl, 0u64).hash(&mut h),
            Method::SvdBaseline { wl, rank_frac } => {
                (1u8, *wl, rank_frac.to_bits()).hash(&mut h)
            }
            Method::SvdIter { wl, rank_frac } => {
                (2u8, *wl, rank_frac.to_bits()).hash(&mut h)
            }
            Method::SvdIterRanks { wl, ranks } => {
                (3u8, *wl).hash(&mut h);
                ranks.hash(&mut h);
            }
        }
        h.finish()
    }
}

/// Apply `method` to one weight matrix at an explicit rank.
pub fn compress_one(w: &Matrix, method: &Method, rank: usize) -> CompressedLinear {
    match method {
        Method::QuantOnly { wl } => compress::quant_only(w, *wl),
        Method::SvdBaseline { wl, .. } => compress::svd_baseline(w, rank, *wl),
        Method::SvdIter { wl, .. } | Method::SvdIterRanks { wl, .. } => {
            compress::itera(w, rank, *wl).0
        }
    }
}

fn rank_of(method: &Method, idx: usize, r_max: usize) -> usize {
    match method {
        Method::QuantOnly { .. } => r_max,
        Method::SvdBaseline { rank_frac, .. } | Method::SvdIter { rank_frac, .. } => {
            ((r_max as f64 * rank_frac).round() as usize).clamp(1, r_max)
        }
        Method::SvdIterRanks { ranks, .. } => ranks[idx].clamp(1, r_max),
    }
}

/// Compress all linears described by `linears`/`weights` (same index
/// space) with `method`.
///
/// For the Algorithm 1 family, passing `cache` (one [`IncrementalItera`]
/// per layer, filled at the method's word length) turns every layer into a
/// rank-truncation query — no recompression, the engine of the SRA/DSE
/// speedup. Without a cache (and always for quant-only / plain SVD) the
/// per-layer compressions fan out on the shared pool.
pub fn compress_model_from(
    linears: &[LinearInfo],
    weights: &[&Matrix],
    method: &Method,
    cache: Option<&[IncrementalItera]>,
    workers: usize,
) -> CompressedModel {
    assert_eq!(linears.len(), weights.len());
    let compressed: Vec<(String, CompressedLinear)> = match (method, cache) {
        (Method::SvdIter { .. } | Method::SvdIterRanks { .. }, Some(cache)) => {
            assert_eq!(cache.len(), linears.len(), "cache/layer inventory mismatch");
            linears
                .iter()
                .enumerate()
                .map(|(i, l)| {
                    assert_eq!(
                        cache[i].word_len(),
                        method.word_len(),
                        "cache filled at a different word length than the method"
                    );
                    let rank = rank_of(method, i, l.r_max);
                    (l.name.clone(), cache[i].query(rank))
                })
                .collect()
        }
        _ => par_map(linears.len(), workers, |i| {
            let l = &linears[i];
            let rank = rank_of(method, i, l.r_max);
            (l.name.clone(), compress_one(weights[i], method, rank))
        }),
    };
    CompressedModel {
        method: method.clone(),
        layers: compressed.into_iter().collect(),
        act_wl: Some(8), // the paper evaluates WxA8 throughout
    }
}

/// Compress all linears of `pair` on the coordinator.
///
/// Algorithm 1 methods go through the coordinator's per-`(pair, wl)`
/// incremental cache once the key warms up (second configuration on), so
/// repeated configurations (the SRA search, the Fig. 7/8/11 sweeps, the
/// DSE codesign loop) pay the full decomposition once per layer and
/// truncation-only after that, while a one-shot compression keeps the
/// direct rank-`r` cost.
#[cfg(feature = "pjrt")]
pub fn compress_model(c: &Coordinator, pair: &str, method: &Method) -> CompressedModel {
    let model = c.model(pair);
    let linears = &c.manifest.linears;
    let weights: Vec<&Matrix> = linears.iter().map(|l| model.linear(&l.name)).collect();
    let cache = match method {
        Method::SvdIter { wl, .. } | Method::SvdIterRanks { wl, .. } => {
            c.itera_cache_opportunistic(pair, *wl)
        }
        _ => None,
    };
    compress_model_from(
        linears,
        &weights,
        method,
        cache.as_ref().map(|c| c.as_slice()),
        c.cfg.workers,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_and_modes() {
        assert_eq!(Method::QuantOnly { wl: 4 }.label(), "Quant W4A8");
        assert_eq!(Method::QuantOnly { wl: 4 }.mode(), Mode::Dense);
        assert_eq!(Method::SvdIter { wl: 6, rank_frac: 0.5 }.mode(), Mode::Svd);
    }

    #[test]
    fn rank_of_clamps() {
        let m = Method::SvdIter { wl: 4, rank_frac: 0.01 };
        assert_eq!(rank_of(&m, 0, 64), 1);
        let m = Method::SvdIter { wl: 4, rank_frac: 1.0 };
        assert_eq!(rank_of(&m, 0, 64), 64);
        let m = Method::SvdIterRanks { wl: 4, ranks: vec![999] };
        assert_eq!(rank_of(&m, 0, 64), 64);
    }

    #[test]
    fn compress_model_from_cache_matches_recompute() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(7);
        let ws: Vec<Matrix> =
            (0..3usize).map(|i| Matrix::randn(10 + i, 12, &mut rng).scale(0.1)).collect();
        let linears: Vec<LinearInfo> = ws
            .iter()
            .enumerate()
            .map(|(i, w)| LinearInfo {
                name: format!("l{i}"),
                k: w.rows(),
                n: w.cols(),
                r_max: w.rows().min(w.cols()),
            })
            .collect();
        let refs: Vec<&Matrix> = ws.iter().collect();
        let method = Method::SvdIterRanks { wl: 4, ranks: vec![3, 5, 2] };
        let cache: Vec<IncrementalItera> =
            ws.iter().map(|w| IncrementalItera::compress(w, 4)).collect();
        let direct = compress_model_from(&linears, &refs, &method, None, 2);
        let cached = compress_model_from(&linears, &refs, &method, Some(&cache), 2);
        for l in &linears {
            assert_eq!(
                direct.layers[&l.name].effective().data(),
                cached.layers[&l.name].effective().data(),
                "layer {}",
                l.name
            );
            assert_eq!(direct.layers[&l.name].rank(), cached.layers[&l.name].rank());
        }
    }

    #[test]
    fn fingerprints_distinguish_configs() {
        let a = CompressedModel {
            method: Method::QuantOnly { wl: 4 },
            layers: BTreeMap::new(),
            act_wl: Some(8),
        };
        let b = CompressedModel {
            method: Method::QuantOnly { wl: 6 },
            layers: BTreeMap::new(),
            act_wl: Some(8),
        };
        assert_ne!(a.fingerprint("en-de"), b.fingerprint("en-de"));
        assert_ne!(a.fingerprint("en-de"), a.fingerprint("fr-en"));
        let c1 = CompressedModel {
            method: Method::SvdIterRanks { wl: 4, ranks: vec![1, 2, 3] },
            layers: BTreeMap::new(),
            act_wl: Some(8),
        };
        let c2 = CompressedModel {
            method: Method::SvdIterRanks { wl: 4, ranks: vec![1, 2, 4] },
            layers: BTreeMap::new(),
            act_wl: Some(8),
        };
        assert_ne!(c1.fingerprint("en-de"), c2.fingerprint("en-de"));
    }
}
