//! Leading singular triplet via alternating power iteration.
//!
//! Algorithm 1 (`SVD(R)_1`) needs only the rank-1 approximation of the
//! residual at each refinement step. Alternating iteration
//! `u <- R v / |R v|`, `v <- R^T u / |R^T u|` converges geometrically at
//! rate (σ2/σ1)² and costs two mat-vecs per sweep — the dominant cost of
//! the whole compression engine, so it is kept allocation-free per sweep.

use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

/// Leading singular triplet `(sigma, u, v)` with `|u| = |v| = 1`.
#[derive(Debug, Clone)]
pub struct TopTriplet {
    pub sigma: f32,
    pub u: Vec<f32>,
    pub v: Vec<f32>,
}

const MAX_ITERS: usize = 300;
const REL_TOL: f64 = 1e-9;

/// Reusable buffers for the alternating power sweeps.
///
/// One workspace serves any matrix shape: the `u`/`v` buffers grow to the
/// largest shape seen and are then reused allocation-free, which is what
/// lets Algorithm 1 run `r` truncated SVDs without a single per-sweep
/// allocation. Also tallies the matvec-equivalent operations executed
/// through it (one unit per `A*v` / `A^T*u`), the cost metric
/// EXPERIMENTS.md §Perf and the compression-cache accounting use.
#[derive(Debug, Default)]
pub struct PowerWorkspace {
    u: Vec<f32>,
    v: Vec<f32>,
    /// matvec-equivalents executed through this workspace.
    pub matvecs: u64,
}

impl PowerWorkspace {
    pub fn new() -> PowerWorkspace {
        PowerWorkspace::default()
    }
}

/// Compute the leading singular triplet of `a`.
///
/// Convenience wrapper over [`svd_top1_ws`] with a throwaway workspace;
/// hot loops (Algorithm 1) should hold a [`PowerWorkspace`] and call
/// [`svd_top1_ws`] directly.
pub fn svd_top1(a: &Matrix, seed: u64) -> TopTriplet {
    let mut ws = PowerWorkspace::new();
    svd_top1_ws(a, seed, &mut ws)
}

/// Compute the leading singular triplet of `a`, reusing `ws`'s buffers so
/// the power sweep itself performs no allocations.
///
/// Deterministic: the start vector is seeded from `seed` so compression
/// runs reproduce bit-identically. Falls back to a zero triplet for an
/// all-zero matrix (residual fully consumed).
pub fn svd_top1_ws(a: &Matrix, seed: u64, ws: &mut PowerWorkspace) -> TopTriplet {
    let (m, n) = a.shape();
    let mut rng = Pcg64::seeded(seed, 0x5eed);
    // Start from the largest-norm row's direction when available — cheap
    // spectral hint that shaves iterations on outlier-heavy weights.
    ws.v.clear();
    {
        let mut best = 0usize;
        let mut best_n = -1.0f32;
        for i in 0..m {
            let nrm = crate::tensor::norm2(a.row(i));
            if nrm > best_n {
                best_n = nrm;
                best = i;
            }
        }
        if best_n <= 0.0 {
            return TopTriplet { sigma: 0.0, u: vec![0.0; m], v: vec![0.0; n] };
        }
        ws.v.extend_from_slice(a.row(best));
    }
    let nv = crate::tensor::norm2(&ws.v);
    if nv == 0.0 {
        for x in ws.v.iter_mut() {
            *x = rng.normal();
        }
    }
    normalize(&mut ws.v);

    let mut sigma_prev = 0.0f64;
    let mut sigma = 0.0f64;
    for _ in 0..MAX_ITERS {
        // u <- A v
        a.matvec_into(&ws.v, &mut ws.u);
        ws.matvecs += 1;
        let un = crate::tensor::norm2(&ws.u);
        if un == 0.0 {
            return TopTriplet { sigma: 0.0, u: vec![0.0; m], v: ws.v.clone() };
        }
        crate::tensor::scale(&mut ws.u, 1.0 / un);
        // v <- A^T u
        a.tr_matvec_into(&ws.u, &mut ws.v);
        ws.matvecs += 1;
        let vn = crate::tensor::norm2(&ws.v);
        if vn == 0.0 {
            return TopTriplet { sigma: 0.0, u: ws.u.clone(), v: vec![0.0; n] };
        }
        crate::tensor::scale(&mut ws.v, 1.0 / vn);
        sigma = vn as f64;
        if (sigma - sigma_prev).abs() <= REL_TOL * sigma.max(1e-30) {
            break;
        }
        sigma_prev = sigma;
    }
    TopTriplet { sigma: sigma as f32, u: ws.u.clone(), v: ws.v.clone() }
}

fn normalize(x: &mut [f32]) {
    let n = crate::tensor::norm2(x);
    if n > 0.0 {
        crate::tensor::scale(x, 1.0 / n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd;

    #[test]
    fn matches_jacobi_on_random() {
        let mut rng = Pcg64::new(30);
        for trial in 0..5 {
            let a = Matrix::randn(9 + trial, 7, &mut rng);
            let full = svd(&a);
            let top = svd_top1(&a, trial as u64);
            assert!(
                (top.sigma - full.s[0]).abs() < 1e-3 * full.s[0],
                "sigma {} vs {}",
                top.sigma,
                full.s[0]
            );
            // Rank-1 approximations agree up to sign.
            let dot_u = crate::tensor::dot(&top.u, &full.u.col(0));
            assert!(dot_u.abs() > 0.999, "u alignment {dot_u}");
        }
    }

    #[test]
    fn rank1_matrix_exact() {
        let u = vec![0.6f32, 0.8];
        let v = vec![0.0f32, 1.0, 0.0];
        let a = crate::tensor::outer(&u, &v).scale(7.0);
        let t = svd_top1(&a, 0);
        assert!((t.sigma - 7.0).abs() < 1e-4);
        let rec = crate::tensor::outer(&t.u, &t.v).scale(t.sigma);
        assert!(rec.sub(&a).frob_norm() < 1e-4);
    }

    #[test]
    fn zero_matrix() {
        let a = Matrix::zeros(4, 5);
        let t = svd_top1(&a, 1);
        assert_eq!(t.sigma, 0.0);
    }

    #[test]
    fn unit_norm_outputs() {
        let mut rng = Pcg64::new(31);
        let a = Matrix::randn(6, 6, &mut rng);
        let t = svd_top1(&a, 2);
        assert!((crate::tensor::norm2(&t.u) - 1.0).abs() < 1e-5);
        assert!((crate::tensor::norm2(&t.v) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn deterministic_across_calls() {
        let mut rng = Pcg64::new(32);
        let a = Matrix::randn(8, 8, &mut rng);
        let t1 = svd_top1(&a, 9);
        let t2 = svd_top1(&a, 9);
        assert_eq!(t1.sigma, t2.sigma);
        assert_eq!(t1.u, t2.u);
    }

    #[test]
    fn workspace_reuse_matches_fresh_across_shapes() {
        let mut rng = Pcg64::new(33);
        let mut ws = PowerWorkspace::new();
        for trial in 0..4u64 {
            let a = Matrix::randn(6 + trial as usize, 9 - trial as usize, &mut rng);
            let fresh = svd_top1(&a, trial);
            let reused = svd_top1_ws(&a, trial, &mut ws);
            assert_eq!(fresh.sigma, reused.sigma);
            assert_eq!(fresh.u, reused.u);
            assert_eq!(fresh.v, reused.v);
        }
        assert!(ws.matvecs > 0, "workspace must tally its matvecs");
    }
}
