//! PJRT runtime: load AOT-compiled HLO text, compile once, execute many.
//!
//! This is the request-path boundary of the three-layer architecture: the
//! Python compile path ran once at build time; from here on everything is
//! Rust + the PJRT C API (`xla` crate over xla_extension 0.5.1, CPU
//! plugin). HLO **text** is the interchange format — `HloModuleProto::
//! from_text_file` reassigns instruction ids, sidestepping the 64-bit-id
//! protos jax>=0.5 emits that this XLA build rejects.
//!
//! Weight arguments are uploaded to device buffers **once per compression
//! configuration** ([`ArgBank`]); each translate call then swaps only the
//! source-token buffer — the same weights-stay-resident discipline a real
//! accelerator deployment would use, and the single biggest perf lever on
//! the eval loop (see EXPERIMENTS.md §Perf).

mod engine;
mod session;

pub use engine::Engine;
pub use session::{ArgBank, Mode, TranslateSession};
