//! Scoped thread pool for data-parallel compression jobs.
//!
//! The image vendors no rayon/tokio; the coordinator parallelizes per-layer
//! compression (Algorithm 1 is independent across weight matrices) with
//! `std::thread::scope` work-stealing over an atomic index. On the 1-core
//! CI image this degrades gracefully to sequential execution.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use (min(available_parallelism, cap)).
pub fn default_workers(cap: usize) -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(cap).max(1)
}

/// Apply `f` to every index in `0..n`, in parallel, collecting results in
/// index order. `f` must be `Sync`; results are written lock-free into a
/// preallocated slot vector.
pub fn par_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.min(n).max(1);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                *slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("worker failed to fill slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = par_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert!(par_map(0, 4, |i| i).is_empty());
        assert_eq!(par_map(1, 8, |i| i + 1), vec![1]);
    }

    #[test]
    fn sequential_fallback_matches() {
        let a = par_map(37, 1, |i| i as f64 * 1.5);
        let b = par_map(37, 3, |i| i as f64 * 1.5);
        assert_eq!(a, b);
    }
}
