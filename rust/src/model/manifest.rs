//! `artifacts/manifest.json` parsing.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Model dimensions (mirrors `python/compile/model.py::ModelConfig`).
#[derive(Debug, Clone)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_enc: usize,
    pub n_dec: usize,
    pub seq_len: usize,
    pub eval_batch: usize,
    pub pad_id: i32,
    pub bos_id: i32,
    pub eos_id: i32,
}

/// One compressed linear layer (the unit of rank allocation).
#[derive(Debug, Clone)]
pub struct LinearInfo {
    pub name: String,
    pub k: usize,
    pub n: usize,
    pub r_max: usize,
}

/// Per-language-pair artifact registry.
#[derive(Debug, Clone)]
pub struct PairInfo {
    pub weights: PathBuf,
    pub corpus: PathBuf,
    pub calib: PathBuf,
    pub act_maxabs: Vec<f32>,
}

/// Compiled HLO artifact registry.
#[derive(Debug, Clone)]
pub struct ArtifactSet {
    pub translate_dense: PathBuf,
    pub translate_svd: PathBuf,
    pub linear512_dense: PathBuf,
    pub linear512_svd: PathBuf,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelDims,
    pub linears: Vec<LinearInfo>,
    /// Positional argument names for each variant ("dense" / "svd").
    pub arg_order: BTreeMap<String, Vec<String>>,
    pub artifacts: ArtifactSet,
    pub pairs: BTreeMap<String, PairInfo>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;

        let m = j.get("model");
        let need = |v: &Json, what: &str| -> Result<usize> {
            v.as_usize().with_context(|| format!("manifest: missing model.{what}"))
        };
        let model = ModelDims {
            vocab: need(m.get("vocab"), "vocab")?,
            d_model: need(m.get("d_model"), "d_model")?,
            n_heads: need(m.get("n_heads"), "n_heads")?,
            d_ff: need(m.get("d_ff"), "d_ff")?,
            n_enc: need(m.get("n_enc"), "n_enc")?,
            n_dec: need(m.get("n_dec"), "n_dec")?,
            seq_len: need(m.get("seq_len"), "seq_len")?,
            eval_batch: need(m.get("eval_batch"), "eval_batch")?,
            pad_id: m.get("pad_id").as_i64().unwrap_or(0) as i32,
            bos_id: m.get("bos_id").as_i64().unwrap_or(1) as i32,
            eos_id: m.get("eos_id").as_i64().unwrap_or(2) as i32,
        };

        let linears = j
            .get("linears")
            .as_arr()
            .context("manifest: linears missing")?
            .iter()
            .map(|l| {
                Ok(LinearInfo {
                    name: l.get("name").as_str().context("linear name")?.to_string(),
                    k: l.get("k").as_usize().context("linear k")?,
                    n: l.get("n").as_usize().context("linear n")?,
                    r_max: l.get("r_max").as_usize().context("linear r_max")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        if linears.is_empty() {
            bail!("manifest: no compressed linears");
        }

        let mut arg_order = BTreeMap::new();
        for (mode, v) in j.get("arg_order").as_obj().context("arg_order")? {
            let names = v
                .as_arr()
                .context("arg_order entry")?
                .iter()
                .map(|s| s.as_str().map(str::to_string).context("arg name"))
                .collect::<Result<Vec<_>>>()?;
            arg_order.insert(mode.clone(), names);
        }

        let a = j.get("artifacts");
        let art = |key: &str| -> Result<PathBuf> {
            Ok(dir.join(a.get(key).as_str().with_context(|| format!("artifacts.{key}"))?))
        };
        let artifacts = ArtifactSet {
            translate_dense: art("translate_dense")?,
            translate_svd: art("translate_svd")?,
            linear512_dense: art("linear512_dense")?,
            linear512_svd: art("linear512_svd")?,
        };

        let mut pairs = BTreeMap::new();
        for (pair, v) in j.get("pairs").as_obj().context("pairs")? {
            let act_maxabs = v
                .get("act_maxabs")
                .as_arr()
                .context("act_maxabs")?
                .iter()
                .map(|x| x.as_f64().context("act_maxabs value").map(|f| f as f32))
                .collect::<Result<Vec<_>>>()?;
            if act_maxabs.len() != linears.len() {
                bail!(
                    "manifest: pair {pair} act_maxabs len {} != linears {}",
                    act_maxabs.len(),
                    linears.len()
                );
            }
            pairs.insert(
                pair.clone(),
                PairInfo {
                    weights: dir.join(v.get("weights").as_str().context("weights")?),
                    corpus: dir.join(v.get("corpus").as_str().context("corpus")?),
                    calib: dir.join(v.get("calib").as_str().context("calib")?),
                    act_maxabs,
                },
            );
        }

        Ok(Manifest { dir, model, linears, arg_order, artifacts, pairs })
    }

    /// Index of a compressed linear by name.
    pub fn linear_index(&self, name: &str) -> Option<usize> {
        self.linears.iter().position(|l| l.name == name)
    }

    /// Per-layer rank caps (`min(K, N)`), the SRA search space bounds.
    pub fn rank_caps(&self) -> Vec<usize> {
        self.linears.iter().map(|l| l.r_max).collect()
    }

    /// Default artifacts directory: `$ITERA_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("ITERA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(Manifest::default_dir()).unwrap();
        assert!(m.model.d_model >= 32 && m.model.d_model % m.model.n_heads == 0);
        assert_eq!(m.linears.len(), m.model.n_enc * 6 + m.model.n_dec * 10);
        assert!(m.arg_order["dense"].len() < m.arg_order["svd"].len());
        assert!(m.pairs.contains_key("en-de"));
        assert_eq!(m.linear_index(&m.linears[3].name), Some(3));
        // Every compressed linear appears in the dense arg order.
        for l in &m.linears {
            assert!(m.arg_order["dense"].iter().any(|a| a == &l.name), "{}", l.name);
        }
        // ... and as a factor pair in the svd arg order.
        for l in &m.linears {
            assert!(m.arg_order["svd"].iter().any(|a| *a == format!("{}.w1", l.name)));
            assert!(m.arg_order["svd"].iter().any(|a| *a == format!("{}.w2", l.name)));
        }
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Manifest::load("/nonexistent/path").is_err());
    }
}
