//! `weights_<pair>.bin` reader — the flat binary weight store written by
//! `python/compile/train.py::save_weights`.
//!
//! Layout: magic `ITWB` | u32 n_entries | entries, where each entry is
//! u32 name_len | name | u32 ndim | u32 dims[ndim] | f32 data (LE).
//! 1-D tensors (layer-norm params) are stored as `1 x n` matrices.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Matrix;

/// All tensors of one trained model, by name.
#[derive(Debug, Clone)]
pub struct WeightStore {
    /// Matrix plus the ndim it was stored with (1-D tensors become `1 x n`
    /// matrices but must be fed back to PJRT with 1-D dims).
    entries: BTreeMap<String, (Matrix, usize)>,
}

impl WeightStore {
    pub fn load(path: impl AsRef<Path>) -> Result<WeightStore> {
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("reading weight store {:?}", path.as_ref()))?;
        Self::parse(&bytes)
    }

    pub fn parse(bytes: &[u8]) -> Result<WeightStore> {
        let mut cur = Cursor { b: bytes, pos: 0 };
        if cur.take(4)? != b"ITWB" {
            bail!("bad magic: not an ITWB weight store");
        }
        let n = cur.u32()? as usize;
        let mut entries = BTreeMap::new();
        for _ in 0..n {
            let name_len = cur.u32()? as usize;
            let name = String::from_utf8(cur.take(name_len)?.to_vec())
                .context("weight name not utf-8")?;
            let ndim = cur.u32()? as usize;
            if ndim == 0 || ndim > 2 {
                bail!("weight {name}: unsupported ndim {ndim}");
            }
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(cur.u32()? as usize);
            }
            let (rows, cols) = if ndim == 1 { (1, dims[0]) } else { (dims[0], dims[1]) };
            let count = rows * cols;
            let raw = cur.take(count * 4)?;
            let mut data = Vec::with_capacity(count);
            for c in raw.chunks_exact(4) {
                data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
            entries.insert(name, (Matrix::from_vec(rows, cols, data), ndim));
        }
        if cur.pos != bytes.len() {
            bail!("trailing bytes in weight store");
        }
        Ok(WeightStore { entries })
    }

    pub fn get(&self, name: &str) -> Option<&Matrix> {
        self.entries.get(name).map(|(m, _)| m)
    }

    /// PJRT dims for a tensor: `[n]` for stored-1-D, `[rows, cols]` else.
    pub fn dims(&self, name: &str) -> Option<Vec<usize>> {
        self.entries.get(name).map(|(m, ndim)| {
            if *ndim == 1 {
                vec![m.cols()]
            } else {
                vec![m.rows(), m.cols()]
            }
        })
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("truncated weight store at byte {}", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a store in-memory in the same format train.py writes.
    fn synth_store(entries: &[(&str, usize, usize)]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"ITWB");
        out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
        for (i, (name, r, c)) in entries.iter().enumerate() {
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.extend_from_slice(&2u32.to_le_bytes());
            out.extend_from_slice(&(*r as u32).to_le_bytes());
            out.extend_from_slice(&(*c as u32).to_le_bytes());
            for k in 0..r * c {
                out.extend_from_slice(&((i * 1000 + k) as f32).to_le_bytes());
            }
        }
        out
    }

    #[test]
    fn parse_synthetic() {
        let bytes = synth_store(&[("a.w", 2, 3), ("b.w", 1, 4)]);
        let s = WeightStore::parse(&bytes).unwrap();
        assert_eq!(s.len(), 2);
        let a = s.get("a.w").unwrap();
        assert_eq!(a.shape(), (2, 3));
        assert_eq!(a.get(1, 2), 5.0);
        assert_eq!(s.dims("a.w").unwrap(), vec![2, 3]);
        assert!(s.get("missing").is_none());
    }

    #[test]
    fn one_dim_entries_keep_their_dims() {
        let mut out = Vec::new();
        out.extend_from_slice(b"ITWB");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&(4u32).to_le_bytes());
        out.extend_from_slice(b"ln_g");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&5u32.to_le_bytes());
        for k in 0..5 {
            out.extend_from_slice(&(k as f32).to_le_bytes());
        }
        let s = WeightStore::parse(&out).unwrap();
        assert_eq!(s.get("ln_g").unwrap().shape(), (1, 5));
        assert_eq!(s.dims("ln_g").unwrap(), vec![5]);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(WeightStore::parse(b"XXXX").is_err());
        let mut bytes = synth_store(&[("a", 2, 2)]);
        bytes.truncate(bytes.len() - 3);
        assert!(WeightStore::parse(&bytes).is_err());
        bytes.push(0);
        assert!(WeightStore::parse(&bytes).is_err());
    }

    #[test]
    fn loads_real_weights() {
        let dir = crate::model::Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = crate::model::Manifest::load(&dir).unwrap();
        let pair = &m.pairs["en-de"];
        let s = WeightStore::load(&pair.weights).unwrap();
        // Every compressed linear must be present with the declared shape.
        for l in &m.linears {
            let w = s.get(&l.name).unwrap_or_else(|| panic!("{} missing", l.name));
            assert_eq!(w.shape(), (l.k, l.n), "{}", l.name);
        }
        // Embeddings present too.
        assert_eq!(
            s.get("src_emb").unwrap().shape(),
            (m.model.vocab, m.model.d_model)
        );
    }
}
