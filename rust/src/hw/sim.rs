//! Cycle-level dataflow simulator of the tiled MatMul engines.
//!
//! Independent cross-check of the analytical model (Eq. 12–15): walks the
//! actual tile schedule of Listing 1 — per (i, j) tile: LHS/RHS FIFO fill,
//! `ceil(K/K_f)` compute beats, output drain — with double buffering
//! (loads of tile t+1 overlap compute of tile t) and a shared off-chip
//! port of finite bandwidth. Produces total cycles plus the PE-array
//! **occupancy** (compute-busy fraction) reported per layer in Fig. 12.
//!
//! Edge tiles compute on padded rows/columns but still load only real
//! data; the padding overhead the paper discusses shows up here as
//! occupancy loss, not as extra analytical terms.

use super::{ceil_div, Platform, TileConfig, Workload};

/// Result of simulating one tiled MatMul.
#[derive(Debug, Clone, Copy)]
pub struct SimResult {
    pub cycles: f64,
    /// Fraction of total cycles the PE array spent computing (0..1).
    pub occupancy: f64,
    /// Cycles lost waiting on the off-chip port.
    pub stall_cycles: f64,
}

/// Simulate a dense `[M x K] * [K x N]` MatMul on one engine tile.
///
/// `bw_bits` is the off-chip budget in bits/cycle available to this
/// engine (a cascade splits the platform port between its stages).
pub fn simulate_matmul(w: &Workload, t: &TileConfig, bw_bits: f64) -> SimResult {
    let m_tiles = ceil_div(w.m, t.mt);
    let n_tiles = ceil_div(w.n, t.nt);
    let k_iters = ceil_div(w.k, t.kf) as f64;

    // Per-tile transfer times at the engine's port rates, then stretched
    // by the shared off-chip port if it is the tighter constraint.
    let compute = k_iters; // cycles for one M_t x N_t output tile

    let mut busy = 0.0f64; // cycles PE array is computing
    let mut clock = 0.0f64;
    let mut stall = 0.0f64;

    // LHS tile loads once per i; RHS tile loads per (i, j).
    for i in 0..m_tiles {
        let rows = real_dim(w.m, t.mt, i);
        // LHS tile: rows x K activations.
        let lhs_words = (rows * w.k) as f64;
        let lhs_cycles = transfer_cycles(lhs_words * w.a_bits as f64, bw_bits);
        // Double buffering hides the load behind the previous tile row's
        // compute when possible; model as port occupancy.
        clock += lhs_cycles_beyond_overlap(lhs_cycles, i, n_tiles as f64 * compute);
        stall += lhs_cycles_beyond_overlap(lhs_cycles, i, n_tiles as f64 * compute);

        for j in 0..n_tiles {
            let cols = real_dim(w.n, t.nt, j);
            let rhs_words = (w.k * cols) as f64;
            // RHS stream is bounded by both the off-chip port and the
            // N_t x K_f-wide FIFO fill port of the array.
            let rhs_cycles = transfer_cycles(rhs_words * w.w_bits as f64, bw_bits)
                .max(rhs_words / (t.nt * t.kf) as f64);
            let out_words = (rows * cols) as f64;
            let out_cycles = transfer_cycles(out_words * w.a_bits as f64, bw_bits);

            // Steady state: next RHS tile streams while current computes
            // (FIFOs), so each (i, j) step costs max(compute, rhs, out).
            let step = compute.max(rhs_cycles).max(out_cycles);
            clock += step;
            // Useful work this step: real MACs vs the array's padded
            // capacity — edge tiles and K-padding show up as lost
            // occupancy (the Fig. 12 effect).
            let useful = (rows * cols) as f64 / (t.mt * t.nt) as f64
                * (w.k as f64 / (k_iters * t.kf as f64));
            busy += compute * useful;
            stall += step - compute;
        }
    }

    SimResult { cycles: clock, occupancy: busy / clock.max(1.0), stall_cycles: stall }
}

/// Simulate the Single SVD engine: two sequential phases sharing the tile.
pub fn simulate_single_svd(
    w: &Workload,
    rank: usize,
    t: &TileConfig,
    bw_bits: f64,
) -> SimResult {
    let s1 = Workload::new(w.m, w.k, rank, w.w_bits, w.a_bits);
    let s2 = Workload::new(w.m, rank, w.n, w.w_bits, w.a_bits);
    let r1 = simulate_matmul(&s1, t, bw_bits);
    let r2 = simulate_matmul(&s2, t, bw_bits);
    combine_sequential(&[r1, r2])
}

/// Simulate the Cascade SVD engine: stages overlap; the off-chip port is
/// split proportionally to each stage's traffic.
pub fn simulate_cascade_svd(
    w: &Workload,
    rank: usize,
    t1: &TileConfig,
    t2: &TileConfig,
    bw_bits: f64,
) -> SimResult {
    assert_eq!(t1.mt, t2.mt, "cascade engines must share M_t");
    let s1 = Workload::new(w.m, w.k, rank, w.w_bits, w.a_bits);
    let s2 = Workload::new(w.m, rank, w.n, w.w_bits, w.a_bits);
    // Traffic-proportional port split (stage 2 moves RHS2 + OUT).
    let bits1 = (w.m * w.k) as f64 * w.a_bits as f64
        + (ceil_div(w.m, t1.mt) * w.k * rank) as f64 * w.w_bits as f64;
    let bits2 = (ceil_div(w.m, t2.mt) * rank * w.n) as f64 * w.w_bits as f64
        + (w.m * w.n) as f64 * w.a_bits as f64;
    let share1 = bits1 / (bits1 + bits2);
    let r1 = simulate_matmul(&s1, t1, bw_bits * share1);
    let r2 = simulate_matmul(&s2, t2, bw_bits * (1.0 - share1));
    // Overlapped: wall clock is the slower stage plus one M-tile fill of
    // the faster stage.
    let m_tiles = ceil_div(w.m, t1.mt) as f64;
    let fill = r1.cycles.min(r2.cycles) / m_tiles;
    let cycles = r1.cycles.max(r2.cycles) + fill;
    let busy = r1.occupancy * r1.cycles + r2.occupancy * r2.cycles;
    SimResult {
        cycles,
        // Two engines: occupancy is averaged over both arrays' busy time.
        occupancy: busy / (2.0 * cycles),
        stall_cycles: r1.stall_cycles + r2.stall_cycles,
    }
}

/// Simulate on a platform (uses its full off-chip port).
pub fn simulate_on(w: &Workload, t: &TileConfig, platform: &Platform) -> SimResult {
    simulate_matmul(w, t, platform.bandwidth_bits_per_cycle)
}

fn real_dim(total: usize, tile: usize, idx: usize) -> usize {
    (total - idx * tile).min(tile)
}

fn transfer_cycles(bits: f64, bw_bits: f64) -> f64 {
    if bw_bits <= 0.0 {
        f64::INFINITY
    } else {
        bits / bw_bits
    }
}

/// First LHS load is exposed; later ones hide behind the previous row's
/// compute span.
fn lhs_cycles_beyond_overlap(lhs_cycles: f64, row_idx: usize, row_compute: f64) -> f64 {
    if row_idx == 0 {
        lhs_cycles / 2.0 // half exposed: fill starts as soon as FIFO has data
    } else {
        (lhs_cycles - row_compute).max(0.0) / 2.0
    }
}

fn combine_sequential(parts: &[SimResult]) -> SimResult {
    let cycles: f64 = parts.iter().map(|r| r.cycles).sum();
    let busy: f64 = parts.iter().map(|r| r.occupancy * r.cycles).sum();
    let stall: f64 = parts.iter().map(|r| r.stall_cycles).sum();
    SimResult { cycles, occupancy: busy / cycles.max(1.0), stall_cycles: stall }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::tile_latency_cycles;

    fn w512(wb: u32) -> Workload {
        Workload::new(512, 512, 512, wb, 8)
    }

    #[test]
    fn sim_agrees_with_analytical_when_unconstrained() {
        // With effectively infinite bandwidth, simulated cycles must match
        // the analytical compute/port bound within 15%.
        for t in [TileConfig::new(8, 8, 8), TileConfig::new(16, 16, 8), TileConfig::new(32, 16, 16)]
        {
            let w = w512(4);
            let sim = simulate_matmul(&w, &t, 1e12);
            let ana = tile_latency_cycles(&w, &t);
            let ratio = sim.cycles / ana.latency_cycles;
            assert!(
                (0.85..=1.15).contains(&ratio),
                "tile {t:?}: sim {} vs ana {} (ratio {ratio})",
                sim.cycles,
                ana.latency_cycles
            );
        }
    }

    #[test]
    fn occupancy_near_one_when_compute_bound() {
        let sim = simulate_matmul(&w512(4), &TileConfig::new(16, 16, 8), 1e12);
        assert!(sim.occupancy > 0.9, "occupancy {}", sim.occupancy);
    }

    #[test]
    fn starved_port_lowers_occupancy_and_stretches() {
        let t = TileConfig::new(32, 32, 16);
        let fast = simulate_matmul(&w512(8), &t, 1e12);
        let slow = simulate_matmul(&w512(8), &t, 64.0);
        assert!(slow.cycles > fast.cycles * 1.5);
        assert!(slow.occupancy < fast.occupancy);
        assert!(slow.stall_cycles > 0.0);
    }

    #[test]
    fn padding_reduces_occupancy() {
        // 100 is not a multiple of 16: edge tiles are padded and the
        // occupancy drops relative to a perfectly dividing workload.
        let t = TileConfig::new(16, 16, 8);
        let exact = simulate_matmul(&Workload::new(96, 96, 96, 8, 8), &t, 1e12);
        let padded = simulate_matmul(&Workload::new(100, 100, 100, 8, 8), &t, 1e12);
        assert!(padded.occupancy < exact.occupancy);
    }

    #[test]
    fn single_svd_sim_tracks_engine_model() {
        let t = TileConfig::new(16, 16, 8);
        let sim = simulate_single_svd(&w512(4), 128, &t, 1e12);
        let ana = crate::hw::EngineDesign::single_svd(&w512(4), 128, t);
        let ratio = sim.cycles / ana.latency_cycles;
        assert!((0.8..=1.2).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn cascade_sim_tracks_engine_model() {
        let t1 = TileConfig::new(16, 8, 8);
        let t2 = TileConfig::new(16, 16, 8);
        let sim = simulate_cascade_svd(&w512(4), 128, &t1, &t2, 1e12);
        let ana = crate::hw::EngineDesign::cascade_svd(&w512(4), 128, t1, t2);
        let ratio = sim.cycles / ana.latency_cycles;
        assert!((0.8..=1.25).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn smaller_tiles_higher_occupancy_when_bandwidth_limited() {
        // Fig. 12's observation: under a tight port, smaller tiles match
        // the available bandwidth better and keep the array busier.
        let big = simulate_matmul(&w512(4), &TileConfig::new(32, 32, 16), 100.0);
        let small = simulate_matmul(&w512(4), &TileConfig::new(8, 8, 8), 100.0);
        assert!(
            small.occupancy > big.occupancy,
            "small {} vs big {}",
            small.occupancy,
            big.occupancy
        );
    }
}
