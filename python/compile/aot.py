"""AOT compile path: train, calibrate, lower, and write ``artifacts/``.

This is the ONLY Python entry point in the deployed system; ``make
artifacts`` runs it once and the Rust binary is self-contained afterwards.

Interchange format is **HLO text**, not serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the image's xla_extension
0.5.1 (used by the Rust ``xla`` crate) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written:
  manifest.json            — arg orders, shapes, calibration, pair registry
  weights_<pair>.bin       — trained FP32 weights (rust: model/weights.rs)
  corpus_<pair>.bin        — held-out test set   (rust: eval/corpus.rs)
  calib_<pair>.bin         — calibration subset  (rust: eval/corpus.rs)
  translate_dense.hlo.txt  — greedy decode, dense weights (quant baseline)
  translate_svd.hlo.txt    — greedy decode, rank-padded SVD factors
  linear512_dense.hlo.txt  — 512x512x512 quant-matmul microbench (Fig. 10)
  linear512_svd.hlo.txt    — 512x512 cascade rank<=128 microbench
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import model as model_mod
from . import train as train_mod
from .kernels import cascade_matmul, quant_matmul

EVAL_BATCH = 16
PAIRS = ("en-de", "fr-en")


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_translate(mode: str, cfg=model_mod.CFG, batch: int = EVAL_BATCH) -> str:
    fn, _ = model_mod.make_flat_translate(mode, cfg)
    specs = model_mod.param_specs(mode, cfg)
    n_lin = len(model_mod.compressed_linear_names(cfg))
    args = [
        jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32),
        jax.ShapeDtypeStruct((n_lin,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    ] + [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs]
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_linear512(mode: str) -> str:
    """The Fig. 10 hardware workload (M=K=N=512, rank 128) as a runnable
    artifact, for runtime microbenches and numerics cross-checks."""
    if mode == "dense":
        fn = lambda x, w: (quant_matmul(x, w, block_m=64, block_n=64, block_k=64),)
        args = [jax.ShapeDtypeStruct((512, 512), jnp.float32)] * 2
    else:
        fn = lambda x, w1, w2: (cascade_matmul(x, w1, w2, block_m=64, block_n=64),)
        args = [
            jax.ShapeDtypeStruct((512, 512), jnp.float32),
            jax.ShapeDtypeStruct((512, 128), jnp.float32),
            jax.ShapeDtypeStruct((128, 512), jnp.float32),
        ]
    return to_hlo_text(jax.jit(fn).lower(*args))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=800)
    ap.add_argument("--skip-train", action="store_true",
                    help="reuse existing weights/corpora, relower HLO only")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    cfg = model_mod.CFG
    t0 = time.time()

    manifest: dict = {
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "n_enc": cfg.n_enc, "n_dec": cfg.n_dec,
            "seq_len": cfg.seq_len, "eval_batch": EVAL_BATCH,
            "pad_id": data_mod.PAD_ID, "bos_id": data_mod.BOS_ID,
            "eos_id": data_mod.EOS_ID,
        },
        "linears": [
            {
                "name": n,
                "k": model_mod.linear_shape(n, cfg)[0],
                "n": model_mod.linear_shape(n, cfg)[1],
                "r_max": model_mod.r_max(n, cfg),
            }
            for n in model_mod.compressed_linear_names(cfg)
        ],
        "arg_order": {
            mode: ["src_tokens", "act_scales", "act_levels"]
            + [n for n, _ in model_mod.param_specs(mode, cfg)]
            for mode in ("dense", "svd")
        },
        "artifacts": {
            "translate_dense": "translate_dense.hlo.txt",
            "translate_svd": "translate_svd.hlo.txt",
            "linear512_dense": "linear512_dense.hlo.txt",
            "linear512_svd": "linear512_svd.hlo.txt",
        },
        "pairs": {},
    }

    for pair in PAIRS:
        wpath = os.path.join(args.out_dir, f"weights_{pair}.bin")
        if args.skip_train and os.path.exists(wpath):
            old = json.load(open(os.path.join(args.out_dir, "manifest.json")))
            manifest["pairs"][pair] = old["pairs"][pair]
            print(f"[aot] reusing trained weights for {pair}")
            continue
        print(f"[aot] training {pair} ...")
        params, test_c, calib_c, maxabs = train_mod.train(
            pair=pair, steps=args.steps, cfg=cfg
        )
        train_mod.save_weights(wpath, params)
        train_mod.save_corpus(
            os.path.join(args.out_dir, f"corpus_{pair}.bin"), test_c.src, test_c.tgt
        )
        train_mod.save_corpus(
            os.path.join(args.out_dir, f"calib_{pair}.bin"), calib_c.src, calib_c.tgt
        )
        manifest["pairs"][pair] = {
            "weights": f"weights_{pair}.bin",
            "corpus": f"corpus_{pair}.bin",
            "calib": f"calib_{pair}.bin",
            "act_maxabs": [float(x) for x in maxabs],
        }
        print(f"[aot] {pair} trained in {time.time() - t0:.0f}s")

    for mode in ("dense", "svd"):
        print(f"[aot] lowering translate_{mode} ...")
        text = lower_translate(mode, cfg)
        with open(os.path.join(args.out_dir, f"translate_{mode}.hlo.txt"), "w") as f:
            f.write(text)
        print(f"[aot] lowering linear512_{mode} ...")
        text = lower_linear512(mode)
        with open(os.path.join(args.out_dir, f"linear512_{mode}.hlo.txt"), "w") as f:
            f.write(text)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] done in {time.time() - t0:.0f}s -> {args.out_dir}")


if __name__ == "__main__":
    main()
