//! Batched corpus evaluation through any [`TranslateBackend`].
//!
//! Backend-agnostic since the native runtime landed: the same loop scores
//! the pure-Rust engine (every build) and the PJRT session (`pjrt`
//! feature), so BLEU numbers are comparable across backends by
//! construction.

use anyhow::Result;

use crate::model::ModelDims;
use crate::runtime::TranslateBackend;

use super::{bleu_score, strip_specials, BleuDetail, Corpus};

/// Greedy-translate up to `limit` sentences of `corpus` (0 = all) and
/// return the de-framed hypothesis token sequences.
pub fn translate_corpus(
    backend: &dyn TranslateBackend,
    corpus: &Corpus,
    dims: &ModelDims,
    limit: usize,
) -> Result<Vec<Vec<i32>>> {
    let n = if limit == 0 { corpus.n } else { limit.min(corpus.n) };
    let b = backend.batch();
    let s = backend.seq_len();
    let mut hyps = Vec::with_capacity(n);
    let mut start = 0;
    while start < n {
        let take = (n - start).min(b);
        // Variable-shape backends skip the padding rows of the tail batch.
        let rows = if backend.fixed_shape() { b } else { take };
        let src = corpus.src_batch(start, rows, dims.pad_id);
        let out = backend.translate(&src)?;
        for r in 0..take {
            hyps.push(strip_specials(
                &out[r * s..(r + 1) * s],
                dims.bos_id,
                dims.eos_id,
                dims.pad_id,
            ));
        }
        start += b;
    }
    Ok(hyps)
}

/// BLEU of a configuration over (a prefix of) a corpus.
pub fn evaluate_bleu(
    backend: &dyn TranslateBackend,
    corpus: &Corpus,
    dims: &ModelDims,
    limit: usize,
) -> Result<BleuDetail> {
    let hyps = translate_corpus(backend, corpus, dims, limit)?;
    let refs: Vec<Vec<i32>> = (0..hyps.len())
        .map(|i| strip_specials(corpus.tgt_row(i), dims.bos_id, dims.eos_id, dims.pad_id))
        .collect();
    Ok(bleu_score(&hyps, &refs))
}
