//! Property-based tests over the compression stack's invariants, driven
//! by the in-tree `testkit` mini-framework (no proptest in the image).

use itera_llm::compress::{self, itera, quant_only, svd_baseline, CompressedLinear,
    IncrementalItera};
use itera_llm::dse::pareto_front;
use itera_llm::eval::bleu_score;
use itera_llm::hw::{sim, tile_latency_cycles, TileConfig, Workload};
use itera_llm::linalg::{reconstruct, svd, svd_top1};
use itera_llm::qkernel::{packed_bytes_for, PackedLinear, QMatrix, ScaleAxis};
use itera_llm::quant;
use itera_llm::sra;
use itera_llm::testkit::{check, Gen};
use itera_llm::util::json::Json;

const CASES: usize = 40;

// ---------------------------------------------------------------- linalg

#[test]
fn prop_svd_reconstructs_and_orders() {
    check("svd-reconstruct", CASES, |g: &mut Gen| {
        let m = g.size(2, 24);
        let n = g.size(2, 24);
        let a = g.matrix(m, n, 1.0);
        let d = svd(&a);
        // Singular values sorted descending, non-negative.
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-5);
        }
        assert!(d.s.iter().all(|&s| s >= 0.0));
        // Full-rank reconstruction recovers A.
        let rec = reconstruct(&d, m.min(n));
        let rel = rec.sub(&a).frob_norm() / a.frob_norm().max(1e-6);
        assert!(rel < 1e-3, "rel err {rel} on {m}x{n}");
    });
}

#[test]
fn prop_top1_matches_full_svd() {
    check("top1-vs-jacobi", CASES, |g: &mut Gen| {
        let m = g.size(2, 20);
        let n = g.size(2, 20);
        let a = g.matrix(m, n, 1.0);
        let full = svd(&a);
        let top = svd_top1(&a, g.case_seed);
        if full.s[0] > 1e-3 {
            // Allow slack when sigma1 ~= sigma2 (power iteration converges
            // slowly / may mix the pair's subspace).
            let gap = if full.s.len() > 1 { full.s[0] - full.s[1] } else { full.s[0] };
            let tol = if gap / full.s[0] < 0.05 { 0.05 } else { 5e-3 };
            let rel = (top.sigma - full.s[0]).abs() / full.s[0];
            assert!(rel < tol, "sigma rel err {rel} (gap {gap})");
        }
    });
}

#[test]
fn prop_eckart_young_ordering() {
    // Truncated SVD error decreases with rank and the rank-r error equals
    // the tail singular values' norm.
    check("eckart-young", CASES / 2, |g: &mut Gen| {
        let m = g.size(3, 16);
        let n = g.size(3, 16);
        let a = g.matrix(m, n, 1.0);
        let d = svd(&a);
        let rmax = m.min(n);
        let mut prev = f32::INFINITY;
        for r in 1..=rmax {
            let err = reconstruct(&d, r).sub(&a).frob_norm();
            let tail: f32 = d.s[r..].iter().map(|s| s * s).sum::<f32>().sqrt();
            assert!((err - tail).abs() < 1e-2 * tail.max(1.0), "r={r}: {err} vs tail {tail}");
            assert!(err <= prev + 1e-4);
            prev = err;
        }
    });
}

// ---------------------------------------------------------------- quant

#[test]
fn prop_quant_error_bounds() {
    check("quant-bounds", CASES, |g: &mut Gen| {
        let m = g.size(1, 24);
        let n = g.size(1, 24);
        let scale = g.f32_in(0.1, 10.0);
        let a = g.matrix(m, n, scale);
        let wl = *g.pick(&[2u32, 3, 4, 6, 8]);
        let (q, s) = quant::quantize_tensor(&a, wl);
        for (x, y) in a.data().iter().zip(q.data()) {
            assert!((x - y).abs() <= 0.5 * s + 1e-5);
            assert!(y.abs() <= a.max_abs() + 1e-5);
        }
    });
}

#[test]
fn prop_vector_quant_no_cross_contamination() {
    // Scaling one column must not change the quantization of others.
    check("col-quant-isolation", CASES, |g: &mut Gen| {
        let m = g.size(2, 16);
        let n = g.size(2, 16);
        let a = g.matrix(m, n, 1.0);
        let mut b = a.clone();
        let col = g.usize_in(0, n - 1);
        for i in 0..m {
            b.set(i, col, b.get(i, col) * 50.0);
        }
        let (qa, _) = quant::quantize_cols(&a, 4);
        let (qb, _) = quant::quantize_cols(&b, 4);
        for j in 0..n {
            if j == col {
                continue;
            }
            for i in 0..m {
                assert!((qa.get(i, j) - qb.get(i, j)).abs() < 1e-6);
            }
        }
    });
}

// ------------------------------------------------------------- compress

#[test]
fn prop_itera_residual_monotone() {
    check("itera-monotone", CASES, |g: &mut Gen| {
        let k = g.size(2, 24);
        let n = g.size(2, 24);
        let a = g.matrix(k, n, 0.5);
        let wl = *g.pick(&[3u32, 4, 6, 8]);
        let r = g.usize_in(1, k.min(n));
        let (c, trace) = itera(&a, r, wl);
        for w in trace.residual_norms.windows(2) {
            assert!(w[1] <= w[0] + 1e-3, "{:?}", trace.residual_norms);
        }
        // Error consistency.
        let err = c.error(&a);
        let last = *trace.residual_norms.last().unwrap();
        assert!((err - last).abs() <= 1e-2 * err.max(1.0) + 1e-4);
    });
}

#[test]
fn prop_itera_never_much_worse_than_svd_baseline() {
    // Iterative refinement compensates quant error: across random cases it
    // must win or tie (within 5%) against SVD-then-quantize at W<=4.
    check("itera-vs-baseline", CASES / 2, |g: &mut Gen| {
        let k = g.size(4, 24);
        let n = g.size(4, 24);
        let a = g.matrix(k, n, 0.5);
        let r = g.usize_in(2, k.min(n));
        let wl = *g.pick(&[3u32, 4]);
        let e_it = itera(&a, r, wl).0.error(&a);
        let e_sv = svd_baseline(&a, r, wl).error(&a);
        assert!(e_it <= e_sv * 1.05 + 1e-4, "iter {e_it} vs baseline {e_sv}");
    });
}

#[test]
fn prop_truncation_invariant() {
    // The contract the incremental compression cache rests on: Algorithm 1
    // is greedy (step k depends only on the residual left by steps 0..k,
    // never on the target rank), so the rank-r factors equal the rank-r
    // prefix of a rank-r_max run — bit for bit, for every (r, r_max, wl).
    check("itera-truncation-prefix", CASES / 2, |g: &mut Gen| {
        let k = g.size(2, 20);
        let n = g.size(2, 20);
        let a = g.matrix(k, n, 0.5);
        let wl = *g.pick(&[3u32, 4, 6, 8]);
        let inc = IncrementalItera::compress(&a, wl);
        let r = g.usize_in(1, k.min(n));
        let (fresh, trace) = itera(&a, r, wl);
        let cached = inc.query(r);
        let (CompressedLinear::LowRank { w1: fw1, w2: fw2, .. },
             CompressedLinear::LowRank { w1: cw1, w2: cw2, .. }) = (&fresh, &cached)
        else {
            panic!("itera returns LowRank");
        };
        assert_eq!(fw1.data(), cw1.data(), "w1 prefix at r={r} of {k}x{n} W{wl}");
        assert_eq!(fw2.data(), cw2.data(), "w2 prefix at r={r} of {k}x{n} W{wl}");
        // The recorded residual trace doubles as the per-rank error table.
        assert_eq!(inc.error_at(r), *trace.residual_norms.last().unwrap());
    });
}

#[test]
fn prop_accounting_consistency() {
    check("accounting", CASES, |g: &mut Gen| {
        let k = g.size(2, 64);
        let n = g.size(2, 64);
        let m = g.size(1, 64);
        let a = g.matrix(k, n, 0.3);
        let wl = *g.pick(&[3u32, 4, 6, 8]);
        let r = g.usize_in(1, k.min(n));

        let dense = quant_only(&a, wl);
        let low = itera(&a, r, wl).0;
        let cd = compress::layer_cost(&dense, m, k, n);
        let cl = compress::layer_cost(&low, m, k, n);
        // Dense ratio matches the exact storage formula (weights at wl
        // bits + one FP32 scale per output column).
        let expect = (32 * k * n) as f64 / ((k * n * wl as usize + 32 * n) as f64);
        assert!((cd.ratio() - expect).abs() < 1e-9, "{} vs {expect}", cd.ratio());
        // NOps formulas.
        assert_eq!(cd.macs, (m * k * n) as u64);
        assert_eq!(cl.macs, (m * r * (k + n)) as u64);
        // Below the breakeven rank the factored MACs are no worse.
        if r <= compress::breakeven_rank(k, n) {
            assert!(cl.macs <= cd.macs);
        }
    });
}

// ------------------------------------------------------------------ sra

#[test]
fn prop_sra_budget_and_caps() {
    check("sra-invariants", 15, |g: &mut Gen| {
        let l = g.usize_in(2, 12);
        let caps: Vec<usize> = (0..l).map(|_| g.usize_in(2, 48)).collect();
        let total_cap: usize = caps.iter().sum();
        let budget = g.usize_in(l, total_cap);
        let weights: Vec<f64> = (0..l).map(|_| g.f32_in(0.1, 5.0) as f64).collect();
        let caps2 = caps.clone();
        let mut oracle = move |ranks: &[usize]| {
            ranks
                .iter()
                .zip(&weights)
                .zip(&caps2)
                .map(|((&r, &w), &c)| w * (r as f64 / c as f64).sqrt())
                .sum()
        };
        let res = sra::run(&mut oracle, budget, &caps, &sra::SraConfig::default());
        let planned: usize = sra::equal_split(budget, &caps).iter().sum();
        assert_eq!(res.ranks.iter().sum::<usize>(), planned);
        for (r, c) in res.ranks.iter().zip(&caps) {
            assert!((1..=*c).contains(r));
        }
        for w in res.trace.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-12);
        }
    });
}

// ------------------------------------------------------------------ hw

#[test]
fn prop_analytical_vs_simulator() {
    // Unconstrained-bandwidth simulation must agree with Eq. 15 within
    // 25% across random workloads and tiles.
    check("model-vs-sim", 30, |g: &mut Gen| {
        let m = g.size(8, 512);
        let k = g.size(8, 512);
        let n = g.size(8, 512);
        let w = Workload::new(m, k, n, *g.pick(&[3u32, 4, 6, 8]), 8);
        let pow2 = [1usize, 2, 4, 8, 16, 32];
        let t = TileConfig::new(*g.pick(&pow2[..5]), *g.pick(&pow2), *g.pick(&pow2));
        let ana = tile_latency_cycles(&w, &t);
        let s = sim::simulate_matmul(&w, &t, 1e12);
        let ratio = s.cycles / ana.latency_cycles;
        assert!(
            (0.75..=1.3).contains(&ratio),
            "{w:?} {t:?}: sim {} ana {} ratio {ratio}",
            s.cycles,
            ana.latency_cycles
        );
        assert!(s.occupancy > 0.0 && s.occupancy <= 1.0 + 1e-9);
    });
}

#[test]
fn prop_bandwidth_monotone_in_cap() {
    check("bw-monotone", 20, |g: &mut Gen| {
        let w = Workload::new(g.size(16, 256), g.size(16, 256), g.size(16, 256), 4, 8);
        let t = TileConfig::new(8, 8, 8);
        let mut prev = f64::INFINITY;
        for bw in [32.0, 64.0, 128.0, 1e9] {
            let s = sim::simulate_matmul(&w, &t, bw);
            assert!(s.cycles <= prev + 1e-6, "more bandwidth must not slow down");
            prev = s.cycles;
        }
    });
}

// ----------------------------------------------------------------- eval

#[test]
fn prop_bleu_bounds_and_identity() {
    check("bleu", CASES, |g: &mut Gen| {
        let n = g.usize_in(1, 10);
        let refs: Vec<Vec<i32>> = (0..n)
            .map(|_| {
                let len = g.usize_in(1, 18);
                g.tokens(len, 60)
            })
            .collect();
        // Identity scores 100 when every sentence has >= 4 tokens.
        if refs.iter().all(|r| r.len() >= 4) {
            let d = bleu_score(&refs, &refs);
            assert!((d.score - 100.0).abs() < 1e-6);
        }
        // Any hypothesis scores within [0, 100].
        let hyps: Vec<Vec<i32>> = (0..n)
            .map(|_| {
                let len = g.usize_in(0, 18);
                g.tokens(len, 60)
            })
            .collect();
        let d = bleu_score(&hyps, &refs);
        assert!((0.0..=100.0 + 1e-9).contains(&d.score));
    });
}

// --------------------------------------------------------------- pareto

#[test]
fn prop_pareto_front_sound_and_complete() {
    check("pareto", CASES, |g: &mut Gen| {
        let n = g.usize_in(1, 60);
        let pts: Vec<(f64, f64)> = (0..n)
            .map(|_| (g.f32_in(0.0, 100.0) as f64, g.f32_in(0.0, 100.0) as f64))
            .collect();
        let front = pareto_front(&pts);
        assert!(!front.is_empty());
        // Soundness: no front point is dominated.
        for &i in &front {
            for (j, p) in pts.iter().enumerate() {
                if i == j {
                    continue;
                }
                let dom = p.0 <= pts[i].0
                    && p.1 >= pts[i].1
                    && (p.0 < pts[i].0 || p.1 > pts[i].1);
                assert!(!dom, "front point {i} dominated by {j}");
            }
        }
        // Completeness: every non-front point is dominated or duplicated.
        for (j, p) in pts.iter().enumerate() {
            if front.contains(&j) {
                continue;
            }
            let covered = front.iter().any(|&i| {
                (pts[i].0 <= p.0 && pts[i].1 >= p.1)
                    && (pts[i].0 < p.0 || pts[i].1 > p.1 || pts[i] == *p)
            });
            assert!(covered, "point {j} neither on front nor dominated");
        }
    });
}

// -------------------------------------------------------------- qkernel

/// Two f32 slices agree bit for bit, modulo the sign of zero (packing
/// canonicalizes -0.0 grid hits to +0.0, which every downstream
/// accumulation treats identically).
fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let same = x.to_bits() == y.to_bits() || (*x == 0.0 && *y == 0.0);
        assert!(same, "{what}: index {i}: {x} ({:#x}) vs {y} ({:#x})", x.to_bits(), y.to_bits());
    }
}

#[test]
fn prop_qmatrix_roundtrip_is_the_fake_quant_grid() {
    // Pack -> unpack == the fake-quant matrix, bit for bit, for every
    // packable word length and arbitrary (word-misaligned) row lengths,
    // on both scale axes; packed bytes match the analytic formula.
    check("qmatrix-roundtrip", CASES, |g: &mut Gen| {
        let m = g.size(1, 40);
        let n = g.size(1, 40);
        let sc = g.f32_in(0.05, 3.0);
        let a = g.matrix(m, n, sc);
        let wl = g.usize_in(2, 8) as u32;

        let (q, s) = quant::quantize_cols(&a, wl);
        let qm = QMatrix::from_fake_quant(&q, &s, wl, ScaleAxis::Col).expect("on-grid");
        assert_bits_eq(qm.to_matrix().data(), q.data(), "col-scaled");
        assert_eq!(qm.packed_bytes(), packed_bytes_for(m, n, wl));

        let (qr, sr) = quant::quantize_rows(&a, wl);
        let qmr = QMatrix::from_fake_quant(&qr, &sr, wl, ScaleAxis::Row).expect("on-grid");
        assert_bits_eq(qmr.to_matrix().data(), qr.data(), "row-scaled");
    });
}

#[test]
fn prop_qmatvec_and_qmatmul_bit_exact() {
    // The packed kernels reproduce the f32 fake-quant kernels bit for
    // bit: qmatvec vs tr_matvec, qmatmul(_par) vs matmul — including
    // zero activations (the skip predicate must match).
    check("qkernel-bitexact", CASES, |g: &mut Gen| {
        let k = g.size(1, 32);
        let n = g.size(1, 32);
        let a = g.matrix(k, n, 0.5);
        let wl = g.usize_in(2, 8) as u32;
        let (q, s) = quant::quantize_cols(&a, wl);
        let qm = QMatrix::from_fake_quant(&q, &s, wl, ScaleAxis::Col).unwrap();

        let mut x: Vec<f32> = (0..k).map(|_| g.normal()).collect();
        if k > 1 {
            let z = g.usize_in(0, k - 1);
            x[z] = 0.0;
        }
        assert_bits_eq(&qm.qmatvec(&x), &q.tr_matvec(&x), "qmatvec vs tr_matvec");

        let m = g.size(1, 8);
        let xm = g.matrix(m, k, 1.0);
        let want = xm.matmul(&q);
        assert_bits_eq(qm.qmatmul(&xm).data(), want.data(), "qmatmul vs matmul");
        let workers = g.usize_in(1, 4);
        assert_bits_eq(qm.qmatmul_par(&xm, workers).data(), want.data(), "qmatmul_par");
    });
}

#[test]
fn prop_packed_compressed_layers_roundtrip() {
    // Every compression method's output packs losslessly (the carried
    // scales are the true grid scales — including alpha-absorbed W2
    // scales from Algorithm 1).
    check("packed-linear-roundtrip", CASES / 2, |g: &mut Gen| {
        let k = g.size(2, 20);
        let n = g.size(2, 20);
        let a = g.matrix(k, n, 0.5);
        let wl = *g.pick(&[2u32, 3, 4, 6, 8]);
        let r = g.usize_in(1, k.min(n));

        let dense = quant_only(&a, wl);
        let CompressedLinear::Dense { w: fq, .. } = &dense else { panic!() };
        let PackedLinear::Dense(qm) = PackedLinear::from_compressed(&dense).unwrap() else {
            panic!("quant_only packs Dense")
        };
        assert_bits_eq(qm.to_matrix().data(), fq.data(), "packed quant_only");

        for low in [itera(&a, r, wl).0, svd_baseline(&a, r, wl)] {
            let CompressedLinear::LowRank { w1, w2, .. } = &low else { panic!() };
            let PackedLinear::Factored(q1, q2) = PackedLinear::from_compressed(&low).unwrap()
            else {
                panic!("factored methods pack Factored")
            };
            assert_bits_eq(q1.to_matrix().data(), w1.data(), "packed w1");
            assert_bits_eq(q2.to_matrix().data(), w2.data(), "packed w2");
        }
    });
}

#[test]
fn prop_qmatvec_i32_exact_and_close_to_f32() {
    // The integer kernel (i32 accumulation, one dequant-rescale per
    // output) matches its exact integer reference bit for bit and stays
    // within float-association distance of the f32 fake-quant path.
    check("qmatvec-i32", CASES / 2, |g: &mut Gen| {
        let k = g.size(1, 40);
        let n = g.size(1, 40);
        let a = g.matrix(k, n, 0.4);
        let wl = g.usize_in(2, 8) as u32;
        let (q, s) = quant::quantize_cols(&a, wl);
        let qm = QMatrix::from_fake_quant(&q, &s, wl, ScaleAxis::Col).unwrap();
        let x: Vec<f32> = (0..k).map(|_| g.normal()).collect();
        let (qx, sx) = quant::quantize_vec_parts(&x, 8);
        let got = qm.qmatvec_i32(&qx, sx).expect("in-envelope activation");
        for (col, &gv) in got.iter().enumerate() {
            let mut acc = 0i64;
            for (row, &xq) in qx.iter().enumerate() {
                acc += xq as i64 * qm.get_int(row, col) as i64;
            }
            let want = (sx * qm.scales()[col]) * acc as f32;
            assert_eq!(gv.to_bits(), want.to_bits(), "col {col}");
        }
        // Distance to the f32 path is bounded by association error.
        let xq_f32: Vec<f32> = qx.iter().map(|&v| quant::dequantize_val(v, sx)).collect();
        let f32_path = q.tr_matvec(&xq_f32);
        for (a, b) in got.iter().zip(&f32_path) {
            assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    });
}

#[test]
fn prop_non_finite_activations_error_instead_of_quantizing() {
    // `try_quantize_vec_parts` reports the first non-finite lane wherever
    // it hides (the max-abs fold must not let `f32::max`'s NaN-dropping
    // semantics swallow it); finite vectors quantize exactly like the
    // infallible path.
    check("quant-nonfinite", CASES, |g: &mut Gen| {
        let k = g.size(1, 48);
        let x: Vec<f32> = (0..k).map(|_| g.normal()).collect();
        let wl = g.usize_in(2, 8) as u32;

        let (qx, sx) = quant::try_quantize_vec_parts(&x, wl).expect("finite input quantizes");
        let (qx2, sx2) = quant::quantize_vec_parts(&x, wl);
        assert_eq!(qx, qx2, "fallible path must quantize identically");
        assert_eq!(sx.to_bits(), sx2.to_bits());

        let mut bad = x.clone();
        let at = g.usize_in(0, k - 1);
        bad[at] = *g.pick(&[f32::NAN, f32::INFINITY, f32::NEG_INFINITY]);
        let err = quant::try_quantize_vec_parts(&bad, wl)
            .expect_err("a poisoned lane must be rejected, not folded away");
        // The first non-finite lane is named (every earlier lane is
        // finite by construction).
        assert_eq!(err.index, at, "reported lane");
        assert_eq!(err.value.to_bits(), bad[at].to_bits(), "reported value");
    });
}

// --------------------------------------------------------------- decode

/// KV-cached greedy decode is bit-identical to the full-buffer replay
/// reference — across all three execution modes, word lengths {4, 6, 8},
/// worker counts {1, 4}, and random ragged batches (source rows of
/// different lengths, so decode rows hit EOS/PAD at different steps and
/// exercise the per-slot done/tgt_ok bookkeeping).
#[test]
fn prop_cached_decode_bit_identical_to_replay() {
    use std::collections::BTreeMap;

    use itera_llm::model::PairModel;
    use itera_llm::runtime::{DecodePolicy, Mode, NativeBackend, TranslateBackend};
    use itera_llm::testkit::tinymodel;

    let (dir, manifest) =
        tinymodel::generate_in_temp("prop_decode", 0xDEC0DE).expect("generate tiny model");
    let model = PairModel::load(&manifest, tinymodel::PAIR).expect("load tiny model");
    let dims = manifest.model.clone();
    let s = dims.seq_len;

    // One compressed bank per (word length, family), built once and
    // shared across cases.
    let wls = [4u32, 6, 8];
    let mut dense_banks: Vec<BTreeMap<String, CompressedLinear>> = Vec::new();
    let mut factored_banks: Vec<BTreeMap<String, CompressedLinear>> = Vec::new();
    for &wl in &wls {
        dense_banks.push(
            manifest
                .linears
                .iter()
                .map(|l| (l.name.clone(), quant_only(model.linear(&l.name), wl)))
                .collect(),
        );
        factored_banks.push(
            manifest
                .linears
                .iter()
                .map(|l| {
                    let r = (l.r_max / 2).max(1);
                    (l.name.clone(), itera(model.linear(&l.name), r, wl).0)
                })
                .collect(),
        );
    }

    check("cached-decode-vs-replay", 12, |g: &mut Gen| {
        let wi = g.usize_in(0, wls.len() - 1);
        let wl = wls[wi];
        let workers = *g.pick(&[1usize, 4]);
        let mode = *g.pick(&[Mode::Dense, Mode::Svd, Mode::Quantized]);
        let layers = match mode {
            Mode::Dense => &dense_banks[wi],
            Mode::Svd => &factored_banks[wi],
            // The packed runtime executes either structure.
            Mode::Quantized => {
                if g.bool() {
                    &dense_banks[wi]
                } else {
                    &factored_banks[wi]
                }
            }
        };

        // Ragged batch: 1..=5 BOS-framed, EOS-terminated, PAD-padded rows
        // with different content lengths.
        let b = g.usize_in(1, 5);
        let mut src = vec![dims.pad_id; b * s];
        for r in 0..b {
            let len = g.usize_in(1, s - 3);
            src[r * s] = dims.bos_id;
            let toks = g.tokens(len, dims.vocab as i32);
            src[r * s + 1..r * s + 1 + len].copy_from_slice(&toks);
            src[r * s + 1 + len] = dims.eos_id;
        }

        let replay = NativeBackend::new(&manifest, &model, layers, Some(8), mode, workers)
            .expect("replay backend")
            .with_decode(DecodePolicy::Replay);
        let cached = NativeBackend::new(&manifest, &model, layers, Some(8), mode, workers)
            .expect("cached backend");
        assert_eq!(cached.decode_policy(), DecodePolicy::Cached, "default policy");
        assert_eq!(
            replay.translate(&src).unwrap(),
            cached.translate(&src).unwrap(),
            "mode {mode:?} W{wl} workers={workers} b={b}"
        );
    });
    std::fs::remove_dir_all(&dir).ok();
}

/// The fast integer decode tier stays within parity tolerance of the
/// exact tier: across word lengths {2, 4, 8}, dense-packed and low-rank
/// cascade banks, ragged batches and worker counts {1, 4}, the
/// teacher-forced step logits of `KernelTier::Fast` (runtime A8
/// activation quantization + pure-i32 GEMV) stay within a scale-aware
/// |Δlogit| bound of `KernelTier::Exact` — and the exact tier itself is
/// bit-identical to the default (tier-less) construction. Greedy tokens
/// under the fast tier may differ (that is the tier's contract) but must
/// stay well-formed.
#[test]
fn prop_fast_kernel_tier_within_parity_tolerance_of_exact() {
    use std::collections::BTreeMap;

    use itera_llm::model::PairModel;
    use itera_llm::runtime::{KernelTier, Mode, NativeBackend, TranslateBackend};
    use itera_llm::testkit::tinymodel;

    let (dir, manifest) =
        tinymodel::generate_in_temp("prop_ktier", 0xFA57A).expect("generate tiny model");
    let model = PairModel::load(&manifest, tinymodel::PAIR).expect("load tiny model");
    let dims = manifest.model.clone();
    let s = dims.seq_len;

    // One packed bank per (word length, family), built once.
    let wls = [2u32, 4, 8];
    let mut dense_banks: Vec<BTreeMap<String, CompressedLinear>> = Vec::new();
    let mut cascade_banks: Vec<BTreeMap<String, CompressedLinear>> = Vec::new();
    for &wl in &wls {
        dense_banks.push(
            manifest
                .linears
                .iter()
                .map(|l| (l.name.clone(), quant_only(model.linear(&l.name), wl)))
                .collect(),
        );
        cascade_banks.push(
            manifest
                .linears
                .iter()
                .map(|l| {
                    let r = (l.r_max / 2).max(1);
                    (l.name.clone(), itera(model.linear(&l.name), r, wl).0)
                })
                .collect(),
        );
    }

    check("fast-tier-parity", 10, |g: &mut Gen| {
        let wi = g.usize_in(0, wls.len() - 1);
        let wl = wls[wi];
        let workers = *g.pick(&[1usize, 4]);
        let cascade = g.bool();
        let layers = if cascade { &cascade_banks[wi] } else { &dense_banks[wi] };

        let exact = NativeBackend::new(&manifest, &model, layers, Some(8), Mode::Quantized, workers)
            .expect("exact backend");
        assert_eq!(exact.kernel_tier(), KernelTier::Exact, "exact is the default tier");
        let fast = NativeBackend::new(&manifest, &model, layers, Some(8), Mode::Quantized, workers)
            .expect("fast backend")
            .with_kernel(KernelTier::Fast);

        // Ragged batch: 1..=4 BOS-framed, EOS-terminated, PAD-padded rows.
        let b = g.usize_in(1, 4);
        let rows: Vec<Vec<i32>> = (0..b)
            .map(|_| {
                let len = g.usize_in(1, s - 3);
                let mut row = vec![dims.pad_id; s];
                row[0] = dims.bos_id;
                let toks = g.tokens(len, dims.vocab as i32);
                row[1..1 + len].copy_from_slice(&toks);
                row[1 + len] = dims.eos_id;
                row
            })
            .collect();

        // Fast-tier greedy decode must run and stay well-formed.
        let outs = fast.translate_stream(&rows).expect("fast decode");
        for out in &outs {
            assert_eq!(out[0], dims.bos_id, "fast decode keeps the BOS framing");
            for &t in out {
                assert!(t >= 0 && (t as usize) < dims.vocab, "fast decode token {t} in vocab");
            }
        }

        // Teacher-force the exact tier's decodes through both tiers' step
        // kernels; the fast tier's |Δlogit| stays inside a scale-aware
        // bound (NaN-sticky comparisons: a poisoned logit can't pass).
        let want = exact.translate_stream(&rows).expect("exact decode");
        let mut dmax = 0.0f32;
        let mut lmax = 0.0f32;
        for (src, tgt) in rows.iter().zip(&want) {
            let a = exact.step_logits(src, &tgt[..s]).expect("exact step logits");
            let b = fast.step_logits(src, &tgt[..s]).expect("fast step logits");
            for (&x, &y) in a.data().iter().zip(b.data()) {
                let d = (x - y).abs();
                if !(d <= dmax) {
                    dmax = d;
                }
                if !(x.abs() <= lmax) {
                    lmax = x.abs();
                }
            }
        }
        let tol = 1.5f32.max(0.05 * lmax);
        assert!(
            dmax <= tol,
            "fast tier drifted past parity tolerance: max |dlogit| {dmax} > {tol} \
             (W{wl}, cascade={cascade}, workers={workers}, b={b})"
        );
    });
    std::fs::remove_dir_all(&dir).ok();
}

/// Continuous batching is bit-identical to per-request sequential decode:
/// for random ragged arrival traces (1..=8 requests with staggered
/// admission steps and per-row content lengths), word lengths {4, 6, 8},
/// worker counts {1, 4} and all three execution modes, every buffer a
/// [`ContinuousBatcher`] completes equals `translate` of that request
/// alone via the existing cached path — whatever mixed-age batches the
/// scheduler happened to form. This is the slot-independence contract the
/// continuous serving path rests on.
#[test]
fn prop_continuous_decode_bit_identical_to_sequential() {
    use std::collections::BTreeMap;

    use itera_llm::coordinator::ContinuousBatcher;
    use itera_llm::model::PairModel;
    use itera_llm::runtime::{Mode, NativeBackend, TranslateBackend};
    use itera_llm::testkit::tinymodel;

    let (dir, manifest) =
        tinymodel::generate_in_temp("prop_batcher", 0xBA7C4).expect("generate tiny model");
    let model = PairModel::load(&manifest, tinymodel::PAIR).expect("load tiny model");
    let dims = manifest.model.clone();
    let s = dims.seq_len;

    // One compressed bank per (word length, family), built once and
    // shared across cases.
    let wls = [4u32, 6, 8];
    let mut dense_banks: Vec<BTreeMap<String, CompressedLinear>> = Vec::new();
    let mut factored_banks: Vec<BTreeMap<String, CompressedLinear>> = Vec::new();
    for &wl in &wls {
        dense_banks.push(
            manifest
                .linears
                .iter()
                .map(|l| (l.name.clone(), quant_only(model.linear(&l.name), wl)))
                .collect(),
        );
        factored_banks.push(
            manifest
                .linears
                .iter()
                .map(|l| {
                    let r = (l.r_max / 2).max(1);
                    (l.name.clone(), itera(model.linear(&l.name), r, wl).0)
                })
                .collect(),
        );
    }

    check("continuous-vs-sequential", 10, |g: &mut Gen| {
        let wi = g.usize_in(0, wls.len() - 1);
        let wl = wls[wi];
        let workers = *g.pick(&[1usize, 4]);
        let mode = *g.pick(&[Mode::Dense, Mode::Svd, Mode::Quantized]);
        let layers = match mode {
            Mode::Dense => &dense_banks[wi],
            Mode::Svd => &factored_banks[wi],
            // The packed runtime executes either structure (and the
            // cascade exercises both qkernel scale axes).
            Mode::Quantized => {
                if g.bool() {
                    &dense_banks[wi]
                } else {
                    &factored_banks[wi]
                }
            }
        };
        let backend = NativeBackend::new(&manifest, &model, layers, Some(8), mode, workers)
            .expect("backend");

        // Ragged requests: BOS-framed, EOS-terminated, PAD-padded rows of
        // random content length.
        let n_req = g.usize_in(1, 8);
        let rows: Vec<Vec<i32>> = (0..n_req)
            .map(|_| {
                let len = g.usize_in(1, s - 3);
                let mut row = vec![dims.pad_id; s];
                row[0] = dims.bos_id;
                let toks = g.tokens(len, dims.vocab as i32);
                row[1..1 + len].copy_from_slice(&toks);
                row[1 + len] = dims.eos_id;
                row
            })
            .collect();

        // Sequential reference: each request decoded alone (cached path).
        let want: Vec<Vec<i32>> = rows
            .iter()
            .map(|r| backend.translate(r).expect("sequential translate"))
            .collect();

        // Continuous run under a random staggered arrival trace: a
        // random capacity, a random initial backlog, and 0..=2 new
        // arrivals before each tick.
        let capacity = g.usize_in(1, 4);
        let mut batcher = ContinuousBatcher::new(&backend, capacity);
        let mut submitted = 0usize;
        let mut got: Vec<Option<Vec<i32>>> = vec![None; n_req];
        let upfront = g.usize_in(1, n_req);
        while submitted < upfront {
            batcher.submit(rows[submitted].clone()).expect("unbounded submit");
            submitted += 1;
        }
        while !(submitted == n_req && batcher.idle()) {
            let arrivals = g.usize_in(0, 2).min(n_req - submitted);
            for _ in 0..arrivals {
                batcher.submit(rows[submitted].clone()).expect("unbounded submit");
                submitted += 1;
            }
            if batcher.idle() && submitted < n_req {
                // Never stall the trace: an idle batcher with requests
                // still unsubmitted must receive at least one.
                batcher.submit(rows[submitted].clone()).expect("unbounded submit");
                submitted += 1;
            }
            for c in batcher.tick() {
                let toks = c.result.expect("fault-free trace completes cleanly");
                got[c.id as usize] = Some(toks);
            }
        }

        for (i, w) in want.iter().enumerate() {
            let g_i = got[i].as_ref().expect("every request completes");
            assert_eq!(
                g_i, w,
                "request {i}/{n_req} diverged (mode {mode:?}, W{wl}, workers={workers}, \
                 capacity={capacity})"
            );
        }
    });
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------------- representation

#[test]
fn prop_rank_padding_is_exact() {
    // Zero-padding factors to r_max must not change the effective matrix —
    // the invariant the single-artifact runtime trick rests on.
    check("rank-padding", CASES, |g: &mut Gen| {
        let k = g.size(2, 32);
        let n = g.size(2, 32);
        let a = g.matrix(k, n, 0.5);
        let r = g.usize_in(1, k.min(n));
        let (c, _) = itera(&a, r, 4);
        if let CompressedLinear::LowRank { w1, w2, .. } = &c {
            let rmax = k.min(n);
            let p1 = w1.pad_to(k, rmax);
            let p2 = w2.pad_to(rmax, n);
            let full = p1.matmul(&p2);
            let trunc = w1.matmul(w2);
            for (x, y) in full.data().iter().zip(trunc.data()) {
                assert!((x - y).abs() < 1e-5);
            }
        }
    });
}

// ----------------------------------------------------------------- json

/// Random finite JSON number drawn from the writer's interesting
/// classes: small and large integers (the `< 1e15` i64 fast path —
/// 2^49 keeps them f64-exact), f32-exact fractions and small-magnitude
/// values (the shortest-repr `Display` path). `-0.0` canonicalizes to
/// `0.0`: the writer prints both as `0`, so the sign of zero is outside
/// the round-trip contract.
fn gen_number(g: &mut Gen) -> f64 {
    let sign = if g.bool() { -1.0 } else { 1.0 };
    let x = match g.usize_in(0, 3) {
        0 => g.usize_in(0, 999) as f64,
        1 => g.usize_in(0, (1u64 << 49) as usize) as f64,
        2 => f64::from(g.f32_in(0.0, 1e6)),
        _ => f64::from(g.normal()) * 1e-3,
    };
    let v = sign * x;
    if v == 0.0 {
        0.0
    } else {
        v
    }
}

/// Random string over a palette that exercises every escape class: the
/// mandatory `\"` / `\\`, whitespace escapes, raw control bytes (the
/// `\uXXXX` writer path), JSON syntax characters inside strings, and
/// multi-byte UTF-8 (two-, three- and four-byte sequences).
fn gen_string(g: &mut Gen) -> String {
    #[rustfmt::skip]
    const PALETTE: &[&str] = &[
        "a", "Z", "7", " ", "\"", "\\", "\n", "\r", "\t", "\u{1}", "\u{1f}", "/", "{", "]",
        ":", ",", "é", "λ", "你", "🦀", "\u{fffd}",
    ];
    let len = g.usize_in(0, 8);
    (0..len).map(|_| *g.pick(PALETTE)).collect()
}

/// Random JSON value with nesting bounded by `depth`.
fn gen_json(g: &mut Gen, depth: usize) -> Json {
    if depth == 0 || g.bool() {
        match g.usize_in(0, 3) {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num(gen_number(g)),
            _ => Json::Str(gen_string(g)),
        }
    } else if g.bool() {
        let n = g.usize_in(0, 4);
        Json::Arr((0..n).map(|_| gen_json(g, depth - 1)).collect())
    } else {
        let n = g.usize_in(0, 4);
        Json::Obj(
            (0..n)
                .map(|i| (format!("k{i}{}", gen_string(g)), gen_json(g, depth - 1)))
                .collect(),
        )
    }
}

/// Structural equality with **bit-exact** numbers (`PartialEq` on f64
/// would pass 0.0 == -0.0 and fail NaN == NaN; `to_bits` does neither).
fn assert_json_bits_eq(a: &Json, b: &Json, path: &str) {
    match (a, b) {
        (Json::Num(x), Json::Num(y)) => {
            assert_eq!(x.to_bits(), y.to_bits(), "{path}: {x} vs {y}");
        }
        (Json::Arr(xs), Json::Arr(ys)) => {
            assert_eq!(xs.len(), ys.len(), "{path}: array length");
            for (i, (x, y)) in xs.iter().zip(ys).enumerate() {
                assert_json_bits_eq(x, y, &format!("{path}[{i}]"));
            }
        }
        (Json::Obj(xm), Json::Obj(ym)) => {
            assert_eq!(xm.len(), ym.len(), "{path}: key count");
            for ((kx, x), (ky, y)) in xm.iter().zip(ym.iter()) {
                assert_eq!(kx, ky, "{path}: key");
                assert_json_bits_eq(x, y, &format!("{path}.{kx}"));
            }
        }
        _ => assert_eq!(a, b, "{path}"),
    }
}

/// write -> parse is the identity, bit for bit: every finite number
/// (integer fast path and shortest-repr `Display` path alike), every
/// escape class, arbitrary nesting. The wire format the HTTP layer
/// speaks is exactly the in-memory value.
#[test]
fn prop_json_round_trips_bit_exact() {
    check("json-roundtrip", CASES, |g: &mut Gen| {
        let v = gen_json(g, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("re-parse {text:?}: {e}"));
        assert_json_bits_eq(&v, &back, "$");
        // Writing the re-parsed value is a fixed point of the encoding.
        assert_eq!(text, back.to_string(), "write-parse-write must be stable");
        // The pretty writer encodes the same value.
        let pretty = Json::parse(&v.to_string_pretty()).expect("pretty output parses");
        assert_json_bits_eq(&v, &pretty, "$ (pretty)");
    });
}

/// Non-finite numbers are unrepresentable in JSON: wherever they sit in
/// a structure, the writer emits `null` (parseable) rather than `NaN` /
/// `inf` (which would poison every downstream consumer of a report).
#[test]
fn prop_json_non_finite_writes_as_null() {
    check("json-nonfinite", CASES, |g: &mut Gen| {
        let bad = *g.pick(&[f64::NAN, f64::INFINITY, f64::NEG_INFINITY]);
        let v = Json::obj(vec![
            ("ok", Json::Num(gen_number(g))),
            ("bad", Json::Num(bad)),
            ("arr", Json::Arr(vec![Json::Num(bad), Json::Bool(true)])),
        ]);
        let back = Json::parse(&v.to_string()).expect("output must stay parseable");
        assert_eq!(back.get("bad"), &Json::Null);
        assert_eq!(back.get("arr").idx(0), &Json::Null);
        assert!(matches!(back.get("ok"), Json::Num(_)));
    });
}

/// The parser is total on arbitrary text: random byte-level mutations
/// of valid documents (truncations, byte flips, syntax-char insertions)
/// must produce `Ok` or a typed `JsonError` — never a panic (the `check`
/// harness converts panics into failures). Successful parses must also
/// re-serialize without panicking: the HTTP server runs this exact
/// parse on every untrusted request body.
#[test]
fn prop_json_parser_total_on_mutated_input() {
    check("json-fuzz", CASES, |g: &mut Gen| {
        let mut bytes = gen_json(g, 3).to_string().into_bytes();
        for _ in 0..g.usize_in(1, 4) {
            if bytes.is_empty() {
                break;
            }
            let i = g.usize_in(0, bytes.len() - 1);
            match g.usize_in(0, 2) {
                0 => bytes.truncate(i),
                1 => bytes[i] = bytes[i].wrapping_add(g.usize_in(1, 255) as u8),
                _ => {
                    const SYNTAX: &[u8] = b"{}[]\",:0e.x\\";
                    bytes.insert(i, SYNTAX[g.usize_in(0, SYNTAX.len() - 1)]);
                }
            }
        }
        // Mutations may break UTF-8; `parse` takes &str, so gate first.
        if let Ok(text) = String::from_utf8(bytes) {
            if let Ok(v) = Json::parse(&text) {
                let _ = v.to_string();
            }
        }
    });
}

// ------------------------------------------------------ fault tolerance

/// Chaos traces preserve FIFO completion of surviving requests: under
/// seeded random fault injection (born-poisoned admissions, scripted
/// step faults/panics, stalling slots), random deadlines, bounded-queue
/// shedding and random client cancels, every submission still gets
/// exactly one terminal outcome, the batcher's books balance, and the
/// requests that survive complete in submission order with outputs
/// bit-identical to a fault-free run (all requests have equal decode
/// length, so FIFO admission implies FIFO completion).
#[test]
fn prop_chaos_traces_preserve_fifo_completion() {
    use std::collections::HashMap;

    use itera_llm::coordinator::{ContinuousBatcher, RequestLimits, ServeError};
    use itera_llm::runtime::SlotEngine;
    use itera_llm::testkit::faultkit::{FaultPlan, FaultyEngine};

    /// Equal-length mock: every request decodes in exactly `need` steps
    /// and outputs `[tag, need]` — so surviving completions must arrive
    /// in submission order, whatever faults hit their neighbors.
    struct EqualEngine {
        seq: usize,
        need: usize,
    }

    struct EqSlot {
        len: usize,
        tag: i32,
    }

    impl SlotEngine for EqualEngine {
        type Slot = EqSlot;
        fn slot_seq_len(&self) -> usize {
            self.seq
        }
        fn admit(&self, src_row: &[i32]) -> anyhow::Result<EqSlot> {
            anyhow::ensure!(src_row.len() == self.seq, "framing");
            Ok(EqSlot { len: 0, tag: src_row[0] })
        }
        fn step(&self, slots: &mut [&mut EqSlot]) -> anyhow::Result<()> {
            for s in slots.iter_mut() {
                s.len += 1;
            }
            Ok(())
        }
        fn slot_complete(&self, s: &EqSlot) -> bool {
            s.len >= self.need
        }
        fn slot_output(&self, s: &EqSlot) -> Vec<i32> {
            vec![s.tag, s.len as i32]
        }
    }

    const NEED: usize = 3;

    /// Drain one tick's completions into the exactly-once ledger.
    fn drain(
        b: &mut ContinuousBatcher<FaultyEngine<EqualEngine>>,
        id_to_req: &HashMap<u64, usize>,
        outcomes: &mut [usize],
        served: &mut Vec<usize>,
    ) {
        for c in b.tick() {
            let i = id_to_req[&c.id];
            outcomes[i] += 1;
            if let Ok(toks) = &c.result {
                assert_eq!(
                    toks,
                    &vec![i as i32, NEED as i32],
                    "survivor {i} must be bit-identical to the fault-free run"
                );
                served.push(i);
            }
        }
    }

    check("chaos-fifo", 25, |g: &mut Gen| {
        let seq = 12;
        let inner = EqualEngine { seq, need: NEED };
        let plan = FaultPlan {
            seed: g.case_seed,
            admit_fault: 0.15,
            step_fault: 0.2,
            panic_frac: 0.5,
            stall: 0.15,
        };
        let engine = FaultyEngine::new(&inner, plan);
        let capacity = g.usize_in(1, 3);
        let queue_limit = g.usize_in(1, 4);
        let mut b = ContinuousBatcher::new(&engine, capacity).with_queue_limit(queue_limit);
        // Generous deadline: clean requests always beat it, stalled
        // slots never do — the drain is guaranteed to terminate.
        let limits = RequestLimits::none().with_deadline(32);

        let n_req = g.usize_in(4, 16);
        // outcomes[i] counts terminal outcomes for submission i — the
        // exactly-once ledger.
        let mut outcomes = vec![0usize; n_req];
        let mut id_to_req: HashMap<u64, usize> = HashMap::new();
        let mut served: Vec<usize> = Vec::new();

        for i in 0..n_req {
            let mut row = vec![0i32; seq];
            row[0] = i as i32;
            match b.submit_with(row, limits) {
                Ok(id) => {
                    id_to_req.insert(id, i);
                    if g.usize_in(0, 9) == 0 {
                        // A client walks away right after submitting.
                        assert!(b.cancel(id), "fresh submission is cancellable");
                        outcomes[i] += 1;
                    }
                }
                Err(ServeError::Overloaded) => outcomes[i] += 1, // the shed IS the outcome
                Err(e) => panic!("unexpected submit error {e}"),
            }
            for _ in 0..g.usize_in(0, 2) {
                drain(&mut b, &id_to_req, &mut outcomes, &mut served);
            }
        }
        while !b.idle() {
            drain(&mut b, &id_to_req, &mut outcomes, &mut served);
        }

        for (i, &n) in outcomes.iter().enumerate() {
            assert_eq!(n, 1, "submission {i} must get exactly one terminal outcome");
        }
        // FIFO of survivors: equal-length requests admitted FIFO must
        // complete in submission order, whatever chaos hit the rest.
        assert!(
            served.windows(2).all(|w| w[0] < w[1]),
            "surviving completions out of order: {served:?}"
        );
        // The batcher's own books balance at idle.
        let s = b.stats();
        assert_eq!(
            n_req,
            s.retired + s.shed + s.expired + s.cancelled + s.faulted,
            "accounting identity: {s:?}"
        );
    });
}

// ------------------------------------------------------------- kv pool

/// Random alloc/grow/evict/retire traces over the paged KV allocator:
/// after every operation the pool's accounting is exact
/// (`outstanding_pages` equals the census over live page tables,
/// `resident_bytes` and `free_pages` follow arithmetically, and the
/// stats snapshot agrees), no page is ever shared between tables (every
/// written row reads back its writer's pattern, whatever evictions and
/// reuses happened around it), and the trace ends with zero leaks.
#[test]
fn prop_kv_pool_accounting_exact_and_leak_free() {
    use std::sync::Arc;

    use itera_llm::runtime::{KvPool, PagedRows};

    check("kvpool-trace", CASES, |g: &mut Gen| {
        let pt = g.usize_in(1, 4);
        let w = g.usize_in(1, 8);
        let cap = g.usize_in(1, 12);
        let page_bytes = pt * w * 4;
        // A sub-page remainder on top of the budget must floor away.
        let slack = g.usize_in(0, page_bytes - 1);
        let pool = Arc::new(KvPool::new(pt, w, Some(cap * page_bytes + slack)));
        assert_eq!(pool.capacity_pages(), Some(cap), "budget floors to whole pages");
        assert_eq!(pool.page_bytes(), page_bytes);

        // Live tables: (page table, rows written, writer tag).
        let mut tables: Vec<(PagedRows, usize, usize)> = Vec::new();
        let mut next_tag = 0usize;
        // Pattern values stay f32-exact: tag < ~60, rows < 60, w <= 8.
        let pat = |tag: usize, i: usize, c: usize| (tag * 1_000 + i * 16 + c) as f32;

        let verify = |pool: &KvPool, tables: &[(PagedRows, usize, usize)]| {
            let held: usize = tables.iter().map(|(t, _, _)| t.n_pages()).sum();
            assert_eq!(pool.outstanding_pages(), held, "pool count vs page-table census");
            assert_eq!(pool.resident_bytes(), held * pool.page_bytes());
            assert_eq!(pool.free_pages(), Some(cap - held));
            let stats = pool.stats();
            assert_eq!(stats.resident_bytes, held * pool.page_bytes());
            assert_eq!(stats.free_pages, Some(cap - held));
            assert_eq!(stats.budget_bytes, Some(cap * pool.page_bytes()));
            // No double-use: every written row still reads back its own
            // writer's pattern.
            for (t, rows, tag) in tables {
                for i in 0..*rows {
                    for (c, &v) in t.row(i).iter().enumerate() {
                        assert_eq!(v, pat(*tag, i, c), "table {tag} row {i} col {c}");
                    }
                }
            }
        };

        for _ in 0..g.usize_in(10, 40) {
            match g.usize_in(0, 4) {
                // Open a new (empty) table.
                0 => {
                    tables.push((PagedRows::new(&pool), 0, next_tag));
                    next_tag += 1;
                }
                // Grow some table by one row; success must agree with
                // the free-page count, and failure must change nothing.
                1 | 2 if !tables.is_empty() => {
                    let ti = g.usize_in(0, tables.len() - 1);
                    let free = pool.free_pages().unwrap();
                    let (t, rows, tag) = &mut tables[ti];
                    let i = *rows;
                    let needs_page = t.needs_page_for(i);
                    let ok = t.ensure_row(i);
                    assert_eq!(ok, !needs_page || free >= 1, "ensure_row vs free pages");
                    if ok {
                        for (c, v) in t.row_mut(i).iter_mut().enumerate() {
                            *v = pat(*tag, i, c);
                        }
                        *rows += 1;
                    }
                }
                // Evict: return the pages, keep the table (re-prefill
                // re-ensures from row 0 later, under a fresh tag so the
                // pattern check keeps discriminating).
                3 if !tables.is_empty() => {
                    let ti = g.usize_in(0, tables.len() - 1);
                    tables[ti].0.release();
                    tables[ti].1 = 0;
                    tables[ti].2 = next_tag;
                    next_tag += 1;
                }
                // Retire: drop the table; drop must release its pages.
                _ if !tables.is_empty() => {
                    let ti = g.usize_in(0, tables.len() - 1);
                    tables.swap_remove(ti);
                }
                _ => {}
            }
            verify(&pool, &tables);
        }
        tables.clear();
        assert_eq!(pool.outstanding_pages(), 0, "zero leaks after every trace");
        assert_eq!(pool.resident_bytes(), 0);
        assert_eq!(pool.free_bytes(), Some(cap * pool.page_bytes()));
    });
}

/// Preemption-by-eviction is invisible in the output: under a KV byte
/// budget tight enough to force evictions and re-prefill, every request
/// a [`ContinuousBatcher`] completes is bit-identical to decoding that
/// request alone — and once the batcher drains, the pool holds zero
/// pages (leak-free across evict/requeue/re-admit cycles) and every
/// preemption has a matching re-admission.
#[test]
fn prop_paged_preemption_bit_identical_and_leak_free() {
    use std::collections::HashMap;

    use itera_llm::coordinator::ContinuousBatcher;
    use itera_llm::model::PairModel;
    use itera_llm::runtime::{NativeBackend, SlotEngine, TranslateBackend};
    use itera_llm::testkit::tinymodel;

    let (dir, manifest) =
        tinymodel::generate_in_temp("prop_kvpage", 0xFA6E5).expect("generate tiny model");
    let model = PairModel::load(&manifest, tinymodel::PAIR).expect("load tiny model");
    let dims = manifest.model.clone();
    let s = dims.seq_len;

    check("paged-preemption-parity", 10, |g: &mut Gen| {
        let workers = *g.pick(&[1usize, 2]);
        let pt = g.usize_in(1, 3);
        let backend =
            NativeBackend::fp32(&manifest, &model, workers).expect("backend").with_kv_pool(None, pt);
        // Tight but admissible: one slot's worst case plus 0..=3 spare
        // pages, so concurrent decodes must collide with the budget.
        let worst = backend.slot_worst_bytes();
        let budget = worst + g.usize_in(0, 3) * backend.kv_pool().page_bytes();
        let backend = backend.with_kv_pool(Some(budget), pt);

        // Ragged requests: BOS-framed, EOS-terminated, PAD-padded rows.
        let n_req = g.usize_in(2, 6);
        let rows: Vec<Vec<i32>> = (0..n_req)
            .map(|_| {
                let len = g.usize_in(1, s - 3);
                let mut row = vec![dims.pad_id; s];
                row[0] = dims.bos_id;
                let toks = g.tokens(len, dims.vocab as i32);
                row[1..1 + len].copy_from_slice(&toks);
                row[1 + len] = dims.eos_id;
                row
            })
            .collect();

        // Sequential reference: each request decoded alone (the batch
        // path, which never touches the page pool).
        let want: Vec<Vec<i32>> =
            rows.iter().map(|r| backend.translate(r).expect("sequential translate")).collect();

        let capacity = g.usize_in(2, 4);
        let mut batcher = ContinuousBatcher::new(&backend, capacity);
        let mut id_to_req: HashMap<u64, usize> = HashMap::new();
        for (i, row) in rows.iter().enumerate() {
            let id = batcher.submit(row.clone()).expect("no queue bound: submit never sheds");
            id_to_req.insert(id, i);
        }
        let mut got: Vec<Option<Vec<i32>>> = vec![None; n_req];
        while !batcher.idle() {
            for c in batcher.tick() {
                let toks = c.result.expect("memory pressure must never fault a request");
                got[id_to_req[&c.id]] = Some(toks);
            }
        }

        for (i, w) in want.iter().enumerate() {
            let g_i = got[i].as_ref().expect("every request completes");
            assert_eq!(
                g_i, w,
                "request {i}/{n_req} diverged under preemption (pt={pt}, \
                 budget={budget}, capacity={capacity}, workers={workers})"
            );
        }
        let st = batcher.stats();
        assert_eq!(st.retired, n_req, "every request retires exactly once");
        assert_eq!(
            st.requeued, st.preempted,
            "with no deadlines, every eviction is eventually re-admitted"
        );
        assert_eq!(
            backend.kv_pool().outstanding_pages(),
            0,
            "an idle batcher holds no pages (leak across evict/re-admit)"
        );
    });
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------- obs

#[test]
fn prop_histogram_buckets_account_for_every_observation() {
    use itera_llm::obs::Histogram;
    check("hist-buckets", CASES, |g: &mut Gen| {
        // Random strictly-increasing bounds; draws land mostly in range
        // with a tail past the last bound (the overflow bucket).
        let n_bounds = g.size(1, 12);
        let mut bounds = Vec::with_capacity(n_bounds);
        let mut b = f64::from(g.f32_in(1e-4, 1e-2));
        for _ in 0..n_bounds {
            bounds.push(b);
            b *= 1.0 + f64::from(g.f32_in(0.5, 3.0));
        }
        let h = Histogram::new(&bounds);
        let n = g.size(1, 200);
        let mut values = Vec::with_capacity(n);
        for _ in 0..n {
            let v = f64::from(g.f32_in(0.0, 1.5)) * bounds[bounds.len() - 1];
            h.observe(v);
            values.push(v);
        }
        let snap = h.snapshot();
        // Totals match the ledger exactly.
        assert_eq!(snap.count, n as u64);
        let sum: f64 = values.iter().sum();
        assert!((snap.sum - sum).abs() <= 1e-9 * sum.abs().max(1.0));
        // One bucket per bound plus overflow; their counts sum to the
        // total, and the cumulative view is monotone up to it.
        assert_eq!(snap.counts.len(), bounds.len() + 1);
        assert_eq!(snap.counts.iter().sum::<u64>(), n as u64);
        let cum = snap.cumulative();
        assert!(cum.windows(2).all(|w| w[0] <= w[1]), "cumulative counts must be monotone");
        assert_eq!(*cum.last().unwrap(), n as u64);
        // Every observation landed in the `(lo, hi]` bucket its value
        // selects.
        for (i, &c) in snap.counts.iter().enumerate() {
            let lo = if i == 0 { f64::NEG_INFINITY } else { bounds[i - 1] };
            let hi = bounds.get(i).copied().unwrap_or(f64::INFINITY);
            let expect = values.iter().filter(|&&v| v > lo && v <= hi).count() as u64;
            assert_eq!(c, expect, "bucket {i} ({lo}, {hi}]");
        }
    });
}

#[test]
fn prop_histogram_quantile_brackets_true_quantile() {
    use itera_llm::obs::Histogram;
    check("hist-quantile", CASES, |g: &mut Gen| {
        // Fixed bounds covering the draw range, so every true quantile
        // has a well-defined bracketing bucket.
        let bounds = [0.125, 0.25, 0.5, 1.0];
        let h = Histogram::new(&bounds);
        let n = g.size(1, 300);
        let mut values: Vec<f64> = (0..n).map(|_| f64::from(g.f32_in(1e-3, 1.0))).collect();
        for &v in &values {
            h.observe(v);
        }
        values.sort_by(f64::total_cmp);
        let snap = h.snapshot();
        for q in [0.5, 0.9, 0.99] {
            let est = snap.quantile(q);
            // The interpolated estimate must stay inside the bucket that
            // holds the true order-statistic quantile.
            let rank = ((q * n as f64).max(1.0).ceil() as usize).min(n);
            let truth = values[rank - 1];
            let idx = bounds.partition_point(|&bb| truth > bb);
            let lo = if idx == 0 { 0.0 } else { bounds[idx - 1] };
            let hi = bounds[idx];
            assert!(
                est >= lo - 1e-12 && est <= hi + 1e-12,
                "q={q}: estimate {est} outside bucket ({lo}, {hi}] of true quantile {truth}"
            );
        }
    });
}
