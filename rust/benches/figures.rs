//! End-to-end figure regeneration benchmarks: one entry per paper
//! table/figure (DESIGN.md experiment index). Each bench times a full
//! (fast-profile) regeneration of the figure's data series so regressions
//! in any layer — compression, runtime, DSE — show up here.
//!
//! `cargo bench --bench figures [filter]`; figures needing artifacts are
//! skipped when `make artifacts` has not run. SRA-bearing figures
//! (7/8/9's search component) are exercised with the fast profile to
//! keep the suite minutes-scale.

use itera_llm::benchkit::Bench;
use itera_llm::config::ExpConfig;
use itera_llm::coordinator::{figures, Coordinator, Method};
use itera_llm::hw::Platform;

fn main() {
    let mut b = Bench::new().minimal();

    // Fig. 10 needs no artifacts — pure analytical DSE.
    b.bench("fig10/engine_pareto_512", || {
        std::hint::black_box(figures::fig10(&Platform::zcu111()));
    });

    if !itera_llm::model::Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("(artifacts not built; skipping model-dependent figure benches)");
        b.finish();
        return;
    }
    let c = Coordinator::new(ExpConfig::fast()).unwrap();
    let pair = "en-de";

    b.bench("fig1/quant_precision_sweep", || {
        std::hint::black_box(figures::fig1(&c, pair).unwrap());
    });

    b.bench("fig4/layer_sensitivity_2probes", || {
        std::hint::black_box(figures::fig4(&c, pair, &["enc0.self_q", "dec1.ff2"]).unwrap());
    });

    // Mini compression grid for figs 7/8/11/12 (6 points, no SRA) so each
    // bench sample stays bounded; `itera fig 7` runs the full version.
    let pts: Vec<_> = [
        Method::QuantOnly { wl: 8 },
        Method::QuantOnly { wl: 3 },
        Method::QuantOnly { wl: 2 },
        Method::SvdBaseline { wl: 4, rank_frac: 0.25 },
        Method::SvdIter { wl: 4, rank_frac: 0.25 },
        Method::SvdIter { wl: 3, rank_frac: 0.4 },
    ]
    .into_iter()
    .map(|m| c.measure(pair, &m).unwrap())
    .collect();

    b.bench("fig7/pareto_ratio_table", || {
        std::hint::black_box(figures::fig7(&c, pair, &pts));
    });
    b.bench("fig8/pareto_nops_table", || {
        std::hint::black_box(figures::fig8(&c, pair, &pts));
    });

    b.bench("fig9/generality_single_point", || {
        // One (pair, method) cell of the Fig. 9 bars.
        std::hint::black_box(c.measure("fr-en", &Method::QuantOnly { wl: 4 }).unwrap());
    });

    let full = Platform::zcu111();
    let quarter = Platform::zcu111_quarter_bw();
    b.bench("fig11/codesign_full_bw", || {
        std::hint::black_box(figures::fig11(&c, &pts, &full));
    });
    b.bench("fig11/codesign_quarter_bw", || {
        std::hint::black_box(figures::fig11(&c, &pts, &quarter));
    });

    let (_, cds) = figures::fig11(&c, &pts, &full);
    b.bench("fig12/occupancy_breakdown", || {
        let sel = [("pt0", &cds[0])];
        std::hint::black_box(figures::fig12(&c, &sel, &full));
    });

    b.finish();
}
