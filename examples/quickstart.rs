//! Quickstart: compress one linear layer three ways and compare.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the core API without any search loops: load the trained
//! model, pull one weight matrix, run quantization-only / plain SVD /
//! Algorithm 1 at the same budget, and print approximation error, storage
//! and operation counts — then verify the factored model through the
//! AOT-compiled PJRT artifact.

use std::collections::BTreeMap;

use anyhow::Result;
use itera_llm::compress::{self, itera, quant_only, svd_baseline};
use itera_llm::eval::evaluate_bleu;
use itera_llm::model::{Manifest, PairModel};
use itera_llm::runtime::{Engine, Mode, PjrtBackend, TranslateSession};

fn main() -> Result<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    let model = PairModel::load(&manifest, "en-de")?;

    // ---- 1. One layer, three compression methods ---------------------
    let layer = &manifest.linears[4]; // enc0.ff1 (64 x 128)
    let w = model.linear(&layer.name);
    println!(
        "layer {} ({}x{}), |W|_F = {:.3}\n",
        layer.name,
        layer.k,
        layer.n,
        w.frob_norm()
    );

    let wl = 4;
    let rank = layer.r_max / 2;
    let methods = [
        ("quant-only W4A8", quant_only(w, wl)),
        ("SVD->quant  W4A8 r/2", svd_baseline(w, rank, wl)),
        ("Algorithm 1 W4A8 r/2", itera(w, rank, wl).0),
    ];
    println!("{:<24} {:>10} {:>12} {:>12}", "method", "rel_err", "kbits", "macs@M=512");
    for (name, c) in &methods {
        let cost = compress::layer_cost(c, 512, layer.k, layer.n);
        println!(
            "{:<24} {:>10.4} {:>12.1} {:>12}",
            name,
            c.error(w) / w.frob_norm(),
            cost.bits as f64 / 1e3,
            cost.macs
        );
    }

    // ---- 2. Run the factored model through PJRT ----------------------
    let engine = Engine::cpu()?;
    let session = TranslateSession::new(&engine, &manifest, Mode::Svd)?;
    let mut layers = BTreeMap::new();
    for l in &manifest.linears {
        layers.insert(l.name.clone(), itera(model.linear(&l.name), l.r_max / 2, 4).0);
    }
    let bank = session.build_bank(&model, &layers, Some(8))?;
    let backend = PjrtBackend::new(session, bank);
    let corpus = itera_llm::eval::Corpus::load(&manifest.pairs["en-de"].corpus)?;
    let d = evaluate_bleu(&backend, &corpus, &manifest.model, 32)?;
    println!(
        "\nW4A8 Algorithm-1 model at half rank: BLEU {:.2} on 32 held-out sentences",
        d.score
    );
    println!("(FP32 reference is ~100 on this synthetic pair; `itera fig 7` runs the full sweep)");
    Ok(())
}
