"""Synthetic language-pair corpus generator.

The paper evaluates OPUS-MT on WMT2019 EN-DE and FR-EN. Neither the
pretrained Marian checkpoints nor WMT data are available in this offline
image, so we substitute two *deterministic synthetic language pairs* that a
small transformer must actually learn (see DESIGN.md §Substitutions):

* ``en-de``  — "verb-final" pair: every source token is remapped through a
  bilingual dictionary, the final verb-class token of each clause moves to
  the clause end, and noun-class tokens trigger an agreement suffix token.
* ``fr-en``  — "adjective-swap" pair: dictionary remap plus swapping each
  (adjective, noun) bigram, and a determiner-dropping rule.

Both transformations are deterministic functions of the source sentence, so
a converged model reaches a high BLEU score and compression-induced
degradation is cleanly measurable — the same role WMT plays in the paper.

Token id conventions (shared with the Rust side, see artifacts/manifest.json):
  0 = PAD, 1 = BOS, 2 = EOS; source words start at 3.
"""

from __future__ import annotations

import dataclasses

import numpy as np

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
NUM_SPECIAL = 3

# Word-class layout inside the "content" vocabulary. Each class gets a
# contiguous id range; the grammar below keys off the class.
N_NOUN = 40
N_VERB = 30
N_ADJ = 30
N_DET = 10
N_SUFFIX = 4  # agreement suffixes used by the en-de pair

VOCAB_SIZE = NUM_SPECIAL + N_NOUN + N_VERB + N_ADJ + N_DET + N_SUFFIX + 11  # 128

NOUN0 = NUM_SPECIAL
VERB0 = NOUN0 + N_NOUN
ADJ0 = VERB0 + N_VERB
DET0 = ADJ0 + N_ADJ
SUF0 = DET0 + N_DET

MAX_SRC_LEN = 18  # content tokens + EOS fits in 20 with BOS
SEQ_LEN = 20  # fixed model sequence length (padded)


def _class_of(tok: int) -> str:
    if NOUN0 <= tok < NOUN0 + N_NOUN:
        return "noun"
    if VERB0 <= tok < VERB0 + N_VERB:
        return "verb"
    if ADJ0 <= tok < ADJ0 + N_ADJ:
        return "adj"
    if DET0 <= tok < DET0 + N_DET:
        return "det"
    return "other"


def _dictionary(pair: str) -> np.ndarray:
    """Deterministic bijective token remap within each word class."""
    rng = np.random.default_rng(0xD1C7 if pair == "en-de" else 0xF2E9)
    table = np.arange(VOCAB_SIZE, dtype=np.int32)
    for lo, n in ((NOUN0, N_NOUN), (VERB0, N_VERB), (ADJ0, N_ADJ), (DET0, N_DET)):
        perm = rng.permutation(n)
        table[lo : lo + n] = lo + perm
    return table


@dataclasses.dataclass
class Corpus:
    pair: str
    src: np.ndarray  # [N, SEQ_LEN] int32, BOS ... EOS PAD*
    tgt: np.ndarray  # [N, SEQ_LEN] int32


def _gen_source_sentence(rng: np.random.Generator) -> list[int]:
    """Clause-structured sentence: (DET? ADJ? NOUN VERB){1..3}."""
    n_clauses = int(rng.integers(1, 4))
    toks: list[int] = []
    for _ in range(n_clauses):
        if rng.random() < 0.7:
            toks.append(DET0 + int(rng.integers(N_DET)))
        if rng.random() < 0.6:
            toks.append(ADJ0 + int(rng.integers(N_ADJ)))
        toks.append(NOUN0 + int(rng.integers(N_NOUN)))
        toks.append(VERB0 + int(rng.integers(N_VERB)))
        if len(toks) >= MAX_SRC_LEN - 4:
            break
    return toks[:MAX_SRC_LEN]


def translate_en_de(toks: list[int], table: np.ndarray) -> list[int]:
    """Verb-final reordering + dictionary remap + noun agreement suffix."""
    out: list[int] = []
    clause: list[int] = []

    def flush():
        nonlocal clause
        verbs = [t for t in clause if _class_of(t) == "verb"]
        rest = [t for t in clause if _class_of(t) != "verb"]
        for t in rest:
            out.append(int(table[t]))
            if _class_of(t) == "noun":
                out.append(SUF0 + t % N_SUFFIX)
        for v in verbs:
            out.append(int(table[v]))
        clause = []

    for t in toks:
        clause.append(t)
        if _class_of(t) == "verb":
            flush()
    flush()
    return out[: MAX_SRC_LEN]


def translate_fr_en(toks: list[int], table: np.ndarray) -> list[int]:
    """(adj, noun) swap + determiner dropping + dictionary remap."""
    out: list[int] = []
    i = 0
    while i < len(toks):
        t = toks[i]
        c = _class_of(t)
        if c == "det":
            i += 1  # determiners are dropped in the target language
            continue
        if c == "adj" and i + 1 < len(toks) and _class_of(toks[i + 1]) == "noun":
            out.append(int(table[toks[i + 1]]))
            out.append(int(table[t]))
            i += 2
            continue
        out.append(int(table[t]))
        i += 1
    return out[: MAX_SRC_LEN]


def _pack(toks: list[int]) -> np.ndarray:
    row = np.full(SEQ_LEN, PAD_ID, dtype=np.int32)
    row[0] = BOS_ID
    row[1 : 1 + len(toks)] = toks
    row[1 + len(toks)] = EOS_ID
    return row


def make_corpus(pair: str, n: int, seed: int) -> Corpus:
    """Generate ``n`` (source, target) sentence pairs for ``pair``."""
    assert pair in ("en-de", "fr-en"), pair
    rng = np.random.default_rng(seed)
    table = _dictionary(pair)
    xlate = translate_en_de if pair == "en-de" else translate_fr_en
    src = np.zeros((n, SEQ_LEN), dtype=np.int32)
    tgt = np.zeros((n, SEQ_LEN), dtype=np.int32)
    for i in range(n):
        s = _gen_source_sentence(rng)
        t = xlate(s, table)
        src[i] = _pack(s)
        tgt[i] = _pack(t)
    return Corpus(pair=pair, src=src, tgt=tgt)


def batches(corpus: Corpus, batch_size: int, seed: int):
    """Yield shuffled (src, tgt) batches forever."""
    rng = np.random.default_rng(seed)
    n = corpus.src.shape[0]
    while True:
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            idx = order[i : i + batch_size]
            yield corpus.src[idx], corpus.tgt[idx]
