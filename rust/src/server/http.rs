//! Dependency-free HTTP/1.1 plumbing for the inference server and its
//! clients: request/response parsing and writing over `std::io`, with
//! keep-alive, `Content-Length` bodies, and chunked transfer encoding
//! (the wire form of streaming token responses). No TLS, no HTTP/2 —
//! exactly the subset a self-contained serving stack needs, implemented
//! on the standard library alone.
//!
//! The reader ([`HttpConn`]) is generic over any byte stream and keeps
//! leftover bytes between messages, which is what makes keep-alive and
//! client-side pipelining work over plain blocking reads; the writers
//! are free functions over `impl Write`, shared by the server, the load
//! generator and the test clients.

use std::io::{self, Read, Write};

use crate::util::json::{Json, JsonError};

/// Upper bound on a request/response head (start line + headers).
const MAX_HEAD: usize = 16 * 1024;

/// How much of an oversized (413) body the server is willing to drain
/// before closing. Draining lets the rejection reach the client — a
/// close with unread bytes in the socket buffer resets the connection
/// and can destroy the in-flight response — while the bound keeps a
/// hostile content-length from pinning the handler.
const MAX_DRAIN: usize = 256 * 1024;

/// A parsed HTTP/1.1 request (server side): head + body.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub target: String,
    /// Header names lowercased, values trimmed; duplicates kept in order.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive header lookup (first match).
    pub fn header(&self, name: &str) -> Option<&str> {
        find_header(&self.headers, &name.to_ascii_lowercase())
    }

    /// The client asked for the connection to close after this exchange.
    pub fn wants_close(&self) -> bool {
        self.header("connection").is_some_and(|v| v.eq_ignore_ascii_case("close"))
    }
}

/// A parsed HTTP/1.1 response (client side). Chunked bodies arrive
/// already reassembled.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        find_header(&self.headers, &name.to_ascii_lowercase())
    }

    /// Parse the body as JSON.
    pub fn json(&self) -> Result<Json, JsonError> {
        match std::str::from_utf8(&self.body) {
            Ok(s) => Json::parse(s),
            Err(_) => Err(JsonError { pos: 0, msg: "body is not utf-8".to_string() }),
        }
    }
}

fn find_header<'a>(headers: &'a [(String, String)], lower_name: &str) -> Option<&'a str> {
    headers.iter().find(|(k, _)| k == lower_name).map(|(_, v)| v.as_str())
}

/// Why reading the next message off a connection failed.
#[derive(Debug)]
pub enum RecvError {
    /// Clean EOF on a message boundary: the peer is done.
    Closed,
    /// The socket's read timeout elapsed. Buffered bytes are kept — call
    /// again to keep waiting (the server's drain-aware idle loop).
    Idle,
    /// Malformed or oversized message — answer 400 (if serving) and
    /// close; the stream position can no longer be trusted.
    Bad(String),
    /// Declared body length exceeds the configured cap (413).
    TooLarge,
    Io(io::Error),
}

impl std::fmt::Display for RecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecvError::Closed => write!(f, "connection closed"),
            RecvError::Idle => write!(f, "read timed out"),
            RecvError::Bad(m) => write!(f, "malformed message: {m}"),
            RecvError::TooLarge => write!(f, "body exceeds the configured cap"),
            RecvError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for RecvError {}

/// Buffered HTTP message reader over any byte stream.
pub struct HttpConn<S> {
    stream: S,
    buf: Vec<u8>,
}

impl<S: Read> HttpConn<S> {
    pub fn new(stream: S) -> HttpConn<S> {
        HttpConn { stream, buf: Vec::new() }
    }

    /// The underlying stream (for writing responses/requests back).
    pub fn get_mut(&mut self) -> &mut S {
        &mut self.stream
    }

    /// Pull more bytes into the buffer. `Ok(false)` on EOF.
    fn fill(&mut self) -> Result<bool, RecvError> {
        let mut chunk = [0u8; 4096];
        match self.stream.read(&mut chunk) {
            Ok(0) => Ok(false),
            Ok(n) => {
                self.buf.extend_from_slice(&chunk[..n]);
                Ok(true)
            }
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                Err(RecvError::Idle)
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => Ok(true),
            Err(e) => Err(RecvError::Io(e)),
        }
    }

    /// Index just past the `\r\n\r\n` head terminator, if buffered.
    fn head_end(&self) -> Option<usize> {
        self.buf.windows(4).position(|w| w == b"\r\n\r\n").map(|i| i + 4)
    }

    /// Block until a full head is buffered; returns its length.
    fn read_head(&mut self) -> Result<usize, RecvError> {
        loop {
            if let Some(end) = self.head_end() {
                return Ok(end);
            }
            if self.buf.len() > MAX_HEAD {
                return Err(RecvError::Bad("head exceeds 16 KiB".to_string()));
            }
            if !self.fill()? {
                return if self.buf.is_empty() {
                    Err(RecvError::Closed)
                } else {
                    Err(RecvError::Bad("connection closed mid-head".to_string()))
                };
            }
        }
    }

    /// Take exactly `len` bytes off the front of the stream.
    fn read_exact_buf(&mut self, len: usize) -> Result<Vec<u8>, RecvError> {
        while self.buf.len() < len {
            if !self.fill()? {
                return Err(RecvError::Bad("connection closed mid-body".to_string()));
            }
        }
        let out = self.buf[..len].to_vec();
        self.buf.drain(..len);
        Ok(out)
    }

    /// One CRLF-terminated line (chunk-size framing).
    fn read_line(&mut self) -> Result<String, RecvError> {
        loop {
            if let Some(i) = self.buf.windows(2).position(|w| w == b"\r\n") {
                let line = String::from_utf8_lossy(&self.buf[..i]).into_owned();
                self.buf.drain(..i + 2);
                return Ok(line);
            }
            if self.buf.len() > MAX_HEAD {
                return Err(RecvError::Bad("line exceeds 16 KiB".to_string()));
            }
            if !self.fill()? {
                return Err(RecvError::Bad("connection closed mid-line".to_string()));
            }
        }
    }

    /// Read one full request (head + `Content-Length` body).
    pub fn read_request(&mut self, max_body: usize) -> Result<HttpRequest, RecvError> {
        let head_len = self.read_head()?;
        let (start, headers) = parse_head(&self.buf[..head_len])?;
        let mut parts = start.split(' ');
        let method = parts.next().unwrap_or("").to_string();
        let target = parts.next().unwrap_or("").to_string();
        let version = parts.next().unwrap_or("");
        if method.is_empty() || target.is_empty() || !version.starts_with("HTTP/1.") {
            return Err(RecvError::Bad(format!("malformed request line: {start:?}")));
        }
        if find_header(&headers, "transfer-encoding").is_some() {
            return Err(RecvError::Bad("chunked request bodies are not supported".to_string()));
        }
        let body_len = match find_header(&headers, "content-length") {
            None => 0,
            Some(v) => v
                .trim()
                .parse::<usize>()
                .map_err(|_| RecvError::Bad(format!("bad content-length: {v:?}")))?,
        };
        if body_len > max_body {
            self.buf.drain(..head_len);
            if body_len <= MAX_DRAIN {
                // Best effort: an Idle/EOF mid-drain still rejects.
                let _ = self.read_exact_buf(body_len);
            }
            return Err(RecvError::TooLarge);
        }
        self.buf.drain(..head_len);
        let body = self.read_exact_buf(body_len)?;
        Ok(HttpRequest { method, target, headers, body })
    }

    /// Read one full response; chunked bodies are reassembled.
    pub fn read_response(&mut self) -> Result<HttpResponse, RecvError> {
        let head_len = self.read_head()?;
        let (start, headers) = parse_head(&self.buf[..head_len])?;
        let status = start
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse::<u16>().ok())
            .ok_or_else(|| RecvError::Bad(format!("malformed status line: {start:?}")))?;
        self.buf.drain(..head_len);
        let chunked = find_header(&headers, "transfer-encoding")
            .is_some_and(|v| v.to_ascii_lowercase().contains("chunked"));
        let body = if chunked {
            let mut body = Vec::new();
            loop {
                let line = self.read_line()?;
                let size = usize::from_str_radix(line.trim(), 16)
                    .map_err(|_| RecvError::Bad(format!("bad chunk size: {line:?}")))?;
                // Chunk data is followed by its own CRLF; the terminal
                // 0-chunk's trailing CRLF closes the body.
                let chunk = self.read_exact_buf(size + 2)?;
                if size == 0 {
                    break;
                }
                body.extend_from_slice(&chunk[..size]);
            }
            body
        } else {
            let len = match find_header(&headers, "content-length") {
                None => 0,
                Some(v) => v
                    .trim()
                    .parse::<usize>()
                    .map_err(|_| RecvError::Bad(format!("bad content-length: {v:?}")))?,
            };
            self.read_exact_buf(len)?
        };
        Ok(HttpResponse { status, headers, body })
    }
}

/// Split a head block (bytes up to and including the blank line) into
/// its start line and lowercased header pairs.
fn parse_head(head: &[u8]) -> Result<(String, Vec<(String, String)>), RecvError> {
    let text = std::str::from_utf8(&head[..head.len() - 4])
        .map_err(|_| RecvError::Bad("head is not utf-8".to_string()))?;
    let mut lines = text.split("\r\n");
    let start = lines.next().unwrap_or("").to_string();
    let mut headers = Vec::new();
    for line in lines {
        let Some((name, value)) = line.split_once(':') else {
            return Err(RecvError::Bad(format!("malformed header line: {line:?}")));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    Ok((start, headers))
}

/// Standard reason phrase for the status codes this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write a complete JSON response with `Content-Length` framing.
pub fn write_response(w: &mut impl Write, status: u16, body: &Json, close: bool) -> io::Result<()> {
    let payload = body.to_string();
    let conn = if close { "close" } else { "keep-alive" };
    write!(
        w,
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: {conn}\r\n\r\n{payload}",
        reason(status),
        payload.len(),
    )?;
    w.flush()
}

/// Write a complete plain-text response with `Content-Length` framing
/// (the Prometheus text exposition on `GET /metrics`).
pub fn write_text_response(
    w: &mut impl Write,
    status: u16,
    body: &str,
    close: bool,
) -> io::Result<()> {
    let conn = if close { "close" } else { "keep-alive" };
    write!(
        w,
        "HTTP/1.1 {status} {}\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: {conn}\r\n\r\n{body}",
        reason(status),
        body.len(),
    )?;
    w.flush()
}

/// Start a chunked (streaming) response; follow with [`write_chunk`]
/// calls and one [`finish_chunks`].
pub fn write_chunked_head(w: &mut impl Write, status: u16) -> io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\n\
         Transfer-Encoding: chunked\r\nConnection: keep-alive\r\n\r\n",
        reason(status),
    )?;
    w.flush()
}

/// One body chunk. Empty data is skipped (an empty chunk would
/// terminate the body early — that is [`finish_chunks`]' job).
pub fn write_chunk(w: &mut impl Write, data: &[u8]) -> io::Result<()> {
    if data.is_empty() {
        return Ok(());
    }
    write!(w, "{:x}\r\n", data.len())?;
    w.write_all(data)?;
    w.write_all(b"\r\n")?;
    w.flush()
}

/// Terminate a chunked body.
pub fn finish_chunks(w: &mut impl Write) -> io::Result<()> {
    w.write_all(b"0\r\n\r\n")?;
    w.flush()
}

/// Write a client request; `body` adds JSON + `Content-Length` framing.
pub fn write_request(
    w: &mut impl Write,
    method: &str,
    path: &str,
    body: Option<&Json>,
) -> io::Result<()> {
    match body {
        Some(j) => {
            let payload = j.to_string();
            write!(
                w,
                "{method} {path} HTTP/1.1\r\nHost: itera\r\n\
                 Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{payload}",
                payload.len(),
            )?;
        }
        None => write!(w, "{method} {path} HTTP/1.1\r\nHost: itera\r\n\r\n")?,
    }
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    use std::io::Cursor;

    #[test]
    fn parses_pipelined_requests_with_bodies() {
        let wire = b"POST /v1/translate HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd\
                     GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut conn = HttpConn::new(Cursor::new(wire.to_vec()));
        let r1 = conn.read_request(1024).unwrap();
        assert_eq!(r1.method, "POST");
        assert_eq!(r1.target, "/v1/translate");
        assert_eq!(r1.body, b"abcd");
        assert_eq!(r1.header("Host"), Some("x"), "header lookup is case-insensitive");
        assert!(!r1.wants_close());
        let r2 = conn.read_request(1024).unwrap();
        assert_eq!(r2.method, "GET");
        assert!(r2.body.is_empty());
        assert!(r2.wants_close());
        assert!(matches!(conn.read_request(1024), Err(RecvError::Closed)), "clean EOF");
    }

    #[test]
    fn rejects_oversized_and_malformed() {
        let wire = b"POST /x HTTP/1.1\r\nContent-Length: 999\r\n\r\n";
        let mut conn = HttpConn::new(Cursor::new(wire.to_vec()));
        assert!(matches!(conn.read_request(10), Err(RecvError::TooLarge)));

        let mut conn = HttpConn::new(Cursor::new(b"garbage\r\n\r\n".to_vec()));
        assert!(matches!(conn.read_request(10), Err(RecvError::Bad(_))));

        let mut conn = HttpConn::new(Cursor::new(b"GET /x HTTP/1.1\r\nnocolon\r\n\r\n".to_vec()));
        assert!(matches!(conn.read_request(10), Err(RecvError::Bad(_))));

        // EOF mid-head is not a clean close.
        let mut conn = HttpConn::new(Cursor::new(b"GET /x HT".to_vec()));
        assert!(matches!(conn.read_request(10), Err(RecvError::Bad(_))));
    }

    #[test]
    fn response_roundtrip_content_length() {
        let body = Json::obj(vec![("ok", Json::Bool(true)), ("n", Json::Num(3.0))]);
        let mut wire = Vec::new();
        write_response(&mut wire, 200, &body, false).unwrap();
        let mut conn = HttpConn::new(Cursor::new(wire));
        let resp = conn.read_response().unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("content-type"), Some("application/json"));
        assert_eq!(resp.json().unwrap(), body);
    }

    #[test]
    fn text_response_roundtrip() {
        let mut wire = Vec::new();
        write_text_response(&mut wire, 200, "a_total 3\n", false).unwrap();
        let mut conn = HttpConn::new(Cursor::new(wire));
        let resp = conn.read_response().unwrap();
        assert_eq!(resp.status, 200);
        assert!(resp.header("content-type").unwrap_or("").starts_with("text/plain"));
        assert_eq!(resp.body, b"a_total 3\n");
    }

    #[test]
    fn response_roundtrip_chunked() {
        let mut wire = Vec::new();
        write_chunked_head(&mut wire, 200).unwrap();
        write_chunk(&mut wire, b"{\"a\":1}\n").unwrap();
        write_chunk(&mut wire, b"").unwrap(); // skipped, not terminal
        write_chunk(&mut wire, b"{\"b\":2}\n").unwrap();
        finish_chunks(&mut wire).unwrap();
        let mut conn = HttpConn::new(Cursor::new(wire));
        let resp = conn.read_response().unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"{\"a\":1}\n{\"b\":2}\n", "chunks reassemble in order");
    }

    #[test]
    fn request_writer_roundtrips_through_parser() {
        let body = Json::obj(vec![("tokens", Json::arr_f64(&[1.0, 2.0]))]);
        let mut wire = Vec::new();
        write_request(&mut wire, "POST", "/v1/translate", Some(&body)).unwrap();
        write_request(&mut wire, "GET", "/healthz", None).unwrap();
        let mut conn = HttpConn::new(Cursor::new(wire));
        let r1 = conn.read_request(1 << 20).unwrap();
        assert_eq!(r1.method, "POST");
        assert_eq!(Json::parse(std::str::from_utf8(&r1.body).unwrap()).unwrap(), body);
        let r2 = conn.read_request(1 << 20).unwrap();
        assert_eq!((r2.method.as_str(), r2.target.as_str()), ("GET", "/healthz"));
    }
}
