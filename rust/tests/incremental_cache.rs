//! Integration: the incremental compression cache against the recompute
//! path on a synthetic multi-layer model.
//!
//! Pins the PR's acceptance criteria: an SRA run backed by real
//! compression performs each `(layer, wl)` compression **at most once**,
//! follows the exact same search trajectory as the recompute oracle, and
//! spends >= 5x fewer itera matvec-equivalents with every layer probed
//! each iteration (`probe_layers = 0`).

use itera_llm::compress::{itera, CompressionCache};
use itera_llm::sra::{self, ProxyOracle, SraConfig};
use itera_llm::tensor::Matrix;
use itera_llm::util::rng::Pcg64;

/// Synthetic multi-layer model with per-layer outlier structure so the
/// sensitivity search has a real gradient to follow.
fn synthetic_model(layers: usize, dim: usize) -> Vec<Matrix> {
    let mut rng = Pcg64::new(0xCAFE);
    (0..layers)
        .map(|i| {
            let mut w = Matrix::randn(dim, dim, &mut rng).scale(0.1);
            let col = i % dim;
            for r in 0..dim {
                w.set(r, col, w.get(r, col) * (2.0 + i as f32));
            }
            w
        })
        .collect()
}

#[test]
fn cached_factors_match_fresh_compression() {
    let layers = synthetic_model(3, 16);
    let refs: Vec<&Matrix> = layers.iter().collect();
    let mut cache = CompressionCache::new();
    cache.fill_all(&refs, 4, 2);
    for (i, w) in layers.iter().enumerate() {
        for r in [1usize, 5, 16] {
            let fresh = itera(w, r, 4).0.effective();
            let cached = cache.query(i, 4, r).unwrap().effective();
            assert_eq!(fresh.data(), cached.data(), "layer {i} rank {r}");
        }
    }
    assert_eq!(cache.fills(), 3, "three layers, three decompositions, ever");
}

#[test]
fn sra_with_cache_compresses_each_layer_once_and_is_5x_cheaper() {
    let layers = synthetic_model(6, 24);
    // Budget at 3/4 of total capacity: the search probes ranks near r_max,
    // so a recompute-backed probe costs nearly as much as one cache fill —
    // the >=5x bound below then holds with a wide margin regardless of how
    // the power-iteration sweep counts distribute across ranks.
    let budget: usize =
        layers.iter().map(|w| w.rows().min(w.cols())).sum::<usize>() * 3 / 4;
    // probe_layers = 0 probes every layer each iteration — the most
    // oracle-hungry configuration (2 evals per layer per iteration).
    let cfg = SraConfig { probe_layers: 0, max_iters: 6, patience: 3, ..Default::default() };

    let (res_cached, cached) = sra::run_cached_proxy(&layers, 4, budget, &cfg, 2);
    assert_eq!(
        cached.compressions(),
        layers.len() as u64,
        "each (layer, wl) compressed at most once"
    );

    let mut recompute = ProxyOracle::recompute(&layers, 4);
    let res_recompute = recompute.run_search(budget, &cfg);

    // Identical search trajectory: same scores, allocation and eval count.
    assert_eq!(res_cached.ranks, res_recompute.ranks);
    assert_eq!(res_cached.accuracy, res_recompute.accuracy);
    assert_eq!(res_cached.trace, res_recompute.trace);
    assert_eq!(res_cached.evals, res_recompute.evals);
    assert_eq!(res_cached.ranks.iter().sum::<usize>(), budget, "budget conserved");

    // The headline: >= 5x fewer itera matvec-equivalents.
    let cheap = cached.matvec_equivalents();
    let costly = recompute.matvec_equivalents();
    assert!(cheap > 0 && costly > 0);
    assert!(
        costly >= 5 * cheap,
        "cache must be >=5x cheaper in matvec-equivalents: recompute {costly} vs cached {cheap}"
    );
}
