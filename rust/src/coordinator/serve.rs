//! Batched serving demo: a minimal request loop over the PJRT runtime.
//!
//! Demonstrates the deployment story: single-sentence translation requests
//! arrive on a channel, a batcher groups them up to the artifact's fixed
//! batch size (padding short batches), executes one PJRT call per batch,
//! and reports per-request latency percentiles and aggregate throughput —
//! all without Python anywhere on the path.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;

use crate::eval::{strip_specials, Corpus};
use crate::runtime::{Mode, TranslateSession};
use crate::util::rng::Pcg64;
use crate::util::stats::Summary;

use super::{Coordinator, Method};

struct Request {
    tokens: Vec<i32>,
    t_arrival: Instant,
    respond: mpsc::Sender<(Vec<i32>, f64)>,
}

/// Run the serving demo: `n_requests` random test sentences, FP32 bank.
pub fn serve_demo(c: &Coordinator, pair: &str, n_requests: usize) -> Result<()> {
    let corpus = Corpus::load(&c.manifest.pairs[pair].corpus)?;
    let session = TranslateSession::new(&c.engine, &c.manifest, Mode::Dense)?;
    // Serve the W8A8 quantized model — the deployment configuration.
    let cm = c.compress(pair, &Method::QuantOnly { wl: 8 });
    let bank = session.build_bank(c.model(pair), &cm.layers, cm.act_wl)?;

    let b = session.batch();
    let s = session.seq_len();
    let dims = &c.manifest.model;

    let (tx, rx) = mpsc::channel::<Request>();

    // Client thread: submits requests back-to-back (closed-loop).
    let seq_len = s;
    let n = n_requests;
    let pad = dims.pad_id;
    let client = std::thread::spawn(move || {
        let mut rng = Pcg64::new(0xBEEF);
        let mut latencies = Summary::new();
        let mut done = Vec::new();
        let corpus = corpus;
        for _ in 0..n {
            let i = rng.below(corpus.n);
            let (rtx, rrx) = mpsc::channel();
            tx.send(Request {
                tokens: corpus.src_row(i).to_vec(),
                t_arrival: Instant::now(),
                respond: rtx,
            })
            .ok();
            // Closed-loop: wait for the response before the next request
            // (the batcher still groups concurrent stragglers via timeout).
            if let Ok((toks, lat)) = rrx.recv() {
                latencies.add(lat);
                done.push(toks);
            }
        }
        let _ = (seq_len, pad);
        (latencies, done)
    });

    // Server loop: drain the channel, batch, execute.
    let t0 = Instant::now();
    let mut served = 0usize;
    let mut batches = 0usize;
    while served < n_requests {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break,
        };
        let mut batch = vec![first];
        while batch.len() < b {
            match rx.try_recv() {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }
        let mut src = vec![dims.pad_id; b * s];
        for (row, req) in batch.iter().enumerate() {
            src[row * s..row * s + req.tokens.len().min(s)]
                .copy_from_slice(&req.tokens[..req.tokens.len().min(s)]);
        }
        let out = session.translate(&bank, &src)?;
        let now = Instant::now();
        for (row, req) in batch.iter().enumerate() {
            let toks = strip_specials(
                &out[row * s..(row + 1) * s],
                dims.bos_id,
                dims.eos_id,
                dims.pad_id,
            );
            let lat = now.duration_since(req.t_arrival).as_secs_f64();
            req.respond.send((toks, lat)).ok();
        }
        served += batch.len();
        batches += 1;
    }
    let wall = t0.elapsed().as_secs_f64();

    let (latencies, translations) = client.join().expect("client thread");
    println!("== serving demo ({pair}, W8A8, batch capacity {b}) ==");
    println!("requests      : {n_requests} ({batches} batches)");
    println!("wall time     : {wall:.2}s");
    println!("throughput    : {:.1} sentences/s", served as f64 / wall);
    println!(
        "latency (s)   : p50 {:.3}  p95 {:.3}  max {:.3}",
        latencies.quantile(0.5),
        latencies.quantile(0.95),
        latencies.max()
    );
    println!("sample output : {:?}", translations.first().map(|t| &t[..t.len().min(8)]));
    Ok(())
}

/// Compressed-model variants available to the serving example.
pub fn serve_bank<'a>(
    c: &'a Coordinator,
    session: &TranslateSession,
    pair: &str,
    method: &Method,
) -> Result<crate::runtime::ArgBank> {
    let cm = c.compress(pair, method);
    session.build_bank(c.model(pair), &cm.layers, cm.act_wl)
}

#[allow(unused)]
fn unused(_: BTreeMap<String, ()>) {}
