//! Pareto-front extraction over (cost, quality) points.

/// A point in a 2-D trade-off space: minimize `cost`, maximize `quality`.
pub trait ParetoPoint {
    fn cost(&self) -> f64;
    fn quality(&self) -> f64;
}

impl ParetoPoint for (f64, f64) {
    fn cost(&self) -> f64 {
        self.0
    }
    fn quality(&self) -> f64 {
        self.1
    }
}

/// Indices of the Pareto-optimal points (min cost, max quality), sorted by
/// ascending cost. A point is dominated if another has `cost <=` and
/// `quality >=` with at least one strict.
pub fn pareto_front<P: ParetoPoint>(points: &[P]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        points[a]
            .cost()
            .partial_cmp(&points[b].cost())
            .unwrap()
            .then(points[b].quality().partial_cmp(&points[a].quality()).unwrap())
    });
    let mut front = Vec::new();
    let mut best_q = f64::NEG_INFINITY;
    for &i in &idx {
        if points[i].quality() > best_q {
            front.push(i);
            best_q = points[i].quality();
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extracts_staircase() {
        let pts = vec![
            (1.0, 1.0), // front
            (1.0, 0.5), // dominated (same cost, lower quality)
            (2.0, 3.0), // front
            (3.0, 2.0), // dominated by (2,3)
            (4.0, 4.0), // front
        ];
        let f = pareto_front(&pts);
        assert_eq!(f, vec![0, 2, 4]);
    }

    #[test]
    fn single_and_empty() {
        assert_eq!(pareto_front(&Vec::<(f64, f64)>::new()), Vec::<usize>::new());
        assert_eq!(pareto_front(&[(5.0, 5.0)]), vec![0]);
    }

    #[test]
    fn front_is_monotone() {
        // Random-ish cloud: along the returned front cost increases and
        // quality strictly increases.
        let pts: Vec<(f64, f64)> = (0..100)
            .map(|i| {
                let x = (i * 37 % 100) as f64;
                let y = (i * 61 % 97) as f64;
                (x, y)
            })
            .collect();
        let f = pareto_front(&pts);
        for w in f.windows(2) {
            assert!(pts[w[1]].0 >= pts[w[0]].0);
            assert!(pts[w[1]].1 > pts[w[0]].1);
        }
        // No front point is dominated by any cloud point.
        for &i in &f {
            for p in &pts {
                let dominates = p.0 <= pts[i].0
                    && p.1 >= pts[i].1
                    && (p.0 < pts[i].0 || p.1 > pts[i].1);
                assert!(!dominates);
            }
        }
    }
}
