//! Post-training compression engine (§III): the paper's contribution.
//!
//! Three methods over a linear layer's weight matrix `W [K x N]`:
//!
//! * [`quant_only`]      — the W`wl`A8 baseline: vector-wise fake-quant of
//!   `W` itself;
//! * [`svd_baseline`]    — plain SVD truncation to rank `r`, then
//!   vector-wise quantization of the produced factors (§VIII-B);
//! * [`itera`]           — **Algorithm 1**: SVD-based *iterative* tensor
//!   decomposition with quantization inside the refinement loop, so each
//!   rank-1 step compensates the quantization error of all previous steps.
//!
//! Size/NOps accounting for Pareto analysis lives in [`accounting`];
//! the run-once-query-any-rank engine behind the SRA/DSE search loops
//! lives in [`incremental`].

mod accounting;
pub mod incremental;
mod itera;

pub use accounting::{breakeven_rank, compression_ratio, layer_cost, nops_dense,
    nops_svd, param_bits, rank_for_ratio, LayerCost};
pub use incremental::{CompressionCache, IncrementalItera};
pub use itera::{itera, itera_opts, IteraOpts, IteraTrace};

use crate::linalg;
use crate::quant::{self, WordLen};
use crate::tensor::Matrix;

/// A compressed linear layer, ready to be fed to the runtime (dense
/// artifact for `Dense`, rank-padded SVD artifact for `LowRank`).
///
/// The matrices are fake-quant f32, but every quantized vector's dequant
/// scale is carried alongside, so each stored value is *exactly*
/// `grid_int * scale` — the invariant that lets [`crate::qkernel`]
/// re-grid the fake-quant values into bit-packed integer storage without
/// losing a single bit (re-deriving a scale from the quantized values
/// alone is only ulp-accurate, which would break the quantized runtime's
/// bit-exactness contract).
#[derive(Debug, Clone)]
pub enum CompressedLinear {
    /// Quantization-only: the full `[K x N]` fake-quantized matrix.
    Dense {
        w: Matrix,
        wl: WordLen,
        /// Per-column dequant scales of the `wl`-bit grid `w` lies on.
        /// Empty for FP-identity probe layers that bypass quantization
        /// (such layers cannot be bit-packed).
        scales: Vec<f32>,
    },
    /// Factored: `w1 [K x r]`, `w2 [r x N]`, both fake-quantized.
    LowRank {
        w1: Matrix,
        w2: Matrix,
        wl: WordLen,
        /// Per-rank scales: `s1[j]` dequantizes column `j` of `w1`.
        s1: Vec<f32>,
        /// Per-rank scales: `s2[i]` dequantizes row `i` of `w2`.
        s2: Vec<f32>,
    },
}

impl CompressedLinear {
    /// Effective weight matrix (reconstructed for LowRank).
    pub fn effective(&self) -> Matrix {
        match self {
            CompressedLinear::Dense { w, .. } => w.clone(),
            CompressedLinear::LowRank { w1, w2, .. } => w1.matmul(w2),
        }
    }

    /// Decomposition rank (full rank for Dense).
    pub fn rank(&self) -> usize {
        match self {
            CompressedLinear::Dense { w, .. } => w.rows().min(w.cols()),
            CompressedLinear::LowRank { w1, .. } => w1.cols(),
        }
    }

    pub fn word_len(&self) -> WordLen {
        match self {
            CompressedLinear::Dense { wl, .. } | CompressedLinear::LowRank { wl, .. } => *wl,
        }
    }

    /// Frobenius-norm approximation error vs the original weights.
    pub fn error(&self, w: &Matrix) -> f32 {
        self.effective().sub(w).frob_norm()
    }
}

/// Quantization-only baseline: vector-wise (per output column) fake-quant.
pub fn quant_only(w: &Matrix, wl: WordLen) -> CompressedLinear {
    let (q, scales) = quant::quantize_cols(w, wl);
    CompressedLinear::Dense { w: q, wl, scales }
}

/// Plain SVD baseline (§VIII-B): truncate to rank `r` with a *single* SVD
/// of the FP32 weights, then quantize the produced factors vector-wise
/// (per rank — each singular vector gets its own scale), matching the
/// quantization granularity of the iterative method for a fair comparison.
pub fn svd_baseline(w: &Matrix, r: usize, wl: WordLen) -> CompressedLinear {
    let r = r.clamp(1, w.rows().min(w.cols()));
    let d = linalg::svd(w);
    let (w1, w2) = linalg::factor_pair(&d, r);
    let (q1, s1) = quant::quantize_cols(&w1, wl); // per-rank scales (columns of W1)
    let (q2, s2) = quant::quantize_rows(&w2, wl); // per-rank scales (rows of W2)
    CompressedLinear::LowRank { w1: q1, w2: q2, wl, s1, s2 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn weights(seed: u64, k: usize, n: usize) -> Matrix {
        let mut rng = Pcg64::new(seed);
        Matrix::randn(k, n, &mut rng).scale(0.1)
    }

    #[test]
    fn quant_only_error_shrinks_with_bits() {
        let w = weights(1, 24, 24);
        let e4 = quant_only(&w, 4).error(&w);
        let e6 = quant_only(&w, 6).error(&w);
        let e8 = quant_only(&w, 8).error(&w);
        assert!(e8 < e6 && e6 < e4, "{e4} {e6} {e8}");
    }

    #[test]
    fn svd_baseline_error_shrinks_with_rank() {
        let w = weights(2, 20, 16);
        let mut prev = f32::INFINITY;
        for r in [2, 4, 8, 16] {
            let e = svd_baseline(&w, r, 8).error(&w);
            assert!(e <= prev + 1e-4, "rank {r}: {e} > {prev}");
            prev = e;
        }
    }

    #[test]
    fn full_rank_high_bits_near_exact() {
        let w = weights(3, 12, 12);
        let c = svd_baseline(&w, 12, 12);
        assert!(c.error(&w) < 0.02 * w.frob_norm());
    }

    #[test]
    fn effective_shapes() {
        let w = weights(4, 10, 14);
        let c = svd_baseline(&w, 3, 6);
        assert_eq!(c.rank(), 3);
        assert_eq!(c.effective().shape(), (10, 14));
        let q = quant_only(&w, 6);
        assert_eq!(q.rank(), 10);
        assert_eq!(q.effective().shape(), (10, 14));
    }
}
