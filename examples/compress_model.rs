//! Whole-model compression comparison at matched compression ratios —
//! the Fig. 7 story as a runnable example.
//!
//! ```bash
//! cargo run --release --example compress_model [-- <pair>]
//! ```
//!
//! For a grid of target compression ratios, configures each method to hit
//! the ratio and reports test-set BLEU side by side, showing the paper's
//! ordering: SVD-iterative > plain SVD, and decomposition methods
//! extending the Pareto front past quantization-only's reach.

use anyhow::Result;
use itera_llm::config::ExpConfig;
use itera_llm::coordinator::figures::ratio_to_frac;
use itera_llm::coordinator::{Coordinator, Method};

fn main() -> Result<()> {
    let pair = std::env::args().nth(1).unwrap_or_else(|| "en-de".to_string());
    let c = Coordinator::new(ExpConfig::fast())?;
    println!("pair {pair}; FP32 reference BLEU {:.2}\n", c.bleu_fp32(&pair)?);

    println!(
        "{:<8} {:<22} {:>8} {:>8} {:>10}",
        "target", "method", "ratio", "bleu", "gmacs@512"
    );
    for target in [6.0f64, 9.0, 12.0] {
        // Quantization-only can only hit ratios of the form ~32/wl.
        let wl_quant = (32.0 / target).round().clamp(2.0, 8.0) as u32;
        let frac4 = ratio_to_frac(&c, 4, target);
        let rows = [
            Method::QuantOnly { wl: wl_quant },
            Method::SvdBaseline { wl: 4, rank_frac: frac4 },
            Method::SvdIter { wl: 4, rank_frac: frac4 },
        ];
        for m in rows {
            let p = c.measure(&pair, &m)?;
            println!(
                "{:<8} {:<22} {:>8.2} {:>8.2} {:>10.2}",
                format!("{target}x"),
                p.label,
                p.ratio,
                p.bleu,
                p.nops as f64 / 1e9
            );
        }
        println!();
    }
    Ok(())
}
