//! End-to-end tests of the HTTP serving layer: concurrent keep-alive
//! clients against [`itera_llm::server::serve_http`] on real sockets.
//!
//! The load-bearing assertions:
//!
//! * HTTP translation is **bit-identical** to in-process
//!   `serve_loop_continuous` on the same request rows — the network
//!   layer adds transport, not semantics — and every concurrent client
//!   request is answered exactly once with a unique server-assigned id;
//! * the typed fault taxonomy surfaces as status codes on the wire:
//!   queue overflow → 503, per-request decode deadlines → 504,
//!   oversized bodies → 413, malformed bodies → 400, unknown routes →
//!   404 — and the books still balance after a graceful drain;
//! * chunked streaming reassembles to exactly the unary response for
//!   the same input, with at least one genuine progress chunk ahead of
//!   the terminal line;
//! * the open-loop load generator drives the server end to end and its
//!   client-side accounting agrees with the server's `ServeStats`;
//! * a stalled reader — a client that requests a multi-megabyte body
//!   and then never reads its socket — costs one clean disconnect via
//!   the write timeout, never a wedged handler: other clients stay
//!   served and the drain completes promptly with balanced books.

use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use itera_llm::coordinator::{
    response_channel, serve_loop_continuous, Request, ResponseRx, ServeConfig,
};
use itera_llm::eval::Corpus;
use itera_llm::model::{Manifest, ModelDims, PairModel};
use itera_llm::runtime::{NativeBackend, SlotEngine};
use itera_llm::server::http::{write_request, HttpConn};
use itera_llm::server::loadgen::{run_loadgen, LoadGenConfig};
use itera_llm::server::{serve_http, HttpConfig};
use itera_llm::testkit::tinymodel;
use itera_llm::util::json::Json;

/// POST one translate body and return (status, parsed body).
fn post_translate(
    conn: &mut HttpConn<TcpStream>,
    tokens: &[i32],
    extra: Vec<(&str, Json)>,
) -> (u16, Json) {
    let mut fields = vec![(
        "tokens",
        Json::Arr(tokens.iter().map(|&t| Json::Num(f64::from(t))).collect()),
    )];
    fields.extend(extra);
    let body = Json::obj(fields);
    write_request(conn.get_mut(), "POST", "/v1/translate", Some(&body)).unwrap();
    let resp = conn.read_response().unwrap();
    let j = resp.json().unwrap_or(Json::Null);
    (resp.status, j)
}

fn shutdown(addr: std::net::SocketAddr) {
    let mut conn = HttpConn::new(TcpStream::connect(addr).unwrap());
    write_request(conn.get_mut(), "POST", "/v1/shutdown", None).unwrap();
    assert_eq!(conn.read_response().unwrap().status, 202);
}

fn tokens_of(j: &Json) -> Vec<i32> {
    j.extract().field("tokens").and_then(|t| t.i32s()).expect("tokens array")
}

/// THE network-serving soak bar: the full tinymodel corpus (repeated)
/// through `serve_http` from concurrent keep-alive clients must answer
/// every request with **exactly** the tokens in-process
/// `serve_loop_continuous` serves for the same rows, assign each a
/// unique id, and drain gracefully with balanced accounting.
#[test]
fn http_serving_soak_bit_identical_to_in_process() {
    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 3;
    const N: usize = CLIENTS * PER_CLIENT;

    let (dir, manifest) = tinymodel::generate_in_temp("e2e_http_soak", 0x7E57).unwrap();
    let model = PairModel::load(&manifest, tinymodel::PAIR).unwrap();
    let corpus = Corpus::load(&manifest.pairs[tinymodel::PAIR].corpus).unwrap();
    let rows: Vec<Vec<i32>> = (0..N).map(|i| corpus.src_row(i % corpus.n).to_vec()).collect();

    // In-process reference: the same rows, pre-queued, served at the
    // same slot capacity on a separately constructed backend (bit-equal
    // by the determinism suite).
    let reference: Vec<Vec<i32>> = {
        let backend = NativeBackend::fp32(&manifest, &model, 2).unwrap();
        let (tx, rx) = mpsc::channel::<Request>();
        let receivers: Vec<ResponseRx> = rows
            .iter()
            .map(|row| {
                let (rtx, rrx) = response_channel();
                tx.send(Request::new(row.clone(), rtx)).unwrap();
                rrx
            })
            .collect();
        drop(tx);
        let stats =
            serve_loop_continuous(&backend, &rx, &manifest.model, N, &ServeConfig::new(3))
                .unwrap();
        assert_eq!(stats.served, N, "reference run is fault-free");
        receivers
            .iter()
            .map(|r| r.recv().expect("answered").expect("fault-free").tokens)
            .collect()
    };

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = {
        let manifest = manifest.clone();
        std::thread::spawn(move || {
            let model = PairModel::load(&manifest, tinymodel::PAIR).unwrap();
            let backend = NativeBackend::fp32(&manifest, &model, 2).unwrap();
            serve_http(&backend, listener, &manifest.model, HttpConfig::new(ServeConfig::new(3)))
                .unwrap()
        })
    };

    // Concurrent keep-alive clients, each owning a slice of the rows.
    let clients: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let mine: Vec<(usize, Vec<i32>)> = (0..PER_CLIENT)
                .map(|k| {
                    let i = c * PER_CLIENT + k;
                    (i, rows[i].clone())
                })
                .collect();
            std::thread::spawn(move || {
                let mut conn = HttpConn::new(TcpStream::connect(addr).unwrap());
                mine.into_iter()
                    .map(|(i, row)| {
                        let (status, j) = post_translate(&mut conn, &row, vec![]);
                        assert_eq!(status, 200, "request {i}: {j:?}");
                        let id = j.get("id").as_f64().expect("server-assigned id") as u64;
                        let lat = j.get("latency_s").as_f64().expect("latency");
                        assert!(lat >= 0.0 && lat.is_finite(), "request {i}: latency {lat}");
                        (i, id, tokens_of(&j))
                    })
                    .collect::<Vec<_>>()
            })
        })
        .collect();
    let mut results: Vec<(usize, u64, Vec<i32>)> = Vec::new();
    for c in clients {
        results.extend(c.join().expect("client thread"));
    }

    // Exactly once: N results, N distinct server-side ids.
    assert_eq!(results.len(), N);
    let mut ids: Vec<u64> = results.iter().map(|(_, id, _)| *id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), N, "every request carries a unique server-assigned id");

    // Bit-identity, request by request.
    for (i, _, toks) in &results {
        assert_eq!(toks, &reference[*i], "request {i}: HTTP diverged from in-process serving");
    }

    shutdown(addr);
    let stats = server.join().expect("server thread");
    assert_eq!(stats.served, N, "every HTTP request served");
    assert_eq!(stats.received, N);
    assert_eq!(stats.failed(), 0);
    assert!(stats.is_balanced(), "accounting identity violated: {stats:?}");
    assert_eq!(stats.latency.count(), N);
    assert_eq!(stats.queue_wait.count(), N, "queue-wait split recorded per request");
    assert_eq!(stats.execution.count(), N, "execution split recorded per request");

    std::fs::remove_dir_all(&dir).ok();
}

/// Slow echo engine: every decode step sleeps, and completion takes
/// `need` steps — so slots stay live long enough for queue overflow and
/// deadline expiry to be observed deterministically over real sockets.
struct SlowSlots {
    seq: usize,
    need: usize,
    step_ms: u64,
}

struct SlowSlot {
    row: Vec<i32>,
    steps: usize,
}

impl SlotEngine for SlowSlots {
    type Slot = SlowSlot;
    fn slot_seq_len(&self) -> usize {
        self.seq
    }
    fn admit(&self, src_row: &[i32]) -> anyhow::Result<SlowSlot> {
        Ok(SlowSlot { row: src_row.to_vec(), steps: 0 })
    }
    fn step(&self, slots: &mut [&mut SlowSlot]) -> anyhow::Result<()> {
        std::thread::sleep(Duration::from_millis(self.step_ms));
        for s in slots.iter_mut() {
            s.steps += 1;
        }
        Ok(())
    }
    fn slot_complete(&self, slot: &SlowSlot) -> bool {
        slot.steps >= self.need
    }
    fn slot_output(&self, slot: &SlowSlot) -> Vec<i32> {
        slot.row.clone()
    }
}

fn tiny_dims(seq_len: usize) -> ModelDims {
    ModelDims {
        vocab: 32,
        d_model: 8,
        n_heads: 2,
        d_ff: 16,
        n_enc: 1,
        n_dec: 1,
        seq_len,
        eval_batch: 4,
        pad_id: 0,
        bos_id: 1,
        eos_id: 2,
    }
}

/// The typed error taxonomy on the wire: a capacity-1 server with a
/// queue bound of 1 answers a backlogged burst with 504 (deadline
/// expiry in the slot), 200 (the queued survivor) and 503 (queue
/// overflow shed) — plus 413/400/404 on the protocol edges — and still
/// drains with balanced books.
#[test]
fn http_maps_overload_deadline_and_protocol_errors_to_statuses() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let engine = SlowSlots { seq: 8, need: 300, step_ms: 1 };
        let mut serve_cfg = ServeConfig::new(1);
        serve_cfg.queue_limit = Some(1);
        let mut cfg = HttpConfig::new(serve_cfg);
        cfg.max_body_bytes = 256;
        serve_http(&engine, listener, &tiny_dims(8), cfg).unwrap()
    });

    // Client A occupies the single slot and expires at step 100 — well
    // before the 300-step completion: a deterministic 504.
    let a = std::thread::spawn(move || {
        let mut conn = HttpConn::new(TcpStream::connect(addr).unwrap());
        let (status, j) =
            post_translate(&mut conn, &[1, 7, 2], vec![("deadline_steps", Json::Num(100.0))]);
        (status, j)
    });
    // Client C queues behind A (queue bound 1 holds exactly one waiter)
    // and completes once A's slot is reclaimed.
    std::thread::sleep(Duration::from_millis(20));
    let c = std::thread::spawn(move || {
        let mut conn = HttpConn::new(TcpStream::connect(addr).unwrap());
        post_translate(&mut conn, &[1, 9, 2], vec![])
    });

    // B arrives while A holds the slot and C holds the queue: shed with
    // an attributed 503 before any decode work happens.
    std::thread::sleep(Duration::from_millis(30));
    let mut conn = HttpConn::new(TcpStream::connect(addr).unwrap());
    let (status, j) = post_translate(&mut conn, &[1, 5, 2], vec![]);
    assert_eq!(status, 503, "queue overflow must shed: {j:?}");
    assert_eq!(j.get("error").as_str(), Some("overloaded"));
    assert!(j.get("id").as_f64().is_some(), "error body carries the request id");

    let (status, j) = a.join().expect("client A");
    assert_eq!(status, 504, "deadline expiry maps to 504: {j:?}");
    assert_eq!(j.get("error").as_str(), Some("deadline_exceeded"));
    let (status, j) = c.join().expect("client C");
    assert_eq!(status, 200, "the queued request survives: {j:?}");
    assert_eq!(tokens_of(&j), vec![9], "echo de-frames the survivor's row");

    // Protocol edges on the same connection: 404 and 400.
    write_request(conn.get_mut(), "GET", "/nope", None).unwrap();
    assert_eq!(conn.read_response().unwrap().status, 404);
    let bad = Json::obj(vec![("tokens", Json::Str("x".to_string()))]);
    write_request(conn.get_mut(), "POST", "/v1/translate", Some(&bad)).unwrap();
    assert_eq!(conn.read_response().unwrap().status, 400);

    // An oversized body on a fresh connection: 413, then close.
    let mut big = HttpConn::new(TcpStream::connect(addr).unwrap());
    let huge: Vec<i32> = (0..500).collect();
    write_request(
        big.get_mut(),
        "POST",
        "/v1/translate",
        Some(&Json::Arr(huge.iter().map(|&t| Json::Num(f64::from(t))).collect())),
    )
    .unwrap();
    assert_eq!(big.read_response().unwrap().status, 413);

    shutdown(addr);
    let stats = server.join().expect("server thread");
    assert_eq!(stats.served, 1, "only the queued survivor completes");
    assert_eq!(stats.expired, 1, "the deadline expiry is accounted");
    assert_eq!(stats.shed, 1, "the queue overflow is accounted");
    assert_eq!(stats.received, 3, "translate requests that reached the loop");
    assert!(stats.is_balanced(), "accounting identity violated: {stats:?}");
}

/// Growing engine: one new content token per decode step, completing
/// after `need` steps — so a streaming client observes genuine
/// incremental progress.
struct GrowSlots {
    seq: usize,
    need: usize,
    step_ms: u64,
}

struct GrowSlot {
    steps: usize,
}

impl SlotEngine for GrowSlots {
    type Slot = GrowSlot;
    fn slot_seq_len(&self) -> usize {
        self.seq
    }
    fn admit(&self, _src_row: &[i32]) -> anyhow::Result<GrowSlot> {
        Ok(GrowSlot { steps: 0 })
    }
    fn step(&self, slots: &mut [&mut GrowSlot]) -> anyhow::Result<()> {
        std::thread::sleep(Duration::from_millis(self.step_ms));
        for s in slots.iter_mut() {
            s.steps += 1;
        }
        Ok(())
    }
    fn slot_complete(&self, slot: &GrowSlot) -> bool {
        slot.steps >= self.need
    }
    fn slot_output(&self, slot: &GrowSlot) -> Vec<i32> {
        // BOS + one content token per completed step + EOS, PAD-padded.
        let mut out = vec![1];
        out.extend((0..slot.steps).map(|k| 10 + k as i32));
        out.push(2);
        out.resize(self.seq, 0);
        out
    }
}

/// Chunked streaming reassembles to exactly the unary response for the
/// same input: the concatenation of the progress lines' tokens plus the
/// terminal line's tail equals the unary token stream, and at least one
/// genuine progress chunk precedes the terminal line.
#[test]
fn http_streaming_reassembles_to_the_unary_response() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let engine = GrowSlots { seq: 8, need: 4, step_ms: 20 };
        serve_http(&engine, listener, &tiny_dims(8), HttpConfig::new(ServeConfig::new(2)))
            .unwrap()
    });

    let mut conn = HttpConn::new(TcpStream::connect(addr).unwrap());
    let (status, j) = post_translate(&mut conn, &[1, 3, 2], vec![]);
    assert_eq!(status, 200);
    let unary = tokens_of(&j);
    assert_eq!(unary, vec![10, 11, 12, 13], "one grown token per decode step");

    let body = Json::obj(vec![
        ("tokens", Json::arr_f64(&[1.0, 3.0, 2.0])),
        ("stream", Json::Bool(true)),
    ]);
    write_request(conn.get_mut(), "POST", "/v1/translate", Some(&body)).unwrap();
    let resp = conn.read_response().unwrap();
    assert_eq!(resp.status, 200, "streaming responses carry the 200 on the chunked head");

    // One JSON line per chunk; HttpConn reassembled the chunked body.
    let text = String::from_utf8(resp.body.clone()).unwrap();
    let lines: Vec<Json> =
        text.lines().map(|l| Json::parse(l).expect("every chunk line is valid JSON")).collect();
    assert!(lines.len() >= 2, "streaming must emit progress before the terminal line: {text}");
    let (terminal, progress) = lines.split_last().unwrap();
    assert_eq!(terminal.get("done").as_bool(), Some(true));
    assert!(terminal.get("latency_s").as_f64().is_some());
    let mut reassembled = Vec::new();
    for line in progress {
        assert_eq!(line.get("done").as_bool(), None, "only the last line is terminal");
        reassembled.extend(tokens_of(line));
    }
    reassembled.extend(tokens_of(terminal));
    assert_eq!(reassembled, unary, "streamed chunks must reassemble to the unary response");

    shutdown(addr);
    let stats = server.join().expect("server thread");
    assert_eq!(stats.served, 2, "unary + streaming");
    assert!(stats.is_balanced(), "{stats:?}");
}

/// The open-loop load generator end to end: every generated request gets
/// a 200, client-side and server-side accounting agree, and the report's
/// rates are finite and positive.
#[test]
fn loadgen_drives_the_server_and_accounts_cleanly() {
    const N: usize = 24;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let engine = SlowSlots { seq: 16, need: 1, step_ms: 0 };
        serve_http(&engine, listener, &tiny_dims(16), HttpConfig::new(ServeConfig::new(4)))
            .unwrap()
    });

    let cfg = LoadGenConfig {
        connections: 4,
        requests: N,
        rate: 400.0,
        len_range: (2, 6),
        vocab: 16,
        ..LoadGenConfig::default()
    };
    let report = run_loadgen(addr, &cfg).unwrap();
    shutdown(addr);
    let stats = server.join().expect("server thread");

    assert_eq!(report.sent, N, "every scheduled request goes on the wire");
    assert_eq!(report.ok, N, "an unloaded echo server answers everything: {:?}", report.errors);
    assert_eq!(report.failed(), 0);
    assert_eq!(report.latency.count(), N);
    assert!(report.wall_s > 0.0 && report.throughput_rps() > 0.0);
    assert!(report.tokens > 0, "echoed content tokens are counted");
    assert_eq!(stats.served, N, "server books agree with the client");
    assert_eq!(stats.received, N);
    assert!(stats.is_balanced(), "{stats:?}");
}

/// Echo engine with a request-selected payload: a row whose first
/// content token is `7` completes into a full-`seq` run of content
/// tokens — a multi-megabyte unary body — while anything else echoes
/// its row. Big responses let a test overfill the kernel's socket
/// buffers and stall a handler mid-write.
struct BigSlots {
    seq: usize,
}

struct BigSlot {
    row: Vec<i32>,
    steps: usize,
}

impl SlotEngine for BigSlots {
    type Slot = BigSlot;
    fn slot_seq_len(&self) -> usize {
        self.seq
    }
    fn admit(&self, src_row: &[i32]) -> anyhow::Result<BigSlot> {
        Ok(BigSlot { row: src_row.to_vec(), steps: 0 })
    }
    fn step(&self, slots: &mut [&mut BigSlot]) -> anyhow::Result<()> {
        for s in slots.iter_mut() {
            s.steps += 1;
        }
        Ok(())
    }
    fn slot_complete(&self, slot: &BigSlot) -> bool {
        slot.steps >= 1
    }
    fn slot_output(&self, slot: &BigSlot) -> Vec<i32> {
        if slot.row.get(1) == Some(&7) {
            // BOS + (seq - 2) content tokens + EOS: de-frames to a
            // response body of roughly 3 bytes per content token.
            let mut out = vec![1];
            out.resize(self.seq - 1, 10);
            out.push(2);
            out
        } else {
            slot.row.clone()
        }
    }
}

/// The slow-reader regression bar: a client that requests a ~3 MB body
/// and then never reads a byte fills the loopback socket's buffers
/// (~hundreds of KB unread capacity) and stalls the handler's write.
/// With the write timeout configured, the write errors out, the handler
/// thread is freed, and the connection is closed with the body
/// undelivered — meanwhile a second client is served normally and the
/// post-shutdown drain completes well inside the 2 s handler grace a
/// wedged writer would otherwise exhaust.
#[test]
fn http_write_timeout_unwedges_a_stalled_reader_and_books_balance() {
    // ~3 MB of `10,` body bytes: ~5x the worst unread capacity of a
    // loopback connection under default kernel buffer sizing, so the
    // server's write reliably blocks once the client stops reading.
    const BIG_SEQ: usize = 1_000_000;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let engine = BigSlots { seq: BIG_SEQ };
        let mut cfg = HttpConfig::new(ServeConfig::new(2));
        cfg.write_timeout = Duration::from_millis(200);
        serve_http(&engine, listener, &tiny_dims(BIG_SEQ), cfg).unwrap()
    });

    // The stalled reader: request the big body, then never touch the
    // socket again until after the server has drained.
    let mut stalled = HttpConn::new(TcpStream::connect(addr).unwrap());
    let body = Json::obj(vec![("tokens", Json::arr_f64(&[7.0]))]);
    write_request(stalled.get_mut(), "POST", "/v1/translate", Some(&body)).unwrap();

    // While the stalled handler is blocked in its write, a second
    // client must be served normally: handlers are isolated and the
    // serve loop never wedges.
    std::thread::sleep(Duration::from_millis(150));
    let t0 = Instant::now();
    let mut conn = HttpConn::new(TcpStream::connect(addr).unwrap());
    let (status, j) = post_translate(&mut conn, &[9], vec![]);
    assert_eq!(status, 200, "a healthy client is served during the stall: {j:?}");
    assert_eq!(tokens_of(&j), vec![9], "echo de-frames the healthy row");
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "the healthy request must not queue behind the stalled write"
    );

    // Give the write timeout time to fire and free the handler, then
    // drain. A wedged handler would pin `active` and cost the full 2 s
    // join grace; a freed one drains promptly.
    std::thread::sleep(Duration::from_millis(600));
    let t0 = Instant::now();
    shutdown(addr);
    let stats = server.join().expect("server thread");
    let drain = t0.elapsed();
    assert!(
        drain < Duration::from_millis(1500),
        "drain took {drain:?}: the stalled handler was not freed by the write timeout"
    );

    // The stalled client got a clean disconnect, not the full body:
    // whatever the kernel buffered is a strict prefix, so reassembling
    // the response fails.
    assert!(
        stalled.read_response().is_err(),
        "the stalled reader must not receive the complete multi-megabyte response"
    );

    // Server-side the request was served into the void — the outcome
    // was delivered to the handler before the write stalled — so the
    // books still balance.
    assert_eq!(stats.received, 2, "both translate requests reached the loop");
    assert_eq!(stats.served, 2, "the stalled request was served before its write failed");
    assert_eq!(stats.failed(), 0);
    assert!(stats.is_balanced(), "accounting identity violated: {stats:?}");
}
