//! Batched NMT serving demo over the PJRT runtime.
//!
//! ```bash
//! cargo run --release --example serve_nmt [-- <requests> <pair>]
//! ```
//!
//! Spins up the request-batching loop (`coordinator::serve_demo`): a
//! closed-loop client submits single-sentence translation requests, the
//! server groups them into fixed-capacity batches, executes one PJRT call
//! per batch against a W8A8-quantized model, and reports latency
//! percentiles and throughput. Python is nowhere on this path.

use anyhow::Result;
use itera_llm::config::ExpConfig;
use itera_llm::coordinator::{serve_demo, Coordinator};

fn main() -> Result<()> {
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(64);
    let pair = std::env::args().nth(2).unwrap_or_else(|| "en-de".to_string());
    let c = Coordinator::new(ExpConfig::fast())?;
    serve_demo(&c, &pair, requests)
}
