//! `itera` command-line interface (hand-rolled; no clap in the image).
//!
//! Always available (native runtime + analytical models):
//!
//! ```text
//! itera info [--wl 4]                # runtime summary + packed-bytes accounting
//! itera eval [--method fp32|quant|svd|itera] [--wl 8] [--rank-frac 0.5]
//!            [--mode dense|svd|quantized] [--decode replay|cached]
//!            [--kernel exact|fast]
//! itera serve [--requests 64] [--mode quantized] [--decode replay|cached]
//!             [--kernel exact|fast]
//!             [--batcher static|continuous] [--queue-limit 8] [--deadline 200]
//!             [--max-new-tokens 16] [--burst 12] [--tinymodel]
//!             [--listen 127.0.0.1:8080 [--loadgen 256] [--connections 16]
//!              [--rate 100] [--max-connections 256] [--metrics]]
//! itera validate [--mode quantized] [--decode cached] [--batcher continuous]
//!                [--kernel exact|fast]
//!                                    # model-vs-sim / qkernel / decode /
//!                                    # continuous-batching / kernel-tier parity
//! ```
//!
//! PJRT-artifact measurement (needs `--features pjrt`):
//!
//! ```text
//! itera fig <1|4|7|8|9|10|11|12|all> [--pair en-de] [--fast] [--no-sra]
//! itera compress --method quant|svd|itera --wl 4 [--rank-frac 0.5]
//! itera sra --wl 4 --budget-frac 0.5 [--pair en-de]
//! itera serve --backend pjrt [--requests 64]
//! ```

mod commands;

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[cfg(feature = "pjrt")]
pub use commands::run_figures;

/// Parsed command line: subcommand, flags (`--k v` / bare `--flag`), and
/// positional arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub cmd: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        a.cmd = it.next().cloned().unwrap_or_else(|| "help".to_string());
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // Flag with a value unless the next token is another flag
                // or absent (then it's boolean).
                let take = it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false);
                let val = if take { it.next().cloned().unwrap() } else { "true".into() };
                a.flags.insert(name.to_string(), val);
            } else {
                a.positional.push(tok.clone());
            }
        }
        Ok(a)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} expects an integer")),
        }
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} expects a number")),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

pub const USAGE: &str = "\
itera — ITERA-LLM co-design framework (paper reproduction)

USAGE (native runtime, every build):
  itera info [--wl <2..8>]
  itera eval [--method <fp32|quant|svd|itera>] [--wl <2..8>] [--rank-frac F]
             [--pair P] [--limit N] [--mode <dense|svd|quantized>]
             [--decode <replay|cached>] [--kernel <exact|fast>]
  itera serve [--requests N] [--pair P] [--backend <native|pjrt>]
              [--mode <dense|quantized>] [--decode <replay|cached>]
              [--kernel <exact|fast>]
              [--batcher <static|continuous>] [--tinymodel]
              [--queue-limit N] [--deadline STEPS] [--max-new-tokens N]
              [--burst N] [--listen ADDR] [--loadgen N] [--connections N]
              [--rate R] [--max-connections N] [--metrics]
  itera validate [--mode quantized] [--decode cached] [--batcher continuous]
                 [--kernel <exact|fast>]
  itera help

  --mode quantized executes the compressed model from bit-packed sub-8-bit
  storage (qkernel) — bit-identical tokens, up to 16x fewer weight bytes.
  --decode picks the greedy loop: KV-cached single-token steps (default)
  or the AOT graph's full-buffer replay — bit-identical tokens, a
  seq_len-factor fewer decoder MACs cached. `validate --decode cached`
  cross-checks the parity on a hermetic tiny model.
  --kernel picks the cached-decode kernel tier for packed (quantized)
  linears: exact (default) keeps the bit-identical fake-quant kernels;
  fast quantizes activations to int8 at runtime and runs a pure-integer
  GEMV with i32 accumulation — non-bit-exact by contract, gated by the
  `validate --kernel fast` parity table (max |Δlogit| + BLEU delta,
  non-zero exit on breach).
  --batcher picks the serving discipline: static group-decode-respond
  waves (default) or the continuous slot scheduler, which retires and
  admits sequences between decode steps so the KV-cached engine stays
  full under dynamic load — bit-identical responses, higher occupancy.
  `validate --batcher continuous` cross-checks continuous vs sequential
  decode on a hermetic tiny model.
  Continuous-batcher robustness knobs: --queue-limit bounds admission
  (overflow gets a typed `overloaded` rejection instead of unbounded
  queueing), --deadline / --max-new-tokens set server-side default
  per-request limits (decode steps / generated tokens), and --burst
  drives the demo client with N requests in flight (push it past
  capacity + queue limit to see load shedding). --tinymodel serves the
  hermetic synthetic model, so the overload smoke needs no artifacts.
  --listen ADDR exposes the continuous serve loop over HTTP/1.1
  (dependency-free, std only): POST /v1/translate, GET /healthz,
  POST /v1/shutdown; bind port 0 for an ephemeral port. --loadgen N
  self-drives it with a seeded open-loop Poisson load generator
  (--connections keep-alive clients at --rate req/s aggregate; rate 0 =
  closed loop), then drains and prints both reports — the HTTP smoke.
  --max-connections bounds concurrent HTTP connections (excess get an
  immediate 503). GET /metrics (Prometheus text) and GET /v1/stats
  (JSON) expose live serving telemetry, answerable mid-drain; the
  self-drive scrapes both and cross-checks them against its own ledger.
  --metrics prints a one-line telemetry digest every second.

USAGE (PJRT artifact measurement, needs --features pjrt):
  itera fig <1|4|7|8|9|10|11|12|all> [--pair en-de|fr-en] [--fast] [--no-sra]
  itera compress --method <quant|svd|itera> --wl <2..8> [--rank-frac F] [--pair P]
  itera sra --wl <2..8> --budget-frac F [--pair P] [--fast]
";

/// Entry point used by `main.rs`.
pub fn main_with_args(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.cmd.as_str() {
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        "info" => commands::cmd_info(&args),
        "eval" => commands::cmd_eval(&args),
        "fig" => commands::cmd_fig(&args),
        "compress" => commands::cmd_compress(&args),
        "sra" => commands::cmd_sra(&args),
        "validate" => commands::cmd_validate(&args),
        "serve" => commands::cmd_serve(&args),
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = Args::parse(&sv(&["fig", "7", "--pair", "en-de", "--fast"])).unwrap();
        assert_eq!(a.cmd, "fig");
        assert_eq!(a.positional, vec!["7"]);
        assert_eq!(a.flag("pair"), Some("en-de"));
        assert!(a.has("fast"));
        assert_eq!(a.flag_or("missing", "x"), "x");
    }

    #[test]
    fn numeric_flags() {
        let a = Args::parse(&sv(&["sra", "--wl", "4", "--budget-frac", "0.5"])).unwrap();
        assert_eq!(a.flag_usize("wl", 8).unwrap(), 4);
        assert!((a.flag_f64("budget-frac", 1.0).unwrap() - 0.5).abs() < 1e-12);
        assert!(a.flag_usize("wl", 8).is_ok());
        let b = Args::parse(&sv(&["sra", "--wl", "x"])).unwrap();
        assert!(b.flag_usize("wl", 8).is_err());
    }

    #[test]
    fn empty_is_help() {
        let a = Args::parse(&[]).unwrap();
        assert_eq!(a.cmd, "help");
    }
}
