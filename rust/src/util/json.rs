//! Minimal JSON parser + writer.
//!
//! The image vendors no `serde`/`serde_json` facade, so the library carries
//! its own small JSON implementation — enough for the artifact manifest,
//! platform/experiment configs, and report emission. Strict on structure,
//! permissive on whitespace; numbers are f64 (the manifest carries nothing
//! that loses precision).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use `BTreeMap` for deterministic iteration order
/// (reports and golden tests depend on stable output).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------- accessors ----------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null on any miss.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(v) => v.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ---------------- builders ----------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // ---------------- parsing ----------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // ---------------- writing ----------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/Infinity; rejecting to null keeps
                    // every writer output re-parseable (the round-trip
                    // property test pins this).
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

/// Which JSON shape a value is — used in extractor error messages.
fn type_name(j: &Json) -> &'static str {
    match j {
        Json::Null => "null",
        Json::Bool(_) => "bool",
        Json::Num(_) => "number",
        Json::Str(_) => "string",
        Json::Arr(_) => "array",
        Json::Obj(_) => "object",
    }
}

/// A typed-extraction failure: the JSONPath-style location that failed
/// and what was expected there. This is what the HTTP layer turns into a
/// 400 body, so the message must name the offending field, not just
/// "type error".
#[derive(Debug, Clone, PartialEq)]
pub struct ExtractError {
    pub path: String,
    pub msg: String,
}

impl std::fmt::Display for ExtractError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "at {}: {}", self.path, self.msg)
    }
}

impl std::error::Error for ExtractError {}

/// Typed, path-tracking view over a parsed [`Json`] value — the
/// alternative to hand-indexing `get`/`as_*` chains whose failures all
/// collapse into an unexplained `None`. Navigation ([`Extract::field`],
/// [`Extract::item`]) extends the recorded path; terminal accessors
/// ([`Extract::str`], [`Extract::usize`], ...) fail with the full path
/// and the expected-vs-found types.
///
/// ```
/// # use itera_llm::util::json::Json;
/// let j = Json::parse(r#"{"tokens": [1, 2, 3], "stream": true}"#).unwrap();
/// let x = j.extract();
/// assert_eq!(x.field("tokens").unwrap().i32s().unwrap(), vec![1, 2, 3]);
/// let err = x.field("missing").unwrap_err();
/// assert_eq!(err.path, "$.missing");
/// ```
#[derive(Clone)]
pub struct Extract<'a> {
    j: &'a Json,
    path: String,
}

impl Json {
    /// Root of a typed extraction (path `$`).
    pub fn extract(&self) -> Extract<'_> {
        Extract { j: self, path: "$".to_string() }
    }
}

impl<'a> Extract<'a> {
    /// The underlying value at this path.
    pub fn json(&self) -> &'a Json {
        self.j
    }

    /// The JSONPath-style location this view points at.
    pub fn path(&self) -> &str {
        &self.path
    }

    fn fail(&self, msg: String) -> ExtractError {
        ExtractError { path: self.path.clone(), msg }
    }

    fn expected(&self, what: &str) -> ExtractError {
        self.fail(format!("expected {what}, got {}", type_name(self.j)))
    }

    /// Required object field: errors when this value is not an object or
    /// the key is absent.
    pub fn field(&self, key: &str) -> Result<Extract<'a>, ExtractError> {
        let Json::Obj(m) = self.j else { return Err(self.expected("object")) };
        match m.get(key) {
            Some(v) => Ok(Extract { j: v, path: format!("{}.{key}", self.path) }),
            None => Err(ExtractError {
                path: format!("{}.{key}", self.path),
                msg: "missing required field".to_string(),
            }),
        }
    }

    /// Optional object field: `None` when absent or `null`; still errors
    /// when this value is not an object at all.
    pub fn opt(&self, key: &str) -> Result<Option<Extract<'a>>, ExtractError> {
        let Json::Obj(m) = self.j else { return Err(self.expected("object")) };
        match m.get(key) {
            None | Some(Json::Null) => Ok(None),
            Some(v) => Ok(Some(Extract { j: v, path: format!("{}.{key}", self.path) })),
        }
    }

    /// Required array element by index.
    pub fn item(&self, i: usize) -> Result<Extract<'a>, ExtractError> {
        let Json::Arr(v) = self.j else { return Err(self.expected("array")) };
        match v.get(i) {
            Some(x) => Ok(Extract { j: x, path: format!("{}[{i}]", self.path) }),
            None => Err(self.fail(format!("index {i} out of bounds (len {})", v.len()))),
        }
    }

    /// Every array element, as typed views.
    pub fn items(&self) -> Result<Vec<Extract<'a>>, ExtractError> {
        let Json::Arr(v) = self.j else { return Err(self.expected("array")) };
        Ok(v.iter()
            .enumerate()
            .map(|(i, x)| Extract { j: x, path: format!("{}[{i}]", self.path) })
            .collect())
    }

    pub fn str(&self) -> Result<&'a str, ExtractError> {
        match self.j {
            Json::Str(s) => Ok(s),
            _ => Err(self.expected("string")),
        }
    }

    pub fn bool(&self) -> Result<bool, ExtractError> {
        match self.j {
            Json::Bool(b) => Ok(*b),
            _ => Err(self.expected("bool")),
        }
    }

    pub fn f64(&self) -> Result<f64, ExtractError> {
        match self.j {
            Json::Num(x) => Ok(*x),
            _ => Err(self.expected("number")),
        }
    }

    /// Exact integer in `i64` range (fractional or out-of-range numbers
    /// are rejected, unlike the truncating [`Json::as_i64`]).
    pub fn i64(&self) -> Result<i64, ExtractError> {
        let x = self.f64()?;
        if x.fract() != 0.0 || !(-9.007199254740992e15..=9.007199254740992e15).contains(&x) {
            return Err(self.fail(format!("expected an integer, got {x}")));
        }
        Ok(x as i64)
    }

    /// Exact non-negative integer.
    pub fn usize(&self) -> Result<usize, ExtractError> {
        let n = self.i64()?;
        usize::try_from(n)
            .map_err(|_| self.fail(format!("expected a non-negative integer, got {n}")))
    }

    /// Exact integer fitting `i32` (token ids on the wire).
    pub fn i32(&self) -> Result<i32, ExtractError> {
        let n = self.i64()?;
        i32::try_from(n).map_err(|_| self.fail(format!("expected a 32-bit integer, got {n}")))
    }

    /// A whole array of `i32`s — the token-row shape every translate
    /// request carries.
    pub fn i32s(&self) -> Result<Vec<i32>, ExtractError> {
        self.items()?.iter().map(|x| x.i32()).collect()
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Multi-byte UTF-8: copy the remaining continuation bytes.
                    let len = if c >= 0xf0 {
                        4
                    } else if c >= 0xe0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    self.pos = start + len;
                    s.push_str(
                        std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("bad utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").idx(2).get("b").as_str(), Some("x"));
        assert_eq!(j.get("c"), &Json::Null);
        assert_eq!(j.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"itera","nums":[1,2.5,-3],"ok":true,"sub":{"x":null}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""Aµλ""#).unwrap();
        assert_eq!(j.as_str(), Some("Aµλ"));
        let out = Json::Str("q\"\\\n".into()).to_string();
        assert_eq!(Json::parse(&out).unwrap().as_str(), Some("q\"\\\n"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn non_finite_numbers_reject_to_null() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let out = Json::Num(bad).to_string();
            assert_eq!(out, "null", "non-finite must not emit unparseable text");
            assert_eq!(Json::parse(&out).unwrap(), Json::Null);
        }
        let j = Json::obj(vec![("x", Json::Num(f64::NAN)), ("y", Json::Num(2.5))]);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("x"), &Json::Null);
        assert_eq!(back.get("y").as_f64(), Some(2.5));
    }

    #[test]
    fn extractor_happy_paths() {
        let j = Json::parse(
            r#"{"tokens": [1, -2, 3], "deadline": 40, "stream": true,
                "name": "xx-yy", "rate": 2.5, "nested": {"inner": [10]}}"#,
        )
        .unwrap();
        let x = j.extract();
        assert_eq!(x.field("tokens").unwrap().i32s().unwrap(), vec![1, -2, 3]);
        assert_eq!(x.field("deadline").unwrap().usize().unwrap(), 40);
        assert!(x.field("stream").unwrap().bool().unwrap());
        assert_eq!(x.field("name").unwrap().str().unwrap(), "xx-yy");
        assert_eq!(x.field("rate").unwrap().f64().unwrap(), 2.5);
        assert_eq!(
            x.field("nested").unwrap().field("inner").unwrap().item(0).unwrap().i64().unwrap(),
            10
        );
        assert!(x.opt("missing").unwrap().is_none(), "absent optional is None");
        assert_eq!(x.opt("deadline").unwrap().unwrap().usize().unwrap(), 40);
        assert_eq!(x.field("tokens").unwrap().items().unwrap().len(), 3);
    }

    #[test]
    fn extractor_errors_carry_paths() {
        let j = Json::parse(r#"{"a": {"b": [1, "x"]}, "n": 1.5, "neg": -1}"#).unwrap();
        let x = j.extract();
        let e = x.field("missing").unwrap_err();
        assert_eq!(e.path, "$.missing");
        assert!(e.msg.contains("missing"), "{e}");
        let e = x.field("a").unwrap().field("b").unwrap().item(1).unwrap().i32().unwrap_err();
        assert_eq!(e.path, "$.a.b[1]");
        assert!(e.msg.contains("expected number"), "{e}");
        let e = x.field("n").unwrap().usize().unwrap_err();
        assert!(e.msg.contains("integer"), "fractional rejected: {e}");
        let e = x.field("neg").unwrap().usize().unwrap_err();
        assert!(e.msg.contains("non-negative"), "{e}");
        let e = x.field("a").unwrap().item(0).unwrap_err();
        assert!(e.msg.contains("expected array"), "{e}");
        let e = x.field("a").unwrap().field("b").unwrap().item(7).unwrap_err();
        assert!(e.msg.contains("out of bounds"), "{e}");
        // Null is treated as absent by opt(), a type error by field accessors.
        let j2 = Json::parse(r#"{"k": null}"#).unwrap();
        assert!(j2.extract().opt("k").unwrap().is_none());
    }

    #[test]
    fn large_manifest_like() {
        let mut obj = BTreeMap::new();
        obj.insert(
            "scales".to_string(),
            Json::arr_f64(&(0..100).map(|i| i as f64 * 0.1).collect::<Vec<_>>()),
        );
        let j = Json::Obj(obj);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("scales").as_arr().unwrap().len(), 100);
        assert!((parsed.get("scales").idx(42).as_f64().unwrap() - 4.2).abs() < 1e-12);
    }
}
