//! Rate/workload performance model (§VI-A, Eq. 12–15 + Eq. 19).
//!
//! Each port of a MatMul tile has a *rate* (words/cycle it can sustain)
//! and a *workload* (total words it must move); tile latency is the
//! bottleneck port's `workload / rate`. The model generalizes the paper's
//! equations to non-dividing tile sizes via ceiling divisions (hardware
//! pads the edge tiles — the occupancy effect Fig. 12 quantifies).

use super::{ceil_div, TileConfig, Workload};

/// Input/output port rates of a MatMul tile (words per cycle), Eq. 13.
#[derive(Debug, Clone, Copy)]
pub struct PortRates {
    pub lhs_in: f64,
    pub rhs_in: f64,
    pub out: f64,
}

/// Latency decomposition of one tiled MatMul.
#[derive(Debug, Clone, Copy)]
pub struct TilePerf {
    pub rates: PortRates,
    /// Port workloads in words (Eq. 14): LHS, RHS, OUT.
    pub words: (f64, f64, f64),
    /// Bottleneck latency in cycles (Eq. 15).
    pub latency_cycles: f64,
    /// Pure compute cycles (output-port bound) — the occupancy reference.
    pub compute_cycles: f64,
    /// Off-chip bandwidth requirement in bits/cycle to run at full
    /// throughput (Eq. 19).
    pub bandwidth_bits_per_cycle: f64,
}

/// Eq. 12–13: port rates of an `M_t x N_t x K_f` tile working on a
/// `[M x K] * [K x N]` MatMul.
///
/// One deviation from the paper's text: Eq. 12 writes the PE LHS rate as
/// `K / (ceil(K/K_f) * N)`, i.e. each LHS tile amortized over the *full* N
/// sweep. For the tiled array of Eq. 13 the LHS tile is consumed over the
/// `N/N_t` temporal tiles it feeds, so the tile-level rate carries an
/// extra `N_t` factor — without it the LHS port would (incorrectly)
/// dominate every design by `N_t`x and the model would disagree with the
/// dataflow simulator. With the correction, LHS/RHS stream bounds
/// coincide with the output-stationary compute bound for dividing tiles,
/// exactly as the paper's output-stationary schedule implies.
pub fn port_rates(w: &Workload, t: &TileConfig) -> PortRates {
    let k_iters = ceil_div(w.k, t.kf) as f64;
    PortRates {
        lhs_in: t.mt as f64 * t.nt as f64 * w.k as f64 / (k_iters * w.n as f64),
        rhs_in: t.nt as f64 * t.kf as f64,
        out: t.mt as f64 * t.nt as f64 / k_iters,
    }
}

/// Eq. 14: port workloads in words. The RHS matrix is re-streamed once per
/// M-tile (`ceil(M/M_t)` times); the LHS is streamed once.
pub fn port_words(w: &Workload, t: &TileConfig) -> (f64, f64, f64) {
    let m_tiles = ceil_div(w.m, t.mt) as f64;
    let lhs = (w.m * w.k) as f64;
    let rhs = m_tiles * (w.k * w.n) as f64;
    let out = (w.m * w.n) as f64;
    (lhs, rhs, out)
}

/// Eq. 15 + Eq. 19 over padded tile grids.
pub fn tile_latency_cycles(w: &Workload, t: &TileConfig) -> TilePerf {
    let rates = port_rates(w, t);
    let words = port_words(w, t);
    // Padded dims: edge tiles compute on padded rows/cols.
    let m_pad = ceil_div(w.m, t.mt) * t.mt;
    let n_pad = ceil_div(w.n, t.nt) * t.nt;
    let k_iters = ceil_div(w.k, t.kf) as f64;
    let compute_cycles = (m_pad as f64 / t.mt as f64) * (n_pad as f64 / t.nt as f64) * k_iters;
    let latency = (words.0 / rates.lhs_in)
        .max(words.1 / rates.rhs_in)
        .max(words.2 / rates.out)
        .max(compute_cycles);
    let bw = bandwidth_bits_per_cycle(w, words, latency);
    TilePerf {
        rates,
        words,
        latency_cycles: latency,
        compute_cycles,
        bandwidth_bits_per_cycle: bw,
    }
}

/// Eq. 19 with per-port word lengths: LHS and OUT move activations
/// (`a_bits`), RHS moves weights (`w_bits`).
pub fn bandwidth_bits_per_cycle(w: &Workload, words: (f64, f64, f64), latency: f64) -> f64 {
    if latency <= 0.0 {
        return 0.0;
    }
    (words.0 * w.a_bits as f64 + words.1 * w.w_bits as f64 + words.2 * w.a_bits as f64) / latency
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w512() -> Workload {
        Workload::new(512, 512, 512, 4, 8)
    }

    #[test]
    fn compute_bound_latency_matches_loop_count() {
        // A 16x16 tile with Kf=8 on 512^3: latency should be the temporal
        // loop count (512/16)*(512/16)*(512/8) when compute dominates.
        let t = TileConfig::new(16, 16, 8);
        let p = tile_latency_cycles(&w512(), &t);
        let loops = (512.0 / 16.0) * (512.0 / 16.0) * (512.0 / 8.0);
        assert!((p.compute_cycles - loops).abs() < 1e-9);
        // For dividing tiles the stream bounds coincide with the compute
        // bound (output-stationary property), so latency == loop count.
        assert!((p.latency_cycles - loops).abs() < 1e-9);
    }

    #[test]
    fn output_stationary_identity() {
        // For dividing tiles the RHS stream bound equals the compute bound
        // exactly: K*N_t words at N_t*K_f w/cyc == K/K_f cycles per tile.
        for t in [TileConfig::new(64, 1, 1), TileConfig::new(8, 32, 4)] {
            let p = tile_latency_cycles(&w512(), &t);
            let rhs_bound = p.words.1 / p.rates.rhs_in;
            assert!(
                ((rhs_bound - p.compute_cycles) / p.compute_cycles).abs() < 1e-9,
                "{t:?}: rhs {rhs_bound} vs compute {}",
                p.compute_cycles
            );
        }
    }

    #[test]
    fn bigger_tiles_never_slower() {
        let mut prev = f64::INFINITY;
        for sz in [2usize, 4, 8, 16, 32] {
            let t = TileConfig::new(sz, sz, 8);
            let p = tile_latency_cycles(&w512(), &t);
            assert!(p.latency_cycles <= prev + 1e-9, "tile {sz}: {}", p.latency_cycles);
            prev = p.latency_cycles;
        }
    }

    #[test]
    fn nondividing_tiles_pad_up() {
        let w = Workload::new(100, 100, 100, 8, 8);
        let t = TileConfig::new(16, 16, 8);
        let p = tile_latency_cycles(&w, &t);
        // 7 tiles each dim (112 padded), 13 k-iters.
        assert!((p.compute_cycles - 7.0 * 7.0 * 13.0).abs() < 1e-9);
    }

    #[test]
    fn bandwidth_scales_with_word_length() {
        let t = TileConfig::new(16, 16, 8);
        let p4 = tile_latency_cycles(&Workload::new(512, 512, 512, 4, 8), &t);
        let p8 = tile_latency_cycles(&Workload::new(512, 512, 512, 8, 8), &t);
        assert!(p8.bandwidth_bits_per_cycle > p4.bandwidth_bits_per_cycle);
    }

    #[test]
    fn faster_engine_needs_more_bandwidth() {
        let slow = tile_latency_cycles(&w512(), &TileConfig::new(4, 4, 4));
        let fast = tile_latency_cycles(&w512(), &TileConfig::new(32, 32, 16));
        assert!(fast.latency_cycles < slow.latency_cycles);
        assert!(fast.bandwidth_bits_per_cycle > slow.bandwidth_bits_per_cycle);
    }
}
