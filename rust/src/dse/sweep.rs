//! Hardware design-space sweeps (§VII: Hardware-Aware Design Space
//! Pruning + Performance Exploration).

use crate::hw::{EngineDesign, EngineKind, Platform, TileConfig, Workload};
use crate::util::pool::par_map;

/// One evaluated hardware design point for a workload.
#[derive(Debug, Clone, Copy)]
pub struct DesignPoint {
    pub design: EngineDesign,
    /// Latency on the target platform, including bandwidth stalls.
    pub effective_latency: f64,
}

/// A linear layer's MatMul workload plus its allocated rank (`None` for
/// the dense / quantization-only mapping).
#[derive(Debug, Clone, Copy)]
pub struct LayerWork {
    pub workload: Workload,
    pub rank: Option<usize>,
}

/// Power-of-two tile candidates `(M_t, N_t, K_f)` bounded by the workload
/// dims and a PE budget. The grid matches the paper's HLS design space
/// (spatial unroll factors are powers of two).
pub fn enumerate_tiles(w: &Workload, max_pes: usize) -> Vec<TileConfig> {
    let pow2 = |limit: usize| {
        let mut v = Vec::new();
        let mut x = 1usize;
        while x <= limit {
            v.push(x);
            x *= 2;
        }
        v
    };
    let mut out = Vec::new();
    for &mt in &pow2(w.m.min(64)) {
        for &nt in &pow2(w.n.min(64)) {
            if mt * nt > max_pes {
                continue;
            }
            for &kf in &pow2(w.k.min(64)) {
                out.push(TileConfig::new(mt, nt, kf));
            }
        }
    }
    out
}

/// Evaluate every engine kind x tile combination for a workload (with
/// optional decomposition rank), keeping only designs that fit the
/// platform's DSP/BRAM budget.
pub fn sweep_engines(
    w: &Workload,
    rank: Option<usize>,
    platform: &Platform,
    kinds: &[EngineKind],
) -> Vec<DesignPoint> {
    let tiles = enumerate_tiles(w, platform.dsp);
    let mut designs: Vec<EngineDesign> = Vec::new();

    for kind in kinds {
        match (kind, rank) {
            (EngineKind::Baseline, _) => {
                designs.extend(tiles.iter().map(|&t| EngineDesign::baseline(w, t)));
            }
            (EngineKind::SingleSvd, Some(r)) => {
                designs.extend(tiles.iter().map(|&t| EngineDesign::single_svd(w, r, t)));
            }
            (EngineKind::CascadeSvd, Some(r)) => {
                // Cascade: stage tiles share M_t; sweep (R_t, N_t, K_f)
                // pairs on a reduced grid to keep the space tractable.
                let s1 = Workload::new(w.m, w.k, r, w.w_bits, w.a_bits);
                for &t2 in &tiles {
                    let t1_candidates = enumerate_tiles(&s1, platform.dsp);
                    for t1 in t1_candidates.into_iter().filter(|t1| t1.mt == t2.mt) {
                        designs.push(EngineDesign::cascade_svd(w, r, t1, t2));
                    }
                }
            }
            _ => {}
        }
    }

    designs
        .into_iter()
        .filter(|d| d.fits(platform))
        .map(|design| DesignPoint {
            design,
            effective_latency: design.effective_latency(platform),
        })
        .collect()
}

/// Lowest-latency feasible design for one layer workload.
pub fn best_design_for_layer(
    w: &Workload,
    rank: Option<usize>,
    platform: &Platform,
) -> Option<DesignPoint> {
    let kinds: &[EngineKind] = match rank {
        None => &[EngineKind::Baseline],
        Some(_) => &[EngineKind::SingleSvd, EngineKind::CascadeSvd],
    };
    sweep_engines(w, rank, platform, kinds)
        .into_iter()
        .min_by(|a, b| a.effective_latency.partial_cmp(&b.effective_latency).unwrap())
}

/// Total model latency: pick the best engine per layer (the accelerator is
/// reconfigured per layer shape as in the paper's per-layer exploration)
/// and sum effective latencies. Returns `(total_cycles, per-layer picks)`.
pub fn best_design_for_model(
    layers: &[LayerWork],
    platform: &Platform,
    workers: usize,
) -> Option<(f64, Vec<DesignPoint>)> {
    let picks = par_map(layers.len(), workers, |i| {
        best_design_for_layer(&layers[i].workload, layers[i].rank, platform)
    });
    let picks: Option<Vec<DesignPoint>> = picks.into_iter().collect();
    let picks = picks?;
    let total = picks.iter().map(|p| p.effective_latency).sum();
    Some((total, picks))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w512(wb: u32) -> Workload {
        Workload::new(512, 512, 512, wb, 8)
    }

    #[test]
    fn tile_enumeration_bounds() {
        let tiles = enumerate_tiles(&w512(4), 1024);
        assert!(!tiles.is_empty());
        for t in &tiles {
            assert!(t.mt * t.nt <= 1024);
            assert!(t.mt <= 64 && t.nt <= 64 && t.kf <= 64);
        }
        // Small workloads bound the tile sizes.
        let small = Workload::new(8, 8, 8, 8, 8);
        for t in enumerate_tiles(&small, 1024) {
            assert!(t.mt <= 8 && t.nt <= 8 && t.kf <= 8);
        }
    }

    #[test]
    fn all_swept_designs_fit() {
        let p = Platform::zcu111();
        for d in sweep_engines(&w512(4), Some(128), &p, &[EngineKind::SingleSvd]) {
            assert!(d.design.fits(&p));
            assert!(d.effective_latency >= d.design.latency_cycles - 1e-9);
        }
    }

    #[test]
    fn best_layer_design_beats_median() {
        let p = Platform::zcu111();
        let pts = sweep_engines(&w512(4), None, &p, &[EngineKind::Baseline]);
        let best = best_design_for_layer(&w512(4), None, &p).unwrap();
        let mut lats: Vec<f64> = pts.iter().map(|d| d.effective_latency).collect();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(best.effective_latency <= lats[0] + 1e-9);
    }

    #[test]
    fn svd_wins_at_low_rank_on_zcu111() {
        // The headline effect (Fig. 11): with rank 128 at W4A8, the best
        // SVD mapping beats the best dense baseline mapping.
        let p = Platform::zcu111();
        let base = best_design_for_layer(&w512(4), None, &p).unwrap();
        let svd = best_design_for_layer(&w512(4), Some(128), &p).unwrap();
        assert!(
            svd.effective_latency < base.effective_latency,
            "svd {} vs base {}",
            svd.effective_latency,
            base.effective_latency
        );
    }

    #[test]
    fn model_total_is_sum_of_layers() {
        let p = Platform::zcu111();
        let layers = vec![
            LayerWork { workload: w512(4), rank: Some(128) },
            LayerWork { workload: Workload::new(512, 512, 2048, 4, 8), rank: None },
        ];
        let (total, picks) = best_design_for_model(&layers, &p, 1).unwrap();
        assert_eq!(picks.len(), 2);
        let sum: f64 = picks.iter().map(|d| d.effective_latency).sum();
        assert!((total - sum).abs() < 1e-9);
    }

    #[test]
    fn quarter_bandwidth_never_faster() {
        let full = Platform::zcu111();
        let quarter = Platform::zcu111_quarter_bw();
        for rank in [None, Some(64), Some(128)] {
            let a = best_design_for_layer(&w512(4), rank, &full).unwrap();
            let b = best_design_for_layer(&w512(4), rank, &quarter).unwrap();
            assert!(b.effective_latency >= a.effective_latency - 1e-9);
        }
    }
}
