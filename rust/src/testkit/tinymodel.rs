//! Deterministic tiny-model artifact generator.
//!
//! Synthesizes everything `Manifest::load` + `PairModel::load` +
//! `Corpus::load` expect — an ITWB weight store, a `manifest.json` with
//! the full linear inventory and argument orders, and an 8-sentence ITCP
//! corpus — in a directory of the caller's choosing. The weights are
//! seeded PCG noise (not a trained model): the native-runtime e2e tests
//! assert *mechanics* (dense/factored parity, decode determinism, the
//! serve loop), which don't need a model that translates well, only one
//! that is fully deterministic and architecturally faithful (1 encoder +
//! 1 decoder block, multi-head attention, tied embeddings).
//!
//! No Python anywhere: this is what makes the always-built e2e suite
//! hermetic.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::model::{Manifest, WeightStore};
use crate::tensor::Matrix;
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// The synthetic language pair the generator registers.
pub const PAIR: &str = "xx-yy";

/// Tiny-but-real dimensions: every architectural feature of the full
/// model (heads, FFN expansion, separate encoder/decoder stacks) at the
/// smallest size where attention still has two heads to merge.
pub const VOCAB: usize = 48;
pub const D_MODEL: usize = 16;
pub const N_HEADS: usize = 2;
pub const D_FF: usize = 32;
pub const N_ENC: usize = 1;
pub const N_DEC: usize = 1;
pub const SEQ_LEN: usize = 10;
pub const EVAL_BATCH: usize = 4;
pub const SENTENCES: usize = 8;

const PAD: i32 = 0;
const BOS: i32 = 1;
const EOS: i32 = 2;

/// Ordered names of every compressed linear (mirrors
/// `model.py::compressed_linear_names` at the tiny configuration).
pub fn linear_names() -> Vec<String> {
    let mut names = Vec::new();
    for i in 0..N_ENC {
        for w in ["self_q", "self_k", "self_v", "self_o", "ff1", "ff2"] {
            names.push(format!("enc{i}.{w}"));
        }
    }
    for i in 0..N_DEC {
        for w in [
            "self_q", "self_k", "self_v", "self_o", "cross_q", "cross_k", "cross_v",
            "cross_o", "ff1", "ff2",
        ] {
            names.push(format!("dec{i}.{w}"));
        }
    }
    names
}

fn linear_shape(name: &str) -> (usize, usize) {
    if name.ends_with(".ff1") {
        (D_MODEL, D_FF)
    } else if name.ends_with(".ff2") {
        (D_FF, D_MODEL)
    } else {
        (D_MODEL, D_MODEL)
    }
}

/// Uncompressed parameters (embeddings, layer norms) in the artifact's
/// fixed argument order.
fn other_param_names() -> Vec<String> {
    let mut names = vec!["src_emb".to_string(), "tgt_emb".to_string(), "pos_emb".to_string()];
    for i in 0..N_ENC {
        for p in ["ln1_g", "ln1_b", "ln2_g", "ln2_b"] {
            names.push(format!("enc{i}.{p}"));
        }
    }
    names.push("enc_ln_g".to_string());
    names.push("enc_ln_b".to_string());
    for i in 0..N_DEC {
        for p in ["ln1_g", "ln1_b", "ln2_g", "ln2_b", "ln3_g", "ln3_b"] {
            names.push(format!("dec{i}.{p}"));
        }
    }
    names.push("dec_ln_g".to_string());
    names.push("dec_ln_b".to_string());
    names
}

/// Generate the full artifact set under `dir` and return the loaded
/// manifest. Deterministic in `seed`: the same seed writes byte-identical
/// stores on every call.
pub fn generate(dir: impl AsRef<Path>, seed: u64) -> Result<Manifest> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
    let mut rng = Pcg64::new(seed);

    // ---- weight store -------------------------------------------------
    let mut store = WeightStore::new();
    store.insert("src_emb", Matrix::randn(VOCAB, D_MODEL, &mut rng).scale(0.3));
    store.insert("tgt_emb", Matrix::randn(VOCAB, D_MODEL, &mut rng).scale(0.3));
    store.insert("pos_emb", Matrix::randn(SEQ_LEN, D_MODEL, &mut rng).scale(0.1));
    for name in other_param_names() {
        if name.ends_with("_g") {
            store.insert_vec(&name, vec![1.0; D_MODEL]);
        } else if name.ends_with("_b") {
            store.insert_vec(&name, vec![0.0; D_MODEL]);
        }
    }
    for name in linear_names() {
        let (k, n) = linear_shape(&name);
        let scale = 1.0 / (k as f32).sqrt();
        store.insert(&name, Matrix::randn(k, n, &mut rng).scale(scale));
    }
    store.save(dir.join(format!("weights_{PAIR}.bin")))?;

    // ---- corpus (identity pair: target copies the source tokens) ------
    let corpus = make_corpus(&mut rng);
    std::fs::write(dir.join(format!("corpus_{PAIR}.bin")), &corpus)?;
    std::fs::write(dir.join(format!("calib_{PAIR}.bin")), &corpus)?;

    // ---- manifest -----------------------------------------------------
    let names = linear_names();
    let linears = Json::Arr(
        names
            .iter()
            .map(|name| {
                let (k, n) = linear_shape(name);
                Json::obj(vec![
                    ("name", Json::Str(name.clone())),
                    ("k", Json::Num(k as f64)),
                    ("n", Json::Num(n as f64)),
                    ("r_max", Json::Num(k.min(n) as f64)),
                ])
            })
            .collect(),
    );
    let mut dense_order =
        vec!["src_tokens".to_string(), "act_scales".to_string(), "act_levels".to_string()];
    dense_order.extend(other_param_names());
    let mut svd_order = dense_order.clone();
    for name in &names {
        dense_order.push(name.clone());
        svd_order.push(format!("{name}.w1"));
        svd_order.push(format!("{name}.w2"));
    }
    let arr_string = |xs: &[String]| Json::Arr(xs.iter().map(|s| Json::Str(s.clone())).collect());
    // Plausible static calibration range; the LN-normalized activations
    // of the random model sit well inside ±4.
    let act_maxabs = vec![4.0f64; names.len()];

    let manifest = Json::obj(vec![
        (
            "model",
            Json::obj(vec![
                ("vocab", Json::Num(VOCAB as f64)),
                ("d_model", Json::Num(D_MODEL as f64)),
                ("n_heads", Json::Num(N_HEADS as f64)),
                ("d_ff", Json::Num(D_FF as f64)),
                ("n_enc", Json::Num(N_ENC as f64)),
                ("n_dec", Json::Num(N_DEC as f64)),
                ("seq_len", Json::Num(SEQ_LEN as f64)),
                ("eval_batch", Json::Num(EVAL_BATCH as f64)),
                ("pad_id", Json::Num(PAD as f64)),
                ("bos_id", Json::Num(BOS as f64)),
                ("eos_id", Json::Num(EOS as f64)),
            ]),
        ),
        ("linears", linears),
        (
            "arg_order",
            Json::obj(vec![
                ("dense", arr_string(&dense_order)),
                ("svd", arr_string(&svd_order)),
            ]),
        ),
        (
            "artifacts",
            Json::obj(vec![
                // The tiny set carries no compiled HLO; these names only
                // resolve if a PJRT build tries to execute them.
                ("translate_dense", Json::Str("translate_dense.hlo.txt".into())),
                ("translate_svd", Json::Str("translate_svd.hlo.txt".into())),
                ("linear512_dense", Json::Str("linear512_dense.hlo.txt".into())),
                ("linear512_svd", Json::Str("linear512_svd.hlo.txt".into())),
            ]),
        ),
        (
            "pairs",
            Json::obj(vec![(
                PAIR,
                Json::obj(vec![
                    ("weights", Json::Str(format!("weights_{PAIR}.bin"))),
                    ("corpus", Json::Str(format!("corpus_{PAIR}.bin"))),
                    ("calib", Json::Str(format!("calib_{PAIR}.bin"))),
                    ("act_maxabs", Json::arr_f64(&act_maxabs)),
                ]),
            )]),
        ),
    ]);
    std::fs::write(dir.join("manifest.json"), manifest.to_string_pretty())?;

    Manifest::load(dir)
}

/// Generate under a process-unique temp dir (`tag` keeps concurrent test
/// binaries apart); returns the directory and the loaded manifest.
pub fn generate_in_temp(tag: &str, seed: u64) -> Result<(PathBuf, Manifest)> {
    let dir = std::env::temp_dir().join(format!("itera_tiny_{tag}_{}", std::process::id()));
    let manifest = generate(&dir, seed)?;
    Ok((dir, manifest))
}

/// ITCP corpus bytes: BOS-framed, EOS-terminated, PAD-padded rows where
/// the target equals the source (a copy pair — deterministic and enough
/// for pipeline mechanics).
fn make_corpus(rng: &mut Pcg64) -> Vec<u8> {
    let mut rows: Vec<Vec<i32>> = Vec::with_capacity(SENTENCES);
    for _ in 0..SENTENCES {
        let len = 3 + rng.below(5); // 3..=7 content tokens
        let mut row = vec![PAD; SEQ_LEN];
        row[0] = BOS;
        for slot in row.iter_mut().skip(1).take(len) {
            *slot = 3 + rng.below(VOCAB - 3) as i32;
        }
        row[1 + len] = EOS;
        rows.push(row);
    }
    let mut out = Vec::new();
    out.extend_from_slice(b"ITCP");
    out.extend_from_slice(&(SENTENCES as u32).to_le_bytes());
    out.extend_from_slice(&(SEQ_LEN as u32).to_le_bytes());
    for row in &rows {
        for &t in row {
            out.extend_from_slice(&t.to_le_bytes());
        }
    }
    for row in &rows {
        for &t in row {
            out.extend_from_slice(&t.to_le_bytes());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Corpus;
    use crate::model::PairModel;

    #[test]
    fn generates_loadable_artifacts() {
        let (dir, m) = generate_in_temp("unit_load", 7).unwrap();
        assert_eq!(m.model.d_model, D_MODEL);
        assert_eq!(m.linears.len(), N_ENC * 6 + N_DEC * 10);
        let model = PairModel::load(&m, PAIR).unwrap();
        assert_eq!(model.act_maxabs.len(), m.linears.len());
        let corpus = Corpus::load(&m.pairs[PAIR].corpus).unwrap();
        assert_eq!(corpus.n, SENTENCES);
        assert_eq!(corpus.seq_len, SEQ_LEN);
        for i in 0..corpus.n {
            assert_eq!(corpus.src_row(i)[0], BOS);
            assert_eq!(corpus.src_row(i), corpus.tgt_row(i), "copy pair");
            assert!(corpus.src_row(i).contains(&EOS));
        }
        // Every manifest linear is present with the declared shape.
        for l in &m.linears {
            assert_eq!(model.linear(&l.name).shape(), (l.k, l.n), "{}", l.name);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn same_seed_is_byte_deterministic() {
        let base = std::env::temp_dir().join(format!("itera_tiny_det_{}", std::process::id()));
        let d1 = base.join("a");
        let d2 = base.join("b");
        generate(&d1, 42).unwrap();
        generate(&d2, 42).unwrap();
        for f in [
            format!("weights_{PAIR}.bin"),
            format!("corpus_{PAIR}.bin"),
            "manifest.json".to_string(),
        ] {
            let a = std::fs::read(d1.join(&f)).unwrap();
            let b = std::fs::read(d2.join(&f)).unwrap();
            assert_eq!(a, b, "{f} differs between same-seed runs");
        }
        let d3 = base.join("c");
        generate(&d3, 43).unwrap();
        assert_ne!(
            std::fs::read(d1.join(format!("weights_{PAIR}.bin"))).unwrap(),
            std::fs::read(d3.join(format!("weights_{PAIR}.bin"))).unwrap(),
            "different seeds must differ"
        );
        std::fs::remove_dir_all(&base).ok();
    }
}
