//! Row-major dense f32 matrix.

use crate::util::rng::Pcg64;

/// Row-major dense matrix of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Gaussian random matrix (testing / synthetic workloads).
    pub fn randn(rows: usize, cols: usize, rng: &mut Pcg64) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal()).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Matrix product `self (m x k) * other (k x n)`.
    ///
    /// i-k-j loop order: the inner loop walks both `other.row(k)` and the
    /// output row contiguously, which is the main reason Algorithm 1's
    /// residual updates run at memory speed (see EXPERIMENTS.md §Perf).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let a_row = &self.data[i * k..(i + 1) * k];
            let o_row = &mut out.data[i * n..(i + 1) * n];
            for (kk, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue; // zero-padded SVD factors skip whole rows
                }
                let b_row = &other.data[kk * n..(kk + 1) * n];
                for (o, &b) in o_row.iter_mut().zip(b_row) {
                    *o += a * b;
                }
            }
        }
        out
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// In-place `self -= other` (residual updates without reallocation).
    pub fn sub_assign(&mut self, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
    }

    /// In-place rank-1 downdate `self -= a * b^T` — the Algorithm 1 residual
    /// step fused to avoid materializing the outer product.
    pub fn sub_outer(&mut self, a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), self.rows);
        assert_eq!(b.len(), self.cols);
        for (i, &ai) in a.iter().enumerate() {
            if ai == 0.0 {
                continue;
            }
            let row = self.row_mut(i);
            for (r, &bj) in row.iter_mut().zip(b) {
                *r -= ai * bj;
            }
        }
    }

    pub fn scale(&self, s: f32) -> Matrix {
        let data = self.data.iter().map(|x| x * s).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Matrix-vector product `self (m x n) * v (n)`.
    pub fn matvec(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.cols);
        (0..self.rows).map(|i| super::dot(self.row(i), v)).collect()
    }

    /// `self^T * v` without materializing the transpose.
    pub fn tr_matvec(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.rows);
        let mut out = vec![0.0f32; self.cols];
        for (i, &vi) in v.iter().enumerate() {
            if vi == 0.0 {
                continue;
            }
            super::axpy(vi, self.row(i), &mut out);
        }
        out
    }

    /// Horizontal concatenation (Algorithm 1's `hstack`).
    pub fn hstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows);
        let mut out = Matrix::zeros(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        out
    }

    /// Vertical concatenation (Algorithm 1's `vstack`).
    pub fn vstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix { rows: self.rows + other.rows, cols: self.cols, data }
    }

    /// Zero-pad to `(rows, cols)` (rank-padding for the SVD artifact).
    pub fn pad_to(&self, rows: usize, cols: usize) -> Matrix {
        assert!(rows >= self.rows && cols >= self.cols);
        let mut out = Matrix::zeros(rows, cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
        }
        out
    }

    /// Take the leading `cols` columns.
    pub fn take_cols(&self, cols: usize) -> Matrix {
        assert!(cols <= self.cols);
        Matrix::from_fn(self.rows, cols, |i, j| self.get(i, j))
    }

    /// Take the leading `rows` rows.
    pub fn take_rows(&self, rows: usize) -> Matrix {
        assert!(rows <= self.rows);
        Matrix::from_vec(rows, self.cols, self.data[..rows * self.cols].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, v: &[f32]) -> Matrix {
        Matrix::from_vec(rows, cols, v.to_vec())
    }

    #[test]
    fn matmul_hand() {
        let a = mat(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = mat(3, 2, &[7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::new(1);
        let a = Matrix::randn(5, 5, &mut rng);
        let i = Matrix::eye(5);
        let prod = a.matmul(&i);
        for (x, y) in prod.data().iter().zip(a.data()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::new(2);
        let a = Matrix::randn(4, 7, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn sub_outer_matches_explicit() {
        let mut rng = Pcg64::new(3);
        let mut a = Matrix::randn(6, 5, &mut rng);
        let b = a.clone();
        let u: Vec<f32> = (0..6).map(|i| i as f32 * 0.3).collect();
        let v: Vec<f32> = (0..5).map(|i| 1.0 - i as f32 * 0.1).collect();
        a.sub_outer(&u, &v);
        let explicit = b.sub(&crate::tensor::outer(&u, &v));
        for (x, y) in a.data().iter().zip(explicit.data()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn frob_norm_hand() {
        let a = mat(2, 2, &[3., 0., 0., 4.]);
        assert!((a.frob_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn stack_and_pad() {
        let a = mat(2, 2, &[1., 2., 3., 4.]);
        let b = mat(2, 1, &[9., 9.]);
        let h = a.hstack(&b);
        assert_eq!(h.shape(), (2, 3));
        assert_eq!(h.row(0), &[1., 2., 9.]);
        let c = mat(1, 2, &[7., 8.]);
        let v = a.vstack(&c);
        assert_eq!(v.shape(), (3, 2));
        assert_eq!(v.row(2), &[7., 8.]);
        let p = a.pad_to(3, 4);
        assert_eq!(p.shape(), (3, 4));
        assert_eq!(p.get(0, 1), 2.0);
        assert_eq!(p.get(2, 3), 0.0);
        assert_eq!(p.take_cols(2).take_rows(2), a);
    }

    #[test]
    fn matvec_and_transpose_matvec() {
        let a = mat(2, 3, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.matvec(&[1., 0., 1.]), vec![4., 10.]);
        assert_eq!(a.tr_matvec(&[1., 1.]), vec![5., 7., 9.]);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
