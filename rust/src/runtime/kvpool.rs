//! Paged KV memory: a fixed-size-page allocator with a global byte
//! budget, plus the page-backed row store `SeqSlot`'s self-attention
//! K/V slabs live in.
//!
//! The contiguous-slab design ([`crate::runtime::SeqSlot`] before this
//! module) sized every slot's K/V at admission: `2 * n_dec` matrices of
//! `[seq_len x d_model]` f32, resident for the whole lifecycle even
//! though a slot that EOSes after 3 steps only ever wrote 3 rows.
//! Capacity was therefore a *slot count*, and ragged traffic either
//! under-used the budget (short sequences pinned full slabs) or had no
//! budget at all.
//!
//! [`KvPool`] replaces that with the paged discipline the serving
//! literature converged on (vLLM's PagedAttention, the block allocator
//! in the inference-optimization survey): KV memory is a pool of
//! fixed-size **pages** of `page_tokens` rows × `width` floats, handed
//! out from a free list under a global byte budget. Each per-layer K or
//! V slab is a [`PagedRows`] — a page table of non-contiguous pages
//! presenting a growable `[rows x width]` view — and pages are
//! allocated **lazily**, one step ahead of the decode cursor, so a
//! slot's resident bytes track what it actually decoded:
//!
//! ```text
//!   logical rows      page table           pool (budget = 6 pages)
//!   ┌───────────┐     ┌───────┐            ┌────┬────┬────┬────┐
//!   │ row 0..3  │ ──▶ │ page A│            │ A  │ B  │ C  │free│ ...
//!   │ row 4..7  │ ──▶ │ page C│            └────┴────┴────┴────┘
//!   │ row 8..   │ ──▶ │ (lazy)│            resident_bytes() == 3 pages
//!   └───────────┘     └───────┘            (A, B, C across all tables)
//! ```
//!
//! Accounting is exact and checked: `resident_bytes()` is
//! `outstanding_pages * page_bytes`, releases `debug_assert` against
//! double-free/underflow, and every [`PagedRows`] returns its pages on
//! [`PagedRows::release`] (explicit, at slot retirement) *and* on drop
//! (the leak-proof safety net), so the pool's outstanding count must
//! return to zero when no slot is live — the invariant the allocator
//! proptest drives with random alloc/grow/free/evict traces.
//!
//! Reads are bit-transparent: a row lives contiguously inside exactly
//! one page (`width` floats at `(row % page_tokens) * width`), so the
//! attention kernels consume the same `&[f32]` rows they read from a
//! contiguous [`Matrix`] slab — paging changes *where* a row lives,
//! never its values or the accumulation order over it. [`RowRead`]
//! abstracts the two layouts so one kernel serves both.

use std::sync::{Arc, Mutex, MutexGuard};

use crate::tensor::Matrix;

/// Read-only row access shared by contiguous [`Matrix`] slabs and
/// page-backed [`PagedRows`]: the attention kernels are written against
/// this, so cross-attention (constant, contiguous) and self-attention
/// (growing, paged) K/V go through one bit-identical code path.
pub trait RowRead {
    /// Row `i` as a contiguous `[width]` slice.
    fn row(&self, i: usize) -> &[f32];
}

impl RowRead for Matrix {
    fn row(&self, i: usize) -> &[f32] {
        Matrix::row(self, i)
    }
}

impl RowRead for PagedRows {
    fn row(&self, i: usize) -> &[f32] {
        PagedRows::row(self, i)
    }
}

/// Point-in-time pool accounting, surfaced to the scheduler through
/// [`crate::runtime::SlotEngine::kv_stats`] and onto `/metrics` as the
/// `kv_resident_bytes` / `kv_pages_free` gauges. `None` fields mean the
/// pool is unbounded (the compatibility default): resident bytes are
/// still tracked exactly, but there is no budget to admit against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvMemStats {
    /// Usable budget in bytes (`capacity_pages * page_bytes`, i.e. the
    /// configured budget rounded down to whole pages); `None` when
    /// unbounded.
    pub budget_bytes: Option<usize>,
    /// Bytes still allocatable; `None` when unbounded.
    pub free_bytes: Option<usize>,
    /// Pages still allocatable; `None` when unbounded.
    pub free_pages: Option<usize>,
    /// Bytes currently held by live page tables (exact).
    pub resident_bytes: usize,
}

/// Free list + outstanding count behind the pool's mutex.
#[derive(Default)]
struct PoolInner {
    /// Released pages, retained for reuse (they count against the
    /// budget only while outstanding).
    free: Vec<Box<[f32]>>,
    /// Pages currently held by page tables.
    outstanding: usize,
}

/// Fixed-size-page KV allocator with a global byte budget.
///
/// Pages are `page_tokens * width` f32 buffers. [`KvPool::try_alloc`]
/// hands out a zeroed page (from the free list, else freshly allocated
/// while under budget) or `None` when the budget is exhausted —
/// allocation failure is a *scheduling* signal (evict or queue), never
/// a panic. The pool is internally synchronized; clones of the same
/// `Arc<KvPool>` share one budget.
pub struct KvPool {
    page_tokens: usize,
    width: usize,
    /// Floats per page (`page_tokens * width`).
    page_floats: usize,
    /// Page budget (`budget_bytes / page_bytes`, floored); `None` is
    /// unbounded.
    budget_pages: Option<usize>,
    inner: Mutex<PoolInner>,
}

impl KvPool {
    /// A pool of `page_tokens`-row pages, `width` floats per row, bounded
    /// by `budget_bytes` (rounded *down* to whole pages; a budget smaller
    /// than one page can never allocate). `None` is unbounded.
    pub fn new(page_tokens: usize, width: usize, budget_bytes: Option<usize>) -> KvPool {
        assert!(page_tokens >= 1 && width >= 1, "pages need at least one row and one column");
        let page_floats = page_tokens * width;
        KvPool {
            page_tokens,
            width,
            page_floats,
            budget_pages: budget_bytes.map(|b| b / (page_floats * 4)),
            inner: Mutex::new(PoolInner::default()),
        }
    }

    /// Unbounded pool (exact accounting, no admission bound) — the
    /// compatibility default every backend starts with.
    pub fn unbounded(page_tokens: usize, width: usize) -> KvPool {
        KvPool::new(page_tokens, width, None)
    }

    fn lock(&self) -> MutexGuard<'_, PoolInner> {
        // A panicking holder (the batcher steps under catch_unwind)
        // must not wedge the pool: the inner state is a free list and a
        // counter, both valid at every await-free point.
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Rows per page.
    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// Floats per row.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Bytes per page.
    pub fn page_bytes(&self) -> usize {
        self.page_floats * 4
    }

    /// Pages needed to back `rows` rows.
    pub fn pages_for(&self, rows: usize) -> usize {
        rows.div_ceil(self.page_tokens)
    }

    /// Total allocatable pages; `None` when unbounded.
    pub fn capacity_pages(&self) -> Option<usize> {
        self.budget_pages
    }

    /// Pages currently held by page tables.
    pub fn outstanding_pages(&self) -> usize {
        self.lock().outstanding
    }

    /// Exact bytes held by live page tables.
    pub fn resident_bytes(&self) -> usize {
        self.outstanding_pages() * self.page_bytes()
    }

    /// Pages still allocatable; `None` when unbounded.
    pub fn free_pages(&self) -> Option<usize> {
        self.budget_pages.map(|c| c.saturating_sub(self.lock().outstanding))
    }

    /// Bytes still allocatable; `None` when unbounded.
    pub fn free_bytes(&self) -> Option<usize> {
        self.free_pages().map(|p| p * self.page_bytes())
    }

    /// Usable budget in bytes (whole pages); `None` when unbounded.
    pub fn budget_bytes(&self) -> Option<usize> {
        self.budget_pages.map(|c| c * self.page_bytes())
    }

    /// The pool's point-in-time accounting snapshot.
    pub fn stats(&self) -> KvMemStats {
        let outstanding = self.lock().outstanding;
        let pb = self.page_bytes();
        KvMemStats {
            budget_bytes: self.budget_pages.map(|c| c * pb),
            free_bytes: self.budget_pages.map(|c| c.saturating_sub(outstanding) * pb),
            free_pages: self.budget_pages.map(|c| c.saturating_sub(outstanding)),
            resident_bytes: outstanding * pb,
        }
    }

    /// Allocate one zeroed page, or `None` when the budget is spent.
    /// Released pages are reused (re-zeroed, so a recycled page is
    /// bit-identical to a fresh one).
    pub fn try_alloc(&self) -> Option<Box<[f32]>> {
        let mut inner = self.lock();
        let page = match inner.free.pop() {
            Some(mut p) => {
                p.fill(0.0);
                p
            }
            None => {
                if self.budget_pages.is_some_and(|c| inner.outstanding >= c) {
                    return None;
                }
                vec![0.0f32; self.page_floats].into_boxed_slice()
            }
        };
        inner.outstanding += 1;
        Some(page)
    }

    /// Return a page to the free list. Double-frees and foreign pages
    /// are programming errors, caught by debug asserts.
    pub fn release(&self, page: Box<[f32]>) {
        debug_assert_eq!(page.len(), self.page_floats, "page from a different pool geometry");
        let mut inner = self.lock();
        debug_assert!(inner.outstanding > 0, "release without a matching alloc (double free?)");
        inner.outstanding = inner.outstanding.saturating_sub(1);
        inner.free.push(page);
    }
}

/// A growable `[rows x width]` row store over non-contiguous pool
/// pages: the page table one K or V slab owns.
///
/// Rows are appended in decode order, so backing is monotone: row `i`
/// is readable iff some [`Self::ensure_row`] covered it. Reads index
/// `page = i / page_tokens`, `offset = i % page_tokens` — each row is
/// contiguous within its page, so kernels consume the same `&[f32]`
/// slices a flat slab would give them.
///
/// Pages return to the pool on [`Self::release`] (explicit, so slot
/// retirement can leak-check) and on drop (the safety net that makes
/// leaks unrepresentable).
pub struct PagedRows {
    pool: Arc<KvPool>,
    pages: Vec<Box<[f32]>>,
}

impl PagedRows {
    /// An empty row store drawing from `pool` (no pages until
    /// [`Self::ensure_row`]).
    pub fn new(pool: &Arc<KvPool>) -> PagedRows {
        PagedRows { pool: Arc::clone(pool), pages: Vec::new() }
    }

    /// Pages currently held.
    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }

    /// Bytes currently held.
    pub fn resident_bytes(&self) -> usize {
        self.pages.len() * self.pool.page_bytes()
    }

    /// Rows currently backed by pages (readable/writable without
    /// allocating).
    pub fn backed_rows(&self) -> usize {
        self.pages.len() * self.pool.page_tokens()
    }

    /// Whether writing row `i` needs a new page first.
    pub fn needs_page_for(&self, i: usize) -> bool {
        i >= self.backed_rows()
    }

    /// Grow the page table until row `i` is backed. `false` when the
    /// pool's budget is exhausted (the table keeps whatever it already
    /// acquired — re-ensuring after an eviction freed pages is safe and
    /// idempotent).
    pub fn ensure_row(&mut self, i: usize) -> bool {
        while self.needs_page_for(i) {
            match self.pool.try_alloc() {
                Some(p) => self.pages.push(p),
                None => return false,
            }
        }
        true
    }

    /// Row `i` as a contiguous `[width]` slice. Panics when `i` is not
    /// backed — decode only reads rows it has written.
    pub fn row(&self, i: usize) -> &[f32] {
        let w = self.pool.width();
        let pt = self.pool.page_tokens();
        let off = (i % pt) * w;
        &self.pages[i / pt][off..off + w]
    }

    /// Mutable row `i`; same backing requirement as [`Self::row`].
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let w = self.pool.width();
        let pt = self.pool.page_tokens();
        let off = (i % pt) * w;
        &mut self.pages[i / pt][off..off + w]
    }

    /// Return every page to the pool. Idempotent; called explicitly at
    /// slot retirement (so the leak check runs at a known point) and
    /// again from drop as a safety net.
    pub fn release(&mut self) {
        for p in self.pages.drain(..) {
            self.pool.release(p);
        }
    }
}

impl Drop for PagedRows {
    fn drop(&mut self) {
        self.release();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_accounts_allocs_and_releases_exactly() {
        let pool = KvPool::new(4, 8, Some(3 * 4 * 8 * 4)); // exactly 3 pages
        assert_eq!(pool.capacity_pages(), Some(3));
        assert_eq!(pool.page_bytes(), 4 * 8 * 4);
        assert_eq!(pool.resident_bytes(), 0);
        let a = pool.try_alloc().unwrap();
        let b = pool.try_alloc().unwrap();
        let c = pool.try_alloc().unwrap();
        assert_eq!(pool.outstanding_pages(), 3);
        assert_eq!(pool.free_pages(), Some(0));
        assert!(pool.try_alloc().is_none(), "budget spent: allocation must fail, not grow");
        pool.release(b);
        assert_eq!(pool.free_pages(), Some(1));
        assert_eq!(pool.resident_bytes(), 2 * pool.page_bytes());
        let b2 = pool.try_alloc().expect("freed page is reusable");
        assert!(b2.iter().all(|&v| v == 0.0), "recycled pages are re-zeroed");
        pool.release(a);
        pool.release(b2);
        pool.release(c);
        assert_eq!(pool.outstanding_pages(), 0, "all pages returned: zero leaks");
        assert_eq!(pool.free_bytes(), Some(3 * pool.page_bytes()));
    }

    #[test]
    fn budget_rounds_down_to_whole_pages() {
        // 2.5 pages of budget -> 2 allocatable pages.
        let pool = KvPool::new(2, 4, Some(2 * 2 * 4 * 4 + 16));
        assert_eq!(pool.capacity_pages(), Some(2));
        assert_eq!(pool.budget_bytes(), Some(2 * pool.page_bytes()));
        // Sub-page budget: nothing ever fits.
        let tiny = KvPool::new(2, 4, Some(1));
        assert_eq!(tiny.capacity_pages(), Some(0));
        assert!(tiny.try_alloc().is_none());
    }

    #[test]
    fn unbounded_pool_tracks_residency_without_a_bound() {
        let pool = KvPool::unbounded(2, 2);
        assert_eq!(pool.capacity_pages(), None);
        assert_eq!(pool.free_bytes(), None);
        let pages: Vec<_> = (0..10).map(|_| pool.try_alloc().unwrap()).collect();
        assert_eq!(pool.resident_bytes(), 10 * pool.page_bytes());
        let stats = pool.stats();
        assert_eq!(stats.budget_bytes, None);
        assert_eq!(stats.resident_bytes, 10 * pool.page_bytes());
        for p in pages {
            pool.release(p);
        }
        assert_eq!(pool.outstanding_pages(), 0);
    }

    #[test]
    fn paged_rows_grow_read_back_and_release() {
        let pool = Arc::new(KvPool::new(3, 4, Some(4 * 3 * 4 * 4))); // 4 pages
        let mut rows = PagedRows::new(&pool);
        assert_eq!(rows.backed_rows(), 0);
        assert!(rows.needs_page_for(0));
        assert!(rows.ensure_row(0));
        assert_eq!(rows.n_pages(), 1);
        assert!(!rows.needs_page_for(2), "page covers page_tokens rows");
        assert!(rows.needs_page_for(3));
        // Write a recognizable pattern across a page boundary, read it back.
        for i in 0..7 {
            assert!(rows.ensure_row(i));
            let r = rows.row_mut(i);
            for (c, v) in r.iter_mut().enumerate() {
                *v = (i * 10 + c) as f32;
            }
        }
        assert_eq!(rows.n_pages(), 3);
        for i in 0..7 {
            let r = rows.row(i);
            assert_eq!(r.len(), 4);
            for (c, &v) in r.iter().enumerate() {
                assert_eq!(v, (i * 10 + c) as f32, "row {i} col {c}");
            }
        }
        assert_eq!(pool.outstanding_pages(), 3);
        rows.release();
        assert_eq!(rows.n_pages(), 0);
        assert_eq!(pool.outstanding_pages(), 0, "explicit release returns every page");
        // Re-ensuring after release works (the re-prefill path).
        assert!(rows.ensure_row(5));
        assert_eq!(rows.n_pages(), 2);
        drop(rows);
        assert_eq!(pool.outstanding_pages(), 0, "drop is the leak-proof safety net");
    }

    #[test]
    fn exhaustion_is_a_clean_false_and_eviction_recovers() {
        let pool = Arc::new(KvPool::new(2, 2, Some(2 * 2 * 2 * 4))); // 2 pages
        let mut a = PagedRows::new(&pool);
        let mut b = PagedRows::new(&pool);
        assert!(a.ensure_row(3), "both pages fit one table");
        assert!(!b.ensure_row(0), "pool exhausted: ensure fails without panicking");
        assert_eq!(b.n_pages(), 0);
        // Evicting `a` frees its pages; `b` can now grow.
        a.release();
        assert!(b.ensure_row(1));
        assert_eq!(pool.outstanding_pages(), 1);
        drop(a);
        drop(b);
        assert_eq!(pool.outstanding_pages(), 0);
    }

    #[test]
    fn row_read_is_layout_transparent() {
        // The same logical rows through Matrix and PagedRows give the
        // same slices — the bit-parity argument for paging the slabs.
        let m = Matrix::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let pool = Arc::new(KvPool::unbounded(2, 2));
        let mut p = PagedRows::new(&pool);
        for i in 0..3 {
            assert!(p.ensure_row(i));
            p.row_mut(i).copy_from_slice(Matrix::row(&m, i));
        }
        fn read<R: RowRead>(r: &R, i: usize) -> Vec<f32> {
            r.row(i).to_vec()
        }
        for i in 0..3 {
            assert_eq!(read(&m, i), read(&p, i));
        }
    }
}
