//! End-to-end integration: artifacts -> PJRT -> BLEU.
//!
//! These tests exercise the full deployed stack: manifest + weight store +
//! corpus loading, argument-bank upload, greedy decoding through the
//! AOT-compiled HLO (with the Pallas kernels lowered inside), and BLEU
//! scoring — i.e. exactly what the coordinator does during DSE, minus the
//! search loops. Skipped when `make artifacts` has not run.
//!
//! The whole suite needs the PJRT runtime, so it only builds with the
//! `pjrt` feature.

#![cfg(feature = "pjrt")]

use std::collections::BTreeMap;

use itera_llm::compress::{itera, quant_only};
use itera_llm::eval::{evaluate_bleu, Corpus};
use itera_llm::model::{Manifest, PairModel};
use itera_llm::runtime::{Engine, Mode, PjrtBackend, TranslateSession};

fn setup() -> Option<(Manifest, Engine)> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    let manifest = Manifest::load(&dir).expect("manifest loads");
    let engine = Engine::cpu().expect("PJRT CPU client");
    Some((manifest, engine))
}

#[test]
fn fp32_reference_translates_near_perfectly() {
    let Some((manifest, engine)) = setup() else { return };
    let model = PairModel::load(&manifest, "en-de").unwrap();
    let corpus = Corpus::load(&manifest.pairs["en-de"].corpus).unwrap();
    let session = TranslateSession::new(&engine, &manifest, Mode::Dense).unwrap();
    // Empty compression map + no activation quant = FP32 reference.
    let bank = session.build_bank(&model, &BTreeMap::new(), None).unwrap();
    let backend = PjrtBackend::new(session, bank);
    let d = evaluate_bleu(&backend, &corpus, &manifest.model, 64).unwrap();
    assert!(
        d.score > 95.0,
        "FP32 reference must be near-perfect on the synthetic pair: BLEU {:.2} ({:?})",
        d.score,
        d.precisions
    );
}

#[test]
fn w8a8_quant_only_stays_close_to_fp32() {
    let Some((manifest, engine)) = setup() else { return };
    let model = PairModel::load(&manifest, "en-de").unwrap();
    let corpus = Corpus::load(&manifest.pairs["en-de"].corpus).unwrap();
    let session = TranslateSession::new(&engine, &manifest, Mode::Dense).unwrap();

    let mut compressed = BTreeMap::new();
    for l in &manifest.linears {
        compressed.insert(l.name.clone(), quant_only(model.linear(&l.name), 8));
    }
    let bank = session.build_bank(&model, &compressed, Some(8)).unwrap();
    let backend = PjrtBackend::new(session, bank);
    let d = evaluate_bleu(&backend, &corpus, &manifest.model, 48).unwrap();
    assert!(d.score > 85.0, "W8A8 should be nearly lossless: BLEU {:.2}", d.score);
}

#[test]
fn svd_artifact_full_rank_matches_dense_path() {
    let Some((manifest, engine)) = setup() else { return };
    let model = PairModel::load(&manifest, "en-de").unwrap();
    let corpus = Corpus::load(&manifest.pairs["en-de"].corpus).unwrap();

    // Factor every layer at full rank / 8 bits through Algorithm 1; the
    // SVD-mode artifact must land in the same accuracy regime as the
    // dense-mode quant baseline (they share quant granularity).
    let mut compressed = BTreeMap::new();
    for l in &manifest.linears {
        let (c, _) = itera(model.linear(&l.name), l.r_max, 8);
        compressed.insert(l.name.clone(), c);
    }
    let svd_session = TranslateSession::new(&engine, &manifest, Mode::Svd).unwrap();
    let bank = svd_session.build_bank(&model, &compressed, Some(8)).unwrap();
    let backend = PjrtBackend::new(svd_session, bank);
    let d = evaluate_bleu(&backend, &corpus, &manifest.model, 48).unwrap();
    assert!(
        d.score > 85.0,
        "full-rank W8A8 iterative decomposition should be near-lossless: {:.2}",
        d.score
    );
}

#[test]
fn svd_mode_rejects_unfactored_layers() {
    let Some((manifest, engine)) = setup() else { return };
    let model = PairModel::load(&manifest, "en-de").unwrap();
    let session = TranslateSession::new(&engine, &manifest, Mode::Svd).unwrap();
    let mut compressed = BTreeMap::new();
    for l in &manifest.linears {
        compressed.insert(l.name.clone(), quant_only(model.linear(&l.name), 8));
    }
    assert!(
        session.build_bank(&model, &compressed, Some(8)).is_err(),
        "Dense layers must be rejected by the SVD artifact"
    );
}

#[test]
fn both_language_pairs_load_and_translate() {
    let Some((manifest, engine)) = setup() else { return };
    for pair in ["en-de", "fr-en"] {
        let model = PairModel::load(&manifest, pair).unwrap();
        let corpus = Corpus::load(&manifest.pairs[pair].corpus).unwrap();
        let session = TranslateSession::new(&engine, &manifest, Mode::Dense).unwrap();
        let bank = session.build_bank(&model, &BTreeMap::new(), None).unwrap();
        let backend = PjrtBackend::new(session, bank);
        let d = evaluate_bleu(&backend, &corpus, &manifest.model, 32).unwrap();
        assert!(d.score > 90.0, "{pair}: FP32 BLEU {:.2}", d.score);
    }
}
