//! Hot-path microbenchmarks (custom harness; no criterion in the image).
//!
//! Covers the compute kernels the perf pass optimizes (EXPERIMENTS.md
//! §Perf): Algorithm 1 and its SVD building blocks, the incremental
//! compression cache behind the SRA/DSE search loops, quantization, the
//! dense matmul (serial + blocked + pool-parallel), the dataflow
//! simulator, the DSE sweep, BLEU scoring, the end-to-end HTTP serving
//! path (`server/*`: real sockets + the seeded load generator), and —
//! when built with `pjrt` and artifacts are present — the PJRT translate
//! call that dominates every figure runner.
//!
//! Every run merges its results into `BENCH_hot_paths.json` at the repo
//! root — the machine-readable trajectory EXPERIMENTS.md tracks. Partial
//! runs (a `cargo bench` filter, or a build without `pjrt`/artifacts)
//! refresh only the entries they executed.

use itera_llm::benchkit::Bench;
use itera_llm::compress::{itera, quant_only, svd_baseline, IncrementalItera};
use itera_llm::dse;
use itera_llm::eval::bleu_score;
use itera_llm::hw::{sim, EngineKind, Platform, TileConfig, Workload};
use itera_llm::linalg::{svd, svd_top1};
use itera_llm::qkernel::{self, QMatrix, ScaleAxis};
use itera_llm::quant;
use itera_llm::sra;
use itera_llm::tensor::Matrix;
use itera_llm::util::pool::default_workers;
use itera_llm::util::rng::Pcg64;

fn main() {
    let mut b = Bench::new();
    let mut rng = Pcg64::new(0xBE7C);

    // ---- linalg -------------------------------------------------------
    let w64 = Matrix::randn(64, 64, &mut rng).scale(0.1);
    let w128 = Matrix::randn(128, 128, &mut rng).scale(0.1);
    let w512 = Matrix::randn(512, 512, &mut rng).scale(0.1);
    b.bench("linalg/svd_jacobi_64x64", || {
        std::hint::black_box(svd(&w64));
    });
    b.bench("linalg/svd_top1_64x64", || {
        std::hint::black_box(svd_top1(&w64, 1));
    });
    b.bench("linalg/svd_top1_512x512", || {
        std::hint::black_box(svd_top1(&w512, 1));
    });

    // ---- tensor -------------------------------------------------------
    let a = Matrix::randn(256, 256, &mut rng);
    let c = Matrix::randn(256, 256, &mut rng);
    b.bench("tensor/matmul_256", || {
        std::hint::black_box(a.matmul(&c));
    });
    let a512 = Matrix::randn(512, 512, &mut rng);
    let c512 = Matrix::randn(512, 512, &mut rng);
    b.bench("tensor/matmul_512", || {
        std::hint::black_box(a512.matmul(&c512));
    });
    let workers = default_workers(8);
    b.bench("tensor/matmul_512_par", || {
        std::hint::black_box(a512.matmul_par(&c512, workers));
    });

    // ---- compression --------------------------------------------------
    b.bench("compress/itera_64x64_r32_w4", || {
        std::hint::black_box(itera(&w64, 32, 4));
    });
    b.bench("compress/itera_512x512_r64_w4", || {
        std::hint::black_box(itera(&w512, 64, 4));
    });
    b.bench("compress/svd_baseline_64x64_r32", || {
        std::hint::black_box(svd_baseline(&w64, 32, 4));
    });
    b.bench("compress/quant_only_512x512", || {
        std::hint::black_box(quant_only(&w512, 4));
    });
    b.bench("quant/quantize_cols_512x512", || {
        std::hint::black_box(quant::quantize_cols(&w512, 4));
    });

    // ---- qkernel: bit-packed storage + integer GEMM ---------------------
    // The quantized execution mode's kernels on the Fig. 10 workload
    // shape, plus the deterministic packed-bytes accounting (gauges) the
    // bandwidth story rests on. Setup is a few milliseconds, so it runs
    // unconditionally and each entry filters itself.
    {
        let (q4, s4) = quant::quantize_cols(&w512, 4);
        let qm4 = QMatrix::from_fake_quant(&q4, &s4, 4, ScaleAxis::Col).unwrap();
        let (q8, s8) = quant::quantize_cols(&w512, 8);
        let qm8 = QMatrix::from_fake_quant(&q8, &s8, 8, ScaleAxis::Col).unwrap();
        let x: Vec<f32> = (0..512).map(|i| ((i * 37) % 101) as f32 * 0.01 - 0.5).collect();
        b.bench("qkernel/pack_512x512_w4", || {
            std::hint::black_box(QMatrix::from_fake_quant(&q4, &s4, 4, ScaleAxis::Col).unwrap());
        });
        b.bench("qkernel/qmatvec_512_w4", || {
            std::hint::black_box(qm4.qmatvec(&x));
        });
        b.bench("qkernel/qmatvec_512_w8", || {
            std::hint::black_box(qm8.qmatvec(&x));
        });
        let (qx, sx) = quant::quantize_vec_parts(&x, 8);
        b.bench("qkernel/qmatvec_i32_512_w4", || {
            std::hint::black_box(qm4.qmatvec_i32(&qx, sx).unwrap());
        });
        b.bench("qkernel/qmatvec_i32_512_w8", || {
            std::hint::black_box(qm8.qmatvec_i32(&qx, sx).unwrap());
        });
        // Dequantized f32 baseline for the same matvec (what the dense
        // fake-quant path pays per token).
        b.bench("qkernel/matvec_f32_512_baseline", || {
            std::hint::black_box(q4.tr_matvec(&x));
        });
        let xm = Matrix::randn(64, 512, &mut rng);
        b.bench("qkernel/qmatmul_64x512x512_w4_par", || {
            std::hint::black_box(qm4.qmatmul_par(&xm, workers));
        });
        // Packed-bytes accounting: ceil(wl*K*N/8) + one f32 scale per
        // column — the >= 3.5x (W8) / >= 7x (W4) compression the
        // acceptance bar asks for, recorded as gauges.
        for wl in [2u32, 4, 8] {
            b.gauge(
                &format!("qkernel/packed_bytes_512x512_w{wl}"),
                qkernel::packed_bytes_for(512, 512, wl) as f64,
            );
        }
        b.gauge("qkernel/fp32_bytes_512x512", qkernel::fp32_bytes(512, 512) as f64);
        b.gauge(
            "qkernel/compression_x_512x512_w4",
            qkernel::fp32_bytes(512, 512) as f64
                / qkernel::packed_bytes_for(512, 512, 4) as f64,
        );
    }

    // ---- incremental cache (the SRA/DSE hot loop) ---------------------
    b.bench("compress/incremental_fill_128x128_w4", || {
        std::hint::black_box(IncrementalItera::compress(&w128, 4));
    });
    if b.enabled("compress/incremental_query_128_r32") {
        let inc128 = IncrementalItera::compress(&w128, 4);
        b.bench("compress/incremental_query_128_r32", || {
            std::hint::black_box(inc128.query(32));
        });
    }

    // One SRA round on an 8-layer synthetic model, cached vs recompute:
    // the end-to-end effect the cache exists for. The whole block (setup
    // included) is skipped when the filter hides it.
    if b.enabled("sra/search_cached_8x32_w4")
        || b.enabled("sra/search_recompute_8x32_w4")
        || b.enabled("sra/cost_comparison")
    {
        let sra_layers: Vec<Matrix> = (0..8u64)
            .map(|i| Matrix::randn(32, 32, &mut Pcg64::new(0x5A + i)).scale(0.1))
            .collect();
        let budget: usize =
            sra_layers.iter().map(|w| w.rows().min(w.cols())).sum::<usize>() / 2;
        let sra_cfg = sra::SraConfig { max_iters: 4, patience: 2, ..Default::default() };
        b.bench("sra/search_cached_8x32_w4", || {
            let (res, _) = sra::run_cached_proxy(&sra_layers, 4, budget, &sra_cfg, workers);
            std::hint::black_box(res);
        });
        b.bench("sra/search_recompute_8x32_w4", || {
            let mut oracle = sra::ProxyOracle::recompute(&sra_layers, 4);
            std::hint::black_box(oracle.run_search(budget, &sra_cfg));
        });
        if b.enabled("sra/cost_comparison") {
            // Deterministic cost comparison for EXPERIMENTS.md (not timed).
            let (_, cached) = sra::run_cached_proxy(&sra_layers, 4, budget, &sra_cfg, workers);
            let mut oracle = sra::ProxyOracle::recompute(&sra_layers, 4);
            let _ = oracle.run_search(budget, &sra_cfg);
            eprintln!(
                "[sra cost] matvec-equivalents: cached {} vs recompute {} ({:.1}x fewer)",
                cached.matvec_equivalents(),
                oracle.matvec_equivalents(),
                oracle.matvec_equivalents() as f64
                    / cached.matvec_equivalents().max(1) as f64
            );
        }
    }

    // ---- hardware models ----------------------------------------------
    let w = Workload::new(512, 512, 512, 4, 8);
    let platform = Platform::zcu111();
    b.bench("hw/sim_matmul_512_t16", || {
        std::hint::black_box(sim::simulate_matmul(&w, &TileConfig::new(16, 16, 8), 427.0));
    });
    b.bench("dse/sweep_single_svd_512_r128", || {
        std::hint::black_box(dse::sweep_engines(
            &w,
            Some(128),
            &platform,
            &[EngineKind::SingleSvd],
        ));
    });
    b.bench("dse/best_design_all_kinds", || {
        std::hint::black_box(dse::best_design_for_layer(&w, Some(128), &platform));
    });

    // ---- eval -----------------------------------------------------------
    let refs: Vec<Vec<i32>> = (0..96)
        .map(|i| (0..16).map(|j| ((i * 17 + j * 3) % 120 + 3) as i32).collect())
        .collect();
    b.bench("eval/bleu_96x16", || {
        std::hint::black_box(bleu_score(&refs, &refs));
    });

    // ---- native runtime (always built, hermetic tiny model) -------------
    // Tokens/sec of one greedy translate batch on the pure-Rust engine;
    // `bench_throughput` merges the rate into BENCH_hot_paths.json as
    // `items_per_s`.
    if b.enabled("runtime/native_decode_tiny") {
        use itera_llm::runtime::{NativeBackend, TranslateBackend};
        use itera_llm::testkit::tinymodel;
        match tinymodel::generate_in_temp("bench", 0xB17) {
            Ok((dir, manifest)) => {
                let model =
                    itera_llm::model::PairModel::load(&manifest, tinymodel::PAIR).unwrap();
                let backend = NativeBackend::fp32(&manifest, &model, workers).unwrap();
                let corpus = itera_llm::eval::Corpus::load(
                    &manifest.pairs[tinymodel::PAIR].corpus,
                )
                .unwrap();
                let src = corpus.src_batch(0, backend.batch(), manifest.model.pad_id);
                // One call emits batch * (seq_len - 1) greedy tokens.
                let tokens = (backend.batch() * (backend.seq_len() - 1)) as u64;
                b.bench_throughput("runtime/native_decode_tiny", tokens, || {
                    std::hint::black_box(backend.translate(&src).unwrap());
                });
                std::fs::remove_dir_all(&dir).ok();
            }
            Err(e) => eprintln!("(tiny-model generation failed: {e}; skipping native bench)"),
        }
    }

    // ---- decode policies: full-buffer replay vs KV-cached steps --------
    decode_benches(&mut b, workers);

    // ---- kernel tiers: pure-i32 GEMV + fast-vs-exact cached decode -----
    kernel_benches(&mut b, workers);

    // ---- serving batchers: static waves vs continuous slot scheduling --
    batcher_benches(&mut b, workers);

    // ---- paged KV memory: byte-bounded admission + preemption ----------
    kvpool_benches(&mut b, workers);

    // ---- HTTP serving: sockets + load generator over the batcher ------
    server_benches(&mut b, workers);

    // ---- telemetry: recording primitives + whole-loop overhead --------
    obs_benches(&mut b, workers);

    // ---- PJRT runtime (needs the `pjrt` feature + artifacts) -----------
    runtime_benches(&mut b);

    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_hot_paths.json");
    match b.write_json(&out) {
        Ok(()) => eprintln!(
            "[bench] {} result(s) merged into {}",
            b.results().len(),
            out.display()
        ),
        Err(e) => eprintln!("[bench] could not write {}: {e}", out.display()),
    }
    b.finish();
}

/// Tokens/sec of one greedy translate under both decode policies
/// (`runtime/native_decode_{replay,cached}_{dense,svd,quantized}`), plus
/// the modeled per-translate linear-MAC reduction as a deterministic
/// gauge (`runtime/decode_macs_ratio`). The outputs are bit-identical
/// (pinned by e2e/proptests); these lanes record how much cheaper the
/// KV-cached loop serves them. Hermetic: runs on the testkit tiny model.
fn decode_benches(b: &mut Bench, workers: usize) {
    use std::collections::BTreeMap;

    use itera_llm::compress::CompressedLinear;
    use itera_llm::runtime::{DecodePolicy, Mode, NativeBackend, TranslateBackend};
    use itera_llm::testkit::tinymodel;

    let modes = [("dense", Mode::Dense), ("svd", Mode::Svd), ("quantized", Mode::Quantized)];
    let policies = [("replay", DecodePolicy::Replay), ("cached", DecodePolicy::Cached)];
    let mut lanes: Vec<String> = Vec::new();
    for (mk, _) in &modes {
        for (pk, _) in &policies {
            lanes.push(format!("runtime/native_decode_{pk}_{mk}"));
        }
    }
    lanes.push("runtime/decode_macs_ratio".to_string());
    if !lanes.iter().any(|n| b.enabled(n)) {
        return;
    }

    let (dir, manifest) = match tinymodel::generate_in_temp("bench_decode", 0xDEC) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("(tiny-model generation failed: {e}; skipping decode benches)");
            return;
        }
    };
    let model = itera_llm::model::PairModel::load(&manifest, tinymodel::PAIR).unwrap();
    let corpus = itera_llm::eval::Corpus::load(&manifest.pairs[tinymodel::PAIR].corpus).unwrap();
    let rows = manifest.model.eval_batch;
    let src = corpus.src_batch(0, rows, manifest.model.pad_id);
    // One call decides rows * (seq_len - 1) output tokens.
    let tokens = (rows * (manifest.model.seq_len - 1)) as u64;
    let quant_bank: BTreeMap<String, CompressedLinear> = manifest
        .linears
        .iter()
        .map(|l| (l.name.clone(), quant_only(model.linear(&l.name), 8)))
        .collect();
    let factored_bank: BTreeMap<String, CompressedLinear> = manifest
        .linears
        .iter()
        .map(|l| {
            let r = (l.r_max / 2).max(1);
            (l.name.clone(), itera(model.linear(&l.name), r, 8).0)
        })
        .collect();

    for (mk, mode) in &modes {
        let bank = match mode {
            Mode::Svd => &factored_bank,
            _ => &quant_bank,
        };
        for (pk, policy) in &policies {
            let name = format!("runtime/native_decode_{pk}_{mk}");
            if !b.enabled(&name) {
                continue;
            }
            let backend = NativeBackend::new(&manifest, &model, bank, Some(8), *mode, workers)
                .unwrap()
                .with_decode(*policy);
            b.bench_throughput(&name, tokens, || {
                std::hint::black_box(backend.translate(&src).unwrap());
            });
        }
    }

    // Modeled per-translate linear MACs, replay / cached — the (~seq_len
    // on the decoder stack) reduction the cache realizes, as a gauge.
    if b.enabled("runtime/decode_macs_ratio") {
        let be =
            NativeBackend::new(&manifest, &model, &quant_bank, Some(8), Mode::Dense, 1).unwrap();
        b.gauge(
            "runtime/decode_macs_ratio",
            be.linear_macs_for(rows, DecodePolicy::Replay) as f64
                / be.linear_macs_for(rows, DecodePolicy::Cached) as f64,
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Integer-kernel lanes (`cargo bench --bench hot_paths kernel` selects
/// the group): the pure-i32 GEMV on the Fig. 10 512x512 shape at
/// W2/W4/W8, benched with a FLOP denominator so `items_per_s` reads as
/// FLOP/s (`qkernel/gemv_i32_w{2,4,8}`), the whole fast-tier linear with
/// its runtime A8 activation quantization included
/// (`qkernel/matvec_fast_512_w4`), and the end-to-end KV-cached greedy
/// decode under both kernel tiers on the W4 quantized tiny model
/// (`runtime/native_decode_{exact,fast}_quantized` tokens/sec, plus the
/// low-rank integer cascade as `runtime/native_decode_fast_cascade`).
/// The fast tier's >= 1.3x throughput bar at W4 is read off the two
/// `*_quantized` lanes in BENCH_hot_paths.json; its (non-bit-exact)
/// numerics are fenced separately by `validate --kernel fast`.
fn kernel_benches(b: &mut Bench, workers: usize) {
    use std::collections::BTreeMap;

    use itera_llm::compress::CompressedLinear;
    use itera_llm::qkernel::PackedLinear;
    use itera_llm::runtime::{KernelTier, Mode, NativeBackend, TranslateBackend};
    use itera_llm::testkit::tinymodel;

    b.set_group(Some("kernel"));
    let lanes = [
        "qkernel/gemv_i32_w2",
        "qkernel/gemv_i32_w4",
        "qkernel/gemv_i32_w8",
        "qkernel/matvec_fast_512_w4",
        "runtime/native_decode_exact_quantized",
        "runtime/native_decode_fast_quantized",
        "runtime/native_decode_fast_cascade",
    ];
    if !lanes.iter().any(|n| b.enabled(n)) {
        b.set_group(None);
        return;
    }

    // One i8 activation vector against the packed 512x512 grid: the
    // decode hot loop's per-output-row work, 2*K*N FLOPs per call.
    let mut rng = Pcg64::new(0x6E4F);
    let w = Matrix::randn(512, 512, &mut rng).scale(0.1);
    let x: Vec<f32> = (0..512).map(|i| ((i * 53) % 97) as f32 * 0.01 - 0.4).collect();
    let (qx, sx) = quant::quantize_vec_parts(&x, 8);
    let flops = 2u64 * 512 * 512;
    for wl in [2u32, 4, 8] {
        let name = format!("qkernel/gemv_i32_w{wl}");
        if !b.enabled(&name) {
            continue;
        }
        let (q, s) = quant::quantize_cols(&w, wl);
        let qm = QMatrix::from_fake_quant(&q, &s, wl, ScaleAxis::Col).unwrap();
        b.bench_throughput(&name, flops, || {
            std::hint::black_box(qm.qmatvec_i32(&qx, sx).unwrap());
        });
    }
    if b.enabled("qkernel/matvec_fast_512_w4") {
        let p = PackedLinear::from_compressed(&quant_only(&w, 4)).unwrap();
        b.bench_throughput("qkernel/matvec_fast_512_w4", flops, || {
            std::hint::black_box(p.matvec_fast(&x).unwrap());
        });
    }

    // End-to-end KV-cached greedy decode under each tier, W4 quantized.
    let (dir, manifest) = match tinymodel::generate_in_temp("bench_kernel", 0x6E1) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("(tiny-model generation failed: {e}; skipping kernel decode lanes)");
            b.set_group(None);
            return;
        }
    };
    let model = itera_llm::model::PairModel::load(&manifest, tinymodel::PAIR).unwrap();
    let corpus = itera_llm::eval::Corpus::load(&manifest.pairs[tinymodel::PAIR].corpus).unwrap();
    let rows = manifest.model.eval_batch;
    let src = corpus.src_batch(0, rows, manifest.model.pad_id);
    // One call decides rows * (seq_len - 1) output tokens.
    let tokens = (rows * (manifest.model.seq_len - 1)) as u64;
    let dense_bank: BTreeMap<String, CompressedLinear> = manifest
        .linears
        .iter()
        .map(|l| (l.name.clone(), quant_only(model.linear(&l.name), 4)))
        .collect();
    let cascade_bank: BTreeMap<String, CompressedLinear> = manifest
        .linears
        .iter()
        .map(|l| {
            let r = (l.r_max / 2).max(1);
            (l.name.clone(), itera(model.linear(&l.name), r, 4).0)
        })
        .collect();
    for (name, bank, tier) in [
        ("runtime/native_decode_exact_quantized", &dense_bank, KernelTier::Exact),
        ("runtime/native_decode_fast_quantized", &dense_bank, KernelTier::Fast),
        ("runtime/native_decode_fast_cascade", &cascade_bank, KernelTier::Fast),
    ] {
        if !b.enabled(name) {
            continue;
        }
        let backend = NativeBackend::new(&manifest, &model, bank, Some(8), Mode::Quantized, workers)
            .unwrap()
            .with_kernel(tier);
        b.bench_throughput(name, tokens, || {
            std::hint::black_box(backend.translate(&src).unwrap());
        });
    }
    b.set_group(None);
    std::fs::remove_dir_all(&dir).ok();
}

/// Tokens/sec of the serving path under both batching disciplines
/// (`runtime/native_serve_{static,continuous}` — the same pre-queued
/// request stream through `serve_loop` and `serve_loop_continuous`), the
/// overload lane (`runtime/native_serve_overload`: the burst at a
/// bounded queue, with its deterministic `runtime/shed_rate` gauge), plus
/// the deterministic mean slot occupancy of a staggered-arrival
/// continuous workload (`runtime/slot_occupancy` gauge). The responses
/// are bit-identical (pinned by the serving soak test and the continuous
/// proptest); these lanes record how much better the slot scheduler
/// keeps the KV-cached decode engine fed. Hermetic: runs on the testkit
/// tiny model. Registered under the `batcher` group, so
/// `cargo bench --bench hot_paths batcher` selects the whole block.
fn batcher_benches(b: &mut Bench, workers: usize) {
    use std::sync::mpsc;

    use itera_llm::coordinator::{
        self, response_channel, serve_loop, serve_loop_continuous, ContinuousBatcher, Method,
        Request, ServeConfig,
    };
    use itera_llm::runtime::Mode;
    use itera_llm::testkit::tinymodel;

    b.set_group(Some("batcher"));
    let lanes = [
        "runtime/native_serve_static",
        "runtime/native_serve_continuous",
        "runtime/native_serve_overload",
        "runtime/slot_occupancy",
        "runtime/shed_rate",
    ];
    if !lanes.iter().any(|n| b.enabled(n)) {
        b.set_group(None);
        return;
    }

    let (dir, manifest) = match tinymodel::generate_in_temp("bench_batcher", 0xBA7) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("(tiny-model generation failed: {e}; skipping batcher benches)");
            b.set_group(None);
            return;
        }
    };
    let model = itera_llm::model::PairModel::load(&manifest, tinymodel::PAIR).unwrap();
    let corpus = itera_llm::eval::Corpus::load(&manifest.pairs[tinymodel::PAIR].corpus).unwrap();
    let dims = manifest.model.clone();
    // The serving configuration: W8A8 quant-only, dense execution (what
    // `serve_demo_native` deploys), KV-cached decode.
    let weights: Vec<&Matrix> =
        manifest.linears.iter().map(|l| model.linear(&l.name)).collect();
    let cm = coordinator::compress_model_from(
        &manifest.linears,
        &weights,
        &Method::QuantOnly { wl: 8 },
        None,
        workers,
    );
    let backend = cm.native_backend_mode(&manifest, &model, Mode::Dense, workers).unwrap();

    // A fixed open-loop request stream: the corpus cycled to 12 requests,
    // pre-queued so both loops measure pure serving throughput.
    let n_requests = 12usize;
    let rows: Vec<Vec<i32>> = (0..n_requests)
        .map(|i| corpus.src_row(i % corpus.n).to_vec())
        .collect();
    let queue_all = |rows: &[Vec<i32>]| {
        let (tx, rx) = mpsc::channel::<Request>();
        let mut receivers = Vec::new();
        for row in rows {
            let (rtx, rrx) = response_channel();
            tx.send(Request::new(row.clone(), rtx)).unwrap();
            receivers.push(rrx);
        }
        drop(tx);
        (rx, receivers)
    };

    let static_on = b.enabled("runtime/native_serve_static");
    let continuous_on = b.enabled("runtime/native_serve_continuous");
    if static_on || continuous_on {
        // Generated tokens per run are deterministic (bit-reproducible
        // decode): measure once, then use as the throughput denominator.
        // Skipped entirely when only the occupancy gauge is selected.
        let (rx, _resp) = queue_all(&rows);
        let tokens = serve_loop(&backend, &rx, &dims, n_requests).unwrap().tokens as u64;

        if static_on {
            b.bench_throughput("runtime/native_serve_static", tokens, || {
                let (rx, _resp) = queue_all(&rows);
                std::hint::black_box(serve_loop(&backend, &rx, &dims, n_requests).unwrap());
            });
        }
        if continuous_on {
            let cfg = ServeConfig::new(dims.eval_batch);
            b.bench_throughput("runtime/native_serve_continuous", tokens, || {
                let (rx, _resp) = queue_all(&rows);
                std::hint::black_box(
                    serve_loop_continuous(&backend, &rx, &dims, n_requests, &cfg).unwrap(),
                );
            });
        }
    }

    // Overload lane: the same 12-request burst against capacity 3 with a
    // queue bound of 3 — the burst lands before the first tick, so the
    // queue absorbs 3 requests and the other 9 are shed immediately with
    // a typed `Overloaded` rejection. The shed rate is deterministic
    // (recorded as a gauge); the throughput lane records how fast the
    // loop answers an over-capacity burst when most of it is load-shed.
    if b.enabled("runtime/native_serve_overload") || b.enabled("runtime/shed_rate") {
        let mut cfg = ServeConfig::new(3);
        cfg.queue_limit = Some(3);
        let (rx, _resp) = queue_all(&rows);
        let stats = serve_loop_continuous(&backend, &rx, &dims, n_requests, &cfg).unwrap();
        assert!(stats.is_balanced(), "overload bench accounting must balance: {stats:?}");
        b.gauge("runtime/shed_rate", stats.shed as f64 / stats.received.max(1) as f64);
        if b.enabled("runtime/native_serve_overload") {
            let tokens = stats.tokens as u64;
            b.bench_throughput("runtime/native_serve_overload", tokens, || {
                let (rx, _resp) = queue_all(&rows);
                std::hint::black_box(
                    serve_loop_continuous(&backend, &rx, &dims, n_requests, &cfg).unwrap(),
                );
            });
        }
    }

    // Deterministic slot occupancy on a staggered-arrival workload:
    // capacity 3, a small initial backlog, then arrivals trickle in per
    // tick (topping the queue back up to capacity) — later admissions
    // join live mixed-age batches, every retirement backfills
    // immediately, and only the final drain tail can idle a slot. The
    // acceptance bar for this gauge is > 0.9.
    if b.enabled("runtime/slot_occupancy") {
        let n = 24usize;
        let capacity = 3usize;
        let mut batcher = ContinuousBatcher::new(&backend, capacity);
        let mut submitted = 0usize;
        while submitted < 2 * capacity {
            batcher.submit(rows[submitted % rows.len()].clone()).expect("unbounded submit");
            submitted += 1;
        }
        while !(submitted == n && batcher.idle()) {
            while submitted < n && batcher.pending() < capacity {
                batcher.submit(rows[submitted % rows.len()].clone()).expect("unbounded submit");
                submitted += 1;
            }
            let _ = batcher.tick();
        }
        b.gauge("runtime/slot_occupancy", batcher.occupancy());
    }
    b.set_group(None);
    std::fs::remove_dir_all(&dir).ok();
}

/// Paged-KV serving lanes (`cargo bench --bench hot_paths kvpool`
/// selects the group): the same seeded ragged arrival trace —
/// Poisson-distributed arrivals per tick off the deterministic PCG
/// stream, over the corpus's ragged rows — through a byte-bounded paged
/// backend and through the unbounded slot-count baseline
/// (`runtime/native_serve_paged` / `runtime/native_serve_unpaged`), plus
/// the deterministic memory-pressure gauges: peak
/// `runtime/kv_resident_bytes` under the tight budget, and
/// `runtime/preemption_rate` (evictions per request). Outputs are
/// bit-identical either way (pinned by the paging proptest); these lanes
/// record what bounded admission and preemption-by-eviction cost.
/// Hermetic: runs on the testkit tiny model, W8A8 dense.
fn kvpool_benches(b: &mut Bench, workers: usize) {
    use itera_llm::coordinator::{self, ContinuousBatcher, Method};
    use itera_llm::runtime::{Mode, NativeBackend, SlotEngine};
    use itera_llm::testkit::tinymodel;

    b.set_group(Some("kvpool"));
    let lanes = [
        "runtime/native_serve_paged",
        "runtime/native_serve_unpaged",
        "runtime/kv_resident_bytes",
        "runtime/preemption_rate",
    ];
    if !lanes.iter().any(|n| b.enabled(n)) {
        b.set_group(None);
        return;
    }

    let (dir, manifest) = match tinymodel::generate_in_temp("bench_kvpool", 0x4B9) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("(tiny-model generation failed: {e}; skipping kvpool benches)");
            b.set_group(None);
            return;
        }
    };
    let model = itera_llm::model::PairModel::load(&manifest, tinymodel::PAIR).unwrap();
    let corpus = itera_llm::eval::Corpus::load(&manifest.pairs[tinymodel::PAIR].corpus).unwrap();
    let weights: Vec<&Matrix> =
        manifest.linears.iter().map(|l| model.linear(&l.name)).collect();
    let cm = coordinator::compress_model_from(
        &manifest.linears,
        &weights,
        &Method::QuantOnly { wl: 8 },
        None,
        workers,
    );
    let make_backend = || cm.native_backend_mode(&manifest, &model, Mode::Dense, workers).unwrap();

    let n_requests = 24usize;
    let capacity = 3usize;
    let rows: Vec<Vec<i32>> =
        (0..n_requests).map(|i| corpus.src_row(i % corpus.n).to_vec()).collect();

    // One seeded ragged trace: Poisson(0.8) arrivals per tick (Knuth
    // sampling off the PCG stream), drained to idle. Returns the output
    // token count, the preemption count and the peak resident bytes.
    let run_trace = |backend: &NativeBackend| -> (u64, usize, usize) {
        let mut rng = Pcg64::new(0x9A6ED);
        let limit = (-0.8f64).exp();
        let mut batcher = ContinuousBatcher::new(backend, capacity);
        let mut submitted = 0usize;
        let mut tokens = 0u64;
        let mut peak = 0usize;
        while !(submitted == n_requests && batcher.idle()) {
            let mut arrivals = 0usize;
            let mut p = rng.next_f64();
            while p > limit {
                arrivals += 1;
                p *= rng.next_f64();
            }
            for _ in 0..arrivals.min(n_requests - submitted) {
                batcher.submit(rows[submitted].clone()).expect("unbounded queue");
                submitted += 1;
            }
            if batcher.idle() && submitted < n_requests {
                // Never stall the trace at an empty batcher.
                batcher.submit(rows[submitted].clone()).expect("unbounded queue");
                submitted += 1;
            }
            for c in batcher.tick() {
                tokens += c.result.expect("fault-free trace").len() as u64;
            }
            peak = peak.max(backend.kv_pool().resident_bytes());
        }
        assert_eq!(batcher.stats().retired, n_requests, "every request retires");
        (tokens, batcher.stats().preempted, peak)
    };

    // Tight budget: one slot's worst case plus two spare pages, so
    // concurrent decodes must collide with the budget and preempt.
    let paged = {
        let be = make_backend().with_kv_pool(None, 2);
        let budget = be.slot_worst_bytes() + 2 * be.kv_pool().page_bytes();
        be.with_kv_pool(Some(budget), 2)
    };
    let unpaged = make_backend();

    let (tokens, preempted, peak) = run_trace(&paged);
    assert_eq!(paged.kv_pool().outstanding_pages(), 0, "kvpool bench trace must not leak pages");
    b.gauge("runtime/kv_resident_bytes", peak as f64);
    b.gauge("runtime/preemption_rate", preempted as f64 / n_requests as f64);

    if b.enabled("runtime/native_serve_paged") {
        b.bench_throughput("runtime/native_serve_paged", tokens, || {
            std::hint::black_box(run_trace(&paged));
        });
    }
    if b.enabled("runtime/native_serve_unpaged") {
        b.bench_throughput("runtime/native_serve_unpaged", tokens, || {
            std::hint::black_box(run_trace(&unpaged));
        });
    }

    b.set_group(None);
    std::fs::remove_dir_all(&dir).ok();
}

/// End-to-end HTTP serving lanes (`cargo bench --bench hot_paths server`
/// selects the group): a real `serve_http` instance on an ephemeral
/// loopback port, saturated by the seeded closed-loop
/// [`run_loadgen`](itera_llm::server::loadgen::run_loadgen) client.
/// `server/http_throughput` times whole request waves (bind, serve,
/// drain) with the generated-token denominator; the deterministic-seed
/// client latency distribution lands as `server/latency_p50|p95|p99`
/// gauges (seconds), and the closed-loop token rate — the saturation
/// ceiling of the HTTP path on this host — as
/// `server/saturation_tokens_per_s`. Responses are bit-identical to
/// in-process serving (pinned by the e2e HTTP soak); these lanes record
/// what the network layer costs on top. Hermetic: tiny model, W8A8.
fn server_benches(b: &mut Bench, workers: usize) {
    use std::net::TcpListener;

    use itera_llm::coordinator::{self, Method, ServeConfig, ShutdownSignal};
    use itera_llm::runtime::Mode;
    use itera_llm::server::loadgen::{run_loadgen, LoadGenConfig};
    use itera_llm::server::{serve_http, HttpConfig};
    use itera_llm::testkit::tinymodel;

    b.set_group(Some("server"));
    let lanes = [
        "server/http_throughput",
        "server/latency_p50",
        "server/latency_p95",
        "server/latency_p99",
        "server/saturation_tokens_per_s",
    ];
    if !lanes.iter().any(|n| b.enabled(n)) {
        b.set_group(None);
        return;
    }

    let (dir, manifest) = match tinymodel::generate_in_temp("bench_server", 0x5EF) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("(tiny-model generation failed: {e}; skipping server benches)");
            b.set_group(None);
            return;
        }
    };
    let model = itera_llm::model::PairModel::load(&manifest, tinymodel::PAIR).unwrap();
    let dims = manifest.model.clone();
    let weights: Vec<&Matrix> =
        manifest.linears.iter().map(|l| model.linear(&l.name)).collect();
    let cm = coordinator::compress_model_from(
        &manifest.linears,
        &weights,
        &Method::QuantOnly { wl: 8 },
        None,
        workers,
    );
    let backend = cm.native_backend_mode(&manifest, &model, Mode::Dense, workers).unwrap();

    let load_cfg = LoadGenConfig {
        connections: 4,
        requests: 16,
        // Closed loop: every connection fires its next request the moment
        // the previous answer lands — the saturation workload.
        rate: 0.0,
        len_range: (2, dims.seq_len.saturating_sub(2).max(2)),
        vocab: dims.vocab as i32,
        ..LoadGenConfig::default()
    };

    // One full wave: fresh ephemeral-port server, the seeded load
    // generator against it, graceful drain, both ledgers back.
    let run_once = |cfg: &LoadGenConfig| {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
        let addr = listener.local_addr().unwrap();
        let shutdown = ShutdownSignal::new();
        let mut serve_cfg = ServeConfig::new(dims.eval_batch);
        serve_cfg.shutdown = Some(shutdown.clone());
        let client = {
            let cfg = cfg.clone();
            std::thread::spawn(move || {
                let report = run_loadgen(addr, &cfg);
                shutdown.drain();
                report
            })
        };
        let stats =
            serve_http(&backend, listener, &dims, HttpConfig::new(serve_cfg)).expect("serve");
        let report = client.join().expect("loadgen thread").expect("loadgen report");
        (stats, report)
    };

    // Reference wave: pins the deterministic token denominator and feeds
    // the latency/saturation gauges.
    let (stats0, report0) = run_once(&load_cfg);
    assert!(stats0.is_balanced(), "server bench accounting must balance: {stats0:?}");
    assert_eq!(report0.failed(), 0, "saturation wave must be error-free: {:?}", report0.errors);

    if b.enabled("server/http_throughput") {
        let tokens = stats0.tokens as u64;
        b.bench_throughput("server/http_throughput", tokens, || {
            let (stats, _) = run_once(&load_cfg);
            std::hint::black_box(stats);
        });
    }
    b.gauge("server/latency_p50", report0.latency.quantile(0.50));
    b.gauge("server/latency_p95", report0.latency.quantile(0.95));
    b.gauge("server/latency_p99", report0.latency.quantile(0.99));
    b.gauge("server/saturation_tokens_per_s", report0.tokens_per_s());

    b.set_group(None);
    std::fs::remove_dir_all(&dir).ok();
}

/// Telemetry lanes (`cargo bench --bench hot_paths obs` selects the
/// group): the primitive recording costs (`obs/counter_inc`,
/// `obs/histogram_observe` — amortized over 1M operations — and
/// `obs/snapshot_prometheus`, one full registry render), then the
/// whole-serving-loop cost of telemetry: the same pre-queued continuous
/// workload with recording enabled vs [`ObsConfig::disabled`]
/// (`obs/decode_enabled` / `obs/decode_disabled`), with the relative
/// cost recorded as the `obs/decode_overhead_pct` gauge. The acceptance
/// bar is < 2%; the lane soft-warns (shared CI hosts are too noisy for
/// a hard assert) and the trajectory keeps the history. The enabled
/// lane's registry snapshot is exported under `obs/serve/*`, so the
/// trajectory also carries the serving counters the lane accumulated.
/// Hermetic: runs on the testkit tiny model.
fn obs_benches(b: &mut Bench, workers: usize) {
    use std::sync::mpsc;

    use itera_llm::coordinator::{
        self, response_channel, serve_loop_continuous, Method, Request, ServeConfig,
    };
    use itera_llm::obs::{Obs, ObsConfig};
    use itera_llm::runtime::Mode;
    use itera_llm::testkit::tinymodel;

    b.set_group(Some("obs"));
    let lanes = [
        "obs/counter_inc",
        "obs/histogram_observe",
        "obs/snapshot_prometheus",
        "obs/decode_enabled",
        "obs/decode_disabled",
        "obs/decode_overhead_pct",
    ];
    if !lanes.iter().any(|n| b.enabled(n)) {
        b.set_group(None);
        return;
    }

    // Primitive costs, amortized over 1M recordings per sample.
    let prim = Obs::fresh();
    let counter = prim.registry().counter("bench_counter_total");
    b.bench_throughput("obs/counter_inc", 1_000_000, || {
        for _ in 0..1_000_000u32 {
            counter.inc();
        }
    });
    let hist = prim.registry().histogram("bench_hist_seconds", &[1e-5, 1e-4, 1e-3, 1e-2, 1e-1]);
    b.bench_throughput("obs/histogram_observe", 1_000_000, || {
        for i in 0..1_000_000u32 {
            hist.observe(f64::from(i % 7) * 1e-4);
        }
    });
    // Snapshot + render cost on a registry of representative size.
    if b.enabled("obs/snapshot_prometheus") {
        let big = Obs::fresh();
        for i in 0..48u64 {
            let lane = format!("{i}");
            big.registry().counter_with("render_total", &[("lane", lane.as_str())]).add(i);
        }
        for i in 0..8 {
            let lane = format!("{i}");
            big.registry().gauge_with("render_depth", &[("lane", lane.as_str())]).set(1.0);
            big.registry().histogram(&format!("render_hist_{i}"), &[0.1, 0.2, 0.4]).observe(0.3);
        }
        b.bench("obs/snapshot_prometheus", || {
            std::hint::black_box(big.registry().snapshot().to_prometheus());
        });
    }

    // Whole-loop overhead: the continuous serving lane from
    // `batcher_benches`, with recording on vs off. The block (tiny-model
    // setup included) is skipped when the filter hides all three lanes.
    let decode_lanes = ["obs/decode_enabled", "obs/decode_disabled", "obs/decode_overhead_pct"];
    if !decode_lanes.iter().any(|n| b.enabled(n)) {
        b.set_group(None);
        return;
    }
    let (dir, manifest) = match tinymodel::generate_in_temp("bench_obs", 0x0B5) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("(tiny-model generation failed: {e}; skipping obs decode lanes)");
            b.set_group(None);
            return;
        }
    };
    let model = itera_llm::model::PairModel::load(&manifest, tinymodel::PAIR).unwrap();
    let corpus = itera_llm::eval::Corpus::load(&manifest.pairs[tinymodel::PAIR].corpus).unwrap();
    let dims = manifest.model.clone();
    let weights: Vec<&Matrix> =
        manifest.linears.iter().map(|l| model.linear(&l.name)).collect();
    let cm = coordinator::compress_model_from(
        &manifest.linears,
        &weights,
        &Method::QuantOnly { wl: 8 },
        None,
        workers,
    );
    let backend = cm.native_backend_mode(&manifest, &model, Mode::Dense, workers).unwrap();

    let n_requests = 12usize;
    let rows: Vec<Vec<i32>> = (0..n_requests)
        .map(|i| corpus.src_row(i % corpus.n).to_vec())
        .collect();
    let queue_all = |rows: &[Vec<i32>]| {
        let (tx, rx) = mpsc::channel::<Request>();
        let mut receivers = Vec::new();
        for row in rows {
            let (rtx, rrx) = response_channel();
            tx.send(Request::new(row.clone(), rtx)).unwrap();
            receivers.push(rrx);
        }
        drop(tx);
        (rx, receivers)
    };

    let cfg = ServeConfig::new(dims.eval_batch);
    let (rx, _resp) = queue_all(&rows);
    let tokens =
        serve_loop_continuous(&backend, &rx, &dims, n_requests, &cfg).unwrap().tokens as u64;

    b.bench_throughput("obs/decode_enabled", tokens, || {
        let (rx, _resp) = queue_all(&rows);
        std::hint::black_box(
            serve_loop_continuous(&backend, &rx, &dims, n_requests, &cfg).unwrap(),
        );
    });
    ObsConfig::disabled().install();
    b.bench_throughput("obs/decode_disabled", tokens, || {
        let (rx, _resp) = queue_all(&rows);
        std::hint::black_box(
            serve_loop_continuous(&backend, &rx, &dims, n_requests, &cfg).unwrap(),
        );
    });
    ObsConfig::enabled().install();

    let mean = |name: &str| {
        b.results().iter().find(|r| r.name == name && r.samples > 0).map(|r| r.mean_s)
    };
    if let (Some(on), Some(off)) = (mean("obs/decode_enabled"), mean("obs/decode_disabled")) {
        let pct = (on - off) / off * 100.0;
        b.gauge("obs/decode_overhead_pct", pct);
        if pct > 2.0 {
            eprintln!("[obs] warning: telemetry overhead {pct:.2}% exceeds the 2% target");
        }
    }
    // The enabled lane's accumulated serving counters, into the
    // trajectory next to the timings.
    b.export_snapshot("obs/serve", &cfg.obs.registry().snapshot());

    b.set_group(None);
    std::fs::remove_dir_all(&dir).ok();
}

#[cfg(feature = "pjrt")]
fn runtime_benches(b: &mut Bench) {
    if !itera_llm::model::Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("(artifacts not built; skipping runtime benches)");
        return;
    }
    use std::collections::BTreeMap;
    let manifest =
        itera_llm::model::Manifest::load(itera_llm::model::Manifest::default_dir()).unwrap();
    let engine = itera_llm::runtime::Engine::cpu().unwrap();
    let model = itera_llm::model::PairModel::load(&manifest, "en-de").unwrap();
    let corpus = itera_llm::eval::Corpus::load(&manifest.pairs["en-de"].corpus).unwrap();
    let session = itera_llm::runtime::TranslateSession::new(
        &engine,
        &manifest,
        itera_llm::runtime::Mode::Dense,
    )
    .unwrap();
    let bank = session.build_bank(&model, &BTreeMap::new(), None).unwrap();
    let src = corpus.src_batch(0, session.batch(), manifest.model.pad_id);
    b.bench("runtime/translate_batch16", || {
        std::hint::black_box(session.translate(&bank, &src).unwrap());
    });
    b.bench("runtime/build_bank_fp32", || {
        std::hint::black_box(session.build_bank(&model, &BTreeMap::new(), None).unwrap());
    });

    // 512^3 kernel artifact (the Fig. 10 workload via Pallas-lowered HLO).
    let exe = engine.load_hlo(&manifest.artifacts.linear512_dense).unwrap();
    let mut r = Pcg64::new(5);
    let x = Matrix::randn(512, 512, &mut r);
    let wm = Matrix::randn(512, 512, &mut r);
    let bx = engine.upload_f32(x.data(), &[512, 512]).unwrap();
    let bw = engine.upload_f32(wm.data(), &[512, 512]).unwrap();
    b.bench("runtime/linear512_dense_kernel", || {
        std::hint::black_box(engine.run_tuple1(&exe, &[&bx, &bw]).unwrap());
    });
}

#[cfg(not(feature = "pjrt"))]
fn runtime_benches(_b: &mut Bench) {
    eprintln!("(built without `pjrt`; skipping runtime benches)");
}
