//! Sub-8-bit integer weight storage + integer GEMM — the quantized
//! execution kernels behind `runtime::Mode::Quantized`.
//!
//! Everywhere else in the stack, quantized weights are *fake-quant* f32:
//! grid values `q * s` stored as full 32-bit floats, so none of the
//! paper's sub-8-bit memory/bandwidth win is realized at runtime. This
//! module stores the grid points themselves — 2..=8-bit two's-complement
//! integers bit-packed into `u32` words ([`pack`]), one f32 dequant scale
//! per quantized vector — cutting resident weight bytes by up to 16x
//! (W2) while reproducing the fake-quant math **bit-exactly**:
//!
//! * [`QMatrix`] — packed `[K x N]` weights with per-column scales
//!   (dense layers, `W1 [K x r]` factors) or per-row scales (`W2 [r x N]`
//!   factors, one scale per rank), plus a flat `i8` fast path for W8;
//! * [`QMatrix::qmatmul`] / [`QMatrix::qmatmul_par`] — cache-blocked,
//!   pool-parallel `x · W` against the packed weights. Each weight panel
//!   is dequantized once per block (`q as f32 * s` — bit-identical to the
//!   fake-quant value, see `quant::dequantize_val`) and accumulated in
//!   exactly `Matrix::matmul`'s per-element order, so the result equals
//!   `x.matmul(&self.to_matrix())` bit for bit — which is what makes the
//!   whole quantized runtime verifiable against the PR 2 deterministic
//!   e2e harness;
//! * [`QMatrix::qmatvec_i32`] / [`QMatrix::qmatvec_i32_rows`] — the
//!   pure-integer paths: an already integer-quantized activation vector
//!   against the packed weights with **i32 accumulation** and a single
//!   `(s_x * s_w[n]) * acc` dequant-rescale per output (column-scaled
//!   dense/`W1`), or the per-rank-rescaled cascade hop for row-scaled
//!   `W2` factors — the arithmetic shapes the paper's fixed-point
//!   MatMul engines implement. Envelope violations (shape, A8 range,
//!   the per-grid `K` cap, scale axis, non-finite activations) return a
//!   typed [`QKernelError`] instead of panicking, so the serving hot
//!   path can fault one request rather than the whole batched step;
//! * [`PackedLinear`] — a compressed layer ([`CompressedLinear`])
//!   re-gridded into packed form, possible losslessly because the
//!   compression engine carries every vector's true dequant scale.
//!
//! Byte accounting ([`packed_bytes_for`], [`QMatrix::packed_bytes`]) is
//! exact: `rows * ceil(cols*wl/32)` words (or `rows*cols` bytes at W8)
//! plus one f32 scale per quantized vector.

pub mod pack;

use anyhow::{ensure, Result};

use crate::compress::CompressedLinear;
use crate::quant::{self, WordLen};
use crate::tensor::Matrix;

/// Which axis the dequant scales run along.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAxis {
    /// One scale per column (dense weights, `W1 [K x r]` factors).
    Col,
    /// One scale per row (`W2 [r x N]` factors — one scale per rank).
    Row,
}

/// Envelope violation of the integer kernels, returned as a value
/// instead of panicking: the fast tier runs these kernels inside
/// `step_slots`, where a panic on one poisoned activation would abort
/// the whole batched step (and cost every co-batched slot a solo
/// re-step through the fault path). A typed error lets the runtime
/// fault exactly the offending request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum QKernelError {
    /// Activation length does not match the weight matrix's `K`.
    ShapeMismatch { expect: usize, got: usize },
    /// An activation grid point outside the A8 envelope (`|q| > 127`).
    ActivationOutOfRange { index: usize, value: i32 },
    /// `K` exceeds the exact-i32-accumulation bound for this weight
    /// grid (see [`QMatrix::i32_k_cap`]).
    KTooLarge { rows: usize, cap: usize, wl: WordLen },
    /// The matrix's scale axis does not fit the kernel called.
    WrongScaleAxis { expect: ScaleAxis, got: ScaleAxis },
    /// A non-finite activation lane caught at runtime quantization.
    NonFinite(quant::NonFiniteError),
}

impl std::fmt::Display for QKernelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QKernelError::ShapeMismatch { expect, got } => {
                write!(f, "integer matvec shape mismatch: weights expect K={expect}, got {got}")
            }
            QKernelError::ActivationOutOfRange { index, value } => write!(
                f,
                "activation grid point {value} at lane {index} outside the A8 envelope \
                 (|q| <= 127)"
            ),
            QKernelError::KTooLarge { rows, cap, wl } => write!(
                f,
                "K={rows} exceeds the exact i32-accumulation bound {cap} for W{wl} at A8"
            ),
            QKernelError::WrongScaleAxis { expect, got } => {
                write!(f, "integer matvec needs {expect:?}-axis scales, matrix is {got:?}-scaled")
            }
            QKernelError::NonFinite(e) => write!(f, "activation quantization failed: {e}"),
        }
    }
}

impl std::error::Error for QKernelError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            QKernelError::NonFinite(e) => Some(e),
            _ => None,
        }
    }
}

impl From<quant::NonFiniteError> for QKernelError {
    fn from(e: quant::NonFiniteError) -> Self {
        QKernelError::NonFinite(e)
    }
}

/// Integer payload of a [`QMatrix`].
#[derive(Debug, Clone)]
enum Payload {
    /// W8 fast path: one byte per element, row-major.
    I8(Vec<i8>),
    /// 2..=7 bits: row-major bit-packed; each row starts on a fresh word.
    Packed { words: Vec<u32>, words_per_row: usize },
}

/// A `[rows x cols]` weight matrix stored as bit-packed `wl`-bit grid
/// points plus per-vector f32 dequant scales.
#[derive(Debug, Clone)]
pub struct QMatrix {
    rows: usize,
    cols: usize,
    wl: WordLen,
    axis: ScaleAxis,
    scales: Vec<f32>,
    payload: Payload,
}

/// Cache-block edges for the packed GEMM: one dequantized
/// `QK_BK x QK_BJ` weight panel (32 KiB of f32, same footprint as the
/// f32 kernel's B panel) stays resident while the activation rows of the
/// range stream over it — the dequant cost is paid once per panel, not
/// once per activation row.
const QK_BK: usize = 64;
const QK_BJ: usize = 128;
/// Below this many MACs a thread handoff costs more than it saves
/// (mirrors the f32 kernel's threshold).
const QK_PAR_MIN_MACS: usize = 1 << 22;
/// Fixed inner-loop width of the integer GEMV rows: the main loop runs
/// over exact `QK_CHUNK`-element blocks whose indices are provably in
/// range, so the compiler drops the bounds checks and vectorizes the
/// MAC body; a scalar tail covers the remainder.
const QK_CHUNK: usize = 16;

/// `acc[j] += xq * row[j]` over one i8 weight row, chunked (see
/// [`QK_CHUNK`]).
#[inline]
fn mac_row_i8(acc: &mut [i32], row: &[i8], xq: i32) {
    debug_assert_eq!(acc.len(), row.len());
    let mut ai = acc.chunks_exact_mut(QK_CHUNK);
    let mut wi = row.chunks_exact(QK_CHUNK);
    for (a, w) in ai.by_ref().zip(wi.by_ref()) {
        for i in 0..QK_CHUNK {
            a[i] += xq * w[i] as i32;
        }
    }
    for (a, &w) in ai.into_remainder().iter_mut().zip(wi.remainder()) {
        *a += xq * w as i32;
    }
}

/// `acc[j] += xq * row[j]` over one unpacked weight row, chunked.
#[inline]
fn mac_row_i32(acc: &mut [i32], row: &[i32], xq: i32) {
    debug_assert_eq!(acc.len(), row.len());
    let mut ai = acc.chunks_exact_mut(QK_CHUNK);
    let mut wi = row.chunks_exact(QK_CHUNK);
    for (a, w) in ai.by_ref().zip(wi.by_ref()) {
        for i in 0..QK_CHUNK {
            a[i] += xq * w[i];
        }
    }
    for (a, &w) in ai.into_remainder().iter_mut().zip(wi.remainder()) {
        *a += xq * w;
    }
}

/// `out[j] += c * row[j]` over one i8 weight row (the per-rank-rescaled
/// cascade hop), chunked.
#[inline]
fn axpy_row_i8(out: &mut [f32], row: &[i8], c: f32) {
    debug_assert_eq!(out.len(), row.len());
    let mut oi = out.chunks_exact_mut(QK_CHUNK);
    let mut wi = row.chunks_exact(QK_CHUNK);
    for (o, w) in oi.by_ref().zip(wi.by_ref()) {
        for i in 0..QK_CHUNK {
            o[i] += c * w[i] as f32;
        }
    }
    for (o, &w) in oi.into_remainder().iter_mut().zip(wi.remainder()) {
        *o += c * w as f32;
    }
}

/// `out[j] += c * row[j]` over one unpacked weight row, chunked.
#[inline]
fn axpy_row_i32(out: &mut [f32], row: &[i32], c: f32) {
    debug_assert_eq!(out.len(), row.len());
    let mut oi = out.chunks_exact_mut(QK_CHUNK);
    let mut wi = row.chunks_exact(QK_CHUNK);
    for (o, w) in oi.by_ref().zip(wi.by_ref()) {
        for i in 0..QK_CHUNK {
            o[i] += c * w[i] as f32;
        }
    }
    for (o, &w) in oi.into_remainder().iter_mut().zip(wi.remainder()) {
        *o += c * w as f32;
    }
}

impl QMatrix {
    /// Quantize FP32 weights onto the per-column `wl`-bit grid (the
    /// vector-wise scheme of `quant::quantize_cols`) and pack them.
    pub fn quantize_cols(w: &Matrix, wl: WordLen) -> QMatrix {
        let (q, scales) = quant::quantize_cols(w, wl);
        Self::from_fake_quant(&q, &scales, wl, ScaleAxis::Col)
            .expect("fresh fake-quant output is always grid-aligned")
    }

    /// Re-grid an already fake-quantized matrix into packed storage.
    ///
    /// Lossless by construction: every stored value must be exactly
    /// `q * scale` for a grid point `|q| <= 2^(wl-1) - 1`; the recovered
    /// integers are validated to dequantize back to the input bit for
    /// bit, so `to_matrix()` (and every kernel) reproduces the fake-quant
    /// f32 matrix exactly. Errors on off-grid values, unpackable word
    /// lengths (`wl` outside 2..=8) or a scale-count mismatch.
    pub fn from_fake_quant(
        w: &Matrix,
        scales: &[f32],
        wl: WordLen,
        axis: ScaleAxis,
    ) -> Result<QMatrix> {
        ensure!(
            (2..=8).contains(&wl),
            "qkernel packs 2..=8-bit grids, got W{wl} (wider grids are \
             fake-quant diagnostics only)"
        );
        let (rows, cols) = w.shape();
        let want = match axis {
            ScaleAxis::Col => cols,
            ScaleAxis::Row => rows,
        };
        ensure!(
            scales.len() == want,
            "{rows}x{cols} matrix with {:?}-axis scales needs {want} scales, got {}",
            axis,
            scales.len()
        );
        let lv = quant::levels(wl);
        let mut ints: Vec<i8> = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for (j, &x) in w.row(i).iter().enumerate() {
                let s = match axis {
                    ScaleAxis::Col => scales[j],
                    ScaleAxis::Row => scales[i],
                };
                let q = quant::quantize_int(x, s, lv);
                ensure!(
                    quant::dequantize_val(q, s) == x,
                    "value {x} at ({i},{j}) is not on the W{wl} grid with scale {s}"
                );
                ints.push(q as i8);
            }
        }
        let payload = if wl == 8 {
            Payload::I8(ints)
        } else {
            let wpr = pack::words_per_row(cols, wl);
            let mut words = vec![0u32; rows * wpr];
            for (i, chunk) in words.chunks_mut(wpr).enumerate() {
                pack::pack_row(&ints[i * cols..(i + 1) * cols], wl, chunk);
            }
            Payload::Packed { words, words_per_row: wpr }
        };
        Ok(QMatrix { rows, cols, wl, axis, scales: scales.to_vec(), payload })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn word_len(&self) -> WordLen {
        self.wl
    }

    pub fn scale_axis(&self) -> ScaleAxis {
        self.axis
    }

    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// Grid point at `(i, j)` (sign-extended).
    pub fn get_int(&self, i: usize, j: usize) -> i32 {
        debug_assert!(i < self.rows && j < self.cols);
        match &self.payload {
            Payload::I8(v) => v[i * self.cols + j] as i32,
            Payload::Packed { words, words_per_row } => {
                pack::unpack_one(&words[i * words_per_row..(i + 1) * words_per_row], j, self.wl)
            }
        }
    }

    /// Dequantized value at `(i, j)` — bit-identical to the fake-quant
    /// matrix this was built from.
    pub fn get(&self, i: usize, j: usize) -> f32 {
        quant::dequantize_val(self.get_int(i, j), self.scale_of(i, j))
    }

    #[inline]
    fn scale_of(&self, i: usize, j: usize) -> f32 {
        match self.axis {
            ScaleAxis::Col => self.scales[j],
            ScaleAxis::Row => self.scales[i],
        }
    }

    /// Unpack grid points `j0..j1` of row `k` into `out` (`j1 - j0` ints).
    fn int_range_into(&self, k: usize, j0: usize, j1: usize, out: &mut [i32]) {
        match &self.payload {
            Payload::I8(v) => {
                for (o, &b) in out.iter_mut().zip(&v[k * self.cols + j0..k * self.cols + j1]) {
                    *o = b as i32;
                }
            }
            Payload::Packed { words, words_per_row } => {
                let row = &words[k * words_per_row..(k + 1) * words_per_row];
                pack::unpack_range_into(row, j0, j1, self.wl, out);
            }
        }
    }

    /// Dequantize values `j0..j1` of row `k` into `out`, via `ibuf`
    /// (`j1 - j0` scratch ints). Every produced f32 is bit-identical to
    /// the source fake-quant matrix entry.
    fn dequant_range_into(
        &self,
        k: usize,
        j0: usize,
        j1: usize,
        ibuf: &mut [i32],
        out: &mut [f32],
    ) {
        self.int_range_into(k, j0, j1, ibuf);
        match self.axis {
            ScaleAxis::Col => {
                for ((o, &q), &s) in out.iter_mut().zip(ibuf.iter()).zip(&self.scales[j0..j1]) {
                    *o = quant::dequantize_val(q, s);
                }
            }
            ScaleAxis::Row => {
                let s = self.scales[k];
                for (o, &q) in out.iter_mut().zip(ibuf.iter()) {
                    *o = quant::dequantize_val(q, s);
                }
            }
        }
    }

    /// Full dequantization back to a dense f32 matrix — bit-identical to
    /// the fake-quant matrix this `QMatrix` was built from.
    pub fn to_matrix(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        let mut ibuf = vec![0i32; self.cols];
        for i in 0..self.rows {
            self.dequant_range_into(i, 0, self.cols, &mut ibuf, out.row_mut(i));
        }
        out
    }

    /// Resident bytes of this matrix: packed payload + f32 scales.
    pub fn packed_bytes(&self) -> usize {
        let payload = match &self.payload {
            Payload::I8(v) => v.len(),
            Payload::Packed { words, .. } => words.len() * 4,
        };
        payload + self.scales.len() * 4
    }

    /// Bytes the same matrix occupies as dense f32.
    pub fn fp32_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }

    /// `x [M x K] · self [K x N]` — bit-identical to
    /// `x.matmul(&self.to_matrix())`: panels are dequantized into a
    /// cache-resident scratch block and accumulated in exactly the f32
    /// kernel's per-element order (k ascending, zero activations skipped).
    pub fn qmatmul(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols(), self.rows, "qmatmul shape mismatch");
        crate::obs::note_qkernel_dispatch(crate::obs::kernels::QMATMUL, self.wl);
        let mut out = Matrix::zeros(x.rows(), self.cols);
        self.qmatmul_rows(x, 0, x.rows(), out.data_mut());
        out
    }

    /// Row-parallel [`Self::qmatmul`] on the shared thread pool —
    /// bit-identical to the serial product (each output element's
    /// accumulation order is unchanged), mirroring `Matrix::matmul_par`.
    pub fn qmatmul_par(&self, x: &Matrix, workers: usize) -> Matrix {
        assert_eq!(x.cols(), self.rows, "qmatmul shape mismatch");
        let (m, k, n) = (x.rows(), self.rows, self.cols);
        let workers = workers.min(m).max(1);
        if workers == 1 || m * k * n < QK_PAR_MIN_MACS {
            return self.qmatmul(x);
        }
        crate::obs::note_qkernel_dispatch(crate::obs::kernels::QMATMUL, self.wl);
        let mut out = Matrix::zeros(m, n);
        crate::tensor::par_row_chunks(out.data_mut(), m, n, workers, |i0, i1, out_rows| {
            self.qmatmul_rows(x, i0, i1, out_rows)
        });
        out
    }

    /// `x^T · self` for one K-length activation vector: the `[1 x K]` row
    /// case of [`Self::qmatmul`], bit-identical to
    /// `self.to_matrix().tr_matvec(x)` (both accumulate each output in
    /// ascending-k order and skip zero activations).
    pub fn qmatvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.rows, "qmatvec shape mismatch");
        crate::obs::note_qkernel_dispatch(crate::obs::kernels::QMATVEC, self.wl);
        let xm = Matrix::from_vec(1, x.len(), x.to_vec());
        let mut out = vec![0.0; self.cols];
        self.qmatmul_rows(&xm, 0, 1, &mut out);
        out
    }

    /// Largest `K` for which the i32 accumulator of
    /// [`Self::qmatvec_i32`] stays exact with A8 activations against
    /// *this matrix's* weight grid: `i32::MAX / (127 * levels(wl))`.
    /// W8: 133,144 rows; W4: ~2.4M; W2: ~16.9M — the bound scales with
    /// the weight grid, so narrow-grid matrices are not over-rejected
    /// by the W8 worst case.
    pub fn i32_k_cap(&self) -> usize {
        (i32::MAX / (127 * quant::levels(self.wl) as i32)) as usize
    }

    /// Shared input envelope of the integer matvec kernels: activation
    /// length matches `K` and every grid point fits A8.
    fn check_i32_activation(&self, qx: &[i32]) -> Result<(), QKernelError> {
        if qx.len() != self.rows {
            return Err(QKernelError::ShapeMismatch { expect: self.rows, got: qx.len() });
        }
        if let Some((index, &value)) =
            qx.iter().enumerate().find(|(_, q)| !(-127..=127).contains(*q))
        {
            return Err(QKernelError::ActivationOutOfRange { index, value });
        }
        Ok(())
    }

    /// Pure-integer matvec: `out[n] = (sx * scale[n]) * sum_k qx[k] *
    /// q[k][n]` with **i32 accumulation** and one dequant-rescale per
    /// output — the fixed-point arithmetic the paper's hardware engines
    /// run, fed by an integer-quantized activation vector
    /// (`quant::quantize_vec_parts` at A8 or narrower, since wider
    /// activation grids could wrap the i32 accumulator). The envelope
    /// is *checked, not asserted* — `|qx| <= 127`, `K <=`
    /// [`Self::i32_k_cap`] (exact per weight grid), column-scale axis —
    /// and violations come back as a typed [`QKernelError`] so a
    /// poisoned activation mid-decode faults one request instead of
    /// aborting the batched step. Column-scaled matrices only: a
    /// row-scaled factor needs a per-k rescale, which
    /// [`Self::qmatvec_i32_rows`] provides.
    pub fn qmatvec_i32(&self, qx: &[i32], sx: f32) -> Result<Vec<f32>, QKernelError> {
        self.check_i32_activation(qx)?;
        let cap = self.i32_k_cap();
        if self.rows > cap {
            return Err(QKernelError::KTooLarge { rows: self.rows, cap, wl: self.wl });
        }
        if self.axis != ScaleAxis::Col {
            return Err(QKernelError::WrongScaleAxis {
                expect: ScaleAxis::Col,
                got: self.axis,
            });
        }
        crate::obs::note_qkernel_dispatch(crate::obs::kernels::QMATVEC_I32, self.wl);
        let mut acc = vec![0i32; self.cols];
        match &self.payload {
            Payload::I8(v) => {
                for (k, &xq) in qx.iter().enumerate() {
                    if xq == 0 {
                        continue;
                    }
                    mac_row_i8(&mut acc, &v[k * self.cols..(k + 1) * self.cols], xq);
                }
            }
            Payload::Packed { words, words_per_row } => {
                let mut ibuf = vec![0i32; self.cols];
                for (k, &xq) in qx.iter().enumerate() {
                    if xq == 0 {
                        continue;
                    }
                    let row = &words[k * words_per_row..(k + 1) * words_per_row];
                    pack::unpack_range_into(row, 0, self.cols, self.wl, &mut ibuf);
                    mac_row_i32(&mut acc, &ibuf, xq);
                }
            }
        }
        Ok(acc.iter().zip(&self.scales).map(|(&a, &s)| (sx * s) * a as f32).collect())
    }

    /// Row-scaled integer matvec — the cascade's second hop `h · W2`
    /// where `W2 [r x N]` carries one scale per rank. A per-k rescale
    /// breaks the single-i32-dot-product shape, so instead the per-rank
    /// dequant coefficient `c_k = (sx * s[k]) * qx[k]` is hoisted out
    /// of the inner loop and the hot body stays a chunked scan of the
    /// integer weight row (`out[n] += c_k * q[k][n]`, f32 accumulation
    /// — each addend is already rescaled, so no i32 wraparound exists
    /// and no K cap applies). Ranks whose activation quantized to zero
    /// are skipped entirely.
    pub fn qmatvec_i32_rows(&self, qx: &[i32], sx: f32) -> Result<Vec<f32>, QKernelError> {
        self.check_i32_activation(qx)?;
        if self.axis != ScaleAxis::Row {
            return Err(QKernelError::WrongScaleAxis {
                expect: ScaleAxis::Row,
                got: self.axis,
            });
        }
        crate::obs::note_qkernel_dispatch(crate::obs::kernels::QMATVEC_I32, self.wl);
        let mut out = vec![0.0f32; self.cols];
        match &self.payload {
            Payload::I8(v) => {
                for (k, &xq) in qx.iter().enumerate() {
                    if xq == 0 {
                        continue;
                    }
                    let c = (sx * self.scales[k]) * xq as f32;
                    axpy_row_i8(&mut out, &v[k * self.cols..(k + 1) * self.cols], c);
                }
            }
            Payload::Packed { words, words_per_row } => {
                let mut ibuf = vec![0i32; self.cols];
                for (k, &xq) in qx.iter().enumerate() {
                    if xq == 0 {
                        continue;
                    }
                    let row = &words[k * words_per_row..(k + 1) * words_per_row];
                    pack::unpack_range_into(row, 0, self.cols, self.wl, &mut ibuf);
                    let c = (sx * self.scales[k]) * xq as f32;
                    axpy_row_i32(&mut out, &ibuf, c);
                }
            }
        }
        Ok(out)
    }

    /// Product of rows `i0..i1` of `x` with the packed weights, written
    /// to `out` (`(i1-i0) x cols`, row-major). Same j/k tiling as the f32
    /// kernel's blocked path; the dequantized panel is shared by every
    /// activation row of the range.
    fn qmatmul_rows(&self, x: &Matrix, i0: usize, i1: usize, out: &mut [f32]) {
        let n = self.cols;
        let k_dim = self.rows;
        let bj = QK_BJ.min(n.max(1));
        let bk = QK_BK.min(k_dim.max(1));
        let mut ibuf = vec![0i32; bj];
        let mut panel = vec![0.0f32; bk * bj];
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + QK_BJ).min(n);
            let w = j1 - j0;
            let mut k0 = 0;
            while k0 < k_dim {
                let k1 = (k0 + QK_BK).min(k_dim);
                for kk in k0..k1 {
                    let dst = &mut panel[(kk - k0) * bj..(kk - k0) * bj + w];
                    self.dequant_range_into(kk, j0, j1, &mut ibuf[..w], dst);
                }
                for i in i0..i1 {
                    let x_row = x.row(i);
                    let o_row = &mut out[(i - i0) * n + j0..(i - i0) * n + j1];
                    for kk in k0..k1 {
                        let av = x_row[kk];
                        if av == 0.0 {
                            continue;
                        }
                        let w_row = &panel[(kk - k0) * bj..(kk - k0) * bj + w];
                        for (o, &bv) in o_row.iter_mut().zip(w_row) {
                            *o += av * bv;
                        }
                    }
                }
                k0 = k1;
            }
            j0 = j1;
        }
    }
}

/// Analytic packed size in bytes of a `[rows x cols]` col-scaled W`wl`
/// matrix: `ceil(cols*wl/32)` words per row (flat bytes at W8) plus one
/// f32 scale per column. Matches [`QMatrix::packed_bytes`] exactly.
pub fn packed_bytes_for(rows: usize, cols: usize, wl: WordLen) -> usize {
    let payload = if wl == 8 { rows * cols } else { rows * pack::words_per_row(cols, wl) * 4 };
    payload + cols * 4
}

/// Dense f32 bytes of the same matrix.
pub fn fp32_bytes(rows: usize, cols: usize) -> usize {
    rows * cols * 4
}

/// One compressed linear in packed executable form — what
/// `Mode::Quantized` keeps resident instead of fake-quant f32.
#[derive(Debug, Clone)]
pub enum PackedLinear {
    /// Packed full `[K x N]` weights (quant-only layers).
    Dense(QMatrix),
    /// Packed factor cascade `w1 [K x r]` (per-rank column scales),
    /// `w2 [r x N]` (per-rank row scales).
    Factored(QMatrix, QMatrix),
}

impl PackedLinear {
    /// Materialize the packed form of a compressed layer. Errors when the
    /// layer cannot be packed: FP-identity probes (no scales), word
    /// lengths outside 2..=8, or off-grid values.
    pub fn from_compressed(c: &CompressedLinear) -> Result<PackedLinear> {
        match c {
            CompressedLinear::Dense { w, wl, scales } => {
                ensure!(
                    !scales.is_empty(),
                    "dense layer carries no quant scales (FP-identity probe?); \
                     nothing to pack"
                );
                Ok(PackedLinear::Dense(QMatrix::from_fake_quant(
                    w,
                    scales,
                    *wl,
                    ScaleAxis::Col,
                )?))
            }
            CompressedLinear::LowRank { w1, w2, wl, s1, s2 } => Ok(PackedLinear::Factored(
                QMatrix::from_fake_quant(w1, s1, *wl, ScaleAxis::Col)?,
                QMatrix::from_fake_quant(w2, s2, *wl, ScaleAxis::Row)?,
            )),
        }
    }

    /// Single-row execution `x · W` — the KV-cached decode-step entry
    /// point: packed dense runs one [`QMatrix::qmatvec`], the packed
    /// cascade runs `(x · W1) · W2`, covering **both scale axes** (`W1`
    /// carries per-rank column scales, `W2` per-rank row scales — the
    /// shared dequant path handles either). Bit-identical to the row the
    /// batched `qmatmul` path would produce for the same activation,
    /// which is what keeps cached decode bit-equal to full-buffer replay
    /// in `Mode::Quantized`.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        // Counted under its own kernel key *and* the inner qmatvec(s) it
        // dispatches — the ratio is the realized factored fan-out.
        match self {
            PackedLinear::Dense(w) => {
                crate::obs::note_qkernel_dispatch(crate::obs::kernels::PACKED_MATVEC, w.wl);
                w.qmatvec(x)
            }
            PackedLinear::Factored(w1, w2) => {
                crate::obs::note_qkernel_dispatch(crate::obs::kernels::PACKED_MATVEC, w1.wl);
                w2.qmatvec(&w1.qmatvec(x))
            }
        }
    }

    /// The fast integer tier of [`Self::matvec`]
    /// (`runtime::KernelTier::Fast`): quantize the f32 activation onto
    /// the A8 grid *at runtime*, then run the whole linear as
    /// int8×int-grid GEMV — dense layers as one [`QMatrix::qmatvec_i32`]
    /// (i32 accumulation, one rescale per output), factored layers as
    /// the integer cascade with a per-rank A8 requantization between
    /// the two skinny matvecs ([`QMatrix::qmatvec_i32`] then
    /// [`QMatrix::qmatvec_i32_rows`]). **Not** bit-identical to
    /// [`Self::matvec`]: the runtime activation requantization perturbs
    /// each lane by up to half an A8 grid step, which is why the tier
    /// is opt-in and fenced by `validate --kernel fast`'s parity table.
    /// A non-finite activation lane surfaces as a typed
    /// [`QKernelError::NonFinite`] naming the lane.
    pub fn matvec_fast(&self, x: &[f32]) -> Result<Vec<f32>, QKernelError> {
        match self {
            PackedLinear::Dense(w) => {
                crate::obs::note_qkernel_dispatch(crate::obs::kernels::PACKED_MATVEC_FAST, w.wl);
                let (qx, sx) = quant::try_quantize_vec_parts(x, 8)?;
                w.qmatvec_i32(&qx, sx)
            }
            PackedLinear::Factored(w1, w2) => {
                crate::obs::note_qkernel_dispatch(crate::obs::kernels::PACKED_MATVEC_FAST, w1.wl);
                let (qx, sx) = quant::try_quantize_vec_parts(x, 8)?;
                let h = w1.qmatvec_i32(&qx, sx)?;
                let (qh, sh) = quant::try_quantize_vec_parts(&h, 8)?;
                w2.qmatvec_i32_rows(&qh, sh)
            }
        }
    }

    /// Output features (the `N` of the underlying `[K x N]` linear).
    pub fn out_features(&self) -> usize {
        match self {
            PackedLinear::Dense(w) => w.cols(),
            PackedLinear::Factored(_, w2) => w2.cols(),
        }
    }

    /// Resident bytes of the packed representation.
    pub fn packed_bytes(&self) -> usize {
        match self {
            PackedLinear::Dense(w) => w.packed_bytes(),
            PackedLinear::Factored(w1, w2) => w1.packed_bytes() + w2.packed_bytes(),
        }
    }

    /// Bytes the same representation occupies as fake-quant f32 (the
    /// dense matrix, or the factor pair, at 4 bytes per element).
    pub fn fp32_bytes(&self) -> usize {
        match self {
            PackedLinear::Dense(w) => w.fp32_bytes(),
            PackedLinear::Factored(w1, w2) => w1.fp32_bytes() + w2.fp32_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{itera, quant_only};
    use crate::util::rng::Pcg64;

    fn randn(seed: u64, r: usize, c: usize, s: f32) -> Matrix {
        let mut rng = Pcg64::new(seed);
        Matrix::randn(r, c, &mut rng).scale(s)
    }

    #[test]
    fn roundtrip_matches_fake_quant_grid_all_widths() {
        // Pack -> unpack reproduces the fake-quant matrix exactly, for
        // every packable width and non-word-aligned row lengths.
        for wl in 2..=8u32 {
            for (r, c) in [(7usize, 11usize), (16, 16), (5, 33), (1, 1), (3, 64)] {
                let a = randn(1000 + wl as u64, r, c, 0.4);
                let (q, s) = quant::quantize_cols(&a, wl);
                let qm = QMatrix::from_fake_quant(&q, &s, wl, ScaleAxis::Col).unwrap();
                assert_eq!(qm.to_matrix().data(), q.data(), "col W{wl} {r}x{c}");
                assert_eq!(qm.packed_bytes(), packed_bytes_for(r, c, wl), "{r}x{c} W{wl}");

                let (qr, sr) = quant::quantize_rows(&a, wl);
                let qmr = QMatrix::from_fake_quant(&qr, &sr, wl, ScaleAxis::Row).unwrap();
                assert_eq!(qmr.to_matrix().data(), qr.data(), "row W{wl} {r}x{c}");

                // Point accessors agree with the dense reconstruction.
                assert_eq!(qm.get(r - 1, c - 1), q.get(r - 1, c - 1));
                assert_eq!(
                    quant::dequantize_val(qm.get_int(0, c - 1), qm.scales()[c - 1]),
                    q.get(0, c - 1)
                );
            }
        }
    }

    #[test]
    fn kernel_dispatches_land_in_the_global_registry() {
        let _gate = crate::obs::test_gate().read().unwrap_or_else(|e| e.into_inner());
        use crate::obs::{key, kernels, Obs};
        let k = key("qkernel_dispatch_total", &[("kernel", "qmatvec"), ("wl", "4")]);
        let a = randn(77, 9, 6, 0.4);
        let qm = QMatrix::quantize_cols(&a, 4);
        let x = vec![0.5f32; 9];
        // The global registry is shared across parallel tests, so only
        // the delta from our own calls is asserted.
        let before = Obs::global().registry().snapshot().counter(&k);
        qm.qmatvec(&x);
        qm.qmatvec(&x);
        let after = Obs::global().registry().snapshot().counter(&k);
        assert!(after >= before + 2, "dispatch counter moved: {before} -> {after}");
        let _ = kernels::QMATVEC; // the public index constants exist
    }

    #[test]
    fn quantize_cols_constructor_matches_quant_module() {
        let a = randn(2, 12, 18, 0.3);
        let qm = QMatrix::quantize_cols(&a, 5);
        let (q, s) = quant::quantize_cols(&a, 5);
        assert_eq!(qm.to_matrix().data(), q.data());
        assert_eq!(qm.scales(), &s[..]);
        assert_eq!(qm.word_len(), 5);
        assert_eq!(qm.scale_axis(), ScaleAxis::Col);
    }

    #[test]
    fn rejects_off_grid_and_bad_metadata() {
        let a = Matrix::from_vec(2, 2, vec![0.03, 0.1, -0.1, 0.0]);
        let bad = QMatrix::from_fake_quant(&a, &[0.1, 0.1], 4, ScaleAxis::Col);
        assert!(bad.is_err(), "0.03 is not on the 0.1 grid");
        let grid = Matrix::from_vec(2, 2, vec![0.1, 0.2, -0.1, 0.0]);
        assert!(QMatrix::from_fake_quant(&grid, &[0.1, 0.1], 4, ScaleAxis::Col).is_ok());
        // Wrong scale count.
        assert!(QMatrix::from_fake_quant(&grid, &[0.1], 4, ScaleAxis::Col).is_err());
        // Unpackable word lengths.
        assert!(QMatrix::from_fake_quant(&grid, &[0.1, 0.1], 16, ScaleAxis::Col).is_err());
        assert!(QMatrix::from_fake_quant(&grid, &[0.1, 0.1], 1, ScaleAxis::Col).is_err());
    }

    #[test]
    fn qmatmul_bit_exact_vs_f32_kernel() {
        // Shapes straddling the block edges, mixed widths (8 hits the i8
        // fast path), both scale axes.
        let cases: &[(usize, usize, usize, u32)] =
            &[(3, 200, 150, 4), (17, 130, 257, 3), (9, 64, 129, 8), (5, 20, 12, 2)];
        for &(m, k, n, wl) in cases {
            let w = randn(10 + wl as u64, k, n, 0.2);
            let x = randn(20 + m as u64, m, k, 1.0);
            for axis in [ScaleAxis::Col, ScaleAxis::Row] {
                let (q, s) = match axis {
                    ScaleAxis::Col => quant::quantize_cols(&w, wl),
                    ScaleAxis::Row => quant::quantize_rows(&w, wl),
                };
                let qm = QMatrix::from_fake_quant(&q, &s, wl, axis).unwrap();
                let want = x.matmul(&q);
                let got = qm.qmatmul(&x);
                assert_eq!(want.data(), got.data(), "{m}x{k}x{n} W{wl} {axis:?}");
            }
        }
    }

    #[test]
    fn qmatmul_handles_zero_activations_like_f32() {
        // The zero-skip must mirror the f32 kernel (it skips on the same
        // predicate, so sparse quantized factors stay cheap and exact).
        let w = randn(30, 24, 40, 0.2);
        let (q, s) = quant::quantize_cols(&w, 4);
        let qm = QMatrix::from_fake_quant(&q, &s, 4, ScaleAxis::Col).unwrap();
        let mut x = randn(31, 6, 24, 1.0);
        for i in 0..x.rows() {
            for j in (0..x.cols()).step_by(3) {
                x.set(i, j, 0.0);
            }
        }
        assert_eq!(x.matmul(&q).data(), qm.qmatmul(&x).data());
    }

    #[test]
    fn qmatmul_par_matches_serial() {
        let w = randn(40, 96, 80, 0.2);
        let (q, s) = quant::quantize_cols(&w, 6);
        let qm = QMatrix::from_fake_quant(&q, &s, 6, ScaleAxis::Col).unwrap();
        let x = randn(41, 70, 96, 1.0);
        let serial = qm.qmatmul(&x);
        assert_eq!(serial.data(), x.matmul(&q).data());
        for workers in [1usize, 2, 3, 7] {
            assert_eq!(serial.data(), qm.qmatmul_par(&x, workers).data(), "workers={workers}");
        }
    }

    #[test]
    fn qmatvec_bit_exact_vs_fake_quant_matvec() {
        let w = randn(50, 33, 21, 0.3);
        for wl in [2u32, 5, 8] {
            let (q, s) = quant::quantize_cols(&w, wl);
            let qm = QMatrix::from_fake_quant(&q, &s, wl, ScaleAxis::Col).unwrap();
            let mut x: Vec<f32> = (0..33).map(|i| ((i * 13) as f32 * 0.07).sin()).collect();
            x[4] = 0.0; // exercise the skip
            let via_f32 = q.tr_matvec(&x);
            let via_row = Matrix::from_vec(1, 33, x.clone()).matmul(&q);
            let got = qm.qmatvec(&x);
            assert_eq!(got, via_f32, "W{wl} vs tr_matvec");
            assert_eq!(got, via_row.into_vec(), "W{wl} vs 1-row matmul");
        }
    }

    #[test]
    fn qmatvec_row_axis_bit_exact() {
        // The row-scaled side of the decode-step entry point: one scale
        // per rank (W2 factors), word-misaligned row lengths included.
        for (r, n, wl) in [(7usize, 33usize, 3u32), (5, 21, 5), (16, 40, 8), (1, 1, 2)] {
            let w = randn(90 + wl as u64, r, n, 0.3);
            let (q, s) = quant::quantize_rows(&w, wl);
            let qm = QMatrix::from_fake_quant(&q, &s, wl, ScaleAxis::Row).unwrap();
            let mut x: Vec<f32> = (0..r).map(|i| ((i * 5) as f32 * 0.19).sin()).collect();
            x[r / 2] = 0.0; // the zero-skip must match the f32 kernel
            let got = qm.qmatvec(&x);
            assert_eq!(got, q.tr_matvec(&x), "{r}x{n} W{wl} row-scaled");
        }
    }

    #[test]
    fn packed_linear_matvec_bit_exact_both_forms() {
        // Dense form: one col-scaled qmatvec, misaligned width.
        let w = randn(95, 26, 33, 0.3);
        let dense = quant_only(&w, 5);
        let p = PackedLinear::from_compressed(&dense).unwrap();
        assert_eq!(p.out_features(), 33);
        let CompressedLinear::Dense { w: fq, .. } = &dense else { unreachable!() };
        let x: Vec<f32> = (0..26).map(|i| ((i * 3) as f32 * 0.23).cos()).collect();
        assert_eq!(p.matvec(&x), fq.tr_matvec(&x), "packed dense matvec");

        // Factored form: col-scaled W1 then row-scaled W2 — the packed
        // cascade must equal the f32 factor cascade bit for bit.
        let (low, _) = itera(&w, 7, 4);
        let p = PackedLinear::from_compressed(&low).unwrap();
        assert_eq!(p.out_features(), 33);
        let CompressedLinear::LowRank { w1, w2, .. } = &low else { unreachable!() };
        let f32_cascade = w2.tr_matvec(&w1.tr_matvec(&x));
        assert_eq!(p.matvec(&x), f32_cascade, "packed cascade matvec");
        // ... and to the batched 1-row qmatmul path (the replay kernel).
        let xm = Matrix::from_vec(1, 26, x.clone());
        let PackedLinear::Factored(q1, q2) = &p else { unreachable!() };
        assert_eq!(p.matvec(&x), q2.qmatmul(&q1.qmatmul(&xm)).into_vec());
    }

    #[test]
    fn qmatvec_i32_matches_integer_reference() {
        let w = randn(60, 48, 37, 0.25);
        for wl in [3u32, 4, 8] {
            let (q, s) = quant::quantize_cols(&w, wl);
            let qm = QMatrix::from_fake_quant(&q, &s, wl, ScaleAxis::Col).unwrap();
            let x: Vec<f32> = (0..48).map(|i| ((i * 7) as f32 * 0.11).cos()).collect();
            let (qx, sx) = quant::quantize_vec_parts(&x, 8);
            let got = qm.qmatvec_i32(&qx, sx).unwrap();
            // Exact reference from the unpacked grid points.
            for (n, &g) in got.iter().enumerate() {
                let mut acc = 0i64;
                for (k, &xq) in qx.iter().enumerate() {
                    acc += xq as i64 * qm.get_int(k, n) as i64;
                }
                assert!(acc.unsigned_abs() < (1 << 24), "stays exact in f32");
                let want = (sx * qm.scales()[n]) * acc as f32;
                assert_eq!(g.to_bits(), want.to_bits(), "W{wl} col {n}");
            }
            // And it approximates the fake-quant f32 matvec: same math up
            // to float association, so the relative gap is tiny.
            let xq_f32: Vec<f32> = qx.iter().map(|&v| quant::dequantize_val(v, sx)).collect();
            let f32_path = q.tr_matvec(&xq_f32);
            for (a, b) in got.iter().zip(&f32_path) {
                assert!(
                    (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                    "W{wl}: i32 path {a} vs f32 path {b}"
                );
            }
        }
    }

    #[test]
    fn qmatvec_i32_rows_matches_per_rank_reference() {
        // The cascade's second hop: per-rank coefficient axpy over the
        // integer rows, bit-exact against the same-order scalar
        // reference and close to the f32 path.
        let w = randn(61, 9, 23, 0.3);
        for wl in [2u32, 4, 8] {
            let (q, s) = quant::quantize_rows(&w, wl);
            let qm = QMatrix::from_fake_quant(&q, &s, wl, ScaleAxis::Row).unwrap();
            let h: Vec<f32> = (0..9).map(|i| ((i * 11) as f32 * 0.13).sin()).collect();
            let (qh, sh) = quant::quantize_vec_parts(&h, 8);
            let got = qm.qmatvec_i32_rows(&qh, sh).unwrap();
            let mut want = vec![0.0f32; 23];
            for (k, &xq) in qh.iter().enumerate() {
                if xq == 0 {
                    continue;
                }
                let c = (sh * qm.scales()[k]) * xq as f32;
                for (n, o) in want.iter_mut().enumerate() {
                    *o += c * qm.get_int(k, n) as f32;
                }
            }
            for (n, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "W{wl} col {n}");
            }
            // Same math as the f32 row-scaled matvec up to association.
            let hq: Vec<f32> = qh.iter().map(|&v| quant::dequantize_val(v, sh)).collect();
            for (a, b) in got.iter().zip(&q.tr_matvec(&hq)) {
                assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "W{wl}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn integer_envelope_errors_are_typed_not_panics() {
        let w = randn(200, 8, 6, 0.3);
        let (q, s) = quant::quantize_cols(&w, 4);
        let qm = QMatrix::from_fake_quant(&q, &s, 4, ScaleAxis::Col).unwrap();
        assert!(matches!(
            qm.qmatvec_i32(&[0i32; 7], 1.0),
            Err(QKernelError::ShapeMismatch { expect: 8, got: 7 })
        ));
        let mut qx = vec![1i32; 8];
        qx[3] = 128;
        assert!(matches!(
            qm.qmatvec_i32(&qx, 1.0),
            Err(QKernelError::ActivationOutOfRange { index: 3, value: 128 })
        ));
        let (qr, sr) = quant::quantize_rows(&w, 4);
        let qmr = QMatrix::from_fake_quant(&qr, &sr, 4, ScaleAxis::Row).unwrap();
        assert!(matches!(
            qmr.qmatvec_i32(&[0i32; 8], 1.0),
            Err(QKernelError::WrongScaleAxis { expect: ScaleAxis::Col, got: ScaleAxis::Row })
        ));
        assert!(matches!(
            qm.qmatvec_i32_rows(&[0i32; 8], 1.0),
            Err(QKernelError::WrongScaleAxis { expect: ScaleAxis::Row, got: ScaleAxis::Col })
        ));
        // A poisoned f32 activation surfaces as NonFinite naming the
        // lane, and the chain formats through std::error::Error.
        let p = PackedLinear::Dense(qm);
        let mut x = vec![0.5f32; 8];
        x[5] = f32::NAN;
        let e = p.matvec_fast(&x).unwrap_err();
        assert!(matches!(e, QKernelError::NonFinite(inner) if inner.index == 5), "{e}");
        let boxed: Box<dyn std::error::Error> = Box::new(e);
        assert!(boxed.source().is_some(), "NonFinite carries its cause");
        assert!(boxed.to_string().contains("lane 5"), "{boxed}");
    }

    #[test]
    fn i32_k_cap_tracks_the_weight_grid() {
        // The bugfix: the exactness bound derives from the actual wl,
        // not a hard-pinned A8/W8 worst case.
        let grid = Matrix::zeros(4, 2);
        let caps: Vec<usize> = [2u32, 4, 8]
            .iter()
            .map(|&wl| {
                QMatrix::from_fake_quant(&grid, &[0.0, 0.0], wl, ScaleAxis::Col)
                    .unwrap()
                    .i32_k_cap()
            })
            .collect();
        assert_eq!(caps[2], (i32::MAX / (127 * 127)) as usize, "W8 keeps the old bound");
        assert_eq!(caps[2], 133_144);
        assert_eq!(caps[1], (i32::MAX / (127 * 7)) as usize, "W4 bound is 127/7x wider");
        assert_eq!(caps[0], (i32::MAX / 127) as usize, "W2 bound is 127x wider");
        assert!(caps[0] > caps[1] && caps[1] > caps[2]);
    }

    #[test]
    fn k_cap_boundary_per_wordlength() {
        // 133,145 rows is one past the A8/W8 exact-accumulation bound.
        // The old hard-pinned cap rejected this K at *every* width; the
        // wl-exact bound accepts it on the narrow grids (whose products
        // cannot wrap) and still rejects it at W8 — as a typed error.
        let rows = 133_145;
        let w = Matrix::zeros(rows, 1);
        let qx = vec![0i32; rows];
        let w8 = QMatrix::from_fake_quant(&w, &[0.0], 8, ScaleAxis::Col).unwrap();
        match w8.qmatvec_i32(&qx, 1.0) {
            Err(QKernelError::KTooLarge { rows: r, cap, wl }) => {
                assert_eq!((r, cap, wl), (rows, 133_144, 8));
            }
            other => panic!("W8 past-cap call must fail typed, got {other:?}"),
        }
        for wl in [2u32, 4] {
            let q = QMatrix::from_fake_quant(&w, &[0.0], wl, ScaleAxis::Col).unwrap();
            assert_eq!(q.qmatvec_i32(&qx, 1.0).unwrap(), vec![0.0], "W{wl} within its cap");
        }
    }

    #[test]
    fn matvec_fast_is_the_composed_integer_path() {
        let w = randn(96, 26, 33, 0.3);
        let x: Vec<f32> = (0..26).map(|i| ((i * 3) as f32 * 0.23).cos()).collect();
        for wl in [2u32, 4, 8] {
            // Dense: exactly qmatvec_i32 on the A8-requantized activation.
            let p = PackedLinear::from_compressed(&quant_only(&w, wl)).unwrap();
            let fast = p.matvec_fast(&x).unwrap();
            let (qx, sx) = quant::quantize_vec_parts(&x, 8);
            let PackedLinear::Dense(qm) = &p else { unreachable!() };
            assert_eq!(fast, qm.qmatvec_i32(&qx, sx).unwrap(), "W{wl} dense");
            // ...and within the A8 perturbation envelope of the exact
            // tier: |Δout[n]| <= Σ_k |Δx_k| |w[k][n]|, |Δx_k| <= sx/2.
            let exact = p.matvec(&x);
            for n in 0..33 {
                let mut bound = 0.0f32;
                for k in 0..26 {
                    bound += qm.get(k, n).abs();
                }
                bound = 0.5 * sx * bound * 1.01 + 1e-5;
                let d = (fast[n] - exact[n]).abs();
                assert!(d <= bound, "W{wl} dense col {n}: |Δ|={d} > {bound}");
            }

            // Factored: the two-hop integer cascade with a mid A8
            // requantization, pinned by composing the public kernels.
            let (low, _) = itera(&w, 7, wl);
            let p = PackedLinear::from_compressed(&low).unwrap();
            let fast = p.matvec_fast(&x).unwrap();
            let PackedLinear::Factored(q1, q2) = &p else { unreachable!() };
            let h = q1.qmatvec_i32(&qx, sx).unwrap();
            let (qh, sh) = quant::quantize_vec_parts(&h, 8);
            assert_eq!(fast, q2.qmatvec_i32_rows(&qh, sh).unwrap(), "W{wl} cascade");
        }
    }

    #[test]
    fn fast_dispatches_count_under_their_own_kernel_key() {
        let _gate = crate::obs::test_gate().read().unwrap_or_else(|e| e.into_inner());
        use crate::obs::{key, Obs};
        let k = key("qkernel_dispatch_total", &[("kernel", "packed_matvec_fast"), ("wl", "4")]);
        let w = randn(78, 9, 6, 0.4);
        let p = PackedLinear::from_compressed(&quant_only(&w, 4)).unwrap();
        let x = vec![0.5f32; 9];
        let before = Obs::global().registry().snapshot().counter(&k);
        p.matvec_fast(&x).unwrap();
        p.matvec_fast(&x).unwrap();
        let after = Obs::global().registry().snapshot().counter(&k);
        assert!(after >= before + 2, "fast dispatch counter moved: {before} -> {after}");
        let _ = crate::obs::kernels::PACKED_MATVEC_FAST;
    }

    #[test]
    fn byte_accounting_hits_paper_ratios() {
        // The acceptance numbers: packed bytes ~= ceil(wl*K*N/8) + scales,
        // >= 3.5x smaller than f32 at W8 and >= 7x at W4 (512^2 layer).
        let f32b = fp32_bytes(512, 512) as f64;
        let w8 = packed_bytes_for(512, 512, 8) as f64;
        let w4 = packed_bytes_for(512, 512, 4) as f64;
        let w2 = packed_bytes_for(512, 512, 2) as f64;
        assert!(f32b / w8 >= 3.5, "W8 ratio {}", f32b / w8);
        assert!(f32b / w4 >= 7.0, "W4 ratio {}", f32b / w4);
        assert!(f32b / w2 >= 14.0, "W2 ratio {}", f32b / w2);
        for wl in 2..=8u32 {
            let ideal = (wl as usize * 512 * 512).div_ceil(8) + 512 * 4;
            let actual = packed_bytes_for(512, 512, wl);
            assert!(
                actual >= ideal && (actual as f64) < ideal as f64 * 1.01,
                "W{wl}: {actual} vs ideal {ideal}"
            );
        }
    }

    #[test]
    fn packed_linear_from_compressed_layers() {
        let w = randn(70, 20, 28, 0.3);
        // Quant-only -> packed dense, byte-exact accounting.
        let dense = quant_only(&w, 4);
        let p = PackedLinear::from_compressed(&dense).unwrap();
        match &p {
            PackedLinear::Dense(qm) => {
                let CompressedLinear::Dense { w: fq, .. } = &dense else { unreachable!() };
                assert_eq!(qm.to_matrix().data(), fq.data());
            }
            _ => panic!("quant_only must pack Dense"),
        }
        assert_eq!(p.packed_bytes(), packed_bytes_for(20, 28, 4));
        assert_eq!(p.fp32_bytes(), fp32_bytes(20, 28));

        // Algorithm 1 factors -> packed cascade, both sides exact.
        let (low, _) = itera(&w, 9, 4);
        let p = PackedLinear::from_compressed(&low).unwrap();
        let CompressedLinear::LowRank { w1, w2, .. } = &low else { unreachable!() };
        match &p {
            PackedLinear::Factored(q1, q2) => {
                assert_eq!(q1.to_matrix().data(), w1.data(), "w1 exact");
                assert_eq!(q2.to_matrix().data(), w2.data(), "w2 exact");
                assert_eq!(q1.scale_axis(), ScaleAxis::Col);
                assert_eq!(q2.scale_axis(), ScaleAxis::Row);
            }
            _ => panic!("itera must pack Factored"),
        }
        assert!(p.packed_bytes() < p.fp32_bytes());

        // FP-identity probes are rejected, not mispacked.
        let probe = CompressedLinear::Dense { w: w.clone(), wl: 16, scales: Vec::new() };
        assert!(PackedLinear::from_compressed(&probe).is_err());
    }

    #[test]
    fn factored_cascade_bit_exact_vs_f32_factors() {
        // The exact execution shape Mode::Quantized runs: x·W1 then ·W2,
        // compared against the fake-quant f32 cascade.
        let w = randn(80, 26, 22, 0.3);
        let (low, _) = itera(&w, 8, 5);
        let CompressedLinear::LowRank { w1, w2, .. } = &low else { unreachable!() };
        let PackedLinear::Factored(q1, q2) = PackedLinear::from_compressed(&low).unwrap()
        else {
            panic!("factored")
        };
        let x = randn(81, 10, 26, 1.0);
        let f32_out = x.matmul(w1).matmul(w2);
        let q_out = q2.qmatmul(&q1.qmatmul(&x));
        assert_eq!(f32_out.data(), q_out.data());
    }
}
