//! Pure-Rust transformer inference engine — execute compressed models
//! without PJRT.
//!
//! Mirrors the forward pass of `python/compile/model.py` (the Marian-style
//! pre-norm encoder–decoder the AOT artifacts lower) directly on
//! [`Matrix`], so the default build can run greedy translation, BLEU
//! evaluation and the serving demo with no external runtime:
//!
//! * embeddings + learned positional encoding, tied output head
//!   (`logits = x · tgt_emb^T`);
//! * pre-norm residual blocks: `x += attn(LN(x))`, `x += ffn(LN(x))`;
//! * multi-head attention with additive `-1e9` masking (softmax over all
//!   positions, masked scores underflow to exactly 0 — the same numeric
//!   convention the JAX graph uses);
//! * per-linear activation fake-quant (`clip(round(x/s), -lv, lv) * s`)
//!   replaying the calibrated scales from the manifest;
//! * a greedy decode loop whose per-step cost depends on the selected
//!   [`DecodePolicy`]: the **cached** default runs a **slot-addressed**
//!   lifecycle — every sequence owns an independent [`SeqSlot`] (its
//!   per-layer self-attention K/V slabs, cross-attention context, token
//!   buffer, `done` flag and step counter) that is admitted
//!   ([`NativeBackend::admit_slot`] or a batched encode), stepped in
//!   mixed-age batches ([`NativeBackend::step_slots`], a single
//!   `[b x D]` activation through single-row kernels:
//!   [`Matrix::vecmat_par`], [`crate::qkernel::PackedLinear::matvec`])
//!   and retired on EOS — while the **replay** reference re-runs the
//!   causally masked decoder over the whole fixed-length buffer —
//!   token-for-token the `translate` loop the HLO artifacts encode. Both
//!   emit PAD once a row has produced EOS (the cached path tracks this
//!   in the slot's flag instead of rescanning the buffer) and are
//!   **bit-identical**: every per-element accumulation order is shared,
//!   masked attention scores underflow to exactly 0 in both, and a
//!   position's hidden state depends only on positions `<=` it. Slot
//!   independence is what the continuous batcher
//!   (`coordinator::scheduler`) builds on: admitting or retiring one
//!   sequence never changes another sequence's bits.
//!
//! Every compressed linear executes in one of three forms:
//!
//! * **dense** (`Mode::Dense`) — one `[M x K]·[K x N]` product against the
//!   fake-quantized (or original FP32) weights;
//! * **factored** (`Mode::Svd`) — two skinny products
//!   `([M x K]·[K x r])·[r x N]` against the low-rank pair at its *actual*
//!   rank, so the paper's FLOP savings are realized at runtime (the AOT
//!   path must zero-pad to `r_max`; the native path doesn't);
//! * **quantized** (`Mode::Quantized`, native-only) — every linear lives
//!   **bit-packed** ([`crate::qkernel::QMatrix`]: 2..=8-bit integers in
//!   `u32` words + per-vector scales, up to 16x fewer resident weight
//!   bytes) and executes through the packed GEMM in whatever structure
//!   the compression produced — packed dense for quant-only layers,
//!   packed factor cascades for the SVD family. Because packed execution
//!   dequantizes to the *same* f32 grid values and accumulates in the
//!   same per-element order, it is **bit-identical** to the corresponding
//!   fake-quant f32 mode above.
//!
//! Matmuls ride the cache-blocked, pool-parallel [`Matrix::matmul_par`]
//! kernel (and its packed twin `QMatrix::qmatmul_par`), which is
//! bit-identical to the serial product — together with the deterministic
//! PRNG-free forward pass this makes greedy decode bit-reproducible
//! across runs, worker counts and execution modes (pinned by
//! `tests/e2e_native.rs`).

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock};

use anyhow::{bail, ensure, Context, Result};

use crate::compress::CompressedLinear;
use crate::model::{Manifest, ModelDims, PairModel};
use crate::obs::{Counter, Obs};
use crate::qkernel::PackedLinear;
use crate::quant::{self, WordLen};
use crate::tensor::{dot, Matrix};

use super::kvpool::{KvMemStats, KvPool, PagedRows, RowRead};
use super::{DecodePolicy, KernelTier, Mode, SlotEngine, TranslateBackend};

/// Process-global decode-progress counters, registered once against
/// [`Obs::global`] and shared by every engine instance: slot admissions
/// (encoder passes), decode steps executed, and slots advanced per step
/// (`stepped_slots / steps` is the realized mean decode batch width).
/// Handles are cached so the per-step hot path never touches the
/// registry's lock.
fn runtime_counters() -> &'static (Arc<Counter>, Arc<Counter>, Arc<Counter>) {
    static CELL: OnceLock<(Arc<Counter>, Arc<Counter>, Arc<Counter>)> = OnceLock::new();
    CELL.get_or_init(|| {
        let reg = Obs::global().registry();
        (
            reg.counter("runtime_slot_admissions_total"),
            reg.counter("runtime_decode_steps_total"),
            reg.counter("runtime_stepped_slots_total"),
        )
    })
}

/// Additive mask value for disallowed attention positions (the JAX graph's
/// `_NEG`); after the stable softmax shift these underflow to exactly 0.
const NEG: f32 = -1e9;

/// One compressed linear, in executable form.
enum LinearOp {
    /// Full `[K x N]` weights (fake-quantized or original FP32).
    Dense(Matrix),
    /// Low-rank pair `w1 [K x r]`, `w2 [r x N]`, executed as a cascade.
    Factored(Matrix, Matrix),
    /// Bit-packed weights (`Mode::Quantized`): packed dense or packed
    /// factor cascade, holding integers + scales instead of f32.
    Packed(PackedLinear),
}

impl LinearOp {
    /// Output features (the `N` of the underlying `[K x N]` linear).
    fn n_out(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.cols(),
            LinearOp::Factored(_, w2) => w2.cols(),
            LinearOp::Packed(p) => p.out_features(),
        }
    }
}

/// Layer-norm gain/bias pair.
struct LnParams {
    g: Vec<f32>,
    b: Vec<f32>,
}

/// One encoder block: LN params + indices into the linear-op table.
struct EncLayer {
    ln1: LnParams,
    ln2: LnParams,
    q: usize,
    k: usize,
    v: usize,
    o: usize,
    ff1: usize,
    ff2: usize,
}

/// One decoder block (self-attention, cross-attention, FFN).
struct DecLayer {
    ln1: LnParams,
    ln2: LnParams,
    ln3: LnParams,
    self_q: usize,
    self_k: usize,
    self_v: usize,
    self_o: usize,
    cross_q: usize,
    cross_k: usize,
    cross_v: usize,
    cross_o: usize,
    ff1: usize,
    ff2: usize,
}

/// One sequence's private share of the KV-cached incremental decode
/// ([`DecodePolicy::Cached`]): an independent **KV slot** that can be
/// admitted, stepped, retired and reused without touching any other
/// sequence.
///
/// A slot owns everything a single decode lifecycle needs:
///
/// * per-decoder-layer self-attention K and V row stores, **page-backed**
///   ([`PagedRows`] over the backend's [`KvPool`]): rows `0..len` valid,
///   appended one row per step, with pages allocated lazily just ahead
///   of the decode cursor — so a slot's resident KV bytes track what it
///   actually decoded, and admission can be bounded by *bytes* instead
///   of slot count;
/// * the cross-attention K/V of *this sequence's* encoder memory (also
///   per decoder layer, constant from admission on) plus the source-key
///   PAD mask — spliced in at [`NativeBackend::admit_slot`] so a freshly
///   admitted sequence can join a batch of older ones mid-decode;
/// * the decoded token buffer (BOS-framed, PAD-initialized), the
///   per-position target-key validity flags (`token != PAD`, the
///   self-attention gate) and the EOS flag (a finished sequence emits
///   PAD without paying for its logits);
/// * the step counter `len` — slots of different ages coexist in one
///   [`NativeBackend::step_slots`] batch, each attending over its own
///   `len + 1`-key prefix.
///
/// Because every per-row kernel on the step path is row-independent with
/// a fixed per-element accumulation order, stepping a slot inside any
/// mixed-age batch is bit-identical to stepping it alone — the invariant
/// the continuous batcher's parity tests pin.
pub struct SeqSlot {
    /// Per-decoder-layer self-attention key rows (page-backed, grows
    /// with the decode cursor).
    self_k: Vec<PagedRows>,
    /// Per-decoder-layer self-attention value rows (page-backed).
    self_v: Vec<PagedRows>,
    /// Per-decoder-layer cross-attention (K, V) of the encoder memory.
    cross: Vec<(Matrix, Matrix)>,
    /// Source-key validity (`token != PAD`) of the encoder memory.
    src_ok: Vec<bool>,
    /// `token != PAD` per decoded position (filled to `len`).
    tgt_ok: Vec<bool>,
    /// Decoded token buffer `[seq_len]`: BOS-framed, PAD-initialized,
    /// position `i + 1` written by the step taken at `len == i`.
    buf: Vec<i32>,
    /// Whether the sequence has emitted EOS.
    done: bool,
    /// Positions decoded so far (the next step appends row `len`).
    len: usize,
}

impl SeqSlot {
    /// Positions decoded so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Whether the sequence has emitted EOS.
    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Whether the lifecycle is over: EOS emitted or the fixed buffer is
    /// full. A complete slot's remaining positions are PAD by
    /// construction, so retiring it early changes no output bit.
    pub fn complete(&self) -> bool {
        self.done || self.len + 1 >= self.buf.len()
    }

    /// The decoded token buffer (BOS-framed, PAD-padded, `seq_len` long).
    pub fn buffer(&self) -> &[i32] {
        &self.buf
    }

    /// Exact KV bytes this slot's page tables currently hold.
    pub fn resident_bytes(&self) -> usize {
        self.self_k.iter().chain(self.self_v.iter()).map(PagedRows::resident_bytes).sum()
    }

    /// Pages this slot's tables currently hold.
    pub fn resident_pages(&self) -> usize {
        self.self_k.iter().chain(self.self_v.iter()).map(PagedRows::n_pages).sum()
    }

    /// Return every KV page to the pool (retirement/eviction). Dropping
    /// the slot also releases; this explicit form lets the scheduler
    /// leak-check at the retirement boundary.
    fn release_pages(&mut self) {
        for rows in self.self_k.iter_mut().chain(self.self_v.iter_mut()) {
            rows.release();
        }
        debug_assert_eq!(self.resident_pages(), 0, "retired slot leaked KV pages");
    }
}

/// The batch-lifecycle view of the KV-cached decode: a set of
/// [`SeqSlot`]s stepped together. After the slot refactor this is a thin
/// container — all per-sequence state lives in the slots themselves, so
/// `translate` batches and the continuous batcher share one lifecycle
/// (admit → step → retire) instead of the old monolithic `[b*s x D]`
/// slabs indexed by batch row.
#[derive(Default)]
pub struct DecodeState {
    slots: Vec<SeqSlot>,
}

impl DecodeState {
    pub fn new() -> DecodeState {
        DecodeState::default()
    }

    /// Add an admitted slot to the batch.
    pub fn push(&mut self, slot: SeqSlot) {
        self.slots.push(slot);
    }

    /// Slots in admission order.
    pub fn slots(&self) -> &[SeqSlot] {
        &self.slots
    }

    /// Number of slots in the batch.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Whether every slot's lifecycle is over (EOS emitted or buffer
    /// full) — the decode loop may stop early.
    pub fn all_complete(&self) -> bool {
        self.slots.iter().all(|s| s.complete())
    }
}

/// Dependency-free transformer inference engine over a compressed model.
///
/// Construction resolves the manifest's linear inventory against a
/// compressed-layer bank once; `translate` calls are then read-only (and
/// `&self`, so one backend can serve many threads... today's callers are
/// single-threaded loops).
pub struct NativeBackend {
    dims: ModelDims,
    head_dim: usize,
    src_emb: Matrix,
    tgt_emb: Matrix,
    pos_emb: Matrix,
    enc: Vec<EncLayer>,
    dec: Vec<DecLayer>,
    enc_ln: LnParams,
    dec_ln: LnParams,
    /// Executable linears in manifest inventory order.
    ops: Vec<LinearOp>,
    /// Per-linear activation quant scales (manifest order).
    act_scales: Vec<f32>,
    /// Positive quant levels; 0 disables activation quantization.
    act_levels: f32,
    workers: usize,
    /// How `translate` runs its greedy decode loop (cached by default).
    decode: DecodePolicy,
    /// Which numerical tier the per-row decode kernels run on
    /// ([`KernelTier::Exact`] by default — bit-identical to the batched
    /// reference; [`KernelTier::Fast`] runs packed linears as runtime-
    /// quantized integer GEMV, non-bit-exact but parity-gated).
    kernel: KernelTier,
    /// Page pool every slot's self-attention K/V rows draw from.
    /// Defaults to unbounded with `seq_len`-row pages (exact residency
    /// accounting, no admission bound); [`Self::with_kv_pool`] installs
    /// a byte budget and page geometry.
    kv_pool: Arc<KvPool>,
}

impl NativeBackend {
    /// Build a backend executing `compressed` layers in `mode`.
    ///
    /// * Dense mode: linears absent from the map run with their original
    ///   FP32 weights; `LowRank` entries are reconstructed (`w1·w2`).
    /// * Svd mode: every linear must be present and `LowRank`; the factor
    ///   pair executes at its actual rank.
    /// * `act_wl` is the activation word length (`A` of WxAy); `None`
    ///   disables activation quantization (FP32 activations).
    pub fn new(
        manifest: &Manifest,
        model: &PairModel,
        compressed: &BTreeMap<String, CompressedLinear>,
        act_wl: Option<WordLen>,
        mode: Mode,
        workers: usize,
    ) -> Result<NativeBackend> {
        let dims = manifest.model.clone();
        ensure!(
            dims.n_heads > 0 && dims.d_model % dims.n_heads == 0,
            "d_model {} not divisible by n_heads {}",
            dims.d_model,
            dims.n_heads
        );
        let head_dim = dims.d_model / dims.n_heads;

        let emb = |name: &str, rows: usize| -> Result<Matrix> {
            let m = model
                .weights
                .get(name)
                .with_context(|| format!("weight store missing {name}"))?;
            ensure!(
                m.shape() == (rows, dims.d_model),
                "{name}: shape {:?}, want ({rows}, {})",
                m.shape(),
                dims.d_model
            );
            Ok(m.clone())
        };
        let src_emb = emb("src_emb", dims.vocab)?;
        let tgt_emb = emb("tgt_emb", dims.vocab)?;
        let pos_emb = {
            let m = model.weights.get("pos_emb").context("weight store missing pos_emb")?;
            ensure!(
                m.rows() >= dims.seq_len && m.cols() == dims.d_model,
                "pos_emb shape {:?} too small for seq_len {}",
                m.shape(),
                dims.seq_len
            );
            m.clone()
        };

        let ln = |name: &str| -> Result<LnParams> {
            let g = model
                .weights
                .get(&format!("{name}_g"))
                .with_context(|| format!("weight store missing {name}_g"))?;
            let b = model
                .weights
                .get(&format!("{name}_b"))
                .with_context(|| format!("weight store missing {name}_b"))?;
            ensure!(
                g.data().len() == dims.d_model && b.data().len() == dims.d_model,
                "{name}: layer-norm params must have d_model={} entries",
                dims.d_model
            );
            Ok(LnParams { g: g.data().to_vec(), b: b.data().to_vec() })
        };

        // Resolve every compressed linear into executable form, in
        // manifest inventory order (the index space act_scales shares).
        let mut ops = Vec::with_capacity(manifest.linears.len());
        for info in &manifest.linears {
            let op = match (mode, compressed.get(&info.name)) {
                (Mode::Dense, Some(c)) => {
                    let w = c.effective();
                    ensure!(
                        w.shape() == (info.k, info.n),
                        "{}: compressed shape {:?}, manifest says ({}, {})",
                        info.name,
                        w.shape(),
                        info.k,
                        info.n
                    );
                    LinearOp::Dense(w)
                }
                (Mode::Dense, None) => LinearOp::Dense(model.linear(&info.name).clone()),
                (Mode::Svd, Some(CompressedLinear::LowRank { w1, w2, .. })) => {
                    ensure!(
                        w1.rows() == info.k && w2.cols() == info.n && w1.cols() == w2.rows(),
                        "{}: factor shapes {:?}/{:?} inconsistent with ({}, {})",
                        info.name,
                        w1.shape(),
                        w2.shape(),
                        info.k,
                        info.n
                    );
                    LinearOp::Factored(w1.clone(), w2.clone())
                }
                (Mode::Svd, Some(_)) => {
                    bail!("layer {} is not factored; SVD mode needs LowRank", info.name)
                }
                (Mode::Svd, None) => {
                    bail!("SVD mode needs a factored layer for {}", info.name)
                }
                (Mode::Quantized, Some(c)) => {
                    let p = PackedLinear::from_compressed(c)
                        .with_context(|| format!("packing layer {}", info.name))?;
                    match &p {
                        PackedLinear::Dense(w) => ensure!(
                            w.rows() == info.k && w.cols() == info.n,
                            "{}: packed shape {}x{}, manifest says ({}, {})",
                            info.name,
                            w.rows(),
                            w.cols(),
                            info.k,
                            info.n
                        ),
                        PackedLinear::Factored(w1, w2) => ensure!(
                            w1.rows() == info.k
                                && w2.cols() == info.n
                                && w1.cols() == w2.rows(),
                            "{}: packed factor shapes {}x{}/{}x{} inconsistent with ({}, {})",
                            info.name,
                            w1.rows(),
                            w1.cols(),
                            w2.rows(),
                            w2.cols(),
                            info.k,
                            info.n
                        ),
                    }
                    LinearOp::Packed(p)
                }
                (Mode::Quantized, None) => {
                    bail!("quantized mode needs a compressed layer for {}", info.name)
                }
            };
            ops.push(op);
        }

        let act_levels = act_wl.map(quant::levels).unwrap_or(0.0);
        let act_scales: Vec<f32> = model
            .act_maxabs
            .iter()
            .map(|&mx| if act_levels > 0.0 { quant::scale_for(mx, act_levels) } else { 1.0 })
            .collect();
        ensure!(
            act_scales.len() == ops.len(),
            "act_maxabs has {} entries for {} linears",
            act_scales.len(),
            ops.len()
        );

        let idx = |name: String| -> Result<usize> {
            manifest
                .linear_index(&name)
                .with_context(|| format!("manifest missing linear {name}"))
        };
        let mut enc = Vec::with_capacity(dims.n_enc);
        for i in 0..dims.n_enc {
            let p = format!("enc{i}");
            enc.push(EncLayer {
                ln1: ln(&format!("{p}.ln1"))?,
                ln2: ln(&format!("{p}.ln2"))?,
                q: idx(format!("{p}.self_q"))?,
                k: idx(format!("{p}.self_k"))?,
                v: idx(format!("{p}.self_v"))?,
                o: idx(format!("{p}.self_o"))?,
                ff1: idx(format!("{p}.ff1"))?,
                ff2: idx(format!("{p}.ff2"))?,
            });
        }
        let mut dec = Vec::with_capacity(dims.n_dec);
        for i in 0..dims.n_dec {
            let p = format!("dec{i}");
            dec.push(DecLayer {
                ln1: ln(&format!("{p}.ln1"))?,
                ln2: ln(&format!("{p}.ln2"))?,
                ln3: ln(&format!("{p}.ln3"))?,
                self_q: idx(format!("{p}.self_q"))?,
                self_k: idx(format!("{p}.self_k"))?,
                self_v: idx(format!("{p}.self_v"))?,
                self_o: idx(format!("{p}.self_o"))?,
                cross_q: idx(format!("{p}.cross_q"))?,
                cross_k: idx(format!("{p}.cross_k"))?,
                cross_v: idx(format!("{p}.cross_v"))?,
                cross_o: idx(format!("{p}.cross_o"))?,
                ff1: idx(format!("{p}.ff1"))?,
                ff2: idx(format!("{p}.ff2"))?,
            });
        }

        let enc_ln = ln("enc_ln")?;
        let dec_ln = ln("dec_ln")?;
        let kv_pool = Arc::new(KvPool::unbounded(dims.seq_len.max(1), dims.d_model.max(1)));
        Ok(NativeBackend {
            dims,
            head_dim,
            src_emb,
            tgt_emb,
            pos_emb,
            enc,
            dec,
            enc_ln,
            dec_ln,
            ops,
            act_scales,
            act_levels,
            workers: workers.max(1),
            decode: DecodePolicy::default(),
            kernel: KernelTier::default(),
            kv_pool,
        })
    }

    /// Select the greedy-decode execution policy (cached by default);
    /// both policies produce bit-identical tokens.
    pub fn with_decode(mut self, policy: DecodePolicy) -> NativeBackend {
        self.decode = policy;
        self
    }

    /// Install a budgeted KV page pool: pages of `page_tokens` rows per
    /// K/V table, `budget_bytes` across all live slots (`None` keeps
    /// the budget unbounded but changes the page geometry). Paging is
    /// bit-transparent — rows keep their values and accumulation order
    /// wherever they live — so any budget/geometry produces identical
    /// tokens; a too-small budget surfaces as scheduling (queueing,
    /// preemption) or a typed step error, never as different bits.
    ///
    /// Call before creating slots: existing slots keep drawing from the
    /// pool they were admitted under.
    pub fn with_kv_pool(mut self, budget_bytes: Option<usize>, page_tokens: usize) -> NativeBackend {
        let pt = page_tokens.clamp(1, self.dims.seq_len.max(1));
        self.kv_pool = Arc::new(KvPool::new(pt, self.dims.d_model.max(1), budget_bytes));
        self
    }

    /// The backend's KV page pool (accounting reads).
    pub fn kv_pool(&self) -> &Arc<KvPool> {
        &self.kv_pool
    }

    /// The active greedy-decode policy.
    pub fn decode_policy(&self) -> DecodePolicy {
        self.decode
    }

    /// Select the kernel tier of the per-row decode path (exact by
    /// default). Only `Mode::Quantized` holds packed linears for the
    /// fast tier to run as integer GEMV; under Dense/Svd the tier
    /// changes nothing. `KernelTier::Fast` output is **not**
    /// bit-identical to exact — it is fenced by `validate --kernel
    /// fast`'s parity table instead.
    pub fn with_kernel(mut self, tier: KernelTier) -> NativeBackend {
        self.kernel = tier;
        self
    }

    /// The active per-row kernel tier.
    pub fn kernel_tier(&self) -> KernelTier {
        self.kernel
    }

    /// FP32 reference backend: original weights, no quantization.
    pub fn fp32(manifest: &Manifest, model: &PairModel, workers: usize) -> Result<NativeBackend> {
        NativeBackend::new(manifest, model, &BTreeMap::new(), None, Mode::Dense, workers)
    }

    pub fn dims(&self) -> &ModelDims {
        &self.dims
    }

    /// Total multiply-accumulates one translate of `rows` source rows
    /// costs in its compressed linears (decode loop included) under the
    /// backend's active [`DecodePolicy`] — the runtime counterpart of
    /// the accounting model, used by benches.
    pub fn linear_macs_per_translate(&self, rows: usize) -> u64 {
        self.linear_macs_for(rows, self.decode)
    }

    /// [`Self::linear_macs_per_translate`] under an explicit policy.
    ///
    /// Encoder linears run once over `rows*seq` tokens; the cross-
    /// attention K/V projections of the constant memory are hoisted to
    /// once per translate in both policies. The decoder stack's per-step
    /// activation differs: **replay** re-runs it over the full buffer
    /// each of the `seq-1` steps (`m_dec = rows*seq*(seq-1)` — the AOT
    /// graph's cost), while **cached** runs each step on one row per
    /// batch element (`m_dec = rows*(seq-1)`), a factor-`seq` reduction.
    /// Only compressed linears are counted.
    pub fn linear_macs_for(&self, rows: usize, policy: DecodePolicy) -> u64 {
        let s = self.dims.seq_len as u64;
        let m_enc = (rows * self.dims.seq_len) as u64;
        let m_dec = match policy {
            DecodePolicy::Replay => m_enc * (s - 1),
            DecodePolicy::Cached => rows as u64 * (s - 1),
        };
        let cost = |op: &LinearOp, m: u64| -> u64 {
            match op {
                LinearOp::Dense(w) => m * w.rows() as u64 * w.cols() as u64,
                LinearOp::Factored(w1, w2) => {
                    m * w1.cols() as u64 * (w1.rows() as u64 + w2.cols() as u64)
                }
                LinearOp::Packed(PackedLinear::Dense(w)) => {
                    m * w.rows() as u64 * w.cols() as u64
                }
                LinearOp::Packed(PackedLinear::Factored(w1, w2)) => {
                    m * w1.cols() as u64 * (w1.rows() as u64 + w2.cols() as u64)
                }
            }
        };
        let mut macs = 0u64;
        for l in &self.enc {
            for i in [l.q, l.k, l.v, l.o, l.ff1, l.ff2] {
                macs += cost(&self.ops[i], m_enc);
            }
        }
        for l in &self.dec {
            for i in [
                l.self_q, l.self_k, l.self_v, l.self_o, l.cross_q, l.cross_o, l.ff1, l.ff2,
            ] {
                macs += cost(&self.ops[i], m_dec);
            }
            for i in [l.cross_k, l.cross_v] {
                macs += cost(&self.ops[i], m_enc);
            }
        }
        macs
    }

    /// Resident bytes of the compressed-linear weights this backend
    /// actually holds: f32 buffers for dense/factored execution, packed
    /// integers + scales for quantized execution — what the CLI's memory
    /// accounting and the byte-savings tests report.
    pub fn weight_bytes(&self) -> usize {
        self.ops
            .iter()
            .map(|op| match op {
                LinearOp::Dense(w) => w.data().len() * 4,
                LinearOp::Factored(w1, w2) => (w1.data().len() + w2.data().len()) * 4,
                LinearOp::Packed(p) => p.packed_bytes(),
            })
            .sum()
    }

    /// Activation fake-quant + compressed-linear product (the `ctx.linear`
    /// of the JAX model): `x` is the flattened `[rows x K]` activation.
    fn linear(&self, idx: usize, x: &Matrix) -> Matrix {
        let xq = self.fake_quant(idx, x);
        let xq = xq.as_ref().unwrap_or(x);
        match &self.ops[idx] {
            LinearOp::Dense(w) => xq.matmul_par(w, self.workers),
            LinearOp::Factored(w1, w2) => {
                xq.matmul_par(w1, self.workers).matmul_par(w2, self.workers)
            }
            LinearOp::Packed(PackedLinear::Dense(w)) => w.qmatmul_par(xq, self.workers),
            LinearOp::Packed(PackedLinear::Factored(w1, w2)) => {
                let h = w1.qmatmul_par(xq, self.workers);
                w2.qmatmul_par(&h, self.workers)
            }
        }
    }

    /// Single-step linear: the same fake-quant + compressed product as
    /// [`Self::linear`], executed row by row through the single-row
    /// kernels ([`Matrix::vecmat_par`], [`PackedLinear::matvec`]).
    /// Under [`KernelTier::Exact`] (the default) it is bit-identical to
    /// [`Self::linear`] on the same rows — every kernel accumulates each
    /// output element in the batched kernel's ascending-`k` order, which
    /// is what makes the cached decode path reproduce the full-buffer
    /// replay exactly. Under [`KernelTier::Fast`], packed linears run
    /// [`PackedLinear::matvec_fast`] instead: runtime A8 activation
    /// quantization + pure-integer GEMV, non-bit-exact by contract. The
    /// fast kernel's typed envelope errors (e.g. a NaN activation lane)
    /// surface as `Err` naming the linear and batch row, which the
    /// batcher's fault attribution turns into exactly one request's
    /// `EngineFault`.
    fn linear_step(&self, idx: usize, x: &Matrix) -> Result<Matrix> {
        let xq = self.fake_quant(idx, x);
        let xq = xq.as_ref().unwrap_or(x);
        let op = &self.ops[idx];
        let mut out = Matrix::zeros(x.rows(), op.n_out());
        for r in 0..xq.rows() {
            let y = match op {
                LinearOp::Dense(w) => w.vecmat_par(xq.row(r), self.workers),
                LinearOp::Factored(w1, w2) => {
                    w2.vecmat_par(&w1.vecmat_par(xq.row(r), self.workers), self.workers)
                }
                LinearOp::Packed(p) => match self.kernel {
                    KernelTier::Exact => p.matvec(xq.row(r)),
                    KernelTier::Fast => p.matvec_fast(xq.row(r)).with_context(|| {
                        format!("fast integer kernel on linear {idx}, step batch row {r}")
                    })?,
                },
            };
            out.row_mut(r).copy_from_slice(&y);
        }
        Ok(out)
    }

    /// `clip(round(x/s), -lv, lv) * s` with the reference's safe-scale
    /// convention (`s <= 0` quantizes with scale 1). `None` when
    /// `act_levels == 0` (the FP32 identity path) — callers fall back to
    /// the borrowed input instead of paying a full-matrix clone on every
    /// linear call.
    fn fake_quant(&self, idx: usize, x: &Matrix) -> Option<Matrix> {
        let lv = self.act_levels;
        if lv <= 0.0 {
            return None;
        }
        let s = self.act_scales[idx];
        let s = if s > 0.0 { s } else { 1.0 };
        let data = x.data().iter().map(|&v| (v / s).round().clamp(-lv, lv) * s).collect();
        Some(Matrix::from_vec(x.rows(), x.cols(), data))
    }

    /// `ff2(relu(ff1(x)))`.
    fn ffn(&self, ff1: usize, ff2: usize, x: &Matrix) -> Matrix {
        let mut h = self.linear(ff1, x);
        for v in h.data_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        self.linear(ff2, &h)
    }

    /// [`Self::ffn`] through the single-row kernels (bit-identical under
    /// the exact tier; fast-tier errors propagate).
    fn ffn_step(&self, ff1: usize, ff2: usize, x: &Matrix) -> Result<Matrix> {
        let mut h = self.linear_step(ff1, x)?;
        for v in h.data_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        self.linear_step(ff2, &h)
    }

    /// Multi-head scaled-dot-product attention core (projections already
    /// applied): `q [b*tq x D]`, `k`/`v` `[b*tk x D]`; `allowed(bi, qi,
    /// kj)` gates key `kj` for query `qi` of batch row `bi`. Returns the
    /// head-merged context `[b*tq x D]` (before the output projection).
    #[allow(clippy::too_many_arguments)] // q/k/v + the three geometry dims are one call site's worth
    fn attend(
        &self,
        q: &Matrix,
        k: &Matrix,
        v: &Matrix,
        b: usize,
        tq: usize,
        tk: usize,
        allowed: impl Fn(usize, usize, usize) -> bool,
    ) -> Matrix {
        let d = self.dims.d_model;
        let hd = self.head_dim;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut out = Matrix::zeros(b * tq, d);
        let mut scores = vec![0.0f32; tk];
        for bi in 0..b {
            for h in 0..self.dims.n_heads {
                let lo = h * hd;
                let hi = lo + hd;
                for qi in 0..tq {
                    let q_slice = &q.row(bi * tq + qi)[lo..hi];
                    for (kj, s) in scores.iter_mut().enumerate() {
                        let raw = dot(q_slice, &k.row(bi * tk + kj)[lo..hi]) * scale;
                        *s = if allowed(bi, qi, kj) { raw } else { raw + NEG };
                    }
                    softmax_in_place(&mut scores);
                    let o_slice = &mut out.row_mut(bi * tq + qi)[lo..hi];
                    for (kj, &w) in scores.iter().enumerate() {
                        if w == 0.0 {
                            continue; // masked keys underflow to exactly 0
                        }
                        let v_slice = &v.row(bi * tk + kj)[lo..hi];
                        for (o, &vv) in o_slice.iter_mut().zip(v_slice) {
                            *o += w * vv;
                        }
                    }
                }
            }
        }
        out
    }

    /// Single-query attention of one batch row over the first `n_keys`
    /// rows of a per-sequence K/V row store: the step-wise,
    /// slot-addressed counterpart of [`Self::attend`] (`tq = 1`, keys
    /// truncated to the filled prefix). `q_row`/`out` are one `[D]` row;
    /// `k`/`v` are any [`RowRead`] row store — the contiguous cross K/V
    /// [`Matrix`] or the page-backed self-attention [`PagedRows`]
    /// (paging moves rows, never their values or per-element order, so
    /// the two layouts are bit-identical through this kernel). Each row
    /// carrying its own `n_keys` is what lets sequences of different
    /// ages share one step batch.
    ///
    /// Bit-identical to [`Self::attend`] over a full score row whose keys
    /// `>= n_keys` are masked: masked scores underflow to exactly 0 after
    /// the stable softmax shift and contribute `+0.0` to the normalizer
    /// (an exact no-op on the non-negative partial sums), so skipping
    /// their computation entirely changes no bit.
    ///
    /// `scratch` is a caller-owned score buffer, resized (not
    /// reallocated, once warm) to `n_keys` and fully overwritten before
    /// use — one allocation per step batch instead of one per row.
    #[allow(clippy::too_many_arguments)] // mirrors attend's one call-site geometry
    fn attend_slot_row<M: RowRead>(
        &self,
        q_row: &[f32],
        k: &M,
        v: &M,
        n_keys: usize,
        allowed: impl Fn(usize) -> bool,
        scratch: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        let hd = self.head_dim;
        let scale = 1.0 / (hd as f32).sqrt();
        scratch.clear();
        scratch.resize(n_keys, 0.0);
        let scores = scratch.as_mut_slice();
        for h in 0..self.dims.n_heads {
            let lo = h * hd;
            let hi = lo + hd;
            let q_slice = &q_row[lo..hi];
            for (kj, s) in scores.iter_mut().enumerate() {
                let raw = dot(q_slice, &k.row(kj)[lo..hi]) * scale;
                *s = if allowed(kj) { raw } else { raw + NEG };
            }
            softmax_in_place(scores);
            let o_slice = &mut out[lo..hi];
            for (kj, &w) in scores.iter().enumerate() {
                if w == 0.0 {
                    continue; // masked keys underflow to exactly 0
                }
                let v_slice = &v.row(kj)[lo..hi];
                for (o, &vv) in o_slice.iter_mut().zip(v_slice) {
                    *o += w * vv;
                }
            }
        }
    }

    /// Token embedding + positional encoding: `[b*s x D]`.
    fn embed(&self, table: &Matrix, tokens: &[i32], b: usize) -> Result<Matrix> {
        let s = self.dims.seq_len;
        let d = self.dims.d_model;
        let mut x = Matrix::zeros(b * s, d);
        for (r, &t) in tokens.iter().enumerate() {
            ensure!(
                t >= 0 && (t as usize) < self.dims.vocab,
                "token {t} at position {r} outside vocab 0..{}",
                self.dims.vocab
            );
            let e = table.row(t as usize);
            let p = self.pos_emb.row(r % s);
            for ((o, &ec), &pc) in x.row_mut(r).iter_mut().zip(e).zip(p) {
                *o = ec + pc;
            }
        }
        Ok(x)
    }

    /// Encoder stack: returns (memory `[b*s x D]`, per-token key validity).
    fn encode(&self, src: &[i32], b: usize) -> Result<(Matrix, Vec<bool>)> {
        let s = self.dims.seq_len;
        let mut x = self.embed(&self.src_emb, src, b)?;
        let key_ok: Vec<bool> = src.iter().map(|&t| t != self.dims.pad_id).collect();
        for layer in &self.enc {
            let h = layer_norm(&x, &layer.ln1);
            let q = self.linear(layer.q, &h);
            let k = self.linear(layer.k, &h);
            let v = self.linear(layer.v, &h);
            let ctx = self.attend(&q, &k, &v, b, s, s, |bi, _qi, kj| key_ok[bi * s + kj]);
            x = x.add(&self.linear(layer.o, &ctx));
            let h = layer_norm(&x, &layer.ln2);
            x = x.add(&self.ffn(layer.ff1, layer.ff2, &h));
        }
        Ok((layer_norm(&x, &self.enc_ln), key_ok))
    }

    /// Cross-attention K/V projections of the encoder memory, one pair per
    /// decoder layer. The memory is constant across the whole greedy
    /// decode, so these are computed once per translate instead of once
    /// per step — numerically identical, (seq_len-2) fewer matmul pairs
    /// per layer on the hot path.
    fn cross_kv(&self, memory: &Matrix) -> Vec<(Matrix, Matrix)> {
        self.dec
            .iter()
            .map(|layer| (self.linear(layer.cross_k, memory), self.linear(layer.cross_v, memory)))
            .collect()
    }

    /// Decoder stack over a full (causally masked) target buffer; returns
    /// the final hidden states `[b*s x D]` (pre output-head). `cross` is
    /// the per-layer memory K/V from [`Self::cross_kv`].
    fn decode_hidden(
        &self,
        buf: &[i32],
        cross: &[(Matrix, Matrix)],
        src_ok: &[bool],
        b: usize,
    ) -> Result<Matrix> {
        let s = self.dims.seq_len;
        let mut x = self.embed(&self.tgt_emb, buf, b)?;
        let tgt_ok: Vec<bool> = buf.iter().map(|&t| t != self.dims.pad_id).collect();
        for (layer, (ck, cv)) in self.dec.iter().zip(cross) {
            let h = layer_norm(&x, &layer.ln1);
            let q = self.linear(layer.self_q, &h);
            let k = self.linear(layer.self_k, &h);
            let v = self.linear(layer.self_v, &h);
            let ctx = self
                .attend(&q, &k, &v, b, s, s, |bi, qi, kj| kj <= qi && tgt_ok[bi * s + kj]);
            x = x.add(&self.linear(layer.self_o, &ctx));

            let h = layer_norm(&x, &layer.ln2);
            let q = self.linear(layer.cross_q, &h);
            let ctx = self.attend(&q, ck, cv, b, s, s, |bi, _qi, kj| src_ok[bi * s + kj]);
            x = x.add(&self.linear(layer.cross_o, &ctx));

            let h = layer_norm(&x, &layer.ln3);
            x = x.add(&self.ffn(layer.ff1, layer.ff2, &h));
        }
        Ok(layer_norm(&x, &self.dec_ln))
    }

    /// Admit one request: run its encoder pass and return a fresh
    /// [`SeqSlot`] positioned at the BOS step, its cross-attention
    /// context spliced in so it can join a live batch of older slots.
    ///
    /// `src_row` is a single BOS-framed, PAD-padded `seq_len`-token
    /// source row. Every encoder op is row-independent with a fixed
    /// per-element accumulation order, so the slot built here is
    /// bit-identical to the corresponding row of a batched encode — the
    /// continuous batcher's admissions reproduce `translate` exactly.
    pub fn admit_slot(&self, src_row: &[i32]) -> Result<SeqSlot> {
        let s = self.dims.seq_len;
        ensure!(
            src_row.len() == s,
            "admit_slot expects one seq_len={s} source row, got {} tokens",
            src_row.len()
        );
        ensure!(
            self.dims.bos_id != self.dims.pad_id,
            "BOS aliased to PAD degrades the reference decode to uniform attention \
             over the full buffer; only the replay loop reproduces that convention"
        );
        let (memory, src_ok) = self.encode(src_row, 1)?;
        let cross = self.cross_kv(&memory);
        runtime_counters().0.inc();
        Ok(self.slot_from_parts(cross, src_ok))
    }

    /// Assemble a BOS-positioned slot from an encoder pass's per-layer
    /// cross K/V (`[seq_len x D]` each) and source-key mask.
    fn slot_from_parts(&self, cross: Vec<(Matrix, Matrix)>, src_ok: Vec<bool>) -> SeqSlot {
        let s = self.dims.seq_len;
        let n_dec = self.dec.len();
        let mut buf = vec![self.dims.pad_id; s];
        buf[0] = self.dims.bos_id;
        SeqSlot {
            // Page tables start empty: pages are allocated lazily by
            // step_slots, one step ahead of the decode cursor, so
            // admission itself never draws from the budget.
            self_k: (0..n_dec).map(|_| PagedRows::new(&self.kv_pool)).collect(),
            self_v: (0..n_dec).map(|_| PagedRows::new(&self.kv_pool)).collect(),
            cross,
            src_ok,
            tgt_ok: vec![false; s],
            buf,
            // Degenerate manifests may alias EOS with BOS or PAD; the
            // replay rescan would see every row as immediately finished
            // in its BOS-framed, PAD-filled initial buffer.
            done: self.dims.bos_id == self.dims.eos_id || self.dims.pad_id == self.dims.eos_id,
            len: 0,
        }
    }

    /// One KV-cached decoder step over a **mixed-age** batch of live
    /// slots: embed each slot's current token at *its own* position, run
    /// the decoder blocks on the `[b x D]` activation, append each slot's
    /// new self-attention K/V row, pick the next token (greedy argmax, or
    /// PAD for finished slots) and advance each step counter.
    ///
    /// Bit-identical to row `slot.len()` of [`Self::decode_hidden`] over
    /// the same buffer — for every slot independently, whatever batch it
    /// shares the step with: a position's hidden state depends only on
    /// positions `<=` it (causal masking — masked attention weights are
    /// exactly 0 and skipped), every linear/layer-norm/FFN is
    /// row-independent with a shared per-element accumulation order, each
    /// row attends over its own slot's caches, and the cached K/V rows
    /// equal the ones replay recomputes each step. This independence is
    /// the architectural unlock for continuous batching: admitting or
    /// retiring a slot never perturbs another slot's bits.
    ///
    /// Failure atomicity: validation errors are raised by the pre-pass
    /// below, **before** any slot state is touched. Fast-tier kernel
    /// errors (a poisoned activation reaching
    /// [`PackedLinear::matvec_fast`]) can surface mid-layer, after some
    /// slot state was written — but every such write is idempotent at a
    /// fixed `len` (K/V row `len` and `tgt_ok[len]` are overwritten
    /// whole; `buf[len + 1]` and the counter advance only in the final
    /// commit below), so a failed step leaves all slots **idempotently
    /// re-steppable**: the batcher's per-slot fault attribution re-steps
    /// survivors and reproduces the same bits (the
    /// [`crate::runtime::SlotEngine::step`] contract).
    pub fn step_slots(&self, slots: &mut [&mut SeqSlot]) -> Result<()> {
        let b = slots.len();
        if b == 0 {
            return Ok(());
        }
        let hidden = self.step_hidden(slots)?;

        // Greedy pick + append: a finished slot emits PAD without paying
        // for its logits (same order as the batched reference — the done
        // flag is consulted before this step's EOS can set it).
        for (r, slot) in slots.iter_mut().enumerate() {
            let i = slot.len;
            let next = if slot.done {
                self.dims.pad_id
            } else {
                let logits = self.tgt_emb.matvec(hidden.row(r));
                argmax(&logits) as i32
            };
            if next == self.dims.eos_id {
                slot.done = true;
            }
            slot.buf[i + 1] = next;
            slot.len = i + 1;
        }
        let counters = runtime_counters();
        counters.1.inc();
        counters.2.add(b as u64);
        Ok(())
    }

    /// Everything of one decode step except the token commit: validate,
    /// back the cursor row with KV pages, embed each slot's current
    /// token, run the decoder blocks on the `[b x D]` activation
    /// (appending each slot's new self-attention K/V row), and return
    /// the final-layer-norm hidden states `[b x D]`. Split out of
    /// [`Self::step_slots`] so diagnostics ([`Self::step_logits`]) can
    /// read the step's full logits instead of only the greedy argmax.
    fn step_hidden(&self, slots: &mut [&mut SeqSlot]) -> Result<Matrix> {
        let b = slots.len();
        let s = self.dims.seq_len;
        let d = self.dims.d_model;

        // Validation pre-pass: reject the whole step before mutating any
        // slot, so Err never leaves a half-stepped batch behind.
        for (r, slot) in slots.iter().enumerate() {
            let i = slot.len;
            ensure!(i + 1 < s, "slot {r} stepped past its fixed {s}-token buffer");
            let t = slot.buf[i];
            ensure!(
                t >= 0 && (t as usize) < self.dims.vocab,
                "token {t} in slot {r} outside vocab 0..{}",
                self.dims.vocab
            );
        }

        // Page-ensure pre-pass: back row `len` of every K/V table before
        // any decode state changes. Page allocation is idempotent
        // bookkeeping (already-backed tables are a no-op and acquired
        // pages survive an Err), so a failed batch remains re-steppable
        // — the memory-aware scheduler prevents this Err by evicting
        // under pressure; hitting it means the pool is over-committed
        // beyond what eviction can recover (e.g. a lone slot larger
        // than the whole budget).
        for (r, slot) in slots.iter_mut().enumerate() {
            let i = slot.len;
            for rows in slot.self_k.iter_mut().chain(slot.self_v.iter_mut()) {
                ensure!(
                    rows.ensure_row(i),
                    "kv pool exhausted backing row {i} of slot {r} \
                     (resident {} bytes, budget {:?})",
                    self.kv_pool.resident_bytes(),
                    self.kv_pool.budget_bytes()
                );
            }
        }

        // Embed each slot's current token at its own position.
        let mut x = Matrix::zeros(b, d);
        for (r, slot) in slots.iter_mut().enumerate() {
            let i = slot.len;
            let t = slot.buf[i];
            let e = self.tgt_emb.row(t as usize);
            let p = self.pos_emb.row(i);
            for ((o, &ec), &pc) in x.row_mut(r).iter_mut().zip(e).zip(p) {
                *o = ec + pc;
            }
            slot.tgt_ok[i] = t != self.dims.pad_id;
        }

        let mut scores = Vec::with_capacity(s);
        for (li, layer) in self.dec.iter().enumerate() {
            let h = layer_norm(&x, &layer.ln1);
            let q = self.linear_step(layer.self_q, &h)?;
            let k_new = self.linear_step(layer.self_k, &h)?;
            let v_new = self.linear_step(layer.self_v, &h)?;
            for (r, slot) in slots.iter_mut().enumerate() {
                let i = slot.len;
                slot.self_k[li].row_mut(i).copy_from_slice(k_new.row(r));
                slot.self_v[li].row_mut(i).copy_from_slice(v_new.row(r));
            }
            let mut ctx = Matrix::zeros(b, d);
            for (r, slot) in slots.iter().enumerate() {
                let sl: &SeqSlot = slot;
                self.attend_slot_row(
                    q.row(r),
                    &sl.self_k[li],
                    &sl.self_v[li],
                    sl.len + 1,
                    |kj| sl.tgt_ok[kj],
                    &mut scores,
                    ctx.row_mut(r),
                );
            }
            x = x.add(&self.linear_step(layer.self_o, &ctx)?);

            let h = layer_norm(&x, &layer.ln2);
            let q = self.linear_step(layer.cross_q, &h)?;
            let mut ctx = Matrix::zeros(b, d);
            for (r, slot) in slots.iter().enumerate() {
                let sl: &SeqSlot = slot;
                let (ck, cv) = &sl.cross[li];
                self.attend_slot_row(
                    q.row(r),
                    ck,
                    cv,
                    s,
                    |kj| sl.src_ok[kj],
                    &mut scores,
                    ctx.row_mut(r),
                );
            }
            x = x.add(&self.linear_step(layer.cross_o, &ctx)?);

            let h = layer_norm(&x, &layer.ln3);
            x = x.add(&self.ffn_step(layer.ff1, layer.ff2, &h)?);
        }
        Ok(layer_norm(&x, &self.dec_ln))
    }

    /// Teacher-forced per-step logits through the **step kernels** — the
    /// tier-sensitive diagnostic surface. [`Self::forward_logits`] runs
    /// the batched replay kernels, which both kernel tiers share; this
    /// drives the same teacher-forced positions through the single-row
    /// cached-decode path (`linear_step`/`ffn_step`/`attend_slot_row`),
    /// so it is the surface where [`KernelTier::Fast`]'s integer
    /// arithmetic is visible — `validate --kernel fast` computes its
    /// max |Δlogit| here. Returns `[(seq_len - 1) x vocab]`: row `i` is
    /// the logits of the step taken at position `i` (predicting
    /// position `i + 1`) given the forced prefix `tgt_in[..=i]`.
    pub fn step_logits(&self, src_row: &[i32], tgt_in: &[i32]) -> Result<Matrix> {
        let s = self.dims.seq_len;
        ensure!(
            tgt_in.len() == s,
            "step_logits expects one seq_len={s} target row, got {} tokens",
            tgt_in.len()
        );
        let mut slot = self.admit_slot(src_row)?;
        slot.buf[0] = tgt_in[0];
        let mut out = Matrix::zeros(s - 1, self.dims.vocab);
        for i in 0..s - 1 {
            let hidden = {
                let mut refs = [&mut slot];
                self.step_hidden(&mut refs)?
            };
            out.row_mut(i).copy_from_slice(&self.tgt_emb.matvec(hidden.row(0)));
            // Teacher-force the next position instead of the greedy pick.
            slot.buf[i + 1] = tgt_in[i + 1];
            slot.len = i + 1;
        }
        Ok(out)
    }

    /// Teacher-forced logits `[b*s x vocab]` for `tgt_in` given `src` —
    /// the parity/diagnostic surface (greedy decode uses only one row per
    /// step, but tolerance comparisons want the full tensor). Runs the
    /// batched kernels, which are tier-insensitive; see
    /// [`Self::step_logits`] for the per-step surface the kernel-tier
    /// parity gate measures.
    pub fn forward_logits(&self, src: &[i32], tgt_in: &[i32]) -> Result<Matrix> {
        let b = self.rows_of(src)?;
        ensure!(
            tgt_in.len() == src.len(),
            "src/tgt length mismatch: {} vs {}",
            src.len(),
            tgt_in.len()
        );
        let (memory, src_ok) = self.encode(src, b)?;
        let cross = self.cross_kv(&memory);
        let hidden = self.decode_hidden(tgt_in, &cross, &src_ok, b)?;
        // Tied head: logits = hidden · tgt_emb^T.
        Ok(hidden.matmul_par(&self.tgt_emb.transpose(), self.workers))
    }

    fn rows_of(&self, tokens: &[i32]) -> Result<usize> {
        let s = self.dims.seq_len;
        ensure!(
            !tokens.is_empty() && tokens.len() % s == 0,
            "token buffer len {} is not a positive multiple of seq_len {s}",
            tokens.len()
        );
        Ok(tokens.len() / s)
    }
}

impl TranslateBackend for NativeBackend {
    fn kind(&self) -> &'static str {
        "native"
    }

    fn batch(&self) -> usize {
        self.dims.eval_batch
    }

    fn seq_len(&self) -> usize {
        self.dims.seq_len
    }

    /// Any positive multiple of `seq_len` rows is accepted.
    fn fixed_shape(&self) -> bool {
        false
    }

    /// Greedy decode under the backend's [`DecodePolicy`]: position `i`'s
    /// logits pick token `i+1`, and a row that has emitted EOS produces
    /// PAD from then on. Unlike the fixed-batch artifacts, any positive
    /// multiple of `seq_len` rows is accepted. Both policies return
    /// bit-identical buffers (pinned by `tests/e2e_native.rs` and the
    /// decode proptest).
    fn translate(&self, src_tokens: &[i32]) -> Result<Vec<i32>> {
        match self.decode {
            DecodePolicy::Replay => self.translate_replay(src_tokens),
            DecodePolicy::Cached => self.translate_cached(src_tokens),
        }
    }
}

/// The slot-addressed decode contract the continuous batcher drives:
/// thin delegation onto the inherent slot API. Slot independence (the
/// bit-parity requirement the trait documents) is pinned by the
/// continuous-vs-sequential proptest and the serving soak test.
impl SlotEngine for NativeBackend {
    type Slot = SeqSlot;

    fn slot_seq_len(&self) -> usize {
        self.dims.seq_len
    }

    fn admit(&self, src_row: &[i32]) -> Result<SeqSlot> {
        self.admit_slot(src_row)
    }

    fn step(&self, slots: &mut [&mut SeqSlot]) -> Result<()> {
        self.step_slots(slots)
    }

    fn slot_complete(&self, slot: &SeqSlot) -> bool {
        slot.complete()
    }

    fn slot_output(&self, slot: &SeqSlot) -> Vec<i32> {
        slot.buffer().to_vec()
    }

    fn kv_stats(&self) -> Option<KvMemStats> {
        Some(self.kv_pool.stats())
    }

    /// Worst case = a full-length decode: rows `0..seq_len-1` across
    /// `2 * n_dec` K/V tables, rounded up to whole pages.
    fn slot_worst_bytes(&self) -> usize {
        let rows = self.dims.seq_len.saturating_sub(1);
        2 * self.dec.len() * self.kv_pool.pages_for(rows) * self.kv_pool.page_bytes()
    }

    /// Bytes the next step must allocate: one page per K/V table whose
    /// cursor row crosses into unbacked territory (0 mid-page).
    fn slot_next_step_bytes(&self, slot: &SeqSlot) -> usize {
        if slot.complete() {
            return 0;
        }
        let i = slot.len;
        let tables = slot
            .self_k
            .iter()
            .chain(slot.self_v.iter())
            .filter(|rows| rows.needs_page_for(i))
            .count();
        tables * self.kv_pool.page_bytes()
    }

    fn release_slot(&self, slot: &mut SeqSlot) {
        slot.release_pages();
    }
}

impl NativeBackend {
    /// [`DecodePolicy::Replay`]: the AOT graph's loop — the decoder
    /// re-runs over the whole fixed-length buffer each step, rescanning
    /// it for EOS. Kept verbatim as the reference the cached path is
    /// pinned against.
    fn translate_replay(&self, src_tokens: &[i32]) -> Result<Vec<i32>> {
        let b = self.rows_of(src_tokens)?;
        let s = self.dims.seq_len;
        let (memory, src_ok) = self.encode(src_tokens, b)?;
        let cross = self.cross_kv(&memory);
        let mut buf = vec![self.dims.pad_id; b * s];
        for r in 0..b {
            buf[r * s] = self.dims.bos_id;
        }
        for i in 0..s - 1 {
            let hidden = self.decode_hidden(&buf, &cross, &src_ok, b)?;
            for r in 0..b {
                let done = buf[r * s..(r + 1) * s].iter().any(|&t| t == self.dims.eos_id);
                let next = if done {
                    self.dims.pad_id
                } else {
                    let logits = self.tgt_emb.matvec(hidden.row(r * s + i));
                    argmax(&logits) as i32
                };
                buf[r * s + i + 1] = next;
            }
        }
        Ok(buf)
    }

    /// [`DecodePolicy::Cached`]: KV-cached incremental decode over
    /// per-sequence [`SeqSlot`]s — one batched encoder pass (bit-identical
    /// per row to encoding each row alone), one slot per batch row, then
    /// [`Self::step_slots`] over whichever slots are still live until all
    /// lifecycles complete. Retiring a finished slot from the step batch
    /// is exact: a finished slot only ever appends PAD, and the buffer is
    /// PAD-initialized. This is the same admit → step → retire lifecycle
    /// the continuous batcher drives — `translate` is simply the variant
    /// where every sequence is admitted at step 0.
    fn translate_cached(&self, src_tokens: &[i32]) -> Result<Vec<i32>> {
        if self.dims.bos_id == self.dims.pad_id {
            // With BOS aliased to PAD every self-attention key is masked
            // at step 0, and the replay reference then degrades to
            // *uniform* attention over the whole fixed buffer — a
            // convention only the full-buffer loop reproduces.
            return self.translate_replay(src_tokens);
        }
        let b = self.rows_of(src_tokens)?;
        let s = self.dims.seq_len;
        let (memory, src_ok) = self.encode(src_tokens, b)?;
        let cross = self.cross_kv(&memory);
        let mut state = DecodeState::new();
        for r in 0..b {
            // Splice row r's share out of the batched encoder products:
            // the same `[s x D]` cross K/V and PAD mask `admit_slot`
            // computes for a lone request.
            let row_cross: Vec<(Matrix, Matrix)> = cross
                .iter()
                .map(|(ck, cv)| (row_block(ck, r * s, s), row_block(cv, r * s, s)))
                .collect();
            state.push(self.slot_from_parts(row_cross, src_ok[r * s..(r + 1) * s].to_vec()));
        }
        while !state.all_complete() {
            let mut live: Vec<&mut SeqSlot> =
                state.slots.iter_mut().filter(|sl| !sl.complete()).collect();
            self.step_slots(&mut live)?;
        }
        let mut buf = vec![self.dims.pad_id; b * s];
        for (r, slot) in state.slots().iter().enumerate() {
            buf[r * s..(r + 1) * s].copy_from_slice(slot.buffer());
        }
        Ok(buf)
    }
}

/// Copy `rows` rows of `m` starting at row `r0` into a fresh matrix
/// (a batch row's private share of a batched `[b*s x D]` product).
fn row_block(m: &Matrix, r0: usize, rows: usize) -> Matrix {
    let d = m.cols();
    Matrix::from_vec(rows, d, m.data()[r0 * d..(r0 + rows) * d].to_vec())
}

/// Row-wise layer norm (eps 1e-5, population variance) with gain/bias.
fn layer_norm(x: &Matrix, ln: &LnParams) -> Matrix {
    let d = x.cols();
    let mut out = Matrix::zeros(x.rows(), d);
    for i in 0..x.rows() {
        let row = x.row(i);
        let mu = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        let orow = out.row_mut(i);
        for (c, o) in orow.iter_mut().enumerate() {
            *o = (row[c] - mu) * inv * ln.g[c] + ln.b[c];
        }
    }
    out
}

/// Numerically stable softmax; `-1e9`-masked entries underflow to 0.
fn softmax_in_place(xs: &mut [f32]) {
    let mx = xs.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - mx).exp();
        sum += *x;
    }
    if sum > 0.0 {
        for x in xs.iter_mut() {
            *x /= sum;
        }
    }
}

/// First index of the maximum (ties break low, like `jnp.argmax`).
fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_masks_to_zero() {
        let mut xs = vec![1.0, 2.0, 1.0 + NEG, 0.5];
        softmax_in_place(&mut xs);
        assert_eq!(xs[2], 0.0, "masked entry must underflow to exactly 0");
        let sum: f32 = xs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(xs[1] > xs[0] && xs[0] > xs[3]);
    }

    #[test]
    fn softmax_all_masked_is_uniform() {
        // A fully padded key row degrades to uniform attention, exactly
        // like jnp.softmax over an all -1e9 score row.
        let mut xs = vec![NEG; 4];
        softmax_in_place(&mut xs);
        for &x in &xs {
            assert!((x - 0.25).abs() < 1e-6);
        }
    }

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
        assert_eq!(argmax(&[2.0, 1.0]), 0);
    }

    /// A hand-built slot (no model needed): 2 decoder layers, seq 5, D 4,
    /// drawing KV pages from `pool`.
    fn test_slot(s: usize, d: usize, pool: &Arc<KvPool>) -> SeqSlot {
        assert_eq!(pool.width(), d, "pool geometry matches the slot");
        SeqSlot {
            self_k: (0..2).map(|_| PagedRows::new(pool)).collect(),
            self_v: (0..2).map(|_| PagedRows::new(pool)).collect(),
            cross: (0..2).map(|_| (Matrix::zeros(s, d), Matrix::zeros(s, d))).collect(),
            src_ok: vec![true; s],
            tgt_ok: vec![false; s],
            buf: vec![0; s],
            done: false,
            len: 0,
        }
    }

    #[test]
    fn seq_slot_lifecycle_bookkeeping() {
        let pool = Arc::new(KvPool::unbounded(5, 4));
        let mut slot = test_slot(5, 4, &pool);
        assert!(slot.is_empty());
        assert_eq!(slot.len(), 0);
        assert!(!slot.is_done() && !slot.complete());
        assert_eq!(slot.self_k.len(), 2);
        assert_eq!(slot.resident_bytes(), 0, "pages are lazy: a fresh slot holds none");
        assert_eq!(slot.buffer().len(), 5);
        // Each slot ages independently of any batch it shares a step with.
        slot.len = 3;
        assert!(!slot.complete(), "positions remain in the buffer");
        slot.len = 4;
        assert!(slot.complete(), "len + 1 == seq_len: buffer full");
        let mut eos = test_slot(5, 4, &pool);
        eos.done = true;
        assert!(eos.complete(), "EOS retires a slot regardless of age");
    }

    #[test]
    fn slot_pages_account_and_release_at_retirement() {
        let pool = Arc::new(KvPool::new(2, 4, Some(64 * 1024)));
        let mut slot = test_slot(5, 4, &pool);
        // Back rows 0..3 across all four tables (2 layers x K/V), the way
        // step_slots' page-ensure pre-pass does.
        for i in 0..3 {
            for t in slot.self_k.iter_mut().chain(slot.self_v.iter_mut()) {
                assert!(t.ensure_row(i));
            }
        }
        assert_eq!(slot.resident_pages(), 4 * 2, "rows 0..3 need 2 pages per table");
        assert_eq!(slot.resident_bytes(), pool.resident_bytes(), "slot view == pool view");
        slot.release_pages();
        assert_eq!(slot.resident_pages(), 0);
        assert_eq!(pool.outstanding_pages(), 0, "retirement returns every page");
    }

    #[test]
    fn decode_state_tracks_slot_completion() {
        let mut st = DecodeState::new();
        assert!(st.is_empty());
        assert!(st.all_complete(), "no slots: vacuously complete");
        let pool = Arc::new(KvPool::unbounded(5, 4));
        for _ in 0..3 {
            st.push(test_slot(5, 4, &pool));
        }
        assert_eq!(st.len(), 3);
        assert!(!st.all_complete());
        st.slots[0].done = true;
        st.slots[2].done = true;
        assert!(!st.all_complete(), "one slot still live");
        st.slots[1].len = 4;
        assert!(st.all_complete(), "EOS or a full buffer both complete a lifecycle");
        assert_eq!(st.slots().len(), 3);
    }

    #[test]
    fn layer_norm_normalizes_rows() {
        let x = Matrix::from_vec(2, 4, vec![1.0, 2.0, 3.0, 4.0, -5.0, 0.0, 5.0, 10.0]);
        let ln = LnParams { g: vec![1.0; 4], b: vec![0.0; 4] };
        let y = layer_norm(&x, &ln);
        for i in 0..2 {
            let row = y.row(i);
            let mu: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / 4.0;
            assert!(mu.abs() < 1e-5, "row {i} mean {mu}");
            assert!((var - 1.0).abs() < 1e-3, "row {i} var {var}");
        }
        // Gain/bias apply after normalization.
        let ln2 = LnParams { g: vec![2.0; 4], b: vec![1.0; 4] };
        let y2 = layer_norm(&x, &ln2);
        for (a, b) in y.data().iter().zip(y2.data()) {
            assert!((a * 2.0 + 1.0 - b).abs() < 1e-5);
        }
    }
}
