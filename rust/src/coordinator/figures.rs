//! Figure runners: one function per paper figure, regenerating the same
//! rows/series the paper reports (DESIGN.md experiment index).
//!
//! Absolute numbers come from our substituted substrate (tiny OPUS-MT-like
//! models on synthetic pairs; ZCU111 analytical models) — the *shape* of
//! each result (who wins, crossovers, trends) is the reproduction target.

use anyhow::Result;

use crate::dse::{self, pareto_front, LayerWork};
use crate::hw::{sim, EngineKind, Platform, Workload};
use crate::sra;
use crate::util::timed;

use super::report::{cycles, f1, f2, Table};
use super::{Coordinator, CompressedModel, Method};

/// A compression design point measured on the test set.
#[derive(Debug, Clone)]
pub struct MeasuredPoint {
    pub label: String,
    pub method: Method,
    pub bleu: f64,
    pub ratio: f64,
    pub nops: u64,
    pub ranks: Vec<usize>,
}

impl Coordinator {
    /// Measure one method end-to-end on `pair` (test-set BLEU + costs).
    pub fn measure(&self, pair: &str, method: &Method) -> Result<MeasuredPoint> {
        let cm = self.compress(pair, method);
        let bleu = self.bleu_test(pair, &cm)?;
        let (ratio, nops) = cm.cost(&self.manifest, self.cfg.nops_batch);
        Ok(MeasuredPoint {
            label: method.label(),
            method: method.clone(),
            bleu,
            ratio,
            nops,
            ranks: cm.ranks(&self.manifest),
        })
    }

    /// SRA search on the calibration set; returns the allocation and its
    /// calibration BLEU.
    pub fn sra_search(&self, pair: &str, wl: u32, budget: usize) -> (Vec<usize>, f64) {
        let caps = self.manifest.rank_caps();
        let mut oracle = |ranks: &[usize]| {
            let method = Method::SvdIterRanks { wl, ranks: ranks.to_vec() };
            let cm = self.compress(pair, &method);
            self.bleu_calib(pair, &cm).unwrap_or(0.0)
        };
        let res = sra::run(&mut oracle, budget, &caps, &self.cfg.sra);
        (res.ranks, res.accuracy)
    }
}

// ------------------------------------------------------------------
// Fig. 1 — PTQ degradation: BLEU vs precision (quant-only).
// ------------------------------------------------------------------
pub fn fig1(c: &Coordinator, pair: &str) -> Result<Table> {
    let mut t = Table::new(
        &format!("Fig.1: post-training quantization, {pair} (BLEU vs precision)"),
        &["precision", "bleu", "delta_vs_fp32"],
    );
    let fp32 = c.bleu_fp32(pair)?;
    t.row(vec!["FP32".into(), f2(fp32), f2(0.0)]);
    // NOTE scale shift vs the paper: the tiny substituted model has far
    // fewer weight outliers than OPUS-MT, so its PTQ knee sits one to two
    // bits lower (W3 instead of W4). We sweep down to W2 so the figure
    // shows the same degradation shape (see EXPERIMENTS.md).
    for wl in [8u32, 6, 5, 4, 3, 2] {
        let p = c.measure(pair, &Method::QuantOnly { wl })?;
        t.row(vec![format!("W{wl}A8"), f2(p.bleu), f2(p.bleu - fp32)]);
    }
    Ok(t)
}

// ------------------------------------------------------------------
// Fig. 4 — per-layer sensitivity to rank truncation.
// ------------------------------------------------------------------
pub fn fig4(c: &Coordinator, pair: &str, layer_names: &[&str]) -> Result<Table> {
    let mut t = Table::new(
        &format!("Fig.4: layer sensitivity, {pair} (BLEU drop vs % rank retained)"),
        &["layer", "rank3%", "rank6%", "rank12%", "rank25%", "rank50%"],
    );
    let fp32 = c.bleu_fp32(pair)?;
    for name in layer_names {
        let idx = c
            .manifest
            .linear_index(name)
            .ok_or_else(|| anyhow::anyhow!("unknown layer {name}"))?;
        let r_max = c.manifest.linears[idx].r_max;
        let mut cells = vec![name.to_string()];
        for frac in [0.03, 0.06, 0.12, 0.25, 0.5] {
            let rank = ((r_max as f64 * frac).round() as usize).max(1);
            // Truncate ONLY this layer (FP32 elsewhere, FP32 activations),
            // exactly the paper's per-layer probe.
            let mut layers = std::collections::BTreeMap::new();
            layers.insert(
                name.to_string(),
                crate::compress::svd_baseline(c.model(pair).linear(name), rank, 16),
            );
            let cm = CompressedModel {
                method: Method::SvdBaseline { wl: 16, rank_frac: frac },
                layers: fill_identity(c, pair, layers),
                act_wl: None,
            };
            let bleu = c.bleu_on_test_dense(pair, &cm)?;
            cells.push(f2(bleu - fp32));
        }
        t.row(cells);
    }
    Ok(t)
}

/// Fig. 4 probes run on the dense artifact: every *other* layer keeps its
/// FP32 weights (identity compression).
fn fill_identity(
    c: &Coordinator,
    pair: &str,
    mut layers: std::collections::BTreeMap<String, crate::compress::CompressedLinear>,
) -> std::collections::BTreeMap<String, crate::compress::CompressedLinear> {
    for l in &c.manifest.linears {
        layers.entry(l.name.clone()).or_insert_with(|| {
            crate::compress::CompressedLinear::Dense {
                w: c.model(pair).linear(&l.name).clone(),
                wl: 16,
                // FP-identity probe: the weights bypass quantization, so
                // there is no grid and nothing to dequantize (or pack).
                scales: Vec::new(),
            }
        });
    }
    layers
}

impl Coordinator {
    /// Test-set BLEU through the dense artifact regardless of method tag
    /// (used by the Fig. 4 single-layer probes).
    fn bleu_on_test_dense(&self, pair: &str, cm: &CompressedModel) -> Result<f64> {
        use crate::eval::evaluate_bleu;
        use crate::runtime::{Mode, PjrtBackend, TranslateSession};
        let session = TranslateSession::new(&self.engine, &self.manifest, Mode::Dense)?;
        let bank = session.build_bank(self.model(pair), &cm.layers, cm.act_wl)?;
        let backend = PjrtBackend::new(session, bank);
        let corpus = crate::eval::Corpus::load(&self.manifest.pairs[pair].corpus)?;
        let d = evaluate_bleu(&backend, &corpus, &self.manifest.model, self.cfg.calib_sentences)?;
        Ok(d.score)
    }
}

// ------------------------------------------------------------------
// Figs. 7 + 8 — accuracy/compression and accuracy/NOps Pareto fronts.
// ------------------------------------------------------------------

/// Shared sweep for Figs. 7/8: measure every method over its grid.
pub fn compression_sweep(
    c: &Coordinator,
    pair: &str,
    with_sra: bool,
) -> Result<Vec<MeasuredPoint>> {
    // Word lengths one bit below the paper's (W3/W4 here play the role of
    // W4/W6 there): the tiny substituted model's PTQ knee sits lower, see
    // EXPERIMENTS.md §Scale-shift.
    let mut pts = Vec::new();
    for wl in [2u32, 3, 4, 6, 8] {
        pts.push(c.measure(pair, &Method::QuantOnly { wl })?);
    }
    for wl in [3u32, 4, 6] {
        for frac in [0.25, 0.4, 0.55, 0.75] {
            pts.push(c.measure(pair, &Method::SvdBaseline { wl, rank_frac: frac })?);
            pts.push(c.measure(pair, &Method::SvdIter { wl, rank_frac: frac })?);
        }
    }
    if with_sra {
        let caps = c.manifest.rank_caps();
        let total: usize = caps.iter().sum();
        for wl in [3u32, 4] {
            for budget_frac in [0.4, 0.55] {
                let budget = (total as f64 * budget_frac) as usize;
                let (ranks, _) = c.sra_search(pair, wl, budget);
                pts.push(c.measure(pair, &Method::SvdIterRanks { wl, ranks })?);
            }
        }
    }
    Ok(pts)
}

pub fn fig7(c: &Coordinator, pair: &str, pts: &[MeasuredPoint]) -> Table {
    let mut t = Table::new(
        &format!("Fig.7: BLEU vs compression ratio, {pair} (region of interest: ratio > 4)"),
        &["method", "ratio", "bleu", "pareto"],
    );
    let coords: Vec<(f64, f64)> = pts.iter().map(|p| (1.0 / p.ratio, p.bleu)).collect();
    let front = pareto_front(&coords);
    for (i, p) in pts.iter().enumerate() {
        t.row(vec![
            p.label.clone(),
            f2(p.ratio),
            f2(p.bleu),
            if front.contains(&i) { "*".into() } else { "".into() },
        ]);
    }
    t
}

pub fn fig8(c: &Coordinator, pair: &str, pts: &[MeasuredPoint]) -> Table {
    let _ = c;
    let mut t = Table::new(
        &format!("Fig.8: BLEU vs number of operations, {pair} (batch 512)"),
        &["method", "gmacs", "bleu", "pareto"],
    );
    let coords: Vec<(f64, f64)> = pts.iter().map(|p| (p.nops as f64, p.bleu)).collect();
    let front = pareto_front(&coords);
    for (i, p) in pts.iter().enumerate() {
        t.row(vec![
            p.label.clone(),
            f2(p.nops as f64 / 1e9),
            f2(p.bleu),
            if front.contains(&i) { "*".into() } else { "".into() },
        ]);
    }
    t
}

// ------------------------------------------------------------------
// Fig. 9 — generality across language pairs (bar plot rows).
// ------------------------------------------------------------------
pub fn fig9(c: &Coordinator) -> Result<Table> {
    let mut t = Table::new(
        "Fig.9: BLEU vs compression ratio across language pairs (W3/W4 A8)",
        &["pair", "ratio", "quant", "svd_iter", "svd_iter_sra"],
    );
    for pair in c.pairs() {
        for target_ratio in [10.0f64] {
            let q = c.measure(&pair, &Method::QuantOnly { wl: 3 })?;
            // rank fraction hitting the target weight-bits ratio at W4:
            // ratio = 32*K*N / (wl * r * (K+N)); for square-ish layers
            // frac ≈ 32 / (wl * ratio) * (K*N)/(r_max*(K+N)).
            let frac = ratio_to_frac(c, 4, target_ratio);
            let it = c.measure(&pair, &Method::SvdIter { wl: 4, rank_frac: frac })?;
            let caps = c.manifest.rank_caps();
            let total: usize = caps.iter().sum();
            let budget = ((total as f64 * frac) as usize).max(caps.len());
            let (ranks, _) = c.sra_search(&pair, 4, budget);
            let sra_pt = c.measure(&pair, &Method::SvdIterRanks { wl: 4, ranks })?;
            t.row(vec![
                pair.clone(),
                f1(target_ratio),
                f2(q.bleu),
                f2(it.bleu),
                f2(sra_pt.bleu),
            ]);
        }
    }
    Ok(t)
}

/// Uniform rank fraction whose model compression ratio approximates
/// `target_ratio` at word length `wl`.
pub fn ratio_to_frac(c: &Coordinator, wl: u32, target_ratio: f64) -> f64 {
    // Solve on aggregate layer dims.
    let mut fp32_bits = 0f64;
    let mut per_rank_bits = 0f64;
    let mut total_rmax = 0f64;
    for l in &c.manifest.linears {
        fp32_bits += (l.k * l.n * 32) as f64;
        per_rank_bits += (wl as usize * (l.k + l.n)) as f64 * l.r_max as f64;
        total_rmax += l.r_max as f64;
    }
    let _ = total_rmax;
    (fp32_bits / (target_ratio * per_rank_bits)).clamp(0.02, 1.0)
}

// ------------------------------------------------------------------
// Fig. 10 — engine latency vs bandwidth requirement Pareto (512^3).
// ------------------------------------------------------------------
pub fn fig10(platform: &Platform) -> Table {
    let w = Workload::new(512, 512, 512, 4, 8);
    let rank = 128;
    let mut t = Table::new(
        "Fig.10: MatMul engine latency vs off-chip bandwidth (512^3, W4A8, rank 128, ZCU111)",
        &["engine", "tile", "bw_bits_per_cycle", "latency_cycles", "pareto"],
    );
    for kind in [EngineKind::Baseline, EngineKind::SingleSvd, EngineKind::CascadeSvd] {
        let pts = dse::sweep_engines(&w, Some(rank), platform, &[kind]);
        let pts = if pts.is_empty() && kind == EngineKind::Baseline {
            dse::sweep_engines(&w, None, platform, &[kind])
        } else {
            pts
        };
        let coords: Vec<(f64, f64)> = pts
            .iter()
            .map(|p| (p.design.bandwidth_req, -p.design.latency_cycles))
            .collect();
        let front = pareto_front(&coords);
        for &i in &front {
            let d = &pts[i].design;
            let tile = match d.tile2 {
                Some(t2) => format!(
                    "Mt{} Rt{} Nt{} Kf{}",
                    d.tile1.mt, d.tile1.nt, t2.nt, d.tile1.kf
                ),
                None => format!("Mt{} Nt{} Kf{}", d.tile1.mt, d.tile1.nt, d.tile1.kf),
            };
            t.row(vec![
                kind.to_string(),
                tile,
                f1(d.bandwidth_req),
                cycles(d.latency_cycles),
                "*".into(),
            ]);
        }
    }
    t
}

// ------------------------------------------------------------------
// Fig. 11 — accuracy vs latency co-design under two bandwidth budgets.
// ------------------------------------------------------------------

/// One co-designed point: a compression config mapped to its best
/// hardware under the platform.
#[derive(Debug, Clone)]
pub struct CodesignPoint {
    pub label: String,
    pub bleu: f64,
    pub total_latency_cycles: f64,
    pub latency_us: f64,
    pub picks: Vec<dse::DesignPoint>,
    pub ranks: Vec<usize>,
}

/// Map a measured compression point onto the best hardware configuration
/// for `platform` (per-layer best engine, paper §VIII-E).
pub fn codesign(
    c: &Coordinator,
    p: &MeasuredPoint,
    platform: &Platform,
) -> CodesignPoint {
    let wl = p.method.word_len();
    let dense = matches!(p.method, Method::QuantOnly { .. });
    let layers: Vec<LayerWork> = c
        .manifest
        .linears
        .iter()
        .zip(&p.ranks)
        .map(|(l, &r)| LayerWork {
            workload: Workload::new(c.cfg.nops_batch, l.k, l.n, wl, 8),
            rank: if dense { None } else { Some(r) },
        })
        .collect();
    let (total, picks) =
        dse::best_design_for_model(&layers, platform, c.cfg.workers).expect("feasible design");
    CodesignPoint {
        label: p.label.clone(),
        bleu: p.bleu,
        total_latency_cycles: total,
        latency_us: platform.cycles_to_us(total),
        picks,
        ranks: p.ranks.clone(),
    }
}

pub fn fig11(
    c: &Coordinator,
    pts: &[MeasuredPoint],
    platform: &Platform,
) -> (Table, Vec<CodesignPoint>) {
    let mut t = Table::new(
        &format!(
            "Fig.11: BLEU vs total linear-layer latency on {} (batch {})",
            platform.name, c.cfg.nops_batch
        ),
        &["method", "bleu", "latency_us", "latency_cycles", "pareto"],
    );
    let cds: Vec<CodesignPoint> = pts.iter().map(|p| codesign(c, p, platform)).collect();
    let coords: Vec<(f64, f64)> =
        cds.iter().map(|d| (d.total_latency_cycles, d.bleu)).collect();
    let front = pareto_front(&coords);
    for (i, d) in cds.iter().enumerate() {
        t.row(vec![
            d.label.clone(),
            f2(d.bleu),
            f1(d.latency_us),
            cycles(d.total_latency_cycles),
            if front.contains(&i) { "*".into() } else { "".into() },
        ]);
    }
    (t, cds)
}

// ------------------------------------------------------------------
// Fig. 12 — per-layer tile occupancy of selected design points.
// ------------------------------------------------------------------
pub fn fig12(
    c: &Coordinator,
    selected: &[(&str, &CodesignPoint)],
    platform: &Platform,
) -> Table {
    let mut t = Table::new(
        &format!("Fig.12: per-layer MatMul tile occupancy ({})", platform.name),
        &["design", "layer", "engine", "occupancy_pct"],
    );
    for (tag, cd) in selected {
        for (l, (pick, &rank)) in
            c.manifest.linears.iter().zip(cd.picks.iter().zip(&cd.ranks))
        {
            let w = Workload::new(
                c.cfg.nops_batch,
                l.k,
                l.n,
                pick.design_w_bits(),
                8,
            );
            let occ = match pick.design.kind {
                EngineKind::Baseline => {
                    sim::simulate_matmul(&w, &pick.design.tile1, platform.bandwidth_bits_per_cycle)
                        .occupancy
                }
                EngineKind::SingleSvd => sim::simulate_single_svd(
                    &w,
                    rank,
                    &pick.design.tile1,
                    platform.bandwidth_bits_per_cycle,
                )
                .occupancy,
                EngineKind::CascadeSvd => sim::simulate_cascade_svd(
                    &w,
                    rank,
                    &pick.design.tile1,
                    &pick.design.tile2.unwrap_or(pick.design.tile1),
                    platform.bandwidth_bits_per_cycle,
                )
                .occupancy,
            };
            t.row(vec![
                tag.to_string(),
                l.name.clone(),
                pick.design.kind.to_string(),
                f1(occ * 100.0),
            ]);
        }
    }
    t
}

impl dse::DesignPoint {
    /// Weight word length is not stored on the design; Fig. 12 re-derives
    /// the workload with W4 (all selected designs are W4/W6 — occupancy is
    /// insensitive to the word length at fixed tile).
    fn design_w_bits(&self) -> u32 {
        4
    }
}

/// Convenience: run the headline comparison (best SRA vs best quant at
/// comparable BLEU) and report the latency reduction the paper headlines
/// (12.1%–41.1%).
pub fn headline_latency_reduction(
    quant: &CodesignPoint,
    sra_pt: &CodesignPoint,
) -> f64 {
    1.0 - sra_pt.total_latency_cycles / quant.total_latency_cycles
}

/// Time a full figure run (used by the bench harness).
pub fn timed_table(f: impl FnOnce() -> Result<Table>) -> Result<(Table, f64)> {
    let (r, dt) = timed(f);
    Ok((r?, dt))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_is_static_and_nonempty() {
        let t = fig10(&Platform::zcu111());
        assert!(!t.is_empty());
        let s = t.render();
        assert!(s.contains("Baseline"));
        assert!(s.contains("SingleSVD"));
        assert!(s.contains("CascadeSVD"));
    }

    #[test]
    fn ratio_frac_monotone() {
        // Static helper check without artifacts: construct via manifest if
        // available, else skip.
        if !crate::model::Manifest::default_dir().join("manifest.json").exists() {
            return;
        }
        let c = Coordinator::new(crate::config::ExpConfig::fast()).unwrap();
        let f8 = ratio_to_frac(&c, 4, 8.0);
        let f16 = ratio_to_frac(&c, 4, 16.0);
        assert!(f16 < f8);
    }
}
