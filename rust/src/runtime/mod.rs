//! Model execution runtimes: the request-path boundary of the (now
//! four-layer) architecture.
//!
//! Two interchangeable backends implement [`TranslateBackend`], the
//! greedy-translation contract everything downstream (BLEU evaluation,
//! the serving batcher, the CLI, the e2e suites) is written against:
//!
//! * **[`native`]** — a dependency-free pure-Rust transformer engine that
//!   executes the encoder–decoder forward pass (embeddings + positional
//!   encoding, multi-head attention, layer-norm, FFN, greedy decode)
//!   directly on [`crate::tensor::Matrix`], consuming the manifest +
//!   weight store + compressed layer banks. It is compiled in **every**
//!   build, so the default `cargo build` can run a model end-to-end. All
//!   three execution modes are supported natively: the dense path
//!   multiplies the full `[K x N]` (fake-quantized) weights; the factored
//!   path runs each compressed linear as two skinny matmuls
//!   `[M x K]·[K x r]` then `[M x r]·[r x N]` at the layer's *actual*
//!   rank — realizing the paper's FLOP savings at inference time instead
//!   of padding up to `r_max` like the AOT artifact must; the quantized
//!   path keeps every linear bit-packed (`crate::qkernel`) and runs the
//!   integer GEMM, realizing the paper's sub-8-bit memory footprint
//!   bit-exactly against the fake-quant reference. Greedy decode runs
//!   under a [`DecodePolicy`]: KV-cached single-token steps by default,
//!   or the AOT graph's full-buffer replay as the bit-identical
//!   reference.
//! * **PJRT** (`pjrt` feature) — loads AOT-compiled HLO text (the Python
//!   compile path ran once at build time), compiles through the PJRT C API
//!   (`xla` crate over xla_extension 0.5.1, CPU plugin) and executes the
//!   Pallas-kernel-lowered graphs. HLO **text** is the interchange format —
//!   `HloModuleProto::from_text_file` reassigns instruction ids,
//!   sidestepping the 64-bit-id protos jax>=0.5 emits that this XLA build
//!   rejects. Weight arguments are uploaded to device buffers once per
//!   compression configuration ([`ArgBank`]); each translate call swaps
//!   only the source-token buffer. [`PjrtBackend`] bundles a compiled
//!   session with its resident bank to satisfy the trait.
//!
//! [`Mode`] is plain metadata shared with the (always-built)
//! compression/coordinator method plumbing, so it lives here
//! unconditionally.

#[cfg(feature = "pjrt")]
mod engine;
pub mod kvpool;
pub mod native;
#[cfg(feature = "pjrt")]
mod session;

#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use kvpool::{KvMemStats, KvPool, PagedRows, RowRead};
pub use native::{NativeBackend, SeqSlot};
#[cfg(feature = "pjrt")]
pub use session::{ArgBank, PjrtBackend, TranslateSession};

/// Which model execution variant to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// `translate_dense.hlo.txt`: each compressed linear is a `[K x N]`
    /// argument (FP32 reference and quantization-only baseline).
    Dense,
    /// `translate_svd.hlo.txt`: each compressed linear is a rank-padded
    /// `[K x r_max]`, `[r_max x N]` factor pair (the native backend skips
    /// the padding and runs the true-rank factors).
    Svd,
    /// Native-only third mode: every compressed linear lives **bit-packed**
    /// (`qkernel::QMatrix` — 2..=8-bit integers + per-vector scales) and
    /// executes through the integer GEMM, in whatever structure the
    /// compression produced (packed dense for quant-only layers, packed
    /// factor cascades for the SVD family). Bit-identical to the
    /// fake-quant f32 paths above while holding up to 16x fewer weight
    /// bytes resident. There is no AOT artifact for this mode.
    Quantized,
}

impl Mode {
    pub fn key(self) -> &'static str {
        match self {
            Mode::Dense => "dense",
            Mode::Svd => "svd",
            Mode::Quantized => "quantized",
        }
    }

    /// Parse a CLI `--mode` value.
    pub fn parse(s: &str) -> Option<Mode> {
        match s {
            "dense" => Some(Mode::Dense),
            "svd" => Some(Mode::Svd),
            "quantized" => Some(Mode::Quantized),
            _ => None,
        }
    }
}

/// How the native engine's greedy decode loop executes.
///
/// Both policies are **bit-identical** in output (pinned by
/// `tests/e2e_native.rs` and the decode proptest); they differ only in
/// how much work each of the `seq_len - 1` greedy steps performs:
///
/// * [`Replay`](DecodePolicy::Replay) — the AOT graph's loop: every step
///   re-runs the full decoder stack over the entire fixed-length buffer,
///   so decoder linear MACs grow as O(s²) and self-attention as O(s³)
///   per translate. Kept as the reference the cached path is verified
///   against.
/// * [`Cached`](DecodePolicy::Cached) — KV-cached incremental decode
///   (the default): every sequence owns a private `SeqSlot` (per-layer
///   self-attention K/V slabs, its encoder memory's cross K/V, token
///   buffer and step counter), and every step embeds one position per
///   live slot, runs the decoder blocks on a `[b x D]` activation
///   through single-row kernels, and appends each slot's new K/V row —
///   decoder linear MACs drop by a factor of `seq_len` (see
///   `NativeBackend::linear_macs_for`). Slots are independent, so the
///   same step kernel serves both a fixed `translate` batch and the
///   continuous batcher's mixed-age batches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DecodePolicy {
    /// Full-buffer replay each step (the AOT graph's reference loop).
    Replay,
    /// Single-token steps over per-layer K/V caches (the default).
    #[default]
    Cached,
}

impl DecodePolicy {
    pub fn key(self) -> &'static str {
        match self {
            DecodePolicy::Replay => "replay",
            DecodePolicy::Cached => "cached",
        }
    }

    /// Parse a CLI `--decode` value.
    pub fn parse(s: &str) -> Option<DecodePolicy> {
        match s {
            "replay" => Some(DecodePolicy::Replay),
            "cached" => Some(DecodePolicy::Cached),
            _ => None,
        }
    }
}

/// Which numerical tier the native engine's per-row decode kernels run
/// on under `Mode::Quantized`.
///
/// The tiers trade bit-exactness for integer arithmetic:
///
/// * [`Exact`](KernelTier::Exact) — the default: packed linears execute
///   via the dequantizing `PackedLinear::matvec`, bit-identical to the
///   fake-quant f32 reference (the property every replay/cached/batched
///   parity suite pins).
/// * [`Fast`](KernelTier::Fast) — the paper's integer engines: each
///   step activation is quantized onto the A8 grid *at runtime*
///   (`quant::try_quantize_vec_parts`) and every packed linear runs as
///   int8×int-grid GEMV with i32 accumulation and one rescale per
///   output (`PackedLinear::matvec_fast`); factored layers requantize
///   once between the two skinny matvecs. **Not bit-identical** — the
///   runtime requantization perturbs activations by up to half an A8
///   step — so the tier is fenced by `validate --kernel fast`'s parity
///   table (max |Δlogit| + BLEU delta on the tiny model) instead of the
///   bit-parity suites. Dense/Svd modes have no packed linears; the
///   tier is a no-op there.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelTier {
    /// Dequantize-then-f32 per-row kernels (bit-exact reference).
    #[default]
    Exact,
    /// Runtime A8 activation quantization + pure-integer GEMV.
    Fast,
}

impl KernelTier {
    pub fn key(self) -> &'static str {
        match self {
            KernelTier::Exact => "exact",
            KernelTier::Fast => "fast",
        }
    }

    /// Parse a CLI `--kernel` value.
    pub fn parse(s: &str) -> Option<KernelTier> {
        match s {
            "exact" => Some(KernelTier::Exact),
            "fast" => Some(KernelTier::Fast),
            _ => None,
        }
    }
}

/// A model execution backend that can greedy-translate token batches.
///
/// `src_tokens` is a row-major `[rows * seq_len()]` buffer of BOS-framed,
/// EOS-terminated, PAD-padded source rows; the returned buffer has the
/// same layout for the hypotheses. `batch()` is the backend's preferred
/// batch size (fixed for the AOT artifacts; a packing hint for the native
/// engine). Implementations must be deterministic: the same tokens and
/// the same weights produce bit-identical output on every call.
pub trait TranslateBackend {
    /// Short backend tag for logs/reports ("native", "pjrt").
    fn kind(&self) -> &'static str;

    /// Preferred (for PJRT: required) number of rows per translate call.
    fn batch(&self) -> usize;

    /// Fixed sequence length of every token row.
    fn seq_len(&self) -> usize;

    /// Whether `translate` requires exactly `batch() * seq_len()` tokens
    /// (the AOT artifacts' compiled shape). Variable-shape backends (the
    /// native engine) return `false`, letting callers pack only the rows
    /// they actually have instead of padding to full batch capacity.
    fn fixed_shape(&self) -> bool {
        true
    }

    /// Greedy-translate one batch of `batch() * seq_len()` source tokens
    /// (or any positive multiple of `seq_len()` when `fixed_shape()` is
    /// false).
    fn translate(&self, src_tokens: &[i32]) -> anyhow::Result<Vec<i32>>;

    /// Translate many independent single-sequence requests (each one
    /// `seq_len()` framed tokens), returning one output buffer per
    /// request. The default decodes each request alone — the sequential
    /// reference the continuous batcher's bit-parity suite pins against.
    /// Backends with a slot API reach higher throughput by scheduling the
    /// same requests through `coordinator::scheduler::ContinuousBatcher`.
    fn translate_stream(&self, rows: &[Vec<i32>]) -> anyhow::Result<Vec<Vec<i32>>> {
        rows.iter().map(|r| self.translate(r)).collect()
    }
}

/// Slot-addressed decode API: the contract continuous batching is built
/// on. An engine that can **admit** a request into a private KV slot,
/// **step** an arbitrary mixed-age set of live slots by one position,
/// and report when a slot's lifecycle is **complete** can be driven by
/// `coordinator::scheduler::ContinuousBatcher` — between decode steps
/// the batcher retires finished slots, admits queued requests into the
/// freed capacity, and steps whatever is live.
///
/// Implementations must keep slots independent: stepping a slot inside
/// any batch must be bit-identical to stepping it alone (the native
/// engine's per-row kernels guarantee this; see
/// [`native::NativeBackend::step_slots`]). The associated `Slot` type
/// keeps the scheduler generic, so its admission/retirement logic is
/// unit-tested against scripted mock engines with no model at all.
///
/// Failure atomicity: a [`SlotEngine::step`] that returns `Err` (or
/// panics) must leave every slot either unchanged or idempotently
/// re-steppable — after a batched step fails, the batcher attributes
/// the fault by re-stepping each slot individually and retires only the
/// offender with `EngineFault`, so survivors must reproduce the same
/// bits on the retry. The native engine validates before mutating;
/// mocks and fault injectors (`testkit::faultkit`) check their fault
/// scripts before delegating.
pub trait SlotEngine {
    /// Per-sequence decode state owned by the engine.
    type Slot;

    /// Fixed token-buffer length of every slot.
    fn slot_seq_len(&self) -> usize;

    /// Run one request's encoder pass and return a fresh slot positioned
    /// at the BOS step. `src_row` is one `slot_seq_len()`-token framed
    /// source row.
    fn admit(&self, src_row: &[i32]) -> anyhow::Result<Self::Slot>;

    /// Advance every given live slot by one decode step (slots may be of
    /// different ages). An empty set is a no-op.
    fn step(&self, slots: &mut [&mut Self::Slot]) -> anyhow::Result<()>;

    /// Whether the slot's lifecycle is over (EOS emitted or buffer full)
    /// and it can be retired/reused.
    fn slot_complete(&self, slot: &Self::Slot) -> bool;

    /// The slot's `slot_seq_len()`-token output buffer.
    fn slot_output(&self, slot: &Self::Slot) -> Vec<i32>;

    /// KV-memory accounting, for engines whose slots draw pages from a
    /// [`kvpool::KvPool`]. `None` (the default) means the engine does
    /// not account KV memory and the scheduler must fall back to pure
    /// slot-count admission — existing mock engines change nothing.
    fn kv_stats(&self) -> Option<KvMemStats> {
        None
    }

    /// Worst-case KV bytes one slot can ever demand (a full-length
    /// decode's page tables). The scheduler's admission gate: a request
    /// whose worst case exceeds the whole budget can never run and is
    /// shed; one that exceeds the currently free bytes waits in the
    /// queue. `0` (the default) disables the gate.
    fn slot_worst_bytes(&self) -> usize {
        0
    }

    /// KV bytes the *next* [`SlotEngine::step`] must newly allocate for
    /// this slot (`0` while the decode cursor stays inside already-backed
    /// pages). The scheduler sums this over the live set to detect
    /// memory pressure *before* stepping, and evicts until the step is
    /// guaranteed to fit.
    fn slot_next_step_bytes(&self, _slot: &Self::Slot) -> usize {
        0
    }

    /// Return the slot's KV pages to the pool. Called by the scheduler
    /// at every slot retirement — completion, expiry, cancellation, and
    /// preemption-by-eviction — so pool accounting is exact at each
    /// scheduling boundary (engines should leak-check here; dropping
    /// the slot must also release, as a safety net).
    fn release_slot(&self, _slot: &mut Self::Slot) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_policy_keys_and_default() {
        assert_eq!(DecodePolicy::default(), DecodePolicy::Cached, "cached is the default");
        for p in [DecodePolicy::Replay, DecodePolicy::Cached] {
            assert_eq!(DecodePolicy::parse(p.key()), Some(p));
        }
        assert_eq!(DecodePolicy::parse("kv"), None);
    }

    #[test]
    fn kernel_tier_keys_and_default() {
        assert_eq!(KernelTier::default(), KernelTier::Exact, "exact is the default");
        for t in [KernelTier::Exact, KernelTier::Fast] {
            assert_eq!(KernelTier::parse(t.key()), Some(t));
        }
        assert_eq!(KernelTier::parse("int8"), None);
    }

    #[test]
    fn mode_keys() {
        assert_eq!(Mode::Dense.key(), "dense");
        assert_eq!(Mode::Svd.key(), "svd");
        assert_eq!(Mode::Quantized.key(), "quantized");
        for m in [Mode::Dense, Mode::Svd, Mode::Quantized] {
            assert_eq!(Mode::parse(m.key()), Some(m));
        }
        assert_eq!(Mode::parse("fp32"), None);
    }
}
