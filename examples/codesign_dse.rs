//! **End-to-end driver**: the full ITERA-LLM co-design pipeline (Fig. 2)
//! on a real trained model — the repo's flagship example.
//!
//! ```bash
//! cargo run --release --example codesign_dse
//! ```
//!
//! 1. Measures a grid of compression configs (quant-only, plain SVD,
//!    Algorithm 1, Algorithm 1 + SRA) on the held-out set via the PJRT
//!    runtime — real BLEU, real compression/NOps accounting.
//! 2. Maps every config onto its best hardware design point under ZCU111
//!    constraints (analytical models + DSE sweep), for both the full and
//!    quarter off-chip bandwidth scenarios of Fig. 11.
//! 3. Prints both accuracy–latency tables, the Pareto markers, and the
//!    headline latency reduction at comparable BLEU.
//!
//! Everything after `make artifacts` is Rust: Python never runs here.

use anyhow::Result;
use itera_llm::config::ExpConfig;
use itera_llm::coordinator::figures::{self, headline_latency_reduction};
use itera_llm::coordinator::{Coordinator, Method};
use itera_llm::hw::Platform;
use itera_llm::util::timed;

fn main() -> Result<()> {
    let c = Coordinator::new(ExpConfig::fast())?;
    let pair = "en-de";

    // ---- 1. Compression grid (with one quick SRA run) ---------------
    println!("[1/3] measuring compression grid on {pair} ...");
    let (pts, dt) = timed(|| -> Result<Vec<_>> {
        let mut pts = vec![
            c.measure(pair, &Method::QuantOnly { wl: 8 })?,
            c.measure(pair, &Method::QuantOnly { wl: 4 })?,
            c.measure(pair, &Method::QuantOnly { wl: 3 })?,
            c.measure(pair, &Method::SvdBaseline { wl: 4, rank_frac: 0.4 })?,
            c.measure(pair, &Method::SvdIter { wl: 4, rank_frac: 0.4 })?,
            c.measure(pair, &Method::SvdIter { wl: 3, rank_frac: 0.55 })?,
        ];
        let caps = c.manifest.rank_caps();
        let budget = caps.iter().sum::<usize>() * 2 / 5;
        let (ranks, _) = c.sra_search(pair, 4, budget);
        pts.push(c.measure(pair, &Method::SvdIterRanks { wl: 4, ranks })?);
        Ok(pts)
    });
    let pts = pts?;
    println!("      {} configs measured in {dt:.0}s", pts.len());

    // ---- 2 + 3. Hardware mapping under both bandwidth budgets -------
    for platform in [Platform::zcu111(), Platform::zcu111_quarter_bw()] {
        println!("\n[2/3] co-design on {} ...", platform.name);
        let (table, cds) = figures::fig11(&c, &pts, &platform);
        print!("{}", table.render());

        // Headline: best decomposed config vs the quant baseline at
        // comparable BLEU (the paper reports 12.1%-41.1%).
        let quant_best = pts
            .iter()
            .enumerate()
            .filter(|(_, p)| matches!(p.method, Method::QuantOnly { .. }))
            .max_by(|a, b| a.1.bleu.partial_cmp(&b.1.bleu).unwrap());
        if let Some((qi, qp)) = quant_best {
            let mut best: Option<(f64, &str)> = None;
            for (i, p) in pts.iter().enumerate() {
                if matches!(p.method, Method::QuantOnly { .. }) || p.bleu + 1.0 < qp.bleu {
                    continue;
                }
                let red = headline_latency_reduction(&cds[qi], &cds[i]);
                if best.map(|b| red > b.0).unwrap_or(true) {
                    best = Some((red, &p.label));
                }
            }
            if let Some((red, label)) = best {
                println!(
                    "[3/3] headline on {}: '{}' cuts linear-layer latency by {:.1}% \
                     vs '{}' at comparable BLEU",
                    platform.name,
                    label,
                    red * 100.0,
                    qp.label
                );
            }
        }
    }
    Ok(())
}
