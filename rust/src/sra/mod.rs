//! Sensitivity-based Rank Allocation (SRA, §IV).
//!
//! Distributes a total rank budget `R*_total` across the `L` compressed
//! linears to maximize model accuracy (Eq. 5). Accuracy is an opaque oracle
//! `A(ranks)` — in production the coordinator evaluates BLEU on a
//! calibration set through the PJRT runtime; tests use synthetic concave
//! response surfaces.
//!
//! Workflow per the paper: equal-split init → finite-difference sensitivity
//! (Eq. 8) → move `δ` ranks from the least- to the most-sensitive layer
//! (Eq. 9–10) → decay `δ` (Eq. 11) → stop on convergence or max iters.
//!
//! Compression-backed oracles live in [`oracle`]: the cache-backed proxy
//! answers every rank probe from one up-front full-rank decomposition per
//! layer (see `compress::incremental`), so a full SRA round costs L
//! compressions instead of O(evals * L).

pub mod oracle;

pub use oracle::{run_cached_proxy, ProxyOracle};

use crate::util::rng::Pcg64;

/// Accuracy oracle: maps a rank allocation to a score (higher = better).
pub trait AccuracyOracle {
    fn evaluate(&mut self, ranks: &[usize]) -> f64;
}

impl<F: FnMut(&[usize]) -> f64> AccuracyOracle for F {
    fn evaluate(&mut self, ranks: &[usize]) -> f64 {
        self(ranks)
    }
}

/// SRA hyper-parameters (defaults follow the paper's description).
#[derive(Debug, Clone)]
pub struct SraConfig {
    /// Initial perturbation δ0 (Eq. 11).
    pub delta0: usize,
    /// Decay constant α (Eq. 11).
    pub alpha: f64,
    /// Maximum outer iterations.
    pub max_iters: usize,
    /// Stop after this many iterations without improvement.
    pub patience: usize,
    /// Sample at most this many layers per sensitivity round (0 = all).
    /// Finite differences cost 2 oracle calls per probed layer; for the
    /// 32-layer model a full probe is 64 BLEU evaluations per iteration,
    /// so the coordinator can subsample.
    pub probe_layers: usize,
    /// PRNG seed for layer subsampling.
    pub seed: u64,
}

impl Default for SraConfig {
    fn default() -> Self {
        SraConfig {
            delta0: 4,
            alpha: 0.35,
            max_iters: 24,
            patience: 6,
            probe_layers: 0,
            seed: 0,
        }
    }
}

/// Result of an SRA run.
#[derive(Debug, Clone)]
pub struct SraResult {
    /// Best rank allocation found.
    pub ranks: Vec<usize>,
    /// Oracle score of `ranks`.
    pub accuracy: f64,
    /// (iteration, accuracy) trace of accepted allocations.
    pub trace: Vec<(usize, f64)>,
    /// Total oracle evaluations spent.
    pub evals: usize,
}

/// Equal-split initialization honoring per-layer rank caps; remainders go
/// to the earliest layers with headroom so the budget is met exactly.
pub fn equal_split(budget: usize, caps: &[usize]) -> Vec<usize> {
    let l = caps.len();
    assert!(l > 0);
    let total_cap: usize = caps.iter().sum();
    let budget = budget.min(total_cap).max(l); // at least rank 1 per layer
    let mut ranks: Vec<usize> = caps.iter().map(|&c| (budget / l).clamp(1, c)).collect();
    let mut left = budget as i64 - ranks.iter().sum::<usize>() as i64;
    while left != 0 {
        let mut moved = false;
        for j in 0..l {
            if left > 0 && ranks[j] < caps[j] {
                ranks[j] += 1;
                left -= 1;
                moved = true;
            } else if left < 0 && ranks[j] > 1 {
                ranks[j] -= 1;
                left += 1;
                moved = true;
            }
            if left == 0 {
                break;
            }
        }
        if !moved {
            break; // caps/floors make the budget unreachable
        }
    }
    ranks
}

/// Eq. 11: `δ_n = round(δ0 / (1 + α n))`, floored at 1.
pub fn delta_schedule(delta0: usize, alpha: f64, n: usize) -> usize {
    ((delta0 as f64 / (1.0 + alpha * n as f64)).round() as usize).max(1)
}

/// Run the SRA search. `caps[i]` is the maximum rank of layer `i`
/// (`min(K_i, N_i)`); the returned allocation always sums to the initial
/// allocation's total (the budget constraint of Eq. 5).
pub fn run(
    oracle: &mut dyn AccuracyOracle,
    budget: usize,
    caps: &[usize],
    cfg: &SraConfig,
) -> SraResult {
    let l = caps.len();
    let mut ranks = equal_split(budget, caps);
    let mut evals = 0usize;
    let mut best_acc = oracle.evaluate(&ranks);
    evals += 1;
    let mut best_ranks = ranks.clone();
    let mut trace = vec![(0usize, best_acc)];
    let mut rng = Pcg64::new(cfg.seed);
    let mut stall = 0usize;

    for iter in 0..cfg.max_iters {
        let delta = delta_schedule(cfg.delta0, cfg.alpha, iter);

        // --- Sensitivity approximation (Eq. 8) -------------------------
        let probe: Vec<usize> = if cfg.probe_layers == 0 || cfg.probe_layers >= l {
            (0..l).collect()
        } else {
            rng.sample_indices(l, cfg.probe_layers)
        };
        let mut sens: Vec<(usize, f64)> = Vec::with_capacity(probe.len());
        for &i in &probe {
            let up = (ranks[i] + delta).min(caps[i]);
            let dn = ranks[i].saturating_sub(delta).max(1);
            if up == ranks[i] && dn == ranks[i] {
                continue;
            }
            let mut r_up = ranks.clone();
            r_up[i] = up;
            let a_up = oracle.evaluate(&r_up);
            let mut r_dn = ranks.clone();
            r_dn[i] = dn;
            let a_dn = oracle.evaluate(&r_dn);
            evals += 2;
            let span = (up - dn) as f64;
            if span > 0.0 {
                sens.push((i, (a_up - a_dn) / span));
            }
        }
        if sens.len() < 2 {
            break;
        }

        // --- Rank adjustment (Eq. 9–10): donor pays, receiver gains ----
        sens.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        // Receiver: highest sensitivity with headroom; donor: lowest
        // sensitivity able to pay. Scan from the ends inward.
        let recv = sens.iter().rev().find(|&&(i, _)| ranks[i] < caps[i]).map(|&(i, _)| i);
        let recv = match recv {
            Some(i) => i,
            None => break,
        };
        let donor = sens
            .iter()
            .find(|&&(j, _)| j != recv && ranks[j] > 1)
            .map(|&(j, _)| j);
        let donor = match donor {
            Some(j) => j,
            None => break,
        };
        let step = delta
            .min(caps[recv] - ranks[recv])
            .min(ranks[donor].saturating_sub(1));
        if step == 0 {
            break;
        }
        let mut cand = ranks.clone();
        cand[recv] += step;
        cand[donor] -= step;
        let acc = oracle.evaluate(&cand);
        evals += 1;

        if acc > best_acc {
            best_acc = acc;
            best_ranks = cand.clone();
            ranks = cand;
            stall = 0;
        } else {
            // Reject the move but keep exploring from the best allocation.
            ranks = best_ranks.clone();
            stall += 1;
        }
        trace.push((iter + 1, best_acc));
        if stall >= cfg.patience {
            break;
        }
    }

    SraResult { ranks: best_ranks, accuracy: best_acc, trace, evals }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Concave synthetic accuracy: layers with larger `weight` are more
    /// sensitive; `A = sum_i weight_i * sqrt(r_i / cap_i)`.
    fn synthetic_oracle(weights: Vec<f64>, caps: Vec<usize>) -> impl FnMut(&[usize]) -> f64 {
        move |ranks: &[usize]| {
            ranks
                .iter()
                .zip(&weights)
                .zip(&caps)
                .map(|((&r, &w), &c)| w * (r as f64 / c as f64).sqrt())
                .sum()
        }
    }

    #[test]
    fn equal_split_conserves_budget() {
        let caps = vec![64usize; 8];
        let r = equal_split(200, &caps);
        assert_eq!(r.iter().sum::<usize>(), 200);
        assert!(r.iter().all(|&x| (1..=64).contains(&x)));
    }

    #[test]
    fn equal_split_respects_caps() {
        let caps = vec![4usize, 64, 64, 64];
        let r = equal_split(120, &caps);
        assert_eq!(r.iter().sum::<usize>(), 120);
        assert!(r[0] <= 4);
    }

    #[test]
    fn delta_decays_to_one() {
        assert_eq!(delta_schedule(4, 0.35, 0), 4);
        assert!(delta_schedule(4, 0.35, 3) < 4);
        assert_eq!(delta_schedule(4, 0.35, 100), 1);
    }

    #[test]
    fn budget_conserved_through_search() {
        let caps = vec![32usize; 6];
        let budget = 96;
        let mut oracle = synthetic_oracle(vec![5.0, 1.0, 1.0, 1.0, 1.0, 1.0], caps.clone());
        let res = run(&mut oracle, budget, &caps, &SraConfig::default());
        assert_eq!(res.ranks.iter().sum::<usize>(), budget);
        assert!(res.ranks.iter().zip(&caps).all(|(&r, &c)| (1..=c).contains(&r)));
    }

    #[test]
    fn sensitive_layer_gets_more_rank() {
        let caps = vec![32usize; 4];
        let mut oracle = synthetic_oracle(vec![10.0, 1.0, 1.0, 1.0], caps.clone());
        let res = run(&mut oracle, 64, &caps, &SraConfig::default());
        // Layer 0 is 10x more sensitive; it must end above equal split.
        assert!(
            res.ranks[0] > 16,
            "sensitive layer should gain rank: {:?}",
            res.ranks
        );
        assert!(res.ranks[0] > res.ranks[2], "{:?}", res.ranks);
    }

    #[test]
    fn improves_over_equal_split() {
        let caps = vec![48usize; 5];
        let weights = vec![8.0, 4.0, 1.0, 0.5, 0.1];
        let mut oracle = synthetic_oracle(weights.clone(), caps.clone());
        let init = equal_split(100, &caps);
        let base = oracle(&init);
        let mut oracle2 = synthetic_oracle(weights, caps.clone());
        let res = run(&mut oracle2, 100, &caps, &SraConfig::default());
        assert!(res.accuracy >= base, "{} < {base}", res.accuracy);
    }

    #[test]
    fn trace_monotone_nondecreasing() {
        let caps = vec![16usize; 8];
        let mut oracle = synthetic_oracle((0..8).map(|i| 1.0 + i as f64).collect(), caps.clone());
        let res = run(&mut oracle, 64, &caps, &SraConfig::default());
        for w in res.trace.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-12);
        }
    }

    #[test]
    fn probe_subsampling_still_conserves() {
        let caps = vec![32usize; 10];
        let cfg = SraConfig { probe_layers: 3, ..Default::default() };
        let mut oracle = synthetic_oracle(vec![1.0; 10], caps.clone());
        let res = run(&mut oracle, 150, &caps, &cfg);
        assert_eq!(res.ranks.iter().sum::<usize>(), 150);
    }

    #[test]
    fn noisy_oracle_never_returns_worse_than_seen_best() {
        let caps = vec![24usize; 6];
        let mut calls = 0usize;
        let mut oracle = move |ranks: &[usize]| {
            calls += 1;
            let base: f64 = ranks.iter().map(|&r| (r as f64).sqrt()).sum();
            // Deterministic pseudo-noise.
            base + ((calls * 2654435761) % 97) as f64 * 1e-3
        };
        let res = run(&mut oracle, 72, &caps, &SraConfig::default());
        for &(_, acc) in &res.trace {
            assert!(res.accuracy >= acc - 1e-12);
        }
    }
}
