//! # ITERA-LLM
//!
//! Reproduction of *ITERA-LLM: Boosting Sub-8-Bit Large Language Model
//! Inference via Iterative Tensor Decomposition* (CS.AR 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the software/hardware co-design framework:
//!   compression engine ([`compress`], Algorithm 1), sensitivity-based rank
//!   allocation ([`sra`]), FPGA analytical models and dataflow simulator
//!   ([`hw`]), design-space exploration ([`dse`]), BLEU evaluation service
//!   ([`eval`]) and the PJRT runtime ([`runtime`]) that executes the
//!   AOT-compiled model artifacts.
//! * **Layer 2** — JAX transformer (`python/compile/model.py`), lowered
//!   once to HLO text under `make artifacts`.
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`) implementing
//!   the paper's MatMul engines; lowered into the same HLO.
//!
//! Python never runs at inference time: the Rust binary loads
//! `artifacts/*.hlo.txt` through the PJRT C API and drives everything else
//! natively.

#[cfg(feature = "pjrt")]
pub mod cli;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod dse;
pub mod eval;
pub mod hw;
pub mod model;
pub mod runtime;
pub mod sra;
pub mod linalg;
pub mod quant;
pub mod tensor;
pub mod testkit;
pub mod benchkit;
pub mod util;
