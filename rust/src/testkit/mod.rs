//! Property-testing mini-framework (the image vendors no proptest) and
//! hermetic test fixtures.
//!
//! A [`Gen`] wraps the PCG PRNG with convenience samplers; [`check`] runs a
//! property over N generated cases and reports the seed of the first
//! failing case so it can be replayed deterministically. No shrinking —
//! generators are kept small-biased instead (sizes are sampled
//! log-uniformly, so small counterexamples are common).
//!
//! [`tinymodel`] synthesizes a complete on-disk model artifact set
//! (ITWB weight store + manifest + corpus) so the native-runtime e2e
//! suites run without any Python-built artifacts.
//!
//! [`faultkit`] wraps any slot engine in seeded, deterministic fault
//! injection (failed/panicking admissions and steps, stalling slots) —
//! the chaos harness behind the serving fault-tolerance soaks.

pub mod faultkit;
pub mod tinymodel;

use crate::util::rng::Pcg64;

/// Case generator handed to properties.
pub struct Gen {
    rng: Pcg64,
    /// Seed of the current case (for reproduction).
    pub case_seed: u64,
}

impl Gen {
    pub fn new(case_seed: u64) -> Gen {
        Gen { rng: Pcg64::new(case_seed), case_seed }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    /// Log-uniform size in [lo, hi] — biases toward small cases.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo >= 1 && lo <= hi);
        let l = (lo as f64).ln();
        let h = (hi as f64).ln();
        let x = l + (h - l) * self.rng.next_f64();
        (x.exp().round() as usize).clamp(lo, hi)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn normal(&mut self) -> f32 {
        self.rng.normal()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// Random matrix with entries ~ scale * N(0,1).
    pub fn matrix(&mut self, rows: usize, cols: usize, scale: f32) -> crate::tensor::Matrix {
        crate::tensor::Matrix::from_fn(rows, cols, |_, _| self.normal() * scale)
    }

    /// Random token sequence of the given length over [3, vocab).
    pub fn tokens(&mut self, len: usize, vocab: i32) -> Vec<i32> {
        (0..len).map(|_| 3 + self.rng.below((vocab - 3) as usize) as i32).collect()
    }
}

/// Run `prop` over `cases` generated cases; panics with the failing seed.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen)) {
    // Suite seed is fixed: failures reproduce across runs; per-case seeds
    // derive from the case index.
    for case in 0..cases {
        let seed = 0x17E8A_u64
            .wrapping_mul(1 + case as u64)
            .wrapping_add(0x9E3779B97F4A7C15);
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}):\n  {msg}\n  \
                 replay: Gen::new({seed:#x})"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_in_range() {
        check("gen-ranges", 50, |g| {
            let n = g.usize_in(2, 9);
            assert!((2..=9).contains(&n));
            let s = g.size(1, 100);
            assert!((1..=100).contains(&s));
            let x = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&x));
            let t = g.tokens(5, 100);
            assert!(t.iter().all(|&v| (3..100).contains(&v)));
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn reports_failing_seed() {
        check("always-fails", 3, |g| {
            assert!(g.usize_in(0, 10) > 100, "impossible");
        });
    }

    #[test]
    fn size_biases_small() {
        let mut small = 0;
        check("size-bias", 200, |g| {
            if g.size(1, 1000) <= 100 {
                small += 1;
            }
        });
        assert!(small > 100, "log-uniform should favor small sizes: {small}/200");
    }
}
