//! Batched NMT serving demo over the native runtime.
//!
//! ```bash
//! cargo run --release --example serve_nmt [-- <requests> <pair> <mode> <decode> <batcher>]
//! ```
//!
//! `<mode>` is `dense` (fake-quant f32, the default) or `quantized`
//! (bit-packed weights — same tokens bit for bit, ~4x fewer weight bytes
//! resident at W8). `<decode>` is `cached` (KV-cached single-token decode
//! steps, the default) or `replay` (the full-buffer reference loop) —
//! same tokens bit for bit, a seq_len-factor fewer decoder MACs cached.
//! `<batcher>` is `static` (group, decode to completion, respond — the
//! default) or `continuous` (the slot scheduler: retire EOS'd sequences
//! and admit queued ones between decode steps) — same responses bit for
//! bit, the decode engine just stays full under load.
//!
//! Spins up the request-batching loop (`coordinator::serve_demo_native`):
//! a closed-loop client submits single-sentence translation requests, the
//! server groups them into fixed-capacity batches, executes one translate
//! call per batch against a W8A8-quantized model on the pure-Rust engine,
//! and reports latency percentiles and throughput. Works in the default
//! build — no PJRT, no Python, no compiled artifacts (point
//! `ITERA_ARTIFACTS` at any directory holding a manifest + weight store,
//! e.g. one written by `testkit::tinymodel::generate`). A `pjrt` build
//! can run the same loop against the AOT artifacts via
//! `itera serve --backend pjrt`.

use anyhow::Result;
use itera_llm::coordinator::{serve_demo_native, Batcher, ServeTuning};
use itera_llm::model::Manifest;
use itera_llm::runtime::{DecodePolicy, Mode};
use itera_llm::util::pool::default_workers;

fn main() -> Result<()> {
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(64);
    let manifest = Manifest::load(Manifest::default_dir())?;
    let pair = match std::env::args().nth(2) {
        Some(p) => p,
        None => manifest
            .pairs
            .keys()
            .next()
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("manifest registers no language pairs"))?,
    };
    // Quant-only compression produces Dense layers, so only the dense
    // and bit-packed execution forms apply here.
    let mode = match std::env::args().nth(3).as_deref() {
        None | Some("dense") => Mode::Dense,
        Some("quantized") => Mode::Quantized,
        Some(m) => anyhow::bail!("unknown mode {m} (expected dense|quantized)"),
    };
    let decode = match std::env::args().nth(4).as_deref() {
        None => DecodePolicy::default(),
        Some(d) => DecodePolicy::parse(d)
            .ok_or_else(|| anyhow::anyhow!("unknown decode policy {d} (expected replay|cached)"))?,
    };
    let batcher = match std::env::args().nth(5).as_deref() {
        None => Batcher::default(),
        Some(b) => Batcher::parse(b)
            .ok_or_else(|| anyhow::anyhow!("unknown batcher {b} (expected static|continuous)"))?,
    };
    // Default tuning: unbounded queue, no deadlines, closed-loop client.
    // The `itera serve` CLI exposes the overload/deadline knobs.
    serve_demo_native(
        &manifest,
        &pair,
        requests,
        default_workers(8),
        mode,
        decode,
        batcher,
        &ServeTuning::default(),
    )?;
    Ok(())
}
