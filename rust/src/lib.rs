//! # ITERA-LLM
//!
//! Reproduction of *ITERA-LLM: Boosting Sub-8-Bit Large Language Model
//! Inference via Iterative Tensor Decomposition* (CS.AR 2025) as a
//! five-layer Rust + JAX + Pallas system:
//!
//! * **Layer 5 ([`runtime`])** — model execution. Two interchangeable
//!   backends behind [`runtime::TranslateBackend`]: the always-built
//!   pure-Rust native engine ([`runtime::native`], dense, factored
//!   low-rank and bit-packed quantized execution on [`tensor::Matrix`])
//!   and the optional PJRT session (`pjrt` feature) that executes the
//!   AOT-compiled artifacts. The native engine decodes under a
//!   [`runtime::DecodePolicy`]: KV-cached **slot-addressed** single-token
//!   steps by default — every sequence owns a [`runtime::SeqSlot`]
//!   (per-layer K/V slabs + cross context + step counter) that is
//!   admitted, stepped in mixed-age batches and retired independently
//!   ([`runtime::SlotEngine`]), a `seq_len`-factor fewer decoder MACs
//!   per translate — with the AOT graph's full-buffer replay kept as the
//!   bit-identical reference. Slot KV lives in **paged memory**
//!   ([`runtime::kvpool`]): fixed-size pages from a byte-budgeted free
//!   list, per-slot page tables growing one page ahead of the decode
//!   cursor, exact `resident_bytes` accounting and leak checks at slot
//!   retirement — reads are layout-transparent ([`runtime::RowRead`]),
//!   so paging never changes a value. Slot independence feeds the
//!   serving layer: `coordinator::scheduler::ContinuousBatcher`
//!   retires/admits between decode steps (continuous batching) with
//!   bit-identical output, and on a budgeted pool it admits by *bytes*
//!   (worst-case page demand against the free list), evicts the
//!   youngest admission when a decode outgrows the budget, and replays
//!   it later bit-identically (preemption-by-eviction + re-prefill),
//!   surfaced as `kv_resident_bytes`/`kv_pages_free` gauges and
//!   `batcher_preempted_total` on `/metrics`.
//! * **Layer 4 ([`qkernel`])** — sub-8-bit execution kernels: bit-packed
//!   [`qkernel::QMatrix`] storage (2..=8-bit grids in `u32` words,
//!   per-vector dequant scales, an `i8` fast path at W8) plus the
//!   integer GEMM/GEMV the native engine's `Mode::Quantized` runs on.
//!   The cached decode hot loop is **two-tier**
//!   ([`runtime::KernelTier`], `--kernel exact|fast`): the default
//!   `Exact` tier dequantizes on the fly and accumulates in f32 —
//!   bit-exact against the fake-quant reference, so the sub-8-bit
//!   memory footprint comes at zero numerical cost (the paper's
//!   bandwidth story made real, and testable) — while the opt-in `Fast`
//!   tier quantizes activations to `i8` at runtime and runs the whole
//!   linear as int8×int-grid GEMV with `i32` accumulation and one
//!   rescale per output (`QMatrix::qmatvec_i32`, plus the
//!   `qmatvec_i32_rows` row-scaled twin for the low-rank integer
//!   cascade). `Fast` is non-bit-exact by contract and fenced by the
//!   `validate --kernel fast` parity gate; its envelope violations
//!   (range, accumulator cap, scale axis, non-finite activations) are
//!   typed [`qkernel::QKernelError`]s that fault one request, never the
//!   batch.
//! * **Layer 3 (the rest of this crate)** — the software/hardware
//!   co-design framework: compression engine ([`compress`], Algorithm 1),
//!   sensitivity-based rank allocation ([`sra`]), FPGA analytical models
//!   and dataflow simulator ([`hw`]), design-space exploration ([`dse`]),
//!   BLEU evaluation service ([`eval`]) and the serving/experiment
//!   coordinator ([`coordinator`]). Serving is fault-tolerant: a typed
//!   error taxonomy ([`coordinator::ServeError`] — overload shedding,
//!   per-request decode-step deadlines, cancellation on client
//!   disconnect, panic-isolated engine faults) guarantees every
//!   admitted request exactly one terminal outcome, with graceful
//!   drain on shutdown and balanced accounting
//!   (`received == served + shed + expired + cancelled + faulted`).
//!   The guarantee is exercised by a seeded deterministic
//!   fault-injection harness ([`testkit::faultkit`]) in chaos soaks.
//!   The serving layer is reachable over the network through [`server`]:
//!   a dependency-free HTTP/1.1 front end (`std::net` only — routing,
//!   keep-alive, chunked streaming of incremental decode progress,
//!   typed error→status mapping) that feeds the same continuous serve
//!   loop, so HTTP responses are bit-identical to in-process serving;
//!   [`server::loadgen`] drives it with seeded Poisson open-loop load
//!   for the latency/saturation bench lanes. A cross-cutting telemetry
//!   layer ([`obs`]) threads through all of it: a dependency-free
//!   metrics registry (lock-free atomic counters/gauges/histograms,
//!   snapshot-on-read), per-request traces that attribute every
//!   terminal outcome to a serving stage (submit → queue → admit →
//!   decode → respond), and a bounded postmortem ring — exported live
//!   as Prometheus text on `GET /metrics` and JSON on `GET /v1/stats`,
//!   with the end-of-run `ServeStats` derived from the same registry
//!   snapshot so there is exactly one source of accounting truth.
//! * **Layer 2** — JAX transformer (`python/compile/model.py`), lowered
//!   once to HLO text under `make artifacts`.
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`) implementing
//!   the paper's MatMul engines; lowered into the same HLO.
//!
//! Python never runs at inference time: the default build executes models
//! natively from the weight store, and a `pjrt` build can additionally
//! load `artifacts/*.hlo.txt` through the PJRT C API.

pub mod cli;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod dse;
pub mod eval;
pub mod hw;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod server;
pub mod sra;
pub mod linalg;
pub mod qkernel;
pub mod quant;
pub mod tensor;
pub mod testkit;
pub mod benchkit;
pub mod util;
