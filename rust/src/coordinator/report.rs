//! Table/CSV emission for the figure runners.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

/// A simple column-aligned table with a CSV twin.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], w: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(w, "{:<width$}  ", c, width = widths[i]);
            }
            let _ = writeln!(w);
        };
        line(&self.headers, &mut out);
        let total: usize = widths.iter().map(|w| w + 2).sum();
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            line(row, &mut out);
        }
        out
    }

    /// Write a CSV twin under `dir/<name>.csv`.
    pub fn write_csv(&self, dir: impl AsRef<Path>, name: &str) -> Result<()> {
        std::fs::create_dir_all(dir.as_ref())?;
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        std::fs::write(dir.as_ref().join(format!("{name}.csv")), out)?;
        Ok(())
    }
}

/// Format helpers shared by the figure runners.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn cycles(x: f64) -> String {
    format!("{:.0}", x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "val"]);
        t.row(vec!["a".into(), "1.00".into()]);
        t.row(vec!["long-name".into(), "2.50".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long-name"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn csv_roundtrip_and_escaping() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let dir = std::env::temp_dir().join("itera_report_test");
        t.write_csv(&dir, "t").unwrap();
        let text = std::fs::read_to_string(dir.join("t.csv")).unwrap();
        assert!(text.contains("\"x,y\""));
        assert!(text.contains("\"q\"\"z\""));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
