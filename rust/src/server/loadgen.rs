//! Open-loop HTTP load generator for the inference server.
//!
//! Offered load is a seeded Poisson process: a single global schedule of
//! exponential inter-arrivals is drawn up-front ([`crate::util::rng`],
//! fully reproducible), round-robined across persistent keep-alive
//! connections, and each connection thread fires at its absolute
//! schedule offsets. When the server saturates, threads fall behind
//! schedule and the backlog surfaces as latency — exactly what the p99
//! lanes should see, instead of the closed-loop coordinated omission
//! that hides it. `rate = 0` degenerates to closed-loop back-to-back
//! requests (the saturation-throughput probe).
//!
//! Request bodies are ragged: token counts draw uniformly from a
//! configured range, content ids uniformly from the model vocabulary,
//! framed the way the tokenizer would (BOS/EOS are the server's
//! business — the loadgen sends raw content rows like any client).

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::stats::Summary;

use super::http::{write_request, HttpConn, HttpResponse, RecvError};

/// Open-loop load-generator configuration.
#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    /// Persistent keep-alive connections, each on its own thread.
    pub connections: usize,
    /// Total requests across the whole fleet.
    pub requests: usize,
    /// Offered arrival rate in requests/second (aggregate, Poisson).
    /// `0.0` means closed-loop: every connection fires back-to-back.
    pub rate: f64,
    /// RNG seed: schedule and request shapes are reproducible.
    pub seed: u64,
    /// Ragged request lengths: token counts draw uniformly from this
    /// inclusive range.
    pub len_range: (usize, usize),
    /// Content token ids draw uniformly from `3..vocab` (ids 0/1/2 are
    /// the PAD/BOS/EOS convention).
    pub vocab: i32,
    /// Per-request decode-step deadline forwarded to the server.
    pub deadline_steps: Option<usize>,
    /// Retry budget for 503 `Overloaded` responses, per request. Each
    /// retry backs off exponentially (5ms doubling, capped) with seeded
    /// jitter so a shed burst does not re-arrive in lockstep. `0` (the
    /// default) keeps the historical fire-once behaviour.
    pub retry_503: usize,
}

impl Default for LoadGenConfig {
    fn default() -> LoadGenConfig {
        LoadGenConfig {
            connections: 8,
            requests: 64,
            rate: 0.0,
            seed: 0x10AD,
            len_range: (2, 8),
            vocab: 16,
            deadline_steps: None,
            retry_503: 0,
        }
    }
}

/// What the load generator observed, aggregated across connections.
#[derive(Debug, Clone, Default)]
pub struct LoadReport {
    /// Requests put on the wire.
    pub sent: usize,
    /// 200 responses.
    pub ok: usize,
    /// Non-200 outcomes bucketed by HTTP status (0 = transport error).
    pub errors: BTreeMap<u16, usize>,
    /// Generated tokens across successful responses.
    pub tokens: usize,
    /// 503 retries that went back on the wire. Kept out of `sent` so
    /// the ledger cross-check stays exact: the server's `received`
    /// counter equals client `sent + retries` (every retry is a fresh
    /// HTTP request server-side), while `sent == ok + failed()` still
    /// accounts one outcome per *scheduled* request.
    pub retries: usize,
    pub wall_s: f64,
    /// Client-observed request latency (send to full response), seconds.
    pub latency: Summary,
}

impl LoadReport {
    /// Successful requests per wall-clock second.
    pub fn throughput_rps(&self) -> f64 {
        self.ok as f64 / self.wall_s.max(1e-12)
    }

    /// Generated tokens per wall-clock second — the saturation gauge.
    pub fn tokens_per_s(&self) -> f64 {
        self.tokens as f64 / self.wall_s.max(1e-12)
    }

    /// Requests that did not end in a 200.
    pub fn failed(&self) -> usize {
        self.errors.values().sum()
    }

    /// One-screen human summary (the CLI's `--loadgen` output).
    pub fn print(&self, label: &str) {
        println!("== loadgen ({label}) ==");
        println!("sent          : {} ({} ok, {} failed)", self.sent, self.ok, self.failed());
        if self.retries > 0 {
            println!("retries (503) : {}", self.retries);
        }
        println!("wall time     : {:.2}s", self.wall_s);
        println!("throughput    : {:.1} req/s", self.throughput_rps());
        println!("tokens/sec    : {:.1} ({} generated tokens)", self.tokens_per_s(), self.tokens);
        println!(
            "latency (s)   : p50 {:.4}  p95 {:.4}  p99 {:.4}  max {:.4}",
            self.latency.quantile(0.5),
            self.latency.quantile(0.95),
            self.latency.quantile(0.99),
            self.latency.max()
        );
        for (status, n) in &self.errors {
            println!("status {status:>3}    : {n}");
        }
    }
}

/// Per-connection slice of the run (merged by [`run_loadgen`]). Each
/// connection keeps its own latency [`Summary`]; the fleet-wide view
/// comes from [`Summary::merge`], so aggregation is O(connections)
/// rather than O(requests).
#[derive(Default)]
struct Part {
    sent: usize,
    ok: usize,
    tokens: usize,
    retries: usize,
    errors: BTreeMap<u16, usize>,
    latency: Summary,
}

/// Drive the configured load against `addr` and aggregate what came
/// back. Blocks until every scheduled request has an outcome.
pub fn run_loadgen(addr: SocketAddr, cfg: &LoadGenConfig) -> Result<LoadReport> {
    let conns = cfg.connections.max(1);
    let mut rng = Pcg64::new(cfg.seed);
    let mut plans: Vec<Vec<(Duration, Vec<i32>)>> = vec![Vec::new(); conns];
    let mut at = 0.0f64;
    let (lo, hi) = cfg.len_range;
    let span = hi.max(lo) - lo + 1;
    let ids = (cfg.vocab - 3).max(1) as usize;
    for i in 0..cfg.requests {
        if cfg.rate > 0.0 {
            // Exponential inter-arrival via inverse CDF: -ln(1-u)/rate.
            at += -(1.0 - rng.next_f64()).ln() / cfg.rate;
        }
        let len = lo + rng.below(span);
        let tokens: Vec<i32> = (0..len).map(|_| 3 + rng.below(ids) as i32).collect();
        plans[i % conns].push((Duration::from_secs_f64(at), tokens));
    }
    let t0 = Instant::now();
    let workers: Vec<_> = plans
        .into_iter()
        .enumerate()
        .map(|(i, plan)| {
            let deadline_steps = cfg.deadline_steps;
            let retry_503 = cfg.retry_503;
            // Per-connection backoff jitter stream, derived from the run
            // seed so retry timing is as reproducible as the schedule.
            let rng = Pcg64::new(cfg.seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(i as u64 + 1));
            std::thread::spawn(move || run_connection(addr, t0, plan, deadline_steps, retry_503, rng))
        })
        .collect();
    let mut report = LoadReport::default();
    for w in workers {
        let part = w.join().map_err(|_| anyhow::anyhow!("loadgen thread panicked"))??;
        report.sent += part.sent;
        report.ok += part.ok;
        report.tokens += part.tokens;
        report.retries += part.retries;
        for (status, n) in part.errors {
            *report.errors.entry(status).or_insert(0) += n;
        }
        report.latency.merge(&part.latency);
    }
    report.wall_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

fn exchange(conn: &mut HttpConn<TcpStream>, body: &Json) -> Result<HttpResponse, RecvError> {
    write_request(conn.get_mut(), "POST", "/v1/translate", Some(body)).map_err(RecvError::Io)?;
    conn.read_response()
}

/// One-shot `GET` on a fresh connection — the telemetry scrape used by
/// the CLI self-drive check and the observability e2e test to pull
/// `/metrics` and `/v1/stats` while the server is still up.
pub fn http_get(addr: SocketAddr, target: &str) -> Result<HttpResponse> {
    let stream = TcpStream::connect(addr).context("scrape connect")?;
    stream.set_nodelay(true).ok();
    let mut conn = HttpConn::new(stream);
    write_request(conn.get_mut(), "GET", target, None).context("scrape send")?;
    conn.read_response().with_context(|| format!("scrape GET {target}"))
}

/// One request attempt. A transport failure reconnects once (the server
/// sheds whole connections at the accept level under overload); a second
/// failure yields `None` and the attempt counts as a transport miss.
fn send_once(conn: &mut HttpConn<TcpStream>, addr: SocketAddr, body: &Json) -> Option<HttpResponse> {
    match exchange(conn, body) {
        Ok(resp) => Some(resp),
        Err(_) => {
            let s = TcpStream::connect(addr).ok()?;
            s.set_nodelay(true).ok();
            *conn = HttpConn::new(s);
            exchange(conn, body).ok()
        }
    }
}

/// Longest pause between 503 retries (the exponential backoff cap).
const BACKOFF_CAP: Duration = Duration::from_millis(160);

fn run_connection(
    addr: SocketAddr,
    t0: Instant,
    plan: Vec<(Duration, Vec<i32>)>,
    deadline_steps: Option<usize>,
    retry_503: usize,
    mut rng: Pcg64,
) -> Result<Part> {
    let mut part = Part::default();
    if plan.is_empty() {
        return Ok(part);
    }
    let stream = TcpStream::connect(addr).context("loadgen connect")?;
    stream.set_nodelay(true).ok();
    let mut conn = HttpConn::new(stream);
    for (at, tokens) in plan {
        // Open-loop pacing: wait for the scheduled offset; once the
        // server saturates we fall behind and the backlog shows up as
        // latency instead of silently thinning the offered load.
        let elapsed = t0.elapsed();
        if at > elapsed {
            std::thread::sleep(at - elapsed);
        }
        let toks = Json::Arr(tokens.iter().map(|&t| Json::Num(f64::from(t))).collect());
        let mut fields = vec![("tokens", toks)];
        if let Some(d) = deadline_steps {
            fields.push(("deadline_steps", Json::Num(d as f64)));
        }
        let body = Json::obj(fields);
        let t_send = Instant::now();
        part.sent += 1;
        // Shed responses are retryable by construction (the request never
        // reached a slot), so back off and re-offer up to the budget.
        let mut left = retry_503;
        let mut backoff = Duration::from_millis(5);
        let resp = loop {
            match send_once(&mut conn, addr, &body) {
                None => break None,
                Some(resp) if resp.status == 503 && left > 0 => {
                    left -= 1;
                    part.retries += 1;
                    // Jitter in [0.5, 1.5)x keeps a shed burst from
                    // re-arriving in lockstep; the stream is seeded, so
                    // timing is reproducible run to run.
                    std::thread::sleep(backoff.mul_f64(0.5 + rng.next_f64()));
                    backoff = (backoff * 2).min(BACKOFF_CAP);
                }
                Some(resp) => break Some(resp),
            }
        };
        let Some(resp) = resp else {
            *part.errors.entry(0).or_insert(0) += 1;
            continue;
        };
        part.latency.add(t_send.elapsed().as_secs_f64());
        if resp.status == 200 {
            part.ok += 1;
            if let Ok(j) = resp.json() {
                part.tokens += j.get("tokens").as_arr().map_or(0, <[Json]>::len);
            }
        } else {
            *part.errors.entry(resp.status).or_insert(0) += 1;
        }
    }
    Ok(part)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Draw the schedule exactly the way `run_loadgen` does.
    fn draw_schedule(cfg: &LoadGenConfig) -> Vec<(f64, Vec<i32>)> {
        let mut rng = Pcg64::new(cfg.seed);
        let (lo, hi) = cfg.len_range;
        let mut at = 0.0;
        let mut out = Vec::new();
        for _ in 0..cfg.requests {
            if cfg.rate > 0.0 {
                at += -(1.0 - rng.next_f64()).ln() / cfg.rate;
            }
            let len = lo + rng.below(hi - lo + 1);
            let tokens: Vec<i32> =
                (0..len).map(|_| 3 + rng.below((cfg.vocab - 3) as usize) as i32).collect();
            out.push((at, tokens));
        }
        out
    }

    #[test]
    fn schedule_is_reproducible_and_poisson_shaped() {
        let cfg = LoadGenConfig { requests: 4000, rate: 500.0, ..LoadGenConfig::default() };
        let sched = draw_schedule(&cfg);
        let (lo, hi) = cfg.len_range;
        let mut prev = 0.0;
        let mut gap_sum = 0.0;
        for (at, tokens) in &sched {
            assert!(*at >= prev, "arrival times are monotone");
            gap_sum += at - prev;
            prev = *at;
            assert!((lo..=hi).contains(&tokens.len()), "ragged lengths stay in range");
            assert!(tokens.iter().all(|t| (3..cfg.vocab).contains(t)));
        }
        // Exponential inter-arrivals: the mean gap estimates 1/rate.
        let mean = gap_sum / sched.len() as f64;
        assert!((mean - 1.0 / cfg.rate).abs() < 0.2 / cfg.rate, "mean gap ~ 1/rate, got {mean}");
        // Same seed, same schedule — bit for bit.
        let again = draw_schedule(&cfg);
        assert_eq!(sched.len(), again.len());
        for ((a, ta), (b, tb)) in sched.iter().zip(&again) {
            assert_eq!(a.to_bits(), b.to_bits());
            assert_eq!(ta, tb);
        }
    }

    #[test]
    fn report_aggregates_and_rates() {
        let mut r = LoadReport::default();
        r.sent = 10;
        r.ok = 8;
        r.errors.insert(503, 2);
        r.tokens = 40;
        r.retries = 3;
        r.wall_s = 2.0;
        for i in 0..8 {
            r.latency.add(0.01 * (i + 1) as f64);
        }
        assert_eq!(r.failed(), 2);
        // The ledger identity the cross-checks rely on: every scheduled
        // request has exactly one outcome, retries ride on top.
        assert_eq!(r.sent, r.ok + r.failed());
        assert_eq!(r.sent + r.retries, 13, "wire-level requests = sent + retries");
        assert!((r.throughput_rps() - 4.0).abs() < 1e-12);
        assert!((r.tokens_per_s() - 20.0).abs() < 1e-12);
        assert_eq!(r.latency.count(), 8);
    }
}
