"""Pallas tiled quantized-matmul kernel — the paper's baseline MatMul engine.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's FPGA engine
(Listing 1) is an output-stationary ``M_t × N_t`` PE array with ``K_f``-wide
dot products, fed by BRAM FIFOs that stage off-chip tiles. On a TPU-shaped
memory hierarchy the same schedule is expressed as a Pallas grid over
``(M/M_t, N/N_t, K/K_t)`` with BlockSpecs staging ``M_t×K_t`` / ``K_t×N_t``
blocks into VMEM (the scratchpad playing the BRAM role) and an
output-stationary accumulator block revisited along the K axis (the PE
accumulator role). The MXU performs the ``M_t×K_t×N_t`` MACs that the DSP
array performs on the FPGA.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; correctness is validated against ``ref.py`` and FPGA
latency/resource numbers come from the Rust analytical models.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    """Output-stationary accumulate: one (mt, nt, kt) grid step."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pick_block(dim: int, want: int) -> int:
    """Largest divisor of ``dim`` that is <= ``want`` (tiles must divide)."""
    b = min(want, dim)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def quant_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    block_m: int = 64,
    block_n: int = 64,
    block_k: int = 64,
) -> jnp.ndarray:
    """Tiled ``y = x @ w`` through the PE-array dataflow.

    ``x: [M, K]``, ``w: [K, N]`` are expected to be fake-quantized upstream
    (weights by the Rust compression engine, activations by the in-graph
    ``fake_quant`` kernel); the kernel itself is the exact fixed-point MAC
    array, which in fake-quant arithmetic is a plain f32 matmul.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    bk = _pick_block(k, block_k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def _fake_quant_kernel(x_ref, s_ref, l_ref, o_ref):
    """Vector-wise symmetric fake-quant: the 'Quant' block of Fig. 3."""
    s = s_ref[0]
    lv = l_ref[0]
    safe = jnp.where(s > 0, s, 1.0)
    x = x_ref[...]
    q = jnp.clip(jnp.round(x / safe), -lv, lv) * safe
    o_ref[...] = jnp.where(lv > 0, q, x)


@functools.partial(jax.jit, static_argnames=("block_m",))
def fake_quant(
    x: jnp.ndarray, scale: jnp.ndarray, levels: jnp.ndarray, *, block_m: int = 64
) -> jnp.ndarray:
    """Symmetric fixed-point fake-quantization of a 2-D activation tile.

    ``scale`` and ``levels`` are scalar runtime arguments (shape ``[1]``)
    so the Rust coordinator can select any A-width — or disable activation
    quantization entirely with ``levels == 0`` — without recompiling.
    """
    m, n = x.shape
    bm = _pick_block(m, block_m)
    scale = jnp.asarray(scale, jnp.float32).reshape(1)
    levels = jnp.asarray(levels, jnp.float32).reshape(1)
    return pl.pallas_call(
        _fake_quant_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, scale, levels)
