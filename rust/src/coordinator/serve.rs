//! Batched serving demo: a minimal request loop over any translate
//! backend.
//!
//! Demonstrates the deployment story: single-sentence translation requests
//! arrive on a channel, a batcher groups them up to the backend's batch
//! capacity (padding short batches), executes one translate call per
//! batch, and reports per-request latency percentiles and aggregate
//! throughput. The loop is backend-agnostic ([`TranslateBackend`]), so
//! the same code path serves the always-built native engine and — with
//! the `pjrt` feature — the AOT-compiled PJRT session; Python is nowhere
//! on either path.
//!
//! The batcher itself ([`pack_rows`], [`serve_loop`]) is split out of the
//! demo driver so it can be unit-tested against a mock backend without
//! threads, models or artifacts.

use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;

use crate::eval::{strip_specials, Corpus};
use crate::model::ModelDims;
use crate::runtime::{DecodePolicy, Mode, TranslateBackend};
use crate::util::rng::Pcg64;
use crate::util::stats::Summary;

#[cfg(feature = "pjrt")]
use crate::runtime::{PjrtBackend, TranslateSession};

#[cfg(feature = "pjrt")]
use super::Coordinator;
use super::Method;

/// One translation request: source tokens in, (tokens, latency_s) out.
pub struct Request {
    pub tokens: Vec<i32>,
    pub t_arrival: Instant,
    pub respond: mpsc::Sender<(Vec<i32>, f64)>,
}

/// Aggregate outcome of one [`serve_loop`] run.
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub served: usize,
    pub batches: usize,
    pub wall_s: f64,
    /// Generated (de-framed) output tokens across all responses — the
    /// numerator of the serving throughput number.
    pub tokens: usize,
    /// Per-request latency samples (seconds, arrival to response), as
    /// observed by the server loop itself.
    pub latency: Summary,
}

impl ServeStats {
    /// Generated tokens per wall-clock second over the whole run.
    pub fn tokens_per_s(&self) -> f64 {
        self.tokens as f64 / self.wall_s.max(1e-12)
    }
}

/// Pack up to `batch` token rows into a fixed `[batch * seq]` buffer:
/// rows are truncated to `seq` and the remainder is PAD-filled (both the
/// tail of short rows and the unused batch slots).
pub fn pack_rows(rows: &[&[i32]], batch: usize, seq: usize, pad: i32) -> Vec<i32> {
    assert!(rows.len() <= batch, "{} rows exceed batch capacity {batch}", rows.len());
    let mut src = vec![pad; batch * seq];
    for (row, tokens) in rows.iter().enumerate() {
        let take = tokens.len().min(seq);
        src[row * seq..row * seq + take].copy_from_slice(&tokens[..take]);
    }
    src
}

/// Drain one batch from the request channel: block for the first request,
/// then opportunistically take whatever else is already queued, up to
/// `capacity`. `None` when the channel has disconnected.
fn next_batch(rx: &mpsc::Receiver<Request>, capacity: usize) -> Option<Vec<Request>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    while batch.len() < capacity {
        match rx.try_recv() {
            Ok(r) => batch.push(r),
            Err(_) => break,
        }
    }
    Some(batch)
}

/// The server loop: batch requests off `rx`, execute them on `backend`,
/// respond with de-framed tokens + latency, until `n_requests` have been
/// served or the channel disconnects.
pub fn serve_loop(
    backend: &dyn TranslateBackend,
    rx: &mpsc::Receiver<Request>,
    dims: &ModelDims,
    n_requests: usize,
) -> Result<ServeStats> {
    let b = backend.batch();
    let s = backend.seq_len();
    let t0 = Instant::now();
    let mut served = 0usize;
    let mut batches = 0usize;
    let mut tokens = 0usize;
    let mut latency = Summary::new();
    while served < n_requests {
        let Some(batch) = next_batch(rx, b) else { break };
        let rows: Vec<&[i32]> = batch.iter().map(|r| r.tokens.as_slice()).collect();
        // Fixed-shape backends (AOT artifacts) need the full compiled
        // batch; variable-shape ones only pay for the rows they got.
        let pack_to = if backend.fixed_shape() { b } else { rows.len() };
        let src = pack_rows(&rows, pack_to, s, dims.pad_id);
        let out = backend.translate(&src)?;
        let now = Instant::now();
        for (row, req) in batch.iter().enumerate() {
            let toks = strip_specials(
                &out[row * s..(row + 1) * s],
                dims.bos_id,
                dims.eos_id,
                dims.pad_id,
            );
            let lat = now.duration_since(req.t_arrival).as_secs_f64();
            tokens += toks.len();
            latency.add(lat);
            req.respond.send((toks, lat)).ok();
        }
        served += batch.len();
        batches += 1;
    }
    Ok(ServeStats { served, batches, wall_s: t0.elapsed().as_secs_f64(), tokens, latency })
}

/// Closed-loop demo driver: a client thread submits `n_requests` random
/// test sentences back-to-back, [`serve_loop`] batches and executes them,
/// and the latency/throughput summary is printed.
pub fn run_demo(
    backend: &dyn TranslateBackend,
    corpus: Corpus,
    dims: &ModelDims,
    n_requests: usize,
    label: &str,
) -> Result<ServeStats> {
    let (tx, rx) = mpsc::channel::<Request>();

    // Client thread: submits requests back-to-back (closed-loop).
    let client = std::thread::spawn(move || {
        let mut rng = Pcg64::new(0xBEEF);
        let mut latencies = Summary::new();
        let mut done = Vec::new();
        for _ in 0..n_requests {
            let i = rng.below(corpus.n);
            let (rtx, rrx) = mpsc::channel();
            let t_submit = Instant::now();
            tx.send(Request {
                tokens: corpus.src_row(i).to_vec(),
                t_arrival: t_submit,
                respond: rtx,
            })
            .ok();
            // Closed-loop: wait for the response before the next request
            // (the batcher still groups concurrent stragglers). Latency
            // is measured at receive time, so it includes the response
            // channel hop the server-side percentile rows can't see.
            if let Ok((toks, _lat)) = rrx.recv() {
                latencies.add(t_submit.elapsed().as_secs_f64());
                done.push(toks);
            }
        }
        (latencies, done)
    });

    let stats = serve_loop(backend, &rx, dims, n_requests)?;
    let (latencies, translations) = client.join().expect("client thread");

    println!(
        "== serving demo ({label}, backend {}, batch capacity {}) ==",
        backend.kind(),
        backend.batch()
    );
    println!("requests      : {n_requests} ({} batches)", stats.batches);
    println!("wall time     : {:.2}s", stats.wall_s);
    println!("throughput    : {:.1} sentences/s", stats.served as f64 / stats.wall_s);
    println!(
        "tokens/sec    : {:.1} ({} generated tokens)",
        stats.tokens_per_s(),
        stats.tokens
    );
    println!(
        "latency (s)   : p50 {:.3}  p95 {:.3}  max {:.3} (client-observed)",
        latencies.quantile(0.5),
        latencies.quantile(0.95),
        latencies.max()
    );
    println!(
        "latency (s)   : p50 {:.3}  p95 {:.3}  max {:.3} (server-side, n={})",
        stats.latency.quantile(0.5),
        stats.latency.quantile(0.95),
        stats.latency.max(),
        stats.latency.count()
    );
    println!(
        "sample output : {:?}",
        translations.first().map(|t| &t[..t.len().min(8)])
    );
    Ok(stats)
}

/// Serving demo on the native runtime: W8A8-quantized model (the
/// deployment configuration), no PJRT anywhere. Works in every build.
///
/// `mode` picks the execution form of the quantized weights:
/// `Mode::Dense` serves fake-quant f32, `Mode::Quantized` serves the
/// bit-packed bank (same tokens bit for bit, ~4x fewer weight bytes
/// resident at W8). `decode` picks the greedy-decode loop — KV-cached
/// single-token steps (the serving default) or the full-buffer replay
/// reference; both produce identical tokens, the cached loop just
/// serves them a `seq_len`-factor cheaper.
pub fn serve_demo_native(
    manifest: &crate::model::Manifest,
    pair: &str,
    n_requests: usize,
    workers: usize,
    mode: Mode,
    decode: DecodePolicy,
) -> Result<ServeStats> {
    let info = manifest
        .pairs
        .get(pair)
        .ok_or_else(|| anyhow::anyhow!("unknown language pair {pair}"))?;
    let corpus = Corpus::load(&info.corpus)?;
    let model = crate::model::PairModel::load(manifest, pair)?;
    let weights: Vec<&crate::tensor::Matrix> =
        manifest.linears.iter().map(|l| model.linear(&l.name)).collect();
    let cm = super::compress_model_from(
        &manifest.linears,
        &weights,
        &Method::QuantOnly { wl: 8 },
        None,
        workers,
    );
    let backend = cm.native_backend_mode(manifest, &model, mode, workers)?.with_decode(decode);
    run_demo(
        &backend,
        corpus,
        &manifest.model,
        n_requests,
        &format!("{pair}, W8A8, {} exec, {} decode", mode.key(), decode.key()),
    )
}

/// Serving demo over the PJRT runtime (kept for artifact parity runs).
#[cfg(feature = "pjrt")]
pub fn serve_demo(c: &Coordinator, pair: &str, n_requests: usize) -> Result<ServeStats> {
    let corpus = Corpus::load(&c.manifest.pairs[pair].corpus)?;
    let session = TranslateSession::new(&c.engine, &c.manifest, Mode::Dense)?;
    // Serve the W8A8 quantized model — the deployment configuration.
    let cm = c.compress(pair, &Method::QuantOnly { wl: 8 });
    let bank = session.build_bank(c.model(pair), &cm.layers, cm.act_wl)?;
    let backend = PjrtBackend::new(session, bank);
    run_demo(&backend, corpus, &c.manifest.model, n_requests, &format!("{pair}, W8A8"))
}

/// Compressed-model variants available to the serving example.
#[cfg(feature = "pjrt")]
pub fn serve_bank<'a>(
    c: &'a Coordinator,
    session: &TranslateSession,
    pair: &str,
    method: &Method,
) -> Result<crate::runtime::ArgBank> {
    let cm = c.compress(pair, method);
    session.build_bank(c.model(pair), &cm.layers, cm.act_wl)
}

#[cfg(test)]
mod tests {
    use super::*;

    use std::cell::Cell;

    /// Echo backend: "translates" by returning the source buffer and
    /// records the size of the last call for shape assertions.
    struct Echo {
        batch: usize,
        seq: usize,
        fixed: bool,
        last_len: Cell<usize>,
    }

    impl Echo {
        fn new(batch: usize, seq: usize, fixed: bool) -> Echo {
            Echo { batch, seq, fixed, last_len: Cell::new(0) }
        }
    }

    impl TranslateBackend for Echo {
        fn kind(&self) -> &'static str {
            "echo"
        }
        fn batch(&self) -> usize {
            self.batch
        }
        fn seq_len(&self) -> usize {
            self.seq
        }
        fn fixed_shape(&self) -> bool {
            self.fixed
        }
        fn translate(&self, src_tokens: &[i32]) -> Result<Vec<i32>> {
            if self.fixed {
                assert_eq!(src_tokens.len(), self.batch * self.seq, "fixed-shape call");
            } else {
                assert!(
                    !src_tokens.is_empty() && src_tokens.len() % self.seq == 0,
                    "variable-shape call must still be row-aligned"
                );
            }
            self.last_len.set(src_tokens.len());
            Ok(src_tokens.to_vec())
        }
    }

    fn dims(seq_len: usize, eval_batch: usize) -> ModelDims {
        ModelDims {
            vocab: 16,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            n_enc: 1,
            n_dec: 1,
            seq_len,
            eval_batch,
            pad_id: 0,
            bos_id: 1,
            eos_id: 2,
        }
    }

    #[test]
    fn pack_rows_pads_and_truncates() {
        let rows: Vec<&[i32]> = vec![&[1, 5, 6, 2], &[1, 9, 2, 7, 7, 7]];
        let src = pack_rows(&rows, 3, 5, 0);
        assert_eq!(src.len(), 15);
        assert_eq!(&src[..5], &[1, 5, 6, 2, 0]); // padded
        assert_eq!(&src[5..10], &[1, 9, 2, 7, 7]); // truncated at seq
        assert_eq!(&src[10..], &[0; 5]); // empty slot stays PAD
    }

    #[test]
    #[should_panic(expected = "exceed batch capacity")]
    fn pack_rows_rejects_overfull() {
        let rows: Vec<&[i32]> = vec![&[1], &[2], &[3]];
        pack_rows(&rows, 2, 4, 0);
    }

    #[test]
    fn serve_loop_batches_and_responds() {
        let backend = Echo::new(4, 6, true);
        let d = dims(6, 4);
        let (tx, rx) = mpsc::channel::<Request>();
        // Queue 5 requests up-front: expect one full batch + one single.
        let mut receivers = Vec::new();
        for i in 0..5 {
            let (rtx, rrx) = mpsc::channel();
            tx.send(Request {
                tokens: vec![1, 10 + i, 2],
                t_arrival: Instant::now(),
                respond: rtx,
            })
            .unwrap();
            receivers.push(rrx);
        }
        drop(tx);
        let stats = serve_loop(&backend, &rx, &d, 5).unwrap();
        assert_eq!(stats.served, 5);
        assert_eq!(stats.batches, 2, "4-capacity batcher must split 5 into 4+1");
        assert_eq!(stats.tokens, 5, "one de-framed token per echoed request");
        assert_eq!(stats.latency.count(), 5, "one server-side latency sample per request");
        assert!(stats.tokens_per_s() > 0.0);
        for (i, rrx) in receivers.into_iter().enumerate() {
            let (toks, lat) = rrx.recv().unwrap();
            // Echo + strip_specials leaves exactly the content token.
            assert_eq!(toks, vec![10 + i as i32]);
            assert!(lat >= 0.0);
        }
    }

    #[test]
    fn serve_loop_stops_on_disconnect() {
        let backend = Echo::new(2, 4, true);
        let d = dims(4, 2);
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        let stats = serve_loop(&backend, &rx, &d, 10).unwrap();
        assert_eq!(stats.served, 0);
        assert_eq!(stats.batches, 0);
        assert_eq!(stats.tokens, 0);
        assert_eq!(stats.latency.count(), 0);
    }

    #[test]
    fn serve_loop_packs_partial_batches_for_variable_shape_backends() {
        let backend = Echo::new(4, 6, false);
        let d = dims(6, 4);
        let (tx, rx) = mpsc::channel::<Request>();
        // A single queued request: the variable-shape path must translate
        // exactly one row (Echo asserts the buffer never exceeds what was
        // packed; a full-capacity pad would be 4 rows).
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            tokens: vec![1, 42, 2],
            t_arrival: Instant::now(),
            respond: rtx,
        })
        .unwrap();
        drop(tx);
        let stats = serve_loop(&backend, &rx, &d, 1).unwrap();
        assert_eq!(stats.served, 1);
        assert_eq!(backend.last_len.get(), 6, "one row packed, not the full capacity");
        let (toks, _) = rrx.recv().unwrap();
        assert_eq!(toks, vec![42]);
    }
}
