//! End-to-end tests of the serving telemetry: `/metrics` and
//! `/v1/stats` scraped from a live `serve_http` instance.
//!
//! The load-bearing assertions:
//!
//! * under the seeded load generator, the live `/metrics` exposition
//!   balances (`serve_received_total` equals the sum over the outcome
//!   counters), agrees with `/v1/stats` (both render the same
//!   registry), agrees with the client's own ledger (every 200 the
//!   client saw is in the server's counters, token for token), and the
//!   end-of-run `ServeStats` is the same snapshot again;
//! * a mixed-outcome workload (served + shed + expired) attributes
//!   every terminal outcome to exactly one pipeline stage in
//!   `serve_outcomes_total{outcome,stage}`, and the non-served outcomes
//!   surface as postmortem events on `/v1/stats`.

use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use itera_llm::coordinator::ServeConfig;
use itera_llm::model::ModelDims;
use itera_llm::obs::{key, parse_text};
use itera_llm::runtime::SlotEngine;
use itera_llm::server::http::{write_request, HttpConn};
use itera_llm::server::loadgen::{http_get, run_loadgen, LoadGenConfig};
use itera_llm::server::{serve_http, HttpConfig};
use itera_llm::util::json::Json;

/// Echo engine: completes after `need` decode steps, each sleeping
/// `step_ms` — slow variants keep slots live long enough for deadline
/// expiry and queue overflow to be deterministic over real sockets.
struct EchoSlots {
    seq: usize,
    need: usize,
    step_ms: u64,
}

struct EchoSlot {
    row: Vec<i32>,
    steps: usize,
}

impl SlotEngine for EchoSlots {
    type Slot = EchoSlot;
    fn slot_seq_len(&self) -> usize {
        self.seq
    }
    fn admit(&self, src_row: &[i32]) -> anyhow::Result<EchoSlot> {
        Ok(EchoSlot { row: src_row.to_vec(), steps: 0 })
    }
    fn step(&self, slots: &mut [&mut EchoSlot]) -> anyhow::Result<()> {
        if self.step_ms > 0 {
            std::thread::sleep(Duration::from_millis(self.step_ms));
        }
        for s in slots.iter_mut() {
            s.steps += 1;
        }
        Ok(())
    }
    fn slot_complete(&self, slot: &EchoSlot) -> bool {
        slot.steps >= self.need
    }
    fn slot_output(&self, slot: &EchoSlot) -> Vec<i32> {
        slot.row.clone()
    }
}

fn tiny_dims(seq_len: usize) -> ModelDims {
    ModelDims {
        vocab: 32,
        d_model: 8,
        n_heads: 2,
        d_ff: 16,
        n_enc: 1,
        n_dec: 1,
        seq_len,
        eval_batch: 4,
        pad_id: 0,
        bos_id: 1,
        eos_id: 2,
    }
}

fn shutdown(addr: std::net::SocketAddr) {
    let mut conn = HttpConn::new(TcpStream::connect(addr).unwrap());
    write_request(conn.get_mut(), "POST", "/v1/shutdown", None).unwrap();
    assert_eq!(conn.read_response().unwrap().status, 202);
}

/// Scrape `/metrics` (parsed exposition) and `/v1/stats` (JSON) from a
/// live server.
fn scrape(addr: std::net::SocketAddr) -> (std::collections::BTreeMap<String, f64>, Json) {
    let metrics = http_get(addr, "/metrics").expect("GET /metrics");
    assert_eq!(metrics.status, 200);
    assert!(
        metrics.header("content-type").unwrap_or("").starts_with("text/plain"),
        "Prometheus exposition is text/plain"
    );
    let text = String::from_utf8(metrics.body).expect("utf-8 exposition");
    let stats = http_get(addr, "/v1/stats").expect("GET /v1/stats");
    assert_eq!(stats.status, 200);
    (parse_text(&text), stats.json().expect("stats JSON"))
}

/// THE observability acceptance bar: `/metrics` and `/v1/stats` on a
/// live loaded server balance, agree with each other, agree with the
/// load generator's ledger, and the end-of-run `ServeStats` renders
/// from the same registry.
#[test]
fn live_metrics_agree_with_loadgen_ledger_and_final_stats() {
    const N: usize = 24;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let engine = EchoSlots { seq: 16, need: 1, step_ms: 0 };
        serve_http(&engine, listener, &tiny_dims(16), HttpConfig::new(ServeConfig::new(4)))
            .unwrap()
    });

    let cfg = LoadGenConfig {
        connections: 4,
        requests: N,
        rate: 400.0,
        len_range: (2, 6),
        vocab: 16,
        ..LoadGenConfig::default()
    };
    let report = run_loadgen(addr, &cfg).unwrap();
    assert_eq!(report.ok, N, "unloaded echo server answers everything: {:?}", report.errors);

    // Scrape while the server is still live — this is the whole point.
    let (m, stats_json) = scrape(addr);
    let counter = |name: &str| m.get(name).copied().unwrap_or(0.0);
    let outcome = |o: &str| counter(&key("serve_requests_total", &[("outcome", o)]));

    // The exported accounting identity holds mid-flight.
    let outcomes: f64 =
        ["served", "shed", "expired", "cancelled", "faulted"].iter().map(|o| outcome(o)).sum();
    assert_eq!(counter("serve_received_total"), outcomes, "exported identity must balance");

    // The server's counters agree with the client's ledger.
    assert_eq!(outcome("served") as usize, report.ok);
    assert_eq!(counter("serve_received_total") as usize, report.sent);
    assert_eq!(counter("serve_tokens_total") as usize, report.tokens, "token-for-token");
    assert_eq!(counter("serve_latency_seconds_count") as usize, N);
    assert_eq!(counter("serve_queue_wait_seconds_count") as usize, N);
    let translate_key =
        key("http_requests_total", &[("route", "/v1/translate"), ("status", "200")]);
    assert_eq!(counter(&translate_key) as usize, N, "HTTP layer counts every translate");
    assert!(counter("http_bytes_read_total") > 0.0);
    assert!(counter("http_bytes_written_total") > 0.0);
    assert!(counter("batcher_decode_steps_total") >= 1.0);

    // `/v1/stats` renders the same registry the exposition does.
    let jc = |name: &str| stats_json.get("metrics").get("counters").get(name).as_f64();
    assert_eq!(jc("serve_received_total"), Some(counter("serve_received_total")));
    assert_eq!(jc("serve_tokens_total"), Some(counter("serve_tokens_total")));
    let served_key = key("serve_requests_total", &[("outcome", "served")]);
    assert_eq!(jc(&served_key), Some(outcome("served")));

    shutdown(addr);
    let stats = server.join().expect("server thread");

    // The end-of-run report is the same snapshot again.
    assert_eq!(stats.served, N);
    assert_eq!(stats.received, N);
    assert_eq!(stats.tokens, report.tokens);
    assert_eq!(stats.latency.count(), N);
    assert!(stats.is_balanced(), "accounting identity violated: {stats:?}");
}

/// POST one translate body and return (status, parsed body).
fn post_translate(
    conn: &mut HttpConn<TcpStream>,
    tokens: &[i32],
    extra: Vec<(&str, Json)>,
) -> (u16, Json) {
    let mut fields = vec![(
        "tokens",
        Json::Arr(tokens.iter().map(|&t| Json::Num(f64::from(t))).collect()),
    )];
    fields.extend(extra);
    let body = Json::obj(fields);
    write_request(conn.get_mut(), "POST", "/v1/translate", Some(&body)).unwrap();
    let resp = conn.read_response().unwrap();
    let j = resp.json().unwrap_or(Json::Null);
    (resp.status, j)
}

/// A mixed-outcome workload attributes every terminal outcome to
/// exactly one pipeline stage, and the dead requests surface as
/// postmortem events on `/v1/stats`.
#[test]
fn traces_attribute_every_outcome_to_a_stage() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let engine = EchoSlots { seq: 8, need: 300, step_ms: 1 };
        let mut serve_cfg = ServeConfig::new(1);
        serve_cfg.queue_limit = Some(1);
        serve_http(&engine, listener, &tiny_dims(8), HttpConfig::new(serve_cfg)).unwrap()
    });

    // A occupies the single slot and expires at step 100 (decode stage).
    let a = std::thread::spawn(move || {
        let mut conn = HttpConn::new(TcpStream::connect(addr).unwrap());
        post_translate(&mut conn, &[1, 7, 2], vec![("deadline_steps", Json::Num(100.0))])
    });
    // C queues behind A and completes once the slot frees (respond).
    std::thread::sleep(Duration::from_millis(20));
    let c = std::thread::spawn(move || {
        let mut conn = HttpConn::new(TcpStream::connect(addr).unwrap());
        post_translate(&mut conn, &[1, 9, 2], vec![])
    });
    // B arrives over capacity + queue bound: shed at submit.
    std::thread::sleep(Duration::from_millis(30));
    let mut conn = HttpConn::new(TcpStream::connect(addr).unwrap());
    let (status, _) = post_translate(&mut conn, &[1, 5, 2], vec![]);
    assert_eq!(status, 503);
    let (status, _) = a.join().expect("client A");
    assert_eq!(status, 504);
    let (status, _) = c.join().expect("client C");
    assert_eq!(status, 200);

    let (m, stats_json) = scrape(addr);
    let attributed = |o: &str, s: &str| {
        m.get(&key("serve_outcomes_total", &[("outcome", o), ("stage", s)]))
            .copied()
            .unwrap_or(0.0)
    };
    assert_eq!(attributed("shed", "submit"), 1.0, "queue overflow dies at submit");
    assert_eq!(attributed("expired", "decode"), 1.0, "deadline expiry dies in decode");
    assert_eq!(attributed("retired", "respond"), 1.0, "the survivor reaches respond");

    // Every terminal outcome carries exactly one stage attribution.
    let attributions: f64 = m
        .iter()
        .filter(|(k, _)| k.starts_with("serve_outcomes_total{"))
        .map(|(_, v)| *v)
        .sum();
    assert_eq!(attributions, m.get("serve_received_total").copied().unwrap_or(0.0));

    // The dead requests are on the postmortem ring with outcome, stage
    // and detail populated; the served request is not an event.
    let events = stats_json.get("events").as_arr().expect("events array").to_vec();
    assert_eq!(events.len(), 2, "shed + expired (the served request is not a postmortem)");
    let kinds: Vec<(String, String)> = events
        .iter()
        .map(|e| {
            assert!(!e.get("detail").as_str().unwrap_or("").is_empty(), "detail populated");
            (
                e.get("outcome").as_str().unwrap_or("").to_string(),
                e.get("stage").as_str().unwrap_or("").to_string(),
            )
        })
        .collect();
    assert!(kinds.contains(&("shed".to_string(), "submit".to_string())), "{kinds:?}");
    assert!(kinds.contains(&("expired".to_string(), "decode".to_string())), "{kinds:?}");

    shutdown(addr);
    let stats = server.join().expect("server thread");
    assert_eq!((stats.served, stats.shed, stats.expired), (1, 1, 1));
    assert!(stats.is_balanced(), "accounting identity violated: {stats:?}");
}
