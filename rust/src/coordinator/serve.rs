//! Batched serving demo: a minimal request loop over any translate
//! backend, under either batching discipline.
//!
//! Demonstrates the deployment story: single-sentence translation
//! requests arrive on a channel and are answered with a typed terminal
//! outcome — de-framed tokens + latency ([`Response`]) or a
//! [`ServeError`] — by one of two server loops:
//!
//! * **static** ([`serve_loop`]) — group whatever is queued up to the
//!   backend's batch capacity, execute one monolithic translate call per
//!   batch (stragglers pin the batch), respond, repeat. Backend-agnostic
//!   ([`TranslateBackend`]): the same code path serves the always-built
//!   native engine and — with the `pjrt` feature — the AOT-compiled PJRT
//!   session.
//! * **continuous** ([`serve_loop_continuous`]) — drive a
//!   [`ContinuousBatcher`] over any slot engine
//!   ([`crate::runtime::SlotEngine`]): between decode steps, retire
//!   EOS'd slots, admit queued requests into the freed capacity, and
//!   step the mixed-age batch — the decode engine never idles while work
//!   is queued, and responses are **bit-identical** to the static loop's
//!   (slot independence; pinned by the serving soak test).
//!
//! The continuous loop carries the fault-tolerance layer
//! ([`super::fault`]): bounded admission sheds with `Overloaded`
//! ([`ServeConfig::queue_limit`]), per-request deadlines and token
//! budgets are enforced by the batcher tick, a dropped response receiver
//! cancels its request instead of leaking the slot, engine faults and
//! panics retire only the poisoned request, and a [`ShutdownSignal`]
//! drains the loop gracefully — admissions stop, in-flight work
//! finishes, and the final [`ServeStats`] balance:
//! `received == served + shed + expired + cancelled + faulted`.
//!
//! Python is nowhere on either path. The batching logic ([`pack_rows`],
//! [`serve_loop`], the scheduler in `coordinator::scheduler`) is split
//! out of the demo driver so it can be unit-tested against mock backends
//! without threads, models or artifacts.

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::eval::{strip_specials, Corpus};
use crate::model::ModelDims;
use crate::obs::{key, Counter, Obs, Outcome, Snapshot, SummaryMetric, Trace, TraceReport};
use crate::runtime::{DecodePolicy, KernelTier, Mode, SlotEngine, TranslateBackend};
use crate::util::rng::Pcg64;
use crate::util::stats::Summary;

use super::fault::{
    response_channel, RequestLimits, Response, ResponseTx, ServeError, ShutdownSignal,
};
use super::scheduler::{Batcher, ContinuousBatcher};

#[cfg(feature = "pjrt")]
use crate::runtime::{PjrtBackend, TranslateSession};

#[cfg(feature = "pjrt")]
use super::Coordinator;
use super::Method;

/// How often the continuous loop wakes from an idle block to re-check
/// its [`ShutdownSignal`] (only when one is configured; without it the
/// loop blocks indefinitely, woken by requests alone).
const SHUTDOWN_POLL: Duration = Duration::from_millis(5);

/// One translation request: source tokens in, exactly one terminal
/// outcome out through the one-shot `respond` channel.
pub struct Request {
    pub tokens: Vec<i32>,
    pub t_arrival: Instant,
    pub respond: ResponseTx,
    /// Per-request deadline/length budget; unset fields fall back to the
    /// server's [`ServeConfig::default_limits`].
    pub limits: RequestLimits,
    /// Stream incremental decode progress through
    /// [`ResponseTx::push_tokens`] between ticks (the continuous loop
    /// only; the terminal outcome still arrives exactly once). Costs one
    /// partial-output read per decode step, so it is opt-in.
    pub stream: bool,
}

impl Request {
    pub fn new(tokens: Vec<i32>, respond: ResponseTx) -> Request {
        Request {
            tokens,
            t_arrival: Instant::now(),
            respond,
            limits: RequestLimits::none(),
            stream: false,
        }
    }

    pub fn with_limits(mut self, limits: RequestLimits) -> Request {
        self.limits = limits;
        self
    }

    /// Opt in to incremental token streaming.
    pub fn with_stream(mut self) -> Request {
        self.stream = true;
        self
    }
}

/// Serving knobs shared by [`serve_loop_continuous`] and the demo
/// drivers. [`ServeConfig::new`] gives the permissive defaults:
/// unbounded queue, no deadlines, no shutdown signal.
#[derive(Clone, Default)]
pub struct ServeConfig {
    /// Concurrent decode slots (the continuous batcher's capacity).
    pub capacity: usize,
    /// Admission-queue bound; `None` is unbounded, `Some(n)` sheds with
    /// [`ServeError::Overloaded`] once `n` requests wait.
    pub queue_limit: Option<usize>,
    /// Server-side limits applied to requests that don't carry their
    /// own ([`RequestLimits::or`]).
    pub default_limits: RequestLimits,
    /// Graceful-shutdown signal; when set, the loop polls it while idle
    /// and drains (no new admissions, in-flight work finishes) once
    /// flipped.
    pub shutdown: Option<ShutdownSignal>,
    /// Telemetry sink for this run. Defaults to an isolated
    /// [`Obs::fresh`] registry so concurrent serve loops (as under
    /// `cargo test`) never share accounting; hand the same handle to an
    /// HTTP front end to expose the loop's live metrics on `/metrics`
    /// and `/v1/stats`. The end-of-run [`ServeStats`] is derived from a
    /// snapshot of this registry ([`ServeStats::from_snapshot`]), so
    /// there is exactly one source of accounting truth per run.
    pub obs: Obs,
}

impl ServeConfig {
    pub fn new(capacity: usize) -> ServeConfig {
        ServeConfig { capacity, ..ServeConfig::default() }
    }
}

/// Aggregate outcome of one [`serve_loop`] / [`serve_loop_continuous`]
/// run.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Successful responses sent.
    pub served: usize,
    /// Requests taken off the channel. Balances on every run (clean,
    /// overloaded, faulted, or drained):
    /// `received == served + shed + expired + cancelled + faulted` —
    /// every request taken off the channel gets exactly one outcome.
    pub received: usize,
    /// Static loop: translate calls. Continuous loop: decode steps.
    pub batches: usize,
    pub wall_s: f64,
    /// Generated (de-framed) output tokens across all responses — the
    /// numerator of the serving throughput number.
    pub tokens: usize,
    /// Per-request latency samples (seconds, arrival to response), as
    /// observed by the server loop itself. Successful responses only.
    pub latency: Summary,
    /// Queue-wait component of `latency`: arrival to admission (static
    /// loop: arrival to batch formation). Together with `execution` this
    /// attributes tail latency to admission pressure vs compute —
    /// `latency ≈ queue_wait + execution` per request.
    pub queue_wait: Summary,
    /// Execution component of `latency`: admission to response (static
    /// loop: the translate call).
    pub execution: Summary,
    /// Mean fraction of batch/slot capacity occupied per translate call
    /// (static) or decode step (continuous), in `[0, 1]`.
    pub occupancy: f64,
    /// Requests shed at admission with [`ServeError::Overloaded`].
    pub shed: usize,
    /// Requests retired with [`ServeError::DeadlineExceeded`].
    pub expired: usize,
    /// Requests cancelled after their client disconnected.
    pub cancelled: usize,
    /// Requests retired with [`ServeError::EngineFault`].
    pub faulted: usize,
    /// Live slots evicted under KV memory pressure and requeued
    /// (continuous loop with a byte budget only). **Non-terminal** —
    /// preempted requests still end in exactly one of the outcomes
    /// above, so this is not part of [`Self::is_balanced`].
    pub preempted: usize,
}

impl ServeStats {
    /// Generated tokens per wall-clock second over the whole run.
    pub fn tokens_per_s(&self) -> f64 {
        self.tokens as f64 / self.wall_s.max(1e-12)
    }

    /// Requests that ended in a typed error (the non-`served` outcomes).
    pub fn failed(&self) -> usize {
        self.shed + self.expired + self.cancelled + self.faulted
    }

    /// The accounting identity every run must satisfy.
    pub fn is_balanced(&self) -> bool {
        self.received == self.served + self.failed()
    }

    /// Derive the end-of-run report from a registry [`Snapshot`] — the
    /// same source `GET /metrics` and `GET /v1/stats` render from, so
    /// the report can never drift from the exported metrics. Counters
    /// are lifetime-of-registry totals; pair one registry with one run.
    pub fn from_snapshot(snap: &Snapshot, wall_s: f64) -> ServeStats {
        let outcome =
            |o: &str| snap.counter(&key("serve_requests_total", &[("outcome", o)])) as usize;
        let steps = snap.counter("batcher_decode_steps_total") as usize;
        let occupied = snap.counter("batcher_occupied_slot_steps_total") as f64;
        let capacity = snap.gauge("batcher_capacity");
        ServeStats {
            served: outcome("served"),
            received: snap.counter("serve_received_total") as usize,
            batches: steps,
            wall_s,
            tokens: snap.counter("serve_tokens_total") as usize,
            latency: snap.summary("serve_latency_seconds"),
            queue_wait: snap.summary("serve_queue_wait_seconds"),
            execution: snap.summary("serve_execution_seconds"),
            occupancy: if steps == 0 || capacity <= 0.0 {
                0.0
            } else {
                occupied / (steps as f64 * capacity)
            },
            shed: outcome("shed"),
            expired: outcome("expired"),
            cancelled: outcome("cancelled"),
            faulted: outcome("faulted"),
            preempted: snap.counter("batcher_preempted_total") as usize,
        }
    }
}

/// Registry handles for the continuous serve loop's accounting: one
/// terminal-outcome counter family, received/token counters and the
/// latency summaries. Created per run against [`ServeConfig::obs`];
/// every increment lands in the registry and nowhere else, and
/// [`ServeStats::from_snapshot`] reads the run's report back out — the
/// single-source fix for the stats double-bookkeeping risk.
struct ServeMetrics {
    obs: Obs,
    received: Arc<Counter>,
    served: Arc<Counter>,
    shed: Arc<Counter>,
    expired: Arc<Counter>,
    cancelled: Arc<Counter>,
    faulted: Arc<Counter>,
    tokens: Arc<Counter>,
    latency: Arc<SummaryMetric>,
    queue_wait: Arc<SummaryMetric>,
    execution: Arc<SummaryMetric>,
}

impl ServeMetrics {
    fn new(obs: &Obs) -> ServeMetrics {
        let reg = obs.registry();
        let outcome = |o| reg.counter_with("serve_requests_total", &[("outcome", o)]);
        ServeMetrics {
            obs: obs.clone(),
            received: reg.counter("serve_received_total"),
            served: outcome("served"),
            shed: outcome("shed"),
            expired: outcome("expired"),
            cancelled: outcome("cancelled"),
            faulted: outcome("faulted"),
            tokens: reg.counter("serve_tokens_total"),
            latency: reg.summary("serve_latency_seconds"),
            queue_wait: reg.summary("serve_queue_wait_seconds"),
            execution: reg.summary("serve_execution_seconds"),
        }
    }

    /// Record one closed trace: the outcome counter, the per-stage
    /// attribution counter (`serve_outcomes_total{outcome,stage}`), and
    /// — for every outcome that is not a normal response — a postmortem
    /// ring event.
    fn finish(&self, report: &TraceReport, detail: &str) {
        let counter = match report.outcome {
            Outcome::Retired => &self.served,
            Outcome::Shed => &self.shed,
            Outcome::Expired => &self.expired,
            Outcome::Cancelled => &self.cancelled,
            Outcome::Faulted => &self.faulted,
        };
        counter.inc();
        let labels = [("outcome", report.outcome.key()), ("stage", report.stage.key())];
        self.obs.registry().counter_with("serve_outcomes_total", &labels).inc();
        if report.outcome != Outcome::Retired {
            self.obs.ring().push(
                report.id,
                report.outcome.key(),
                report.stage.key(),
                detail.to_string(),
            );
        }
    }

    /// Record the latency split + token count of a served response.
    fn served_latency(&self, report: &TraceReport, n_tokens: usize) {
        self.tokens.add(n_tokens as u64);
        self.latency.observe(report.total_s);
        self.queue_wait.observe(report.queue_s);
        self.execution.observe(report.decode_s);
    }
}

/// Pack up to `batch` token rows into a fixed `[batch * seq]` buffer:
/// rows are truncated to `seq` and the remainder is PAD-filled (both the
/// tail of short rows and the unused batch slots).
pub fn pack_rows(rows: &[&[i32]], batch: usize, seq: usize, pad: i32) -> Vec<i32> {
    assert!(rows.len() <= batch, "{} rows exceed batch capacity {batch}", rows.len());
    let mut src = vec![pad; batch * seq];
    for (row, tokens) in rows.iter().enumerate() {
        let take = tokens.len().min(seq);
        src[row * seq..row * seq + take].copy_from_slice(&tokens[..take]);
    }
    src
}

/// Drain one batch from the request channel: block for the **first**
/// request only, then opportunistically take whatever else is already
/// queued, up to `capacity`. `None` when the channel has disconnected.
///
/// Blocking past the first request would be head-of-line blocking — the
/// loop would wait indefinitely for a full batch while admitted clients
/// hold their responses. Partial batches must flush; pinned by the
/// `partial_batch_flushes_without_disconnect` regression test.
fn next_batch(rx: &mpsc::Receiver<Request>, capacity: usize) -> Option<Vec<Request>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    while batch.len() < capacity {
        match rx.try_recv() {
            Ok(r) => batch.push(r),
            Err(_) => break,
        }
    }
    Some(batch)
}

/// The static server loop: batch requests off `rx`, execute them on
/// `backend`, respond with de-framed tokens + latency, until
/// `n_requests` have received an outcome or the channel disconnects.
/// A failing translate call faults only its own batch (each member gets
/// [`ServeError::EngineFault`]); the loop keeps serving.
pub fn serve_loop(
    backend: &dyn TranslateBackend,
    rx: &mpsc::Receiver<Request>,
    dims: &ModelDims,
    n_requests: usize,
) -> Result<ServeStats> {
    let b = backend.batch();
    let s = backend.seq_len();
    let t0 = Instant::now();
    let mut served = 0usize;
    let mut received = 0usize;
    let mut cancelled = 0usize;
    let mut faulted = 0usize;
    let mut batches = 0usize;
    let mut tokens = 0usize;
    let mut occupied_rows = 0usize;
    let mut latency = Summary::new();
    let mut queue_wait = Summary::new();
    let mut execution = Summary::new();
    while served + cancelled + faulted < n_requests {
        let Some(batch) = next_batch(rx, b) else { break };
        received += batch.len();
        occupied_rows += batch.len();
        let rows: Vec<&[i32]> = batch.iter().map(|r| r.tokens.as_slice()).collect();
        // Fixed-shape backends (AOT artifacts) need the full compiled
        // batch; variable-shape ones only pay for the rows they got.
        let pack_to = if backend.fixed_shape() { b } else { rows.len() };
        let src = pack_rows(&rows, pack_to, s, dims.pad_id);
        batches += 1;
        let t_exec = Instant::now();
        let out = match backend.translate(&src) {
            Ok(out) => out,
            Err(e) => {
                // The whole batch shares the translate call, so the
                // fault is attributed to every member — typed errors,
                // not a dead server.
                for req in batch {
                    req.respond.send(Err(ServeError::EngineFault(format!("{e:#}"))));
                    faulted += 1;
                }
                continue;
            }
        };
        let now = Instant::now();
        for (row, req) in batch.into_iter().enumerate() {
            let toks = strip_specials(
                &out[row * s..(row + 1) * s],
                dims.bos_id,
                dims.eos_id,
                dims.pad_id,
            );
            let lat = now.duration_since(req.t_arrival).as_secs_f64();
            tokens += toks.len();
            latency.add(lat);
            queue_wait.add(t_exec.duration_since(req.t_arrival).as_secs_f64());
            execution.add(now.duration_since(t_exec).as_secs_f64());
            if req.respond.send(Ok(Response { tokens: toks, latency_s: lat })) {
                served += 1;
            } else {
                // Receiver gone: the work was done, but nobody read it.
                cancelled += 1;
            }
        }
    }
    Ok(ServeStats {
        served,
        received,
        batches,
        wall_s: t0.elapsed().as_secs_f64(),
        tokens,
        latency,
        queue_wait,
        execution,
        occupancy: occupied_rows as f64 / (batches * b).max(1) as f64,
        shed: 0,
        expired: 0,
        cancelled,
        faulted,
        preempted: 0,
    })
}

/// The continuous server loop: drive a [`ContinuousBatcher`] over a slot
/// engine. Each round drains whatever the channel already holds into the
/// admission queue (shedding with [`ServeError::Overloaded`] past
/// `cfg.queue_limit`), cancels requests whose clients disconnected,
/// ticks the batcher — expire, retire, admit, one mixed-age decode
/// step — and delivers every completion's terminal outcome. Runs until
/// `n_requests` outcomes are delivered, or the channel disconnects and
/// the backlog drains, or `cfg.shutdown` flips and the drain finishes.
/// Successful responses are bit-identical to the static loop's for the
/// same requests (slot independence), whatever faults hit other slots.
pub fn serve_loop_continuous<E: SlotEngine>(
    engine: &E,
    rx: &mpsc::Receiver<Request>,
    dims: &ModelDims,
    n_requests: usize,
    cfg: &ServeConfig,
) -> Result<ServeStats> {
    let s = engine.slot_seq_len();
    let t0 = Instant::now();
    let metrics = ServeMetrics::new(&cfg.obs);
    let mut batcher = ContinuousBatcher::new(engine, cfg.capacity).with_obs(&cfg.obs);
    if let Some(limit) = cfg.queue_limit {
        batcher = batcher.with_queue_limit(limit);
    }
    let mut inflight: HashMap<u64, Inflight> = HashMap::new();
    // `received`/`done` drive loop termination; all exported accounting
    // lives in `metrics` (the registry), nowhere else.
    let mut received = 0usize;
    let mut done = 0usize;
    let mut disconnected = false;
    loop {
        let draining = cfg.shutdown.as_ref().is_some_and(|sig| sig.is_draining());
        if draining && !batcher.draining() {
            batcher.begin_drain();
        }
        if batcher.idle() {
            if done >= n_requests || received >= n_requests || disconnected || draining {
                break;
            }
            // Block for a request only when a tick would be an idle
            // no-op — with a poll interval when a shutdown signal could
            // arrive while we sleep.
            let first = match &cfg.shutdown {
                None => rx.recv().map_err(|_| ()),
                Some(_) => match rx.recv_timeout(SHUTDOWN_POLL) {
                    Ok(req) => Ok(req),
                    Err(mpsc::RecvTimeoutError::Timeout) => continue,
                    Err(mpsc::RecvTimeoutError::Disconnected) => Err(()),
                },
            };
            match first {
                Ok(req) => {
                    received += 1;
                    metrics.received.inc();
                    admit_or_shed(req, cfg, &metrics, s, dims.pad_id, &mut batcher, &mut inflight);
                }
                Err(()) => {
                    disconnected = true;
                    continue;
                }
            }
        }
        // Opportunistically drain the channel between steps.
        while received < n_requests && !disconnected && !draining {
            match rx.try_recv() {
                Ok(req) => {
                    received += 1;
                    metrics.received.inc();
                    admit_or_shed(req, cfg, &metrics, s, dims.pad_id, &mut batcher, &mut inflight);
                }
                Err(mpsc::TryRecvError::Disconnected) => disconnected = true,
                Err(mpsc::TryRecvError::Empty) => break,
            }
        }
        // Cancel orphans: a dropped response receiver means nobody will
        // read the answer — retire the slot now instead of decoding to
        // EOS for nobody (the slot-leak fix).
        let orphans: Vec<u64> = inflight
            .iter()
            .filter(|(_, inf)| inf.req.respond.is_disconnected())
            .map(|(&id, _)| id)
            .collect();
        for id in orphans {
            let was_live = batcher.is_live(id);
            if batcher.cancel(id) {
                if let Some(inf) = inflight.remove(&id) {
                    let report = inf.trace.finish(Outcome::Cancelled, was_live, Instant::now());
                    metrics.finish(&report, "client disconnected");
                }
                done += 1;
            }
        }
        let t_tick = Instant::now();
        for c in batcher.tick() {
            let Some(mut inf) = inflight.remove(&c.id) else { continue };
            done += 1;
            // A request that entered a slot and completed within this
            // same tick was admitted at the tick boundary.
            if c.slot.is_some() {
                inf.trace.admitted(t_tick);
            }
            match c.result {
                Ok(buf) => {
                    let toks = strip_specials(&buf, dims.bos_id, dims.eos_id, dims.pad_id);
                    let report = inf.trace.finish(Outcome::Retired, true, Instant::now());
                    metrics.finish(&report, "");
                    metrics.served_latency(&report, toks.len());
                    let lat = report.total_s;
                    inf.req.respond.send(Ok(Response { tokens: toks, latency_s: lat }));
                }
                Err(e) => {
                    let outcome = match &e {
                        ServeError::DeadlineExceeded => Outcome::Expired,
                        ServeError::EngineFault(_) => Outcome::Faulted,
                        ServeError::Overloaded => Outcome::Shed,
                        ServeError::Cancelled => Outcome::Cancelled,
                    };
                    let report = inf.trace.finish(outcome, c.slot.is_some(), Instant::now());
                    metrics.finish(&report, &e.to_string());
                    inf.req.respond.send(Err(e));
                }
            }
        }
        // Post-tick bookkeeping over still-inflight requests: timestamp
        // slot entry (admission happens inside the tick, at its start —
        // the queue-wait/execution split pivots there), count the decode
        // step each live slot just took, and push each opted-in live
        // request's newly decoded tokens (its partial output past what
        // was already pushed). Completions this tick were removed above,
        // so their tail tokens travel with the terminal Response instead.
        for (id, inf) in inflight.iter_mut() {
            if batcher.is_live(*id) {
                inf.trace.admitted(t_tick);
                inf.trace.step();
            }
            if !inf.req.stream {
                continue;
            }
            if let Some(buf) = batcher.peek_output(*id) {
                let toks = strip_specials(&buf, dims.bos_id, dims.eos_id, dims.pad_id);
                if toks.len() > inf.streamed {
                    inf.req.respond.push_tokens(&toks[inf.streamed..]);
                    inf.streamed = toks.len();
                }
            }
        }
        if done >= n_requests {
            break;
        }
    }
    // The end-of-run report IS the registry snapshot — the same data
    // `/metrics` and `/v1/stats` serve, read back once at the end.
    let snap = cfg.obs.registry().snapshot();
    Ok(ServeStats::from_snapshot(&snap, t0.elapsed().as_secs_f64()))
}

/// One submitted request plus the serve loop's bookkeeping: its live
/// [`Trace`] (submit/admit timestamps + step count — the pivot of the
/// queue-wait/execution latency split) and how many tokens have already
/// been streamed to its client.
struct Inflight {
    req: Request,
    trace: Trace,
    streamed: usize,
}

/// Pack, apply server-side default limits, and submit one request; on
/// [`ServeError::Overloaded`] the client is answered immediately — a
/// shed trace attributed to the submit stage — and the request never
/// enters `inflight`.
fn admit_or_shed<E: SlotEngine>(
    req: Request,
    cfg: &ServeConfig,
    metrics: &ServeMetrics,
    seq: usize,
    pad: i32,
    batcher: &mut ContinuousBatcher<E>,
    inflight: &mut HashMap<u64, Inflight>,
) {
    let limits = req.limits.or(cfg.default_limits);
    let row = pack_rows(&[req.tokens.as_slice()], 1, seq, pad);
    match batcher.submit_with(row, limits) {
        Ok(id) => {
            let trace = Trace::begin(id, req.t_arrival);
            inflight.insert(id, Inflight { req, trace, streamed: 0 });
        }
        Err(e) => {
            let report =
                Trace::begin(0, req.t_arrival).finish(Outcome::Shed, false, Instant::now());
            metrics.finish(&report, &e.to_string());
            req.respond.send(Err(e));
        }
    }
}

/// Spawn the demo client: submits `n_requests` random test sentences in
/// waves of `burst` (1 = closed loop: each request waits for its
/// outcome before the next goes out; larger bursts overlap requests and
/// can drive the server into overload). Returns client-observed
/// latencies, the received translations, and the number of error
/// outcomes on join.
fn spawn_client(
    corpus: Corpus,
    n_requests: usize,
    burst: usize,
    tx: mpsc::Sender<Request>,
) -> std::thread::JoinHandle<(Summary, Vec<Vec<i32>>, usize)> {
    std::thread::spawn(move || {
        let burst = burst.max(1);
        let mut rng = Pcg64::new(0xBEEF);
        let mut latencies = Summary::new();
        let mut done = Vec::new();
        let mut errors = 0usize;
        let mut sent = 0usize;
        while sent < n_requests {
            let wave = burst.min(n_requests - sent);
            let mut pending = Vec::with_capacity(wave);
            for _ in 0..wave {
                let i = rng.below(corpus.n);
                let (rtx, rrx) = response_channel();
                let t_submit = Instant::now();
                tx.send(Request::new(corpus.src_row(i).to_vec(), rtx)).ok();
                pending.push((t_submit, rrx));
                sent += 1;
            }
            for (t_submit, rrx) in pending {
                // Latency is measured at receive time, so it includes
                // the response channel hop the server-side percentile
                // rows can't see.
                match rrx.recv() {
                    Some(Ok(resp)) => {
                        latencies.add(t_submit.elapsed().as_secs_f64());
                        done.push(resp.tokens);
                    }
                    Some(Err(_)) | None => errors += 1,
                }
            }
        }
        (latencies, done, errors)
    })
}

fn print_demo_stats(
    label: &str,
    kind: &str,
    batcher: Batcher,
    capacity: usize,
    stats: &ServeStats,
    latencies: &Summary,
    translations: &[Vec<i32>],
    client_errors: usize,
) {
    println!(
        "== serving demo ({label}, backend {kind}, {} batcher, capacity {capacity}) ==",
        batcher.key()
    );
    let unit = match batcher {
        Batcher::Static => "batches",
        Batcher::Continuous => "decode steps",
    };
    println!("requests      : {} ({} {unit})", stats.served, stats.batches);
    println!("wall time     : {:.2}s", stats.wall_s);
    println!("throughput    : {:.1} sentences/s", stats.served as f64 / stats.wall_s);
    println!(
        "tokens/sec    : {:.1} ({} generated tokens)",
        stats.tokens_per_s(),
        stats.tokens
    );
    println!("occupancy     : {:.1}% of capacity per {unit}", stats.occupancy * 100.0);
    if stats.failed() > 0 || client_errors > 0 {
        println!(
            "errors        : shed {} expired {} cancelled {} faulted {} \
             (client saw {client_errors} error outcomes)",
            stats.shed, stats.expired, stats.cancelled, stats.faulted
        );
    }
    if stats.preempted > 0 {
        println!(
            "kv pressure   : {} preemptions (evict + re-prefill; outputs unaffected)",
            stats.preempted
        );
    }
    println!(
        "latency (s)   : p50 {:.3}  p95 {:.3}  max {:.3} (client-observed)",
        latencies.quantile(0.5),
        latencies.quantile(0.95),
        latencies.max()
    );
    println!(
        "latency (s)   : p50 {:.3}  p95 {:.3}  max {:.3} (server-side, n={})",
        stats.latency.quantile(0.5),
        stats.latency.quantile(0.95),
        stats.latency.max(),
        stats.latency.count()
    );
    println!(
        "sample output : {:?}",
        translations.first().map(|t| &t[..t.len().min(8)])
    );
}

/// Closed-loop demo driver over the **static** batcher: a client thread
/// submits `n_requests` random test sentences back-to-back,
/// [`serve_loop`] batches and executes them, and the latency/throughput
/// summary is printed.
pub fn run_demo(
    backend: &dyn TranslateBackend,
    corpus: Corpus,
    dims: &ModelDims,
    n_requests: usize,
    label: &str,
) -> Result<ServeStats> {
    let (tx, rx) = mpsc::channel::<Request>();
    let client = spawn_client(corpus, n_requests, 1, tx);
    let stats = serve_loop(backend, &rx, dims, n_requests)?;
    let (latencies, translations, client_errors) = client
        .join()
        .map_err(|_| anyhow::anyhow!("serve demo client thread panicked"))?;
    print_demo_stats(
        label,
        backend.kind(),
        Batcher::Static,
        backend.batch(),
        &stats,
        &latencies,
        &translations,
        client_errors,
    );
    Ok(stats)
}

/// [`run_demo`]'s twin over the **continuous** batcher: the same demo
/// client (at `burst` requests in flight), served by
/// [`serve_loop_continuous`] under `cfg`.
#[allow(clippy::too_many_arguments)]
pub fn run_demo_continuous<E: SlotEngine>(
    engine: &E,
    kind: &str,
    cfg: &ServeConfig,
    burst: usize,
    corpus: Corpus,
    dims: &ModelDims,
    n_requests: usize,
    label: &str,
) -> Result<ServeStats> {
    let (tx, rx) = mpsc::channel::<Request>();
    let client = spawn_client(corpus, n_requests, burst, tx);
    let stats = serve_loop_continuous(engine, &rx, dims, n_requests, cfg)?;
    let (latencies, translations, client_errors) = client
        .join()
        .map_err(|_| anyhow::anyhow!("serve demo client thread panicked"))?;
    print_demo_stats(
        label,
        kind,
        Batcher::Continuous,
        cfg.capacity,
        &stats,
        &latencies,
        &translations,
        client_errors,
    );
    Ok(stats)
}

/// Robustness knobs for [`serve_demo_native`] (all default to the
/// permissive demo behavior). These only apply under
/// `Batcher::Continuous` — the static loop has no admission queue,
/// deadlines, or bursts to tune.
#[derive(Debug, Clone, Default)]
pub struct ServeTuning {
    /// Admission-queue bound (sheds with `Overloaded` beyond it).
    pub queue_limit: Option<usize>,
    /// Server-side default deadline/length limits.
    pub limits: RequestLimits,
    /// Demo-client burst size (requests in flight per wave; 0/1 =
    /// closed loop).
    pub burst: usize,
    /// Global KV pool byte budget (`serve --kv-budget`). `None` keeps
    /// the unbounded compatibility pool: exact residency accounting,
    /// no memory-bounded admission, no preemption.
    pub kv_budget: Option<usize>,
    /// Rows per KV page (`serve --page-tokens`); defaults to the
    /// model's `seq_len` (one page per table, the coarsest grain).
    pub page_tokens: Option<usize>,
    /// Decode kernel tier (`serve --kernel`): `Exact` (default) keeps
    /// the bit-identical fake-quant kernels; `Fast` serves packed
    /// linears through the integer A8 GEMV path (non-bit-exact, gated
    /// by `validate --kernel fast`).
    pub kernel: KernelTier,
}

/// Serving demo on the native runtime: W8A8-quantized model (the
/// deployment configuration), no PJRT anywhere. Works in every build.
///
/// `mode` picks the execution form of the quantized weights:
/// `Mode::Dense` serves fake-quant f32, `Mode::Quantized` serves the
/// bit-packed bank (same tokens bit for bit, ~4x fewer weight bytes
/// resident at W8). `decode` picks the greedy-decode loop — KV-cached
/// single-token steps (the serving default) or the full-buffer replay
/// reference; both produce identical tokens, the cached loop just
/// serves them a `seq_len`-factor cheaper. `batcher` picks the serving
/// discipline — static group-decode-respond waves, or the continuous
/// slot scheduler (requires the cached decode policy; identical tokens
/// either way, the batch just stays full under dynamic load). `tuning`
/// carries the continuous loop's robustness knobs (queue bound,
/// default deadlines, client burst).
pub fn serve_demo_native(
    manifest: &crate::model::Manifest,
    pair: &str,
    n_requests: usize,
    workers: usize,
    mode: Mode,
    decode: DecodePolicy,
    batcher: Batcher,
    tuning: &ServeTuning,
) -> Result<ServeStats> {
    let info = manifest
        .pairs
        .get(pair)
        .ok_or_else(|| anyhow::anyhow!("unknown language pair {pair}"))?;
    let corpus = Corpus::load(&info.corpus)?;
    let model = crate::model::PairModel::load(manifest, pair)?;
    let weights: Vec<&crate::tensor::Matrix> =
        manifest.linears.iter().map(|l| model.linear(&l.name)).collect();
    let cm = super::compress_model_from(
        &manifest.linears,
        &weights,
        &Method::QuantOnly { wl: 8 },
        None,
        workers,
    );
    let backend = cm
        .native_backend_mode(manifest, &model, mode, workers)?
        .with_decode(decode)
        .with_kernel(tuning.kernel);
    let label = format!(
        "{pair}, W8A8, {} exec, {} decode, {} batcher, {} kernel",
        mode.key(),
        decode.key(),
        batcher.key(),
        tuning.kernel.key()
    );
    match batcher {
        Batcher::Static => run_demo(&backend, corpus, &manifest.model, n_requests, &label),
        Batcher::Continuous => {
            anyhow::ensure!(
                decode == DecodePolicy::Cached,
                "the continuous batcher schedules KV slots; it requires --decode cached \
                 (replay has no slot lifecycle to interleave)"
            );
            // Install a bounded/paged KV pool only when asked: the
            // scheduler then admits by bytes and preempts under
            // pressure instead of treating capacity as a slot count.
            let backend = if tuning.kv_budget.is_some() || tuning.page_tokens.is_some() {
                let pt = tuning.page_tokens.unwrap_or(manifest.model.seq_len);
                backend.with_kv_pool(tuning.kv_budget, pt)
            } else {
                backend
            };
            let mut cfg = ServeConfig::new(backend.batch());
            cfg.queue_limit = tuning.queue_limit;
            cfg.default_limits = tuning.limits;
            run_demo_continuous(
                &backend,
                "native",
                &cfg,
                tuning.burst,
                corpus,
                &manifest.model,
                n_requests,
                &label,
            )
        }
    }
}

/// Serving demo over the PJRT runtime (kept for artifact parity runs).
#[cfg(feature = "pjrt")]
pub fn serve_demo(c: &Coordinator, pair: &str, n_requests: usize) -> Result<ServeStats> {
    let corpus = Corpus::load(&c.manifest.pairs[pair].corpus)?;
    let session = TranslateSession::new(&c.engine, &c.manifest, Mode::Dense)?;
    // Serve the W8A8 quantized model — the deployment configuration.
    let cm = c.compress(pair, &Method::QuantOnly { wl: 8 });
    let bank = session.build_bank(c.model(pair), &cm.layers, cm.act_wl)?;
    let backend = PjrtBackend::new(session, bank);
    run_demo(&backend, corpus, &c.manifest.model, n_requests, &format!("{pair}, W8A8"))
}

/// Compressed-model variants available to the serving example.
#[cfg(feature = "pjrt")]
pub fn serve_bank<'a>(
    c: &'a Coordinator,
    session: &TranslateSession,
    pair: &str,
    method: &Method,
) -> Result<crate::runtime::ArgBank> {
    let cm = c.compress(pair, method);
    session.build_bank(c.model(pair), &cm.layers, cm.act_wl)
}

#[cfg(test)]
mod tests {
    use super::*;

    use std::cell::Cell;

    use crate::coordinator::fault::ResponseRx;

    /// Echo backend: "translates" by returning the source buffer and
    /// records the size of the last call for shape assertions.
    struct Echo {
        batch: usize,
        seq: usize,
        fixed: bool,
        last_len: Cell<usize>,
    }

    impl Echo {
        fn new(batch: usize, seq: usize, fixed: bool) -> Echo {
            Echo { batch, seq, fixed, last_len: Cell::new(0) }
        }
    }

    impl TranslateBackend for Echo {
        fn kind(&self) -> &'static str {
            "echo"
        }
        fn batch(&self) -> usize {
            self.batch
        }
        fn seq_len(&self) -> usize {
            self.seq
        }
        fn fixed_shape(&self) -> bool {
            self.fixed
        }
        fn translate(&self, src_tokens: &[i32]) -> Result<Vec<i32>> {
            if self.fixed {
                assert_eq!(src_tokens.len(), self.batch * self.seq, "fixed-shape call");
            } else {
                assert!(
                    !src_tokens.is_empty() && src_tokens.len() % self.seq == 0,
                    "variable-shape call must still be row-aligned"
                );
            }
            self.last_len.set(src_tokens.len());
            Ok(src_tokens.to_vec())
        }
    }

    fn dims(seq_len: usize, eval_batch: usize) -> ModelDims {
        ModelDims {
            vocab: 16,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            n_enc: 1,
            n_dec: 1,
            seq_len,
            eval_batch,
            pad_id: 0,
            bos_id: 1,
            eos_id: 2,
        }
    }

    fn send_request(tx: &mpsc::Sender<Request>, tokens: Vec<i32>) -> ResponseRx {
        let (rtx, rrx) = response_channel();
        tx.send(Request::new(tokens, rtx)).unwrap();
        rrx
    }

    fn recv_tokens(rrx: &ResponseRx) -> Vec<i32> {
        match rrx.recv() {
            Some(Ok(resp)) => resp.tokens,
            other => panic!("expected a successful response, got {other:?}"),
        }
    }

    #[test]
    fn pack_rows_pads_and_truncates() {
        let rows: Vec<&[i32]> = vec![&[1, 5, 6, 2], &[1, 9, 2, 7, 7, 7]];
        let src = pack_rows(&rows, 3, 5, 0);
        assert_eq!(src.len(), 15);
        assert_eq!(&src[..5], &[1, 5, 6, 2, 0]); // padded
        assert_eq!(&src[5..10], &[1, 9, 2, 7, 7]); // truncated at seq
        assert_eq!(&src[10..], &[0; 5]); // empty slot stays PAD
    }

    #[test]
    #[should_panic(expected = "exceed batch capacity")]
    fn pack_rows_rejects_overfull() {
        let rows: Vec<&[i32]> = vec![&[1], &[2], &[3]];
        pack_rows(&rows, 2, 4, 0);
    }

    #[test]
    fn serve_loop_batches_and_responds() {
        let backend = Echo::new(4, 6, true);
        let d = dims(6, 4);
        let (tx, rx) = mpsc::channel::<Request>();
        // Queue 5 requests up-front: expect one full batch + one single.
        let mut receivers = Vec::new();
        for i in 0..5 {
            receivers.push(send_request(&tx, vec![1, 10 + i, 2]));
        }
        drop(tx);
        let stats = serve_loop(&backend, &rx, &d, 5).unwrap();
        assert_eq!(stats.served, 5);
        assert!(stats.is_balanced(), "requests in == outcomes out: {stats:?}");
        assert_eq!(stats.batches, 2, "4-capacity batcher must split 5 into 4+1");
        assert_eq!(stats.tokens, 5, "one de-framed token per echoed request");
        assert_eq!(stats.latency.count(), 5, "one server-side latency sample per request");
        assert_eq!(stats.queue_wait.count(), 5, "latency split covers every served request");
        assert_eq!(stats.execution.count(), 5);
        assert!(
            (stats.queue_wait.mean() + stats.execution.mean() - stats.latency.mean()).abs() < 1e-6,
            "latency decomposes into queue-wait + execution: {stats:?}"
        );
        assert!(stats.tokens_per_s() > 0.0);
        for (i, rrx) in receivers.into_iter().enumerate() {
            // Echo + strip_specials leaves exactly the content token.
            assert_eq!(recv_tokens(&rrx), vec![10 + i as i32]);
        }
    }

    /// Head-of-line regression: with fewer queued requests than batch
    /// capacity and the sender still alive, the loop must flush a
    /// partial batch instead of waiting indefinitely for a full one.
    /// (If `next_batch` ever regresses to blocking until `capacity`
    /// requests arrive, this test hangs: the sender is never dropped.)
    #[test]
    fn partial_batch_flushes_without_disconnect() {
        let backend = Echo::new(4, 6, true);
        let d = dims(6, 4);
        let (tx, rx) = mpsc::channel::<Request>();
        let mut receivers = Vec::new();
        for i in 0..2 {
            receivers.push(send_request(&tx, vec![1, 20 + i, 2]));
        }
        // NOTE: tx intentionally kept alive — no disconnect to fall back on.
        let stats = serve_loop(&backend, &rx, &d, 2).unwrap();
        assert_eq!(stats.served, 2);
        assert_eq!(stats.received, 2, "requests in == responses out");
        assert_eq!(stats.batches, 1, "both queued requests flush in one partial batch");
        assert!((stats.occupancy - 0.5).abs() < 1e-12, "2 of 4 slots occupied");
        for (i, rrx) in receivers.into_iter().enumerate() {
            assert_eq!(recv_tokens(&rrx), vec![20 + i as i32]);
        }
        drop(tx);
    }

    /// Backend whose translate call always fails: the static loop must
    /// answer the batch with typed `EngineFault`s and keep running.
    struct Broken {
        seq: usize,
    }

    impl TranslateBackend for Broken {
        fn kind(&self) -> &'static str {
            "broken"
        }
        fn batch(&self) -> usize {
            2
        }
        fn seq_len(&self) -> usize {
            self.seq
        }
        fn translate(&self, _src: &[i32]) -> Result<Vec<i32>> {
            anyhow::bail!("matmul exploded")
        }
    }

    #[test]
    fn serve_loop_turns_translate_errors_into_engine_faults() {
        let backend = Broken { seq: 4 };
        let d = dims(4, 2);
        let (tx, rx) = mpsc::channel::<Request>();
        let r0 = send_request(&tx, vec![1, 9, 2]);
        let r1 = send_request(&tx, vec![1, 8, 2]);
        drop(tx);
        let stats = serve_loop(&backend, &rx, &d, 2).unwrap();
        assert_eq!(stats.served, 0);
        assert_eq!(stats.faulted, 2, "the failing batch faults both members");
        assert!(stats.is_balanced(), "{stats:?}");
        for rrx in [r0, r1] {
            match rrx.recv() {
                Some(Err(ServeError::EngineFault(m))) => {
                    assert!(m.contains("matmul exploded"), "fault carries the cause: {m}")
                }
                other => panic!("expected EngineFault, got {other:?}"),
            }
        }
    }

    /// Minimal slot engine for continuous-loop unit tests: admission
    /// stores the framed row; a slot completes after `need` steps
    /// (default 1), output echoes the row.
    struct EchoSlots {
        seq: usize,
        need: usize,
    }

    struct EchoSlot {
        row: Vec<i32>,
        steps: usize,
    }

    impl crate::runtime::SlotEngine for EchoSlots {
        type Slot = EchoSlot;
        fn slot_seq_len(&self) -> usize {
            self.seq
        }
        fn admit(&self, src_row: &[i32]) -> Result<EchoSlot> {
            assert_eq!(src_row.len(), self.seq, "framed admission");
            Ok(EchoSlot { row: src_row.to_vec(), steps: 0 })
        }
        fn step(&self, slots: &mut [&mut EchoSlot]) -> Result<()> {
            for s in slots.iter_mut() {
                s.steps += 1;
            }
            Ok(())
        }
        fn slot_complete(&self, slot: &EchoSlot) -> bool {
            slot.steps >= self.need
        }
        fn slot_output(&self, slot: &EchoSlot) -> Vec<i32> {
            slot.row.clone()
        }
    }

    #[test]
    fn continuous_loop_serves_and_balances() {
        let _gate = crate::obs::test_gate().read().unwrap_or_else(|e| e.into_inner());
        let engine = EchoSlots { seq: 6, need: 1 };
        let d = dims(6, 4);
        let (tx, rx) = mpsc::channel::<Request>();
        let mut receivers = Vec::new();
        for i in 0..5 {
            receivers.push(send_request(&tx, vec![1, 30 + i, 2]));
        }
        drop(tx);
        let stats = serve_loop_continuous(&engine, &rx, &d, 5, &ServeConfig::new(3)).unwrap();
        assert_eq!(stats.served, 5);
        assert_eq!(stats.received, 5, "requests in == responses out");
        assert!(stats.is_balanced(), "{stats:?}");
        assert!(stats.batches >= 2, "5 one-step requests need >= 2 decode steps at capacity 3");
        assert!(stats.occupancy > 0.0 && stats.occupancy <= 1.0);
        assert_eq!(stats.tokens, 5, "one de-framed token per echoed request");
        assert_eq!(stats.latency.count(), 5);
        assert_eq!(stats.queue_wait.count(), 5, "latency split covers every served request");
        assert_eq!(stats.execution.count(), 5);
        assert!(
            (stats.queue_wait.mean() + stats.execution.mean() - stats.latency.mean()).abs() < 1e-6,
            "latency decomposes into queue-wait + execution: {stats:?}"
        );
        for (i, rrx) in receivers.into_iter().enumerate() {
            assert_eq!(
                recv_tokens(&rrx),
                vec![30 + i as i32],
                "responses route to their requester, FIFO"
            );
        }
    }

    /// Slot engine whose output grows by one content token per step —
    /// exercises the incremental streaming deltas.
    struct GrowSlots {
        seq: usize,
        need: usize,
    }

    struct GrowSlot {
        steps: usize,
    }

    impl crate::runtime::SlotEngine for GrowSlots {
        type Slot = GrowSlot;
        fn slot_seq_len(&self) -> usize {
            self.seq
        }
        fn admit(&self, _src_row: &[i32]) -> Result<GrowSlot> {
            Ok(GrowSlot { steps: 0 })
        }
        fn step(&self, slots: &mut [&mut GrowSlot]) -> Result<()> {
            for s in slots.iter_mut() {
                s.steps += 1;
            }
            Ok(())
        }
        fn slot_complete(&self, slot: &GrowSlot) -> bool {
            slot.steps >= self.need
        }
        fn slot_output(&self, slot: &GrowSlot) -> Vec<i32> {
            // BOS, one content token (10 + k) per completed step, EOS,
            // PAD-filled to seq — framed like a real decode buffer.
            let mut out = vec![1];
            out.extend((0..slot.steps).map(|k| 10 + k as i32));
            out.push(2);
            out.resize(self.seq, 0);
            out
        }
    }

    #[test]
    fn continuous_loop_streams_incremental_tokens() {
        let _gate = crate::obs::test_gate().read().unwrap_or_else(|e| e.into_inner());
        use crate::coordinator::fault::StreamEvent;
        let engine = GrowSlots { seq: 6, need: 3 };
        let d = dims(6, 4);
        let (tx, rx) = mpsc::channel::<Request>();
        let (rtx, rrx) = response_channel();
        tx.send(Request::new(vec![1, 5, 2], rtx).with_stream()).unwrap();
        drop(tx);
        let stats = serve_loop_continuous(&engine, &rx, &d, 1, &ServeConfig::new(1)).unwrap();
        assert_eq!(stats.served, 1);
        // The two non-final ticks pushed [10] then [11]; reading after
        // the run coalesces them into one event. The final step's token
        // travels with the terminal Response, which carries the full
        // de-framed output.
        let t = Duration::from_secs(5);
        assert_eq!(rrx.recv_progress(t), StreamEvent::Tokens(vec![10, 11]));
        match rrx.recv_progress(t) {
            StreamEvent::Done(Ok(resp)) => assert_eq!(resp.tokens, vec![10, 11, 12]),
            other => panic!("expected terminal response, got {other:?}"),
        }
    }

    #[test]
    fn continuous_loop_sheds_on_overload() {
        let _gate = crate::obs::test_gate().read().unwrap_or_else(|e| e.into_inner());
        let engine = EchoSlots { seq: 6, need: 1 };
        let d = dims(6, 4);
        let (tx, rx) = mpsc::channel::<Request>();
        // 8 requests pre-queued against a queue bound of 2: the first
        // channel drain happens before any tick, so the queue absorbs 2
        // and the other 6 are shed with an immediate typed rejection.
        let receivers: Vec<ResponseRx> =
            (0..8).map(|i| send_request(&tx, vec![1, 3 + i, 2])).collect();
        drop(tx);
        let mut cfg = ServeConfig::new(1);
        cfg.queue_limit = Some(2);
        let stats = serve_loop_continuous(&engine, &rx, &d, 8, &cfg).unwrap();
        assert_eq!(stats.received, 8);
        assert_eq!(stats.shed, 6, "queue bound 2 absorbs 2 of the burst, 6 shed");
        assert_eq!(stats.served, 2);
        assert!(stats.is_balanced(), "{stats:?}");
        let mut outcomes = [0usize; 2]; // [ok, overloaded]
        for rrx in receivers {
            match rrx.recv() {
                Some(Ok(_)) => outcomes[0] += 1,
                Some(Err(ServeError::Overloaded)) => outcomes[1] += 1,
                other => panic!("unexpected outcome {other:?}"),
            }
        }
        assert_eq!(outcomes, [2, 6], "every request answered exactly once");
    }

    #[test]
    fn continuous_loop_cancels_disconnected_clients() {
        let _gate = crate::obs::test_gate().read().unwrap_or_else(|e| e.into_inner());
        // Slow engine (3 steps per request) so cancellation happens
        // before natural completion; receiver 1 is dropped up-front.
        let engine = EchoSlots { seq: 6, need: 3 };
        let d = dims(6, 4);
        let (tx, rx) = mpsc::channel::<Request>();
        let keep0 = send_request(&tx, vec![1, 7, 2]);
        let orphan = send_request(&tx, vec![1, 8, 2]);
        let keep2 = send_request(&tx, vec![1, 9, 2]);
        drop(orphan); // client walks away before the server even starts
        drop(tx);
        let stats = serve_loop_continuous(&engine, &rx, &d, 3, &ServeConfig::new(2)).unwrap();
        assert_eq!(stats.cancelled, 1, "orphaned request retired, not decoded to EOS");
        assert_eq!(stats.served, 2);
        assert!(stats.is_balanced(), "{stats:?}");
        assert_eq!(recv_tokens(&keep0), vec![7]);
        assert_eq!(recv_tokens(&keep2), vec![9], "slots after the orphan still serve");
    }

    #[test]
    fn continuous_loop_applies_default_deadline() {
        let _gate = crate::obs::test_gate().read().unwrap_or_else(|e| e.into_inner());
        // An engine that never completes a slot: without the server-side
        // default deadline this loop would spin forever.
        let engine = EchoSlots { seq: 6, need: usize::MAX };
        let d = dims(6, 4);
        let (tx, rx) = mpsc::channel::<Request>();
        let rrx = send_request(&tx, vec![1, 5, 2]);
        drop(tx);
        let mut cfg = ServeConfig::new(1);
        cfg.default_limits = RequestLimits::none().with_deadline(4);
        let stats = serve_loop_continuous(&engine, &rx, &d, 1, &cfg).unwrap();
        assert_eq!(stats.expired, 1);
        assert!(stats.is_balanced(), "{stats:?}");
        assert_eq!(rrx.recv(), Some(Err(ServeError::DeadlineExceeded)));
        assert_eq!(stats.batches, 4, "exactly the deadline's worth of decode steps");
    }

    #[test]
    fn continuous_loop_drains_gracefully_on_shutdown() {
        let _gate = crate::obs::test_gate().read().unwrap_or_else(|e| e.into_inner());
        let engine = EchoSlots { seq: 6, need: 2 };
        let d = dims(6, 4);
        let (tx, rx) = mpsc::channel::<Request>();
        let shutdown = ShutdownSignal::new();
        let mut cfg = ServeConfig::new(2);
        cfg.shutdown = Some(shutdown.clone());
        // Client thread: send 3 requests, wait for all outcomes, then
        // signal drain. The server (open-ended n_requests) must exit on
        // its own with balanced books — the join proves it.
        let client = std::thread::spawn(move || {
            let receivers: Vec<ResponseRx> =
                (0..3).map(|i| send_request(&tx, vec![1, 4 + i, 2])).collect();
            let served = receivers.iter().filter(|r| matches!(r.recv(), Some(Ok(_)))).count();
            shutdown.drain();
            served
        });
        let stats = serve_loop_continuous(&engine, &rx, &d, usize::MAX, &cfg).unwrap();
        let served_by_client = client.join().expect("client thread");
        assert_eq!(served_by_client, 3);
        assert_eq!(stats.served, 3);
        assert_eq!(stats.received, 3);
        assert!(stats.is_balanced(), "drain exits with balanced books: {stats:?}");
    }

    /// Regression for the stats double-bookkeeping fix: the returned
    /// `ServeStats` IS the registry snapshot, so the exported metrics
    /// must satisfy the accounting identity and re-deriving the report
    /// from a fresh snapshot must reproduce the returned stats exactly.
    #[test]
    fn continuous_loop_stats_derive_from_exported_metrics() {
        let _gate = crate::obs::test_gate().read().unwrap_or_else(|e| e.into_inner());
        let engine = EchoSlots { seq: 6, need: 3 };
        let d = dims(6, 4);
        let (tx, rx) = mpsc::channel::<Request>();
        // Mixed outcomes: the queue bound of 2 absorbs the first two of
        // four pre-queued requests and sheds the rest; one absorbed
        // client walks away before its slot completes.
        let keep = send_request(&tx, vec![1, 7, 2]);
        let orphan = send_request(&tx, vec![1, 8, 2]);
        let shed: Vec<ResponseRx> = (0..2).map(|i| send_request(&tx, vec![1, 9 + i, 2])).collect();
        drop(orphan);
        drop(tx);
        let mut cfg = ServeConfig::new(1);
        cfg.queue_limit = Some(2);
        let stats = serve_loop_continuous(&engine, &rx, &d, 4, &cfg).unwrap();
        assert_eq!(
            (stats.received, stats.served, stats.shed, stats.cancelled),
            (4, 1, 2, 1),
            "{stats:?}"
        );
        assert!(stats.is_balanced(), "{stats:?}");

        let snap = cfg.obs.registry().snapshot();
        // The exported counters satisfy the same identity the report does…
        let outcome = |o: &str| snap.counter(&key("serve_requests_total", &[("outcome", o)]));
        let terminal: u64 =
            ["served", "shed", "expired", "cancelled", "faulted"].into_iter().map(outcome).sum();
        assert_eq!(snap.counter("serve_received_total"), terminal, "exported serve identity");
        let batcher_terminal: u64 = ["retired", "shed", "expired", "cancelled", "faulted"]
            .into_iter()
            .map(|o| snap.counter(&key("batcher_outcomes_total", &[("outcome", o)])))
            .sum();
        assert_eq!(snap.counter("batcher_submitted_total"), batcher_terminal, "batcher identity");
        // …and re-deriving the report reproduces the returned stats.
        let again = ServeStats::from_snapshot(&snap, stats.wall_s);
        assert_eq!(stats.served, again.served);
        assert_eq!(stats.received, again.received);
        assert_eq!(stats.shed, again.shed);
        assert_eq!(stats.cancelled, again.cancelled);
        assert_eq!(stats.tokens, again.tokens);
        assert_eq!(stats.latency.count(), again.latency.count());
        // Stage attribution: sheds terminate at submit, the queued
        // cancel in queue, the served request in respond.
        let attributed =
            |o, s| snap.counter(&key("serve_outcomes_total", &[("outcome", o), ("stage", s)]));
        assert_eq!(attributed("shed", "submit"), 2);
        assert_eq!(attributed("cancelled", "queue"), 1);
        assert_eq!(attributed("retired", "respond"), 1);
        // Every non-served outcome left a postmortem event in the ring.
        assert_eq!(cfg.obs.ring().len(), 3);
        for rrx in shed {
            assert_eq!(rrx.recv(), Some(Err(ServeError::Overloaded)));
        }
        assert_eq!(recv_tokens(&keep), vec![7]);
    }

    #[test]
    fn serve_loop_stops_on_disconnect() {
        let backend = Echo::new(2, 4, true);
        let d = dims(4, 2);
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        let stats = serve_loop(&backend, &rx, &d, 10).unwrap();
        assert_eq!(stats.served, 0);
        assert_eq!(stats.batches, 0);
        assert_eq!(stats.tokens, 0);
        assert_eq!(stats.latency.count(), 0);
    }

    #[test]
    fn serve_loop_packs_partial_batches_for_variable_shape_backends() {
        let backend = Echo::new(4, 6, false);
        let d = dims(6, 4);
        let (tx, rx) = mpsc::channel::<Request>();
        // A single queued request: the variable-shape path must translate
        // exactly one row (Echo asserts the buffer never exceeds what was
        // packed; a full-capacity pad would be 4 rows).
        let rrx = send_request(&tx, vec![1, 42, 2]);
        drop(tx);
        let stats = serve_loop(&backend, &rx, &d, 1).unwrap();
        assert_eq!(stats.served, 1);
        assert_eq!(backend.last_len.get(), 6, "one row packed, not the full capacity");
        assert_eq!(recv_tokens(&rrx), vec![42]);
    }
}
