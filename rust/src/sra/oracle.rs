//! Compression-backed accuracy oracles for the SRA search.
//!
//! The paper's oracle is BLEU through the PJRT runtime; everything below
//! that — which layers get compressed, at which rank, at what cost — is
//! runtime-independent, so this module provides the *proxy* oracle used by
//! tests, benches and the synthetic search loops: model accuracy is
//! approximated by the negative root-sum-square of the per-layer
//! approximation errors (lower total compression error == higher score,
//! monotone in every layer's rank — the same structure the BLEU surface
//! has on the calibration set).
//!
//! Two interchangeable backends:
//!
//! * **cached** — fills a [`CompressionCache`] once per `(layer, wl)` (in
//!   parallel on the shared pool) and answers every rank probe from the
//!   recorded residual trace: the SRA inner loop becomes O(1) lookups.
//! * **recompute** — runs Algorithm 1 from scratch for every layer of
//!   every probed allocation: the pre-cache behavior, kept so the
//!   regression tests can pin score equality and the >=5x cost win.

use crate::compress::{self, CompressionCache};
use crate::quant::WordLen;
use crate::tensor::Matrix;

use super::{run, SraConfig, SraResult};

/// Proxy accuracy oracle over a slice of layer weight matrices.
pub struct ProxyOracle<'a> {
    weights: &'a [Matrix],
    wl: WordLen,
    /// `Some` = cached backend, `None` = recompute backend.
    cache: Option<CompressionCache>,
    /// Matvec-equivalents spent (cache fills or per-eval recompressions).
    matvec_equivalents: u64,
    /// Algorithm 1 invocations by the recompute backend.
    recompressions: u64,
    evals: usize,
}

impl<'a> ProxyOracle<'a> {
    /// Cache-backed oracle: compresses each layer exactly once (at
    /// `r_max`, fanned out over `workers` threads) up front.
    pub fn cached(weights: &'a [Matrix], wl: WordLen, workers: usize) -> ProxyOracle<'a> {
        let refs: Vec<&Matrix> = weights.iter().collect();
        let mut cache = CompressionCache::new();
        cache.fill_all(&refs, wl, workers);
        let fill_cost = cache.fill_cost();
        ProxyOracle {
            weights,
            wl,
            cache: Some(cache),
            matvec_equivalents: fill_cost,
            recompressions: 0,
            evals: 0,
        }
    }

    /// Recompute-backed oracle (the path the cache replaces).
    pub fn recompute(weights: &'a [Matrix], wl: WordLen) -> ProxyOracle<'a> {
        ProxyOracle {
            weights,
            wl,
            cache: None,
            matvec_equivalents: 0,
            recompressions: 0,
            evals: 0,
        }
    }

    /// Total matvec-equivalent work performed so far (including any
    /// up-front cache fill).
    pub fn matvec_equivalents(&self) -> u64 {
        self.matvec_equivalents
    }

    /// Full Algorithm 1 runs performed so far.
    pub fn compressions(&self) -> u64 {
        match &self.cache {
            Some(c) => c.fills(),
            None => self.recompressions,
        }
    }

    pub fn evals(&self) -> usize {
        self.evals
    }

    /// Per-layer rank caps (`min(K, N)`).
    pub fn caps(&self) -> Vec<usize> {
        self.weights.iter().map(|w| w.rows().min(w.cols())).collect()
    }

    fn layer_error(&mut self, i: usize, r: usize) -> f32 {
        match &self.cache {
            Some(c) => c
                .error_at(i, self.wl, r)
                .expect("cache filled for every layer at construction"),
            None => {
                let (_, trace) = compress::itera(&self.weights[i], r, self.wl);
                self.matvec_equivalents += trace.matvec_equivalents;
                self.recompressions += 1;
                *trace.residual_norms.last().unwrap()
            }
        }
    }

    /// Proxy accuracy of an allocation: negative root-sum-square of the
    /// per-layer approximation errors (an inherent method rather than an
    /// `AccuracyOracle` impl — the crate's blanket `FnMut` oracle impl
    /// would conflict; adapt with a closure, see [`Self::run_search`]).
    pub fn evaluate(&mut self, ranks: &[usize]) -> f64 {
        assert_eq!(ranks.len(), self.weights.len());
        self.evals += 1;
        let mut sum = 0.0f64;
        for (i, &r) in ranks.iter().enumerate() {
            let e = self.layer_error(i, r) as f64;
            sum += e * e;
        }
        -sum.sqrt()
    }

    /// Run the SRA search against this oracle (caps from the layer shapes).
    pub fn run_search(&mut self, budget: usize, cfg: &SraConfig) -> SraResult {
        let caps = self.caps();
        let mut f = |ranks: &[usize]| self.evaluate(ranks);
        run(&mut f, budget, &caps, cfg)
    }
}

/// Convenience: SRA search over `weights` with the cache-backed proxy
/// oracle. Returns the search result plus the oracle (for cost
/// introspection: `compressions() == weights.len()` always holds).
pub fn run_cached_proxy<'a>(
    weights: &'a [Matrix],
    wl: WordLen,
    budget: usize,
    cfg: &SraConfig,
    workers: usize,
) -> (SraResult, ProxyOracle<'a>) {
    let mut oracle = ProxyOracle::cached(weights, wl, workers);
    let res = oracle.run_search(budget, cfg);
    (res, oracle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn layers(n: usize, lo: usize, hi: usize) -> Vec<Matrix> {
        let mut rng = Pcg64::new(0xACE);
        (0..n)
            .map(|i| {
                let k = lo + (i * 3) % (hi - lo + 1);
                let m = lo + (i * 5) % (hi - lo + 1);
                Matrix::randn(k, m, &mut rng).scale(0.2)
            })
            .collect()
    }

    #[test]
    fn cached_scores_equal_recompute_scores() {
        let ws = layers(4, 8, 14);
        let mut cached = ProxyOracle::cached(&ws, 4, 2);
        let mut recompute = ProxyOracle::recompute(&ws, 4);
        let caps = cached.caps();
        for probe in [1usize, 2, 3] {
            let ranks: Vec<usize> = caps.iter().map(|&c| (c / probe).max(1)).collect();
            let a = cached.evaluate(&ranks);
            let b = recompute.evaluate(&ranks);
            assert_eq!(a, b, "ranks {ranks:?}");
        }
        assert_eq!(cached.compressions(), ws.len() as u64);
        assert!(recompute.compressions() > cached.compressions());
    }

    #[test]
    fn cached_search_fills_each_layer_once() {
        let ws = layers(5, 8, 12);
        let total: usize = ws.iter().map(|w| w.rows().min(w.cols())).sum();
        let (res, oracle) = run_cached_proxy(&ws, 4, total / 2, &SraConfig::default(), 2);
        assert_eq!(res.ranks.len(), ws.len());
        assert_eq!(
            oracle.compressions(),
            ws.len() as u64,
            "every (layer, wl) compressed at most once"
        );
        assert!(res.evals > ws.len(), "search must actually probe");
    }
}
