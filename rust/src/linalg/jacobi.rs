//! Full SVD via one-sided Jacobi rotations.
//!
//! One-sided Jacobi (Demmel [21], §5.4.3) orthogonalizes the columns of `A`
//! by plane rotations accumulated into `V`; on convergence the column norms
//! are the singular values and the normalized columns form `U`. Chosen over
//! Golub–Kahan bidiagonalization for robustness and simplicity: the weight
//! matrices here are at most 512x512 and the full SVD is off the hot path
//! (Algorithm 1 uses `svd_top1`).

use crate::tensor::Matrix;

/// Full singular value decomposition `A = U * diag(S) * Vt`.
#[derive(Debug, Clone)]
pub struct Svd {
    /// Left singular vectors, `m x min(m,n)` (columns orthonormal).
    pub u: Matrix,
    /// Singular values, descending, length `min(m,n)`.
    pub s: Vec<f32>,
    /// Right singular vectors transposed, `min(m,n) x n` (rows orthonormal).
    pub vt: Matrix,
}

const MAX_SWEEPS: usize = 60;
const TOL: f64 = 1e-10;

/// Compute the thin SVD of `a`.
pub fn svd(a: &Matrix) -> Svd {
    let (m, n) = a.shape();
    if m < n {
        // Work on the transpose and swap the factors back.
        let t = svd(&a.transpose());
        return Svd { u: t.vt.transpose(), s: t.s, vt: t.u.transpose() };
    }
    // Work in f64: repeated rotations on f32 accumulate error fast enough to
    // matter for the orthogonality property tests.
    let mut w: Vec<f64> = a.data().iter().map(|&x| x as f64).collect(); // m x n
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let col_dot = |w: &[f64], p: usize, q: usize| -> f64 {
        let mut s = 0.0;
        for i in 0..m {
            s += w[i * n + p] * w[i * n + q];
        }
        s
    };

    for _sweep in 0..MAX_SWEEPS {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let app = col_dot(&w, p, p);
                let aqq = col_dot(&w, q, q);
                let apq = col_dot(&w, p, q);
                if apq.abs() <= TOL * (app * aqq).sqrt() {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let wp = w[i * n + p];
                    let wq = w[i * n + q];
                    w[i * n + p] = c * wp - s * wq;
                    w[i * n + q] = s * wp + c * wq;
                }
                for i in 0..n {
                    let vp = v[i * n + p];
                    let vq = v[i * n + q];
                    v[i * n + p] = c * vp - s * vq;
                    v[i * n + q] = s * vp + c * vq;
                }
            }
        }
        if off == 0.0 {
            break;
        }
    }

    // Singular values = column norms; normalize columns into U.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = (0..n)
        .map(|j| (0..m).map(|i| w[i * n + j] * w[i * n + j]).sum::<f64>().sqrt())
        .collect();
    order.sort_by(|&a, &b| norms[b].partial_cmp(&norms[a]).unwrap());

    let mut u = Matrix::zeros(m, n);
    let mut s = vec![0.0f32; n];
    let mut vt = Matrix::zeros(n, n);
    for (k, &j) in order.iter().enumerate() {
        let nj = norms[j];
        s[k] = nj as f32;
        if nj > 0.0 {
            for i in 0..m {
                u.set(i, k, (w[i * n + j] / nj) as f32);
            }
        }
        for i in 0..n {
            vt.set(k, i, v[i * n + j] as f32);
        }
    }
    Svd { u, s, vt }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn check_orthonormal_cols(m: &Matrix, tol: f32) {
        for p in 0..m.cols() {
            for q in p..m.cols() {
                let d = crate::tensor::dot(&m.col(p), &m.col(q));
                let want = if p == q { 1.0 } else { 0.0 };
                assert!((d - want).abs() < tol, "col dot ({p},{q}) = {d}");
            }
        }
    }

    #[test]
    fn diagonal_matrix() {
        let a = Matrix::from_vec(3, 3, vec![3., 0., 0., 0., 5., 0., 0., 0., 1.]);
        let d = svd(&a);
        assert!((d.s[0] - 5.0).abs() < 1e-5);
        assert!((d.s[1] - 3.0).abs() < 1e-5);
        assert!((d.s[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn orthogonality_and_reconstruction_tall() {
        let mut rng = Pcg64::new(20);
        let a = Matrix::randn(12, 5, &mut rng);
        let d = svd(&a);
        check_orthonormal_cols(&d.u, 1e-4);
        check_orthonormal_cols(&d.vt.transpose(), 1e-4);
        let rec = crate::linalg::reconstruct(&d, 5);
        assert!(rec.sub(&a).frob_norm() < 1e-4 * a.frob_norm());
    }

    #[test]
    fn wide_matrix_via_transpose() {
        let mut rng = Pcg64::new(21);
        let a = Matrix::randn(4, 9, &mut rng);
        let d = svd(&a);
        assert_eq!(d.u.shape(), (4, 4));
        assert_eq!(d.vt.shape(), (4, 9));
        let rec = crate::linalg::reconstruct(&d, 4);
        assert!(rec.sub(&a).frob_norm() < 1e-4 * a.frob_norm());
    }

    #[test]
    fn singular_values_descending_nonnegative() {
        let mut rng = Pcg64::new(22);
        let a = Matrix::randn(10, 10, &mut rng);
        let d = svd(&a);
        for k in 1..d.s.len() {
            assert!(d.s[k - 1] >= d.s[k] - 1e-6);
            assert!(d.s[k] >= 0.0);
        }
    }

    #[test]
    fn rank_deficient() {
        // rank-2 matrix: outer products
        let u1 = vec![1.0f32, 2.0, 3.0, 4.0];
        let v1 = vec![1.0f32, -1.0, 0.5];
        let mut a = crate::tensor::outer(&u1, &v1);
        let u2 = vec![0.5f32, -0.5, 1.0, 0.0];
        let v2 = vec![0.2f32, 0.8, -0.3];
        a = a.add(&crate::tensor::outer(&u2, &v2));
        let d = svd(&a);
        assert!(d.s[2] < 1e-4, "third sv should vanish: {:?}", d.s);
        let rec = crate::linalg::reconstruct(&d, 2);
        assert!(rec.sub(&a).frob_norm() < 1e-4);
    }

    #[test]
    fn frobenius_matches_sv_norm() {
        let mut rng = Pcg64::new(23);
        let a = Matrix::randn(7, 7, &mut rng);
        let d = svd(&a);
        let sv_norm: f32 = d.s.iter().map(|s| s * s).sum::<f32>().sqrt();
        assert!((sv_norm - a.frob_norm()).abs() < 1e-3);
    }
}
