//! PCG-XSH-RR 64/32 pseudo-random number generator.
//!
//! The image vendors no `rand` crate, so the library carries its own small,
//! fully deterministic PRNG (O'Neill's PCG family). Determinism matters
//! beyond reproducibility: the SRA search, calibration sampling and the
//! property-test framework all key off explicit seeds so experiment tables
//! in EXPERIMENTS.md regenerate bit-identically.

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output, period 2^64.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Seed with an arbitrary value; `stream` selects an independent
    /// sequence (useful to decorrelate e.g. per-layer noise).
    pub fn seeded(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn new(seed: u64) -> Self {
        Self::seeded(seed, 0xda3e39cb94b95bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) (Lemire's unbiased method, simplified
    /// rejection variant).
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        let bound = bound as u64;
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return (r % bound) as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        let k = k.min(n);
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_bounds_and_covers() {
        let mut r = Pcg64::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(3);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut uniq = s.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 20);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
