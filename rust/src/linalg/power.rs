//! Leading singular triplet via alternating power iteration.
//!
//! Algorithm 1 (`SVD(R)_1`) needs only the rank-1 approximation of the
//! residual at each refinement step. Alternating iteration
//! `u <- R v / |R v|`, `v <- R^T u / |R^T u|` converges geometrically at
//! rate (σ2/σ1)² and costs two mat-vecs per sweep — the dominant cost of
//! the whole compression engine, so it is kept allocation-free per sweep.

use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

/// Leading singular triplet `(sigma, u, v)` with `|u| = |v| = 1`.
#[derive(Debug, Clone)]
pub struct TopTriplet {
    pub sigma: f32,
    pub u: Vec<f32>,
    pub v: Vec<f32>,
}

const MAX_ITERS: usize = 300;
const REL_TOL: f64 = 1e-9;

/// Compute the leading singular triplet of `a`.
///
/// Deterministic: the start vector is seeded from `seed` so compression
/// runs reproduce bit-identically. Falls back to a zero triplet for an
/// all-zero matrix (residual fully consumed).
pub fn svd_top1(a: &Matrix, seed: u64) -> TopTriplet {
    let (m, n) = a.shape();
    let mut rng = Pcg64::seeded(seed, 0x5eed);
    // Start from the largest-norm row's direction when available — cheap
    // spectral hint that shaves iterations on outlier-heavy weights.
    let mut v: Vec<f32> = {
        let mut best = 0usize;
        let mut best_n = -1.0f32;
        for i in 0..m {
            let nrm = crate::tensor::norm2(a.row(i));
            if nrm > best_n {
                best_n = nrm;
                best = i;
            }
        }
        if best_n <= 0.0 {
            return TopTriplet { sigma: 0.0, u: vec![0.0; m], v: vec![0.0; n] };
        }
        a.row(best).to_vec()
    };
    let nv = crate::tensor::norm2(&v);
    if nv == 0.0 {
        for x in v.iter_mut() {
            *x = rng.normal();
        }
    }
    normalize(&mut v);

    let mut u = vec![0.0f32; m];
    let mut sigma_prev = 0.0f64;
    let mut sigma = 0.0f64;
    for _ in 0..MAX_ITERS {
        // u <- A v
        u = a.matvec(&v);
        let un = crate::tensor::norm2(&u);
        if un == 0.0 {
            return TopTriplet { sigma: 0.0, u: vec![0.0; m], v };
        }
        crate::tensor::scale(&mut u, 1.0 / un);
        // v <- A^T u
        v = a.tr_matvec(&u);
        let vn = crate::tensor::norm2(&v);
        if vn == 0.0 {
            return TopTriplet { sigma: 0.0, u, v: vec![0.0; n] };
        }
        crate::tensor::scale(&mut v, 1.0 / vn);
        sigma = vn as f64;
        if (sigma - sigma_prev).abs() <= REL_TOL * sigma.max(1e-30) {
            break;
        }
        sigma_prev = sigma;
    }
    TopTriplet { sigma: sigma as f32, u, v }
}

fn normalize(x: &mut [f32]) {
    let n = crate::tensor::norm2(x);
    if n > 0.0 {
        crate::tensor::scale(x, 1.0 / n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd;

    #[test]
    fn matches_jacobi_on_random() {
        let mut rng = Pcg64::new(30);
        for trial in 0..5 {
            let a = Matrix::randn(9 + trial, 7, &mut rng);
            let full = svd(&a);
            let top = svd_top1(&a, trial as u64);
            assert!(
                (top.sigma - full.s[0]).abs() < 1e-3 * full.s[0],
                "sigma {} vs {}",
                top.sigma,
                full.s[0]
            );
            // Rank-1 approximations agree up to sign.
            let dot_u = crate::tensor::dot(&top.u, &full.u.col(0));
            assert!(dot_u.abs() > 0.999, "u alignment {dot_u}");
        }
    }

    #[test]
    fn rank1_matrix_exact() {
        let u = vec![0.6f32, 0.8];
        let v = vec![0.0f32, 1.0, 0.0];
        let a = crate::tensor::outer(&u, &v).scale(7.0);
        let t = svd_top1(&a, 0);
        assert!((t.sigma - 7.0).abs() < 1e-4);
        let rec = crate::tensor::outer(&t.u, &t.v).scale(t.sigma);
        assert!(rec.sub(&a).frob_norm() < 1e-4);
    }

    #[test]
    fn zero_matrix() {
        let a = Matrix::zeros(4, 5);
        let t = svd_top1(&a, 1);
        assert_eq!(t.sigma, 0.0);
    }

    #[test]
    fn unit_norm_outputs() {
        let mut rng = Pcg64::new(31);
        let a = Matrix::randn(6, 6, &mut rng);
        let t = svd_top1(&a, 2);
        assert!((crate::tensor::norm2(&t.u) - 1.0).abs() < 1e-5);
        assert!((crate::tensor::norm2(&t.v) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn deterministic_across_calls() {
        let mut rng = Pcg64::new(32);
        let a = Matrix::randn(8, 8, &mut rng);
        let t1 = svd_top1(&a, 9);
        let t2 = svd_top1(&a, 9);
        assert_eq!(t1.sigma, t2.sigma);
        assert_eq!(t1.u, t2.u);
    }
}
