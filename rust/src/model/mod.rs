//! Rust-side model description: artifact manifest + weight store.
//!
//! `make artifacts` (the one-time Python compile path) trains the tiny
//! OPUS-MT-style models and records everything the coordinator needs in
//! `artifacts/manifest.json`: compressed-linear inventory (the layer index
//! space shared with SRA and the hardware DSE), the exact positional
//! argument order of each compiled HLO variant, and per-language-pair
//! weight/corpus/calibration registries.

mod manifest;
mod weights;

pub use manifest::{ArtifactSet, LinearInfo, Manifest, ModelDims, PairInfo};
pub use weights::WeightStore;

use anyhow::Context;

use crate::tensor::Matrix;

/// A loaded language-pair model: weights + calibration ranges.
pub struct PairModel {
    pub pair: String,
    pub weights: WeightStore,
    /// Per compressed-linear activation max-abs from offline calibration.
    pub act_maxabs: Vec<f32>,
}

impl PairModel {
    /// Load the trained model for `pair` from the artifact registry.
    pub fn load(manifest: &Manifest, pair: &str) -> anyhow::Result<PairModel> {
        let info = manifest
            .pairs
            .get(pair)
            .ok_or_else(|| anyhow::anyhow!("unknown language pair {pair}"))?;
        let weights = WeightStore::load(&info.weights)?;
        weights.check_finite().with_context(|| {
            format!("weight store {:?} (pair {pair}) failed load-time validation", info.weights)
        })?;
        for l in &manifest.linears {
            anyhow::ensure!(
                weights.get(&l.name).map(|m| m.shape()) == Some((l.k, l.n)),
                "weight store {:?} missing or mis-shaped linear {} (expected {}x{})",
                info.weights,
                l.name,
                l.k,
                l.n
            );
        }
        Ok(PairModel {
            pair: pair.to_string(),
            weights,
            act_maxabs: info.act_maxabs.clone(),
        })
    }

    /// Original FP32 weight matrix of compressed linear `name`.
    pub fn linear(&self, name: &str) -> &Matrix {
        self.weights
            .get(name)
            .unwrap_or_else(|| panic!("weight {name} missing from store"))
    }
}
